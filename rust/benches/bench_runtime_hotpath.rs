//! L3 hot-path microbenchmarks: DES simulation throughput (events/s),
//! scheduler solve latency, PJRT dispatch latency, and the gradient
//! reduction path (Rust loop vs the AOT Pallas `grad_reduce` executable).
//!
//! These are the §Perf numbers recorded in EXPERIMENTS.md. The PJRT rows
//! self-skip when artifacts are missing.

use deft::bench::{run_pipeline_opts, time_it, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::links::ClusterEnv;
use deft::metrics::Table;
use deft::runtime::{ArtifactManifest, Engine, HostTensor};

fn main() {
    let env = ClusterEnv::paper_testbed();
    let mut t = Table::new(&["benchmark", "median", "derived"]);

    // --- DES throughput (metric-only path: no span recording) ---
    let w = workload_by_name("gpt2").expect("gpt2 workload");
    for (label, iters) in [("sim 100 iters (gpt2/deft)", 100usize), ("sim 400 iters", 400)] {
        let (med, _) = time_it(1, 5, || {
            std::hint::black_box(
                run_pipeline_opts(
                    &w,
                    Scheme::Deft,
                    &env,
                    PAPER_PARTITION,
                    PAPER_DDP_MB,
                    iters,
                    false,
                )
                .expect("pipeline"),
            );
        });
        let r = run_pipeline_opts(
            &w,
            Scheme::Deft,
            &env,
            PAPER_PARTITION,
            PAPER_DDP_MB,
            iters,
            false,
        )
        .expect("pipeline");
        let events = r.sim.events_processed;
        t.row(&[
            label.into(),
            format!("{:.2} ms", med * 1e3),
            format!("{:.2} M events/s", events as f64 / med / 1e6),
        ]);
    }

    // --- scheduler solve latency (steady-state planning) ---
    for scheme in [Scheme::UsByte, Scheme::Deft] {
        let buckets = deft::partition::partition(
            &w,
            deft::partition::Strategy::DeftConstrained {
                partition_size: PAPER_PARTITION,
            },
            &env,
        )
        .expect("partition");
        let s = deft::bench::scheduler_for(scheme, true, &env);
        let (med, _) = time_it(2, 10, || {
            std::hint::black_box(s.schedule(&buckets));
        });
        t.row(&[
            format!("schedule solve ({})", scheme.name()),
            format!("{:.3} ms", med * 1e3),
            format!("{} buckets", buckets.len()),
        ]);
    }

    // --- PJRT paths (need artifacts) ---
    if std::path::Path::new("artifacts/manifest.toml").exists() {
        let m = ArtifactManifest::load(std::path::Path::new("artifacts/manifest.toml")).unwrap();
        let engine = Engine::cpu().unwrap();
        let reduce = engine.load(m.exe("grad_reduce").unwrap()).unwrap();
        let workers = m.meta_usize("workers").unwrap();
        let sizes: Vec<usize> = reduce
            .spec
            .inputs
            .iter()
            .map(|s| s.elements() / workers)
            .collect();
        let total: usize = sizes.iter().sum();

        let stacked: Vec<Vec<f32>> = reduce
            .spec
            .inputs
            .iter()
            .map(|s| vec![0.5f32; s.elements()])
            .collect();

        // PJRT grad_reduce (Pallas bucket_reduce kernel, AOT).
        let inputs: Vec<HostTensor> = stacked.iter().cloned().map(HostTensor::F32).collect();
        let (med_pjrt, _) = time_it(2, 10, || {
            std::hint::black_box(reduce.run(&inputs).unwrap());
        });
        t.row(&[
            "grad_reduce via PJRT (Pallas)".into(),
            format!("{:.3} ms", med_pjrt * 1e3),
            format!(
                "{:.2} GB/s effective",
                (total * workers * 4) as f64 / med_pjrt / 1e9
            ),
        ]);

        // Equivalent Rust loop (zip-based, matching the trainer's
        // `Trainer::allreduce` so the comparison reflects production).
        let (med_rust, _) = time_it(2, 10, || {
            let mut out: Vec<Vec<f32>> = sizes.iter().map(|&s| vec![0.0; s]).collect();
            for (b, slab) in stacked.iter().enumerate() {
                let n = sizes[b];
                for wk in 0..workers {
                    let src = &slab[wk * n..(wk + 1) * n];
                    for (a, x) in out[b].iter_mut().zip(src) {
                        *a += *x;
                    }
                }
                let inv = 1.0 / workers as f32;
                for a in out[b].iter_mut() {
                    *a *= inv;
                }
            }
            std::hint::black_box(out);
        });
        t.row(&[
            "grad_reduce in Rust loop".into(),
            format!("{:.3} ms", med_rust * 1e3),
            format!(
                "{:.2} GB/s effective",
                (total * workers * 4) as f64 / med_rust / 1e9
            ),
        ]);

        // train_step dispatch latency (full fwd+bwd of the small model).
        let step = engine.load(m.exe("train_step").unwrap()).unwrap();
        let init: Vec<Vec<f32>> = m.meta["init_files"]
            .split(';')
            .map(|f| {
                std::fs::read(m.dir.join(f))
                    .unwrap()
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect()
            })
            .collect();
        let batch = m.meta_usize("batch").unwrap();
        let seq = m.meta_usize("seq").unwrap();
        let mut step_inputs: Vec<HostTensor> =
            init.iter().cloned().map(HostTensor::F32).collect();
        step_inputs.push(HostTensor::I32(vec![1i32; batch * (seq + 1)]));
        let (med_step, _) = time_it(1, 5, || {
            std::hint::black_box(step.run(&step_inputs).unwrap());
        });
        let params: usize = sizes.iter().sum();
        let flops = 6.0 * params as f64 * (batch * seq) as f64;
        t.row(&[
            "train_step fwd+bwd via PJRT".into(),
            format!("{:.1} ms", med_step * 1e3),
            format!("{:.2} GFLOP/s", flops / med_step / 1e9),
        ]);
    } else {
        t.row(&[
            "PJRT benches".into(),
            "SKIPPED".into(),
            "run `make artifacts`".into(),
        ]);
    }

    println!("=== L3 hot-path microbenchmarks ===\n\n{}", t.render());
}
