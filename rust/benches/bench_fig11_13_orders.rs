//! Paper **Figs. 11–13** — bucket scheduling orders of the four schemes
//! on ResNet-101 (Fig. 11), VGG-19 (Fig. 12) and GPT-2 (Fig. 13),
//! rendered as ASCII Gantt charts over one steady-state window; plus the
//! Table III feature matrix header.
//!
//! Expected shapes (paper):
//!  * DDP: all comm in the backward/gap window, big bubbles before fwd.
//!  * Bytescheduler/US-Byte: comm spills into the forward window, fewer
//!    bubbles, still capped by CR.
//!  * DeFT: two links busy concurrently, forward never stalls (delayed
//!    updates), bucket #1's comm moved into the next forward window.

use deft::bench::{run_pipeline, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::links::ClusterEnv;
use deft::metrics::gantt_steady;
use deft::sched::feature_matrix;

fn main() {
    println!("=== Table III (feature matrix) ===\n{}", feature_matrix());
    let env = ClusterEnv::paper_testbed();
    for (fig, wname) in [("Fig. 11", "resnet101"), ("Fig. 12", "vgg19"), ("Fig. 13", "gpt2")] {
        let w = workload_by_name(wname).expect("workload");
        println!("\n=== {fig}: bucket scheduling orders, {} ===", w.name);
        let mut schemes = Scheme::ALL.to_vec();
        schemes.push(Scheme::DeftNoMultilink);
        for scheme in schemes {
            let r = run_pipeline(&w, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB, 40)
                .expect("pipeline");
            println!(
                "\n--- {} | buckets {} | iter {} | bubbles {:.1}% | upd/iter {:.2} ---",
                scheme.name(),
                r.buckets.len(),
                r.sim.steady_iter_time,
                r.sim.bubble_ratio() * 100.0,
                r.schedule.update_frequency(),
            );
            println!("{}", gantt_steady(&r.sim, r.schedule.cycle.len(), 112));
        }
    }
}
