//! DeFT mechanism ablation (DESIGN.md design-choice index): how much of
//! the speedup comes from each of the three techniques the paper stacks?
//!
//!   A. baseline: US-Byte (non-sequential order, no dependency relaxing)
//!   B. + delayed updates only (DeFT, single link, preserver off)
//!   C. + heterogeneous links (DeFT, multi-link, preserver off)
//!   D. + Preserver feedback (full DeFT)
//!
//! Also sweeps the recursive knapsack (Alg. 1) against a naive-only
//! variant by comparing packed overlap on the backward stage instances.

use deft::bench::{run_pipeline, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::links::ClusterEnv;
use deft::metrics::Table;
use deft::models::vgg19_table2_buckets;
use deft::partition::{partition, Strategy};
use deft::sched::{Deft, DeftOptions, Scheduler};
use deft::sim::{simulate, SimOptions};
use deft::solver::{naive_knapsack, recursive_knapsack, Item};
use deft::util::Micros;

fn main() {
    let env = ClusterEnv::paper_testbed();
    for wname in ["resnet101", "vgg19", "gpt2"] {
        let w = workload_by_name(wname).expect("workload");
        println!("=== DeFT mechanism ablation, {} ===\n", w.name);
        let mut t = Table::new(&["variant", "iter time", "bubble %", "upd/iter", "vs us-byte"]);

        let base = run_pipeline(&w, Scheme::UsByte, &env, PAPER_PARTITION, PAPER_DDP_MB, 40)
            .expect("pipeline");
        let base_t = base.sim.steady_iter_time;
        t.row(&[
            "A: us-byte (no dependency relaxing)".into(),
            format!("{base_t}"),
            format!("{:.1}", base.sim.bubble_ratio() * 100.0),
            "1.00".into(),
            "1.00x".into(),
        ]);

        let buckets = partition(
            &w,
            Strategy::DeftConstrained {
                partition_size: PAPER_PARTITION,
            },
            &env,
        )
        .expect("partition");
        let variants: Vec<(&str, Deft)> = vec![
            ("B: + delayed updates (single link)", Deft::without_multilink()),
            (
                "C: + heterogeneous links",
                Deft::new(DeftOptions {
                    preserver: false,
                    ..DeftOptions::default()
                }),
            ),
            ("D: + preserver feedback (full DeFT)", Deft::new(DeftOptions::default())),
        ];
        for (label, deft) in variants {
            let schedule = deft.schedule(&buckets);
            let sim = simulate(
                &buckets,
                &schedule,
                &env,
                &SimOptions {
                    iterations: (schedule.cycle.len() * 6).max(40),
                    warmup: schedule.cycle.len().max(4),
                    record_timeline: true,
                },
            );
            t.row(&[
                label.into(),
                format!("{}", sim.steady_iter_time),
                format!("{:.1}", sim.bubble_ratio() * 100.0),
                format!("{:.2}", schedule.update_frequency()),
                format!("{:.2}x", base_t.ratio(sim.steady_iter_time)),
            ]);
        }
        println!("{}", t.render());
    }

    // --- Algorithm 1 vs naive-only on backward-stage instances. ---
    println!("=== Alg. 1 (recursive) vs naive knapsack on backward instances ===\n");
    let mut t = Table::new(&["instance", "naive packed", "recursive packed", "gain"]);
    let tbl2 = vgg19_table2_buckets();
    // Backward readiness order: buckets n-1 .. 1, release = own bwd.
    let items: Vec<Item> = tbl2[1..]
        .iter()
        .rev()
        .map(|b| Item::new(b.id, b.comm))
        .collect();
    let release: Vec<Micros> = tbl2[1..].iter().rev().map(|b| b.bwd).collect();
    let caps = [
        Micros(30_000),
        Micros(60_000),
        Micros(93_119),
        Micros(130_000),
    ];
    for cap in caps {
        let n = naive_knapsack(&items, cap);
        let r = recursive_knapsack(&items, &release, cap);
        t.row(&[
            format!("vgg19-table2 bwd, cap {cap}"),
            format!("{}", n.total),
            format!("{}", r.total),
            format!(
                "{:+.1}%",
                (r.total.as_us() as f64 / n.total.as_us().max(1) as f64 - 1.0) * 100.0
            ),
        ]);
    }
    println!("{}", t.render());
}
