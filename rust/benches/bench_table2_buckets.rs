//! Paper **Table II** — per-bucket communication/computation times of
//! VGG-19 at partition size 6,500,000: the published measurement verbatim
//! (the scheduling instance every figure reuses), side by side with the
//! bucket profile our own partition + link model produces.

use deft::bench::PAPER_PARTITION;
use deft::links::ClusterEnv;
use deft::metrics::Table;
use deft::models::{vgg19, vgg19_table2_buckets};
use deft::partition::{partition, Strategy};
use deft::util::Micros;

fn main() {
    println!("=== Table II: VGG-19 bucket times (partition 6.5M) ===\n");
    println!("-- paper measurement (verbatim) --");
    let mut t = Table::new(&["bucket", "forward(us)", "backward(us)", "communication(us)"]);
    let paper = vgg19_table2_buckets();
    for b in &paper {
        t.row(&[
            format!("{}", b.id + 1),
            b.fwd.as_us().to_string(),
            b.bwd.as_us().to_string(),
            b.comm.as_us().to_string(),
        ]);
    }
    let (f, bw, c): (Micros, Micros, Micros) = paper.iter().fold(
        (Micros::ZERO, Micros::ZERO, Micros::ZERO),
        |(a, b, cc), x| (a + x.fwd, b + x.bwd, cc + x.comm),
    );
    t.row(&[
        "total".into(),
        f.as_us().to_string(),
        bw.as_us().to_string(),
        format!("{} (paper total row: 257725 — 10ms row misprint)", c.as_us()),
    ]);
    println!("{}", t.render());

    println!("-- our layer model partitioned US-Byte-style at 6.5M --");
    let w = vgg19();
    let buckets = partition(
        &w,
        Strategy::UsByte {
            partition_size: PAPER_PARTITION,
        },
        &ClusterEnv::paper_testbed(),
    )
    .expect("partition");
    let mut t2 = Table::new(&["bucket", "params", "forward(us)", "backward(us)", "comm(us)"]);
    for b in &buckets {
        t2.row(&[
            format!("{}", b.id + 1),
            b.params.to_string(),
            b.fwd.as_us().to_string(),
            b.bwd.as_us().to_string(),
            b.comm.as_us().to_string(),
        ]);
    }
    println!("{}", t2.render());
    println!(
        "shape check: the fc6 bucket dominates comm ({}% of total) as in the paper's bucket #4.",
        buckets.iter().map(|b| b.comm.as_us()).max().unwrap() * 100
            / buckets.iter().map(|b| b.comm.as_us()).sum::<u64>()
    );
}
