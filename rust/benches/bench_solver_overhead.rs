//! Paper §III.C claim — "in all experiments we conducted, the [solver]
//! overheads were always less than 1 second" — plus solver-quality
//! ablations: the greedy heuristics vs the exact branch-and-bound oracle
//! on real workload instances.

use deft::bench::{time_it, workload_by_name, PAPER_PARTITION};
use deft::links::ClusterEnv;
use deft::metrics::Table;
use deft::models::vgg19_table2_buckets;
use deft::partition::{partition, Strategy};
use deft::sched::{Deft, DeftOptions, Scheduler};
use deft::solver::{
    knapsack_exact, multi_knapsack_exact, multi_knapsack_greedy, naive_knapsack,
    recursive_knapsack, Item,
};
use deft::util::Micros;

fn items_of(buckets: &[deft::models::BucketProfile]) -> Vec<Item> {
    buckets
        .iter()
        .map(|b| Item::new(b.id, b.comm))
        .collect()
}

fn main() {
    let env = ClusterEnv::paper_testbed();
    println!("=== Solver overhead (paper bound: < 1 s per solve) ===\n");
    let mut t = Table::new(&["solve", "instance", "median", "per-solve budget ok"]);

    // Full DeFT schedule solve (queues + knapsacks + cycle detection).
    for wname in ["resnet101", "vgg19", "gpt2"] {
        let w = workload_by_name(wname).expect("workload");
        let buckets = partition(
            &w,
            Strategy::DeftConstrained {
                partition_size: PAPER_PARTITION,
            },
            &env,
        )
        .expect("partition");
        let deft = Deft::new(DeftOptions {
            preserver: true,
            ..DeftOptions::default()
        });
        let (med, _sd) = time_it(1, 5, || {
            std::hint::black_box(deft.schedule(&buckets));
        });
        t.row(&[
            "full DeFT schedule (incl. preserver)".into(),
            format!("{wname} ({} buckets)", buckets.len()),
            format!("{:.3} ms", med * 1e3),
            (med < 1.0).to_string(),
        ]);
    }

    // Individual solver calls on the Table II instance.
    let tbl2 = vgg19_table2_buckets();
    let its = items_of(&tbl2);
    let caps = [Micros(130_285), Micros(78_960)];
    let (med, _) = time_it(10, 50, || {
        std::hint::black_box(naive_knapsack(&its, caps[0]));
    });
    t.row(&["naive knapsack".into(), "table2 (6 items)".into(), format!("{:.1} us", med * 1e6), (med < 1.0).to_string()]);
    let release: Vec<Micros> = tbl2.iter().rev().map(|b| b.bwd).collect();
    let rev_items: Vec<Item> = its.iter().rev().copied().collect();
    let (med, _) = time_it(10, 50, || {
        std::hint::black_box(recursive_knapsack(&rev_items, &release, caps[0]));
    });
    t.row(&["recursive knapsack (Alg. 1)".into(), "table2".into(), format!("{:.1} us", med * 1e6), (med < 1.0).to_string()]);
    let (med, _) = time_it(10, 50, || {
        std::hint::black_box(multi_knapsack_greedy(&its, &caps));
    });
    t.row(&["multi-knapsack greedy (Prob. 2)".into(), "table2, 2 links".into(), format!("{:.1} us", med * 1e6), (med < 1.0).to_string()]);
    println!("{}", t.render());

    println!("=== Solver quality: greedy vs exact (ablation) ===\n");
    let mut q = Table::new(&["instance", "greedy total", "exact total", "ratio"]);
    // Table II instance + random instances from the property generator.
    let mut rng = deft::util::Rng::new(99);
    let mut cases: Vec<(String, Vec<Item>, Vec<Micros>)> = vec![(
        "vgg19 table2".into(),
        its.clone(),
        caps.to_vec(),
    )];
    for c in 0..6 {
        let n = 6 + rng.range(0, 8);
        let items: Vec<Item> = (0..n)
            .map(|i| Item::new(i, Micros(rng.range_u64(500, 120_000))))
            .collect();
        let cap = Micros(rng.range_u64(50_000, 200_000));
        cases.push((format!("random-{c} ({n} items)"), items, vec![cap, cap.scale(0.606)]));
    }
    for (name, items, caps) in &cases {
        let g = multi_knapsack_greedy(items, caps);
        let (_, e) = multi_knapsack_exact(items, caps);
        q.row(&[
            name.clone(),
            format!("{}", g.total),
            format!("{e}"),
            format!("{:.3}", g.total.as_us() as f64 / e.as_us().max(1) as f64),
        ]);
    }
    println!("{}", q.render());

    // Single-sack greedy vs exact.
    let g1 = naive_knapsack(&its, caps[0]);
    let e1 = knapsack_exact(&its, caps[0]);
    println!(
        "single-sack table2: greedy {} vs exact {} (ratio {:.3})",
        g1.total,
        e1.total,
        g1.total.as_us() as f64 / e1.total.as_us().max(1) as f64
    );
}
