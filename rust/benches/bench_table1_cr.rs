//! Paper **Table I** — per-iteration forward/backward/communication times
//! and coverage rate (CR) of the three evaluation DNNs at the reference
//! environment (16 GPUs, 40 Gbps).
//!
//! Paper values: ResNet-101 59/118/242 ms (CR misprinted 1.67, computed
//! 1.37); VGG-19 37/93/258 (1.98); GPT-2 169/381/546.4 (0.99).

use deft::bench::workload_by_name;
use deft::metrics::Table;

fn main() {
    println!("=== Table I: computation and communication time of DNNs ===\n");
    let mut t = Table::new(&[
        "DNN",
        "T_forward",
        "T_backward",
        "T_communication",
        "CR",
        "paper (fwd/bwd/comm/CR)",
    ]);
    let paper = [
        ("resnet101", "59ms/118ms/242ms/1.37*"),
        ("vgg19", "37ms/93ms/258ms/1.98"),
        ("gpt2", "169ms/381ms/546.4ms/0.99"),
        ("llama2", "(section VI: CR < 0.1)"),
    ];
    for (name, paper_row) in paper {
        let w = workload_by_name(name).expect("workload");
        t.row(&[
            w.name.clone(),
            format!("{:.1}ms", w.total_fwd().as_ms_f64()),
            format!("{:.1}ms", w.total_bwd().as_ms_f64()),
            format!("{:.1}ms", w.total_comm_ref().as_ms_f64()),
            format!("{:.2}", w.coverage_rate_ref()),
            paper_row.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("* the paper's CR column prints 1.67 for ResNet-101; 242/(59+118) = 1.37 (the text says \"approximately 1.4\").");
}
