//! Paper **Fig. 16** — influence of partition size on the four schemes'
//! schedules, VGG-19 at partition sizes 3e6 / 4e6 / 8e6 / 1e7 (DDP bucket
//! caps 10 / 15 / 30 / 40 MB respectively).
//!
//! Paper shape: small partitions inflate Bytescheduler's startup
//! overhead (many blocks); US-Byte's fusion cuts total comm; DeFT caps
//! each bucket at fwd/μ, so its total comm is not the lowest, but its
//! iteration time is (heterogeneous links + delayed updates).

use deft::bench::{run_pipeline, workload_by_name, PAPER_DDP_MB};
use deft::config::Scheme;
use deft::links::ClusterEnv;
use deft::metrics::{gantt_steady, Table};

fn main() {
    let w = workload_by_name("vgg19").expect("workload");
    let env = ClusterEnv::paper_testbed();
    let settings: [(u64, f64); 5] = [
        (3_000_000, 10.0),
        (4_000_000, 15.0),
        (6_500_000, PAPER_DDP_MB),
        (8_000_000, 30.0),
        (10_000_000, 40.0),
    ];
    for (psize, ddp_mb) in settings {
        println!(
            "=== Fig. 16: VGG-19, partition size {psize} (DDP bucket {ddp_mb} MB) ===\n"
        );
        let mut t = Table::new(&[
            "scheme",
            "buckets",
            "iter time",
            "bubble %",
            "upd/iter",
            "speedup vs ddp",
        ]);
        let mut ddp_time = None;
        for scheme in Scheme::ALL {
            let r = run_pipeline(&w, scheme, &env, psize, ddp_mb, 30).expect("pipeline");
            if scheme == Scheme::PytorchDdp {
                ddp_time = Some(r.sim.steady_iter_time);
            }
            t.row(&[
                scheme.name().into(),
                r.buckets.len().to_string(),
                format!("{}", r.sim.steady_iter_time),
                format!("{:.1}", r.sim.bubble_ratio() * 100.0),
                format!("{:.2}", r.schedule.update_frequency()),
                ddp_time
                    .map(|d| format!("{:.2}x", d.ratio(r.sim.steady_iter_time)))
                    .unwrap_or("-".into()),
            ]);
        }
        println!("{}", t.render());
    }
    // One detailed schedule rendering at 8e6 (the paper's Fig. 16(c)).
    let r = run_pipeline(&w, Scheme::Deft, &env, 8_000_000, 30.0, 30).expect("pipeline");
    println!("--- DeFT schedule at partition 8e6 (cf. Fig. 16c) ---");
    println!("{}", gantt_steady(&r.sim, r.schedule.cycle.len(), 112));
}
