//! Paper **Fig. 6** (allreduce time vs tensor size, NCCL vs gloo),
//! **Table IV** (multi-link vs single-link contention), and the N-link
//! generalization: DeFT end-to-end on the `nvlink-ib-tcp` registry
//! preset, showing the effective coverage rate fall as links are added.
//!
//! Paper numbers at 16 GPUs / 40 Gbps, two NICs:
//!   NCCL:  14 / 25 / 51 / 110 / 231 ms at 4.2M…67.1M f32
//!   gloo (multi):  22 / 41 / 80 / 169 / 428 ms
//!   gloo (single): 22 / 50 / 96 / 204 / 534 ms (+0…+25% contention)
//!   ratio stabilises at μ ≈ 1.59–1.69 (set to 1.65).

use deft::bench::PAPER_PARTITION;
use deft::links::{ClusterEnv, Codec, ContentionModel, LinkId, LinkPreset, Topology};
use deft::metrics::Table;
use deft::models::{vgg19, BucketProfile};
use deft::partition::{partition, Strategy};
use deft::preserver::{acceptable, quantify_with_error, table5_setting, EPSILON};
use deft::sched::{CommOp, Deft, FwdDependency, IterPlan, Schedule, Scheduler, Stage};
use deft::sim::{simulate, SimOptions};
use deft::util::Micros;

fn main() {
    let multi = ClusterEnv::paper_testbed();
    let single = ClusterEnv::paper_testbed().with_single_link();
    let nccl = multi.link("nccl").expect("nccl registered");
    let gloo = multi.link("gloo").expect("gloo registered");

    println!("=== Fig. 6: allreduce time vs parameter count ===\n");
    let mut t = Table::new(&["params", "nccl(ms)", "gloo(ms)", "ratio", "paper nccl", "paper gloo"]);
    let paper: [(u64, &str, &str); 7] = [
        (1_048_576, "-", "-"),
        (2_097_152, "-", "-"),
        (4_194_304, "14", "22"),
        (8_388_608, "25", "41"),
        (16_777_216, "51", "80"),
        (33_554_432, "110", "169"),
        (67_108_864, "231", "428"),
    ];
    for (params, pn, pg) in paper {
        let n = multi.allreduce_us(nccl, params);
        let g = multi.allreduce_us(gloo, params);
        t.row(&[
            params.to_string(),
            format!("{:.1}", n.as_ms_f64()),
            format!("{:.1}", g.as_ms_f64()),
            format!("{:.2}", g.as_us() as f64 / n.as_us() as f64),
            pn.into(),
            pg.into(),
        ]);
    }
    println!("{}", t.render());

    println!("=== Table IV: multi-link vs single-link allreduce ===\n");
    let mut t2 = Table::new(&[
        "params",
        "multi gloo(ms)",
        "single gloo(ms)",
        "degradation",
        "paper (multi/single)",
    ]);
    let paper2: [(u64, &str); 5] = [
        (4_194_304, "22 / 22 (+0%)"),
        (8_388_608, "41 / 50 (+18%)"),
        (16_777_216, "80 / 96 (+17%)"),
        (33_554_432, "169 / 204 (+17%)"),
        (67_108_864, "428 / 534 (+20%)"),
    ];
    for (params, p) in paper2 {
        let m = multi.allreduce_us(gloo, params);
        let s = single.allreduce_us(gloo, params);
        t2.row(&[
            params.to_string(),
            format!("{:.1}", m.as_ms_f64()),
            format!("{:.1}", s.as_ms_f64()),
            format!("+{:.0}%", (s.as_us() as f64 / m.as_us() as f64 - 1.0) * 100.0),
            p.into(),
        ]);
    }
    println!("{}", t2.render());
    println!(
        "NCCL is unaffected by link sharing (as in the paper): 33.5M multi {} vs single {}.\n",
        multi.allreduce_us(nccl, 33_554_432),
        single.allreduce_us(nccl, 33_554_432)
    );
    // The Table IV single-NIC rows above run under the default k-way
    // model, whose k = 2 factor is bit-for-bit the pairwise penalty —
    // the fit itself is pinned in tier-1 by
    // `tests/contention_model.rs::table4_single_nic_rows_hold_under_the_kway_model`.
    for params in [8_388_608u64, 16_777_216, 33_554_432, 67_108_864] {
        let deg = single.allreduce_us(gloo, params).as_us() as f64
            / multi.allreduce_us(gloo, params).as_us() as f64
            - 1.0;
        assert!(
            (0.15..=0.25).contains(&deg),
            "single-NIC gloo degradation {deg} at {params} left the Table IV band"
        );
    }

    // === Contention-model ablation: pairwise vs aggregate k-way on a
    // 3-way shared NIC. Three links collapse onto one NIC and their
    // transfers overlap 3-deep (dispatches staggered by the backward
    // order); the pairwise rule keeps charging the 2-transfer penalty,
    // while the k-way model splits the NIC's calibrated spare capacity
    // among the payers — pricing strictly slower, with the exempt
    // member untouched. The static planning estimate follows the same
    // model (planning μ = path μ × static factor).
    println!("=== Contention models: 3 concurrent transfers on one NIC ===\n");
    let probe_params = 33_554_432u64;
    let probe_bucket = |id: usize, comm: u64| BucketProfile {
        id,
        params: probe_params,
        fwd: Micros(10_000),
        bwd: Micros(10_000),
        comm: Micros(comm),
    };
    let probe_buckets = vec![
        probe_bucket(0, 50_000),
        probe_bucket(1, 30_000),
        probe_bucket(2, 30_000),
    ];
    let probe_op = |bucket: usize, link: LinkId| CommOp {
        bucket,
        link,
        stage: Stage::Backward,
        priority: 0,
        grad_age: 0,
        merged: 1,
        update_offset: 0,
    };
    let probe_schedule = Schedule {
        scheme: "3way-probe".into(),
        cycle: vec![IterPlan {
            fwd_ops: Vec::new(),
            bwd_ops: vec![
                probe_op(2, LinkId(2)),
                probe_op(1, LinkId(1)),
                probe_op(0, LinkId(0)),
            ],
            update_at_end: true,
        }],
        fwd_dependency: FwdDependency::Barrier,
        updates_per_cycle: 1,
        batch_multipliers: vec![1],
        warmup_iters: 0,
        max_outstanding_iters: usize::MAX,
        capacity_scale_bits: (1.0f64).to_bits(),
    };
    probe_schedule.validate().expect("probe schedule");
    let mut t2b = Table::new(&[
        "model",
        "static factor (k-grp)",
        "planning mu (slowest)",
        "probe makespan",
        "per-link busy (ms)",
    ]);
    let mut makespans = Vec::new();
    for model in ContentionModel::ALL {
        let env = LinkPreset::NvlinkIbTcp
            .env()
            .with_single_link()
            .with_contention_model(model);
        let sim = simulate(
            &probe_buckets,
            &probe_schedule,
            &env,
            &SimOptions {
                iterations: 1,
                warmup: 0,
                record_timeline: false,
            },
        );
        let slowest = LinkId(2);
        t2b.row(&[
            model.name().into(),
            format!("{:.2}", env.static_contention_factor(slowest, probe_params)),
            format!("{:.2}", env.planning_mu(slowest)),
            format!("{}", sim.total),
            sim.link_busy
                .iter()
                .map(|(id, b)| format!("{}={:.1}", env.spec(*id).name, b.as_ms_f64()))
                .collect::<Vec<_>>()
                .join(" "),
        ]);
        makespans.push((model, sim.total));
    }
    println!("{}", t2b.render());
    let pairwise_total = makespans[0].1;
    let kway_total = makespans[1].1;
    assert!(
        kway_total > pairwise_total,
        "3-way contention must price slower under k-way: {kway_total} vs {pairwise_total}"
    );

    // === N-link registry: the shape the old NCCL/gloo enum could not
    // express. Grow the nvlink-ib-tcp preset one link at a time and run
    // DeFT end-to-end (partition → schedule → simulate) on VGG-19. The
    // effective coverage rate CR_eff = comm / (compute · Σ 1/μ_i) drops
    // with every added link — the registry turns spare heterogeneous
    // bandwidth into overlap capacity.
    println!("=== N-link topologies: DeFT on the nvlink-ib-tcp preset (VGG-19) ===\n");
    let workload = vgg19();
    let all_links = LinkPreset::NvlinkIbTcp.links();
    let mut t3 = Table::new(&[
        "links",
        "raw CR",
        "effective CR",
        "updates/iter",
        "steady iter",
        "per-link busy (ms)",
    ]);
    let mut prev_eff_cr = f64::INFINITY;
    for n in 1..=all_links.len() {
        let env = ClusterEnv::paper_testbed().with_links(all_links[..n].to_vec());
        let buckets = partition(
            &workload,
            Strategy::DeftConstrained {
                partition_size: PAPER_PARTITION,
            },
            &env,
        )
        .expect("partition");
        let deft = Deft::for_env(&env, false);
        let schedule = deft.schedule(&buckets);
        let sim = simulate(
            &buckets,
            &schedule,
            &env,
            &SimOptions {
                iterations: (schedule.cycle.len() * 4).max(24),
                warmup: schedule.cycle.len().max(4),
                record_timeline: false,
            },
        );
        let comm: Micros = buckets.iter().map(|b| b.comm).sum();
        let compute: Micros = buckets.iter().map(|b| b.fwd + b.bwd).sum();
        let raw_cr = comm.ratio(compute);
        let cap_factor: f64 = env.link_mus().iter().map(|mu| 1.0 / mu).sum();
        let eff_cr = raw_cr / cap_factor;
        let busy = sim
            .link_busy
            .iter()
            .map(|(id, b)| format!("{}={:.0}", env.spec(*id).name, b.as_ms_f64()))
            .collect::<Vec<_>>()
            .join(" ");
        t3.row(&[
            env.link_names().join("+"),
            format!("{raw_cr:.2}"),
            format!("{eff_cr:.2}"),
            format!("{:.2}", schedule.update_frequency()),
            format!("{}", sim.steady_iter_time),
            busy,
        ]);
        assert!(
            eff_cr < prev_eff_cr,
            "effective CR must fall as links are added: {eff_cr} vs {prev_eff_cr}"
        );
        prev_eff_cr = eff_cr;
    }
    println!("{}", t3.render());

    // === Rank-level topology: the same registry, hierarchically. With
    // NVLink as the node-local segment (intra) and IB as its cross-node
    // fabric, growing the node moves traffic onto the fast segment: the
    // effective path slowdown of every fabric falls below its raw μ and
    // the 33.5M-param allreduce gets monotonically cheaper.
    println!("\n=== Rank-level topology: hierarchical allreduce vs ranks/node ===\n");
    let base = LinkPreset::NvlinkIbTcp.env();
    let ib = base.link("ib").expect("ib registered");
    let mut t4 = Table::new(&["ranks/node", "path mu(ib)", "path mu(tcp)", "ib allreduce 33.5M"]);
    let mut prev = Micros::MAX;
    for rpn in [1usize, 2, 4, 8] {
        let env = if rpn == 1 {
            base.clone()
        } else {
            base.clone().with_topology(Topology::hierarchical(rpn, LinkId(0), LinkId(1)))
        };
        let a = env.allreduce_us(ib, 33_554_432);
        t4.row(&[
            rpn.to_string(),
            format!("{:.3}", env.path_mu(ib)),
            format!("{:.3}", env.path_mu(LinkId(2))),
            format!("{:.1}ms", a.as_ms_f64()),
        ]);
        assert!(
            a <= prev,
            "hierarchical allreduce must not slow down as the node grows: {a:?} vs {prev:?}"
        );
        prev = a;
    }
    println!("{}", t4.render());

    // === Codec ablation: compression on the slowest link. Attaching a
    // codec to tcp scales its per-byte cost (codec-effective μ), so the
    // effective coverage rate CR_eff = comm / (compute · Σ 1/μ_eff)
    // falls — fp16 without tripping the Preserver's `acceptable` gate;
    // the aggressive rank-1 codec buys the most coverage but its
    // truncation error is rejected (the lifecycle would fall back to
    // raw links).
    println!("\n=== Codec ablation: DeFT with compression on tcp (VGG-19) ===\n");
    let (walk, base_batch) = table5_setting();
    let mut t5 = Table::new(&[
        "tcp codec",
        "path mu(tcp)",
        "effective CR",
        "updates/iter",
        "steady iter",
        "tcp wire/raw (MB)",
        "walk ratio",
        "preserver ok",
    ]);
    let mut raw_eff_cr = None;
    let mut fp16_row: Option<(f64, bool)> = None;
    for codec in [Codec::Raw, Codec::Fp16, Codec::RankK { k: 4 }, Codec::RankK { k: 1 }] {
        let env = ClusterEnv::paper_testbed()
            .with_links(all_links.clone())
            .with_codec(LinkId(2), codec);
        let buckets = partition(
            &workload,
            Strategy::DeftConstrained {
                partition_size: PAPER_PARTITION,
            },
            &env,
        )
        .expect("partition");
        // Preserver ON: fp16's negligible error clears the gate through
        // the normal capacity feedback; rank-1's irreducible error makes
        // the loop stop early and the gate reject the route.
        let deft = Deft::for_env(&env, true);
        let schedule = deft.schedule(&buckets);
        let sim = simulate(
            &buckets,
            &schedule,
            &env,
            &SimOptions {
                iterations: (schedule.cycle.len() * 4).max(24),
                warmup: schedule.cycle.len().max(4),
                record_timeline: false,
            },
        );
        let comm: Micros = buckets.iter().map(|b| b.comm).sum();
        let compute: Micros = buckets.iter().map(|b| b.fwd + b.bwd).sum();
        let cap_factor: f64 = env.link_path_mus().iter().map(|mu| 1.0 / mu).sum();
        let eff_cr = comm.ratio(compute) / cap_factor;
        // The Preserver gate: the worst codec error among links the
        // schedule actually routes over, injected into the walk.
        let err = schedule.worst_codec_error(&env.link_path_codec_errors());
        let rep = quantify_with_error(&walk, base_batch, &schedule.batch_multipliers, err);
        let ok = acceptable(&rep, EPSILON);
        let tcp = &sim.link_traffic[2];
        t5.row(&[
            codec.name(),
            format!("{:.3}", env.path_mu(LinkId(2))),
            format!("{eff_cr:.2}"),
            format!("{:.2}", schedule.update_frequency()),
            format!("{}", sim.steady_iter_time),
            format!("{:.0}/{:.0}", tcp.wire_bytes as f64 / 1e6, tcp.raw_bytes as f64 / 1e6),
            format!("{:.4}", rep.ratio),
            ok.to_string(),
        ]);
        match codec {
            Codec::Raw => raw_eff_cr = Some(eff_cr),
            Codec::Fp16 => fp16_row = Some((eff_cr, ok)),
            Codec::RankK { k: 1 } => assert!(
                !ok,
                "rank-1 truncation error must trip the Preserver gate (ratio {})",
                rep.ratio
            ),
            _ => {}
        }
    }
    println!("{}", t5.render());
    let (fp16_cr, fp16_ok) = fp16_row.expect("fp16 row ran");
    let raw_cr_eff = raw_eff_cr.expect("raw row ran");
    assert!(
        fp16_cr < raw_cr_eff,
        "fp16 on the slowest link must lower the effective CR: {fp16_cr} vs {raw_cr_eff}"
    );
    assert!(fp16_ok, "fp16's rounding error must not trip the Preserver gate");
}
