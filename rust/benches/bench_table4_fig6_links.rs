//! Paper **Fig. 6** (allreduce time vs tensor size, NCCL vs gloo) and
//! **Table IV** (multi-link vs single-link contention).
//!
//! Paper numbers at 16 GPUs / 40 Gbps, two NICs:
//!   NCCL:  14 / 25 / 51 / 110 / 231 ms at 4.2M…67.1M f32
//!   gloo (multi):  22 / 41 / 80 / 169 / 428 ms
//!   gloo (single): 22 / 50 / 96 / 204 / 534 ms (+0…+25% contention)
//!   ratio stabilises at μ ≈ 1.59–1.69 (set to 1.65).

use deft::links::{ClusterEnv, LinkKind};
use deft::metrics::Table;

fn main() {
    let multi = ClusterEnv::paper_testbed();
    let single = ClusterEnv::paper_testbed().with_single_link();

    println!("=== Fig. 6: allreduce time vs parameter count ===\n");
    let mut t = Table::new(&["params", "nccl(ms)", "gloo(ms)", "ratio", "paper nccl", "paper gloo"]);
    let paper: [(u64, &str, &str); 7] = [
        (1_048_576, "-", "-"),
        (2_097_152, "-", "-"),
        (4_194_304, "14", "22"),
        (8_388_608, "25", "41"),
        (16_777_216, "51", "80"),
        (33_554_432, "110", "169"),
        (67_108_864, "231", "428"),
    ];
    for (params, pn, pg) in paper {
        let n = multi.allreduce_us(LinkKind::Nccl, params);
        let g = multi.allreduce_us(LinkKind::Gloo, params);
        t.row(&[
            params.to_string(),
            format!("{:.1}", n.as_ms_f64()),
            format!("{:.1}", g.as_ms_f64()),
            format!("{:.2}", g.as_us() as f64 / n.as_us() as f64),
            pn.into(),
            pg.into(),
        ]);
    }
    println!("{}", t.render());

    println!("=== Table IV: multi-link vs single-link allreduce ===\n");
    let mut t2 = Table::new(&[
        "params",
        "multi gloo(ms)",
        "single gloo(ms)",
        "degradation",
        "paper (multi/single)",
    ]);
    let paper2: [(u64, &str); 5] = [
        (4_194_304, "22 / 22 (+0%)"),
        (8_388_608, "41 / 50 (+18%)"),
        (16_777_216, "80 / 96 (+17%)"),
        (33_554_432, "169 / 204 (+17%)"),
        (67_108_864, "428 / 534 (+20%)"),
    ];
    for (params, p) in paper2 {
        let m = multi.allreduce_us(LinkKind::Gloo, params);
        let s = single.allreduce_us(LinkKind::Gloo, params);
        t2.row(&[
            params.to_string(),
            format!("{:.1}", m.as_ms_f64()),
            format!("{:.1}", s.as_ms_f64()),
            format!("+{:.0}%", (s.as_us() as f64 / m.as_us() as f64 - 1.0) * 100.0),
            p.into(),
        ]);
    }
    println!("{}", t2.render());
    println!("NCCL is unaffected by link sharing (as in the paper): 33.5M multi {} vs single {}.",
        multi.allreduce_us(LinkKind::Nccl, 33_554_432),
        single.allreduce_us(LinkKind::Nccl, 33_554_432));
}
