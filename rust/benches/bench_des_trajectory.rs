//! DES hot-path perf trajectory runner and CI regression gate.
//!
//! Runs the pinned scenarios from `deft::bench::trajectory` through both
//! engines (scan reference with timeline vs indexed without), prints the
//! points, optionally writes them as `BENCH_*.json`, and optionally
//! gates them against a committed trajectory file.
//!
//! ```text
//! cargo bench --bench bench_des_trajectory -- --smoke \
//!     --check ../BENCH_des_hotpath.json --band 0.25 --out fresh.json
//! ```
//!
//! Flags: `--smoke` (default) | `--full` grid selection; `--sweep`
//! adds the batch-sweep throughput scenario (serial vs 4-thread
//! `sweep::run_grid` over the full zoo × preset × topology × codec ×
//! contention grid — implied by `--full`, skipped in smoke runs);
//! `--reps N` timed repetitions per engine (default 3); `--out FILE`
//! write fresh points; `--check FILE` gate against a committed file;
//! `--band F` allowed fractional regression (default 0.25);
//! `--absolute` also gate raw events/sec (same-host runs only). Exits
//! non-zero when the gate fails. See BENCHMARKS.md for the workflow.

use deft::bench::trajectory::{
    check_against, full_scenarios, parse_points, run, run_sweep_points, smoke_scenarios, to_json,
};
use deft::metrics::Table;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut full = false;
    let mut sweep = false;
    let mut reps = 3usize;
    let mut out: Option<String> = None;
    let mut check: Option<String> = None;
    let mut band = 0.25f64;
    let mut absolute = false;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--full" => full = true,
            "--smoke" => full = false,
            "--sweep" => sweep = true,
            "--absolute" => absolute = true,
            "--reps" => reps = take(&mut it, a).parse().expect("--reps takes an integer"),
            "--out" => out = Some(take(&mut it, a)),
            "--check" => check = Some(take(&mut it, a)),
            "--band" => band = take(&mut it, a).parse().expect("--band takes a float"),
            other => {
                eprintln!(
                    "unknown flag `{other}` (expected --smoke | --full | --sweep | --reps N | \
                     --out FILE | --check FILE | --band F | --absolute)"
                );
                std::process::exit(2);
            }
        }
    }

    let scenarios = if full { full_scenarios() } else { smoke_scenarios() };
    eprintln!(
        "running {} scenarios ({}), {reps} reps per engine...",
        scenarios.len(),
        if full { "full grid" } else { "smoke" }
    );
    let mut points = run(&scenarios, reps).expect("trajectory run failed");
    if sweep || full {
        eprintln!("running the full-grid sweep scenario (serial vs 4 threads)...");
        points.extend(run_sweep_points(reps));
    }

    let mut t = Table::new(&["scenario", "engine", "wall", "events/s", "speedup"]);
    for p in &points {
        let speedup = if p.engine == "indexed" {
            points
                .iter()
                .find(|q| q.engine == "scan" && q.scenario == p.scenario)
                .map(|q| format!("{:.2}x", p.events_per_sec / q.events_per_sec))
                .unwrap_or_default()
        } else {
            String::new()
        };
        t.row(&[
            p.scenario.clone(),
            p.engine.clone(),
            format!("{:.2} ms", p.wall_s * 1e3),
            format!("{:.2} M", p.events_per_sec / 1e6),
            speedup,
        ]);
    }
    println!("=== DES hot-path trajectory ===\n\n{}", t.render());

    if let Some(path) = out {
        let host = std::env::var("HOSTNAME").unwrap_or_else(|_| "unknown-host".to_string());
        std::fs::write(&path, to_json("des_hotpath", &host, &points)).expect("write --out file");
        eprintln!("wrote {} points to {path}", points.len());
    }

    if let Some(path) = check {
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("cannot read --check file {path}: {e}"));
        let committed = parse_points(&text)
            .unwrap_or_else(|e| panic!("cannot parse --check file {path}: {e}"));
        let outcome = check_against(&committed, &points, band, absolute);
        if outcome.compared == 0 {
            eprintln!("gate: WARNING — no scenarios in common with {path}");
            std::process::exit(1);
        }
        if outcome.passed() {
            eprintln!(
                "gate: OK — {} scenarios within {:.0}% of {path}",
                outcome.compared,
                band * 100.0
            );
        } else {
            eprintln!("gate: FAILED against {path}:");
            for f in &outcome.failures {
                eprintln!("  {f}");
            }
            std::process::exit(1);
        }
    }
}

fn take<'a>(it: &mut impl Iterator<Item = &'a String>, flag: &str) -> String {
    it.next()
        .unwrap_or_else(|| panic!("{flag} needs a value"))
        .clone()
}
