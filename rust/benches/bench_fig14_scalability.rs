//! Paper **Fig. 14** — scalability: relative speedup (vs one GPU) of the
//! four schemes at 2 / 4 / 8 / 16 GPUs on the three DNNs.
//!
//! Paper shape: DeFT closest to linear everywhere; its speedup is
//! 1.21–1.92× US-Byte, 1.32–1.98× Bytescheduler, 1.55–2.24× PyTorch.

use deft::bench::{run_pipeline, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::links::ClusterEnv;
use deft::metrics::Table;

fn main() {
    let gpu_counts = [2usize, 4, 8, 16];
    for wname in ["resnet101", "vgg19", "gpt2"] {
        let w = workload_by_name(wname).expect("workload");
        // 1-GPU reference: no communication; iteration = compute.
        let single_iter = w.total_compute();
        println!("=== Fig. 14: speedup vs #GPUs, {} (linear = N) ===\n", w.name);
        let mut t = Table::new(&["scheme", "2 GPUs", "4 GPUs", "8 GPUs", "16 GPUs"]);
        let mut per_scheme: Vec<(String, Vec<f64>)> = Vec::new();
        for scheme in Scheme::ALL {
            let mut speedups = Vec::new();
            for &n in &gpu_counts {
                let env = ClusterEnv::paper_testbed().with_workers(n);
                let r = run_pipeline(&w, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB, 30)
                    .expect("pipeline");
                // Relative speedup = N-GPU throughput / 1-GPU throughput
                //                  = N * t_single / t_N.
                let s = n as f64 * single_iter.ratio(r.sim.steady_iter_time).min(1.0);
                speedups.push(s);
            }
            per_scheme.push((scheme.name().into(), speedups));
        }
        t.row(&[
            "linear".into(),
            "2.00".into(),
            "4.00".into(),
            "8.00".into(),
            "16.00".into(),
        ]);
        for (name, sp) in &per_scheme {
            t.row(&[
                name.clone(),
                format!("{:.2}", sp[0]),
                format!("{:.2}", sp[1]),
                format!("{:.2}", sp[2]),
                format!("{:.2}", sp[3]),
            ]);
        }
        println!("{}", t.render());
        // Paper bands at 16 GPUs.
        let deft16 = per_scheme.iter().find(|(n, _)| n == "deft").unwrap().1[3];
        let usb16 = per_scheme.iter().find(|(n, _)| n == "us-byte").unwrap().1[3];
        let bs16 = per_scheme.iter().find(|(n, _)| n == "bytescheduler").unwrap().1[3];
        let ddp16 = per_scheme.iter().find(|(n, _)| n == "pytorch-ddp").unwrap().1[3];
        println!(
            "at 16 GPUs: deft/us-byte {:.2}x (paper 1.21-1.92), deft/bytesched {:.2}x (1.32-1.98), deft/ddp {:.2}x (1.55-2.24)\n",
            deft16 / usb16,
            deft16 / bs16,
            deft16 / ddp16
        );
    }
}
