//! Paper **Fig. 10** — time-to-solution curves of the four schemes on the
//! three DNNs, plus the DeFT-without-multilink ablation (§V.B.4).
//!
//! Timing comes from the DES; loss/accuracy trajectories from the
//! Gaussian-walk convergence co-simulation (DESIGN.md §Substitutions).
//! Paper shape: DeFT reaches the target 29–115% faster; the no-multilink
//! ablation trains as fast but loses final accuracy (ResNet 76→71%,
//! VGG 71→66%) / converges slower early (GPT-2).

use deft::bench::{run_pipeline, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::links::ClusterEnv;
use deft::metrics::Table;
use deft::models::TargetMetric;
use deft::sim::{training_curve, ConvergenceModel};

fn main() {
    let env = ClusterEnv::paper_testbed();
    for wname in ["resnet101", "vgg19", "gpt2"] {
        let w = workload_by_name(wname).expect("workload");
        let model = ConvergenceModel::for_workload(wname);
        // Realistic training lengths: ImageNet 90 epochs at global batch
        // 4096 is ~28k iterations; VGG at 1024 ~25k; GPT-2 ~15k.
        let total_iters = match wname {
            "resnet101" => 28_000usize,
            "vgg19" => 25_000,
            _ => 15_000,
        };
        println!("=== Fig. 10: time-to-solution, {} ===\n", w.name);
        let mut t = Table::new(&[
            "scheme",
            "iter time",
            "eff batch mult",
            "final acc/loss",
            "time-to-target (h)",
            "vs ddp",
        ]);
        let mut schemes = Scheme::ALL.to_vec();
        schemes.push(Scheme::DeftNoMultilink);
        // Generate every scheme's curve first, then time-to-target against
        // a shared target every curve reaches (slightly inside the worst
        // final metric).
        let mut rows = Vec::new();
        for scheme in schemes {
            let r = run_pipeline(&w, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB, 40)
                .expect("pipeline");
            let cycle_time = r.sim.steady_iter_time * r.schedule.cycle.len() as u64;
            let curve = training_curve(
                &model,
                scheme.name(),
                cycle_time,
                r.schedule.cycle.len(),
                &r.schedule.batch_multipliers,
                w.batch_size as f64,
                total_iters,
            );
            rows.push((scheme, r.sim.steady_iter_time, curve));
        }
        let target = match w.target {
            TargetMetric::Accuracy(_) => {
                let worst = rows
                    .iter()
                    .map(|(_, _, c)| c.final_accuracy())
                    .fold(f64::INFINITY, f64::min);
                TargetMetric::Accuracy(worst - 0.005)
            }
            TargetMetric::Loss(_) => {
                let worst = rows
                    .iter()
                    .map(|(_, _, c)| c.final_loss())
                    .fold(f64::NEG_INFINITY, f64::max);
                TargetMetric::Loss(worst + 0.01)
            }
        };
        let ddp_ttt = rows
            .iter()
            .find(|(s, _, _)| *s == Scheme::PytorchDdp)
            .and_then(|(_, _, c)| c.time_to_target(target));
        for (scheme, iter_time, curve) in &rows {
            let ttt = curve.time_to_target(target);
            let final_metric = match w.target {
                TargetMetric::Accuracy(_) => format!("{:.1}%", 100.0 * curve.final_accuracy()),
                TargetMetric::Loss(_) => format!("{:.3}", curve.final_loss()),
            };
            t.row(&[
                scheme.name().into(),
                format!("{iter_time}"),
                format!("{:.2}", curve.eff_multiplier),
                final_metric,
                ttt.map(|s| format!("{:.2}", s / 3600.0)).unwrap_or("-".into()),
                match (ddp_ttt, ttt) {
                    (Some(d), Some(x)) => format!("{:.2}x", d / x),
                    _ => "-".into(),
                },
            ]);
        }
        println!("{}", t.render());
    }
    // §VI negative result appendix row.
    let w = workload_by_name("llama2").expect("workload");
    let ddp = run_pipeline(&w, Scheme::PytorchDdp, &env, PAPER_PARTITION, PAPER_DDP_MB, 20)
        .expect("pipeline");
    let deft =
        run_pipeline(&w, Scheme::Deft, &env, PAPER_PARTITION, PAPER_DDP_MB, 20).expect("pipeline");
    println!(
        "=== §VI check: llama2-like (CR = {:.3}) — ddp {} vs deft {} ({:.2}x: no gain) ===",
        w.coverage_rate_ref(),
        ddp.sim.steady_iter_time,
        deft.sim.steady_iter_time,
        ddp.sim.steady_iter_time.ratio(deft.sim.steady_iter_time)
    );
}
