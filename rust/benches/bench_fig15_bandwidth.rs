//! Paper **Fig. 15** — throughput of the four schemes at 10 / 20 / 30 /
//! 40 Gbps inter-node bandwidth (16 GPUs).
//!
//! Paper shape: DeFT highest at every bandwidth; 1.28–2.83× US-Byte,
//! 1.36–3.09× Bytescheduler, 1.61–3.94× PyTorch, with DeFT's speedup
//! growing as bandwidth shrinks (its volume reduction matters more) but
//! staying linear-in-bandwidth thanks to the Preserver bound.

use deft::bench::{run_pipeline, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::links::ClusterEnv;
use deft::metrics::Table;

fn main() {
    let bandwidths = [10.0f64, 20.0, 30.0, 40.0];
    for wname in ["resnet101", "vgg19", "gpt2"] {
        let w = workload_by_name(wname).expect("workload");
        println!(
            "=== Fig. 15: throughput (samples/s) vs bandwidth, {} ===\n",
            w.name
        );
        let mut t = Table::new(&["scheme", "10Gbps", "20Gbps", "30Gbps", "40Gbps"]);
        let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
        for scheme in Scheme::ALL {
            let mut tp = Vec::new();
            for &bw in &bandwidths {
                let env = ClusterEnv::paper_testbed().with_bandwidth(bw);
                let r = run_pipeline(&w, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB, 30)
                    .expect("pipeline");
                tp.push(r.sim.throughput(w.batch_size, env.workers));
            }
            rows.push((scheme.name().into(), tp));
        }
        for (name, tp) in &rows {
            t.row(&[
                name.clone(),
                format!("{:.0}", tp[0]),
                format!("{:.0}", tp[1]),
                format!("{:.0}", tp[2]),
                format!("{:.0}", tp[3]),
            ]);
        }
        println!("{}", t.render());
        let get = |n: &str| rows.iter().find(|(x, _)| x == n).unwrap().1.clone();
        let deft = get("deft");
        let usb = get("us-byte");
        let ddp = get("pytorch-ddp");
        println!(
            "deft/us-byte: {:.2}x @10G … {:.2}x @40G (paper band 1.28-2.83); deft/ddp: {:.2}x … {:.2}x (1.61-3.94)\n",
            deft[0] / usb[0],
            deft[3] / usb[3],
            deft[0] / ddp[0],
            deft[3] / ddp[3],
        );
    }
}
