//! Paper **Table V** — expected-state evolution E_B(s_{t+1}) of the
//! fixed-batch order O_B vs DeFT's variable-batch order O_D on the
//! ResNet-101 setting (A = 1000, N = 4, S* = 0, η = 0.01, B = 256).
//!
//! Paper row values: O_B E = .2103 .2054 .1989 .1967 .1922; O_D merges
//! iteration A+1..A+2 into one B=512 update (E = .2012) and the final
//! ratio is 0.993.

use deft::metrics::Table;
use deft::preserver::{acceptable, quantify, table5_setting, EPSILON};

fn main() {
    let (walk, b) = table5_setting();
    println!("=== Table V: E_B(s_t+1) of O_B and O_D, ResNet-101 ===");
    println!("setting: A=1000, N=4, S*=0, eta=0.01, s_A={}\n", walk.s_t);

    let rep = quantify(&walk, b, &[2, 1, 1]);
    let mut t = Table::new(&["order", "iter A", "A+1", "A+2", "A+3", "A+4", "final ratio"]);
    let fmt = |v: f64| format!("{v:.4}");
    t.row(&[
        "O_B (paper)".into(),
        "0.2103".into(),
        "0.2054".into(),
        "0.1989".into(),
        "0.1967".into(),
        "0.1922".into(),
        "0.993".into(),
    ]);
    t.row(&[
        "O_B (ours)".into(),
        fmt(walk.s_t),
        fmt(rep.baseline[0]),
        fmt(rep.baseline[1]),
        fmt(rep.baseline[2]),
        fmt(rep.baseline[3]),
        format!("{:.4}", rep.ratio),
    ]);
    t.row(&[
        "O_D (paper)".into(),
        "0.2103".into(),
        "0.2012 (B=512)".into(),
        "-".into(),
        "0.1979".into(),
        "0.1935".into(),
        "".into(),
    ]);
    t.row(&[
        "O_D (ours)".into(),
        fmt(walk.s_t),
        format!("{} (B=512)", fmt(rep.deft[0])),
        "-".into(),
        fmt(rep.deft[1]),
        fmt(rep.deft[2]),
        "".into(),
    ]);
    println!("{}", t.render());
    println!(
        "ratio within [1-eps, 1+eps]? {} (eps = {EPSILON})",
        acceptable(&rep, 0.03)
    );
    println!("\n=== sweep: how much merging does the walk tolerate? ===");
    let mut t2 = Table::new(&["k sequence", "ratio", "acceptable"]);
    for ks in [vec![1u64; 4], vec![2, 1, 1], vec![2, 2], vec![4], vec![8], vec![32]] {
        let r = quantify(&walk, b, &ks);
        t2.row(&[
            format!("{ks:?}"),
            format!("{:.4}", r.ratio),
            acceptable(&r, EPSILON).to_string(),
        ]);
    }
    println!("{}", t2.render());
}
