//! Regression parity: the `paper-2link` registry preset must reproduce
//! the pre-refactor `LinkKind` enum (NCCL/gloo) **exactly** — same wire
//! pricing, same schedules, same `SimResult` metrics — for all four
//! schemes. The old enum's two-link cost model is reimplemented verbatim
//! below as the reference oracle; the discrete-event engine is shared, so
//! op-for-op wire equality plus schedule equality implies bit-for-bit
//! metric equality (which the sim-level assertions then confirm).

use deft::bench::{run_pipeline, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::links::{ClusterEnv, LinkId, LinkPreset, LinkSpec, PAPER_MU};
use deft::models::{gpt2_buckets_calibrated, vgg19_table2_buckets, BucketProfile};
use deft::sched::{Bytescheduler, Deft, DeftOptions, Schedule, Scheduler, UsByte, Wfbp};
use deft::sim::{simulate, SimOptions, SimResult};
use deft::util::Micros;

/// The deleted enum's wire-time rule, verbatim: NCCL ships at the
/// reference time; gloo at μ×, with the Table IV contention ramp when
/// both libraries share a NIC.
fn legacy_wire(env: &ClusterEnv, link: LinkId, comm: Micros, params: u64, single_nic: bool) -> Micros {
    match link.index() {
        0 => comm,
        1 => {
            let t = comm.scale(PAPER_MU);
            if single_nic {
                t.scale(1.0 + env.contention_penalty(params))
            } else {
                t
            }
        }
        other => panic!("paper-2link schedule used unknown link {other}"),
    }
}

fn schedulers() -> Vec<Box<dyn Scheduler>> {
    vec![
        Box::new(Wfbp),
        Box::new(Bytescheduler::default()),
        Box::new(UsByte::default()),
        Box::new(Deft::new(DeftOptions {
            preserver: false,
            ..DeftOptions::default()
        })),
        Box::new(Deft::without_multilink()),
    ]
}

fn sim(buckets: &[BucketProfile], schedule: &Schedule, env: &ClusterEnv) -> SimResult {
    simulate(
        buckets,
        schedule,
        env,
        &SimOptions {
            iterations: (schedule.cycle.len() * 4).max(24),
            warmup: schedule.cycle.len().max(4),
            record_timeline: true,
        },
    )
}

/// `paper_testbed()` and the preset must be the same registry, with
/// exactly the old enum's constants.
#[test]
fn paper_preset_matches_old_constants() {
    let env = ClusterEnv::paper_testbed();
    assert_eq!(env.links, LinkPreset::Paper2Link.links());
    assert_eq!(env.n_links(), 2);
    let nccl = env.spec(LinkId(0));
    let gloo = env.spec(LinkId(1));
    assert_eq!(nccl.name, "nccl");
    assert_eq!(gloo.name, "gloo");
    assert!((nccl.mu - 1.0).abs() < 1e-12);
    assert!((gloo.mu - PAPER_MU).abs() < 1e-12);
    assert_eq!(nccl.alpha, Micros(300));
    assert_eq!(gloo.alpha, Micros(900));
    // Dual NICs: nobody contends. Single NIC: only gloo does (the old
    // `multi_link: false` flag).
    assert!(!env.contended(LinkId(0)) && !env.contended(LinkId(1)));
    let single = ClusterEnv::paper_testbed().with_single_link();
    assert!(!single.contended(LinkId(0)));
    assert!(single.contended(LinkId(1)));
    assert_eq!(single.links, LinkPreset::SingleNic.links());
}

/// Every op of every scheme prices identically to the legacy enum rule,
/// in both the dual-NIC and single-NIC configurations.
#[test]
fn wire_pricing_matches_legacy_enum() {
    let multi = ClusterEnv::paper_testbed();
    let single = ClusterEnv::paper_testbed().with_single_link();
    for buckets in [vgg19_table2_buckets(), gpt2_buckets_calibrated()] {
        for s in schedulers() {
            let schedule = s.schedule(&buckets);
            for plan in &schedule.cycle {
                for op in plan.all_ops() {
                    let b = &buckets[op.bucket];
                    assert_eq!(
                        multi.wire_time(op.link, b.comm, b.params),
                        legacy_wire(&multi, op.link, b.comm, b.params, false),
                        "{}: multi-NIC wire mismatch on bucket {}",
                        s.name(),
                        op.bucket
                    );
                    assert_eq!(
                        single.wire_time(op.link, b.comm, b.params),
                        legacy_wire(&single, op.link, b.comm, b.params, true),
                        "{}: single-NIC wire mismatch on bucket {}",
                        s.name(),
                        op.bucket
                    );
                }
            }
        }
    }
}

/// The microbenchmark pricing (`allreduce_us`) matches the legacy enum's
/// closed form across the Table IV size sweep, including the gloo
/// oversize ramp and single-NIC contention.
#[test]
fn allreduce_matches_legacy_closed_form() {
    let multi = ClusterEnv::paper_testbed();
    let single = ClusterEnv::paper_testbed().with_single_link();
    // Legacy constants, lifted from the deleted enum implementation.
    let legacy = |env: &ClusterEnv, gloo: bool, single_nic: bool, params: u64| -> Micros {
        if params == 0 {
            return Micros::ZERO;
        }
        let ring = 2.0 * (env.workers as f64 - 1.0) / env.workers as f64;
        let bytes = params as f64 * 4.0 * ring;
        let wire_bytes_per_us = env.bandwidth_gbps * 1e9 / 8.0 / 1e6;
        let base_us = bytes / (wire_bytes_per_us * 0.469);
        if !gloo {
            return Micros(300) + Micros::from_us_f64(base_us);
        }
        let knee = 33.6e6;
        let p = params as f64;
        let oversize = if p <= knee {
            1.0
        } else {
            1.0 + 0.12 * ((p - knee) / knee).min(1.0)
        };
        let t = Micros(900) + Micros::from_us_f64(base_us * 1.65 * oversize);
        if single_nic {
            t.scale(1.0 + env.contention_penalty(params))
        } else {
            t
        }
    };
    for params in [0u64, 1_048_576, 4_194_304, 8_388_608, 16_777_216, 33_554_432, 50_000_000, 67_108_864, 134_217_728] {
        assert_eq!(
            multi.allreduce_us(LinkId(0), params),
            legacy(&multi, false, false, params),
            "nccl @ {params}"
        );
        assert_eq!(
            multi.allreduce_us(LinkId(1), params),
            legacy(&multi, true, false, params),
            "gloo multi @ {params}"
        );
        assert_eq!(
            single.allreduce_us(LinkId(1), params),
            legacy(&single, true, true, params),
            "gloo single @ {params}"
        );
        // NCCL is never penalized by NIC sharing.
        assert_eq!(
            single.allreduce_us(LinkId(0), params),
            multi.allreduce_us(LinkId(0), params)
        );
    }
}

/// Full pipeline parity: building the environment from the preset, from
/// `paper_testbed()`, and from hand-rolled `LinkSpec`s must yield
/// identical schedules and identical `SimResult` metrics for all four
/// schemes (plus the no-multilink ablation) on the Table II profile.
#[test]
fn schedules_and_metrics_are_identical_across_constructions() {
    let buckets = vgg19_table2_buckets();
    let by_hand = ClusterEnv::paper_testbed().with_links(vec![
        LinkSpec::new("nccl", 1.0).with_alpha(Micros(300)).with_group(0),
        LinkSpec::new("gloo", PAPER_MU)
            .with_alpha(Micros(900))
            .with_group(1)
            .with_staging_ramp(0.12),
    ]);
    let from_preset = LinkPreset::Paper2Link.env();
    let testbed = ClusterEnv::paper_testbed();

    for s in schedulers() {
        let schedule = s.schedule(&buckets);
        let r_hand = sim(&buckets, &schedule, &by_hand);
        let r_preset = sim(&buckets, &schedule, &from_preset);
        let r_testbed = sim(&buckets, &schedule, &testbed);
        for (a, b) in [(&r_hand, &r_preset), (&r_preset, &r_testbed)] {
            assert_eq!(a.steady_iter_time, b.steady_iter_time, "{}", s.name());
            assert_eq!(a.total, b.total, "{}", s.name());
            assert_eq!(a.compute_bubbles, b.compute_bubbles, "{}", s.name());
            assert_eq!(a.update_times, b.update_times, "{}", s.name());
            assert_eq!(a.link_busy, b.link_busy, "{}", s.name());
            assert_eq!(a.iter_ends, b.iter_ends, "{}", s.name());
        }
        // Per-link busy equals the sum of legacy-priced wire times: the
        // metric the engine reports is exactly what the old enum charged.
        let iters = r_testbed.iter_ends.len();
        for (link, busy) in &r_testbed.link_busy {
            let mut expect = Micros::ZERO;
            for t in 0..iters {
                let plan = &schedule.cycle[t % schedule.cycle.len()];
                for op in plan.all_ops().filter(|op| op.link == *link) {
                    let b = &buckets[op.bucket];
                    expect += legacy_wire(&testbed, *link, b.comm, b.params, false);
                }
            }
            assert_eq!(*busy, expect, "{}: link {:?} busy", s.name(), link);
        }
    }
}

/// Determinism guard: scheduling twice and simulating twice must agree
/// with itself (the registry introduced no iteration-order dependence).
#[test]
fn scheduling_is_deterministic_under_the_registry() {
    let buckets = vgg19_table2_buckets();
    let env = ClusterEnv::paper_testbed();
    for s in schedulers() {
        let a = s.schedule(&buckets);
        let b = s.schedule(&buckets);
        assert_eq!(a, b, "{} schedule nondeterministic", s.name());
        let ra = sim(&buckets, &a, &env);
        let rb = sim(&buckets, &b, &env);
        assert_eq!(ra.steady_iter_time, rb.steady_iter_time);
        assert_eq!(ra.link_busy, rb.link_busy);
    }
}

/// The full paper pipeline (partition → schedule → simulate) still
/// reproduces the headline orderings under the registry — a coarse but
/// end-to-end guard that `paper-2link` behaves as the old enum did.
#[test]
fn pipeline_orderings_survive_the_refactor() {
    let env = ClusterEnv::paper_testbed();
    let w = workload_by_name("vgg19").unwrap();
    let ddp =
        run_pipeline(&w, Scheme::PytorchDdp, &env, PAPER_PARTITION, PAPER_DDP_MB, 40).unwrap();
    let deft = run_pipeline(&w, Scheme::Deft, &env, PAPER_PARTITION, PAPER_DDP_MB, 40).unwrap();
    assert!(deft.sim.steady_iter_time < ddp.sim.steady_iter_time);
    // DeFT's heterogeneous schedule uses the slow link.
    assert!(deft
        .schedule
        .cycle
        .iter()
        .flat_map(|p| p.all_ops())
        .any(|op| op.link == LinkId(1)));
    // And the engine's registry-wide accounting covers both links.
    assert_eq!(deft.sim.link_busy.len(), 2);
    assert_eq!(deft.sim.link_names, vec!["nccl".to_string(), "gloo".to_string()]);
}

/// The 3-link preset runs the whole pipeline end-to-end — the scenario
/// the enum could never express.
#[test]
fn nvlink_ib_tcp_runs_end_to_end() {
    let env = LinkPreset::NvlinkIbTcp.env();
    assert_eq!(env.n_links(), 3);
    let buckets = vgg19_table2_buckets();
    let deft = Deft::for_env(&env, false);
    let schedule = deft.schedule(&buckets);
    schedule.validate().unwrap();
    let r = sim(&buckets, &schedule, &env);
    assert_eq!(r.link_busy.len(), 3);
    assert!(r.steady_iter_time.as_us() > 0);
    let used: usize = r
        .link_busy
        .iter()
        .filter(|(_, busy)| !busy.is_zero())
        .count();
    assert!(used >= 2, "3-link DeFT schedule used only {used} link(s)");
}
