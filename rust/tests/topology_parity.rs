//! Rank-level topology parity and overlap-aware contention regressions.
//!
//! 1. A flat [`Topology`] (the default) and the degenerate hierarchical
//!    topology with one rank per node must reproduce the flat registry
//!    pricing **bit-for-bit** — same schedules, same `SimResult` metrics
//!    — for every preset and all four schemes (plus the no-multilink
//!    ablation), in the same spirit as `tests/link_parity.rs`.
//! 2. The phantom shared-NIC contention bug: a `single-nic` environment
//!    running a schedule that only ever uses the slow link must price
//!    identically to the same schedule on `paper-2link` — an idle
//!    group-mate costs nothing at execution time. The static planner
//!    estimate stays conservative (that split is deliberate).
//! 3. When same-group transfers *do* overlap, the **pairwise** execution
//!    model charges the Table IV penalty exactly for the shared window —
//!    these are regression pins for the legacy one-shot charge, so they
//!    select `ContentionModel::Pairwise` explicitly (the default is the
//!    aggregate k-way model, pinned in `tests/contention_model.rs`).

use deft::bench::{run_pipeline, scheduler_for, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::links::{ClusterEnv, ContentionModel, LinkId, LinkPreset, Topology};
use deft::models::{vgg19_table2_buckets, BucketProfile};
use deft::sched::{CommOp, FwdDependency, IterPlan, Schedule, Scheduler, Stage, Wfbp};
use deft::sim::{simulate, SimOptions, SimResult};
use deft::util::Micros;

fn sim(buckets: &[BucketProfile], schedule: &Schedule, env: &ClusterEnv) -> SimResult {
    simulate(
        buckets,
        schedule,
        env,
        &SimOptions {
            iterations: (schedule.cycle.len() * 4).max(24),
            warmup: schedule.cycle.len().max(4),
            record_timeline: true,
        },
    )
}

fn assert_same_metrics(a: &SimResult, b: &SimResult, what: &str) {
    assert_eq!(a.steady_iter_time, b.steady_iter_time, "{what}: steady");
    assert_eq!(a.total, b.total, "{what}: total");
    assert_eq!(a.compute_bubbles, b.compute_bubbles, "{what}: bubbles");
    assert_eq!(a.update_times, b.update_times, "{what}: updates");
    assert_eq!(a.link_busy, b.link_busy, "{what}: link busy");
    assert_eq!(a.iter_ends, b.iter_ends, "{what}: iter ends");
}

/// One rank per node ⇒ no intra segment exists ⇒ the hierarchical model
/// must collapse to flat registry pricing bit-for-bit, for every preset
/// and every scheme.
#[test]
fn one_rank_per_node_reproduces_flat_pricing_everywhere() {
    let buckets = vgg19_table2_buckets();
    for preset in LinkPreset::ALL {
        let flat = preset.env();
        let one = preset.env().with_topology(Topology::hierarchical(1, LinkId(1), LinkId(0)));
        // Identical knapsack factors ⇒ identical schedules.
        assert_eq!(flat.link_path_mus(), one.link_path_mus(), "{}", preset.name());
        assert!((flat.max_mu() - one.max_mu()).abs() < 1e-15);
        let mut schemes = Scheme::ALL.to_vec();
        schemes.push(Scheme::DeftNoMultilink);
        for scheme in schemes {
            let s_flat = scheduler_for(scheme, false, &flat).schedule(&buckets);
            let s_one = scheduler_for(scheme, false, &one).schedule(&buckets);
            assert_eq!(s_flat, s_one, "{}/{:?}: schedule", preset.name(), scheme);
            let r_flat = sim(&buckets, &s_flat, &flat);
            let r_one = sim(&buckets, &s_one, &one);
            assert_same_metrics(&r_flat, &r_one, &format!("{}/{:?}", preset.name(), scheme));
        }
    }
}

/// Regression for the phantom contention bug: a single-NIC environment
/// whose schedule only ever touches the slow link must execute exactly
/// like the dual-NIC testbed — the fast link is idle, so nothing
/// contends. (The old engine statically inflated every slow-link op
/// whenever a faster group-mate merely *existed*.)
#[test]
fn idle_group_mate_no_longer_inflates_single_link_schedules() {
    let buckets = vgg19_table2_buckets();
    let mut schedule = Wfbp.schedule(&buckets);
    for op in &mut schedule.cycle[0].bwd_ops {
        op.link = LinkId(1); // everything on the slow (gloo) link
    }
    schedule.validate().unwrap();
    let multi = LinkPreset::Paper2Link.env();
    let single = LinkPreset::SingleNic.env();
    let r_multi = sim(&buckets, &schedule, &multi);
    let r_single = sim(&buckets, &schedule, &single);
    assert_same_metrics(&r_multi, &r_single, "slow-link-only schedule");

    // The schedulers' static planning estimate deliberately stays
    // conservative: on the shared NIC the slow link still budgets the
    // full Table IV penalty.
    let comm = Micros(100_000);
    let p = 33_554_432u64;
    assert!(
        single.wire_time(LinkId(1), comm, p) > multi.wire_time(LinkId(1), comm, p),
        "planning estimate must keep the static contention rule"
    );
    assert_eq!(
        single.wire_time_uncontended(LinkId(1), comm),
        multi.wire_time_uncontended(LinkId(1), comm),
        "execution pricing is contention-free until transfers overlap"
    );
}

/// When same-group transfers genuinely overlap, the engine charges the
/// penalty for exactly the shared window — deterministic arithmetic.
fn pair_schedule(first: LinkId, second: LinkId) -> (Vec<BucketProfile>, Schedule) {
    // Two buckets, 10 ms fwd/bwd each, 50 ms reference comm each, both
    // far above the contention knee. Backward runs bucket 1 then bucket
    // 0, so bucket 1's transfer (on `first`) dispatches at 30 ms and
    // bucket 0's (on `second`) at 40 ms.
    let bucket = |id: usize| BucketProfile {
        id,
        params: 40_000_000,
        fwd: Micros(10_000),
        bwd: Micros(10_000),
        comm: Micros(50_000),
    };
    let op = |bucket: usize, link: LinkId| CommOp {
        bucket,
        link,
        stage: Stage::Backward,
        priority: 0,
        grad_age: 0,
        merged: 1,
        update_offset: 0,
    };
    let schedule = Schedule {
        scheme: "pair".into(),
        cycle: vec![IterPlan {
            fwd_ops: Vec::new(),
            bwd_ops: vec![op(1, first), op(0, second)],
            update_at_end: true,
        }],
        fwd_dependency: FwdDependency::Barrier,
        updates_per_cycle: 1,
        batch_multipliers: vec![1],
        warmup_iters: 0,
        max_outstanding_iters: usize::MAX,
        capacity_scale_bits: (1.0f64).to_bits(),
    };
    schedule.validate().unwrap();
    (vec![bucket(0), bucket(1)], schedule)
}

const PAIR_OPTS: SimOptions = SimOptions {
    iterations: 1,
    warmup: 0,
    record_timeline: false,
};

#[test]
fn overlapping_same_group_transfers_pay_for_the_shared_window() {
    // NCCL first: its transfer [30 ms, 80 ms) is in flight when the gloo
    // transfer starts at 40 ms (base wire 82.5 ms) ⇒ 40 ms of overlap.
    let (buckets, schedule) = pair_schedule(LinkId(0), LinkId(1));
    let multi = LinkPreset::Paper2Link
        .env()
        .with_contention_model(ContentionModel::Pairwise);
    let single = LinkPreset::SingleNic
        .env()
        .with_contention_model(ContentionModel::Pairwise);
    let r_multi = simulate(&buckets, &schedule, &multi, &PAIR_OPTS);
    let r_single = simulate(&buckets, &schedule, &single, &PAIR_OPTS);
    // Dual NICs: gloo finishes at 40 ms + 82.5 ms.
    assert_eq!(r_multi.total, Micros(122_500));
    // Shared NIC: + 21% of the 40 ms overlap window = 8.4 ms.
    assert_eq!(r_single.total, Micros(130_900));
    let gloo_busy = |r: &SimResult| r.link_busy[1].1;
    assert_eq!(gloo_busy(&r_multi), Micros(82_500));
    assert_eq!(gloo_busy(&r_single), Micros(90_900));
    // The fast group member is never slowed (the paper's observation).
    assert_eq!(r_multi.link_busy[0], r_single.link_busy[0]);
}

#[test]
fn paying_transfer_in_flight_is_extended_when_group_mate_starts() {
    // Reversed dispatch order: gloo starts first [30 ms, 112.5 ms) and
    // NCCL joins at 40 ms for [40 ms, 90 ms). The charge must be
    // symmetric in dispatch order — the already-in-flight paying
    // transfer is extended by 21% of the shared 50 ms window (10.5 ms),
    // while the exempt NCCL transfer is untouched.
    let (buckets, schedule) = pair_schedule(LinkId(1), LinkId(0));
    let multi = LinkPreset::Paper2Link
        .env()
        .with_contention_model(ContentionModel::Pairwise);
    let single = LinkPreset::SingleNic
        .env()
        .with_contention_model(ContentionModel::Pairwise);
    let r_multi = simulate(&buckets, &schedule, &multi, &PAIR_OPTS);
    let r_single = simulate(&buckets, &schedule, &single, &PAIR_OPTS);
    assert_eq!(r_multi.total, Micros(112_500));
    assert_eq!(r_single.total, Micros(123_000));
    let gloo_busy = |r: &SimResult| r.link_busy[1].1;
    assert_eq!(gloo_busy(&r_multi), Micros(82_500));
    assert_eq!(gloo_busy(&r_single), Micros(93_000));
    assert_eq!(r_multi.link_busy[0], r_single.link_busy[0]);
}

/// Hierarchical topology end-to-end: DeFT runs on a 2-node NVLink+IB+TCP
/// cluster, knapsack capacities follow the segment paths, the §III.D
/// partition constraint uses the slowest path, and per-link busy
/// accounting includes the shared intra segment's foreign legs.
#[test]
fn hierarchical_topology_runs_the_full_pipeline() {
    let env = LinkPreset::NvlinkIbTcp
        .env()
        .with_topology(Topology::hierarchical(8, LinkId(0), LinkId(1)));
    let w = workload_by_name("vgg19").unwrap();
    let r = run_pipeline(&w, Scheme::Deft, &env, PAPER_PARTITION, PAPER_DDP_MB, 40).unwrap();
    r.schedule.validate().unwrap();
    assert!(r.sim.steady_iter_time.as_us() > 0);

    // §III.D constraint against the slowest segment path (1.33 here,
    // not the raw μ = 6 of the TCP link).
    assert!(env.max_mu() < 1.5, "slowest path {}", env.max_mu());
    let cap = w.total_fwd().scale(1.0 / env.max_mu());
    for b in &r.buckets {
        assert!(
            b.comm <= cap + Micros(1),
            "bucket {} comm {:?} exceeds path-derived cap {cap:?}",
            b.id,
            b.comm
        );
    }

    // Busy accounting: home totals plus foreign segment legs, per link.
    let iters = r.sim.iter_ends.len();
    let mut expect = vec![Micros::ZERO; env.n_links()];
    let mut foreign_legs = 0usize;
    for t in 0..iters {
        let plan = &r.schedule.cycle[t % r.schedule.cycle.len()];
        for op in plan.all_ops() {
            let segs = env.wire_segments(op.link, r.buckets[op.bucket].comm);
            let total: Micros = segs.iter().map(|&(_, x)| x).sum();
            expect[op.link.index()] += total;
            for &(l, x) in &segs {
                if l != op.link {
                    expect[l.index()] += x;
                    foreign_legs += 1;
                }
            }
        }
    }
    assert!(
        foreign_legs > 0,
        "hierarchical schedule produced no shared-segment legs"
    );
    for (k, (id, busy)) in r.sim.link_busy.iter().enumerate() {
        assert_eq!(id.index(), k);
        assert_eq!(*busy, expect[k], "link {k} segment busy");
    }
}
