//! Integration tests: partition → schedule → simulate across all schemes
//! and workloads, checking the cross-scheme orderings the paper reports.

use deft::bench::{run_pipeline, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::links::{ClusterEnv, LinkId, LinkSpec};
use deft::models::{vgg19_table2_buckets, BucketProfile};
use deft::sched::{
    Bytescheduler, CommOp, Deft, DeftOptions, FwdDependency, IterPlan, Schedule, Scheduler,
    Stage, UsByte, Wfbp,
};
use deft::sim::{simulate, SimOptions, StreamId};
use deft::util::Micros;

fn env() -> ClusterEnv {
    ClusterEnv::paper_testbed()
}

fn iter_time(scheme: Scheme, workload: &str) -> Micros {
    let w = workload_by_name(workload).unwrap();
    run_pipeline(&w, scheme, &env(), PAPER_PARTITION, PAPER_DDP_MB, 40)
        .unwrap()
        .sim
        .steady_iter_time
}

/// Paper §V.B ordering: DeFT ≥ US-Byte ≥ Bytescheduler ≳ DDP on every
/// benchmark (DeFT strictly fastest).
#[test]
fn scheme_ordering_matches_paper_on_all_workloads() {
    for wname in ["resnet101", "vgg19", "gpt2"] {
        let ddp = iter_time(Scheme::PytorchDdp, wname);
        let bs = iter_time(Scheme::Bytescheduler, wname);
        let usb = iter_time(Scheme::UsByte, wname);
        let deft = iter_time(Scheme::Deft, wname);
        assert!(
            deft < usb && deft < bs && deft < ddp,
            "{wname}: deft {deft} usb {usb} bs {bs} ddp {ddp}"
        );
        // When both baselines are fully link-bound (CR≫1) they tie to
        // within partitioning noise — allow 1%.
        let usb_f = usb.as_us() as f64;
        let bs_f = bs.as_us() as f64;
        let ddp_f = ddp.as_us() as f64;
        assert!(usb_f <= bs_f * 1.01, "{wname}: us-byte {usb} vs bytescheduler {bs}");
        assert!(bs_f <= ddp_f * 1.01, "{wname}: bytescheduler {bs} vs ddp {ddp}");
    }
}

/// Paper §V.B headline speedup bands: DeFT vs best baseline ≈ +29–115%.
#[test]
fn deft_speedup_within_paper_band() {
    // (workload, min speedup over the best baseline, max plausible)
    for (wname, lo, hi) in [
        ("resnet101", 1.15, 2.2),
        ("vgg19", 1.3, 2.6),
        ("gpt2", 1.1, 2.0),
    ] {
        let best_baseline = iter_time(Scheme::UsByte, wname)
            .min(iter_time(Scheme::Bytescheduler, wname));
        let deft = iter_time(Scheme::Deft, wname);
        let speedup = best_baseline.ratio(deft);
        assert!(
            (lo..hi).contains(&speedup),
            "{wname}: speedup {speedup:.2} outside [{lo}, {hi})"
        );
    }
}

/// WFBP barrier: DDP compute stream must contain bubbles on a CR>1
/// workload; DeFT should cut the bubble ratio dramatically.
#[test]
fn deft_reduces_bubbles() {
    let w = workload_by_name("vgg19").unwrap();
    let ddp =
        run_pipeline(&w, Scheme::PytorchDdp, &env(), PAPER_PARTITION, PAPER_DDP_MB, 40).unwrap();
    let deft = run_pipeline(&w, Scheme::Deft, &env(), PAPER_PARTITION, PAPER_DDP_MB, 40).unwrap();
    assert!(ddp.sim.bubble_ratio() > 0.3, "ddp bubbles {}", ddp.sim.bubble_ratio());
    assert!(
        deft.sim.bubble_ratio() < 0.5 * ddp.sim.bubble_ratio(),
        "deft {} vs ddp {}",
        deft.sim.bubble_ratio(),
        ddp.sim.bubble_ratio()
    );
}

/// GPT-2 (CR≈1): even the baselines overlap most communication; DeFT's
/// edge comes from the hard-dependency elimination (paper: 29–62%).
#[test]
fn gpt2_gains_from_hard_dependency_elimination() {
    let ddp = iter_time(Scheme::PytorchDdp, "gpt2");
    let deft = iter_time(Scheme::Deft, "gpt2");
    let speedup = ddp.ratio(deft);
    assert!((1.2..2.2).contains(&speedup), "gpt2 ddp/deft {speedup:.2}");
}

/// §VI negative result: CR < 0.1 ⇒ scheduling cannot help (< 10% gain).
#[test]
fn llama_low_cr_no_gain() {
    let ddp = iter_time(Scheme::PytorchDdp, "llama2");
    let deft = iter_time(Scheme::Deft, "llama2");
    let speedup = ddp.ratio(deft);
    assert!(
        (0.98..1.10).contains(&speedup),
        "low-CR workload should see ~no gain, got {speedup:.2}"
    );
}

/// Simulator conservation: total link busy time equals the sum of the
/// executed ops' wire times, and compute busy equals Σ(fwd+bwd)·iters.
#[test]
fn simulator_conserves_time() {
    let buckets = vgg19_table2_buckets();
    let schedule = Wfbp.schedule(&buckets);
    let iters = 12;
    let r = simulate(
        &buckets,
        &schedule,
        &env(),
        &SimOptions {
            iterations: iters,
            warmup: 2,
            record_timeline: true,
        },
    );
    let compute_busy = r.timeline.busy(StreamId::Compute);
    let per_iter: Micros = buckets.iter().map(|b| b.fwd + b.bwd).sum();
    assert_eq!(compute_busy, per_iter * iters as u64);
    let nccl_busy = r.timeline.busy(StreamId::Link(LinkId::REFERENCE));
    let comm_per_iter: Micros = buckets.iter().map(|b| b.comm).sum();
    assert_eq!(nccl_busy, comm_per_iter * iters as u64);
}

/// Time conservation under forced 3-way shared-NIC contention (the k-way
/// execution model): compute busy is untouched, per-link busy equals the
/// timeline's span occupancy, the exempt group member moves exactly its
/// uncontended wire time, and every paying member's occupancy is bounded
/// by its uncontended wire below and the full k-way factor above.
#[test]
fn simulator_conserves_time_under_forced_3way_contention() {
    // Three links on one NIC (a exempt; b, c pay); backward order makes
    // the three transfers overlap 3-deep mid-iteration.
    let env = ClusterEnv::paper_testbed().with_links(vec![
        LinkSpec::new("a", 1.0).with_group(0),
        LinkSpec::new("b", 2.0).with_group(0),
        LinkSpec::new("c", 4.0).with_group(0),
    ]);
    let params = 33_554_432u64; // penalty plateau: factor(3) = 2.42
    let bucket = |id: usize, comm: u64| BucketProfile {
        id,
        params,
        fwd: Micros(10_000),
        bwd: Micros(10_000),
        comm: Micros(comm),
    };
    let buckets = vec![bucket(0, 50_000), bucket(1, 30_000), bucket(2, 30_000)];
    let op = |bucket: usize, link: LinkId| CommOp {
        bucket,
        link,
        stage: Stage::Backward,
        priority: 0,
        grad_age: 0,
        merged: 1,
        update_offset: 0,
    };
    let schedule = Schedule {
        scheme: "forced-3way".into(),
        cycle: vec![IterPlan {
            fwd_ops: Vec::new(),
            bwd_ops: vec![op(2, LinkId(2)), op(1, LinkId(1)), op(0, LinkId(0))],
            update_at_end: true,
        }],
        fwd_dependency: FwdDependency::Barrier,
        updates_per_cycle: 1,
        batch_multipliers: vec![1],
        warmup_iters: 0,
        max_outstanding_iters: usize::MAX,
        capacity_scale_bits: (1.0f64).to_bits(),
    };
    schedule.validate().unwrap();
    let iters = 3usize;
    let r = simulate(
        &buckets,
        &schedule,
        &env,
        &SimOptions {
            iterations: iters,
            warmup: 1,
            record_timeline: true,
        },
    );
    assert_eq!(r.contention, "kway");
    // Compute conservation is unaffected by wire contention.
    let per_iter: Micros = buckets.iter().map(|b| b.fwd + b.bwd).sum();
    assert_eq!(r.timeline.busy(StreamId::Compute), per_iter * iters as u64);
    // Per-link busy equals the recorded span occupancy, sits at exactly
    // the uncontended wire for the exempt member, and within
    // [uncontended, uncontended × factor(3)] for the payers.
    let wires = [Micros(50_000), Micros(60_000), Micros(120_000)];
    let f3 = env.contention_factor(3, params);
    for (k, &wire) in wires.iter().enumerate() {
        let link = LinkId(k);
        let (id, busy) = r.link_busy[k];
        assert_eq!(id, link);
        assert_eq!(busy, r.timeline.busy(StreamId::Link(link)), "link {k} spans");
        let floor = wire * iters as u64;
        if k == 0 {
            assert_eq!(busy, floor, "exempt member must move at its full rate");
        } else {
            assert!(busy >= floor, "link {k}: busy {busy:?} below uncontended {floor:?}");
            assert!(
                busy <= floor.scale(f3),
                "link {k}: busy {busy:?} above the k-way ceiling"
            );
        }
    }
    // Updates gate each iteration (Barrier), so no transfer leaks across
    // iteration boundaries and the wall clock ends with the last update.
    assert_eq!(r.update_times.len(), iters);
    assert_eq!(r.total, *r.update_times.last().unwrap());
}

/// DDP iteration time bounds for Table II VGG-19: between compute-only
/// and fully-serial, and visibly better than fully-serial (WFBP overlaps
/// the backward window).
#[test]
fn ddp_iteration_time_bounds() {
    let buckets = vgg19_table2_buckets();
    let schedule = Wfbp.schedule(&buckets);
    let r = simulate(&buckets, &schedule, &env(), &SimOptions::default());
    let compute: Micros = buckets.iter().map(|b| b.fwd + b.bwd).sum();
    let comm: Micros = buckets.iter().map(|b| b.comm).sum();
    assert!(r.steady_iter_time >= compute);
    assert!(r.steady_iter_time <= compute + comm);
    assert!(r.steady_iter_time < compute + comm.scale(0.95));
}

/// All four schedulers run on a single-bucket degenerate profile.
#[test]
fn single_bucket_degenerate_profiles() {
    let buckets = vec![BucketProfile {
        id: 0,
        params: 1_000_000,
        fwd: Micros(1_000),
        bwd: Micros(2_000),
        comm: Micros(2_500),
    }];
    for s in [
        Wfbp.schedule(&buckets),
        Bytescheduler::default().schedule(&buckets),
        UsByte::default().schedule(&buckets),
        Deft::new(DeftOptions {
            preserver: false,
            ..DeftOptions::default()
        })
        .schedule(&buckets),
    ] {
        s.validate().unwrap();
        let r = simulate(
            &buckets,
            &s,
            &env(),
            &SimOptions {
                iterations: 10,
                warmup: 2,
                record_timeline: false,
            },
        );
        assert!(r.steady_iter_time >= Micros(3_000), "{}", s.scheme);
    }
}

/// Bandwidth monotonicity: halving bandwidth must not speed anything up.
#[test]
fn bandwidth_monotonicity() {
    let w = workload_by_name("vgg19").unwrap();
    for scheme in Scheme::ALL {
        let t40 = run_pipeline(&w, scheme, &env(), PAPER_PARTITION, PAPER_DDP_MB, 30)
            .unwrap()
            .sim
            .steady_iter_time;
        let env10 = env().with_bandwidth(10.0);
        let t10 = run_pipeline(&w, scheme, &env10, PAPER_PARTITION, PAPER_DDP_MB, 30)
            .unwrap()
            .sim
            .steady_iter_time;
        assert!(t10 >= t40, "{scheme:?}: 10Gbps {t10} faster than 40Gbps {t40}");
    }
}
