//! Integration tests: partition → schedule → simulate across all schemes
//! and workloads, checking the cross-scheme orderings the paper reports.

use deft::bench::{run_pipeline, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::links::{ClusterEnv, LinkId};
use deft::models::{vgg19_table2_buckets, BucketProfile};
use deft::sched::{Bytescheduler, Deft, DeftOptions, Scheduler, UsByte, Wfbp};
use deft::sim::{simulate, SimOptions, StreamId};
use deft::util::Micros;

fn env() -> ClusterEnv {
    ClusterEnv::paper_testbed()
}

fn iter_time(scheme: Scheme, workload: &str) -> Micros {
    let w = workload_by_name(workload);
    run_pipeline(&w, scheme, &env(), PAPER_PARTITION, PAPER_DDP_MB, 40)
        .sim
        .steady_iter_time
}

/// Paper §V.B ordering: DeFT ≥ US-Byte ≥ Bytescheduler ≳ DDP on every
/// benchmark (DeFT strictly fastest).
#[test]
fn scheme_ordering_matches_paper_on_all_workloads() {
    for wname in ["resnet101", "vgg19", "gpt2"] {
        let ddp = iter_time(Scheme::PytorchDdp, wname);
        let bs = iter_time(Scheme::Bytescheduler, wname);
        let usb = iter_time(Scheme::UsByte, wname);
        let deft = iter_time(Scheme::Deft, wname);
        assert!(
            deft < usb && deft < bs && deft < ddp,
            "{wname}: deft {deft} usb {usb} bs {bs} ddp {ddp}"
        );
        // When both baselines are fully link-bound (CR≫1) they tie to
        // within partitioning noise — allow 1%.
        let usb_f = usb.as_us() as f64;
        let bs_f = bs.as_us() as f64;
        let ddp_f = ddp.as_us() as f64;
        assert!(usb_f <= bs_f * 1.01, "{wname}: us-byte {usb} vs bytescheduler {bs}");
        assert!(bs_f <= ddp_f * 1.01, "{wname}: bytescheduler {bs} vs ddp {ddp}");
    }
}

/// Paper §V.B headline speedup bands: DeFT vs best baseline ≈ +29–115%.
#[test]
fn deft_speedup_within_paper_band() {
    // (workload, min speedup over the best baseline, max plausible)
    for (wname, lo, hi) in [
        ("resnet101", 1.15, 2.2),
        ("vgg19", 1.3, 2.6),
        ("gpt2", 1.1, 2.0),
    ] {
        let best_baseline = iter_time(Scheme::UsByte, wname)
            .min(iter_time(Scheme::Bytescheduler, wname));
        let deft = iter_time(Scheme::Deft, wname);
        let speedup = best_baseline.ratio(deft);
        assert!(
            (lo..hi).contains(&speedup),
            "{wname}: speedup {speedup:.2} outside [{lo}, {hi})"
        );
    }
}

/// WFBP barrier: DDP compute stream must contain bubbles on a CR>1
/// workload; DeFT should cut the bubble ratio dramatically.
#[test]
fn deft_reduces_bubbles() {
    let w = workload_by_name("vgg19");
    let ddp = run_pipeline(&w, Scheme::PytorchDdp, &env(), PAPER_PARTITION, PAPER_DDP_MB, 40);
    let deft = run_pipeline(&w, Scheme::Deft, &env(), PAPER_PARTITION, PAPER_DDP_MB, 40);
    assert!(ddp.sim.bubble_ratio() > 0.3, "ddp bubbles {}", ddp.sim.bubble_ratio());
    assert!(
        deft.sim.bubble_ratio() < 0.5 * ddp.sim.bubble_ratio(),
        "deft {} vs ddp {}",
        deft.sim.bubble_ratio(),
        ddp.sim.bubble_ratio()
    );
}

/// GPT-2 (CR≈1): even the baselines overlap most communication; DeFT's
/// edge comes from the hard-dependency elimination (paper: 29–62%).
#[test]
fn gpt2_gains_from_hard_dependency_elimination() {
    let ddp = iter_time(Scheme::PytorchDdp, "gpt2");
    let deft = iter_time(Scheme::Deft, "gpt2");
    let speedup = ddp.ratio(deft);
    assert!((1.2..2.2).contains(&speedup), "gpt2 ddp/deft {speedup:.2}");
}

/// §VI negative result: CR < 0.1 ⇒ scheduling cannot help (< 10% gain).
#[test]
fn llama_low_cr_no_gain() {
    let ddp = iter_time(Scheme::PytorchDdp, "llama2");
    let deft = iter_time(Scheme::Deft, "llama2");
    let speedup = ddp.ratio(deft);
    assert!(
        (0.98..1.10).contains(&speedup),
        "low-CR workload should see ~no gain, got {speedup:.2}"
    );
}

/// Simulator conservation: total link busy time equals the sum of the
/// executed ops' wire times, and compute busy equals Σ(fwd+bwd)·iters.
#[test]
fn simulator_conserves_time() {
    let buckets = vgg19_table2_buckets();
    let schedule = Wfbp.schedule(&buckets);
    let iters = 12;
    let r = simulate(
        &buckets,
        &schedule,
        &env(),
        &SimOptions {
            iterations: iters,
            warmup: 2,
            record_timeline: true,
        },
    );
    let compute_busy = r.timeline.busy(StreamId::Compute);
    let per_iter: Micros = buckets.iter().map(|b| b.fwd + b.bwd).sum();
    assert_eq!(compute_busy, per_iter * iters as u64);
    let nccl_busy = r.timeline.busy(StreamId::Link(LinkId::REFERENCE));
    let comm_per_iter: Micros = buckets.iter().map(|b| b.comm).sum();
    assert_eq!(nccl_busy, comm_per_iter * iters as u64);
}

/// DDP iteration time bounds for Table II VGG-19: between compute-only
/// and fully-serial, and visibly better than fully-serial (WFBP overlaps
/// the backward window).
#[test]
fn ddp_iteration_time_bounds() {
    let buckets = vgg19_table2_buckets();
    let schedule = Wfbp.schedule(&buckets);
    let r = simulate(&buckets, &schedule, &env(), &SimOptions::default());
    let compute: Micros = buckets.iter().map(|b| b.fwd + b.bwd).sum();
    let comm: Micros = buckets.iter().map(|b| b.comm).sum();
    assert!(r.steady_iter_time >= compute);
    assert!(r.steady_iter_time <= compute + comm);
    assert!(r.steady_iter_time < compute + comm.scale(0.95));
}

/// All four schedulers run on a single-bucket degenerate profile.
#[test]
fn single_bucket_degenerate_profiles() {
    let buckets = vec![BucketProfile {
        id: 0,
        params: 1_000_000,
        fwd: Micros(1_000),
        bwd: Micros(2_000),
        comm: Micros(2_500),
    }];
    for s in [
        Wfbp.schedule(&buckets),
        Bytescheduler.schedule(&buckets),
        UsByte.schedule(&buckets),
        Deft::new(DeftOptions {
            preserver: false,
            ..DeftOptions::default()
        })
        .schedule(&buckets),
    ] {
        s.validate().unwrap();
        let r = simulate(
            &buckets,
            &s,
            &env(),
            &SimOptions {
                iterations: 10,
                warmup: 2,
                record_timeline: false,
            },
        );
        assert!(r.steady_iter_time >= Micros(3_000), "{}", s.scheme);
    }
}

/// Bandwidth monotonicity: halving bandwidth must not speed anything up.
#[test]
fn bandwidth_monotonicity() {
    let w = workload_by_name("vgg19");
    for scheme in Scheme::ALL {
        let t40 = run_pipeline(&w, scheme, &env(), PAPER_PARTITION, PAPER_DDP_MB, 30)
            .sim
            .steady_iter_time;
        let env10 = env().with_bandwidth(10.0);
        let t10 = run_pipeline(&w, scheme, &env10, PAPER_PARTITION, PAPER_DDP_MB, 30)
            .sim
            .steady_iter_time;
        assert!(t10 >= t40, "{scheme:?}: 10Gbps {t10} faster than 40Gbps {t40}");
    }
}
