//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have run; they self-skip (with a loud
//! message) when `artifacts/manifest.toml` is absent so `cargo test`
//! stays green on a fresh checkout.

use std::path::Path;

use deft::runtime::{ArtifactManifest, Engine, HostTensor};

fn manifest() -> Option<ArtifactManifest> {
    let path = Path::new("artifacts/manifest.toml");
    if !path.exists() {
        eprintln!("SKIP: artifacts/manifest.toml missing — run `make artifacts`");
        return None;
    }
    Some(ArtifactManifest::load(path).expect("manifest parses"))
}

fn read_init(m: &ArtifactManifest) -> Vec<Vec<f32>> {
    m.meta["init_files"]
        .split(';')
        .map(|f| {
            let bytes = std::fs::read(m.dir.join(f)).expect("init file");
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        })
        .collect()
}

fn tokens(m: &ArtifactManifest, seed: u64) -> Vec<i32> {
    let batch = m.meta_usize("batch").unwrap();
    let seq = m.meta_usize("seq").unwrap();
    let vocab = m.meta_usize("vocab").unwrap() as u64;
    let mut rng = deft::util::Rng::new(seed);
    (0..batch * (seq + 1))
        .map(|_| rng.below(vocab) as i32)
        .collect()
}

#[test]
fn train_step_runs_and_loss_is_near_uniform() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let exe = engine.load(m.exe("train_step").unwrap()).unwrap();
    let init = read_init(&m);
    let mut inputs: Vec<HostTensor> = init.iter().cloned().map(HostTensor::F32).collect();
    inputs.push(HostTensor::I32(tokens(&m, 1)));
    let out = exe.run(&inputs).unwrap();
    let loss = out[0].as_f32().unwrap()[0];
    let vocab = m.meta_usize("vocab").unwrap() as f32;
    let uniform = vocab.ln();
    assert!(
        loss > 0.5 * uniform && loss < 1.5 * uniform,
        "init loss {loss} vs ln(V) {uniform}"
    );
    // Gradients must be non-trivial for every bucket.
    for (i, g) in out[1..].iter().enumerate() {
        let g = g.as_f32().unwrap();
        let max = g.iter().fold(0f32, |a, &x| a.max(x.abs()));
        assert!(max > 0.0, "bucket {i} gradient is all-zero");
        assert!(max.is_finite(), "bucket {i} gradient not finite");
    }
}

#[test]
fn update_then_step_reduces_loss() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let step = engine.load(m.exe("train_step").unwrap()).unwrap();
    let update = engine.load(m.exe("apply_update").unwrap()).unwrap();
    let k = m.meta_usize("n_buckets").unwrap();
    let mut params = read_init(&m);
    let mut momenta: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    let toks = tokens(&m, 2);

    let run_step = |params: &[Vec<f32>], toks: &[i32]| {
        let mut inputs: Vec<HostTensor> =
            params.iter().cloned().map(HostTensor::F32).collect();
        inputs.push(HostTensor::I32(toks.to_vec()));
        step.run(&inputs).unwrap()
    };

    let out0 = run_step(&params, &toks);
    let loss0 = out0[0].as_f32().unwrap()[0];

    // Three SGD steps on the same batch must reduce the loss.
    let mut loss_prev = loss0;
    for _ in 0..3 {
        let out = run_step(&params, &toks);
        let grads: Vec<Vec<f32>> = out[1..]
            .iter()
            .map(|t| t.as_f32().unwrap().to_vec())
            .collect();
        let mut inputs: Vec<HostTensor> = Vec::new();
        for p in &params {
            inputs.push(HostTensor::F32(p.clone()));
        }
        for g in &grads {
            inputs.push(HostTensor::F32(g.clone()));
        }
        for mo in &momenta {
            inputs.push(HostTensor::F32(mo.clone()));
        }
        inputs.push(HostTensor::F32(vec![0.3]));
        inputs.push(HostTensor::F32(vec![1.0]));
        let out = update.run(&inputs).unwrap();
        for i in 0..k {
            params[i] = out[i].as_f32().unwrap().to_vec();
            momenta[i] = out[k + i].as_f32().unwrap().to_vec();
        }
        let loss = run_step(&params, &toks)[0].as_f32().unwrap()[0];
        assert!(loss.is_finite());
        loss_prev = loss;
    }
    assert!(
        loss_prev < loss0 * 0.98,
        "loss did not drop: {loss0} -> {loss_prev}"
    );
}

#[test]
fn grad_reduce_matches_rust_mean() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let reduce = engine.load(m.exe("grad_reduce").unwrap()).unwrap();
    let workers = m.meta_usize("workers").unwrap();
    let spec = &reduce.spec.inputs;
    let mut rng = deft::util::Rng::new(3);
    let stacked: Vec<Vec<f32>> = spec
        .iter()
        .map(|s| {
            (0..s.elements())
                .map(|_| (rng.f64() as f32) - 0.5)
                .collect()
        })
        .collect();
    let inputs: Vec<HostTensor> = stacked.iter().cloned().map(HostTensor::F32).collect();
    let out = reduce.run(&inputs).unwrap();
    for (slab, o) in stacked.iter().zip(out.iter()) {
        let o = o.as_f32().unwrap();
        let n = o.len();
        for j in 0..n {
            let mut mean = 0.0f64;
            for w in 0..workers {
                mean += slab[w * n + j] as f64;
            }
            mean /= workers as f64;
            assert!(
                (o[j] as f64 - mean).abs() < 1e-5,
                "element {j}: {} vs {mean}",
                o[j]
            );
        }
    }
}

#[test]
fn trainer_end_to_end_short_run() {
    let Some(_m) = manifest() else { return };
    use deft::config::Scheme;
    use deft::links::ClusterEnv;
    use deft::train::{TrainOptions, Trainer};

    let opts = TrainOptions {
        manifest: "artifacts/manifest.toml".into(),
        scheme: Scheme::Deft,
        workers: 2,
        iterations: 8,
        lr: 0.2,
        momentum: 0.9,
        seed: 5,
        log_every: 2,
        env: ClusterEnv::paper_testbed().with_workers(2),
    };
    let env = opts.env.clone();
    let mut trainer = Trainer::new(opts).unwrap();
    let profiles = trainer.profile_buckets(1).unwrap();
    assert_eq!(profiles.len(), trainer.n_buckets());
    let scheduler = deft::bench::scheduler_for(Scheme::Deft, false, &env);
    let schedule = scheduler.schedule(&profiles);
    let report = trainer.run(&schedule, &profiles).unwrap();
    assert!(report.updates > 0, "no updates fired");
    let first = report.losses.first().unwrap().1;
    let last = report.final_loss;
    assert!(last.is_finite() && first.is_finite());
    assert!(
        last < first,
        "8 iterations should reduce loss: {first} -> {last}"
    );
}
