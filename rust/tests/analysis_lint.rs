//! Integration suite for the static verifier (`deft::analysis`).
//!
//! Three angles, per the paper's soundness story:
//!
//! 1. **Grid cleanliness** — every plan the four schedulers emit across
//!    the model zoo × link presets × topologies lints clean, and the
//!    lint's per-cycle volume accounting matches what the discrete-event
//!    simulator actually puts on the wire (`SimResult::link_traffic`).
//! 2. **Solver agreement** — schedules built from the greedy *and* the
//!    exact §III.D multi-knapsack assignments both pass the capacity
//!    lint, with the greedy objective never above the exact optimum
//!    (property-checked over random instances).
//! 3. **Mutation sensitivity** — every `analysis::MutationClass` applied
//!    to a known-clean DeFT plan trips at least one error diagnostic,
//!    including its designated code (property-checked over random
//!    class × seed draws).
//!
//! Plus a docs-sync check: `docs/diagnostics.md` documents every code.

use deft::analysis::{apply_mutation, lint_plan, Code, LintOptions, MutationClass};
use deft::bench::{
    partition_for, scheduler_for, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION,
};
use deft::config::Scheme;
use deft::links::{ClusterEnv, LinkId, LinkPreset, Topology};
use deft::models::BucketProfile;
use deft::sched::{CommOp, FwdDependency, IterPlan, Schedule, Stage};
use deft::sim::{simulate, SimOptions};
use deft::solver::{multi_knapsack_exact, multi_knapsack_greedy, Item};
use deft::util::prop::{check, Gen};
use deft::util::Micros;

fn all_schemes() -> Vec<Scheme> {
    let mut schemes = Scheme::ALL.to_vec();
    schemes.push(Scheme::DeftNoMultilink);
    schemes
}

fn grid_envs(preset: LinkPreset) -> Vec<(&'static str, ClusterEnv)> {
    vec![
        ("flat", preset.env()),
        (
            "hier8",
            preset
                .env()
                .with_topology(Topology::hierarchical(8, LinkId(0), LinkId(1))),
        ),
    ]
}

// ---- 1. Grid cleanliness + simulator consistency. ----

/// Every plan any scheduler emits over the zoo × preset × topology grid
/// passes the full verifier (capacity, coverage, conservation, precision).
/// (llama2 rides the CI explorer `--lint` grid; `small` keeps this test
/// fast while still covering a non-paper shape.)
#[test]
fn every_scheduler_plan_lints_clean_across_the_grid() {
    let opts = LintOptions::default();
    let mut linted = 0usize;
    for wname in ["resnet101", "vgg19", "gpt2", "small"] {
        let w = workload_by_name(wname).expect("zoo workload");
        for preset in LinkPreset::ALL {
            for (topo, env) in grid_envs(preset) {
                for scheme in all_schemes() {
                    let Ok(buckets) =
                        partition_for(&w, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB)
                    else {
                        continue; // sweep semantics: infeasible combos skip
                    };
                    let schedule = scheduler_for(scheme, true, &env).schedule(&buckets);
                    let report = lint_plan(&schedule, &buckets, &env, &opts);
                    assert!(
                        report.is_clean(),
                        "{wname} × {} × {topo} × {}:\n{}",
                        preset.name(),
                        scheme.name(),
                        report.render_text()
                    );
                    linted += 1;
                }
            }
        }
    }
    assert!(linted >= 100, "grid shrank unexpectedly: {linted} plans");
}

/// The lint's per-cycle byte accounting is the simulator's ground truth:
/// over any whole number of cycles, `SimResult::link_traffic[k].raw_bytes`
/// is exactly `cycles × LintReport::link_raw_bytes[k]`.
#[test]
fn lint_volume_accounting_matches_the_simulator() {
    let opts = LintOptions::default();
    for wname in ["vgg19", "gpt2"] {
        let w = workload_by_name(wname).expect("zoo workload");
        for (topo, env) in grid_envs(LinkPreset::Paper2Link) {
            for scheme in all_schemes() {
                let buckets = partition_for(&w, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB)
                    .expect("partition");
                let schedule = scheduler_for(scheme, true, &env).schedule(&buckets);
                let report = lint_plan(&schedule, &buckets, &env, &opts);
                assert!(report.is_clean(), "{}", report.render_text());

                let cycle = schedule.cycle.len();
                let cycles = 6usize;
                let sim = simulate(
                    &buckets,
                    &schedule,
                    &env,
                    &SimOptions {
                        iterations: cycle * cycles,
                        warmup: cycle,
                        record_timeline: false,
                    },
                );
                for (k, traffic) in sim.link_traffic.iter().enumerate() {
                    assert_eq!(
                        traffic.raw_bytes,
                        report.link_raw_bytes[k] * cycles as u64,
                        "{wname} × {topo} × {} link {k}: sim bytes diverge from lint",
                        scheme.name()
                    );
                }
            }
        }
    }
}

// ---- 2. Greedy and exact knapsack plans both pass the capacity lint. ----

/// Mirror of `sched::cap_loss` (the §III.D knapsack capacity for one
/// link): a slow link's window holds `window/μ` of reference-time comm.
fn cap_of(window: Micros, mu: f64) -> Micros {
    if mu == 1.0 {
        window
    } else {
        window.scale(1.0 / mu)
    }
}

/// Bucket set for a synthetic knapsack instance: `fwd = comm` per bucket
/// so the whole-iteration amortization bound always covers force-shipped
/// leftovers.
fn knapsack_buckets(comms: &[u64], bwds: &[u64]) -> Vec<BucketProfile> {
    comms
        .iter()
        .zip(bwds.iter())
        .enumerate()
        .map(|(id, (&comm, &bwd))| BucketProfile {
            id,
            params: 1_000_000,
            fwd: Micros(comm),
            bwd: Micros(bwd),
            comm: Micros(comm),
        })
        .collect()
}

/// One-iteration `FwdDependency::None` schedule realizing a multi-knapsack
/// assignment: packed ids ride their sack's link in the backward window;
/// leftovers force-ship on the reference link (priority −1), exactly like
/// DeFT's over-capacity path.
fn schedule_from_assignment(
    scheme: &str,
    n_buckets: usize,
    assignments: &[Vec<usize>],
) -> Schedule {
    let mut bwd_ops = Vec::new();
    let mut packed = vec![false; n_buckets];
    for (k, ids) in assignments.iter().enumerate() {
        for (i, &id) in ids.iter().enumerate() {
            packed[id] = true;
            bwd_ops.push(CommOp {
                bucket: id,
                link: LinkId(k),
                stage: Stage::Backward,
                priority: i as i64,
                grad_age: 1,
                merged: 1,
                update_offset: 0,
            });
        }
    }
    for (id, &was_packed) in packed.iter().enumerate() {
        if !was_packed {
            bwd_ops.push(CommOp {
                bucket: id,
                link: LinkId::REFERENCE,
                stage: Stage::Backward,
                priority: -1,
                grad_age: 1,
                merged: 1,
                update_offset: 0,
            });
        }
    }
    Schedule {
        scheme: scheme.into(),
        cycle: vec![IterPlan {
            fwd_ops: Vec::new(),
            bwd_ops,
            update_at_end: true,
        }],
        fwd_dependency: FwdDependency::None,
        updates_per_cycle: 1,
        batch_multipliers: vec![1],
        warmup_iters: 1,
        max_outstanding_iters: usize::MAX,
        capacity_scale_bits: (1.0f64).to_bits(),
    }
}

/// Deterministic instance: both solver outputs realize as lint-clean
/// plans and greedy never beats the exact optimum.
#[test]
fn greedy_and_exact_knapsack_plans_both_lint_clean() {
    let env = ClusterEnv::paper_testbed();
    let mus = env.link_planning_mus();
    let comms: Vec<u64> = vec![9_000, 7_500, 6_000, 4_500, 3_000, 2_500, 1_500, 800];
    let bwds: Vec<u64> = vec![2_000; 8];
    let buckets = knapsack_buckets(&comms, &bwds);
    let sum_bwd: Micros = buckets.iter().map(|b| b.bwd).sum();
    let caps: Vec<Micros> = mus.iter().map(|&mu| cap_of(sum_bwd, mu)).collect();
    let items: Vec<Item> = buckets
        .iter()
        .map(|b| Item::new(b.id, b.comm))
        .collect();

    let greedy = multi_knapsack_greedy(&items, &caps);
    let (exact_assign, exact_total) = multi_knapsack_exact(&items, &caps);
    assert!(greedy.total <= exact_total, "greedy beat the exact optimum");

    let opts = LintOptions::default();
    for (name, assign) in [("greedy", &greedy.assignments), ("exact", &exact_assign)] {
        let s = schedule_from_assignment(name, buckets.len(), assign);
        let report = lint_plan(&s, &buckets, &env, &opts);
        assert!(
            report.is_clean(),
            "{name} assignment failed the lint:\n{}",
            report.render_text()
        );
        // The lint's recorded backward-window loads equal the packed
        // comm per sack — capacity accounting is exact, not bounded.
        for w in report
            .loads
            .iter()
            .filter(|w| w.stage == Stage::Backward)
        {
            let packed: Micros = assign[w.link.index()]
                .iter()
                .map(|&id| buckets[id].comm)
                .sum();
            assert_eq!(w.load, packed, "{name} link {:?}", w.link);
            assert!(w.load <= w.cap);
        }
    }
}

/// Property: over random instances, the greedy plan passes the capacity
/// lint (the packer never overfills the caps the lint re-derives) and its
/// objective never exceeds the exact optimum.
#[test]
fn prop_capacity_lint_passing_greedy_stays_below_exact() {
    let env = ClusterEnv::paper_testbed();
    let mus = env.link_planning_mus();
    check("greedy ≤ exact on lint-clean plans", 120, |g: &mut Gen| {
        let comms = g.vec_u64(2..=8, 100..=30_000);
        let bwds = g.vec_u64(comms.len()..=comms.len(), 100..=20_000);
        let buckets = knapsack_buckets(&comms, &bwds);
        let sum_bwd: Micros = buckets.iter().map(|b| b.bwd).sum();
        let caps: Vec<Micros> = mus.iter().map(|&mu| cap_of(sum_bwd, mu)).collect();
        let items: Vec<Item> = buckets.iter().map(|b| Item::new(b.id, b.comm)).collect();

        let greedy = multi_knapsack_greedy(&items, &caps);
        let s = schedule_from_assignment("greedy-prop", buckets.len(), &greedy.assignments);
        let report = lint_plan(&s, &buckets, &env, &LintOptions::default());
        if !report.is_clean() {
            return Err(format!(
                "greedy plan failed the lint:\n{}",
                report.render_text()
            ));
        }
        let (_, exact_total) = multi_knapsack_exact(&items, &caps);
        if greedy.total > exact_total {
            return Err(format!(
                "greedy {} µs beat exact {} µs",
                greedy.total.as_us(),
                exact_total.as_us()
            ));
        }
        Ok(())
    });
}

// ---- 3. Mutation sensitivity. ----

fn deft_vgg19_case() -> (Schedule, Vec<BucketProfile>, ClusterEnv) {
    let env = ClusterEnv::paper_testbed();
    let w = workload_by_name("vgg19").expect("zoo workload");
    let buckets =
        partition_for(&w, Scheme::Deft, &env, PAPER_PARTITION, PAPER_DDP_MB).expect("partition");
    let schedule = scheduler_for(Scheme::Deft, true, &env).schedule(&buckets);
    (schedule, buckets, env)
}

/// Property: any mutation class at any seed produces at least one error
/// diagnostic, and specifically the class's designated code — the
/// differential argument that the verifier actually discriminates.
#[test]
fn prop_every_mutation_trips_its_designated_code() {
    let (schedule, buckets, env) = deft_vgg19_case();
    let opts = LintOptions::default();
    let base = lint_plan(&schedule, &buckets, &env, &opts);
    assert!(base.is_clean(), "base plan dirty:\n{}", base.render_text());

    check("mutations always trip their code", 80, |g: &mut Gen| {
        let class = MutationClass::ALL[g.usize_in(0..=MutationClass::ALL.len() - 1)];
        let seed = g.u64_in(0..=10_000);
        let case = apply_mutation(class, &schedule, &buckets, &env, seed);
        let report = lint_plan(&case.schedule, &case.buckets, &case.env, &opts);
        if report.is_clean() {
            return Err(format!("{} (seed {seed}) linted clean", class.name()));
        }
        if !report.has_code(case.expected) {
            return Err(format!(
                "{} (seed {seed}) missed {}:\n{}",
                class.name(),
                case.expected.as_str(),
                report.render_text()
            ));
        }
        Ok(())
    });
}

// ---- 4. Docs stay in sync with the code table. ----

#[test]
fn docs_list_every_diagnostic_code() {
    let docs = include_str!("../../docs/diagnostics.md");
    for code in Code::ALL {
        assert!(
            docs.contains(code.as_str()),
            "docs/diagnostics.md is missing {}",
            code.as_str()
        );
    }
}
