//! Fault-injection suite: seeded determinism, engine equivalence under
//! faults, a hand-computed single-flap oracle, straggler monotonicity,
//! profiler-trace replay under faults, and the lifecycle's drift-aware
//! Preserver re-gate.
//!
//! The contract under test (see `docs/faults.md`): a [`FaultSpec`] is
//! compiled into a deterministic trace before simulation, so identical
//! seed + fault config ⇒ bit-for-bit identical [`deft::sim::SimResult`]
//! — fault log included — on both engines.

use deft::bench::{partition_for, scheduler_for, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::faults::{FaultEvent, FaultSpec, Flap, Straggler};
use deft::links::{ClusterEnv, Codec, LinkId, LinkPreset, LinkSpec, Topology};
use deft::models::BucketProfile;
use deft::profiler::{generate_trace, reconstruct, TraceOptions};
use deft::sched::{
    run_lifecycle, CommOp, FallbackReason, FwdDependency, IterPlan, LifecycleOptions, Schedule,
    Stage,
};
use deft::sim::{simulate, simulate_faulted, simulate_scan_faulted, SimOptions};
use deft::util::Micros;

const ALL_SCHEMES: [Scheme; 5] = [
    Scheme::PytorchDdp,
    Scheme::Bytescheduler,
    Scheme::UsByte,
    Scheme::Deft,
    Scheme::DeftNoMultilink,
];

fn bucket(id: usize, comm: Micros) -> BucketProfile {
    BucketProfile {
        id,
        params: 1_000_000,
        fwd: Micros(10_000),
        bwd: Micros(10_000),
        comm,
    }
}

fn op(bucket: usize, link: LinkId, grad_age: usize) -> CommOp {
    CommOp {
        bucket,
        link,
        stage: Stage::Backward,
        priority: 0,
        grad_age,
        merged: 1,
        update_offset: 0,
    }
}

fn schedule_of(bwd_ops: Vec<CommOp>) -> Schedule {
    let s = Schedule {
        scheme: "fault-probe".into(),
        cycle: vec![IterPlan {
            fwd_ops: Vec::new(),
            bwd_ops,
            update_at_end: true,
        }],
        fwd_dependency: FwdDependency::Barrier,
        updates_per_cycle: 1,
        batch_multipliers: vec![1],
        warmup_iters: 0,
        max_outstanding_iters: usize::MAX,
        capacity_scale_bits: (1.0f64).to_bits(),
    };
    s.validate().unwrap();
    s
}

/// Build a real pipeline (workload → partition → schedule) and simulate
/// it on both engines under `spec`, asserting bit-for-bit agreement.
fn faulted_pipeline(
    workload: &str,
    scheme: Scheme,
    env: &ClusterEnv,
    spec: Option<&FaultSpec>,
    label: &str,
) -> deft::sim::SimResult {
    let w = workload_by_name(workload).unwrap();
    let buckets = partition_for(&w, scheme, env, PAPER_PARTITION, PAPER_DDP_MB).unwrap();
    let schedule = scheduler_for(scheme, true, env).schedule(&buckets);
    let warmup = schedule.warmup_iters + schedule.cycle.len() + 2;
    let opts = SimOptions {
        iterations: warmup * 3 + 12,
        warmup,
        record_timeline: true,
    };
    let indexed = simulate_faulted(&buckets, &schedule, env, &opts, spec);
    let scan = simulate_scan_faulted(&buckets, &schedule, env, &opts, spec);
    assert_eq!(indexed, scan, "engines diverged under faults on {label}");
    indexed
}

/// Hand-computed single-flap oracle on a one-link, one-bucket plan.
///
/// fwd 0→10 000 µs, bwd 10 000→20 000 µs, then a 50 000 µs transfer on
/// the lone μ=1 link: healthy end = 70 000 µs. A flap to 3.0× at
/// t = 40 000 banks the 20 000 µs already transferred, re-prices the
/// 30 000 µs remainder at 3× → end = 40 000 + 90 000 = 130 000 µs.
#[test]
fn single_flap_matches_hand_computed_piecewise_repricing() {
    let env = ClusterEnv::paper_testbed().with_links(vec![LinkSpec::new("w", 1.0).with_group(0)]);
    let buckets = vec![bucket(0, Micros(50_000))];
    let schedule = schedule_of(vec![op(0, LinkId(0), 0)]);
    let opts = SimOptions {
        iterations: 1,
        warmup: 0,
        record_timeline: true,
    };
    let healthy = simulate(&buckets, &schedule, &env, &opts);
    assert_eq!(healthy.total, Micros(70_000));

    let spec = FaultSpec {
        flaps: vec![Flap {
            link: LinkId(0),
            at: Micros(40_000),
            factor: 3.0,
        }],
        ..FaultSpec::default()
    };
    let flapped = simulate_faulted(&buckets, &schedule, &env, &opts, Some(&spec));
    assert_eq!(flapped.total, Micros(130_000), "piecewise re-pricing is exact");
    assert_eq!(
        flapped.fault_log,
        vec![FaultEvent::LinkFlap {
            link: LinkId(0),
            at: Micros(40_000),
            ratio_ppm: 3_000_000,
        }]
    );
    let scan = simulate_scan_faulted(&buckets, &schedule, &env, &opts, Some(&spec));
    assert_eq!(flapped, scan);
}

/// Hand-computed slowest-rank oracle on a one-link, one-bucket plan.
///
/// fwd 0→10 000 µs, bwd 10 000→20 000 µs, then a 50 000 µs transfer on
/// the lone μ=1 link: healthy end = 70 000 µs. Stragglers of 1.5× on
/// rank 0 and 1.25× on rank 1 both start at iteration 0; the window
/// follows the **slowest rank** (rank 0, +50%): fwd and bwd each gain
/// 5 000 µs → end = 80 000 µs, not the 85 000 µs the old uniform-sum
/// rule (+75%) would give. Moving both stragglers onto rank 0 *does*
/// sum — same-rank excesses compound — and yields exactly 85 000 µs.
#[test]
fn rank_asymmetric_stragglers_match_hand_computed_slowest_rank_rule() {
    let env = ClusterEnv::paper_testbed().with_links(vec![LinkSpec::new("w", 1.0).with_group(0)]);
    let buckets = vec![bucket(0, Micros(50_000))];
    let schedule = schedule_of(vec![op(0, LinkId(0), 0)]);
    let opts = SimOptions {
        iterations: 1,
        warmup: 0,
        record_timeline: true,
    };
    let healthy = simulate(&buckets, &schedule, &env, &opts);
    assert_eq!(healthy.total, Micros(70_000));

    let two_ranks = FaultSpec {
        stragglers: vec![
            Straggler {
                from_iter: 0,
                factor: 1.5,
                rank: 0,
            },
            Straggler {
                from_iter: 0,
                factor: 1.25,
                rank: 1,
            },
        ],
        ..FaultSpec::default()
    };
    let indexed = simulate_faulted(&buckets, &schedule, &env, &opts, Some(&two_ranks));
    assert_eq!(
        indexed.total,
        Micros(80_000),
        "the window follows the slowest rank, not the rank sum"
    );
    assert_eq!(
        indexed.fault_log,
        vec![
            FaultEvent::StragglerOnset {
                iter: 0,
                factor_ppm: 1_500_000,
            },
            FaultEvent::StragglerOnset {
                iter: 0,
                factor_ppm: 1_250_000,
            },
        ]
    );
    let scan = simulate_scan_faulted(&buckets, &schedule, &env, &opts, Some(&two_ranks));
    assert_eq!(indexed, scan, "engines diverged on the straggler oracle");

    let same_rank = FaultSpec {
        stragglers: vec![
            Straggler {
                from_iter: 0,
                factor: 1.5,
                rank: 0,
            },
            Straggler {
                from_iter: 0,
                factor: 1.25,
                rank: 0,
            },
        ],
        ..FaultSpec::default()
    };
    let stacked = simulate_faulted(&buckets, &schedule, &env, &opts, Some(&same_rank));
    assert_eq!(
        stacked.total,
        Micros(85_000),
        "excesses on the same rank compound additively"
    );
    let scan = simulate_scan_faulted(&buckets, &schedule, &env, &opts, Some(&same_rank));
    assert_eq!(stacked, scan);
}

/// A noop spec (no jitter, no faults, no drift band) must be exactly the
/// unfaulted simulation — same events, same metrics, empty fault log.
#[test]
fn noop_spec_is_bit_for_bit_the_healthy_run() {
    let env = ClusterEnv::paper_testbed();
    let noop = FaultSpec::default();
    assert!(noop.is_noop());
    for scheme in [Scheme::PytorchDdp, Scheme::Deft] {
        let w = workload_by_name("small").unwrap();
        let buckets = partition_for(&w, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB).unwrap();
        let schedule = scheduler_for(scheme, true, &env).schedule(&buckets);
        let warmup = schedule.warmup_iters + schedule.cycle.len() + 2;
        let opts = SimOptions {
            iterations: warmup * 3 + 8,
            warmup,
            record_timeline: true,
        };
        let healthy = simulate(&buckets, &schedule, &env, &opts);
        let faulted = simulate_faulted(&buckets, &schedule, &env, &opts, Some(&noop));
        assert_eq!(healthy, faulted, "{}: noop spec perturbed the run", scheme.name());
        assert!(faulted.fault_log.is_empty());
    }
}

/// Identical seed + fault config ⇒ identical `SimResult`, fault log
/// included — and a different jitter seed actually changes the run.
#[test]
fn seeded_fault_runs_replay_bit_for_bit() {
    let env = ClusterEnv::paper_testbed();
    let mut spec = FaultSpec::preset("mixed", env.workers).unwrap();
    let a = faulted_pipeline("small", Scheme::Deft, &env, Some(&spec), "replay/a");
    let b = faulted_pipeline("small", Scheme::Deft, &env, Some(&spec), "replay/b");
    assert_eq!(a, b, "same seed must replay bit-for-bit");
    assert!(!a.fault_log.is_empty(), "mixed scenario records its faults");

    spec.seed ^= 0x9e37_79b9;
    let c = faulted_pipeline("small", Scheme::Deft, &env, Some(&spec), "replay/c");
    assert_ne!(
        a.iter_ends, c.iter_ends,
        "a different jitter seed must perturb iteration timing"
    );
}

/// Both engines, every preset × topology × scheme, under the compound
/// "mixed" scenario (jitter + straggler + flap + membership).
#[test]
fn engines_agree_under_mixed_faults_on_the_full_grid() {
    for preset in LinkPreset::ALL {
        for (topo, env) in [
            ("flat", preset.env()),
            (
                "hier8",
                preset
                    .env()
                    .with_topology(Topology::hierarchical(8, LinkId(0), LinkId(1))),
            ),
        ] {
            let spec = FaultSpec::preset("mixed", env.workers).unwrap();
            for scheme in ALL_SCHEMES {
                faulted_pipeline(
                    "small",
                    scheme,
                    &env,
                    Some(&spec),
                    &format!("{}/{topo}/{}", preset.name(), scheme.name()),
                );
            }
        }
    }
}

/// Time-to-solution is monotone non-decreasing in straggler severity: a
/// slower worker can never finish training earlier.
#[test]
fn tts_is_monotone_in_straggler_severity() {
    let env = ClusterEnv::paper_testbed();
    let mut prev = Micros::ZERO;
    for factor in [1.0, 1.2, 1.5, 2.0, 3.0] {
        let spec = FaultSpec {
            stragglers: vec![Straggler {
                from_iter: 2,
                factor,
                rank: 0,
            }],
            ..FaultSpec::default()
        };
        let sim = faulted_pipeline(
            "small",
            Scheme::Deft,
            &env,
            Some(&spec),
            &format!("straggler-{factor}"),
        );
        assert!(
            sim.total >= prev,
            "total {:?} decreased at straggler factor {factor} (prev {:?})",
            sim.total,
            prev
        );
        prev = sim.total;
    }
}

/// Satellite: a recorded operator trace, reconstructed at bucket level,
/// replays through the faulted simulator — the Fig. 8 round-trip is a
/// valid fault-scenario input, and both engines agree on it.
#[test]
fn reconstructed_trace_replays_under_a_straggler() {
    let env = ClusterEnv::paper_testbed();
    let w = workload_by_name("gpt2").unwrap();
    let topts = TraceOptions::uniform(&w, 6);
    let (events, _truth) = generate_trace(&w, &topts);
    let rec = reconstruct(&events);
    let mut profile: Vec<BucketProfile> = Vec::with_capacity(rec.len());
    let mut layer = 0usize;
    for (b, r) in rec.iter().enumerate() {
        let count = topts.layers_per_bucket[b];
        let params: u64 = w.layers[layer..layer + count].iter().map(|l| l.params).sum();
        layer += count;
        profile.push(BucketProfile {
            id: r.id,
            params,
            fwd: r.fwd,
            bwd: r.bwd,
            comm: env.reference_comm(params, w.comm_rate_ref),
        });
    }
    let schedule = scheduler_for(Scheme::Deft, true, &env).schedule(&profile);
    let warmup = schedule.warmup_iters + schedule.cycle.len() + 2;
    let opts = SimOptions {
        iterations: warmup * 3 + 8,
        warmup,
        record_timeline: true,
    };
    let spec = FaultSpec {
        stragglers: vec![Straggler {
            from_iter: 2,
            factor: 1.5,
            rank: 0,
        }],
        ..FaultSpec::default()
    };
    let healthy = simulate(&profile, &schedule, &env, &opts);
    let indexed = simulate_faulted(&profile, &schedule, &env, &opts, Some(&spec));
    let scan = simulate_scan_faulted(&profile, &schedule, &env, &opts, Some(&spec));
    assert_eq!(indexed, scan, "engines diverged on the reconstructed trace");
    assert!(
        indexed.total >= healthy.total,
        "a straggler cannot speed up the reconstructed replay"
    );
    assert_eq!(
        indexed.fault_log,
        vec![FaultEvent::StragglerOnset {
            iter: 2,
            factor_ppm: 1_500_000,
        }]
    );
}

/// Tentpole acceptance: a drift-band breach in the trial demonstrably
/// re-runs the Preserver gate, records its decision on the fault log,
/// and degrades the lossy plan to the raw replay.
#[test]
fn drift_band_breach_regates_and_falls_back() {
    // fp16 on gloo passes the codec gate (error ≪ ε), so without faults
    // this env accepts the lossy plan with no fallback. A severe early
    // link flap (4× on the reference link until t = 400 ms) pushes the
    // measured busy far outside the 25% drift band: the re-gate walk
    // runs with the drift error composed in and must reject.
    let env = ClusterEnv::paper_testbed().with_codec(LinkId(1), Codec::Fp16);
    let opts = LifecycleOptions {
        faults: Some(FaultSpec::preset("flap", env.workers).unwrap()),
        ..LifecycleOptions::default()
    };
    let rep = run_lifecycle(&workload_by_name("gpt2").unwrap(), &env, &opts).expect("lifecycle");

    let alarms = rep
        .trial
        .fault_log
        .iter()
        .filter(|e| matches!(e, FaultEvent::DriftAlarm { .. }))
        .count();
    assert!(alarms > 0, "the 4x flap must trip the drift monitor");
    let decisions: Vec<&FaultEvent> = rep
        .trial
        .fault_log
        .iter()
        .filter(|e| matches!(e, FaultEvent::GateDecision { .. }))
        .collect();
    assert_eq!(decisions.len(), 1, "exactly one re-gate decision is recorded");
    assert!(
        matches!(decisions[0], FaultEvent::GateDecision { accepted: false, .. }),
        "the composed drift error must fail the walk: {:?}",
        decisions[0]
    );
    assert!(
        matches!(rep.fallback, FallbackReason::DriftGateRejected { .. }),
        "fallback reason must be the drift re-gate: {:?}",
        rep.fallback
    );
    assert!(rep.fallback.is_fallback());
    assert!(rep.codec_fallback, "rejection degrades to the raw replay");

    // The same scenario against an already-raw registry still records
    // the rejected gate decision but has nothing safer to degrade to.
    let raw_env = ClusterEnv::paper_testbed();
    let rep_raw = run_lifecycle(&workload_by_name("gpt2").unwrap(), &raw_env, &opts)
        .expect("raw lifecycle");
    assert!(
        rep_raw
            .trial
            .fault_log
            .iter()
            .any(|e| matches!(e, FaultEvent::GateDecision { .. })),
        "gate decision recorded on the raw registry too"
    );
    assert!(!rep_raw.codec_fallback, "no lossy plan to fall back from");
}
