//! Property tests over the schedule contract — the invariants every
//! scheme must satisfy regardless of the bucket profile it is given.
//!
//! Uses the crate's own miniature property harness (`deft::util::prop`);
//! the offline build has no proptest.

use deft::links::ClusterEnv;
use deft::models::BucketProfile;
use deft::sched::{
    Bytescheduler, Deft, DeftOptions, Schedule, Scheduler, Stage, UsByte, Wfbp,
};
use deft::sim::{simulate, SimOptions};
use deft::util::prop::{check, Gen};
use deft::util::Micros;

/// Generate a random but plausible bucket profile set.
fn gen_buckets(g: &mut Gen) -> Vec<BucketProfile> {
    let n = g.usize_in(1..=10);
    let mut out = Vec::with_capacity(n);
    for id in 0..n {
        let fwd = g.u64_in(50..=30_000);
        let bwd = g.u64_in(100..=80_000);
        let comm = g.u64_in(100..=150_000);
        out.push(BucketProfile {
            id,
            params: comm * 500, // plausible param/comm proportionality
            fwd: Micros(fwd),
            bwd: Micros(bwd),
            comm: Micros(comm),
        });
    }
    out
}

fn schedulers() -> Vec<(&'static str, Box<dyn Scheduler>)> {
    vec![
        ("wfbp", Box::new(Wfbp)),
        ("bytescheduler", Box::new(Bytescheduler::default())),
        ("us-byte", Box::new(UsByte::default())),
        (
            "deft",
            Box::new(Deft::new(DeftOptions {
                preserver: false,
                ..DeftOptions::default()
            })),
        ),
        ("deft-nolink", Box::new(Deft::without_multilink())),
    ]
}

/// Invariant 1: schedules validate and conserve gradient volume — over
/// one cycle, each bucket's shipped `merged` counts sum to exactly the
/// cycle length (every iteration's gradient leaves exactly once).
#[test]
fn prop_volume_conservation() {
    check("gradient volume conservation", 120, |g| {
        let buckets = gen_buckets(g);
        for (name, s) in schedulers() {
            let schedule = s.schedule(&buckets);
            schedule.validate().map_err(|e| format!("{name}: {e}"))?;
            for b in 0..buckets.len() {
                let shipped: usize = schedule
                    .cycle
                    .iter()
                    .flat_map(|p| p.all_ops())
                    .filter(|op| op.bucket == b)
                    .map(|op| op.merged)
                    .sum();
                if shipped != schedule.cycle.len() {
                    return Err(format!(
                        "{name}: bucket {b} ships {shipped} iters over {}-iter cycle",
                        schedule.cycle.len()
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Invariant 2: no op for the current iteration's gradient launches in
/// the forward window (the data does not exist yet), and DeFT never
/// ships bucket 0 with age 0 (the paper's hard dependency).
#[test]
fn prop_causality_of_launch_windows() {
    check("launch-window causality", 120, |g| {
        let buckets = gen_buckets(g);
        for (name, s) in schedulers() {
            let schedule = s.schedule(&buckets);
            for plan in &schedule.cycle {
                for op in plan.all_ops() {
                    if op.grad_age == 0 && op.stage == Stage::Forward {
                        return Err(format!("{name}: fresh grad in forward window"));
                    }
                    if name.starts_with("deft") && op.bucket == 0 && op.grad_age == 0 {
                        return Err(format!("{name}: bucket 0 shipped un-delayed"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Invariant 3: the simulator executes every schedule to completion with
/// a steady iteration time no smaller than the compute floor.
#[test]
fn prop_simulation_terminates_above_compute_floor() {
    check("simulation floor", 60, |g| {
        let buckets = gen_buckets(g);
        let compute: Micros = buckets.iter().map(|b| b.fwd + b.bwd).sum();
        let env = ClusterEnv::paper_testbed();
        for (name, s) in schedulers() {
            let schedule = s.schedule(&buckets);
            let iters = (schedule.cycle.len() * 4).max(12);
            let r = simulate(
                &buckets,
                &schedule,
                &env,
                &SimOptions {
                    iterations: iters,
                    warmup: schedule.cycle.len().max(2),
                    record_timeline: false,
                },
            );
            if r.steady_iter_time < compute {
                return Err(format!(
                    "{name}: iter {} below compute floor {compute}",
                    r.steady_iter_time
                ));
            }
            if r.update_times.is_empty() {
                return Err(format!("{name}: no updates fired"));
            }
        }
        Ok(())
    });
}

/// Invariant 4: DeFT's update pattern is consistent — Σ batch
/// multipliers equals the cycle length, and the update frequency equals
/// updates/cycle (validate() already enforces it; this checks through
/// the public accessors on random inputs plus monotonicity vs the
/// no-multilink ablation).
#[test]
fn prop_deft_update_accounting() {
    check("deft update accounting", 80, |g| {
        let buckets = gen_buckets(g);
        let het = Deft::new(DeftOptions {
            preserver: false,
            ..DeftOptions::default()
        })
        .schedule(&buckets);
        let solo = Deft::without_multilink().schedule(&buckets);
        let k_sum: u64 = het.batch_multipliers.iter().sum();
        if k_sum != het.cycle.len() as u64 {
            return Err(format!("Σk {k_sum} != cycle {}", het.cycle.len()));
        }
        if solo.update_frequency() > het.update_frequency() + 1e-9 {
            return Err(format!(
                "single-link updates more often: {} vs {}",
                solo.update_frequency(),
                het.update_frequency()
            ));
        }
        Ok(())
    });
}

/// Invariant 5: baselines update exactly once per iteration (exact
/// convergence consistency, Table III).
#[test]
fn prop_baselines_update_every_iteration() {
    check("baseline update frequency", 100, |g| {
        let buckets = gen_buckets(g);
        for (name, s) in schedulers() {
            if name.starts_with("deft") {
                continue;
            }
            let schedule: Schedule = s.schedule(&buckets);
            if (schedule.update_frequency() - 1.0).abs() > 1e-12 {
                return Err(format!("{name}: freq {}", schedule.update_frequency()));
            }
            if schedule.batch_multipliers.iter().any(|&k| k != 1) {
                return Err(format!("{name}: non-unit batch multiplier"));
            }
        }
        Ok(())
    });
}
