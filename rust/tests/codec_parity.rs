//! Codec parity & property suite (mirrors `link_parity.rs` /
//! `topology_parity.rs` for the compression layer).
//!
//! 1. **Raw parity**: `Codec::Raw` — the default on every link — must
//!    reproduce the pre-codec pricing **bit-for-bit** on all three
//!    presets, flat and hierarchical: the pre-codec flat closed forms
//!    and the PR-2 segment-path arithmetic are reimplemented below as
//!    oracles, and full pipelines (schedule + `SimResult` metrics) are
//!    compared across raw-codec constructions for all four schemes plus
//!    the no-multilink ablation.
//! 2. **Properties** (`util::prop` style): codec-effective knapsack
//!    capacities keep the paper's greedy within the exact optimum; fp16
//!    wire time never exceeds raw wire time; rank-k wire time is
//!    monotone in `k` and saturates at raw.
//! 3. **Preserver regression**: a lossy codec whose injected gradient
//!    error fails `acceptable(report, eps)` forces the lifecycle to fall
//!    back to the raw link, and the resulting plan is byte-identical to
//!    the no-codec plan.
//! 4. **Engine**: encode overhead is charged on the compute stream and
//!    the per-link compressed-vs-raw byte counters are exact.

use deft::bench::scheduler_for;
use deft::config::Scheme;
use deft::links::{ClusterEnv, Codec, LinkId, LinkPreset, LinkSpec, Topology};
use deft::models::{vgg19, vgg19_table2_buckets, BucketProfile};
use deft::sched::{
    run_lifecycle, CommOp, FwdDependency, IterPlan, LifecycleOptions, Schedule, Stage,
};
use deft::sim::{simulate, LinkTraffic, SimOptions, SimResult, StreamId};
use deft::solver::{multi_knapsack_exact, multi_knapsack_greedy, Item};
use deft::util::prop::check;
use deft::util::Micros;

const PARAM_SWEEP: [u64; 8] = [
    0,
    1_048_576,
    4_194_304,
    8_388_608,
    16_777_216,
    33_554_432,
    67_108_864,
    134_217_728,
];

fn sim(buckets: &[BucketProfile], schedule: &Schedule, env: &ClusterEnv) -> SimResult {
    simulate(
        buckets,
        schedule,
        env,
        &SimOptions {
            iterations: (schedule.cycle.len() * 4).max(24),
            warmup: schedule.cycle.len().max(4),
            record_timeline: true,
        },
    )
}

// ---- Pre-codec oracles, reimplemented verbatim. ----

/// Flat wire-time rule as it stood before codecs: `comm · μ` (exact for
/// μ = 1) with the static Table IV contention scaling.
fn legacy_flat_wire(env: &ClusterEnv, link: LinkId, comm: Micros, params: u64) -> Micros {
    let mu = env.spec(link).mu;
    let t = if mu == 1.0 { comm } else { comm.scale(mu) };
    if env.contended(link) {
        t.scale(1.0 + env.contention_penalty(params))
    } else {
        t
    }
}

/// Flat `allreduce_us` closed form as it stood before codecs.
fn legacy_flat_allreduce(env: &ClusterEnv, link: LinkId, params: u64) -> Micros {
    if env.workers <= 1 || params == 0 {
        return Micros::ZERO;
    }
    let ring = 2.0 * (env.workers as f64 - 1.0) / env.workers as f64;
    let bytes = params as f64 * 4.0 * ring;
    let wire_bytes_per_us = env.bandwidth_gbps * 1e9 / 8.0 / 1e6;
    let base_us = bytes / (wire_bytes_per_us * env.efficiency);
    let spec = env.spec(link);
    let knee = 33.6e6;
    let p = params as f64;
    let staging = if spec.staging_ramp == 0.0 || p <= knee {
        1.0
    } else {
        1.0 + spec.staging_ramp * ((p - knee) / knee).min(1.0)
    };
    let t = spec.alpha + Micros::from_us_f64(base_us * 1.0 * spec.mu * staging);
    if env.contended(link) {
        t.scale(1.0 + env.contention_penalty(params))
    } else {
        t
    }
}

fn ring(k: usize) -> f64 {
    if k <= 1 {
        0.0
    } else {
        2.0 * (k as f64 - 1.0) / k as f64
    }
}

/// PR-2 hierarchical segment decomposition (intra = link 0, inter =
/// link 1) as it stood before codecs.
fn legacy_hier_segments(
    env: &ClusterEnv,
    link: LinkId,
    comm: Micros,
    rpn: usize,
) -> Vec<(LinkId, Micros)> {
    let price = |l: LinkId, factor: f64| {
        if factor == 1.0 {
            comm
        } else {
            comm.scale(factor)
        }
    };
    let w = env.workers;
    if rpn <= 1 || w <= 1 {
        return vec![(link, price(link, env.spec(link).mu * 1.0))];
    }
    let (intra, inter) = (LinkId(0), LinkId(1));
    let nodes = w / rpn;
    let flat_ring = ring(w);
    let fabric = if link == intra { inter } else { link };
    let mut out = Vec::new();
    let intra_traffic = ring(rpn) / flat_ring;
    if intra_traffic > 0.0 {
        out.push((intra, price(intra, env.spec(intra).mu * intra_traffic)));
    }
    let inter_traffic = ring(nodes) / (rpn as f64 * flat_ring);
    if inter_traffic > 0.0 {
        out.push((fabric, price(fabric, env.spec(fabric).mu * inter_traffic)));
    }
    out
}

// ---- 1. Raw parity. ----

/// Every preset link (plus the single-NIC contention variants) prices
/// exactly as the pre-codec flat closed forms across the Table IV sweep.
#[test]
fn raw_flat_pricing_matches_the_pre_codec_closed_forms() {
    let mut envs: Vec<ClusterEnv> = LinkPreset::ALL.iter().map(|p| p.env()).collect();
    // The pre-codec closed forms priced shared NICs with the pairwise
    // Table IV rule; the collapsed 3-link registry therefore pins the
    // pairwise model explicitly (for 2-member groups — every preset —
    // the default k-way model is bit-for-bit identical, which
    // `tests/contention_model.rs` pins separately).
    envs.push(
        LinkPreset::NvlinkIbTcp
            .env()
            .with_single_link()
            .with_contention_model(deft::links::ContentionModel::Pairwise),
    );
    for env in &envs {
        for link in env.link_ids() {
            for params in PARAM_SWEEP {
                let comm = Micros(params / 37 + 11);
                assert_eq!(
                    env.wire_time(link, comm, params),
                    legacy_flat_wire(env, link, comm, params),
                    "{:?} wire @ {params}",
                    link
                );
                assert_eq!(
                    env.allreduce_us(link, params),
                    legacy_flat_allreduce(env, link, params),
                    "{:?} allreduce @ {params}",
                    link
                );
            }
            // Codec-effective μ degenerates to the raw μ.
            assert!((env.path_mu(link) - env.spec(link).mu).abs() < 1e-15);
        }
    }
}

/// Hierarchical segment pricing with raw codecs matches the PR-2
/// arithmetic bit-for-bit for every preset and node size.
#[test]
fn raw_hierarchical_pricing_matches_the_pre_codec_segments() {
    for preset in LinkPreset::ALL {
        for rpn in [1usize, 2, 8] {
            let env = preset
                .env()
                .with_topology(Topology::hierarchical(rpn, LinkId(0), LinkId(1)));
            for link in env.link_ids() {
                for params in PARAM_SWEEP {
                    let comm = Micros(params / 53 + 7);
                    let want = legacy_hier_segments(&env, link, comm, rpn);
                    assert_eq!(
                        env.wire_segments(link, comm),
                        want,
                        "{}/rpn {rpn}/{:?} segments",
                        preset.name(),
                        link
                    );
                    let total: Micros = want.iter().map(|&(_, t)| t).sum();
                    assert_eq!(env.wire_time_uncontended(link, comm), total);
                }
            }
        }
    }
}

/// Full pipeline parity: the default registry, an explicitly
/// `with_codec(Raw)` registry, and a `with_raw_codecs()` round-trip must
/// yield identical schedules and identical `SimResult` metrics for all
/// four schemes (plus the no-multilink ablation), flat and hierarchical
/// — and the engine's codec accounting must be the identity.
#[test]
fn raw_codec_pipelines_are_bit_for_bit_identical() {
    let buckets = vgg19_table2_buckets();
    for preset in LinkPreset::ALL {
        for hier in [false, true] {
            let mut base = preset.env();
            if hier {
                base = base.with_topology(Topology::hierarchical(8, LinkId(0), LinkId(1)));
            }
            let mut explicit = base.clone().with_raw_codecs();
            for id in base.link_ids().collect::<Vec<_>>() {
                explicit = explicit.with_codec(id, Codec::Raw);
            }
            assert_eq!(base.links, explicit.links, "{}", preset.name());
            let mut schemes = Scheme::ALL.to_vec();
            schemes.push(Scheme::DeftNoMultilink);
            for scheme in schemes {
                let s_base = scheduler_for(scheme, false, &base).schedule(&buckets);
                let s_explicit = scheduler_for(scheme, false, &explicit).schedule(&buckets);
                assert_eq!(s_base, s_explicit, "{}/{:?}", preset.name(), scheme);
                let r_base = sim(&buckets, &s_base, &base);
                let r_explicit = sim(&buckets, &s_explicit, &explicit);
                let what = format!("{}/{:?}/hier={hier}", preset.name(), scheme);
                assert_eq!(r_base.steady_iter_time, r_explicit.steady_iter_time, "{what}");
                assert_eq!(r_base.total, r_explicit.total, "{what}");
                assert_eq!(r_base.compute_bubbles, r_explicit.compute_bubbles, "{what}");
                assert_eq!(r_base.update_times, r_explicit.update_times, "{what}");
                assert_eq!(r_base.link_busy, r_explicit.link_busy, "{what}");
                assert_eq!(r_base.iter_ends, r_explicit.iter_ends, "{what}");
                assert_eq!(r_base.link_traffic, r_explicit.link_traffic, "{what}");
                // Raw codecs are the identity in the engine accounting.
                assert!(r_base.link_codecs.iter().all(|c| c == "raw"), "{what}");
                for tr in &r_base.link_traffic {
                    assert_eq!(tr.raw_bytes, tr.wire_bytes, "{what}");
                    assert!(tr.encode.is_zero(), "{what}");
                }
            }
        }
    }
}

// ---- 2. Properties. ----

/// Codec-effective knapsack capacities (compute ÷ codec-effective path
/// μ, exactly as the schedulers derive them) keep the paper's greedy
/// within the exact multi-knapsack optimum, and both stay within every
/// capacity.
#[test]
fn prop_codec_effective_capacities_keep_greedy_within_exact() {
    check("greedy <= exact (codec-effective caps)", 40, |g| {
        let n_links = g.usize_in(2..=4);
        let mut links = Vec::with_capacity(n_links);
        for i in 0..n_links {
            let mu = if i == 0 { 1.0 } else { 1.0 + g.f64_in(0.0, 6.0) };
            let codec = match g.usize_in(0..=2) {
                0 => Codec::Raw,
                1 => Codec::Fp16,
                _ => Codec::RankK {
                    k: g.u64_in(1..=64) as u32,
                },
            };
            links.push(LinkSpec::new(&format!("l{i}"), mu).with_group(i).with_codec(codec));
        }
        let env = ClusterEnv::paper_testbed().with_links(links);
        let compute = Micros(g.u64_in(1_000..=100_000));
        let caps: Vec<Micros> = env
            .link_path_mus()
            .iter()
            .map(|&mu| compute.scale(1.0 / mu))
            .collect();
        let comms = g.vec_u64(0..=9, 0..=60_000);
        let its: Vec<Item> = comms
            .iter()
            .enumerate()
            .map(|(i, &c)| Item::new(i, Micros(c)))
            .collect();
        let (assign, e_total) = multi_knapsack_exact(&its, &caps);
        let gr = multi_knapsack_greedy(&its, &caps);
        if gr.total > e_total {
            return Err(format!("greedy {:?} beats exact {e_total:?}", gr.total));
        }
        for (k, sack) in assign.iter().chain(gr.assignments.iter()).enumerate() {
            let cap = caps[k % caps.len()];
            let used: Micros = sack.iter().map(|&id| its[id].comm).sum();
            if used > cap {
                return Err(format!("sack {k} over codec-effective capacity"));
            }
        }
        Ok(())
    });
}

/// fp16 wire time never exceeds raw wire time — for all parameter
/// sizes, μs, contention configurations, and topologies.
#[test]
fn prop_fp16_wire_time_never_exceeds_raw() {
    check("fp16 wire <= raw wire", 120, |g| {
        let mu = 1.0 + g.f64_in(0.0, 8.0);
        let shared_nic = g.usize_in(0..=1) == 1;
        let mk = |codec: Codec| {
            let slow_group = if shared_nic { 0 } else { 1 };
            ClusterEnv::paper_testbed().with_links(vec![
                LinkSpec::new("ref", 1.0).with_group(0),
                LinkSpec::new("slow", mu).with_group(slow_group).with_codec(codec),
            ])
        };
        let raw = mk(Codec::Raw);
        let fp16 = mk(Codec::Fp16);
        let params = g.u64_in(0..=200_000_000);
        let comm = Micros(g.u64_in(0..=10_000_000));
        let slow = LinkId(1);
        if fp16.wire_time(slow, comm, params) > raw.wire_time(slow, comm, params) {
            return Err(format!("flat wire: fp16 beats raw at {params} params"));
        }
        if fp16.wire_time_uncontended(slow, comm) > raw.wire_time_uncontended(slow, comm) {
            return Err("flat uncontended wire: fp16 beats raw".into());
        }
        // Hierarchical: fp16 on the fabric must stay ≤ raw.
        let rpn = [2usize, 4, 8][g.usize_in(0..=2)];
        let topo = Topology::hierarchical(rpn, LinkId(0), LinkId(1));
        let raw_h = raw.clone().with_topology(topo);
        let fp16_h = fp16.clone().with_topology(topo);
        if fp16_h.wire_time(slow, comm, params) > raw_h.wire_time(slow, comm, params) {
            return Err(format!("hierarchical wire: fp16 beats raw at rpn {rpn}"));
        }
        Ok(())
    });
}

/// Rank-k wire time is monotone non-decreasing in `k` (more rank = more
/// bytes) and saturates exactly at the raw wire time at
/// `k ≥ RANKK_REF_DIM / 2`.
#[test]
fn prop_rankk_wire_time_monotone_in_k() {
    check("rank-k wire monotone in k", 80, |g| {
        let mu = 1.0 + g.f64_in(0.0, 8.0);
        let base = ClusterEnv::paper_testbed().with_links(vec![
            LinkSpec::new("ref", 1.0).with_group(0),
            LinkSpec::new("slow", mu).with_group(1),
        ]);
        let params = g.u64_in(1..=100_000_000);
        let comm = Micros(g.u64_in(1..=5_000_000));
        let slow = LinkId(1);
        let mut prev = Micros::ZERO;
        for k in [1u32, 2, 4, 8, 16, 64, 256, 512, 1024] {
            let env = base.clone().with_codec(slow, Codec::RankK { k });
            let t = env.wire_time(slow, comm, params);
            if t < prev {
                return Err(format!("wire not monotone at k={k}: {t:?} < {prev:?}"));
            }
            prev = t;
        }
        let raw = base.wire_time(slow, comm, params);
        if prev != raw {
            return Err(format!("saturated rank-k {prev:?} != raw {raw:?}"));
        }
        Ok(())
    });
}

// ---- 3. Preserver regression. ----

/// A lossy codec whose injected error makes `acceptable(report, eps)`
/// false forces the lifecycle to fall back to the raw link, and the
/// resulting plan is byte-identical to the no-codec plan.
#[test]
fn preserver_rejection_forces_fallback_to_the_no_codec_plan() {
    let raw_env = ClusterEnv::paper_testbed();
    let lossy_env = ClusterEnv::paper_testbed().with_codec(LinkId(1), Codec::RankK { k: 1 });
    let opts = LifecycleOptions::default();
    let w = vgg19();
    let r_raw = run_lifecycle(&w, &raw_env, &opts).expect("raw lifecycle");
    let r_lossy = run_lifecycle(&w, &lossy_env, &opts).expect("lossy lifecycle");
    assert!(!r_raw.codec_fallback);
    assert!(r_lossy.codec_fallback, "rank-1 error must be rejected");
    assert!(
        (r_lossy.attempts[0].1 - 1.0).abs() > opts.epsilon,
        "first (lossy) attempt must fail eps: ratio {}",
        r_lossy.attempts[0].1
    );
    assert_eq!(
        r_lossy.schedule, r_raw.schedule,
        "fallback plan must be byte-identical to the no-codec plan"
    );
    assert_eq!(r_lossy.trial.iter_ends, r_raw.trial.iter_ends);
    assert_eq!(r_lossy.trial.update_times, r_raw.trial.update_times);

    // fp16's error is inside ε: the lossy route is kept.
    let fp16_env = ClusterEnv::paper_testbed().with_codec(LinkId(1), Codec::Fp16);
    let r_fp16 = run_lifecycle(&w, &fp16_env, &opts).expect("fp16 lifecycle");
    assert!(!r_fp16.codec_fallback, "fp16 must pass the gate");
}

// ---- 4. Engine: encode on the compute stream, byte counters. ----

fn two_bucket_schedule() -> (Vec<BucketProfile>, Schedule) {
    let bucket = |id: usize| BucketProfile {
        id,
        params: 1_000_000, // 4 MB raw → 8 µs fp16 encode
        fwd: Micros(10_000),
        bwd: Micros(10_000),
        comm: Micros(5_000),
    };
    let op = |bucket: usize| CommOp {
        bucket,
        link: LinkId(0),
        stage: Stage::Backward,
        priority: 0,
        grad_age: 0,
        merged: 1,
        update_offset: 0,
    };
    let schedule = Schedule {
        scheme: "codec-probe".into(),
        cycle: vec![IterPlan {
            fwd_ops: Vec::new(),
            bwd_ops: vec![op(1), op(0)],
            update_at_end: true,
        }],
        fwd_dependency: FwdDependency::Barrier,
        updates_per_cycle: 1,
        batch_multipliers: vec![1],
        warmup_iters: 0,
        max_outstanding_iters: usize::MAX,
        capacity_scale_bits: (1.0f64).to_bits(),
    };
    schedule.validate().unwrap();
    (vec![bucket(0), bucket(1)], schedule)
}

#[test]
fn engine_charges_encode_on_the_compute_stream_and_counts_bytes() {
    let (buckets, schedule) = two_bucket_schedule();
    let opts = SimOptions {
        iterations: 1,
        warmup: 0,
        record_timeline: true,
    };
    let raw_env = ClusterEnv::paper_testbed();
    let fp16_env = ClusterEnv::paper_testbed().with_codec(LinkId(0), Codec::Fp16);
    let r_raw = simulate(&buckets, &schedule, &raw_env, &opts);
    let r_fp16 = simulate(&buckets, &schedule, &fp16_env, &opts);

    // Raw: fwd 20 ms, bwd1 ends 30 ms → wire [30, 35), bwd0 ends 40 ms
    // → wire [40, 45); update at 45 ms.
    assert_eq!(r_raw.total, Micros(45_000));
    assert_eq!(r_raw.timeline.busy(StreamId::Compute), Micros(40_000));
    assert_eq!(
        r_raw.link_traffic[0],
        LinkTraffic {
            raw_bytes: 8_000_000,
            wire_bytes: 8_000_000,
            encode: Micros::ZERO,
        }
    );

    // fp16: each backward task stretches by its op's 8 µs encode (the
    // wire cannot start before the gradient is compressed), and each
    // wire halves: bwd1 [20, 30.008) → wire [30.008, 32.508),
    // bwd0 [30.008, 40.016) → wire [40.016, 42.516).
    assert_eq!(r_fp16.total, Micros(42_516));
    assert_eq!(r_fp16.timeline.busy(StreamId::Compute), Micros(40_016));
    assert_eq!(r_fp16.iter_ends, vec![Micros(40_016)]);
    assert_eq!(r_fp16.update_times, vec![Micros(42_516)]);
    assert_eq!(r_fp16.link_busy[0].1, Micros(5_000), "wire time halves");
    assert_eq!(
        r_fp16.link_traffic[0],
        LinkTraffic {
            raw_bytes: 8_000_000,
            wire_bytes: 4_000_000,
            encode: Micros(16),
        }
    );
    assert_eq!(r_fp16.link_codecs, vec!["fp16".to_string(), "raw".to_string()]);
}
