//! Contention-model suite: closed-form oracles, the Table IV golden fit,
//! and property tests for the aggregate k-way sharing model
//! (`ClusterEnv::contention_factor` + the DES engine's piecewise
//! re-pricing), pinned against the legacy pairwise model.
//!
//! 1. **k = 1** — a transfer with no in-flight group-mate prices exactly
//!    as `wire_time_uncontended`, on every preset, flat and hierarchical.
//! 2. **k = 2** — a payer fully overlapped by the group's exempt member
//!    prices exactly `uncontended · contention_factor(2, params)` —
//!    bit-for-bit the pairwise Table IV penalty — on every collapsed
//!    preset, flat and hierarchical.
//! 3. A 3-transfer **staircase** whose group membership changes at five
//!    distinct events, checked µs-for-µs against a hand-computed
//!    piecewise timeline, and strictly slower than the pairwise model.
//! 4. **Finalize-path regression**: a paying transfer extended by a
//!    late-starting group-mate speeds back up when the mate finishes
//!    early — the re-check the pairwise one-shot extension lacks.
//! 5. **Golden Table IV fit** under the k-way model (promoted from
//!    `bench_table4_fig6_links` so tier-1 catches drift).
//! 6. Properties: group throughput caps, completion monotone in k,
//!    greedy ≤ exact on k-way planning capacities.

use deft::links::{
    ClusterEnv, ContentionModel, LinkId, LinkPreset, LinkSpec, Topology, CONTENTION_PEAK,
};
use deft::models::BucketProfile;
use deft::sched::{CommOp, FwdDependency, IterPlan, Schedule, Stage};
use deft::sim::{simulate, SimOptions, SimResult, SpanKind, StreamId};
use deft::solver::{multi_knapsack_exact, multi_knapsack_greedy, Item};
use deft::util::prop::check;
use deft::util::Micros;

/// All scenario tensors sit on the Table IV plateau: penalty = 0.21.
const PARAMS: u64 = 33_554_432;

fn bucket(id: usize, comm: Micros) -> BucketProfile {
    BucketProfile {
        id,
        params: PARAMS,
        fwd: Micros(10_000),
        bwd: Micros(10_000),
        comm,
    }
}

fn op(bucket: usize, link: LinkId, grad_age: usize) -> CommOp {
    CommOp {
        bucket,
        link,
        stage: Stage::Backward,
        priority: 0,
        grad_age,
        merged: 1,
        update_offset: 0,
    }
}

fn schedule_of(bwd_ops: Vec<CommOp>) -> Schedule {
    let s = Schedule {
        scheme: "contention-probe".into(),
        cycle: vec![IterPlan {
            fwd_ops: Vec::new(),
            bwd_ops,
            update_at_end: true,
        }],
        fwd_dependency: FwdDependency::Barrier,
        updates_per_cycle: 1,
        batch_multipliers: vec![1],
        warmup_iters: 0,
        max_outstanding_iters: usize::MAX,
        capacity_scale_bits: (1.0f64).to_bits(),
    };
    s.validate().unwrap();
    s
}

fn run(buckets: &[BucketProfile], schedule: &Schedule, env: &ClusterEnv) -> SimResult {
    simulate(
        buckets,
        schedule,
        env,
        &SimOptions {
            iterations: 1,
            warmup: 0,
            record_timeline: true,
        },
    )
}

/// Completion time of `bucket`'s transfer on its home stream `link`
/// (home spans are recorded at completion; foreign hierarchical legs of
/// other transfers are filtered out by the bucket id).
fn comm_end(r: &SimResult, link: LinkId, bucket: usize) -> Micros {
    r.timeline
        .spans
        .iter()
        .filter(|s| {
            s.stream == StreamId::Link(link)
                && matches!(s.kind, SpanKind::Comm { bucket: b, .. } if b == bucket)
        })
        .map(|s| s.end)
        .max()
        .unwrap_or_else(|| panic!("no comm span for bucket {bucket} on {link:?}"))
}

/// The flat presets plus their hierarchical (8 ranks/node) variants.
fn preset_envs(preset: LinkPreset) -> Vec<ClusterEnv> {
    vec![
        preset.env(),
        preset
            .env()
            .with_topology(Topology::hierarchical(8, LinkId(0), LinkId(1))),
    ]
}

// ---- 1. k = 1: uncontended pricing, bit-for-bit. ----

/// A transfer flying alone prices exactly `wire_time_uncontended` under
/// the k-way model, on every preset link, flat and hierarchical — even
/// when the registry shares a NIC (an idle group-mate costs nothing).
#[test]
fn k1_matches_uncontended_pricing_on_all_presets() {
    for preset in LinkPreset::ALL {
        for base in preset_envs(preset) {
            for env in [base.clone(), base.clone().with_single_link()] {
                assert_eq!(env.contention, ContentionModel::Kway);
                for link in env.link_ids() {
                    let comm = Micros(50_000);
                    let buckets = vec![bucket(0, comm)];
                    let schedule = schedule_of(vec![op(0, link, 0)]);
                    let r = run(&buckets, &schedule, &env);
                    // Gradient ready at fwd (10 ms) + bwd (10 ms).
                    let want = Micros(20_000) + env.wire_time_uncontended(link, comm);
                    assert_eq!(
                        comm_end(&r, link, 0),
                        want,
                        "{}/{:?} hier={}",
                        preset.name(),
                        link,
                        env.topology != Topology::Flat
                    );
                }
            }
        }
    }
}

// ---- 2. k = 2: the pairwise Table IV penalty, bit-for-bit. ----

/// A payer whose flight is fully covered by the group's exempt member
/// prices exactly `uncontended · contention_factor(2, params)` — the
/// pairwise Table IV penalty — under the k-way engine, on every
/// collapsed preset, flat and hierarchical. For two-member groups this
/// equals the static planning estimate `wire_time` bit-for-bit, and the
/// legacy pairwise engine agrees on the same scenario.
#[test]
fn k2_full_overlap_matches_the_pairwise_penalty_bit_for_bit() {
    for preset in LinkPreset::ALL {
        for base in preset_envs(preset) {
            let env = base.with_single_link();
            let exempt = LinkId(0);
            assert!(!env.contended(exempt), "{}: link 0 must be exempt", preset.name());
            for payer in env.link_ids().filter(|&l| env.contended(l)) {
                // Long exempt transfer dispatched first (ready 30 ms),
                // short payer second (ready 40 ms), fully inside it.
                let comm1 = Micros(400_000);
                let comm2 = Micros(50_000);
                let buckets = vec![bucket(0, comm2), bucket(1, comm1)];
                let schedule = schedule_of(vec![op(1, exempt, 0), op(0, payer, 0)]);
                let r = run(&buckets, &schedule, &env);
                let uncontended = env.wire_time_uncontended(payer, comm2);
                let factor = env.contention_factor(2, PARAMS);
                let want = Micros(40_000) + uncontended.scale(factor);
                let got = comm_end(&r, payer, 0);
                assert_eq!(got, want, "{}/{:?}", preset.name(), payer);
                // Premise: the payer really was covered end to end.
                assert!(comm_end(&r, exempt, 1) >= got, "{}: not fully overlapped", preset.name());
                // The exempt member is never slowed.
                assert_eq!(
                    comm_end(&r, exempt, 1),
                    Micros(30_000) + env.wire_time_uncontended(exempt, comm1)
                );
                // Two-member groups: execution == static planning rule.
                if env.group_size(payer) == 2 {
                    assert_eq!(got, Micros(40_000) + env.wire_time(payer, comm2, PARAMS));
                }
                // The legacy pairwise engine prices this scenario the
                // same way (full overlap is the calibration point the
                // two models share).
                let pair_env = env.clone().with_contention_model(ContentionModel::Pairwise);
                let r_pair = run(&buckets, &schedule, &pair_env);
                assert_eq!(comm_end(&r_pair, payer, 0), got, "{}/{:?}", preset.name(), payer);
            }
        }
    }
}

// ---- 3. The 3-transfer staircase, hand-computed. ----

/// Three links on one NIC: a (μ1, exempt), b (μ2), c (μ4).
fn staircase_env() -> ClusterEnv {
    ClusterEnv::paper_testbed().with_links(vec![
        LinkSpec::new("a", 1.0).with_group(0),
        LinkSpec::new("b", 2.0).with_group(0),
        LinkSpec::new("c", 4.0).with_group(0),
    ])
}

/// Backward runs buckets 2→1→0, so c's transfer dispatches at 40 ms,
/// b's at 50 ms, a's at 60 ms: membership walks 1 → 2 → 3 → 2 → 1.
fn staircase_case() -> (Vec<BucketProfile>, Schedule) {
    let buckets = vec![
        bucket(0, Micros(50_000)),  // on a: wire 50 ms
        bucket(1, Micros(30_000)),  // on b: wire 60 ms
        bucket(2, Micros(30_000)),  // on c: wire 120 ms
    ];
    let schedule = schedule_of(vec![
        op(2, LinkId(2), 0),
        op(1, LinkId(1), 0),
        op(0, LinkId(0), 0),
    ]);
    (buckets, schedule)
}

/// The piecewise re-pricing, µs for µs against a hand-computed timeline
/// (penalty 0.21 ⇒ factor(2) = 1.21, factor(3) = 2.42; `scale` rounds to
/// the nearest µs at each membership event):
///
/// * 40 ms — c dispatches alone: rem 120 000, rate 1 ⇒ end 160 000.
/// * 50 ms — b dispatches (k = 2): c banked 10 000 (rem 110 000) and
///   slows to 1.21 ⇒ end 183 100; b: 60 000 · 1.21 ⇒ end 122 600.
/// * 60 ms — a dispatches (k = 3, exempt): b and c each banked
///   ⌊10 000/1.21⌉ = 8 264 ⇒ rems 51 736 / 101 736 at factor 2.42 ⇒
///   ends 185 201 / 306 201; a ends 110 000 at rate 1.
/// * 110 000 — a finalizes (k = 2): b and c each banked
///   ⌊50 000/2.42⌉ = 20 661 ⇒ rems 31 075 / 81 075 at 1.21 ⇒ ends
///   147 601 / 208 101.
/// * 147 601 — b finalizes (k = 1): c banked ⌊37 601/1.21⌉ = 31 075 ⇒
///   rem 50 000 at rate 1 ⇒ end **197 601**.
#[test]
fn three_transfer_staircase_is_repriced_piecewise() {
    let (buckets, schedule) = staircase_case();
    let env = staircase_env();
    assert_eq!(env.contention, ContentionModel::Kway);
    assert!(!env.contended(LinkId(0)));
    assert!(env.contended(LinkId(1)) && env.contended(LinkId(2)));
    let r = run(&buckets, &schedule, &env);
    assert_eq!(comm_end(&r, LinkId(0), 0), Micros(110_000), "exempt a");
    assert_eq!(comm_end(&r, LinkId(1), 1), Micros(147_601), "payer b");
    assert_eq!(comm_end(&r, LinkId(2), 2), Micros(197_601), "payer c");
    assert_eq!(r.iter_ends, vec![Micros(60_000)]);
    assert_eq!(r.update_times, vec![Micros(197_601)]);
    assert_eq!(r.total, Micros(197_601));
    // Busy = actual occupancy including the contention stretch.
    assert_eq!(r.link_busy[0].1, Micros(50_000));
    assert_eq!(r.link_busy[1].1, Micros(97_601));
    assert_eq!(r.link_busy[2].1, Micros(157_601));
    assert_eq!(r.contention, "kway");
}

/// The same staircase under the pairwise model prices strictly faster —
/// three concurrent transfers are exactly the regime the pairwise rule
/// underprices (the acceptance criterion for replacing it).
#[test]
fn staircase_prices_strictly_slower_than_the_pairwise_model() {
    let (buckets, schedule) = staircase_case();
    let kway = run(&buckets, &schedule, &staircase_env());
    let pair = run(
        &buckets,
        &schedule,
        &staircase_env().with_contention_model(ContentionModel::Pairwise),
    );
    assert_eq!(pair.contention, "pairwise");
    // Pairwise hand-compute: b charges 60 000 · 0.21 = 12 600 at its own
    // dispatch (end 122 600) and is extended 10 500 by a (end 133 100);
    // c is extended 15 246 by b and 10 500 by a (end 185 746).
    assert_eq!(comm_end(&pair, LinkId(1), 1), Micros(133_100));
    assert_eq!(comm_end(&pair, LinkId(2), 2), Micros(185_746));
    assert_eq!(pair.total, Micros(185_746));
    assert!(
        kway.total > pair.total,
        "3-way contention must price slower under k-way: {:?} vs {:?}",
        kway.total,
        pair.total
    );
    // The exempt member is identical under both models.
    assert_eq!(comm_end(&kway, LinkId(0), 0), comm_end(&pair, LinkId(0), 0));
}

// ---- 4. Finalize-path regression. ----

/// A paying transfer slowed by a late-starting group-mate must speed
/// back up when the mate finishes early. The pairwise engine charges the
/// whole projected window at the mate's dispatch and never re-checks at
/// its finalize; the k-way engine re-prices there — the regression this
/// PR fixes.
#[test]
fn payer_speeds_back_up_when_its_group_mate_finishes_early() {
    // Single-NIC paper pair: gloo (payer) flies [30 ms, …) with wire
    // 99 000; nccl (exempt) joins [40 ms, 60 ms) and finishes early.
    let buckets = vec![bucket(0, Micros(20_000)), bucket(1, Micros(60_000))];
    let schedule = schedule_of(vec![op(1, LinkId(1), 0), op(0, LinkId(0), 0)]);
    let kway_env = LinkPreset::SingleNic.env();
    let pair_env = LinkPreset::SingleNic
        .env()
        .with_contention_model(ContentionModel::Pairwise);
    let r_kway = run(&buckets, &schedule, &kway_env);
    let r_pair = run(&buckets, &schedule, &pair_env);
    let uncontended = Micros(30_000 + 99_000);
    // k-way hand-compute: gloo banks 10 000 before nccl joins
    // (rem 89 000 at 1.21), then banks ⌊20 000/1.21⌉ = 16 529 over the
    // shared window; at nccl's finalize (60 ms) the remaining 72 471
    // runs at rate 1 again ⇒ end 132 471.
    assert_eq!(comm_end(&r_kway, LinkId(1), 1), Micros(132_471));
    // Pairwise: one-shot extension of 20 000 · 0.21 = 4 200 at nccl's
    // dispatch, never revisited ⇒ end 133 200.
    assert_eq!(comm_end(&r_pair, LinkId(1), 1), Micros(133_200));
    assert!(Micros(132_471) > uncontended && Micros(132_471) < Micros(133_200));
    // The exempt mate is untouched either way.
    assert_eq!(comm_end(&r_kway, LinkId(0), 0), Micros(60_000));
    assert_eq!(comm_end(&r_pair, LinkId(0), 0), Micros(60_000));
}

// ---- 5. Golden Table IV fit under the k-way model. ----

/// Promoted from `bench_table4_fig6_links`: the k-way model's k = 2
/// calibration must keep reproducing the paper's Table IV single-NIC
/// gloo column (within the α–β fit's 15% band), leave NCCL untouched by
/// NIC sharing, and keep the multi-link NCCL:gloo ratio inside the
/// paper's 1.57–1.85 corridor (±5% fit slack).
#[test]
fn table4_single_nic_rows_hold_under_the_kway_model() {
    let multi = ClusterEnv::paper_testbed();
    let single = ClusterEnv::paper_testbed().with_single_link();
    assert_eq!(single.contention, ContentionModel::Kway);
    let nccl = multi.link("nccl").unwrap();
    let gloo = multi.link("gloo").unwrap();
    // Paper Table IV, single-link gloo column (ms → µs).
    let rows: [(u64, f64); 5] = [
        (4_194_304, 22_000.0),
        (8_388_608, 50_000.0),
        (16_777_216, 96_000.0),
        (33_554_432, 204_000.0),
        (67_108_864, 534_000.0),
    ];
    for (params, want_us) in rows {
        let got = single.allreduce_us(gloo, params).as_us() as f64;
        let err = (got - want_us).abs() / want_us;
        assert!(err < 0.15, "single-NIC gloo {params}: got {got}, want {want_us}");
        assert_eq!(
            single.allreduce_us(nccl, params),
            multi.allreduce_us(nccl, params),
            "NCCL must be unaffected by NIC sharing @ {params}"
        );
        let ratio = multi.allreduce_us(gloo, params).as_us() as f64
            / multi.allreduce_us(nccl, params).as_us() as f64;
        assert!(
            (1.5..=1.9).contains(&ratio),
            "multi-link gloo/nccl ratio {ratio} @ {params} outside the 1.57–1.85 band"
        );
    }
    // And the plateau degradation itself stays at the calibrated +21%.
    assert!((CONTENTION_PEAK - 0.21).abs() < 1e-12);
}

// ---- 6. Properties. ----

/// Throughput caps of the degradation curve, for **both** group
/// compositions: with the exempt member among the k in-flight transfers,
/// the paying cohort `(k−1)/factor` never exceeds one uncontended
/// transfer's bandwidth share and the whole group sits exactly at the
/// NIC's calibrated capacity `1 + 1/(1+penalty)`; with only payers in
/// flight, the aggregate `k/factor(k)` still never exceeds that
/// capacity.
#[test]
fn prop_group_throughput_never_exceeds_link_bandwidth() {
    check("k-way group throughput cap", 200, |g| {
        let env = ClusterEnv::paper_testbed();
        let params = g.u64_in(0..=200_000_000);
        let cap = 1.0 + 1.0 / (1.0 + env.contention_penalty(params));
        let mut prev = 1.0;
        for k in 1..=10usize {
            let f = env.contention_factor(k, params);
            if f < prev {
                return Err(format!("factor not monotone at k={k}: {f} < {prev}"));
            }
            prev = f;
            if k < 2 {
                continue;
            }
            // Exempt + (k−1) payers in flight.
            let payers = (k - 1) as f64 / f;
            if payers > 1.0 + 1e-12 {
                return Err(format!("payer cohort outships the link at k={k}: {payers}"));
            }
            if 1.0 + payers > cap + 1e-12 {
                return Err(format!(
                    "group throughput {} exceeds calibrated capacity {cap} at k={k}",
                    1.0 + payers
                ));
            }
            // Payers-only in flight (the exempt member idle): each of
            // the k payers runs at 1/factor(k).
            let payers_only = k as f64 / f;
            if payers_only > cap + 1e-12 {
                return Err(format!(
                    "payers-only throughput {payers_only} exceeds capacity {cap} at k={k}"
                ));
            }
        }
        Ok(())
    });
}

/// Per-transfer completion time is monotone non-decreasing in the
/// concurrency k, for any wire time and tensor size.
#[test]
fn prop_completion_time_monotone_in_k() {
    check("completion monotone in k", 200, |g| {
        let env = ClusterEnv::paper_testbed();
        let wire = Micros(g.u64_in(0..=10_000_000));
        let params = g.u64_in(0..=200_000_000);
        let mut prev = Micros::ZERO;
        for k in 1..=8usize {
            let t = wire.scale(env.contention_factor(k, params));
            if t < prev {
                return Err(format!("completion shrank at k={k}: {t:?} < {prev:?}"));
            }
            prev = t;
        }
        Ok(())
    });
}

/// Engine-level monotonicity: adding concurrent group-mates never
/// finishes the observed payer earlier (and strictly later once any
/// mate exists). Buckets 1..=m carry the mates; all ops launch together
/// at the backward-window open (delayed gradients).
#[test]
fn engine_payer_completion_monotone_in_concurrency() {
    let env = ClusterEnv::paper_testbed().with_links(vec![
        LinkSpec::new("f0", 1.0).with_group(0),
        LinkSpec::new("f1", 1.5).with_group(0),
        LinkSpec::new("f2", 1.5).with_group(0),
        LinkSpec::new("x", 2.0).with_group(0),
    ]);
    let x = LinkId(3);
    let buckets = vec![
        bucket(0, Micros(50_000)),
        bucket(1, Micros(100_000)),
        bucket(2, Micros(100_000)),
        bucket(3, Micros(100_000)),
    ];
    let mut prev = Micros::ZERO;
    for m in 0..=3usize {
        let mut ops = vec![op(0, x, 1)];
        for mate in 1..=m {
            ops.push(op(mate, LinkId(mate - 1), 1));
        }
        let r = run(&buckets, &schedule_of(ops), &env);
        let end = comm_end(&r, x, 0);
        if m == 0 {
            // Alone: uncontended.
            assert_eq!(end, Micros(40_000) + env.wire_time_uncontended(x, Micros(50_000)));
            prev = end;
        } else {
            assert!(end > prev, "m={m}: {end:?} not later than {prev:?}");
            prev = end;
        }
    }
}

/// Greedy ≤ exact multi-knapsack still holds when capacities derive from
/// the k-way planning slowdowns (path μ × static contention factor) of
/// randomly shared registries.
#[test]
fn prop_greedy_within_exact_on_kway_planning_capacities() {
    check("greedy <= exact (k-way planning caps)", 40, |g| {
        let n_links = g.usize_in(2..=4);
        let n_groups = g.usize_in(1..=2);
        let mut links = Vec::with_capacity(n_links);
        for i in 0..n_links {
            let mu = if i == 0 { 1.0 } else { 1.0 + g.f64_in(0.0, 6.0) };
            let group = g.usize_in(0..=n_groups - 1);
            links.push(LinkSpec::new(&format!("l{i}"), mu).with_group(group));
        }
        let env = ClusterEnv::paper_testbed().with_links(links);
        let compute = Micros(g.u64_in(1_000..=100_000));
        let caps: Vec<Micros> = env
            .link_planning_mus()
            .iter()
            .map(|&mu| compute.scale(1.0 / mu))
            .collect();
        let comms = g.vec_u64(0..=9, 0..=60_000);
        let its: Vec<Item> = comms
            .iter()
            .enumerate()
            .map(|(i, &c)| Item::new(i, Micros(c)))
            .collect();
        let (assign, e_total) = multi_knapsack_exact(&its, &caps);
        let gr = multi_knapsack_greedy(&its, &caps);
        if gr.total > e_total {
            return Err(format!("greedy {:?} beats exact {e_total:?}", gr.total));
        }
        for (k, sack) in assign.iter().chain(gr.assignments.iter()).enumerate() {
            let cap = caps[k % caps.len()];
            let used: Micros = sack.iter().map(|&id| its[id].comm).sum();
            if used > cap {
                return Err(format!("sack {k} over k-way planning capacity"));
            }
        }
        Ok(())
    });
}
