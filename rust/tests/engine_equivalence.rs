//! Golden-equivalence suite for the indexed-event DES engine: on every
//! preset × topology × scheme × contention-model combination the indexed
//! engine (`simulate`) must reproduce the retired scan engine
//! (`simulate_scan`) **bit-for-bit** — the full `SimResult`, timeline
//! spans included. The scan engine is kept verbatim in `sim::reference`
//! as the oracle; any divergence is a bug in the indexed hot path, never
//! an acceptable drift.

use deft::bench::{partition_for, scheduler_for, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::Scheme;
use deft::links::{ClusterEnv, ContentionModel, LinkId, LinkPreset, LinkSpec, Topology};
use deft::models::BucketProfile;
use deft::sched::{CommOp, FwdDependency, IterPlan, Schedule, Stage};
use deft::sim::{simulate, simulate_scan, SimOptions};
use deft::util::Micros;

const ALL_SCHEMES: [Scheme; 5] = [
    Scheme::PytorchDdp,
    Scheme::Bytescheduler,
    Scheme::UsByte,
    Scheme::Deft,
    Scheme::DeftNoMultilink,
];

/// Run both engines on one pipeline config and assert full equality.
fn assert_engines_agree(
    workload: &str,
    scheme: Scheme,
    env: &ClusterEnv,
    iterations: usize,
    record_timeline: bool,
    label: &str,
) {
    let w = workload_by_name(workload).unwrap();
    let buckets = partition_for(&w, scheme, env, PAPER_PARTITION, PAPER_DDP_MB).unwrap();
    let scheduler = scheduler_for(scheme, true, env);
    let schedule = scheduler.schedule(&buckets);
    let warmup = schedule.warmup_iters + schedule.cycle.len() + 2;
    let opts = SimOptions {
        iterations: iterations.max(warmup * 3 + 4),
        warmup,
        record_timeline,
    };
    let scan = simulate_scan(&buckets, &schedule, env, &opts);
    let indexed = simulate(&buckets, &schedule, env, &opts);
    assert_eq!(scan, indexed, "engines diverged on {label}");
    assert!(scan.events_processed > 0, "{label}: no events counted");
}

/// The flat and hierarchical (8 ranks/node) variants of a preset, under
/// both contention models.
fn env_grid(preset: LinkPreset) -> Vec<(String, ClusterEnv)> {
    let mut envs = Vec::new();
    for (topo, base) in [
        ("flat", preset.env()),
        (
            "hier8",
            preset
                .env()
                .with_topology(Topology::hierarchical(8, LinkId(0), LinkId(1))),
        ),
    ] {
        for model in [ContentionModel::Kway, ContentionModel::Pairwise] {
            envs.push((
                format!("{}/{topo}/{}", preset.name(), model.name()),
                base.clone().with_contention_model(model),
            ));
        }
    }
    envs
}

/// Every preset × topology × contention model × scheme on the small
/// transformer: the exhaustive sweep (120 engine pairs).
#[test]
fn indexed_engine_matches_scan_on_the_full_grid() {
    for preset in LinkPreset::ALL {
        for (label, env) in env_grid(preset) {
            for scheme in ALL_SCHEMES {
                assert_engines_agree(
                    "small",
                    scheme,
                    &env,
                    24,
                    true,
                    &format!("{label}/{}", scheme.name()),
                );
            }
        }
    }
}

/// The real evaluation workloads on the paper testbed, all schemes, with
/// the full span timeline compared too.
#[test]
fn indexed_engine_matches_scan_on_real_workloads() {
    let env = ClusterEnv::paper_testbed();
    for workload in ["vgg19", "gpt2"] {
        for scheme in ALL_SCHEMES {
            assert_engines_agree(
                workload,
                scheme,
                &env,
                40,
                true,
                &format!("paper/{workload}/{}", scheme.name()),
            );
        }
    }
}

/// The no-timeline fast path must agree with the scan engine running the
/// same options, and with its own timeline-recording run on every
/// non-timeline field.
#[test]
fn no_timeline_fast_path_matches_scan_and_its_own_timeline_run() {
    let env = ClusterEnv::paper_testbed();
    for scheme in [Scheme::PytorchDdp, Scheme::Deft] {
        assert_engines_agree(
            "vgg19",
            scheme,
            &env,
            30,
            false,
            &format!("no-timeline/{}", scheme.name()),
        );

        let w = workload_by_name("vgg19").unwrap();
        let buckets = partition_for(&w, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB).unwrap();
        let schedule = scheduler_for(scheme, true, &env).schedule(&buckets);
        let warmup = schedule.warmup_iters + schedule.cycle.len() + 2;
        let mk = |record_timeline| SimOptions {
            iterations: warmup * 3 + 30,
            warmup,
            record_timeline,
        };
        let with = simulate(&buckets, &schedule, &env, &mk(true));
        let without = simulate(&buckets, &schedule, &env, &mk(false));
        assert!(!with.timeline.spans.is_empty());
        assert!(without.timeline.spans.is_empty());
        let mut stripped = with.clone();
        stripped.timeline = Default::default();
        assert_eq!(stripped, without, "{}: metrics depend on span recording", scheme.name());
    }
}

// ---- Hand-built contention scenarios (from tests/contention_model.rs):
// the k-way staircase re-pricing and pairwise extension paths exercise
// the engine's repricing code far harder than any scheduler output. ----

/// All scenario tensors sit on the Table IV plateau.
const PARAMS: u64 = 33_554_432;

fn bucket(id: usize, comm: Micros) -> BucketProfile {
    BucketProfile {
        id,
        params: PARAMS,
        fwd: Micros(10_000),
        bwd: Micros(10_000),
        comm,
    }
}

fn op(bucket: usize, link: LinkId, grad_age: usize) -> CommOp {
    CommOp {
        bucket,
        link,
        stage: Stage::Backward,
        priority: 0,
        grad_age,
        merged: 1,
        update_offset: 0,
    }
}

fn schedule_of(bwd_ops: Vec<CommOp>) -> Schedule {
    let s = Schedule {
        scheme: "equivalence-probe".into(),
        cycle: vec![IterPlan {
            fwd_ops: Vec::new(),
            bwd_ops,
            update_at_end: true,
        }],
        fwd_dependency: FwdDependency::Barrier,
        updates_per_cycle: 1,
        batch_multipliers: vec![1],
        warmup_iters: 0,
        max_outstanding_iters: usize::MAX,
        capacity_scale_bits: (1.0f64).to_bits(),
    };
    s.validate().unwrap();
    s
}

/// Three links on one NIC: a (μ1, exempt), b (μ2), c (μ4) — membership
/// walks 1 → 2 → 3 → 2 → 1 across five re-pricing events.
fn staircase() -> (Vec<BucketProfile>, Schedule, ClusterEnv) {
    let env = ClusterEnv::paper_testbed().with_links(vec![
        LinkSpec::new("a", 1.0).with_group(0),
        LinkSpec::new("b", 2.0).with_group(0),
        LinkSpec::new("c", 4.0).with_group(0),
    ]);
    let buckets = vec![
        bucket(0, Micros(50_000)),
        bucket(1, Micros(30_000)),
        bucket(2, Micros(30_000)),
    ];
    let schedule = schedule_of(vec![
        op(2, LinkId(2), 0),
        op(1, LinkId(1), 0),
        op(0, LinkId(0), 0),
    ]);
    (buckets, schedule, env)
}

/// The 3-transfer k-way staircase and its pairwise counterpart: both
/// engines must produce identical piecewise timelines — and the k-way one
/// must still land on the hand-computed 197 601 µs total pinned in
/// `tests/contention_model.rs`.
#[test]
fn staircase_repricing_is_identical_across_engines() {
    let (buckets, schedule, env) = staircase();
    let opts = SimOptions {
        iterations: 1,
        warmup: 0,
        record_timeline: true,
    };
    for model in [ContentionModel::Kway, ContentionModel::Pairwise] {
        let env = env.clone().with_contention_model(model);
        let scan = simulate_scan(&buckets, &schedule, &env, &opts);
        let indexed = simulate(&buckets, &schedule, &env, &opts);
        assert_eq!(scan, indexed, "staircase diverged under {}", model.name());
    }
    let kway = simulate(&buckets, &schedule, &env, &opts);
    assert_eq!(kway.total, Micros(197_601));
    let pair = simulate(
        &buckets,
        &schedule,
        &env.with_contention_model(ContentionModel::Pairwise),
        &opts,
    );
    assert_eq!(pair.total, Micros(185_746));
}

/// A group-mate finishing early shrinks the payer's flight at finalize —
/// the indexed engine's lazy-invalidation path must fire the shrunk
/// completion at the same instant the scan engine's rescan does.
#[test]
fn finalize_shrink_fires_identically_across_engines() {
    let buckets = vec![bucket(0, Micros(20_000)), bucket(1, Micros(60_000))];
    let schedule = schedule_of(vec![op(1, LinkId(1), 0), op(0, LinkId(0), 0)]);
    let env = LinkPreset::SingleNic.env();
    let opts = SimOptions {
        iterations: 1,
        warmup: 0,
        record_timeline: true,
    };
    let scan = simulate_scan(&buckets, &schedule, &env, &opts);
    let indexed = simulate(&buckets, &schedule, &env, &opts);
    assert_eq!(scan, indexed);
}

/// The memoized contention staircase the indexed engine prices from must
/// agree entry-for-entry with the closed-form `contention_factor`.
#[test]
fn contention_staircase_memo_matches_the_closed_form() {
    for preset in LinkPreset::ALL {
        let env = preset.env();
        for params in [0u64, 4_194_304, PARAMS, 200_000_000] {
            let stair = env.contention_staircase(10, params);
            assert_eq!(stair.max_k(), 10);
            for k in 0..=10usize {
                assert_eq!(
                    stair.factor(k),
                    env.contention_factor(k, params),
                    "{}: staircase[{k}] drifted at {params} params",
                    preset.name()
                );
            }
        }
    }
}
