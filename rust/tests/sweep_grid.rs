//! Integration pins for the batch sweep engine (`deft::sweep`).
//!
//! The contract under test, in order: a sweep answer is *exactly* the
//! standalone run's answer (DeFT leg = `run_lifecycle`, baselines =
//! partition → schedule → faulted simulation with the pinned iteration
//! rule); parallel execution is bit-for-bit identical to serial, fault
//! injection included; the JSONL schema round-trips real results; and
//! the capacity planner answers a scripted query sequence
//! deterministically, with observable cache hits.

use deft::bench::{partition_for, scheduler_for, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use deft::config::{ExperimentConfig, Scheme};
use deft::sched::{run_lifecycle, FallbackReason, LifecycleOptions};
use deft::sim::{simulate_faulted, SimOptions};
use deft::sweep::{
    parse_jsonl, run_cell, run_grid, summary_csv, to_jsonl, Planner, SweepCell, SweepGrid,
};

fn cell(workload: &str, faults: Option<&str>) -> SweepCell {
    SweepCell {
        workload: workload.to_string(),
        preset: "paper-2link".to_string(),
        ranks_per_node: 1,
        codec: "raw".to_string(),
        contention: "kway".to_string(),
        faults: faults.map(str::to_string),
        workers: 16,
    }
}

/// A small all-`small`-workload grid so the parallel-equality and
/// round-trip pins stay fast; `faults` axis per test.
fn tiny_grid(faults: Vec<Option<String>>) -> SweepGrid {
    let mut grid = SweepGrid::small();
    grid.workloads = vec!["small".to_string()];
    grid.presets = vec!["paper-2link".to_string()];
    grid.faults = faults;
    grid
}

#[test]
fn sweep_answers_equal_standalone_runs_exactly() {
    let c = cell("small", None);
    let res = run_cell(&c).result.expect("healthy cell succeeds");
    let env = c.env().expect("cell env builds");
    let workload = workload_by_name("small").expect("workload");

    // The DeFT leg is the real lifecycle — same schedule, same trial,
    // same fallback reason as running the explorer on this cell.
    let rep = run_lifecycle(&workload, &env, &LifecycleOptions::default()).expect("lifecycle");
    let deft = res.schemes.iter().find(|s| s.scheme == "deft").expect("deft row");
    assert_eq!(deft.status, "ok");
    assert_eq!(deft.iter_us, rep.trial.steady_iter_time.as_us());
    assert_eq!(deft.total_us, rep.trial.total.as_us());
    assert_eq!(deft.events, rep.trial.events_processed);
    let label = match rep.fallback {
        FallbackReason::None => "none",
        FallbackReason::CodecGateRejected { .. } => "codec-gate",
        FallbackReason::LintRejected { .. } => "lint",
        FallbackReason::DriftGateRejected { .. } => "drift-gate",
        FallbackReason::Replanned { .. } => "replanned",
    };
    assert_eq!(deft.fallback, label);

    // A baseline leg is partition → schedule → simulation under the
    // sweep's pinned iteration rule, nothing more.
    let buckets = partition_for(&workload, Scheme::PytorchDdp, &env, PAPER_PARTITION, PAPER_DDP_MB)
        .expect("partition");
    let schedule = scheduler_for(Scheme::PytorchDdp, true, &env).schedule(&buckets);
    let warmup = schedule.warmup_iters + schedule.cycle.len() + 2;
    let opts = SimOptions {
        iterations: warmup * 3 + 12,
        warmup,
        record_timeline: false,
    };
    let sim = simulate_faulted(&buckets, &schedule, &env, &opts, None);
    let ddp = res
        .schemes
        .iter()
        .find(|s| s.scheme == "pytorch-ddp")
        .expect("ddp row");
    assert_eq!(ddp.iter_us, sim.steady_iter_time.as_us());
    assert_eq!(ddp.total_us, sim.total.as_us());
    assert_eq!(ddp.events, sim.events_processed);

    // The winner is the first minimal-iteration scheme in
    // `Scheme::ALL` order, and the headline fields are its fields.
    let best = res
        .schemes
        .iter()
        .filter(|s| s.status == "ok")
        .min_by_key(|s| s.iter_us)
        .expect("an ok scheme");
    assert_eq!(res.winner, best.scheme);
    assert_eq!(res.tts_us, best.total_us);
    assert_eq!(res.iter_us, best.iter_us);
    assert_eq!(res.coverage_ppm, best.coverage_ppm);
    assert_eq!(res.fallback, best.fallback);
}

#[test]
fn parallel_sweep_is_bit_for_bit_serial_including_faults() {
    let grid = tiny_grid(vec![None, Some("mixed".to_string())]);
    let cells = grid.cells();
    assert_eq!(cells.len(), 8, "1 × 1 × {{1,8}} × {{raw,fp16}} × kway × {{none,mixed}}");
    assert!(cells.iter().any(|c| c.faults.as_deref() == Some("mixed")));
    let serial = run_grid(&grid, 1);
    assert!(serial.iter().all(|o| o.result.is_ok()));
    for threads in [2, 4] {
        let parallel = run_grid(&grid, threads);
        assert_eq!(
            serial, parallel,
            "{threads}-thread sweep must equal serial bit-for-bit"
        );
    }
}

#[test]
fn parallel_sweep_with_replan_is_bit_for_bit_serial() {
    // The closed loop must not cost determinism: with re-planning on,
    // the mixed-fault grid still answers byte-identically on any
    // thread count (acceptance criterion of docs/replan.md).
    let mut grid = tiny_grid(vec![Some("mixed".to_string())]);
    grid.replan = true;
    let serial = run_grid(&grid, 1);
    assert!(serial.iter().all(|o| o.result.is_ok()));
    let parallel = run_grid(&grid, 4);
    assert_eq!(parallel, serial, "4-thread replan sweep must equal serial");
}

#[test]
fn jsonl_and_csv_round_trip_real_results() {
    let mut grid = tiny_grid(vec![None, Some("straggler".to_string())]);
    grid.ranks_per_node = vec![1];
    grid.codecs = vec!["raw".to_string()];
    let outcomes = run_grid(&grid, 2);
    let text = to_jsonl(&outcomes);
    assert_eq!(text.lines().count(), outcomes.len(), "one JSONL line per cell");
    let back = parse_jsonl(&text).expect("real sweep output parses");
    assert_eq!(back, outcomes, "parse(write(x)) == x on real sweep output");
    let csv = summary_csv(&outcomes);
    assert_eq!(csv.lines().count(), outcomes.len() + 1, "header + one row per cell");
}

#[test]
fn planner_answers_a_scripted_sequence_deterministically() {
    let script = [
        r#"{"workload": "small"}"#,
        r#"{"workload": "small", "faults": "mixed"}"#,
        r#"{"workload": "small"}"#,
        r#"{"workload": "warpnet"}"#,
        r#"{"workload": "small", "faults": "mixed"}"#,
    ];
    let run_script = || {
        let mut p = Planner::new();
        let out: Vec<String> = script
            .iter()
            .map(|q| p.handle(q).expect("every line answers"))
            .collect();
        (out, p.hits(), p.misses())
    };
    let (a, hits_a, misses_a) = run_script();
    let (b, hits_b, misses_b) = run_script();
    assert_eq!(a, b, "two fresh planners answer the script byte-identically");
    assert_eq!((hits_a, misses_a), (hits_b, misses_b));
    // Repeats (queries 3 and 5) are cache hits — the second answer is
    // demonstrably served from the memo table, not re-simulated — and
    // even the unknown-workload cell is cached as an error outcome.
    assert_eq!((hits_a, misses_a), (2, 3));
    assert!(a[0].contains("\"cache\": \"miss\""));
    assert!(a[2].contains("\"cache\": \"hit\""));
    assert!(a[4].contains("\"cache\": \"hit\""));
    let strip = |s: &str| s.split("\"answer\": ").nth(1).expect("answer payload").to_string();
    assert_eq!(strip(&a[0]), strip(&a[2]), "hit repeats the miss's answer");
    assert_eq!(strip(&a[1]), strip(&a[4]));
    assert!(strip(&a[3]).contains("\"status\": \"error\""));
}

#[test]
fn planner_serve_loop_survives_bad_lines_and_keeps_answering() {
    // A malformed request line — JSON garbage or raw bytes that are not
    // even UTF-8 — must answer with a typed JSON error and leave the
    // loop serving: the query that follows still gets its real answer.
    let mut input: Vec<u8> = Vec::new();
    input.extend_from_slice(b"\xc3\x28 broken utf-8\n");
    input.extend_from_slice(b"{\"preset\": 7}\n");
    input.extend_from_slice(b"{\"workload\": \"small\"}\n");
    input.extend_from_slice(b"quit\n");
    let mut p = Planner::new();
    let mut out = Vec::new();
    p.serve(&input[..], &mut out).expect("serve survives bad lines");
    let text = String::from_utf8(out).expect("responses are utf8");
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 3, "two error replies, then the good answer");
    assert!(lines[0].contains("\"status\": \"error\""));
    assert!(lines[0].contains("\"code\": \"bad-line\""));
    assert!(lines[1].contains("\"status\": \"error\""));
    assert!(lines[1].contains("\"code\": \"bad-query\""));
    assert!(lines[2].contains("\"cache\": \"miss\""));
    assert!(lines[2].contains("\"answer\": "));
    assert_eq!((p.hits(), p.misses()), (0, 1), "bad lines never touch the cache");
}

#[test]
fn config_sweep_table_drives_the_grid() {
    let cfg = ExperimentConfig::default();
    let grid = SweepGrid::from_config(&cfg).expect("default [sweep] table builds");
    assert_eq!(grid, SweepGrid::full(), "default table is the acceptance grid");
    assert_eq!(grid.cells().len(), 96);

    let mut cfg = ExperimentConfig::default();
    cfg.sweep_workloads = "small".to_string();
    cfg.sweep_presets = "paper-2link".to_string();
    cfg.sweep_ranks_per_node = "1,8".to_string();
    cfg.sweep_codecs = "raw".to_string();
    cfg.sweep_contention = "pairwise,kway".to_string();
    cfg.sweep_faults = "none,flap".to_string();
    let grid = SweepGrid::from_config(&cfg).expect("custom table builds");
    assert_eq!(grid.cells().len(), 8);
    let outcomes = run_grid(&grid, 2);
    assert!(
        outcomes.iter().all(|o| o.result.is_ok()),
        "a validated config grid runs without cell errors"
    );
}
