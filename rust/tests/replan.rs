//! Integration pins for measured-drift adaptive re-planning
//! (`deft::sched::replan` threaded through the lifecycle).
//!
//! The contract under test: on the seeded `mixed` fault preset over the
//! paper 2-link testbed with a hier8 topology and an fp16 fabric codec,
//! (a) the classic drift gate rejects the plan and degrades to the raw
//! replay, (b) switching re-planning on instead re-solves against the
//! measured capacities, keeps the codec, lints clean, and reports a
//! strictly better time-to-solution than the raw fallback, and (c) the
//! whole closed loop is deterministic — same seed, byte-identical
//! report.

use deft::faults::{FaultEvent, FaultSpec};
use deft::links::{ClusterEnv, Codec, LinkId, Topology};
use deft::models::{gpt2, vgg19};
use deft::sched::{run_lifecycle, FallbackReason, LifecycleOptions, ReplanOptions};

/// The scenario every pin below runs: paper 2-link testbed, hier8
/// topology (link 0 intra, link 1 fabric), fp16 on the non-reference
/// fabric link, and the seeded `mixed` preset — whose 2.5× flap on the
/// reference link trips the 25% drift band during the trial.
fn drifting_env() -> ClusterEnv {
    ClusterEnv::paper_testbed()
        .with_topology(Topology::hierarchical(8, LinkId(0), LinkId(1)))
        .with_codec(LinkId(1), Codec::Fp16)
}

fn opts(replan: bool) -> LifecycleOptions {
    let env = drifting_env();
    LifecycleOptions {
        faults: Some(FaultSpec::preset("mixed", env.workers).expect("mixed preset")),
        replan: ReplanOptions {
            enabled: replan,
            ..ReplanOptions::default()
        },
        ..LifecycleOptions::default()
    }
}

fn gate_decisions(log: &[FaultEvent]) -> Vec<bool> {
    log.iter()
        .filter_map(|e| match e {
            FaultEvent::GateDecision { accepted, .. } => Some(*accepted),
            _ => None,
        })
        .collect()
}

#[test]
fn replanning_beats_the_raw_fallback_on_the_mixed_preset() {
    let env = drifting_env();
    let w = vgg19();

    // Baseline: re-planning off. The mixed preset's flap drives the
    // compounded drift error far past ε, the re-gate rejects, and the
    // lifecycle degrades to the raw (codec-stripped) replay.
    let base = run_lifecycle(&w, &env, &opts(false)).expect("baseline lifecycle");
    assert!(
        matches!(base.fallback, FallbackReason::DriftGateRejected { .. }),
        "mixed preset must trip the drift gate: {:?}",
        base.fallback
    );
    assert!(base.codec_fallback, "rejection must strip the fp16 codec");
    assert_eq!(gate_decisions(&base.trial.fault_log), vec![false]);

    // Closed loop: same seed, same scenario, re-planning on. The
    // lifecycle re-solves against the measured capacities, keeps fp16,
    // and the re-plan passes both gates.
    let rep = run_lifecycle(&w, &env, &opts(true)).expect("replan lifecycle");
    assert!(
        matches!(rep.fallback, FallbackReason::Replanned { .. }),
        "re-planning must adopt the measured-capacity solve: {:?}",
        rep.fallback
    );
    assert!(!rep.codec_fallback, "the re-plan keeps the fp16 fabric");
    assert_eq!(
        gate_decisions(&rep.trial.fault_log),
        vec![true],
        "exactly one accepting gate decision on the re-planned trial"
    );
    assert!(
        rep.lint.is_clean(),
        "re-planned schedule must lint clean:\n{}",
        rep.lint.render_text()
    );
    // The re-plan's accepting walk ratio rides in the fallback reason
    // and must sit inside ε (the rejected combined error does not).
    if let FallbackReason::Replanned {
        ratio, error_ppm, ..
    } = rep.fallback
    {
        assert!((ratio - 1.0).abs() <= deft::preserver::EPSILON);
        assert!(error_ppm > deft::faults::to_ppm(deft::preserver::EPSILON));
    }

    // The point of the whole loop: adapting to the measured topology
    // beats abandoning the codec. Same trial length, strictly less
    // time-to-solution.
    assert_eq!(
        rep.trial.iter_ends.len(),
        base.trial.iter_ends.len(),
        "both trials run the same iteration count"
    );
    assert!(
        rep.trial.total < base.trial.total,
        "re-planned TTS {} must beat the raw fallback's {}",
        rep.trial.total,
        base.trial.total
    );
}

#[test]
fn replanned_lifecycle_is_deterministic() {
    let env = drifting_env();
    let w = gpt2();
    let a = run_lifecycle(&w, &env, &opts(true)).expect("first run");
    let b = run_lifecycle(&w, &env, &opts(true)).expect("second run");
    // Byte-identical reports, field by field: seeded faults in, integer
    // µs through the solver and both gates, no wall clock anywhere.
    assert_eq!(a.profile, b.profile);
    assert_eq!(a.schedule, b.schedule);
    assert_eq!(a.attempts, b.attempts);
    assert_eq!(a.trial, b.trial, "trial SimResults must replay bit-for-bit");
    assert_eq!(a.codec_fallback, b.codec_fallback);
    assert_eq!(a.fallback, b.fallback);
    assert_eq!(a.lint.render_text(), b.lint.render_text());
}
