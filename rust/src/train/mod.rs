//! Real data-parallel training driver (the end-to-end path).
//!
//! `examples/train_e2e.rs` uses this to train the small transformer with
//! *real* gradients through the PJRT runtime while the communication
//! timing is charged by the link model — one run produces both a loss
//! curve and scheduling metrics. DeFT's delayed-update semantics (the
//! current/future queue algebra of §III.B) are applied to the actual
//! gradient buffers: delayed buckets accumulate locally and parameter
//! updates fire exactly when the schedule says they do.

mod data;
mod trainer;

pub use data::{CorpusGen, DataOptions};
pub use trainer::{TrainOptions, TrainReport, Trainer};
