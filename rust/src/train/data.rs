//! Synthetic corpus generator for the end-to-end trainer.
//!
//! We need a corpus with *learnable structure* (so the loss curve is
//! meaningful) but no external data: a first-order Markov chain over a
//! byte-sized vocabulary whose transition table is deterministic in the
//! seed. `vocab` contexts × `branching` preferred successors is learnable
//! within a few hundred steps, so the loss drops well below the uniform
//! baseline `ln(vocab)` toward the chain's conditional entropy.

use crate::util::Rng;

/// Corpus generation options.
#[derive(Clone, Debug)]
pub struct DataOptions {
    pub vocab: usize,
    pub seq_len: usize,
    /// Number of high-probability successors per context.
    pub branching: usize,
    /// Probability mass on the preferred successors.
    pub peak_mass: f64,
    pub seed: u64,
}

impl Default for DataOptions {
    fn default() -> Self {
        DataOptions {
            vocab: 512,
            seq_len: 128,
            branching: 4,
            peak_mass: 0.9,
            seed: 23,
        }
    }
}

/// A deterministic Markov corpus: each token prefers `branching`
/// successors chosen by a hash of the token (first-order chain).
pub struct CorpusGen {
    opts: DataOptions,
    rng: Rng,
}

impl CorpusGen {
    pub fn new(opts: DataOptions) -> CorpusGen {
        assert!(opts.vocab >= 4 && opts.branching >= 1);
        assert!(opts.branching < opts.vocab);
        assert!((0.0..=1.0).contains(&opts.peak_mass));
        let rng = Rng::new(opts.seed);
        CorpusGen { opts, rng }
    }

    /// Preferred successor set of a context (deterministic).
    fn successors(&self, cur: usize) -> Vec<usize> {
        let mut h = (cur as u64 + 1)
            .wrapping_mul(0xBF58_476D_1CE4_E5B9)
            ^ self.opts.seed;
        (0..self.opts.branching)
            .map(|_| {
                h ^= h >> 27;
                h = h.wrapping_mul(0x94D0_49BB_1331_11EB);
                (h >> 33) as usize % self.opts.vocab
            })
            .collect()
    }

    /// Sample one token sequence of `seq_len + 1` tokens (inputs are the
    /// first `seq_len`, next-token targets the last `seq_len`).
    pub fn sample_sequence(&mut self) -> Vec<i32> {
        let n = self.opts.seq_len + 1;
        let mut out = Vec::with_capacity(n);
        let mut cur = self.rng.below(self.opts.vocab as u64) as usize;
        out.push(cur as i32);
        while out.len() < n {
            let next = if self.rng.chance(self.opts.peak_mass) {
                let succ = self.successors(cur);
                succ[self.rng.pick_index(&succ)]
            } else {
                self.rng.below(self.opts.vocab as u64) as usize
            };
            out.push(next as i32);
            cur = next;
        }
        out
    }

    /// Sample a `[batch, seq_len+1]` token block (row-major flat vec).
    pub fn sample_batch(&mut self, batch: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * (self.opts.seq_len + 1));
        for _ in 0..batch {
            out.extend(self.sample_sequence());
        }
        out
    }

    /// Entropy ceiling: uniform-distribution cross-entropy ln(vocab).
    pub fn uniform_loss(&self) -> f64 {
        (self.opts.vocab as f64).ln()
    }

    /// Rough entropy floor of the chain (mixture of peaked + uniform).
    pub fn entropy_floor(&self) -> f64 {
        let p = self.opts.peak_mass;
        let b = self.opts.branching as f64;
        let v = self.opts.vocab as f64;
        // H ≈ p·ln(b/p is not exact; use mixture entropy bound)
        let peaked = if b > 0.0 { p * (b / p).ln() } else { 0.0 };
        let tail = (1.0 - p) * (v / (1.0 - p).max(1e-9)).ln();
        peaked + tail
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_have_right_shape_and_range() {
        let mut g = CorpusGen::new(DataOptions::default());
        let s = g.sample_sequence();
        assert_eq!(s.len(), 129);
        assert!(s.iter().all(|&t| (0..512).contains(&t)));
        let b = g.sample_batch(4);
        assert_eq!(b.len(), 4 * 129);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = CorpusGen::new(DataOptions::default());
        let mut b = CorpusGen::new(DataOptions::default());
        assert_eq!(a.sample_batch(2), b.sample_batch(2));
    }

    #[test]
    fn structure_is_learnable() {
        // Empirical conditional entropy given context must be far below
        // the uniform ceiling — otherwise the model has nothing to learn.
        let opts = DataOptions {
            vocab: 64,
            seq_len: 64,
            ..DataOptions::default()
        };
        let mut g = CorpusGen::new(opts.clone());
        let mut counts: std::collections::HashMap<(i32, i32), usize> =
            std::collections::HashMap::new();
        let mut ctx_counts: std::collections::HashMap<i32, usize> =
            std::collections::HashMap::new();
        for _ in 0..200 {
            let s = g.sample_sequence();
            for w in s.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
                *ctx_counts.entry(w[0]).or_default() += 1;
            }
        }
        let mut h = 0.0;
        let total: usize = counts.values().sum();
        for ((a, _b), &n) in &counts {
            let ctx = ctx_counts[a];
            let p_cond = n as f64 / ctx as f64;
            h -= (n as f64 / total as f64) * p_cond.ln();
        }
        let ceiling = (64f64).ln();
        assert!(
            h < 0.75 * ceiling,
            "conditional entropy {h:.3} too close to uniform {ceiling:.3}"
        );
    }

    #[test]
    fn entropy_floor_below_ceiling() {
        let g = CorpusGen::new(DataOptions::default());
        assert!(g.entropy_floor() < g.uniform_loss());
    }
}
