//! The data-parallel trainer: real gradients, schedule-driven updates.

use std::path::Path;
use std::time::Instant;

use crate::util::error::{Context, Error, Result};
use crate::bail;

use super::data::{CorpusGen, DataOptions};
use crate::config::Scheme;
use crate::links::ClusterEnv;
use crate::models::BucketProfile;
use crate::runtime::{ArtifactManifest, Engine, Executable};
use crate::runtime::engine::HostTensor;
use crate::sched::Schedule;
use crate::sim::{simulate, SimOptions};
use crate::util::Micros;

/// Training options.
#[derive(Clone, Debug)]
pub struct TrainOptions {
    /// Path to `artifacts/manifest.toml`.
    pub manifest: String,
    pub scheme: Scheme,
    /// Simulated data-parallel workers (each computes real gradients on
    /// its own shard).
    pub workers: usize,
    pub iterations: usize,
    pub lr: f32,
    pub momentum: f32,
    pub seed: u64,
    /// Record loss every `log_every` iterations.
    pub log_every: usize,
    /// Cluster environment for the co-simulated wire time.
    pub env: ClusterEnv,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            manifest: "artifacts/manifest.toml".into(),
            scheme: Scheme::Deft,
            workers: 4,
            iterations: 100,
            lr: 0.2,
            momentum: 0.9,
            seed: 23,
            log_every: 5,
            env: ClusterEnv::paper_testbed().with_workers(4),
        }
    }
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub scheme: String,
    /// (iteration, mean loss across workers) samples.
    pub losses: Vec<(usize, f64)>,
    /// Number of parameter updates applied.
    pub updates: usize,
    /// Mean measured wall time of one train_step execution.
    pub measured_step: Micros,
    /// Co-simulated steady-state iteration time under the schedule.
    pub sim_iter_time: Micros,
    pub final_loss: f64,
    pub uniform_loss: f64,
}

/// The trainer.
pub struct Trainer {
    opts: TrainOptions,
    train_step: Executable,
    apply_update: Executable,
    /// Per-bucket parameter vectors (shared across workers — synchronous
    /// DP keeps replicas identical; updates are delayed identically).
    params: Vec<Vec<f32>>,
    momenta: Vec<Vec<f32>>,
    bucket_sizes: Vec<usize>,
    batch: usize,
    seq: usize,
    vocab: usize,
    data: Vec<CorpusGen>,
}

impl Trainer {
    /// Load artifacts and initial parameters.
    pub fn new(opts: TrainOptions) -> Result<Trainer> {
        let manifest = ArtifactManifest::load(Path::new(&opts.manifest))?;
        let engine = Engine::cpu()?;
        let train_spec = manifest.exe("train_step")?;
        let update_spec = manifest.exe("apply_update")?;
        let train_step = engine.load(train_spec)?;
        let apply_update = engine.load(update_spec)?;

        let n_buckets = manifest.meta_usize("n_buckets")?;
        let vocab = manifest.meta_usize("vocab")?;
        let seq = manifest.meta_usize("seq")?;
        let batch = manifest.meta_usize("batch")?;

        // Bucket sizes from the train_step signature: b0..b{K-1}, tokens.
        if train_spec.inputs.len() != n_buckets + 1 {
            bail!(
                "train_step wants {} inputs, expected {} buckets + tokens",
                train_spec.inputs.len(),
                n_buckets
            );
        }
        let bucket_sizes: Vec<usize> = train_spec.inputs[..n_buckets]
            .iter()
            .map(|t| t.elements())
            .collect();

        // Initial parameters from the binary init files.
        let init_files = manifest
            .meta
            .get("init_files")
            .context("manifest missing meta.init_files")?
            .clone();
        let mut params = Vec::with_capacity(n_buckets);
        for (i, f) in init_files.split(';').filter(|s| !s.is_empty()).enumerate() {
            let path = manifest.dir.join(f);
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading init file {}", path.display()))?;
            if bytes.len() != bucket_sizes[i] * 4 {
                bail!(
                    "init file {} has {} bytes, bucket {i} wants {}",
                    path.display(),
                    bytes.len(),
                    bucket_sizes[i] * 4
                );
            }
            let v: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            params.push(v);
        }
        if params.len() != n_buckets {
            bail!("manifest lists {} init files, want {n_buckets}", params.len());
        }
        let momenta = bucket_sizes.iter().map(|&s| vec![0.0f32; s]).collect();

        // One independent data stream per worker (disjoint shards via
        // distinct seeds).
        let data = (0..opts.workers)
            .map(|w| {
                CorpusGen::new(DataOptions {
                    vocab,
                    seq_len: seq,
                    seed: opts.seed.wrapping_add(1 + w as u64),
                    ..DataOptions::default()
                })
            })
            .collect();

        Ok(Trainer {
            opts,
            train_step,
            apply_update,
            params,
            momenta,
            bucket_sizes,
            batch,
            seq,
            vocab,
            data,
        })
    }

    pub fn n_buckets(&self) -> usize {
        self.bucket_sizes.len()
    }

    /// One worker's real train step: loss + per-bucket gradients.
    fn worker_step(&mut self, worker: usize) -> Result<(f64, Vec<Vec<f32>>)> {
        let tokens = self.data[worker].sample_batch(self.batch);
        debug_assert_eq!(tokens.len(), self.batch * (self.seq + 1));
        let mut inputs: Vec<HostTensor> = self
            .params
            .iter()
            .map(|p| HostTensor::F32(p.clone()))
            .collect();
        inputs.push(HostTensor::I32(tokens));
        let outputs = self.train_step.run(&inputs)?;
        let loss = outputs[0].as_f32()?[0] as f64;
        let grads = outputs[1..]
            .iter()
            .map(|t| t.as_f32().map(|s| s.to_vec()))
            .collect::<Result<Vec<_>>>()?;
        Ok((loss, grads))
    }

    /// Mean-allreduce gradients across workers (the real reduction the
    /// link model charges wire time for).
    fn allreduce(grads: &mut [Vec<Vec<f32>>]) -> Vec<Vec<f32>> {
        let w = grads.len() as f32;
        let n_buckets = grads[0].len();
        let mut out = Vec::with_capacity(n_buckets);
        for b in 0..n_buckets {
            let mut acc = std::mem::take(&mut grads[0][b]);
            for g in grads.iter().skip(1) {
                for (a, x) in acc.iter_mut().zip(&g[b]) {
                    *a += *x;
                }
            }
            for a in acc.iter_mut() {
                *a /= w;
            }
            out.push(acc);
        }
        out
    }

    /// Apply a (possibly merged) update: `scale` divides the accumulated
    /// gradient (1/k for a k-iteration merge).
    fn update(&mut self, acc: &[Vec<f32>], scale: f32) -> Result<()> {
        let mut inputs: Vec<HostTensor> = Vec::with_capacity(self.n_buckets() * 3 + 2);
        for p in &self.params {
            inputs.push(HostTensor::F32(p.clone()));
        }
        for g in acc {
            inputs.push(HostTensor::F32(g.clone()));
        }
        for m in &self.momenta {
            inputs.push(HostTensor::F32(m.clone()));
        }
        inputs.push(HostTensor::F32(vec![self.opts.lr]));
        inputs.push(HostTensor::F32(vec![scale]));
        let out = self.apply_update.run(&inputs)?;
        let k = self.n_buckets();
        for (i, t) in out[..k].iter().enumerate() {
            self.params[i] = t.as_f32()?.to_vec();
        }
        for (i, t) in out[k..2 * k].iter().enumerate() {
            self.momenta[i] = t.as_f32()?.to_vec();
        }
        Ok(())
    }

    /// Run training under `schedule` (whose cycle defines update timing
    /// and merge factors) and co-simulate the wall clock with `profiles`.
    pub fn run(
        &mut self,
        schedule: &Schedule,
        profiles: &[BucketProfile],
    ) -> Result<TrainReport> {
        schedule.validate().map_err(Error::msg)?;
        let cycle = schedule.cycle.len();
        let mut losses = Vec::new();
        let mut updates = 0usize;
        let mut acc: Vec<Vec<f32>> = self
            .bucket_sizes
            .iter()
            .map(|&s| vec![0.0f32; s])
            .collect();
        let mut acc_iters = 0usize;
        let mut step_times = Vec::new();

        for it in 0..self.opts.iterations {
            // Real compute: every worker steps on its own shard.
            let t0 = Instant::now();
            let mut worker_grads = Vec::with_capacity(self.opts.workers);
            let mut mean_loss = 0.0;
            for w in 0..self.opts.workers {
                let (loss, grads) = self.worker_step(w)?;
                mean_loss += loss;
                worker_grads.push(grads);
            }
            mean_loss /= self.opts.workers as f64;
            step_times.push(t0.elapsed().as_secs_f64());

            // The "communication": mean across workers, then accumulate
            // into the pending-update buffer (DeFT's local accumulation).
            let reduced = Self::allreduce(&mut worker_grads);
            for (a, g) in acc.iter_mut().zip(&reduced) {
                for (x, y) in a.iter_mut().zip(g) {
                    *x += *y;
                }
            }
            acc_iters += 1;

            // Update when the schedule says so.
            if schedule.cycle[it % cycle].update_at_end {
                let scale = 1.0 / acc_iters as f32;
                let acc_snapshot = acc.clone();
                self.update(&acc_snapshot, scale)?;
                for a in acc.iter_mut() {
                    a.iter_mut().for_each(|x| *x = 0.0);
                }
                acc_iters = 0;
                updates += 1;
            }

            if it % self.opts.log_every == 0 || it + 1 == self.opts.iterations {
                losses.push((it, mean_loss));
            }
        }

        // Co-simulate the wall clock for the schedule over the measured
        // profiles.
        let sim = simulate(
            profiles,
            schedule,
            &self.opts.env,
            &SimOptions {
                iterations: (cycle * 6).max(24),
                warmup: cycle.max(4),
                record_timeline: false,
            },
        );

        let measured_step = Micros::from_us_f64(
            crate::util::stats::median(&step_times) * 1e6 / self.opts.workers.max(1) as f64,
        );
        let final_loss = losses.last().map(|&(_, l)| l).unwrap_or(f64::INFINITY);
        Ok(TrainReport {
            scheme: schedule.scheme.clone(),
            losses,
            updates,
            measured_step,
            sim_iter_time: sim.steady_iter_time,
            final_loss,
            uniform_loss: (self.vocab as f64).ln(),
        })
    }

    /// Measure real per-step compute and derive bucket profiles for the
    /// co-simulation: the measured step time is split across buckets
    /// proportionally to parameter counts (fwd:bwd = 1:2), and the wire
    /// rate is chosen so the workload's coverage rate equals `cr_target`
    /// — emulating the paper's bandwidth-constrained testbed, where a
    /// model this small would otherwise have CR ≈ 0 on loopback.
    pub fn profile_buckets_with_cr(
        &mut self,
        probe_steps: usize,
        cr_target: f64,
    ) -> Result<Vec<BucketProfile>> {
        let mut times = Vec::with_capacity(probe_steps.max(1));
        for _ in 0..probe_steps.max(1) {
            let t0 = Instant::now();
            let _ = self.worker_step(0)?;
            times.push(t0.elapsed().as_secs_f64());
        }
        let step = crate::util::stats::median(&times); // fwd+bwd seconds
        let total_params: usize = self.bucket_sizes.iter().sum();
        // µs per parameter such that total comm = cr_target × compute.
        let rate = cr_target * step * 1e6 / total_params as f64;
        Ok(self
            .bucket_sizes
            .iter()
            .enumerate()
            .map(|(id, &sz)| {
                let frac = sz as f64 / total_params as f64;
                let fwd = Micros::from_us_f64(step * 1e6 / 3.0 * frac);
                let bwd = Micros::from_us_f64(step * 1e6 * 2.0 / 3.0 * frac);
                let comm = Micros::from_us_f64(sz as f64 * rate);
                BucketProfile {
                    id,
                    params: sz as u64,
                    fwd,
                    bwd,
                    comm,
                }
            })
            .collect())
    }

    /// Default profiling at the paper-like CR of 1.5.
    pub fn profile_buckets(&mut self, probe_steps: usize) -> Result<Vec<BucketProfile>> {
        self.profile_buckets_with_cr(probe_steps, 1.5)
    }
}
