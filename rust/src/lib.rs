//! # deft — Mitigating Data Dependencies for Flexible Communication Scheduling
//!
//! Full-system reproduction of *DeFT: Mitigating Data Dependencies for
//! Flexible Communication Scheduling in Distributed Training* (CS.DC 2025).
//!
//! The crate is organised as three layers:
//!
//! * **L3 — Rust coordinator** (this crate): the paper's contribution —
//!   bucket partitioning, the two-stage 0/1 multi-knapsack communication
//!   scheduler with delayed updates and heterogeneous links, the accuracy
//!   Preserver, the trace Profiler, plus every substrate it depends on
//!   (a discrete-event cluster simulator, allreduce link-cost models,
//!   a config system, a launcher and a metrics/timeline exporter).
//!
//!   Heterogeneous communication is modelled by an **N-link topology
//!   registry** ([`links::ClusterEnv`] owning [`links::LinkSpec`]s,
//!   addressed by [`links::LinkId`]): schedulers solve one knapsack per
//!   link, the simulator runs one serial stream per link, and the TOML
//!   config selects a [`links::LinkPreset`] (`paper-2link`, `single-nic`,
//!   `nvlink-ib-tcp`) or declares a custom `[[links]]` array. The
//!   `paper-2link` preset reproduces the paper's NCCL+gloo pair exactly
//!   (`tests/link_parity.rs`). A rank-level [`links::Topology`] further
//!   maps rank pairs onto node-local vs cross-node segments whose α–β
//!   terms compose hierarchically (`[topology]` in TOML); the flat and
//!   1-rank-per-node cases reproduce the registry pricing bit-for-bit
//!   (`tests/topology_parity.rs`).
//!
//!   **Codecs**: every link can carry a gradient compression
//!   [`links::Codec`] (`fp16`, PowerSGD-style `rank<k>`; TOML
//!   `codec = "fp16"` in `[[links]]` / `[topology]`, explorer
//!   `--codec link=name`). A codec scales the link's bytes on the wire
//!   (and therefore its codec-effective μ, which knapsack capacities and
//!   the §III.D partition constraint divide by), charges an encode
//!   overhead on the simulator's compute stream, and injects a gradient
//!   error into the Preserver's walk — `quantify`/`acceptable` gate
//!   whether a schedule may route over a lossy link, and the lifecycle
//!   falls back to raw links on rejection. `Codec::Raw` is the identity:
//!   pre-codec pricing is reproduced bit-for-bit
//!   (`tests/codec_parity.rs`).
//! * **L2 — JAX model** (`python/compile/model.py`, build-time only): a
//!   bucketed transformer whose `train_step`/`apply_update` are AOT-lowered
//!   to HLO text and executed from Rust via PJRT.
//! * **L1 — Pallas kernels** (`python/compile/kernels/`): the compute
//!   hot-spots (causal attention, gradient bucket reduction, fused
//!   momentum-SGD update), lowered in interpret mode into the same HLO.
//!
//! The public API is intentionally small: build a [`models::Workload`],
//! pick a [`sched::Scheduler`], run it through [`sim::simulate`], or
//! drive real training with [`train::Trainer`]. Plans can be proven
//! sound before any of that via the static verifier in [`analysis`]
//! (typed `DEFT-E…` diagnostics; see `docs/diagnostics.md`).

// ---- Crate-wide lint policy ----
// The crate is pure safe Rust (the PJRT FFI lives behind the vendored
// `xla` crate, not here); keep it that way.
#![forbid(unsafe_code)]
// Debugging leftovers never land on main.
#![warn(clippy::dbg_macro, clippy::todo, clippy::unimplemented)]
// Non-test code must surface failure context: `expect` with a message
// (or a typed `util::error::Result`) instead of bare `unwrap`. Tests
// keep `unwrap` for brevity — the `not(test)` gate exempts `#[cfg(test)]`
// builds, and integration tests/benches/examples are separate crates.
#![cfg_attr(not(test), warn(clippy::unwrap_used))]

pub mod util;
pub mod solver;
pub mod partition;
pub mod models;
pub mod links;
pub mod sim;
pub mod sched;
pub mod faults;
pub mod preserver;
pub mod analysis;
pub mod profiler;
pub mod config;
pub mod metrics;
pub mod runtime;
pub mod train;
pub mod bench;
pub mod sweep;
