//! JSON-lines serialization of sweep results, plus the summary CSV.
//!
//! One line per cell, written as each cell completes (the sweep streams;
//! a crashed run keeps every finished cell). The schema is documented in
//! `docs/sweeps.md` and round-trips exactly through [`parse_jsonl`]:
//! every numeric field is an integer well inside f64's exact range, so
//! parse(write(x)) == x bit-for-bit — pinned by `tests/sweep_grid.rs`.

use super::runner::{CellOutcome, CellResult, SchemeResult};
use super::SweepCell;
use crate::util::error::Result;
use crate::util::json::{esc, parse_json, Json};

fn cell_json(cell: &SweepCell) -> String {
    let faults = match &cell.faults {
        Some(f) => format!("\"{}\"", esc(f)),
        None => "null".to_string(),
    };
    format!(
        "{{\"workload\": \"{}\", \"preset\": \"{}\", \"ranks_per_node\": {}, \
         \"codec\": \"{}\", \"contention\": \"{}\", \"faults\": {}, \"workers\": {}}}",
        esc(&cell.workload),
        esc(&cell.preset),
        cell.ranks_per_node,
        esc(&cell.codec),
        esc(&cell.contention),
        faults,
        cell.workers
    )
}

fn scheme_json(s: &SchemeResult) -> String {
    format!(
        "{{\"scheme\": \"{}\", \"status\": \"{}\", \"iter_us\": {}, \"total_us\": {}, \
         \"events\": {}, \"coverage_ppm\": {}, \"fallback\": \"{}\"}}",
        esc(&s.scheme),
        esc(&s.status),
        s.iter_us,
        s.total_us,
        s.events,
        s.coverage_ppm,
        esc(&s.fallback)
    )
}

/// Serialize one cell outcome as a single JSON line (no trailing
/// newline).
pub fn outcome_to_json(outcome: &CellOutcome) -> String {
    match &outcome.result {
        Err(e) => format!(
            "{{\"cell\": {}, \"status\": \"error\", \"error\": \"{}\"}}",
            cell_json(&outcome.cell),
            esc(e)
        ),
        Ok(res) => {
            let schemes: Vec<String> = res.schemes.iter().map(scheme_json).collect();
            format!(
                "{{\"cell\": {}, \"status\": \"ok\", \"winner\": \"{}\", \"tts_us\": {}, \
                 \"iter_us\": {}, \"coverage_ppm\": {}, \"fallback\": \"{}\", \
                 \"schemes\": [{}]}}",
                cell_json(&outcome.cell),
                esc(&res.winner),
                res.tts_us,
                res.iter_us,
                res.coverage_ppm,
                esc(&res.fallback),
                schemes.join(", ")
            )
        }
    }
}

/// Serialize a full result set, one line per cell.
pub fn to_jsonl(outcomes: &[CellOutcome]) -> String {
    let mut out = String::new();
    for o in outcomes {
        out.push_str(&outcome_to_json(o));
        out.push('\n');
    }
    out
}

fn req_str(doc: &Json, key: &str, what: &str) -> Result<String> {
    doc.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| crate::err!("{what}: missing string `{key}`"))
}

fn req_u64(doc: &Json, key: &str, what: &str) -> Result<u64> {
    doc.get(key)
        .and_then(Json::as_f64)
        .map(|n| n as u64)
        .ok_or_else(|| crate::err!("{what}: missing numeric `{key}`"))
}

/// Parse a `"cell"` object (shared with the server's query parser,
/// which fills defaults before delegating here).
pub fn cell_from_json(doc: &Json) -> Result<SweepCell> {
    let faults = match doc.get("faults") {
        None | Some(Json::Null) => None,
        Some(Json::Str(s)) if s == "none" => None,
        Some(Json::Str(s)) => Some(s.clone()),
        Some(other) => crate::bail!("cell: `faults` must be a string or null, got {other:?}"),
    };
    Ok(SweepCell {
        workload: req_str(doc, "workload", "cell")?,
        preset: req_str(doc, "preset", "cell")?,
        ranks_per_node: req_u64(doc, "ranks_per_node", "cell")? as usize,
        codec: req_str(doc, "codec", "cell")?,
        contention: req_str(doc, "contention", "cell")?,
        faults,
        workers: req_u64(doc, "workers", "cell")? as usize,
    })
}

fn scheme_from_json(doc: &Json) -> Result<SchemeResult> {
    Ok(SchemeResult {
        scheme: req_str(doc, "scheme", "scheme")?,
        status: req_str(doc, "status", "scheme")?,
        iter_us: req_u64(doc, "iter_us", "scheme")?,
        total_us: req_u64(doc, "total_us", "scheme")?,
        events: req_u64(doc, "events", "scheme")?,
        coverage_ppm: req_u64(doc, "coverage_ppm", "scheme")?,
        fallback: req_str(doc, "fallback", "scheme")?,
    })
}

/// Parse one JSONL line back into a [`CellOutcome`].
pub fn outcome_from_json(line: &str) -> Result<CellOutcome> {
    let doc = parse_json(line)?;
    let cell = cell_from_json(
        doc.get("cell")
            .ok_or_else(|| crate::err!("outcome: missing `cell`"))?,
    )?;
    let status = req_str(&doc, "status", "outcome")?;
    if status == "error" {
        return Ok(CellOutcome {
            cell,
            result: Err(req_str(&doc, "error", "outcome")?),
        });
    }
    let Some(Json::Arr(items)) = doc.get("schemes") else {
        crate::bail!("outcome: missing `schemes` array");
    };
    let mut schemes = Vec::with_capacity(items.len());
    for item in items {
        schemes.push(scheme_from_json(item)?);
    }
    Ok(CellOutcome {
        cell: cell.clone(),
        result: Ok(CellResult {
            cell,
            schemes,
            winner: req_str(&doc, "winner", "outcome")?,
            tts_us: req_u64(&doc, "tts_us", "outcome")?,
            iter_us: req_u64(&doc, "iter_us", "outcome")?,
            coverage_ppm: req_u64(&doc, "coverage_ppm", "outcome")?,
            fallback: req_str(&doc, "fallback", "outcome")?,
        }),
    })
}

/// Parse a JSONL document (blank lines ignored).
pub fn parse_jsonl(text: &str) -> Result<Vec<CellOutcome>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        out.push(
            outcome_from_json(line)
                .map_err(|e| crate::err!("sweep results line {}: {e}", i + 1))?,
        );
    }
    Ok(out)
}

/// Per-cell winner summary in the repo's CSV idiom (one row per cell;
/// error cells carry the message in `status`).
pub fn summary_csv(outcomes: &[CellOutcome]) -> String {
    let mut out = String::from(
        "workload,preset,ranks_per_node,codec,contention,faults,workers,\
         status,winner,tts_us,iter_us,coverage_ppm,fallback\n",
    );
    for o in outcomes {
        let c = &o.cell;
        let prefix = format!(
            "{},{},{},{},{},{},{}",
            c.workload,
            c.preset,
            c.ranks_per_node,
            c.codec,
            c.contention,
            c.faults.as_deref().unwrap_or("none"),
            c.workers
        );
        match &o.result {
            Ok(r) => out.push_str(&format!(
                "{prefix},ok,{},{},{},{},{}\n",
                r.winner, r.tts_us, r.iter_us, r.coverage_ppm, r.fallback
            )),
            Err(e) => out.push_str(&format!(
                "{prefix},error: {},,,,,\n",
                e.replace(',', ";").replace('\n', " ")
            )),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell() -> SweepCell {
        SweepCell {
            workload: "gpt2".into(),
            preset: "paper-2link".into(),
            ranks_per_node: 8,
            codec: "fp16".into(),
            contention: "kway".into(),
            faults: Some("mixed".into()),
            workers: 16,
        }
    }

    fn outcome() -> CellOutcome {
        let schemes = vec![
            SchemeResult {
                scheme: "pytorch-ddp".into(),
                status: "ok".into(),
                iter_us: 120,
                total_us: 4800,
                events: 960,
                coverage_ppm: 1_000_000,
                fallback: "none".into(),
            },
            SchemeResult {
                scheme: "deft".into(),
                status: "ok".into(),
                iter_us: 90,
                total_us: 3600,
                events: 1200,
                coverage_ppm: 500_000,
                fallback: "drift-gate".into(),
            },
        ];
        CellOutcome {
            cell: cell(),
            result: Ok(CellResult {
                cell: cell(),
                schemes,
                winner: "deft".into(),
                tts_us: 3600,
                iter_us: 90,
                coverage_ppm: 500_000,
                fallback: "drift-gate".into(),
            }),
        }
    }

    #[test]
    fn jsonl_round_trips_exactly() {
        let outcomes = vec![
            outcome(),
            CellOutcome {
                cell: SweepCell { faults: None, ..cell() },
                result: Err("unknown preset `warp`".into()),
            },
        ];
        let text = to_jsonl(&outcomes);
        assert_eq!(text.lines().count(), 2, "one line per cell");
        let back = parse_jsonl(&text).expect("round-trip parses");
        assert_eq!(back, outcomes, "parse(write(x)) == x");
    }

    #[test]
    fn summary_csv_has_one_row_per_cell() {
        let outcomes = vec![
            outcome(),
            CellOutcome {
                cell: cell(),
                result: Err("boom, with a comma".into()),
            },
        ];
        let csv = summary_csv(&outcomes);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3, "header + 2 rows");
        assert!(lines[0].starts_with("workload,preset,"));
        assert!(lines[1].contains(",ok,deft,3600,90,500000,drift-gate"));
        assert!(lines[2].contains("error: boom; with a comma"));
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        assert!(parse_jsonl("{\"cell\": {}}\n").is_err());
        assert!(parse_jsonl("not json\n").is_err());
        assert!(parse_jsonl("").expect("empty ok").is_empty());
    }
}
