//! The capacity-planning service: a long-running query loop over the
//! sweep engine.
//!
//! Protocol (line-delimited JSON over any `BufRead`/`Write` pair —
//! `schedule_explorer --serve` wires it to stdin/stdout):
//!
//! ```text
//! → {"workload": "gpt2", "preset": "nvlink-ib-tcp", "ranks_per_node": 8}
//! ← {"cache": "miss", "cache_hits": 0, "cache_misses": 1, "answer": {…cell outcome…}}
//! → {"workload": "gpt2", "preset": "nvlink-ib-tcp", "ranks_per_node": 8}
//! ← {"cache": "hit", "cache_hits": 1, "cache_misses": 1, "answer": {…identical…}}
//! → quit
//! ```
//!
//! Every query field except `workload` is optional (`preset`
//! "paper-2link", `ranks_per_node` 1, `codec` "raw", `contention`
//! "kway", `faults` null, `workers` 16). Answers are full
//! [`CellOutcome`] lines (the JSONL schema), wrapped with the cache
//! verdict: a repeated query is served from the memoized cell table —
//! profiling, partition solutions, and the per-cell [`ClusterEnv`]
//! staircases are all paid once — and the hit/miss counters make that
//! observable to clients and to the acceptance test. Responses carry no
//! wall-clock fields, so a scripted query sequence is answered
//! byte-identically by any fresh [`Planner`].
//!
//! [`ClusterEnv`]: crate::links::ClusterEnv

use std::collections::HashMap;
use std::io::{BufRead, Write};

use super::jsonl::outcome_to_json;
use super::runner::{run_cell, CellOutcome};
use super::SweepCell;
use crate::util::error::Result;
use crate::util::json::{esc, parse_json, Json};

/// The query server's state: a memoized cell table plus hit/miss
/// counters.
#[derive(Default)]
pub struct Planner {
    cache: HashMap<String, CellOutcome>,
    hits: u64,
    misses: u64,
}

fn query_cell(doc: &Json) -> Result<SweepCell> {
    if !matches!(doc, Json::Obj(_)) {
        crate::bail!("query must be a JSON object");
    }
    let opt_str = |key: &str, default: &str| -> Result<String> {
        match doc.get(key) {
            None => Ok(default.to_string()),
            Some(Json::Str(s)) => Ok(s.clone()),
            Some(other) => crate::bail!("query: `{key}` must be a string, got {other:?}"),
        }
    };
    let opt_usize = |key: &str, default: usize| -> Result<usize> {
        match doc.get(key) {
            None => Ok(default),
            Some(Json::Num(n)) if *n >= 0.0 && n.fract() == 0.0 => Ok(*n as usize),
            Some(other) => {
                crate::bail!("query: `{key}` must be a non-negative integer, got {other:?}")
            }
        }
    };
    let workload = match doc.get("workload") {
        Some(Json::Str(s)) => s.clone(),
        _ => crate::bail!("query: missing string `workload`"),
    };
    let faults = match doc.get("faults") {
        None | Some(Json::Null) => None,
        Some(Json::Str(s)) if s == "none" => None,
        Some(Json::Str(s)) => Some(s.clone()),
        Some(other) => crate::bail!("query: `faults` must be a string or null, got {other:?}"),
    };
    Ok(SweepCell {
        workload,
        preset: opt_str("preset", "paper-2link")?,
        ranks_per_node: opt_usize("ranks_per_node", 1)?,
        codec: opt_str("codec", "raw")?,
        contention: opt_str("contention", "kway")?,
        faults,
        workers: opt_usize("workers", 16)?,
    })
}

impl Planner {
    pub fn new() -> Planner {
        Planner::default()
    }

    /// Cache-hit counter (queries answered without re-running a cell).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Cache-miss counter (cells solved from scratch).
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Seed the cache with already-computed outcomes (e.g. a finished
    /// batch sweep), so the server starts warm.
    pub fn preload(&mut self, outcomes: &[CellOutcome]) {
        for o in outcomes {
            self.cache.insert(o.cell.key(), o.clone());
        }
    }

    /// Answer one cell question, memoized. The JSON response wraps the
    /// cell's JSONL outcome with the cache verdict and counters.
    pub fn answer(&mut self, cell: &SweepCell) -> String {
        let key = cell.key();
        let verdict = if self.cache.contains_key(&key) {
            self.hits += 1;
            "hit"
        } else {
            let out = run_cell(cell);
            self.cache.insert(key.clone(), out);
            self.misses += 1;
            "miss"
        };
        let outcome = &self.cache[&key];
        format!(
            "{{\"cache\": \"{verdict}\", \"cache_hits\": {}, \"cache_misses\": {}, \
             \"answer\": {}}}",
            self.hits,
            self.misses,
            outcome_to_json(outcome)
        )
    }

    /// Handle one protocol line. `None` = quit; `Some(response)` is one
    /// JSON line to write back (parse and validation errors included —
    /// the server never dies on a bad query).
    pub fn handle(&mut self, line: &str) -> Option<String> {
        let line = line.trim();
        if line == "quit" || line == "exit" {
            return None;
        }
        let cell = parse_json(line).and_then(|doc| query_cell(&doc));
        Some(match cell {
            Ok(cell) => self.answer(&cell),
            Err(e) => format!(
                "{{\"status\": \"error\", \"code\": \"bad-query\", \"error\": \"{}\"}}",
                esc(&e.to_string())
            ),
        })
    }

    /// The blocking serve loop: one response line per request line,
    /// flushed immediately; ends on `quit`/`exit` or EOF. Blank lines
    /// are ignored. A malformed request line — including one that is not
    /// valid UTF-8, which `BufRead::lines` would surface as a fatal
    /// `io::Error` — answers with a typed JSON error line and the loop
    /// keeps serving: only EOF, `quit`/`exit`, or a real transport error
    /// ends it.
    pub fn serve<R: BufRead, W: Write>(
        &mut self,
        mut reader: R,
        mut writer: W,
    ) -> std::io::Result<()> {
        let mut buf = Vec::new();
        loop {
            buf.clear();
            if reader.read_until(b'\n', &mut buf)? == 0 {
                break; // EOF
            }
            let resp = match std::str::from_utf8(&buf) {
                Ok(line) => {
                    if line.trim().is_empty() {
                        continue;
                    }
                    match self.handle(line) {
                        None => break,
                        Some(resp) => resp,
                    }
                }
                Err(_) => "{\"status\": \"error\", \"code\": \"bad-line\", \
                           \"error\": \"request line is not valid UTF-8\"}"
                    .to_string(),
            };
            writeln!(writer, "{resp}")?;
            writer.flush()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const QUERY: &str = r#"{"workload": "small"}"#;

    #[test]
    fn repeated_queries_hit_the_cache_and_answer_identically() {
        let mut p = Planner::new();
        let first = p.handle(QUERY).expect("response");
        assert!(first.contains("\"cache\": \"miss\""));
        assert!(first.contains("\"cache_misses\": 1"));
        let second = p.handle(QUERY).expect("response");
        assert!(second.contains("\"cache\": \"hit\""));
        assert!(second.contains("\"cache_hits\": 1"));
        // Identical answers modulo the cache verdict.
        let strip = |s: &str| s.split("\"answer\": ").nth(1).map(str::to_string);
        assert_eq!(strip(&first), strip(&second));
        assert!(strip(&first).is_some());
        assert_eq!((p.hits(), p.misses()), (1, 1));
    }

    #[test]
    fn bad_queries_answer_with_errors_not_death() {
        let mut p = Planner::new();
        let resp = p.handle("not json").expect("response");
        assert!(resp.contains("\"status\": \"error\""));
        let resp = p.handle("{\"preset\": \"paper-2link\"}").expect("response");
        assert!(resp.contains("missing string `workload`"));
        // An unknown workload is a valid query answered with a cell
        // error, not a protocol error.
        let resp = p
            .handle("{\"workload\": \"warpnet\"}")
            .expect("response");
        assert!(resp.contains("\"status\": \"error\"") || resp.contains("unknown workload"));
        assert!(p.handle("quit").is_none());
    }

    #[test]
    fn serve_loop_speaks_the_line_protocol() {
        let mut p = Planner::new();
        let input = format!("\n{QUERY}\n{QUERY}\nquit\n{QUERY}\n");
        let mut out = Vec::new();
        p.serve(input.as_bytes(), &mut out).expect("io");
        let text = String::from_utf8(out).expect("utf8");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "quit stops the loop; blank lines skipped");
        assert!(lines[0].contains("\"cache\": \"miss\""));
        assert!(lines[1].contains("\"cache\": \"hit\""));
    }

    #[test]
    fn serve_loop_survives_malformed_lines() {
        let mut p = Planner::new();
        let mut input: Vec<u8> = Vec::new();
        input.extend_from_slice(b"\xff\xfe not utf-8\n"); // invalid UTF-8
        input.extend_from_slice(b"not json\n");
        input.extend_from_slice(QUERY.as_bytes());
        input.push(b'\n');
        input.extend_from_slice(b"quit\n");
        let mut out = Vec::new();
        p.serve(&input[..], &mut out).expect("io");
        let text = String::from_utf8(out).expect("responses stay utf8");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "both bad lines answer, then the good one");
        assert!(lines[0].contains("\"code\": \"bad-line\""));
        assert!(lines[1].contains("\"code\": \"bad-query\""));
        assert!(lines[2].contains("\"cache\": \"miss\""));
        assert_eq!((p.hits(), p.misses()), (0, 1));
    }

    #[test]
    fn preload_makes_the_first_query_a_hit() {
        let cell = query_cell(&parse_json(QUERY).expect("json")).expect("cell");
        let outcome = run_cell(&cell);
        let mut p = Planner::new();
        p.preload(std::slice::from_ref(&outcome));
        let resp = p.handle(QUERY).expect("response");
        assert!(resp.contains("\"cache\": \"hit\""));
        assert_eq!((p.hits(), p.misses()), (1, 0));
    }
}
