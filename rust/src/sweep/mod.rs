//! Batch sweep engine + capacity-planning service.
//!
//! The explorer answers one (model, topology, codec, contention)
//! question per process run; this module answers *all* of them. A
//! [`SweepGrid`] names the axes — model zoo × link preset ×
//! `ranks_per_node` × codec × contention model × fault preset — and
//! [`runner::run_grid`] fans the resulting [`SweepCell`]s across a
//! thread pool of DES runs. Every cell runs the full scheme suite: the
//! DeFT leg goes through the real
//! [`run_lifecycle`](crate::sched::run_lifecycle) (Profiler → Solver →
//! Preserver gate → trial, drift re-gate included), the baselines
//! through partition → schedule → faulted simulation. The per-cell
//! winner (best scheme, time-to-solution, effective coverage rate) is
//! aggregated into a [`runner::CellResult`].
//!
//! Determinism contract: [`runner::run_cell`] is a **pure function** of
//! its cell — no shared mutable state, no ambient randomness — so the
//! thread pool claims cells by index and collects results *in index
//! order*, making parallel output bit-for-bit identical to serial
//! (pinned by `tests/sweep_grid.rs`, faults included).
//!
//! Results stream as JSON lines ([`jsonl`]) plus a summary CSV, and
//! [`server::Planner`] exposes the long-running query mode: line-
//! delimited JSON questions over stdin/stdout, answered from a memoized
//! cell cache so a repeated query never re-pays profiling, partitioning,
//! or simulation (a reported hit/miss counter proves it). See
//! `docs/sweeps.md`.

pub mod jsonl;
pub mod runner;
pub mod server;

pub use jsonl::{parse_jsonl, summary_csv, to_jsonl};
pub use runner::{
    run_cell, run_cell_with, run_cells, run_cells_with, run_grid, CellOutcome, CellResult,
    SchemeResult,
};
pub use server::Planner;

use crate::config::ExperimentConfig;
use crate::faults::FaultSpec;
use crate::links::{ClusterEnv, Codec, ContentionModel, LinkId, LinkPreset, Topology};

/// One point of the sweep grid: everything needed to build the cluster
/// environment and fault scenario of a planning question. All fields
/// are plain values so cells hash, compare, and round-trip exactly.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SweepCell {
    /// Model-zoo workload name (see [`crate::bench::workload_by_name`]).
    pub workload: String,
    /// Link-topology preset name (see [`LinkPreset::parse`]).
    pub preset: String,
    /// Ranks per node: 1 = flat; > 1 = hierarchical on the preset's
    /// first two links (intra = link 0, inter = link 1).
    pub ranks_per_node: usize,
    /// Codec attached to every non-reference link (`raw` = leave the
    /// preset untouched).
    pub codec: String,
    /// Contention-model name (see [`ContentionModel::parse`]).
    pub contention: String,
    /// Fault preset injected into every run of the cell
    /// ([`FaultSpec::preset`]); `None` = healthy cluster.
    pub faults: Option<String>,
    pub workers: usize,
}

impl SweepCell {
    /// Stable identity string: the JSONL/cache key and log label.
    pub fn key(&self) -> String {
        format!(
            "{}|{}|rpn{}|{}|{}|{}|w{}",
            self.workload,
            self.preset,
            self.ranks_per_node,
            self.codec,
            self.contention,
            self.faults.as_deref().unwrap_or("none"),
            self.workers
        )
    }

    /// Build the cluster environment this cell describes. Every axis
    /// value is validated here so a malformed query or config surfaces
    /// as a typed cell error, never a panic inside a worker thread.
    pub fn env(&self) -> Result<ClusterEnv, String> {
        let preset = LinkPreset::parse(&self.preset)
            .ok_or_else(|| format!("unknown preset `{}`", self.preset))?;
        let contention = ContentionModel::parse(&self.contention)
            .ok_or_else(|| format!("unknown contention model `{}`", self.contention))?;
        let codec = Codec::parse(&self.codec)
            .ok_or_else(|| format!("unknown codec `{}`", self.codec))?;
        if self.workers < 2 {
            return Err(format!("workers {} must be ≥ 2", self.workers));
        }
        let mut env = preset
            .env()
            .with_workers(self.workers)
            .with_contention_model(contention);
        if self.ranks_per_node > 1 {
            if self.workers % self.ranks_per_node != 0 {
                return Err(format!(
                    "ranks_per_node {} must divide workers {}",
                    self.ranks_per_node, self.workers
                ));
            }
            if env.n_links() < 2 {
                return Err(format!(
                    "preset `{}` has {} link(s); hierarchical cells need ≥ 2",
                    self.preset,
                    env.n_links()
                ));
            }
            env = env.with_topology(Topology::hierarchical(
                self.ranks_per_node,
                LinkId(0),
                LinkId(1),
            ));
        }
        if codec != Codec::Raw {
            // The reference link stays raw (it anchors μ = 1 pricing);
            // every other link carries the cell's codec.
            for id in 1..env.n_links() {
                env = env.with_codec(LinkId(id), codec);
            }
        }
        Ok(env)
    }

    /// Resolve the cell's fault preset (validated against the cell's
    /// worker count). `Ok(None)` = healthy cell.
    pub fn fault_spec(&self) -> Result<Option<FaultSpec>, String> {
        match self.faults.as_deref() {
            None | Some("none") => Ok(None),
            Some(name) => FaultSpec::preset(name, self.workers)
                .map(Some)
                .ok_or_else(|| format!("unknown fault preset `{name}`")),
        }
    }
}

/// The sweep's grid axes. [`SweepGrid::cells`] is the cartesian product
/// in a fixed nesting order (workloads outermost, faults innermost), so
/// cell order — and therefore every downstream artifact — is
/// deterministic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SweepGrid {
    pub workloads: Vec<String>,
    pub presets: Vec<String>,
    pub ranks_per_node: Vec<usize>,
    pub codecs: Vec<String>,
    pub contention: Vec<String>,
    /// Fault presets; `None` entries sweep the healthy cluster.
    pub faults: Vec<Option<String>>,
    pub workers: usize,
    /// Run every DeFT leg with measured-drift re-planning enabled
    /// (`schedule_explorer --replan` / `[replan] enabled`). Not a cell
    /// axis: it changes how cells run, not which cells exist, so keys
    /// and JSONL schema stay unchanged.
    pub replan: bool,
}

/// Split a comma-separated axis string into trimmed, non-empty items.
pub fn split_csv(s: &str) -> Vec<String> {
    s.split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect()
}

impl SweepGrid {
    /// The acceptance-criteria grid: full model zoo × all three link
    /// presets × {flat, hier8} × {raw, fp16} × {pairwise, kway},
    /// healthy — 96 cells.
    pub fn full() -> SweepGrid {
        SweepGrid {
            workloads: ["resnet101", "vgg19", "gpt2", "llama2"]
                .map(String::from)
                .to_vec(),
            presets: ["paper-2link", "single-nic", "nvlink-ib-tcp"]
                .map(String::from)
                .to_vec(),
            ranks_per_node: vec![1, 8],
            codecs: ["raw", "fp16"].map(String::from).to_vec(),
            contention: ["pairwise", "kway"].map(String::from).to_vec(),
            faults: vec![None],
            workers: 16,
            replan: false,
        }
    }

    /// The CI smoke grid: 2 workloads × 2 presets × {flat, hier8} ×
    /// {raw, fp16}, k-way only, healthy — 16 cells.
    pub fn small() -> SweepGrid {
        SweepGrid {
            workloads: ["vgg19", "gpt2"].map(String::from).to_vec(),
            presets: ["paper-2link", "nvlink-ib-tcp"].map(String::from).to_vec(),
            ranks_per_node: vec![1, 8],
            codecs: ["raw", "fp16"].map(String::from).to_vec(),
            contention: vec!["kway".to_string()],
            faults: vec![None],
            workers: 16,
            replan: false,
        }
    }

    /// Build the grid a config's `[sweep]` table describes. The table's
    /// axes are comma-separated strings (the TOML subset has no arrays);
    /// they are re-validated here so a hand-built config fails the same
    /// way a parsed one does.
    pub fn from_config(cfg: &ExperimentConfig) -> Result<SweepGrid, String> {
        let mut ranks_per_node = Vec::new();
        for r in split_csv(&cfg.sweep_ranks_per_node) {
            ranks_per_node.push(
                r.parse::<usize>()
                    .map_err(|_| format!("sweep.ranks_per_node: `{r}` is not an integer"))?,
            );
        }
        let faults = split_csv(&cfg.sweep_faults)
            .into_iter()
            .map(|f| if f == "none" { None } else { Some(f) })
            .collect();
        let grid = SweepGrid {
            workloads: split_csv(&cfg.sweep_workloads),
            presets: split_csv(&cfg.sweep_presets),
            ranks_per_node,
            codecs: split_csv(&cfg.sweep_codecs),
            contention: split_csv(&cfg.sweep_contention),
            faults,
            workers: cfg.workers,
            replan: cfg.replan_enabled,
        };
        for axis in [
            grid.workloads.len(),
            grid.presets.len(),
            grid.ranks_per_node.len(),
            grid.codecs.len(),
            grid.contention.len(),
            grid.faults.len(),
        ] {
            if axis == 0 {
                return Err("sweep: every grid axis needs at least one value".into());
            }
        }
        for cell in grid.cells() {
            cell.env().map_err(|e| format!("sweep cell {}: {e}", cell.key()))?;
            cell.fault_spec()
                .map_err(|e| format!("sweep cell {}: {e}", cell.key()))?;
        }
        Ok(grid)
    }

    /// The cartesian product, in deterministic nesting order.
    pub fn cells(&self) -> Vec<SweepCell> {
        let mut out = Vec::new();
        for w in &self.workloads {
            for p in &self.presets {
                for &rpn in &self.ranks_per_node {
                    for c in &self.codecs {
                        for m in &self.contention {
                            for f in &self.faults {
                                out.push(SweepCell {
                                    workload: w.clone(),
                                    preset: p.clone(),
                                    ranks_per_node: rpn,
                                    codec: c.clone(),
                                    contention: m.clone(),
                                    faults: f.clone(),
                                    workers: self.workers,
                                });
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_grid_is_the_acceptance_grid() {
        let cells = SweepGrid::full().cells();
        assert_eq!(cells.len(), 96, "4 workloads × 3 presets × 2 × 2 × 2");
        // Every cell validates.
        for cell in &cells {
            cell.env().expect("full-grid cell must build");
            assert_eq!(cell.fault_spec().expect("healthy"), None);
        }
        // Keys are unique (the cache and JSONL rely on it).
        let mut keys: Vec<String> = cells.iter().map(SweepCell::key).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), 96);
    }

    #[test]
    fn small_grid_is_a_subset_of_full() {
        let small = SweepGrid::small().cells();
        assert_eq!(small.len(), 16);
        let full = SweepGrid::full().cells();
        for cell in &small {
            assert!(full.contains(cell), "small cell {} not in full grid", cell.key());
        }
    }

    #[test]
    fn cell_env_rejects_bad_axes() {
        let cell = SweepCell {
            workload: "gpt2".into(),
            preset: "paper-2link".into(),
            ranks_per_node: 1,
            codec: "raw".into(),
            contention: "kway".into(),
            faults: None,
            workers: 16,
        };
        cell.env().expect("baseline cell builds");
        assert!(SweepCell { preset: "warp".into(), ..cell.clone() }.env().is_err());
        assert!(SweepCell { codec: "zfp".into(), ..cell.clone() }.env().is_err());
        assert!(SweepCell { contention: "freeway".into(), ..cell.clone() }.env().is_err());
        assert!(SweepCell { ranks_per_node: 3, ..cell.clone() }.env().is_err());
        assert!(SweepCell { workers: 1, ..cell.clone() }.env().is_err());
        assert!(
            SweepCell { faults: Some("meteor".into()), ..cell.clone() }
                .fault_spec()
                .is_err()
        );
        assert!(
            SweepCell { faults: Some("mixed".into()), ..cell }
                .fault_spec()
                .expect("known preset")
                .is_some()
        );
    }

    #[test]
    fn grid_from_config_round_trips() {
        let cfg = ExperimentConfig::default();
        let grid = SweepGrid::from_config(&cfg).expect("default config sweeps");
        assert_eq!(grid, SweepGrid::full(), "default [sweep] table is the full grid");

        let mut cfg = ExperimentConfig::default();
        cfg.sweep_workloads = "gpt2".into();
        cfg.sweep_presets = "paper-2link".into();
        cfg.sweep_ranks_per_node = "1".into();
        cfg.sweep_codecs = "raw".into();
        cfg.sweep_contention = "kway".into();
        cfg.sweep_faults = "none,mixed".into();
        let grid = SweepGrid::from_config(&cfg).expect("faulted grid");
        assert_eq!(grid.cells().len(), 2);
        assert_eq!(grid.faults, vec![None, Some("mixed".to_string())]);

        cfg.sweep_faults = "meteor".into();
        assert!(SweepGrid::from_config(&cfg).is_err());
        cfg.sweep_faults = "none".into();
        cfg.sweep_ranks_per_node = "nope".into();
        assert!(SweepGrid::from_config(&cfg).is_err());
    }
}
