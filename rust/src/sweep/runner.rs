//! The sweep executor: one pure function per cell, fanned across a
//! work-claiming thread pool.
//!
//! [`run_cell`] runs the full scheme suite for one [`SweepCell`]: the
//! DeFT leg goes through the real [`run_lifecycle`] (so sweep answers
//! are *exactly* the explorer's answers — pinned by
//! `tests/sweep_grid.rs`), the baselines through partition → schedule →
//! faulted simulation with a deterministic iteration rule. Everything a
//! cell reads is owned by the cell (the contention staircases and
//! partition memos live inside each cell's own [`ClusterEnv`]), so cells
//! never share mutable state and any execution order yields identical
//! results.
//!
//! [`run_grid`] exploits that: worker threads claim cell indices from an
//! atomic counter and park each result in its index's slot; collection
//! happens in index order, making N-thread output bit-for-bit equal to
//! serial output.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::{SweepCell, SweepGrid};
use crate::bench::{partition_for, scheduler_for, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use crate::config::Scheme;
use crate::sched::{run_lifecycle, FallbackReason, LifecycleOptions, ReplanOptions, Schedule};
use crate::sim::{simulate_faulted, SimOptions, SimResult};

/// One scheme's outcome inside a cell. Integer/string fields only so
/// cell results compare exactly (`Eq`) across serial and parallel runs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SchemeResult {
    pub scheme: String,
    /// `"ok"`, or `"skipped: <reason>"` when this scheme cannot run in
    /// the cell's environment (e.g. its partitioner rejects the model).
    pub status: String,
    /// Steady-state iteration time, µs.
    pub iter_us: u64,
    /// Time-to-solution of the cell's trial run, µs.
    pub total_us: u64,
    /// Discrete events the trial executed.
    pub events: u64,
    /// Effective coverage rate (updates per cycle / cycle length) in
    /// ppm — DeFT's N:M delayed-update coverage; 1 000 000 = every
    /// iteration updates.
    pub coverage_ppm: u64,
    /// Lifecycle fallback label: `none` | `codec-gate` | `lint` |
    /// `drift-gate` | `replanned` (always `none` for the baseline
    /// schemes).
    pub fallback: String,
}

/// Aggregated answer for one cell: the per-scheme table plus the winner
/// by steady-state iteration time (ties break in [`Scheme::ALL`] order).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CellResult {
    pub cell: SweepCell,
    pub schemes: Vec<SchemeResult>,
    pub winner: String,
    /// Winner's time-to-solution, µs.
    pub tts_us: u64,
    /// Winner's steady-state iteration time, µs.
    pub iter_us: u64,
    /// Winner's effective coverage rate, ppm.
    pub coverage_ppm: u64,
    /// Winner's fallback label.
    pub fallback: String,
}

/// A cell's terminal outcome: its result, or the error that stopped it
/// (invalid environment, or every scheme failed).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CellOutcome {
    pub cell: SweepCell,
    pub result: Result<CellResult, String>,
}

fn fallback_label(reason: &FallbackReason) -> &'static str {
    match reason {
        FallbackReason::None => "none",
        FallbackReason::CodecGateRejected { .. } => "codec-gate",
        FallbackReason::LintRejected { .. } => "lint",
        FallbackReason::DriftGateRejected { .. } => "drift-gate",
        FallbackReason::Replanned { .. } => "replanned",
    }
}

fn coverage_ppm(schedule: &Schedule) -> u64 {
    let cycle = schedule.cycle.len().max(1) as u64;
    schedule.updates_per_cycle as u64 * 1_000_000 / cycle
}

fn scheme_result(
    scheme: Scheme,
    schedule: &Schedule,
    sim: &SimResult,
    fallback: &'static str,
) -> SchemeResult {
    SchemeResult {
        scheme: scheme.name().to_string(),
        status: "ok".to_string(),
        iter_us: sim.steady_iter_time.as_us(),
        total_us: sim.total.as_us(),
        events: sim.events_processed,
        coverage_ppm: coverage_ppm(schedule),
        fallback: fallback.to_string(),
    }
}

fn skipped(scheme: Scheme, reason: String) -> SchemeResult {
    SchemeResult {
        scheme: scheme.name().to_string(),
        status: format!("skipped: {reason}"),
        iter_us: 0,
        total_us: 0,
        events: 0,
        coverage_ppm: 0,
        fallback: "none".to_string(),
    }
}

/// Run one cell: every scheme in [`Scheme::ALL`] order, then pick the
/// winner. Pure — same cell in, same bits out, on any thread.
pub fn run_cell(cell: &SweepCell) -> CellOutcome {
    run_cell_with(cell, false)
}

/// [`run_cell`] with the DeFT leg's measured-drift re-planning switched
/// on or off ([`ReplanOptions::enabled`]). Still pure: the re-plan loop
/// consumes only integer-µs alarms from the cell's seeded fault trace,
/// so serial and parallel sweeps stay bit-for-bit identical either way.
pub fn run_cell_with(cell: &SweepCell, replan: bool) -> CellOutcome {
    let outcome = |result| CellOutcome {
        cell: cell.clone(),
        result,
    };
    let env = match cell.env() {
        Ok(env) => env,
        Err(e) => return outcome(Err(e)),
    };
    let spec = match cell.fault_spec() {
        Ok(spec) => spec,
        Err(e) => return outcome(Err(e)),
    };
    let workload = match workload_by_name(&cell.workload) {
        Ok(w) => w,
        Err(e) => return outcome(Err(e.to_string())),
    };

    let mut schemes = Vec::with_capacity(Scheme::ALL.len());
    for scheme in Scheme::ALL {
        if scheme == Scheme::Deft {
            // The DeFT leg is the full lifecycle — Profiler, Solver,
            // Preserver gate, trial, drift re-gate — so a sweep answer
            // is exactly what `run_lifecycle` would report standalone.
            let opts = LifecycleOptions {
                faults: spec.clone(),
                replan: ReplanOptions {
                    enabled: replan,
                    ..ReplanOptions::default()
                },
                ..LifecycleOptions::default()
            };
            match run_lifecycle(&workload, &env, &opts) {
                Ok(rep) => schemes.push(scheme_result(
                    scheme,
                    &rep.schedule,
                    &rep.trial,
                    fallback_label(&rep.fallback),
                )),
                Err(e) => schemes.push(skipped(scheme, e.to_string())),
            }
            continue;
        }
        let buckets =
            match partition_for(&workload, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB) {
                Ok(b) => b,
                Err(e) => {
                    schemes.push(skipped(scheme, e.to_string()));
                    continue;
                }
            };
        let schedule = scheduler_for(scheme, true, &env).schedule(&buckets);
        let warmup = schedule.warmup_iters + schedule.cycle.len() + 2;
        let opts = SimOptions {
            iterations: warmup * 3 + 12,
            warmup,
            record_timeline: false,
        };
        let sim = simulate_faulted(&buckets, &schedule, &env, &opts, spec.as_ref());
        schemes.push(scheme_result(scheme, &schedule, &sim, "none"));
    }

    let winner = schemes
        .iter()
        .filter(|s| s.status == "ok")
        .fold(None::<&SchemeResult>, |best, s| match best {
            Some(b) if b.iter_us <= s.iter_us => Some(b),
            _ => Some(s),
        });
    let Some(winner) = winner else {
        let reasons: Vec<&str> = schemes.iter().map(|s| s.status.as_str()).collect();
        return outcome(Err(format!("every scheme failed: {}", reasons.join("; "))));
    };
    let result = CellResult {
        cell: cell.clone(),
        winner: winner.scheme.clone(),
        tts_us: winner.total_us,
        iter_us: winner.iter_us,
        coverage_ppm: winner.coverage_ppm,
        fallback: winner.fallback.clone(),
        schemes: schemes.clone(),
    };
    outcome(Ok(result))
}

/// Run a cell list across `threads` workers. Threads claim cells by
/// index from an atomic counter; results are collected in index order,
/// so output is bit-for-bit identical to `threads = 1`.
pub fn run_cells(cells: &[SweepCell], threads: usize) -> Vec<CellOutcome> {
    run_cells_with(cells, threads, false)
}

/// [`run_cells`] with re-planning on or off (see [`run_cell_with`]).
pub fn run_cells_with(cells: &[SweepCell], threads: usize, replan: bool) -> Vec<CellOutcome> {
    if threads <= 1 || cells.len() <= 1 {
        return cells.iter().map(|c| run_cell_with(c, replan)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<CellOutcome>>> =
        cells.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads.min(cells.len()) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let out = run_cell_with(&cells[i], replan);
                *slots[i].lock().expect("sweep slot lock poisoned") = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("sweep slot lock poisoned")
                .expect("every cell index was claimed and filled")
        })
        .collect()
}

/// Run a whole grid (see [`run_cells`]); [`SweepGrid::replan`] decides
/// whether the DeFT legs re-plan on drift.
pub fn run_grid(grid: &SweepGrid, threads: usize) -> Vec<CellOutcome> {
    run_cells_with(&grid.cells(), threads, grid.replan)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cell() -> SweepCell {
        SweepCell {
            workload: "small".into(),
            preset: "paper-2link".into(),
            ranks_per_node: 1,
            codec: "raw".into(),
            contention: "kway".into(),
            faults: None,
            workers: 16,
        }
    }

    #[test]
    fn run_cell_answers_with_a_winner() {
        let out = run_cell(&tiny_cell());
        let res = out.result.expect("healthy cell succeeds");
        assert_eq!(res.schemes.len(), Scheme::ALL.len());
        assert!(res.schemes.iter().all(|s| s.status == "ok"));
        assert!(res.schemes.iter().any(|s| s.scheme == res.winner));
        assert!(res.iter_us > 0 && res.tts_us >= res.iter_us);
        // The winner actually has the minimal iteration time.
        let min = res.schemes.iter().map(|s| s.iter_us).min().expect("schemes");
        assert_eq!(res.iter_us, min);
        // Full coverage on the healthy defaults (no N:M delay in play
        // for the winner's accepted schedule would show < 1.0 here).
        assert!(res.coverage_ppm > 0 && res.coverage_ppm <= 1_000_000);
    }

    #[test]
    fn run_cell_is_deterministic() {
        let cell = SweepCell {
            faults: Some("mixed".into()),
            ..tiny_cell()
        };
        let a = run_cell(&cell);
        let b = run_cell(&cell);
        assert_eq!(a, b, "same cell must replay bit-for-bit");
    }

    #[test]
    fn invalid_cells_error_instead_of_panicking() {
        let out = run_cell(&SweepCell {
            preset: "warp".into(),
            ..tiny_cell()
        });
        assert!(out.result.is_err());
        let out = run_cell(&SweepCell {
            workload: "warpnet".into(),
            ..tiny_cell()
        });
        assert!(out.result.is_err());
    }
}
