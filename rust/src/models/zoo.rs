//! Constructors for the paper's evaluation workloads (Table VI) plus the
//! small transformer used by the real end-to-end trainer.
//!
//! Layer structures follow the real architectures; per-layer compute times
//! are synthesized by distributing the paper's Table I totals across
//! layers **proportionally to each layer's MAC count**, so partitioning at
//! any granularity sees realistic imbalance (the paper's problem 3).
//!
//! Communication calibration: each workload carries `comm_rate_ref`, the
//! µs/parameter NCCL allreduce rate at the paper's reference environment
//! (16 GPUs, 40 Gbps), pinned so total comm matches Table I. The paper's
//! own tables are mutually inconsistent here (Table IV's microbenchmark
//! rate would give VGG-19 a 480 ms comm total, not 258 ms), so each table
//! is calibrated independently — see DESIGN.md.

use super::{Layer, TargetMetric, Workload};
use crate::util::Micros;

/// Split `total` µs across weights (largest-remainder apportionment) so
/// the per-layer values sum *exactly* to `total`.
pub(crate) fn distribute(total: Micros, weights: &[f64]) -> Vec<Micros> {
    assert!(!weights.is_empty());
    let wsum: f64 = weights.iter().sum();
    assert!(wsum > 0.0, "weights must be positive");
    let t = total.as_us();
    // Floor shares + distribute the remainder to the largest fractional
    // parts (stable by index for determinism).
    let raw: Vec<f64> = weights.iter().map(|w| t as f64 * w / wsum).collect();
    let mut shares: Vec<u64> = raw.iter().map(|r| r.floor() as u64).collect();
    let assigned: u64 = shares.iter().sum();
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by(|&a, &b| {
        let fa = raw[a] - raw[a].floor();
        let fb = raw[b] - raw[b].floor();
        fb.total_cmp(&fa).then(a.cmp(&b))
    });
    for i in 0..(t - assigned) as usize {
        shares[order[i % order.len()]] += 1;
    }
    shares.into_iter().map(Micros).collect()
}

fn mk_layers(
    names: Vec<String>,
    params: Vec<u64>,
    macs: Vec<f64>,
    total_fwd: Micros,
    total_bwd: Micros,
) -> Vec<Layer> {
    assert_eq!(names.len(), params.len());
    assert_eq!(names.len(), macs.len());
    let fwd = distribute(total_fwd, &macs);
    let bwd = distribute(total_bwd, &macs);
    names
        .into_iter()
        .zip(params)
        .zip(fwd.into_iter().zip(bwd))
        .map(|((name, params), (fwd, bwd))| Layer {
            name,
            params,
            fwd,
            bwd,
        })
        .collect()
}

/// VGG-19 (Table VI: 143,652,544 params; Table I: 37/93/258 ms).
///
/// 16 conv layers + 3 fully connected. The fc6 layer alone holds 102.8M
/// parameters — the source of the paper's bucket-imbalance problem
/// (Table II bucket #4).
pub fn vgg19() -> Workload {
    // (name, params, MACs in millions at 224×224)
    let spec: Vec<(&str, u64, f64)> = vec![
        ("conv1_1", 1_792, 86.7),
        ("conv1_2", 36_928, 1_849.7),
        ("conv2_1", 73_856, 924.8),
        ("conv2_2", 147_584, 1_849.7),
        ("conv3_1", 295_168, 924.8),
        ("conv3_2", 590_080, 1_849.7),
        ("conv3_3", 590_080, 1_849.7),
        ("conv3_4", 590_080, 1_849.7),
        ("conv4_1", 1_180_160, 924.8),
        ("conv4_2", 2_359_808, 1_849.7),
        ("conv4_3", 2_359_808, 1_849.7),
        ("conv4_4", 2_359_808, 1_849.7),
        ("conv5_1", 2_359_808, 462.4),
        ("conv5_2", 2_359_808, 462.4),
        ("conv5_3", 2_359_808, 462.4),
        ("conv5_4", 2_359_808, 462.4),
        ("fc6", 102_764_544, 102.8),
        ("fc7", 16_781_312, 16.8),
        ("fc8", 4_097_000, 4.1),
    ];
    // Trim 5,506 params from fc8 biases/etc. so the total matches the
    // paper's 143,652,544 exactly.
    let mut spec = spec;
    let raw_total: u64 = spec.iter().map(|s| s.1).sum();
    let excess = raw_total - 143_652_544;
    spec.last_mut().expect("non-empty layer spec").1 -= excess;

    let names = spec.iter().map(|s| s.0.to_string()).collect();
    let params = spec.iter().map(|s| s.1).collect();
    let macs = spec.iter().map(|s| s.2).collect();
    let layers = mk_layers(
        names,
        params,
        macs,
        Micros::from_ms(37),
        Micros::from_ms(93),
    );
    let total_params: u64 = 143_652_544;
    Workload {
        name: "vgg19".into(),
        layers,
        // Table I: 258 ms total comm over 143.65M params.
        comm_rate_ref: 258_000.0 / total_params as f64,
        batch_size: 64,
        target: TargetMetric::Accuracy(0.71),
    }
}

/// ResNet-101 (≈44.5M params; Table I: 59/118/242 ms).
///
/// conv1 + bottleneck stages [3, 4, 23, 3] + fc. Blocks have roughly
/// equal MAC counts (~220M each), which is why ResNet buckets are *time*
/// balanced but *size* imbalanced (later stages hold most parameters).
pub fn resnet101() -> Workload {
    let mut names: Vec<String> = vec!["conv1".into()];
    let mut params: Vec<u64> = vec![9_408 + 64];
    let mut macs: Vec<f64> = vec![118.0];

    // (stage, blocks, width w; block params: 1x1 in->w, 3x3 w->w, 1x1 w->4w)
    let stages: [(usize, usize, u64, u64); 4] = [
        // (stage idx, num blocks, width, input channels)
        (1, 3, 64, 64),
        (2, 4, 128, 256),
        (3, 23, 256, 512),
        (4, 3, 512, 1024),
    ];
    for (si, blocks, w, cin) in stages {
        for b in 0..blocks {
            let cin_b = if b == 0 { cin } else { 4 * w };
            let mut p = cin_b * w + 9 * w * w + w * 4 * w + (w + w + 4 * w); // convs + BN-ish
            if b == 0 {
                p += cin_b * 4 * w; // downsample projection
            }
            names.push(format!("res{}_{}", si, b + 1));
            params.push(p);
            // Roughly equal MACs per block; first block of a stage does the
            // downsample so costs a bit more.
            macs.push(if b == 0 { 260.0 } else { 215.0 });
        }
    }
    names.push("fc".into());
    params.push(2048 * 1000 + 1000);
    macs.push(2.1);

    // Nudge conv1 params so the total lands on 44.55M (BN/bias bookkeeping).
    let total: u64 = params.iter().sum();
    let target: u64 = 44_549_160;
    if total < target {
        params[0] += target - total;
    } else {
        params[0] -= total - target;
    }

    let layers = mk_layers(
        names,
        params,
        macs,
        Micros::from_ms(59),
        Micros::from_ms(118),
    );
    Workload {
        name: "resnet101".into(),
        layers,
        comm_rate_ref: 242_000.0 / target as f64,
        batch_size: 256,
        target: TargetMetric::Accuracy(0.76),
    }
}

/// GPT-2 variant (Table VI: 81,894,144 params; Table I: 169/381/546.4 ms).
///
/// 11 transformer blocks (d=768) + a THUC-News-sized input embedding:
/// 11 × 7,084,800 + 3,961,344 = 81,894,144 exactly. At partition size
/// 6.5M this yields ~13 buckets, matching the paper's mention of bucket
/// #13. Per-block compute is uniform, so bucket computation/communication
/// times are "relatively balanced" as §V.B.3 observes.
pub fn gpt2() -> Workload {
    let mut names: Vec<String> = vec!["wte".into()];
    let mut params: Vec<u64> = vec![3_961_344]; // 5158-token embedding × 768
    let mut macs: Vec<f64> = vec![2.0];
    for b in 0..11 {
        // attention: qkv (768→2304) + proj (768→768), with biases
        names.push(format!("h{b}_attn"));
        params.push(768 * 2304 + 2304 + 768 * 768 + 768);
        macs.push(45.0);
        // mlp: 768→3072→768, with biases
        names.push(format!("h{b}_mlp"));
        params.push(768 * 3072 + 3072 + 3072 * 768 + 768);
        macs.push(55.0);
    }
    let layers = mk_layers(
        names,
        params,
        macs,
        Micros::from_ms(169),
        Micros::from_ms(381),
    );
    let total: u64 = layers.iter().map(|l| l.params).sum();
    debug_assert_eq!(total, 81_894_144);
    Workload {
        name: "gpt2".into(),
        layers,
        comm_rate_ref: 546_400.0 / total as f64,
        batch_size: 16,
        target: TargetMetric::Loss(2.8),
    }
}

/// Llama-2-7B-like workload (paper §VI): coverage rate < 0.1, the regime
/// where communication scheduling cannot help. Only the CR matters for
/// the reported negative result; absolute times are per-iteration with
/// activation checkpointing and long sequences.
pub fn llama2_7b_like() -> Workload {
    let mut names = Vec::new();
    let mut params = Vec::new();
    let mut macs = Vec::new();
    names.push("embed".to_string());
    params.push(32_000u64 * 4096);
    macs.push(5.0);
    for b in 0..32 {
        names.push(format!("l{b}_attn"));
        params.push(4 * 4096 * 4096);
        macs.push(40.0);
        names.push(format!("l{b}_mlp"));
        params.push(3 * 4096 * 11008);
        macs.push(60.0);
    }
    let layers = mk_layers(
        names,
        params,
        macs,
        Micros::from_secs(25),
        Micros::from_secs(60),
    );
    Workload {
        name: "llama2_7b_like".into(),
        layers,
        // Large fused tensors reach near-peak ring bandwidth.
        comm_rate_ref: 1.0e-3,
        batch_size: 4,
        target: TargetMetric::Loss(2.2),
    }
}

/// The small GPT-style transformer trained end-to-end by
/// `examples/train_e2e.rs` (real gradients through the PJRT runtime).
///
/// Compute times are *estimates* for planning only — the real trainer
/// measures its own step times and re-profiles the workload.
pub fn small_transformer(n_layers: u32, d_model: u64, vocab: u64, seq: u64) -> Workload {
    let mut names: Vec<String> = vec!["wte".into()];
    let mut params: Vec<u64> = vec![vocab * d_model + seq * d_model];
    let mut macs: Vec<f64> = vec![(vocab * d_model) as f64 * 0.05];
    for b in 0..n_layers {
        names.push(format!("h{b}_attn"));
        params.push(4 * d_model * d_model + 4 * d_model);
        macs.push((4 * d_model * d_model * seq) as f64);
        names.push(format!("h{b}_mlp"));
        params.push(8 * d_model * d_model + 5 * d_model);
        macs.push((8 * d_model * d_model * seq) as f64);
    }
    names.push("lm_head".into());
    params.push(vocab * d_model);
    macs.push((vocab * d_model * seq) as f64);

    // Rough CPU-class estimate: 1 GFLOP ≈ 100 ms; fwd ≈ 2·MAC, bwd ≈ 4·MAC.
    let total_macs: f64 = macs.iter().sum();
    let fwd = Micros::from_us_f64((total_macs * 2.0 / 1e9 * 100_000.0).max(1_000.0));
    let bwd = Micros::from_us_f64((total_macs * 4.0 / 1e9 * 100_000.0).max(2_000.0));
    let layers = mk_layers(names, params, macs, fwd, bwd);
    Workload {
        name: format!("small_transformer_L{n_layers}_d{d_model}"),
        layers,
        // Loopback-class effective rate (the trainer charges simulated wire
        // time via links::ClusterEnv, this is just the planning default).
        comm_rate_ref: 1.0e-3,
        batch_size: 8,
        target: TargetMetric::Loss(1.5),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distribute_sums_exactly() {
        let shares = distribute(Micros(1000), &[1.0, 2.0, 3.0]);
        let total: Micros = shares.iter().sum();
        assert_eq!(total, Micros(1000));
        assert!(shares[2] > shares[1] && shares[1] > shares[0]);
    }

    #[test]
    fn distribute_handles_tiny_totals() {
        let shares = distribute(Micros(2), &[1.0, 1.0, 1.0]);
        let total: Micros = shares.iter().sum();
        assert_eq!(total, Micros(2));
    }

    #[test]
    fn gpt2_param_count_exact() {
        assert_eq!(gpt2().total_params(), 81_894_144);
    }

    #[test]
    fn vgg_param_count_exact() {
        assert_eq!(vgg19().total_params(), 143_652_544);
    }

    #[test]
    fn resnet_has_34_plus_layers() {
        let r = resnet101();
        assert_eq!(r.num_layers(), 1 + 3 + 4 + 23 + 3 + 1);
    }

    #[test]
    fn fc6_dominates_vgg_params() {
        let v = vgg19();
        let fc6 = v.layers.iter().find(|l| l.name == "fc6").unwrap();
        assert!(fc6.params * 2 > v.total_params());
    }
}
