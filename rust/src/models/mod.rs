//! Workload models — the DNNs the paper evaluates (§V, Tables I–II, VI).
//!
//! A [`Workload`] is a layer-level description (input → output order) of
//! one training job: per-layer parameter counts and forward/backward
//! compute times, plus a calibrated communication rate. The paper's own
//! published numbers are the calibration targets:
//!
//! * Table I — per-iteration fwd/bwd/comm totals and coverage rate (CR)
//!   for ResNet-101, VGG-19 and GPT-2 on 16 GPUs / 40 Gbps.
//! * Table II — per-bucket fwd/bwd/comm of VGG-19 at partition size 6.5M.
//! * §VI — a Llama-2-7B-like workload with CR < 0.1 (the negative result).
//!
//! Layer *structures* follow the real architectures (VGG-19's 16 conv +
//! 3 fc layers, ResNet-101's bottleneck stages, GPT-2's transformer
//! blocks); per-layer times are synthesized to sum exactly to the paper's
//! totals, since the authors' per-operator traces are not public. Note the
//! paper's Table I CR column lists 1.67 for ResNet-101 while the text says
//! "approximately 1.4" — 242/(59+118) = 1.37, so we follow the computed
//! value (the text), not the misprinted column.

mod profiles;
mod zoo;

pub use profiles::{
    coverage_rate, gpt2_buckets_calibrated, totals, vgg19_table2_buckets, BucketProfile,
};
pub use zoo::{gpt2, llama2_7b_like, resnet101, small_transformer, vgg19};

use crate::util::Micros;

/// One parameter tensor (layer) of a DNN, in forward order.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    /// Number of f32 parameters in this layer's gradient tensor.
    pub params: u64,
    /// Forward compute time of this layer (one iteration, profiled scale).
    pub fwd: Micros,
    /// Backward compute time of this layer.
    pub bwd: Micros,
}

/// What the benchmark tracks as "solution" for time-to-solution curves.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TargetMetric {
    /// Top-1 accuracy target (image classification).
    Accuracy(f64),
    /// Training-loss target (text generation).
    Loss(f64),
}

/// A full data-parallel training workload.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    /// Layers in forward order (`layers[0]` is the input side). Backward
    /// traverses them in reverse.
    pub layers: Vec<Layer>,
    /// Calibrated NCCL communication rate, µs per parameter, at the
    /// reference point (16 GPUs, 40 Gbps, ring allreduce). The paper's
    /// Table I totals pin this per workload; `links::ClusterEnv` rescales
    /// it for other worker counts / bandwidths.
    pub comm_rate_ref: f64,
    /// Per-GPU batch size used in the paper's runs.
    pub batch_size: u32,
    pub target: TargetMetric,
}

impl Workload {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total parameter count.
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params).sum()
    }

    /// Total forward compute per iteration.
    pub fn total_fwd(&self) -> Micros {
        self.layers.iter().map(|l| l.fwd).sum()
    }

    /// Total backward compute per iteration.
    pub fn total_bwd(&self) -> Micros {
        self.layers.iter().map(|l| l.bwd).sum()
    }

    /// Total compute per iteration (fwd + bwd) — the knapsack capacity
    /// base of paper Problem 1.
    pub fn total_compute(&self) -> Micros {
        self.total_fwd() + self.total_bwd()
    }

    /// Total NCCL communication time at the reference environment.
    pub fn total_comm_ref(&self) -> Micros {
        Micros::from_us_f64(self.total_params() as f64 * self.comm_rate_ref)
    }

    /// Coverage rate CR = T_comm / (T_fwd + T_bwd) at the reference
    /// environment (paper §I).
    pub fn coverage_rate_ref(&self) -> f64 {
        self.total_comm_ref().ratio(self.total_compute())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table I reproduction at model level: totals must match the paper
    /// within 2% (per-layer synthesis rounds to integer µs).
    #[test]
    fn table1_totals_match_paper() {
        // (workload, fwd_ms, bwd_ms, comm_ms)
        let cases: Vec<(Workload, f64, f64, f64)> = vec![
            (resnet101(), 59.0, 118.0, 242.0),
            (vgg19(), 37.0, 93.0, 258.0),
            (gpt2(), 169.0, 381.0, 546.4),
        ];
        for (w, fwd, bwd, comm) in cases {
            let got_fwd = w.total_fwd().as_ms_f64();
            let got_bwd = w.total_bwd().as_ms_f64();
            let got_comm = w.total_comm_ref().as_ms_f64();
            assert!(
                (got_fwd - fwd).abs() / fwd < 0.02,
                "{}: fwd {got_fwd} vs {fwd}",
                w.name
            );
            assert!(
                (got_bwd - bwd).abs() / bwd < 0.02,
                "{}: bwd {got_bwd} vs {bwd}",
                w.name
            );
            assert!(
                (got_comm - comm).abs() / comm < 0.02,
                "{}: comm {got_comm} vs {comm}",
                w.name
            );
        }
    }

    #[test]
    fn coverage_rates_match_paper_text() {
        // Text: ResNet-101 ≈ 1.4 (computed 1.37), VGG-19 ≈ 2.0 (1.98),
        // GPT-2 ≈ 0.99.
        assert!((resnet101().coverage_rate_ref() - 1.37).abs() < 0.05);
        assert!((vgg19().coverage_rate_ref() - 1.98).abs() < 0.06);
        assert!((gpt2().coverage_rate_ref() - 0.99).abs() < 0.04);
    }

    #[test]
    fn parameter_counts_match_paper() {
        // Table VI: VGG-19 143,652,544; GPT-2 81,894,144.
        let vgg = vgg19().total_params() as f64;
        assert!((vgg - 143_652_544.0).abs() / 143_652_544.0 < 0.01, "vgg {vgg}");
        let g = gpt2().total_params() as f64;
        assert!((g - 81_894_144.0).abs() / 81_894_144.0 < 0.01, "gpt2 {g}");
        // ResNet-101 ≈ 44.5M (well known).
        let r = resnet101().total_params() as f64;
        assert!((r - 44.5e6).abs() / 44.5e6 < 0.03, "resnet {r}");
    }

    #[test]
    fn llama_cr_below_point_one() {
        // §VI: CR < 0.1 for the Llama-2-7B-like workload.
        let w = llama2_7b_like();
        assert!(w.coverage_rate_ref() < 0.1, "CR = {}", w.coverage_rate_ref());
    }

    #[test]
    fn layers_ordered_and_positive() {
        for w in [resnet101(), vgg19(), gpt2(), llama2_7b_like()] {
            assert!(w.num_layers() >= 3, "{} too few layers", w.name);
            for l in &w.layers {
                assert!(l.params > 0, "{}: zero-param layer {}", w.name, l.name);
            }
            assert!(w.total_fwd() > Micros::ZERO);
            assert!(w.total_bwd() > w.total_fwd(), "{}: bwd should exceed fwd", w.name);
        }
    }

    #[test]
    fn small_transformer_is_configurable() {
        let w = small_transformer(4, 256, 2048, 128);
        assert!(w.total_params() > 1_000_000);
        assert!(w.coverage_rate_ref() > 0.0);
    }
}
