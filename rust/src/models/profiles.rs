//! Bucket-level profiles — the direct inputs to the schedulers.
//!
//! A [`BucketProfile`] is what the paper's Profiler module hands the
//! Solver: for each gradient bucket, its forward/backward computation
//! time and its (reference-link) communication time. Profiles come from
//! three sources in this repo:
//!
//! 1. [`vgg19_table2_buckets`] — the paper's own Table II, verbatim.
//! 2. `partition::partition(..)` — layer-level workloads partitioned by a
//!    strategy and priced by a `links::ClusterEnv`.
//! 3. `profiler::reconstruct(..)` — recovered from raw operator traces.

use crate::util::Micros;

/// Per-bucket profile: the scheduling unit of every scheme in the paper.
///
/// Buckets are numbered in **forward order**: bucket `0` is nearest the
/// input (paper bucket #1); its backward completes *last* and its
/// communication is the one hard-blocked between iterations (the paper's
/// motivating hard dependency).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BucketProfile {
    pub id: usize,
    /// Number of f32 parameters carried by the bucket.
    pub params: u64,
    /// Forward computation time of the bucket's layers.
    pub fwd: Micros,
    /// Backward computation time of the bucket's layers.
    pub bwd: Micros,
    /// Communication (allreduce) time on the reference (NCCL) link.
    pub comm: Micros,
}

/// Total fwd/bwd/comm over a profile set.
pub fn totals(buckets: &[BucketProfile]) -> (Micros, Micros, Micros) {
    let fwd = buckets.iter().map(|b| b.fwd).sum();
    let bwd = buckets.iter().map(|b| b.bwd).sum();
    let comm = buckets.iter().map(|b| b.comm).sum();
    (fwd, bwd, comm)
}

/// Coverage rate CR = comm / (fwd + bwd) of a profile set.
pub fn coverage_rate(buckets: &[BucketProfile]) -> f64 {
    let (fwd, bwd, comm) = totals(buckets);
    comm.ratio(fwd + bwd)
}

/// Paper **Table II**: the measured per-bucket times of VGG-19 at
/// partition size 6,500,000 — used verbatim by `bench_table2_buckets` and
/// the Fig. 12 scheduling-order bench. Bucket ids are paper ids minus 1.
pub fn vgg19_table2_buckets() -> Vec<BucketProfile> {
    // (fwd, bwd, comm) µs — paper Table II rows 1..=6.
    let rows: [(u64, u64, u64); 6] = [
        (1_238, 72_496, 1_968),
        (28_799, 12_786, 11_262),
        (4_801, 4_872, 15_447),
        (1_899, 2_319, 178_643),
        (326, 484, 31_754),
        (103, 162, 8_651),
    ];
    // Param counts back-solved from comm at the Table II effective rate
    // (1.794e-3 µs/param); bucket 3 is dominated by VGG's 102.8M fc6.
    let params: [u64; 6] = [
        1_097_000, 6_278_000, 8_611_000, 99_577_000, 17_700_000, 4_822_000,
    ];
    rows.iter()
        .zip(params)
        .enumerate()
        .map(|(id, (&(fwd, bwd, comm), params))| BucketProfile {
            id,
            params,
            fwd: Micros(fwd),
            bwd: Micros(bwd),
            comm: Micros(comm),
        })
        .collect()
}

/// A GPT-2 bucket profile calibrated to Table I totals with the balanced
/// per-bucket structure §V.B.3 describes (~13 buckets at partition 6.5M).
/// Used by the Fig. 13 scheduling-order bench when the layer-level
/// pipeline is not exercised.
pub fn gpt2_buckets_calibrated() -> Vec<BucketProfile> {
    let n = 13usize;
    let total_fwd = Micros::from_ms(169);
    let total_bwd = Micros::from_ms(381);
    let total_comm = Micros::from_us_f64(546_400.0);
    let total_params = 81_894_144u64;
    let weights = vec![1.0; n];
    let fwd = super::zoo::distribute(total_fwd, &weights);
    let bwd = super::zoo::distribute(total_bwd, &weights);
    let comm = super::zoo::distribute(total_comm, &weights);
    (0..n)
        .map(|id| BucketProfile {
            id,
            params: total_params / n as u64,
            fwd: fwd[id],
            bwd: bwd[id],
            comm: comm[id],
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_totals_match_paper_rows() {
        let b = vgg19_table2_buckets();
        let (fwd, bwd, comm) = totals(&b);
        assert_eq!(fwd, Micros(37_166));
        assert_eq!(bwd, Micros(93_119));
        // NOTE: the paper's Table II "total" row prints 257,725 µs, but
        // its six comm rows sum to 247,725 µs — a 10 ms misprint in one
        // of them. We reproduce the rows as published.
        assert_eq!(comm, Micros(247_725));
    }

    #[test]
    fn table2_bucket4_dominates_comm() {
        let b = vgg19_table2_buckets();
        // Paper bucket #4 (id 3) carries fc6: > 70% of total comm.
        assert!(b[3].comm.as_us() * 10 > 247_725 * 7);
    }

    #[test]
    fn table2_coverage_rate_near_two() {
        // 247,725 / 130,285 = 1.90 from the published rows (the paper's
        // total row would give 1.98 — see the misprint note above).
        let b = vgg19_table2_buckets();
        assert!((coverage_rate(&b) - 1.90).abs() < 0.02);
    }

    #[test]
    fn gpt2_profile_balanced_and_cr_one() {
        let b = gpt2_buckets_calibrated();
        assert_eq!(b.len(), 13);
        let cr = coverage_rate(&b);
        assert!((cr - 0.99).abs() < 0.02, "cr = {cr}");
        // Balance: max/min comm within 1.01 (uniform split).
        let max = b.iter().map(|x| x.comm.as_us()).max().unwrap();
        let min = b.iter().map(|x| x.comm.as_us()).min().unwrap();
        assert!(max - min <= 1);
    }
}
