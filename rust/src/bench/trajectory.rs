//! The DES perf trajectory: pinned scenarios, the `BENCH_des_hotpath.json`
//! point format, and the CI regression gate.
//!
//! Every point times one engine on one pinned scenario and reports
//! events/sec from [`crate::sim::SimResult::events_processed`]. Two
//! engines are recorded per scenario:
//!
//! * `scan` — the golden reference loop ([`crate::sim::simulate_scan`])
//!   with the span timeline on: the exact configuration every bench paid
//!   before the indexed engine landed (the "before" point);
//! * `indexed` — the event-queue engine ([`crate::sim::simulate`]) with
//!   the timeline off: the metric-only path throughput benches use now
//!   (the "after" point).
//!
//! Absolute events/sec is host-specific, so the default CI gate compares
//! the **indexed/scan speedup ratio** per scenario — a hardware-
//! independent measure of the hot path itself — against the committed
//! file within a band, failing only on regression below it. Absolute
//! throughput gating is available behind a flag for same-host
//! comparisons. See `BENCHMARKS.md` for the schema and workflow.

use std::hint::black_box;

use super::{partition_for, scheduler_for, time_it, workload_by_name, PAPER_DDP_MB, PAPER_PARTITION};
use crate::config::Scheme;
use crate::faults::FaultSpec;
use crate::links::{ClusterEnv, LinkId, LinkPreset, Topology};
use crate::sim::{simulate_faulted, simulate_scan_faulted, SimOptions};
use crate::util::error::Result;
use crate::util::json::{esc, parse_json, Json};

/// One pinned benchmark scenario. Scenarios are identified by `name` in
/// the JSON file; the gate matches committed and fresh points on it, so
/// the definition behind a name must never change silently — add a new
/// scenario instead.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub workload: &'static str,
    pub preset: LinkPreset,
    /// `Some(rpn)` = hierarchical topology with `rpn` ranks per node
    /// (intra = link 0, inter = link 1); `None` = flat.
    pub ranks_per_node: Option<usize>,
    pub workers: usize,
    pub scheme: Scheme,
    /// Simulated training iterations (floor; the pipeline may raise it
    /// to cover scheduler warm-up).
    pub iterations: usize,
    /// `Some(scenario)` = run both engines under this named fault
    /// scenario ([`FaultSpec::preset`]); `None` = healthy cluster.
    pub faults: Option<&'static str>,
    /// Time the lifecycle's measured-drift re-planned schedule instead
    /// of the plain solver output (requires `faults`; DeFT scheme only —
    /// see [`crate::sched::replan`]). The timed engines are unchanged;
    /// only the schedule they replay comes from the closed loop.
    pub replan: bool,
}

impl Scenario {
    fn new(
        workload: &'static str,
        preset: LinkPreset,
        ranks_per_node: Option<usize>,
        workers: usize,
        scheme: Scheme,
    ) -> Scenario {
        let topo = match ranks_per_node {
            Some(rpn) => format!("hier{rpn}"),
            None => "flat".to_string(),
        };
        Scenario {
            name: format!(
                "{workload}-{}-{topo}-w{workers}-{}",
                preset.name(),
                scheme.name()
            ),
            workload,
            preset,
            ranks_per_node,
            workers,
            scheme,
            iterations: 120,
            faults: None,
            replan: false,
        }
    }

    /// Pin a named fault scenario onto this scenario. The name suffix
    /// keeps faulted rows distinct in the committed file — the gate
    /// never compares a faulted run against a healthy baseline.
    fn with_faults(mut self, scenario: &'static str) -> Scenario {
        self.name.push_str("+faults-");
        self.name.push_str(scenario);
        self.faults = Some(scenario);
        self
    }

    /// Pin a named fault scenario *and* measured-drift re-planning: the
    /// timed schedule is the one the closed lifecycle loop accepted
    /// after re-solving against measured capacities. Its own name
    /// suffix keeps re-planned rows distinct from plain faulted ones.
    fn with_replan(mut self, scenario: &'static str) -> Scenario {
        self.name.push_str("+replan-");
        self.name.push_str(scenario);
        self.faults = Some(scenario);
        self.replan = true;
        self
    }

    /// Topology label used in the JSON point (`flat` / `hier<rpn>`).
    pub fn topology_label(&self) -> String {
        match self.ranks_per_node {
            Some(rpn) => format!("hier{rpn}"),
            None => "flat".to_string(),
        }
    }

    /// Build the cluster environment this scenario pins.
    pub fn env(&self) -> ClusterEnv {
        let mut env = self.preset.env().with_workers(self.workers);
        if let Some(rpn) = self.ranks_per_node {
            env = env.with_topology(Topology::hierarchical(rpn, LinkId(0), LinkId(1)));
        }
        env
    }
}

/// The four pinned cluster shapes of the full grid: the paper testbed
/// and the 3-link modern preset, each flat at 16 ranks and hierarchical
/// (8 ranks/node) at 10240 ranks.
fn grid_envs() -> [(LinkPreset, Option<usize>, usize); 4] {
    [
        (LinkPreset::Paper2Link, None, 16),
        (LinkPreset::Paper2Link, Some(8), 10_240),
        (LinkPreset::NvlinkIbTcp, None, 16),
        (LinkPreset::NvlinkIbTcp, Some(8), 10_240),
    ]
}

/// Full pinned grid: gpt2/vgg19/llama2 × the four cluster shapes × all
/// four schemes (48 scenarios, 96 points), plus one faulted row that
/// keeps the fault-injection hot path on the perf trajectory and one
/// re-planned row that keeps the closed drift loop on it.
pub fn full_scenarios() -> Vec<Scenario> {
    let mut v = Vec::new();
    for workload in ["gpt2", "vgg19", "llama2"] {
        for (preset, rpn, workers) in grid_envs() {
            for scheme in Scheme::ALL {
                v.push(Scenario::new(workload, preset, rpn, workers, scheme));
            }
        }
    }
    v.push(
        Scenario::new("gpt2", LinkPreset::Paper2Link, None, 16, Scheme::PytorchDdp)
            .with_faults("mixed"),
    );
    v.push(
        Scenario::new("gpt2", LinkPreset::Paper2Link, None, 16, Scheme::Deft)
            .with_replan("mixed"),
    );
    v
}

/// Per-PR CI smoke subset (must stay a subset of [`full_scenarios`] so
/// the committed full file always carries the rows the gate matches):
/// the DDP barrier path on the flat paper testbed, the 10k-rank
/// hierarchical headline scenario, and the faulted row (fault-injection
/// pricing must not rot off the trajectory).
pub fn smoke_scenarios() -> Vec<Scenario> {
    vec![
        Scenario::new("gpt2", LinkPreset::Paper2Link, None, 16, Scheme::PytorchDdp),
        Scenario::new(
            "gpt2",
            LinkPreset::NvlinkIbTcp,
            Some(8),
            10_240,
            Scheme::PytorchDdp,
        ),
        Scenario::new("gpt2", LinkPreset::Paper2Link, None, 16, Scheme::PytorchDdp)
            .with_faults("mixed"),
        // The closed loop's accepted schedule must stay on the perf
        // trajectory too: profile → solve → drift re-gate → re-plan,
        // then both engines replay the re-planned plan under faults.
        Scenario::new("gpt2", LinkPreset::Paper2Link, None, 16, Scheme::Deft)
            .with_replan("mixed"),
    ]
}

/// One recorded measurement: engine × scenario.
#[derive(Clone, Debug, PartialEq)]
pub struct Point {
    pub scenario: String,
    /// `"scan"` or `"indexed"`.
    pub engine: String,
    pub workload: String,
    pub preset: String,
    pub topology: String,
    pub workers: u64,
    pub scheme: String,
    pub contention: String,
    pub iterations: u64,
    pub record_timeline: bool,
    /// Median wall time of one simulation run, seconds.
    pub wall_s: f64,
    /// Discrete events executed per run ([`crate::sim::SimResult::events_processed`]).
    pub events: u64,
    pub events_per_sec: f64,
    pub peak_in_flight: u64,
    /// Documented greedy placement bound for the scenario's scheduler:
    /// buckets × links for the multi-knapsack schemes, buckets for the
    /// single-queue baselines.
    pub solver_iterations: u64,
}

/// Run one scenario: golden-equivalence check, then time both engines.
/// `reps` timed repetitions (one warm-up) per engine.
pub fn run_scenario(s: &Scenario, reps: usize) -> Result<Vec<Point>> {
    let w = workload_by_name(s.workload)?;
    let env = s.env();
    // Faulted scenarios resolve their named preset once; healthy rows
    // pass `None`, which is exactly the pre-fault simulate() path.
    let spec = s
        .faults
        .map(|n| FaultSpec::preset(n, s.workers).expect("pinned scenario names a known preset"));
    let (buckets, schedule) = if s.replan {
        // Re-planned rows time the engines on the schedule the closed
        // lifecycle loop accepted (profile → solve → drift re-gate →
        // measured-capacity re-solve), paired with its own profile.
        let opts = crate::sched::LifecycleOptions {
            faults: spec.clone(),
            replan: crate::sched::ReplanOptions {
                enabled: true,
                ..crate::sched::ReplanOptions::default()
            },
            ..crate::sched::LifecycleOptions::default()
        };
        let rep = crate::sched::run_lifecycle(&w, &env, &opts)?;
        (rep.profile, rep.schedule)
    } else {
        let buckets = partition_for(&w, s.scheme, &env, PAPER_PARTITION, PAPER_DDP_MB)?;
        let schedule = scheduler_for(s.scheme, true, &env).schedule(&buckets);
        (buckets, schedule)
    };
    let warmup = schedule.warmup_iters + schedule.cycle.len() + 2;
    let iterations = s.iterations.max(warmup * 3 + 4);
    // "Before" = the scan engine in the configuration every bench paid
    // pre-indexed-engine (timeline on); "after" = the indexed engine on
    // the metric-only path (timeline off).
    let scan_opts = SimOptions {
        iterations,
        warmup,
        record_timeline: true,
    };
    let indexed_opts = SimOptions {
        iterations,
        warmup,
        record_timeline: false,
    };

    let spec = spec.as_ref();

    // Insurance on every trajectory run: the engines must agree
    // bit-for-bit before their timings mean anything.
    let reference = simulate_scan_faulted(&buckets, &schedule, &env, &indexed_opts, spec);
    let indexed = simulate_faulted(&buckets, &schedule, &env, &indexed_opts, spec);
    assert_eq!(
        reference, indexed,
        "indexed engine diverged from the scan reference on `{}`",
        s.name
    );

    let (scan_s, _) = time_it(1, reps, || {
        black_box(simulate_scan_faulted(&buckets, &schedule, &env, &scan_opts, spec));
    });
    let (indexed_s, _) = time_it(1, reps, || {
        black_box(simulate_faulted(&buckets, &schedule, &env, &indexed_opts, spec));
    });

    let solver_iterations = match s.scheme {
        Scheme::Deft | Scheme::DeftNoMultilink => buckets.len() * env.n_links(),
        _ => buckets.len(),
    } as u64;
    let mk = |engine: &str, wall_s: f64, record_timeline: bool| Point {
        scenario: s.name.clone(),
        engine: engine.to_string(),
        workload: s.workload.to_string(),
        preset: s.preset.name().to_string(),
        topology: s.topology_label(),
        workers: s.workers as u64,
        scheme: s.scheme.name().to_string(),
        contention: reference.contention.clone(),
        iterations: iterations as u64,
        record_timeline,
        wall_s,
        events: reference.events_processed,
        events_per_sec: reference.events_processed as f64 / wall_s.max(1e-12),
        peak_in_flight: reference.peak_in_flight as u64,
        solver_iterations,
    };
    Ok(vec![
        mk("scan", scan_s, true),
        mk("indexed", indexed_s, false),
    ])
}

/// Run a scenario list, collecting both engines' points per scenario.
pub fn run(scenarios: &[Scenario], reps: usize) -> Result<Vec<Point>> {
    let mut points = Vec::with_capacity(scenarios.len() * 2);
    for s in scenarios {
        points.extend(run_scenario(s, reps)?);
    }
    Ok(points)
}

/// Scenario name of the sweep-throughput trajectory row.
pub const SWEEP_SCENARIO: &str = "sweep-zoo-full-4t";

/// Number of worker threads the sweep row's parallel leg uses.
pub const SWEEP_THREADS: usize = 4;

/// Time the full acceptance sweep ([`SweepGrid::full`], 96 cells)
/// serial vs `SWEEP_THREADS`-threaded, as one trajectory scenario:
/// `engine = "scan"` is the serial run, `engine = "indexed"` the
/// parallel one, so the existing indexed/scan ratio gate doubles as the
/// N-thread-speedup gate (acceptance floor: ≥ 2× at N = 4). Equality of
/// the two runs is asserted before any timing — a sweep whose parallel
/// results drift from serial has no trajectory to stand on.
pub fn run_sweep_points(reps: usize) -> Vec<Point> {
    use crate::sweep::{run_cells, SweepGrid};
    let cells = SweepGrid::full().cells();
    let serial = run_cells(&cells, 1);
    let parallel = run_cells(&cells, SWEEP_THREADS);
    assert_eq!(
        serial, parallel,
        "{SWEEP_THREADS}-thread sweep diverged from serial execution"
    );
    let events: u64 = serial
        .iter()
        .filter_map(|o| o.result.as_ref().ok())
        .flat_map(|r| r.schemes.iter())
        .map(|s| s.events)
        .sum();
    // The equality pass above already warmed both paths.
    let (serial_s, _) = time_it(0, reps, || {
        black_box(run_cells(&cells, 1));
    });
    let (parallel_s, _) = time_it(0, reps, || {
        black_box(run_cells(&cells, SWEEP_THREADS));
    });
    let mk = |engine: &str, wall_s: f64, threads: usize| Point {
        scenario: SWEEP_SCENARIO.to_string(),
        engine: engine.to_string(),
        workload: "zoo".to_string(),
        preset: "all".to_string(),
        topology: "flat+hier8".to_string(),
        workers: 16,
        scheme: "all".to_string(),
        contention: "pairwise+kway".to_string(),
        iterations: cells.len() as u64,
        record_timeline: false,
        wall_s,
        events,
        events_per_sec: events as f64 / wall_s.max(1e-12),
        peak_in_flight: threads as u64,
        solver_iterations: (cells.len() * crate::config::Scheme::ALL.len()) as u64,
    };
    vec![
        mk("scan", serial_s, 1),
        mk("indexed", parallel_s, SWEEP_THREADS),
    ]
}

// ---- BENCH_*.json writing (via `util::json`, no serde). ----

/// Serialize points into the committed `BENCH_des_hotpath.json` format
/// (schema documented in `BENCHMARKS.md`).
pub fn to_json(bench: &str, host: &str, points: &[Point]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"bench\": \"{}\",\n", esc(bench)));
    out.push_str("  \"schema_version\": 1,\n");
    out.push_str(&format!("  \"host\": \"{}\",\n", esc(host)));
    out.push_str("  \"points\": [\n");
    for (i, p) in points.iter().enumerate() {
        out.push_str("    {");
        out.push_str(&format!("\"scenario\": \"{}\", ", esc(&p.scenario)));
        out.push_str(&format!("\"engine\": \"{}\", ", esc(&p.engine)));
        out.push_str(&format!("\"workload\": \"{}\", ", esc(&p.workload)));
        out.push_str(&format!("\"preset\": \"{}\", ", esc(&p.preset)));
        out.push_str(&format!("\"topology\": \"{}\", ", esc(&p.topology)));
        out.push_str(&format!("\"workers\": {}, ", p.workers));
        out.push_str(&format!("\"scheme\": \"{}\", ", esc(&p.scheme)));
        out.push_str(&format!("\"contention\": \"{}\", ", esc(&p.contention)));
        out.push_str(&format!("\"iterations\": {}, ", p.iterations));
        out.push_str(&format!("\"record_timeline\": {}, ", p.record_timeline));
        out.push_str(&format!("\"wall_s\": {:.6}, ", p.wall_s));
        out.push_str(&format!("\"events\": {}, ", p.events));
        out.push_str(&format!("\"events_per_sec\": {:.1}, ", p.events_per_sec));
        out.push_str(&format!("\"peak_in_flight\": {}, ", p.peak_in_flight));
        out.push_str(&format!("\"solver_iterations\": {}", p.solver_iterations));
        out.push_str(if i + 1 < points.len() { "},\n" } else { "}\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// Parse a `BENCH_*.json` document back into points.
pub fn parse_points(text: &str) -> Result<Vec<Point>> {
    let doc = parse_json(text)?;
    let points = doc
        .get("points")
        .ok_or_else(|| crate::err!("missing `points` array"))?;
    let Json::Arr(items) = points else {
        crate::bail!("`points` is not an array");
    };
    let mut out = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        let f = |key: &str| -> Result<f64> {
            item.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| crate::err!("point {i}: missing numeric `{key}`"))
        };
        let s = |key: &str| -> Result<String> {
            item.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| crate::err!("point {i}: missing string `{key}`"))
        };
        out.push(Point {
            scenario: s("scenario")?,
            engine: s("engine")?,
            workload: s("workload")?,
            preset: s("preset")?,
            topology: s("topology")?,
            workers: f("workers")? as u64,
            scheme: s("scheme")?,
            contention: s("contention")?,
            iterations: f("iterations")? as u64,
            record_timeline: item
                .get("record_timeline")
                .and_then(Json::as_bool)
                .ok_or_else(|| crate::err!("point {i}: missing bool `record_timeline`"))?,
            wall_s: f("wall_s")?,
            events: f("events")? as u64,
            events_per_sec: f("events_per_sec")?,
            peak_in_flight: f("peak_in_flight")? as u64,
            solver_iterations: f("solver_iterations")? as u64,
        });
    }
    Ok(out)
}

// ---- The regression gate. ----

/// Indexed/scan events-per-sec ratio per scenario (both engines needed).
fn speedups(points: &[Point]) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for p in points.iter().filter(|p| p.engine == "indexed") {
        let Some(scan) = points
            .iter()
            .find(|q| q.engine == "scan" && q.scenario == p.scenario)
        else {
            continue;
        };
        if scan.events_per_sec > 0.0 {
            out.push((p.scenario.clone(), p.events_per_sec / scan.events_per_sec));
        }
    }
    out
}

/// Gate outcome: scenarios compared and human-readable failures.
#[derive(Clone, Debug, Default)]
pub struct GateOutcome {
    pub compared: usize,
    pub failures: Vec<String>,
}

impl GateOutcome {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Compare fresh points against the committed trajectory within `band`
/// (e.g. 0.25 = ±25%). Default mode gates the hardware-independent
/// indexed/scan speedup ratio and fails **only on regression** below
/// `committed × (1 − band)` — improvements always pass, so the committed
/// file ratchets forward, never blocks progress. With `absolute`, fresh
/// indexed events/sec must additionally stay above
/// `committed × (1 − band)` (same-host comparisons only).
pub fn check_against(
    committed: &[Point],
    fresh: &[Point],
    band: f64,
    absolute: bool,
) -> GateOutcome {
    let mut outcome = GateOutcome::default();
    let committed_ratio = speedups(committed);
    for (scenario, fresh_ratio) in speedups(fresh) {
        let Some((_, want)) = committed_ratio.iter().find(|(s, _)| *s == scenario) else {
            continue; // new scenario: nothing committed to regress from
        };
        outcome.compared += 1;
        let floor = want * (1.0 - band);
        if fresh_ratio < floor {
            outcome.failures.push(format!(
                "{scenario}: indexed/scan speedup {fresh_ratio:.2}x regressed below \
                 {floor:.2}x (committed {want:.2}x, band {:.0}%)",
                band * 100.0
            ));
        }
    }
    if absolute {
        for p in fresh.iter().filter(|p| p.engine == "indexed") {
            let Some(c) = committed
                .iter()
                .find(|q| q.engine == "indexed" && q.scenario == p.scenario)
            else {
                continue;
            };
            let floor = c.events_per_sec * (1.0 - band);
            if p.events_per_sec < floor {
                outcome.failures.push(format!(
                    "{}: indexed {:.0} events/s below absolute floor {:.0} \
                     (committed {:.0}, band {:.0}%)",
                    p.scenario,
                    p.events_per_sec,
                    floor,
                    c.events_per_sec,
                    band * 100.0
                ));
            }
        }
    }
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(scenario: &str, engine: &str, eps: f64) -> Point {
        Point {
            scenario: scenario.to_string(),
            engine: engine.to_string(),
            workload: "gpt2".to_string(),
            preset: "paper-2link".to_string(),
            topology: "flat".to_string(),
            workers: 16,
            scheme: "pytorch-ddp".to_string(),
            contention: "kway".to_string(),
            iterations: 120,
            record_timeline: engine == "scan",
            wall_s: 0.01,
            events: 10_000,
            events_per_sec: eps,
            peak_in_flight: 2,
            solver_iterations: 19,
        }
    }

    #[test]
    fn smoke_is_subset_of_full() {
        let full: Vec<String> = full_scenarios().into_iter().map(|s| s.name).collect();
        for s in smoke_scenarios() {
            assert!(full.contains(&s.name), "smoke scenario `{}` not in full grid", s.name);
        }
    }

    #[test]
    fn scenario_names_are_unique() {
        let mut names: Vec<String> = full_scenarios().into_iter().map(|s| s.name).collect();
        let before = names.len();
        names.sort();
        names.dedup();
        assert_eq!(before, names.len());
    }

    #[test]
    fn faulted_scenario_is_pinned_and_distinct() {
        let s = smoke_scenarios()
            .into_iter()
            .find(|s| s.faults.is_some())
            .expect("smoke grid carries a faulted row");
        assert!(s.name.ends_with("+faults-mixed"), "{}", s.name);
        assert!(
            FaultSpec::preset(s.faults.unwrap(), s.workers).is_some(),
            "pinned fault scenario must resolve"
        );
    }

    #[test]
    fn json_round_trips() {
        let pts = vec![point("a", "scan", 1.0e6), point("a", "indexed", 2.5e6)];
        let text = to_json("des_hotpath", "test-host", &pts);
        let back = parse_points(&text).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].scenario, "a");
        assert_eq!(back[1].engine, "indexed");
        assert!((back[1].events_per_sec - 2.5e6).abs() < 1.0);
        assert_eq!(back[0].events, 10_000);
        assert!(back[0].record_timeline && !back[1].record_timeline);
    }

    #[test]
    fn gate_fails_on_ratio_regression_only() {
        let committed = vec![point("a", "scan", 1.0e6), point("a", "indexed", 2.0e6)];
        // Fresh ratio 1.2x vs committed 2.0x: outside the 25% band.
        let slow = vec![point("a", "scan", 1.0e6), point("a", "indexed", 1.2e6)];
        let out = check_against(&committed, &slow, 0.25, false);
        assert_eq!(out.compared, 1);
        assert!(!out.passed(), "{:?}", out.failures);
        // Fresh ratio 3.0x (improvement) passes.
        let fast = vec![point("a", "scan", 1.0e6), point("a", "indexed", 3.0e6)];
        assert!(check_against(&committed, &fast, 0.25, false).passed());
        // Within-band wobble (1.6x vs 2.0x at 25%) passes.
        let wobble = vec![point("a", "scan", 1.0e6), point("a", "indexed", 1.6e6)];
        assert!(check_against(&committed, &wobble, 0.25, false).passed());
    }

    #[test]
    fn gate_absolute_mode_checks_indexed_throughput() {
        let committed = vec![point("a", "scan", 1.0e6), point("a", "indexed", 2.0e6)];
        // Ratio preserved (2x) but everything absolutely slower by 2.5x.
        let slow_host = vec![point("a", "scan", 0.4e6), point("a", "indexed", 0.8e6)];
        assert!(check_against(&committed, &slow_host, 0.25, false).passed());
        assert!(!check_against(&committed, &slow_host, 0.25, true).passed());
    }

    #[test]
    fn unknown_committed_scenarios_are_ignored() {
        let committed = vec![point("other", "scan", 1.0e6), point("other", "indexed", 2.0e6)];
        let fresh = vec![point("a", "scan", 1.0e6), point("a", "indexed", 1.1e6)];
        let out = check_against(&committed, &fresh, 0.25, false);
        assert_eq!(out.compared, 0);
        assert!(out.passed());
    }

    #[test]
    fn committed_trajectory_carries_the_faulted_and_sweep_rows() {
        let pts = parse_points(include_str!("../../../BENCH_des_hotpath.json"))
            .expect("committed trajectory parses");
        for engine in ["scan", "indexed"] {
            assert!(
                pts.iter()
                    .any(|p| p.engine == engine && p.scenario.ends_with("+faults-mixed")),
                "committed file must carry a `{engine}` faulted row"
            );
            assert!(
                pts.iter()
                    .any(|p| p.engine == engine && p.scenario.ends_with("+replan-mixed")),
                "committed file must carry a `{engine}` re-planned row"
            );
            assert!(
                pts.iter().any(|p| p.engine == engine && p.scenario == SWEEP_SCENARIO),
                "committed file must carry a `{engine}` sweep-throughput row"
            );
        }
        // And the ratio gate actually covers them: a self-comparison
        // must compare every committed scenario — faulted and sweep
        // rows included, so a regression there fails CI like any other.
        let out = check_against(&pts, &pts, 0.25, false);
        assert!(out.passed(), "{:?}", out.failures);
        let scenarios: std::collections::BTreeSet<&str> =
            pts.iter().map(|p| p.scenario.as_str()).collect();
        assert_eq!(out.compared, scenarios.len(), "every committed scenario is gated");
    }

    #[test]
    fn parser_rejects_garbage() {
        assert!(parse_points("{").is_err());
        assert!(parse_points("{\"points\": 3}").is_err());
        assert!(parse_points("{\"points\": []} trailing").is_err());
        assert!(parse_points("{\"points\": []}").unwrap().is_empty());
    }
}
