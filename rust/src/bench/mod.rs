//! Bench harness shared by `rust/benches/*` (the offline build has no
//! criterion): warm-up + timed repetitions, median/stddev reporting, and
//! helpers that assemble the standard experiment pipeline
//! (workload → partition → schedule → simulate).

pub mod trajectory;

use std::time::Instant;

use crate::config::Scheme;
use crate::links::ClusterEnv;
use crate::models::{self, BucketProfile, Workload};
use crate::partition::{partition, Strategy};
use crate::sched::{Bytescheduler, Deft, DeftOptions, Schedule, Scheduler, UsByte, Wfbp};
use crate::sim::{simulate, SimOptions, SimResult};
use crate::util::error::{Context, Result};
use crate::util::stats;

/// Time `f` with `warmup` unmeasured and `reps` measured runs; returns
/// (median_s, stddev_s).
pub fn time_it<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    (stats::median(&samples), stats::stddev(&samples))
}

/// Resolve a workload by name. Unknown names are a typed error so
/// sweep-style callers can skip bad combos instead of aborting.
pub fn workload_by_name(name: &str) -> Result<Workload> {
    Ok(match name {
        "resnet101" => models::resnet101(),
        "vgg19" => models::vgg19(),
        "gpt2" => models::gpt2(),
        "llama2" | "llama2_7b_like" => models::llama2_7b_like(),
        "small" => models::small_transformer(4, 256, 2048, 128),
        other => crate::bail!(
            "unknown workload `{other}` (expected resnet101, vgg19, gpt2, llama2, or small)"
        ),
    })
}

/// Build the scheduler for a scheme; DeFT's knapsack set follows the
/// environment's link registry (one knapsack per link), each capacity
/// derived from that link's **planning** slowdown — the codec-effective
/// segment-path μ times the static shared-NIC contention factor of the
/// environment's contention model; under a flat topology with raw codecs
/// and unshared NICs these are the raw μs. The single-queue baselines
/// ride the planning-fastest link (the reference link on every preset).
/// Per-link codec errors feed DeFT's Preserver gate.
pub fn scheduler_for(scheme: Scheme, preserver: bool, env: &ClusterEnv) -> Box<dyn Scheduler> {
    match scheme {
        Scheme::PytorchDdp => Box::new(Wfbp),
        Scheme::Bytescheduler => Box::new(Bytescheduler::for_env(env)),
        Scheme::UsByte => Box::new(UsByte::for_env(env)),
        Scheme::Deft => Box::new(Deft::new(DeftOptions {
            preserver,
            link_mus: env.link_planning_mus(),
            link_errors: env.link_path_codec_errors(),
            ..DeftOptions::default()
        })),
        Scheme::DeftNoMultilink => Box::new(Deft::new(DeftOptions {
            heterogeneous: false,
            preserver: false,
            link_mus: env.link_planning_mus(),
            link_errors: env.link_path_codec_errors(),
            ..DeftOptions::default()
        })),
    }
}

/// The standard experiment pipeline used by most benches: partition the
/// workload for the scheme, schedule, and simulate.
pub struct PipelineResult {
    pub buckets: Vec<BucketProfile>,
    pub schedule: Schedule,
    pub sim: SimResult,
}

/// Run workload × scheme × env through partition → schedule → simulate,
/// with the span timeline recorded (most benches render Gantt rows or
/// read spans). Equivalent to [`run_pipeline_opts`] with
/// `record_timeline = true`.
pub fn run_pipeline(
    workload: &Workload,
    scheme: Scheme,
    env: &ClusterEnv,
    partition_size: u64,
    ddp_bucket_mb: f64,
    iterations: usize,
) -> Result<PipelineResult> {
    run_pipeline_opts(
        workload,
        scheme,
        env,
        partition_size,
        ddp_bucket_mb,
        iterations,
        true,
    )
}

/// [`run_pipeline`] with span recording under caller control: throughput
/// benches pass `record_timeline = false` so they stop paying span
/// allocation costs they never measure. Partition failures surface as
/// typed errors (sweep callers skip the combo; tests `.expect`).
#[allow(clippy::too_many_arguments)]
pub fn run_pipeline_opts(
    workload: &Workload,
    scheme: Scheme,
    env: &ClusterEnv,
    partition_size: u64,
    ddp_bucket_mb: f64,
    iterations: usize,
    record_timeline: bool,
) -> Result<PipelineResult> {
    let buckets = partition_for(workload, scheme, env, partition_size, ddp_bucket_mb)?;
    let scheduler = scheduler_for(scheme, true, env);
    let schedule = scheduler.schedule(&buckets);
    let warmup = schedule.warmup_iters + schedule.cycle.len() + 2;
    let iterations = iterations.max(warmup * 3 + 4);
    let sim = simulate(
        &buckets,
        &schedule,
        env,
        &SimOptions {
            iterations,
            warmup,
            record_timeline,
        },
    );
    Ok(PipelineResult {
        buckets,
        schedule,
        sim,
    })
}

/// Partition `workload` with the scheme's canonical strategy (DDP fixed
/// buckets; uniform / us-byte / DeFT-constrained partitions). The
/// single-link DeFT ablation still partitions with the DeFT constraint.
pub fn partition_for(
    workload: &Workload,
    scheme: Scheme,
    env: &ClusterEnv,
    partition_size: u64,
    ddp_bucket_mb: f64,
) -> Result<Vec<BucketProfile>> {
    let strategy = match scheme {
        Scheme::PytorchDdp => Strategy::DdpFixed {
            bucket_size_mb: ddp_bucket_mb,
        },
        Scheme::Bytescheduler => Strategy::Uniform { partition_size },
        Scheme::UsByte => Strategy::UsByte { partition_size },
        Scheme::Deft | Scheme::DeftNoMultilink => Strategy::DeftConstrained { partition_size },
    };
    partition(workload, strategy, env)
        .with_context(|| format!("partitioning {} failed", workload.name))
}

/// Convenience: paper-default partition sizes.
pub const PAPER_PARTITION: u64 = 6_500_000;
pub const PAPER_DDP_MB: f64 = 25.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_runs_all_schemes_on_gpt2() {
        let w = workload_by_name("gpt2").unwrap();
        let env = ClusterEnv::paper_testbed();
        for scheme in Scheme::ALL {
            let r = run_pipeline(&w, scheme, &env, PAPER_PARTITION, PAPER_DDP_MB, 40).unwrap();
            assert!(r.sim.steady_iter_time.as_us() > 0, "{scheme:?}");
            assert!(!r.buckets.is_empty());
        }
    }

    #[test]
    fn unknown_workload_is_a_typed_error() {
        let e = workload_by_name("no-such-model").unwrap_err();
        assert!(e.to_string().contains("no-such-model"), "{e}");
    }

    #[test]
    fn no_timeline_pipeline_matches_metrics_and_skips_spans() {
        let w = workload_by_name("small").unwrap();
        let env = ClusterEnv::paper_testbed();
        let with = run_pipeline(&w, Scheme::Deft, &env, PAPER_PARTITION, PAPER_DDP_MB, 24).unwrap();
        let without = run_pipeline_opts(
            &w,
            Scheme::Deft,
            &env,
            PAPER_PARTITION,
            PAPER_DDP_MB,
            24,
            false,
        )
        .unwrap();
        assert!(without.sim.timeline.spans.is_empty());
        assert!(!with.sim.timeline.spans.is_empty());
        assert_eq!(with.sim.steady_iter_time, without.sim.steady_iter_time);
        assert_eq!(with.sim.events_processed, without.sim.events_processed);
        assert_eq!(with.sim.iter_ends, without.sim.iter_ends);
    }

    #[test]
    fn deft_beats_ddp_on_vgg19() {
        // The paper's headline: DeFT speedup on the CR≈2 workload.
        let w = workload_by_name("vgg19").unwrap();
        let env = ClusterEnv::paper_testbed();
        let ddp = run_pipeline(&w, Scheme::PytorchDdp, &env, PAPER_PARTITION, PAPER_DDP_MB, 40)
            .unwrap();
        let deft = run_pipeline(&w, Scheme::Deft, &env, PAPER_PARTITION, PAPER_DDP_MB, 40).unwrap();
        // Compare per-sample time: DeFT updates less often but each
        // iteration still consumes one batch per worker, so iteration
        // time is the right unit.
        let speedup = ddp.sim.steady_iter_time.ratio(deft.sim.steady_iter_time);
        assert!(
            speedup > 1.3,
            "DeFT speedup over DDP only {speedup:.2}x (ddp {:?} vs deft {:?})",
            ddp.sim.steady_iter_time,
            deft.sim.steady_iter_time
        );
    }

    #[test]
    fn time_it_returns_positive() {
        let (med, _sd) = time_it(1, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(med >= 0.0);
    }
}
