//! Configuration system: a TOML-subset parser (offline build — no serde)
//! plus the typed experiment configuration consumed by the launcher.
//!
//! Supported syntax: `[section]` headers, `key = value` with string
//! (`"x"`), boolean, integer, and float values, `#` comments. That covers
//! every config this project ships; nested tables and arrays are
//! deliberately out of scope.

pub mod toml_lite;

pub use toml_lite::{parse, ParseError, Value};

use crate::links::ClusterEnv;
use crate::partition::Strategy;
use std::collections::BTreeMap;

/// Which scheduling scheme to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    PytorchDdp,
    Bytescheduler,
    UsByte,
    Deft,
    DeftNoMultilink,
}

impl Scheme {
    pub const ALL: [Scheme; 4] = [
        Scheme::PytorchDdp,
        Scheme::Bytescheduler,
        Scheme::UsByte,
        Scheme::Deft,
    ];

    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "pytorch-ddp" | "ddp" | "pytorch" => Some(Scheme::PytorchDdp),
            "bytescheduler" => Some(Scheme::Bytescheduler),
            "us-byte" | "usbyte" => Some(Scheme::UsByte),
            "deft" => Some(Scheme::Deft),
            "deft-nolink" | "deft-no-multilink" => Some(Scheme::DeftNoMultilink),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::PytorchDdp => "pytorch-ddp",
            Scheme::Bytescheduler => "bytescheduler",
            Scheme::UsByte => "us-byte",
            Scheme::Deft => "deft",
            Scheme::DeftNoMultilink => "deft-nolink",
        }
    }
}

/// Full experiment configuration (simulation path).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Workload name: resnet101 | vgg19 | gpt2 | llama2 | small.
    pub workload: String,
    pub scheme: Scheme,
    pub workers: usize,
    pub bandwidth_gbps: f64,
    pub multi_link: bool,
    pub partition_size: u64,
    pub ddp_bucket_mb: f64,
    pub iterations: usize,
    pub warmup: usize,
    pub mu: f64,
    pub preserver: bool,
    pub epsilon: f64,
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            workload: "vgg19".into(),
            scheme: Scheme::Deft,
            workers: 16,
            bandwidth_gbps: 40.0,
            multi_link: true,
            partition_size: 6_500_000,
            ddp_bucket_mb: 25.0,
            iterations: 60,
            warmup: 8,
            mu: crate::links::PAPER_MU,
            preserver: true,
            epsilon: crate::preserver::EPSILON,
            seed: 17,
        }
    }
}

impl ExperimentConfig {
    /// Load from TOML-subset text. Unknown keys are rejected — configs
    /// must not silently ignore typos.
    pub fn from_toml(text: &str) -> Result<ExperimentConfig, String> {
        let doc = parse(text).map_err(|e| e.to_string())?;
        let mut cfg = ExperimentConfig::default();
        for (key, value) in doc.flatten() {
            cfg.set_key(&key, &value)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.workers == 0 {
            return Err("workers must be ≥ 1".into());
        }
        if self.bandwidth_gbps <= 0.0 {
            return Err("bandwidth must be positive".into());
        }
        if self.partition_size == 0 {
            return Err("partition_size must be positive".into());
        }
        if self.iterations <= self.warmup {
            return Err("iterations must exceed warmup".into());
        }
        if !(0.0..1.0).contains(&self.epsilon) {
            return Err("epsilon must be in [0, 1)".into());
        }
        Ok(())
    }

    /// The cluster environment this config describes.
    pub fn env(&self) -> ClusterEnv {
        let mut env = ClusterEnv::paper_testbed()
            .with_workers(self.workers)
            .with_bandwidth(self.bandwidth_gbps);
        env.multi_link = self.multi_link;
        env.mu = self.mu;
        env
    }

    /// The partition strategy this config's scheme uses.
    pub fn strategy(&self) -> Strategy {
        match self.scheme {
            Scheme::PytorchDdp => Strategy::DdpFixed {
                bucket_size_mb: self.ddp_bucket_mb,
            },
            Scheme::Bytescheduler => Strategy::Uniform {
                partition_size: self.partition_size,
            },
            Scheme::UsByte => Strategy::UsByte {
                partition_size: self.partition_size,
            },
            Scheme::Deft | Scheme::DeftNoMultilink => Strategy::DeftConstrained {
                partition_size: self.partition_size,
            },
        }
    }

    /// Apply `--key=value` command-line overrides: each value is parsed
    /// as a TOML scalar if possible, else treated as a bare string.
    pub fn apply_overrides(&mut self, overrides: &BTreeMap<String, String>) -> Result<(), String> {
        for (k, v) in overrides {
            let value = Value::parse_scalar(v);
            self.set_key(k, &value)?;
        }
        self.validate()
    }

    fn set_key(&mut self, key: &str, value: &Value) -> Result<(), String> {
        match key {
            "experiment.workload" | "workload" => self.workload = value.as_str()?.to_string(),
            "experiment.scheme" | "scheme" => {
                self.scheme = Scheme::parse(value.as_str()?)
                    .ok_or_else(|| format!("unknown scheme {value:?}"))?
            }
            "cluster.workers" | "workers" => self.workers = value.as_int()? as usize,
            "cluster.bandwidth_gbps" | "bandwidth_gbps" => self.bandwidth_gbps = value.as_float()?,
            "cluster.multi_link" | "multi_link" => self.multi_link = value.as_bool()?,
            "cluster.mu" | "mu" => self.mu = value.as_float()?,
            "schedule.partition_size" | "partition_size" => {
                self.partition_size = value.as_int()? as u64
            }
            "schedule.ddp_bucket_mb" | "ddp_bucket_mb" => self.ddp_bucket_mb = value.as_float()?,
            "schedule.preserver" | "preserver" => self.preserver = value.as_bool()?,
            "schedule.epsilon" | "epsilon" => self.epsilon = value.as_float()?,
            "run.iterations" | "iterations" => self.iterations = value.as_int()? as usize,
            "run.warmup" | "warmup" => self.warmup = value.as_int()? as usize,
            "run.seed" | "seed" => self.seed = value.as_int()? as u64,
            other => return Err(format!("unknown config key `{other}`")),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let text = r#"
# DeFT experiment
[experiment]
workload = "gpt2"
scheme = "deft"

[cluster]
workers = 8
bandwidth_gbps = 20.0
multi_link = false

[schedule]
partition_size = 4000000
preserver = true

[run]
iterations = 30
warmup = 4
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.workload, "gpt2");
        assert_eq!(cfg.scheme, Scheme::Deft);
        assert_eq!(cfg.workers, 8);
        assert!((cfg.bandwidth_gbps - 20.0).abs() < 1e-12);
        assert!(!cfg.multi_link);
        assert_eq!(cfg.partition_size, 4_000_000);
        assert_eq!(cfg.iterations, 30);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(ExperimentConfig::from_toml("nonsense = 1\n").is_err());
        assert!(ExperimentConfig::from_toml("scheme = \"magic\"\n").is_err());
        assert!(ExperimentConfig::from_toml("workers = 0\n").is_err());
        assert!(ExperimentConfig::from_toml("iterations = 2\nwarmup = 5\n").is_err());
    }

    #[test]
    fn overrides_apply() {
        let mut cfg = ExperimentConfig::default();
        let mut ov = BTreeMap::new();
        ov.insert("workers".to_string(), "4".to_string());
        ov.insert("scheme".to_string(), "us-byte".to_string());
        cfg.apply_overrides(&ov).unwrap();
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.scheme, Scheme::UsByte);
    }

    #[test]
    fn strategy_matches_scheme() {
        let mut cfg = ExperimentConfig::default();
        cfg.scheme = Scheme::PytorchDdp;
        assert!(matches!(cfg.strategy(), Strategy::DdpFixed { .. }));
        cfg.scheme = Scheme::Bytescheduler;
        assert!(matches!(cfg.strategy(), Strategy::Uniform { .. }));
        cfg.scheme = Scheme::Deft;
        assert!(matches!(cfg.strategy(), Strategy::DeftConstrained { .. }));
    }

    #[test]
    fn env_reflects_cluster_settings() {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 4;
        cfg.bandwidth_gbps = 10.0;
        cfg.multi_link = false;
        let env = cfg.env();
        assert_eq!(env.workers, 4);
        assert!((env.bandwidth_gbps - 10.0).abs() < 1e-12);
        assert!(!env.multi_link);
    }
}
