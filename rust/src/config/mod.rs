//! Configuration system: a TOML-subset parser (offline build — no serde)
//! plus the typed experiment configuration consumed by the launcher.
//!
//! Supported syntax: `[section]` headers, `[[links]]` array-of-tables
//! blocks (custom link topologies), `key = value` with string (`"x"`),
//! boolean, integer, and float values, `#` comments.
//!
//! Link topology is configured either by preset name
//! (`links_preset = "nvlink-ib-tcp"` — see [`LinkPreset`]) or by an
//! explicit `[[links]]` array, one block per link:
//!
//! ```toml
//! [[links]]
//! name = "nccl"
//! mu = 1.0
//! alpha_us = 300
//! bandwidth_gbps = 40.0
//! contention_group = 0
//!
//! [[links]]
//! name = "gloo"
//! mu = 1.65
//! alpha_us = 900
//! contention_group = 1
//! staging_ramp = 0.12
//! codec = "fp16"        # per-link gradient compression: raw | fp16 | rank<k>
//! ```
//!
//! A rank-level topology is configured with a `[topology]` table whose
//! `intra`/`inter` keys reference registry links by name:
//!
//! ```toml
//! [cluster]
//! links_preset = "nvlink-ib-tcp"
//!
//! [topology]
//! ranks_per_node = 8    # must divide `workers`; 1 (default) = flat
//! intra = "nvlink"      # link serving node-local segments
//! inter = "ib"          # fabric for transfers scheduled on `intra`
//! codec = "fp16"        # compress the cross-node fabric (the inter link)
//! ```
//!
//! How shared-NIC contention is priced (planning estimate and DES
//! execution alike) is selected with a `[contention]` table:
//!
//! ```toml
//! [contention]
//! model = "kway"        # aggregate k-way sharing (default) | "pairwise"
//! ```
//!
//! The legacy knobs are kept: `multi_link = false` collapses a 2-link
//! preset onto one NIC (the Table IV configuration) and `mu` overrides
//! the slow link's μ of a 2-link preset.

pub mod toml_lite;

pub use toml_lite::{parse, ParseError, Value};

use crate::faults::{FaultSpec, Flap, MembershipChange, Straggler};
use crate::links::{ClusterEnv, Codec, ContentionModel, LinkId, LinkPreset, LinkSpec, Topology};
use crate::partition::Strategy;
use crate::util::Micros;
use std::collections::BTreeMap;

/// Which scheduling scheme to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    PytorchDdp,
    Bytescheduler,
    UsByte,
    Deft,
    DeftNoMultilink,
}

impl Scheme {
    pub const ALL: [Scheme; 4] = [
        Scheme::PytorchDdp,
        Scheme::Bytescheduler,
        Scheme::UsByte,
        Scheme::Deft,
    ];

    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "pytorch-ddp" | "ddp" | "pytorch" => Some(Scheme::PytorchDdp),
            "bytescheduler" => Some(Scheme::Bytescheduler),
            "us-byte" | "usbyte" => Some(Scheme::UsByte),
            "deft" => Some(Scheme::Deft),
            "deft-nolink" | "deft-no-multilink" => Some(Scheme::DeftNoMultilink),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::PytorchDdp => "pytorch-ddp",
            Scheme::Bytescheduler => "bytescheduler",
            Scheme::UsByte => "us-byte",
            Scheme::Deft => "deft",
            Scheme::DeftNoMultilink => "deft-nolink",
        }
    }
}

/// Full experiment configuration (simulation path).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Workload name: resnet101 | vgg19 | gpt2 | llama2 | small.
    pub workload: String,
    pub scheme: Scheme,
    pub workers: usize,
    pub bandwidth_gbps: f64,
    /// Legacy knob: `false` collapses a 2-link preset onto one NIC.
    pub multi_link: bool,
    /// Link topology preset name (see [`LinkPreset`]); ignored when
    /// `custom_links` is non-empty.
    pub links_preset: String,
    /// Explicit `[[links]]` topology; overrides `links_preset` when set.
    pub custom_links: Vec<LinkSpec>,
    pub partition_size: u64,
    pub ddp_bucket_mb: f64,
    pub iterations: usize,
    pub warmup: usize,
    pub mu: f64,
    pub preserver: bool,
    pub epsilon: f64,
    pub seed: u64,
    /// `[topology] ranks_per_node`: ranks sharing a node; 1 = flat
    /// topology (the default). Must divide `workers`.
    pub ranks_per_node: usize,
    /// `[topology] intra`: name of the registry link serving node-local
    /// segments (required when `ranks_per_node > 1`).
    pub topology_intra: String,
    /// `[topology] inter`: name of the fabric carrying the cross-node
    /// leg of transfers scheduled on the intra link itself; defaults to
    /// the reference link (registry index 0).
    pub topology_inter: String,
    /// `[topology] codec`: compression codec attached to the `inter`
    /// fabric link (`raw` | `fp16` | `rank<k>`; empty = leave the link's
    /// own codec). Requires a hierarchical topology.
    pub topology_codec: String,
    /// `[contention] model`: how shared-NIC contention is priced —
    /// `"kway"` (aggregate k-way sharing, the default) or `"pairwise"`
    /// (the legacy Table IV rule). See [`ContentionModel`].
    pub contention_model: String,
    /// `[faults] scenario`: named fault preset injected into simulation
    /// runs (`straggler` | `flap` | `elastic` | `mixed`; empty = none).
    /// The remaining `[faults]` keys override or extend it — see
    /// docs/faults.md and [`FaultSpec::preset`].
    pub faults_scenario: String,
    /// `[faults] seed`: jitter-stream seed override (< 0 = keep the
    /// scenario's seed).
    pub faults_seed: i64,
    /// `[faults] jitter_pct`: per-task compute jitter override (< 0 =
    /// keep the scenario's value).
    pub faults_jitter_pct: f64,
    /// `[faults] drift_band`: drift-monitor band override (< 0 = keep
    /// the scenario's value; 0 disables the monitor).
    pub faults_drift_band: f64,
    /// `[faults] drift_low_side`: also raise band-symmetric low-side
    /// drift alarms ([`FaultSpec::drift_low_side`]) — the re-planner's
    /// over-conservative-plan signal. Off by default.
    pub faults_drift_low_side: bool,
    /// `[faults] straggler_factor`: extra persistent straggler stretch
    /// (≤ 0 = none).
    pub faults_straggler_factor: f64,
    /// `[faults] straggler_from_iter`: onset iteration of the extra
    /// straggler.
    pub faults_straggler_from_iter: usize,
    /// `[faults] straggler_rank`: rank the extra straggler lives on
    /// (slowest-rank rule — see docs/faults.md). Must be < `workers`.
    pub faults_straggler_rank: usize,
    /// `[faults] flap_link`: registry link name of an extra flap (empty
    /// = none).
    pub faults_flap_link: String,
    /// `[faults] flap_at_us`: sim time (µs) of the extra flap.
    pub faults_flap_at_us: u64,
    /// `[faults] flap_factor`: wire-time factor of the extra flap
    /// (> 1 degrades, 1 recovers).
    pub faults_flap_factor: f64,
    /// `[faults] elastic_workers`: extra membership change to this many
    /// ranks (0 = none).
    pub faults_elastic_workers: usize,
    /// `[faults] elastic_at_iter`: iteration of the extra membership
    /// change.
    pub faults_elastic_at_iter: usize,
    /// `[sweep] workloads`: comma-separated model-zoo names the batch
    /// sweep engine fans over (see docs/sweeps.md).
    pub sweep_workloads: String,
    /// `[sweep] presets`: comma-separated link-preset names.
    pub sweep_presets: String,
    /// `[sweep] ranks_per_node`: comma-separated per-node rank counts
    /// (1 = flat; > 1 = hierarchical on the preset's first two links).
    pub sweep_ranks_per_node: String,
    /// `[sweep] codecs`: comma-separated codec names attached to every
    /// non-reference link of a cell (`raw` leaves the preset as-is).
    pub sweep_codecs: String,
    /// `[sweep] contention`: comma-separated contention-model names.
    pub sweep_contention: String,
    /// `[sweep] faults`: comma-separated fault-preset names; `none`
    /// sweeps the healthy cluster.
    pub sweep_faults: String,
    /// `[sweep] threads`: worker threads of the sweep pool (1 = serial;
    /// results are bit-for-bit identical either way).
    pub sweep_threads: usize,
    /// `[replan] enabled`: on a rejected drift re-gate, re-solve the
    /// §III.D knapsacks against measured link capacities before falling
    /// back to the raw plan (see docs/replan.md).
    pub replan_enabled: bool,
    /// `[replan] min_excess_ppm`: only re-plan when the compounded
    /// drift error is at least this many ppm (0 = re-plan on every
    /// rejected re-gate).
    pub replan_min_excess_ppm: u64,
    /// `[replan] max_retries`: capacity-feedback retries of the re-plan
    /// loop (the same ×1.15 feedback the Preserver uses).
    pub replan_max_retries: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            workload: "vgg19".into(),
            scheme: Scheme::Deft,
            workers: 16,
            bandwidth_gbps: 40.0,
            multi_link: true,
            links_preset: "paper-2link".into(),
            custom_links: Vec::new(),
            partition_size: 6_500_000,
            ddp_bucket_mb: 25.0,
            iterations: 60,
            warmup: 8,
            mu: crate::links::PAPER_MU,
            preserver: true,
            epsilon: crate::preserver::EPSILON,
            seed: 17,
            ranks_per_node: 1,
            topology_intra: String::new(),
            topology_inter: String::new(),
            topology_codec: String::new(),
            contention_model: ContentionModel::default().name().to_string(),
            faults_scenario: String::new(),
            faults_seed: -1,
            faults_jitter_pct: -1.0,
            faults_drift_band: -1.0,
            faults_drift_low_side: false,
            faults_straggler_factor: 0.0,
            faults_straggler_from_iter: 2,
            faults_straggler_rank: 0,
            faults_flap_link: String::new(),
            faults_flap_at_us: 20_000,
            faults_flap_factor: 2.0,
            faults_elastic_workers: 0,
            faults_elastic_at_iter: 2,
            sweep_workloads: "resnet101,vgg19,gpt2,llama2".into(),
            sweep_presets: "paper-2link,single-nic,nvlink-ib-tcp".into(),
            sweep_ranks_per_node: "1,8".into(),
            sweep_codecs: "raw,fp16".into(),
            sweep_contention: "pairwise,kway".into(),
            sweep_faults: "none".into(),
            sweep_threads: 4,
            replan_enabled: false,
            replan_min_excess_ppm: 0,
            replan_max_retries: crate::preserver::MAX_RETRIES,
        }
    }
}

impl ExperimentConfig {
    /// Load from TOML-subset text. Unknown keys are rejected — configs
    /// must not silently ignore typos.
    pub fn from_toml(text: &str) -> Result<ExperimentConfig, String> {
        let doc = parse(text).map_err(|e| e.to_string())?;
        let mut cfg = ExperimentConfig::default();
        for (key, value) in doc.flatten() {
            cfg.set_key(&key, &value)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.workers == 0 {
            return Err("workers must be ≥ 1".into());
        }
        if self.bandwidth_gbps <= 0.0 {
            return Err("bandwidth must be positive".into());
        }
        if self.partition_size == 0 {
            return Err("partition_size must be positive".into());
        }
        if self.iterations <= self.warmup {
            return Err("iterations must exceed warmup".into());
        }
        if !(0.0..1.0).contains(&self.epsilon) {
            return Err("epsilon must be in [0, 1)".into());
        }
        if self.mu <= 0.0 {
            return Err("mu must be positive".into());
        }
        if ContentionModel::parse(&self.contention_model).is_none() {
            return Err(format!(
                "contention.model: unknown model `{}` (known: {})",
                self.contention_model,
                ContentionModel::ALL
                    .iter()
                    .map(|m| m.name())
                    .collect::<Vec<_>>()
                    .join(" | ")
            ));
        }
        if self.custom_links.is_empty() {
            if LinkPreset::parse(&self.links_preset).is_none() {
                return Err(format!(
                    "unknown links preset `{}` (known: {})",
                    self.links_preset,
                    LinkPreset::ALL
                        .iter()
                        .map(|p| p.name())
                        .collect::<Vec<_>>()
                        .join(", ")
                ));
            }
        } else {
            for (i, l) in self.custom_links.iter().enumerate() {
                if l.name.is_empty() {
                    return Err(format!(
                        "links[{i}]: name must be set — every [[links]] entry (or \
                         links.{i}.* override) needs an explicit name"
                    ));
                }
                if l.mu <= 0.0 {
                    return Err(format!("links[{i}]: mu must be positive"));
                }
                if l.bandwidth_gbps <= 0.0 {
                    return Err(format!("links[{i}]: bandwidth_gbps must be positive"));
                }
                if self.custom_links[..i].iter().any(|o| o.name == l.name) {
                    return Err(format!("links[{i}]: duplicate link name `{}`", l.name));
                }
            }
            if (self.custom_links[0].mu - 1.0).abs() > 1e-9 {
                return Err("links[0] is the reference link and must have mu = 1.0".into());
            }
        }
        self.validate_faults()?;
        self.validate_sweep()?;
        self.validate_topology()
    }

    /// Validate the `[faults]` table. Only registry-independent checks
    /// live here; link-name resolution happens in [`Self::fault_spec`],
    /// which has the effective [`ClusterEnv`] in hand.
    fn validate_faults(&self) -> Result<(), String> {
        if !self.faults_scenario.is_empty()
            && FaultSpec::preset(&self.faults_scenario, self.workers).is_none()
        {
            return Err(format!(
                "faults.scenario: unknown scenario `{}` (known: {})",
                self.faults_scenario,
                FaultSpec::preset_names().join(" | ")
            ));
        }
        if self.faults_jitter_pct >= 0.0 && !(0.0..10.0).contains(&self.faults_jitter_pct) {
            return Err("faults.jitter_pct must be in [0, 10)".into());
        }
        if self.faults_drift_band >= 0.0 && !(0.0..10.0).contains(&self.faults_drift_band) {
            return Err("faults.drift_band must be in [0, 10)".into());
        }
        if self.faults_straggler_factor > 0.0
            && !(self.faults_straggler_factor >= 1.0 && self.faults_straggler_factor.is_finite())
        {
            return Err("faults.straggler_factor must be ≥ 1 (or ≤ 0 for none)".into());
        }
        if !self.faults_flap_link.is_empty()
            && !(self.faults_flap_factor > 0.0 && self.faults_flap_factor.is_finite())
        {
            return Err("faults.flap_factor must be positive and finite".into());
        }
        if self.faults_elastic_workers == 1 {
            return Err("faults.elastic_workers must be ≥ 2 (or 0 for none)".into());
        }
        if self.faults_straggler_factor > 0.0 && self.faults_straggler_rank >= self.workers {
            return Err(format!(
                "faults.straggler_rank {} outside the {}-rank cluster",
                self.faults_straggler_rank, self.workers
            ));
        }
        Ok(())
    }

    /// Validate the `[sweep]` table's grid axes: every comma-separated
    /// item must name a known workload / preset / codec / contention
    /// model / fault preset, and every axis must be non-empty.
    fn validate_sweep(&self) -> Result<(), String> {
        if self.sweep_threads == 0 {
            return Err("sweep.threads must be ≥ 1".into());
        }
        let items = |s: &str| -> Vec<String> {
            s.split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect()
        };
        for (key, axis) in [
            ("sweep.workloads", &self.sweep_workloads),
            ("sweep.presets", &self.sweep_presets),
            ("sweep.ranks_per_node", &self.sweep_ranks_per_node),
            ("sweep.codecs", &self.sweep_codecs),
            ("sweep.contention", &self.sweep_contention),
            ("sweep.faults", &self.sweep_faults),
        ] {
            if items(axis).is_empty() {
                return Err(format!("{key}: axis must list at least one value"));
            }
        }
        for w in items(&self.sweep_workloads) {
            crate::bench::workload_by_name(&w)
                .map_err(|e| format!("sweep.workloads: {e}"))?;
        }
        for p in items(&self.sweep_presets) {
            if LinkPreset::parse(&p).is_none() {
                return Err(format!(
                    "sweep.presets: unknown preset `{p}` (known: {})",
                    LinkPreset::ALL
                        .iter()
                        .map(|p| p.name())
                        .collect::<Vec<_>>()
                        .join(", ")
                ));
            }
        }
        for r in items(&self.sweep_ranks_per_node) {
            let rpn: usize = r
                .parse()
                .map_err(|_| format!("sweep.ranks_per_node: `{r}` is not an integer"))?;
            if rpn == 0 {
                return Err("sweep.ranks_per_node: values must be ≥ 1".into());
            }
            if self.workers % rpn != 0 {
                return Err(format!(
                    "sweep.ranks_per_node: {rpn} must divide workers {}",
                    self.workers
                ));
            }
        }
        for c in items(&self.sweep_codecs) {
            if Codec::parse(&c).is_none() {
                return Err(format!(
                    "sweep.codecs: unknown codec `{c}` (known: raw | fp16 | rank<k>)"
                ));
            }
        }
        for m in items(&self.sweep_contention) {
            if ContentionModel::parse(&m).is_none() {
                return Err(format!(
                    "sweep.contention: unknown model `{m}` (known: {})",
                    ContentionModel::ALL
                        .iter()
                        .map(|m| m.name())
                        .collect::<Vec<_>>()
                        .join(" | ")
                ));
            }
        }
        for f in items(&self.sweep_faults) {
            if f != "none" && FaultSpec::preset(&f, self.workers).is_none() {
                return Err(format!(
                    "sweep.faults: unknown preset `{f}` (known: none | {})",
                    FaultSpec::preset_names().join(" | ")
                ));
            }
        }
        Ok(())
    }

    /// Validate the `[topology]` table against the effective registry.
    fn validate_topology(&self) -> Result<(), String> {
        if self.ranks_per_node == 0 {
            return Err("ranks_per_node must be ≥ 1".into());
        }
        if self.workers % self.ranks_per_node != 0 {
            return Err(format!(
                "ranks_per_node {} must divide workers {}",
                self.ranks_per_node, self.workers
            ));
        }
        let names = self.link_names();
        for (key, name) in [
            ("topology.intra", &self.topology_intra),
            ("topology.inter", &self.topology_inter),
        ] {
            if !name.is_empty() && !names.iter().any(|n| n == name) {
                return Err(format!(
                    "{key}: unknown link `{name}` (registry: {})",
                    names.join(", ")
                ));
            }
        }
        if self.ranks_per_node <= 1
            && (!self.topology_intra.is_empty() || !self.topology_inter.is_empty())
        {
            return Err(
                "[topology] intra/inter take effect only with ranks_per_node > 1 — set it, \
                 or drop the keys for a flat topology"
                    .into(),
            );
        }
        if self.ranks_per_node > 1 {
            if self.topology_intra.is_empty() {
                return Err(
                    "hierarchical topology (ranks_per_node > 1) needs topology.intra = \
                     \"<link name>\""
                        .into(),
                );
            }
            let inter = if self.topology_inter.is_empty() {
                &names[0]
            } else {
                &self.topology_inter
            };
            if *inter == self.topology_intra {
                return Err(format!(
                    "topology.intra and topology.inter must be distinct links (both `{inter}`; \
                     inter defaults to the reference link)"
                ));
            }
        }
        if !self.topology_codec.is_empty() {
            if Codec::parse(&self.topology_codec).is_none() {
                return Err(format!(
                    "topology.codec: unknown codec `{}` (known: raw | fp16 | rank<k>)",
                    self.topology_codec
                ));
            }
            if self.ranks_per_node <= 1 {
                return Err(
                    "topology.codec compresses the inter fabric and needs a hierarchical \
                     topology (ranks_per_node > 1); use a [[links]] codec for flat registries"
                        .into(),
                );
            }
        }
        Ok(())
    }

    /// Link names of the effective registry (custom links, else preset).
    fn link_names(&self) -> Vec<String> {
        if self.custom_links.is_empty() {
            LinkPreset::parse(&self.links_preset)
                .map(|p| p.links().iter().map(|l| l.name.clone()).collect())
                .unwrap_or_default()
        } else {
            self.custom_links.iter().map(|l| l.name.clone()).collect()
        }
    }

    /// The cluster environment this config describes.
    pub fn env(&self) -> ClusterEnv {
        let mut env = ClusterEnv::paper_testbed()
            .with_workers(self.workers)
            .with_bandwidth(self.bandwidth_gbps)
            .with_contention_model(
                ContentionModel::parse(&self.contention_model).expect("validated model"),
            );
        if !self.custom_links.is_empty() {
            env.links = self.custom_links.clone();
            return self.apply_topology(env);
        }
        let preset = LinkPreset::parse(&self.links_preset).expect("validated preset");
        env.links = preset.links();
        // Legacy knobs apply to 2-link presets only: `mu` retunes the
        // slow link, `multi_link = false` collapses onto one NIC. (Wider
        // topologies use `with_single_link()` / contention groups
        // explicitly.)
        if env.links.len() == 2 {
            env.links[1].mu = self.mu;
            if !self.multi_link {
                for l in &mut env.links {
                    l.contention_group = 0;
                }
            }
        }
        self.apply_topology(env)
    }

    /// Attach the `[topology]` table to a built environment.
    fn apply_topology(&self, env: ClusterEnv) -> ClusterEnv {
        if self.ranks_per_node <= 1 {
            return env;
        }
        let intra = env.link(&self.topology_intra).expect("validated intra link");
        let inter = if self.topology_inter.is_empty() {
            LinkId::REFERENCE
        } else {
            env.link(&self.topology_inter).expect("validated inter link")
        };
        let mut env =
            env.with_topology(Topology::hierarchical(self.ranks_per_node, intra, inter));
        if !self.topology_codec.is_empty() {
            let codec = Codec::parse(&self.topology_codec).expect("validated codec");
            env = env.with_codec(inter, codec);
        }
        env
    }

    /// The fault-injection spec the `[faults]` table describes, resolved
    /// against the effective environment (flap links are named, so the
    /// registry must already be built). `Ok(None)` means the table is
    /// absent or declares nothing — run healthy.
    pub fn fault_spec(&self, env: &ClusterEnv) -> Result<Option<FaultSpec>, String> {
        let mut spec = if self.faults_scenario.is_empty() {
            FaultSpec::default()
        } else {
            FaultSpec::preset(&self.faults_scenario, self.workers)
                .ok_or_else(|| format!("unknown fault scenario `{}`", self.faults_scenario))?
        };
        if self.faults_seed >= 0 {
            spec.seed = self.faults_seed as u64;
        }
        if self.faults_jitter_pct >= 0.0 {
            spec.jitter_pct = self.faults_jitter_pct;
        }
        if self.faults_drift_band >= 0.0 {
            spec.drift_band = self.faults_drift_band;
        }
        if self.faults_drift_low_side {
            spec.drift_low_side = true;
        }
        if self.faults_straggler_factor > 0.0 {
            spec.stragglers.push(Straggler {
                from_iter: self.faults_straggler_from_iter,
                factor: self.faults_straggler_factor,
                rank: self.faults_straggler_rank,
            });
        }
        if !self.faults_flap_link.is_empty() {
            let link = env.link(&self.faults_flap_link).ok_or_else(|| {
                format!(
                    "faults.flap_link: unknown link `{}` (registry: {})",
                    self.faults_flap_link,
                    env.links
                        .iter()
                        .map(|l| l.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?;
            spec.flaps.push(Flap {
                link,
                at: Micros(self.faults_flap_at_us),
                factor: self.faults_flap_factor,
            });
        }
        if self.faults_elastic_workers > 0 {
            spec.membership.push(MembershipChange {
                at_iter: self.faults_elastic_at_iter,
                workers: self.faults_elastic_workers,
            });
        }
        if self.faults_scenario.is_empty() && spec.is_noop() && spec.drift_band <= 0.0 {
            return Ok(None);
        }
        spec.validate(env)?;
        Ok(Some(spec))
    }

    /// The re-planner knobs the `[replan]` table describes (see
    /// docs/replan.md): measured-drift adaptive re-planning on a
    /// rejected drift re-gate.
    pub fn replan_options(&self) -> crate::sched::ReplanOptions {
        crate::sched::ReplanOptions {
            enabled: self.replan_enabled,
            min_excess_ppm: self.replan_min_excess_ppm,
            max_retries: self.replan_max_retries,
        }
    }

    /// The partition strategy this config's scheme uses.
    pub fn strategy(&self) -> Strategy {
        match self.scheme {
            Scheme::PytorchDdp => Strategy::DdpFixed {
                bucket_size_mb: self.ddp_bucket_mb,
            },
            Scheme::Bytescheduler => Strategy::Uniform {
                partition_size: self.partition_size,
            },
            Scheme::UsByte => Strategy::UsByte {
                partition_size: self.partition_size,
            },
            Scheme::Deft | Scheme::DeftNoMultilink => Strategy::DeftConstrained {
                partition_size: self.partition_size,
            },
        }
    }

    /// Apply `--key=value` command-line overrides: each value is parsed
    /// as a TOML scalar if possible, else treated as a bare string.
    pub fn apply_overrides(&mut self, overrides: &BTreeMap<String, String>) -> Result<(), String> {
        for (k, v) in overrides {
            let value = Value::parse_scalar(v);
            self.set_key(k, &value)?;
        }
        self.validate()
    }

    fn set_key(&mut self, key: &str, value: &Value) -> Result<(), String> {
        match key {
            "experiment.workload" | "workload" => self.workload = value.as_str()?.to_string(),
            "experiment.scheme" | "scheme" => {
                self.scheme = Scheme::parse(value.as_str()?)
                    .ok_or_else(|| format!("unknown scheme {value:?}"))?
            }
            "cluster.workers" | "workers" => self.workers = value.as_int()? as usize,
            "cluster.bandwidth_gbps" | "bandwidth_gbps" => self.bandwidth_gbps = value.as_float()?,
            "cluster.multi_link" | "multi_link" => self.multi_link = value.as_bool()?,
            "cluster.mu" | "mu" => self.mu = value.as_float()?,
            "cluster.links_preset" | "links_preset" => {
                self.links_preset = value.as_str()?.to_string()
            }
            "schedule.partition_size" | "partition_size" => {
                self.partition_size = value.as_int()? as u64
            }
            "schedule.ddp_bucket_mb" | "ddp_bucket_mb" => self.ddp_bucket_mb = value.as_float()?,
            "schedule.preserver" | "preserver" => self.preserver = value.as_bool()?,
            "schedule.epsilon" | "epsilon" => self.epsilon = value.as_float()?,
            "run.iterations" | "iterations" => self.iterations = value.as_int()? as usize,
            "run.warmup" | "warmup" => self.warmup = value.as_int()? as usize,
            "run.seed" | "seed" => self.seed = value.as_int()? as u64,
            "topology.ranks_per_node" | "ranks_per_node" => {
                self.ranks_per_node = value.as_int()? as usize
            }
            "topology.intra" => self.topology_intra = value.as_str()?.to_string(),
            "topology.inter" => self.topology_inter = value.as_str()?.to_string(),
            "topology.codec" => self.topology_codec = value.as_str()?.to_string(),
            "contention.model" | "contention_model" => {
                self.contention_model = value.as_str()?.to_string()
            }
            "faults.scenario" | "faults_scenario" => {
                self.faults_scenario = value.as_str()?.to_string()
            }
            "faults.seed" | "faults_seed" => self.faults_seed = value.as_int()?,
            "faults.jitter_pct" | "faults_jitter_pct" => {
                self.faults_jitter_pct = value.as_float()?
            }
            "faults.drift_band" | "faults_drift_band" => {
                self.faults_drift_band = value.as_float()?
            }
            "faults.drift_low_side" | "faults_drift_low_side" => {
                self.faults_drift_low_side = value.as_bool()?
            }
            "faults.straggler_factor" | "faults_straggler_factor" => {
                self.faults_straggler_factor = value.as_float()?
            }
            "faults.straggler_from_iter" | "faults_straggler_from_iter" => {
                self.faults_straggler_from_iter = value.as_int()? as usize
            }
            "faults.straggler_rank" | "faults_straggler_rank" => {
                self.faults_straggler_rank = value.as_int()? as usize
            }
            "faults.flap_link" | "faults_flap_link" => {
                self.faults_flap_link = value.as_str()?.to_string()
            }
            "faults.flap_at_us" | "faults_flap_at_us" => {
                self.faults_flap_at_us = value.as_int()? as u64
            }
            "faults.flap_factor" | "faults_flap_factor" => {
                self.faults_flap_factor = value.as_float()?
            }
            "faults.elastic_workers" | "faults_elastic_workers" => {
                self.faults_elastic_workers = value.as_int()? as usize
            }
            "faults.elastic_at_iter" | "faults_elastic_at_iter" => {
                self.faults_elastic_at_iter = value.as_int()? as usize
            }
            "sweep.workloads" | "sweep_workloads" => {
                self.sweep_workloads = value.as_str()?.to_string()
            }
            "sweep.presets" | "sweep_presets" => self.sweep_presets = value.as_str()?.to_string(),
            "sweep.ranks_per_node" | "sweep_ranks_per_node" => {
                self.sweep_ranks_per_node = value.as_str()?.to_string()
            }
            "sweep.codecs" | "sweep_codecs" => self.sweep_codecs = value.as_str()?.to_string(),
            "sweep.contention" | "sweep_contention" => {
                self.sweep_contention = value.as_str()?.to_string()
            }
            "sweep.faults" | "sweep_faults" => self.sweep_faults = value.as_str()?.to_string(),
            "sweep.threads" | "sweep_threads" => self.sweep_threads = value.as_int()? as usize,
            "replan.enabled" | "replan_enabled" => self.replan_enabled = value.as_bool()?,
            "replan.min_excess_ppm" | "replan_min_excess_ppm" => {
                self.replan_min_excess_ppm = value.as_int()? as u64
            }
            "replan.max_retries" | "replan_max_retries" => {
                self.replan_max_retries = value.as_int()? as usize
            }
            other => {
                // `[[links]]` blocks flatten to `links.<index>.<field>`.
                if let Some(rest) = other.strip_prefix("links.") {
                    if let Some((idx, field)) = rest.split_once('.') {
                        if let Ok(idx) = idx.parse::<usize>() {
                            return self.set_link_field(idx, field, value);
                        }
                    }
                }
                return Err(format!("unknown config key `{other}`"));
            }
        }
        Ok(())
    }

    fn set_link_field(&mut self, idx: usize, field: &str, value: &Value) -> Result<(), String> {
        if idx > 16 {
            return Err(format!("links[{idx}]: implausibly many links"));
        }
        // Filler entries carry an empty name; validate() rejects any link
        // that is never explicitly named, so a stray partial override
        // (e.g. `--links.1.mu=2.0` on its own) fails loudly instead of
        // silently replacing the preset topology.
        while self.custom_links.len() <= idx {
            let i = self.custom_links.len();
            self.custom_links.push(LinkSpec::new("", 1.0).with_group(i));
        }
        let link = &mut self.custom_links[idx];
        match field {
            "name" => link.name = value.as_str()?.to_string(),
            "mu" => link.mu = value.as_float()?,
            "alpha_us" => link.alpha = Micros(value.as_int()? as u64),
            "bandwidth_gbps" => link.bandwidth_gbps = value.as_float()?,
            "contention_group" => link.contention_group = value.as_int()? as usize,
            "staging_ramp" => link.staging_ramp = value.as_float()?,
            "codec" => {
                let name = value.as_str()?;
                link.codec = Codec::parse(name).ok_or_else(|| {
                    format!("links[{idx}]: unknown codec `{name}` (known: raw | fp16 | rank<k>)")
                })?;
            }
            other => return Err(format!("unknown link field `{other}`")),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let text = r#"
# DeFT experiment
[experiment]
workload = "gpt2"
scheme = "deft"

[cluster]
workers = 8
bandwidth_gbps = 20.0
multi_link = false

[schedule]
partition_size = 4000000
preserver = true

[run]
iterations = 30
warmup = 4
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.workload, "gpt2");
        assert_eq!(cfg.scheme, Scheme::Deft);
        assert_eq!(cfg.workers, 8);
        assert!((cfg.bandwidth_gbps - 20.0).abs() < 1e-12);
        assert!(!cfg.multi_link);
        assert_eq!(cfg.partition_size, 4_000_000);
        assert_eq!(cfg.iterations, 30);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(ExperimentConfig::from_toml("nonsense = 1\n").is_err());
        assert!(ExperimentConfig::from_toml("scheme = \"magic\"\n").is_err());
        assert!(ExperimentConfig::from_toml("workers = 0\n").is_err());
        assert!(ExperimentConfig::from_toml("iterations = 2\nwarmup = 5\n").is_err());
    }

    #[test]
    fn faults_table_builds_a_spec() {
        let text = r#"
[faults]
scenario = "flap"
seed = 99
jitter_pct = 0.01
straggler_factor = 1.4
straggler_from_iter = 3
flap_link = "gloo"
flap_at_us = 30000
flap_factor = 2.5
elastic_workers = 8
elastic_at_iter = 4
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        let env = cfg.env();
        let spec = cfg.fault_spec(&env).unwrap().expect("faults declared");
        assert_eq!(spec.seed, 99);
        assert!((spec.jitter_pct - 0.01).abs() < 1e-12);
        // Preset "flap" contributes two flaps; the table appends a third.
        assert_eq!(spec.flaps.len(), 3);
        assert_eq!(spec.flaps[2].at, Micros(30_000));
        assert_eq!(spec.stragglers.len(), 1);
        assert_eq!(spec.stragglers[0].from_iter, 3);
        assert_eq!(spec.membership.len(), 1);
        assert_eq!(spec.membership[0].workers, 8);

        // An empty table means "run healthy".
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.fault_spec(&cfg.env()).unwrap(), None);

        // Unknown scenario names and nonsense ranges are rejected early.
        assert!(ExperimentConfig::from_toml("[faults]\nscenario = \"meteor\"\n").is_err());
        assert!(ExperimentConfig::from_toml("[faults]\nstraggler_factor = 0.5\n").is_err());
        assert!(ExperimentConfig::from_toml("[faults]\nelastic_workers = 1\n").is_err());
        // Unknown flap links surface when the spec is resolved.
        let cfg = ExperimentConfig::from_toml("[faults]\nflap_link = \"warp\"\n").unwrap();
        assert!(cfg.fault_spec(&cfg.env()).is_err());

        // The extra straggler carries its rank; out-of-cluster ranks are
        // rejected up front (slowest-rank rule — docs/faults.md).
        let cfg = ExperimentConfig::from_toml(
            "[faults]\nstraggler_factor = 1.5\nstraggler_rank = 3\n",
        )
        .unwrap();
        let spec = cfg.fault_spec(&cfg.env()).unwrap().expect("declared");
        assert_eq!(spec.stragglers[0].rank, 3);
        assert!(ExperimentConfig::from_toml(
            "[faults]\nstraggler_factor = 1.5\nstraggler_rank = 99\n"
        )
        .is_err());
    }

    #[test]
    fn sweep_table_is_validated() {
        let cfg = ExperimentConfig::from_toml(
            "[sweep]\nworkloads = \"vgg19,gpt2\"\npresets = \"paper-2link\"\nthreads = 2\n",
        )
        .unwrap();
        assert_eq!(cfg.sweep_workloads, "vgg19,gpt2");
        assert_eq!(cfg.sweep_presets, "paper-2link");
        assert_eq!(cfg.sweep_threads, 2);
        // Defaults describe the full acceptance grid.
        let d = ExperimentConfig::default();
        assert_eq!(d.sweep_workloads, "resnet101,vgg19,gpt2,llama2");
        assert_eq!(d.sweep_ranks_per_node, "1,8");
        // Every axis item is validated against its registry.
        assert!(ExperimentConfig::from_toml("[sweep]\nworkloads = \"warpnet\"\n").is_err());
        assert!(ExperimentConfig::from_toml("[sweep]\npresets = \"warp\"\n").is_err());
        assert!(ExperimentConfig::from_toml("[sweep]\nranks_per_node = \"3\"\n").is_err());
        assert!(ExperimentConfig::from_toml("[sweep]\ncodecs = \"zfp\"\n").is_err());
        assert!(ExperimentConfig::from_toml("[sweep]\ncontention = \"freeway\"\n").is_err());
        assert!(ExperimentConfig::from_toml("[sweep]\nfaults = \"meteor\"\n").is_err());
        assert!(ExperimentConfig::from_toml("[sweep]\nthreads = 0\n").is_err());
        assert!(ExperimentConfig::from_toml("[sweep]\nworkloads = \",\"\n").is_err());
    }

    #[test]
    fn replan_table_round_trips() {
        // Defaults: the loop is closed only on request, and the config
        // builder mirrors ReplanOptions::default() exactly.
        let d = ExperimentConfig::default();
        assert_eq!(d.replan_options(), crate::sched::ReplanOptions::default());
        assert!(!d.faults_drift_low_side);

        let cfg = ExperimentConfig::from_toml(
            "[replan]\nenabled = true\nmin_excess_ppm = 50000\nmax_retries = 4\n\n\
             [faults]\ndrift_band = 0.25\ndrift_low_side = true\n",
        )
        .unwrap();
        let opts = cfg.replan_options();
        assert!(opts.enabled);
        assert_eq!(opts.min_excess_ppm, 50_000);
        assert_eq!(opts.max_retries, 4);
        let spec = cfg.fault_spec(&cfg.env()).unwrap().expect("monitor on");
        assert!(spec.drift_low_side);
        assert!((spec.drift_band - 0.25).abs() < 1e-12);
        // Low-side alarms are strictly opt-in: the table key is the only
        // way to flip them on.
        let cfg = ExperimentConfig::from_toml("[faults]\ndrift_band = 0.25\n").unwrap();
        let spec = cfg.fault_spec(&cfg.env()).unwrap().expect("monitor on");
        assert!(!spec.drift_low_side);
    }

    #[test]
    fn overrides_apply() {
        let mut cfg = ExperimentConfig::default();
        let mut ov = BTreeMap::new();
        ov.insert("workers".to_string(), "4".to_string());
        ov.insert("scheme".to_string(), "us-byte".to_string());
        cfg.apply_overrides(&ov).unwrap();
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.scheme, Scheme::UsByte);
    }

    #[test]
    fn strategy_matches_scheme() {
        let mut cfg = ExperimentConfig::default();
        cfg.scheme = Scheme::PytorchDdp;
        assert!(matches!(cfg.strategy(), Strategy::DdpFixed { .. }));
        cfg.scheme = Scheme::Bytescheduler;
        assert!(matches!(cfg.strategy(), Strategy::Uniform { .. }));
        cfg.scheme = Scheme::Deft;
        assert!(matches!(cfg.strategy(), Strategy::DeftConstrained { .. }));
    }

    #[test]
    fn env_reflects_cluster_settings() {
        use crate::links::LinkId;
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 4;
        cfg.bandwidth_gbps = 10.0;
        cfg.multi_link = false;
        let env = cfg.env();
        assert_eq!(env.workers, 4);
        assert!((env.bandwidth_gbps - 10.0).abs() < 1e-12);
        // multi_link = false collapses the pair onto one NIC: the slow
        // link now pays contention.
        assert!(env.contended(LinkId(1)));
        assert!(!env.contended(LinkId(0)));
        // And the legacy μ knob retunes the slow link.
        cfg.mu = 2.0;
        assert!((cfg.env().links[1].mu - 2.0).abs() < 1e-12);
    }

    #[test]
    fn links_preset_key_selects_topology() {
        let cfg = ExperimentConfig::from_toml(
            "[cluster]\nlinks_preset = \"nvlink-ib-tcp\"\n",
        )
        .unwrap();
        let env = cfg.env();
        assert_eq!(env.n_links(), 3);
        assert_eq!(
            env.link_names(),
            vec!["nvlink".to_string(), "ib".to_string(), "tcp".to_string()]
        );
        assert!(
            ExperimentConfig::from_toml("links_preset = \"warp-drive\"\n").is_err(),
            "unknown preset must be rejected"
        );
    }

    #[test]
    fn custom_links_array_overrides_preset() {
        let text = r#"
[[links]]
name = "nccl"
mu = 1.0
alpha_us = 250

[[links]]
name = "roce"
mu = 2.0
bandwidth_gbps = 20.0
contention_group = 1
staging_ramp = 0.05
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.custom_links.len(), 2);
        let env = cfg.env();
        assert_eq!(env.n_links(), 2);
        assert_eq!(env.link_names(), vec!["nccl".to_string(), "roce".to_string()]);
        assert_eq!(env.links[0].alpha, Micros(250));
        assert!((env.links[1].mu - 2.0).abs() < 1e-12);
        assert!((env.links[1].staging_ramp - 0.05).abs() < 1e-12);

        // Reference link must have μ = 1.
        let bad = "[[links]]\nname = \"slow\"\nmu = 2.0\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        // Unknown link fields are rejected.
        let bad2 = "[[links]]\nname = \"x\"\ncolour = \"red\"\n";
        assert!(ExperimentConfig::from_toml(bad2).is_err());
        // Every custom link must be explicitly named: a stray partial
        // override must not silently replace the preset topology.
        let mut cfg = ExperimentConfig::default();
        let mut ov = BTreeMap::new();
        ov.insert("links.1.mu".to_string(), "2.0".to_string());
        assert!(cfg.apply_overrides(&ov).is_err());
        // Duplicate names are ambiguous for the name-keyed registry.
        let dup = "[[links]]\nname = \"nccl\"\nmu = 1.0\n[[links]]\nname = \"nccl\"\nmu = 2.0\n";
        assert!(ExperimentConfig::from_toml(dup).is_err());
    }

    #[test]
    fn topology_table_builds_hierarchical_env() {
        use crate::links::{LinkId, Topology};
        let cfg = ExperimentConfig::from_toml(
            "[cluster]\nlinks_preset = \"nvlink-ib-tcp\"\nworkers = 16\n\
             [topology]\nranks_per_node = 8\nintra = \"nvlink\"\ninter = \"ib\"\n",
        )
        .unwrap();
        let env = cfg.env();
        assert_eq!(
            env.topology,
            Topology::Hierarchical {
                ranks_per_node: 8,
                intra: LinkId(0),
                inter: LinkId(1),
            }
        );
        // The path factor of the fabric drops below its raw μ: most
        // traffic moved onto the NVLink segment.
        assert!(env.path_mu(LinkId(1)) < env.spec(LinkId(1)).mu);
        // Default (no [topology] table) stays flat.
        let flat = ExperimentConfig::default().env();
        assert_eq!(flat.topology, Topology::Flat);
    }

    #[test]
    fn topology_table_is_validated() {
        // Unknown link name.
        assert!(ExperimentConfig::from_toml(
            "[topology]\nranks_per_node = 8\nintra = \"warp\"\n"
        )
        .is_err());
        // ranks_per_node must divide workers.
        assert!(ExperimentConfig::from_toml(
            "[cluster]\nworkers = 16\nlinks_preset = \"nvlink-ib-tcp\"\n\
             [topology]\nranks_per_node = 3\nintra = \"nvlink\"\ninter = \"ib\"\n"
        )
        .is_err());
        // Hierarchical needs an intra link.
        assert!(ExperimentConfig::from_toml("[topology]\nranks_per_node = 8\n").is_err());
        // intra and inter must be distinct (inter defaults to link 0).
        assert!(ExperimentConfig::from_toml(
            "[cluster]\nlinks_preset = \"nvlink-ib-tcp\"\n\
             [topology]\nranks_per_node = 8\nintra = \"nvlink\"\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml("[topology]\nranks_per_node = 0\n").is_err());
    }

    #[test]
    fn links_codec_key_attaches_a_codec() {
        use crate::links::Codec;
        let text = r#"
[[links]]
name = "nccl"
mu = 1.0

[[links]]
name = "tcp"
mu = 6.0
codec = "fp16"
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        let env = cfg.env();
        assert_eq!(env.links[0].codec, Codec::Raw);
        assert_eq!(env.links[1].codec, Codec::Fp16);
        // Codec-effective μ follows (§III.D / knapsack capacities).
        assert!((env.path_mu(crate::links::LinkId(1)) - 3.0).abs() < 1e-12);

        let rank = "[[links]]\nname = \"n\"\nmu = 1.0\ncodec = \"rank4\"\n";
        let cfg = ExperimentConfig::from_toml(rank).unwrap();
        assert_eq!(cfg.env().links[0].codec, Codec::RankK { k: 4 });
        // Unknown codec names are rejected.
        let bad = "[[links]]\nname = \"n\"\nmu = 1.0\ncodec = \"zfp\"\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
    }

    #[test]
    fn topology_codec_compresses_the_inter_fabric() {
        use crate::links::Codec;
        let cfg = ExperimentConfig::from_toml(
            "[cluster]\nlinks_preset = \"nvlink-ib-tcp\"\nworkers = 16\n\
             [topology]\nranks_per_node = 8\nintra = \"nvlink\"\ninter = \"ib\"\n\
             codec = \"fp16\"\n",
        )
        .unwrap();
        let env = cfg.env();
        assert_eq!(env.links[1].codec, Codec::Fp16, "inter fabric carries the codec");
        assert_eq!(env.links[0].codec, Codec::Raw);
        // The fabric's path factor shrinks further than codec-free.
        let free = ExperimentConfig::from_toml(
            "[cluster]\nlinks_preset = \"nvlink-ib-tcp\"\nworkers = 16\n\
             [topology]\nranks_per_node = 8\nintra = \"nvlink\"\ninter = \"ib\"\n",
        )
        .unwrap()
        .env();
        assert!(env.path_mu(LinkId(1)) < free.path_mu(LinkId(1)));
        // topology.codec needs a hierarchical topology and a known name.
        assert!(ExperimentConfig::from_toml("[topology]\ncodec = \"fp16\"\n").is_err());
        assert!(ExperimentConfig::from_toml(
            "[cluster]\nlinks_preset = \"nvlink-ib-tcp\"\nworkers = 16\n\
             [topology]\nranks_per_node = 8\nintra = \"nvlink\"\ninter = \"ib\"\n\
             codec = \"zfp\"\n"
        )
        .is_err());
    }

    #[test]
    fn contention_model_key_selects_the_pricing_model() {
        use crate::links::ContentionModel;
        // Default: aggregate k-way sharing.
        assert_eq!(
            ExperimentConfig::default().env().contention,
            ContentionModel::Kway
        );
        let cfg =
            ExperimentConfig::from_toml("[contention]\nmodel = \"pairwise\"\n").unwrap();
        assert_eq!(cfg.env().contention, ContentionModel::Pairwise);
        // Bare-key override form.
        let mut cfg = ExperimentConfig::default();
        let mut ov = BTreeMap::new();
        ov.insert("contention_model".to_string(), "pairwise".to_string());
        cfg.apply_overrides(&ov).unwrap();
        assert_eq!(cfg.env().contention, ContentionModel::Pairwise);
        // Unknown models are rejected.
        assert!(ExperimentConfig::from_toml("[contention]\nmodel = \"freeway\"\n").is_err());
    }

    #[test]
    fn legacy_knobs_do_not_touch_wider_presets() {
        // multi_link/mu are 2-link legacy knobs; a 3-link preset must
        // keep its contention groups and μs even if they are set.
        let mut cfg = ExperimentConfig::default();
        cfg.links_preset = "nvlink-ib-tcp".into();
        cfg.multi_link = false;
        cfg.mu = 9.0;
        let env = cfg.env();
        use crate::links::{LinkId, LinkPreset};
        assert_eq!(env.links, LinkPreset::NvlinkIbTcp.links());
        assert!(!env.contended(LinkId(1)));
    }
}
