//! A small TOML-subset parser (the offline build has no `toml`/`serde`).
//!
//! Supported: `[section]` headers, `[[section]]` array-of-tables headers,
//! `key = value` pairs, `#` comments, string / bool / integer / float
//! scalars. Sections flatten to dot-joined keys (`[cluster] workers = 8`
//! → `cluster.workers`); array-of-tables entries gain a running index
//! (the second `[[links]]` block flattens to `links.1.<key>`).
//!
//! Defining the same flattened key twice is a parse error (consistent
//! with the duplicate-link-name rejection in the typed config layer):
//! silently letting the last definition win hides typos and merge
//! accidents. Repeated `[[section]]` blocks are fine — each gets a fresh
//! index.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

/// A scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
}

impl Value {
    pub fn as_str(&self) -> Result<&str, String> {
        match self {
            Value::Str(s) => Ok(s),
            v => Err(format!("expected string, got {v:?}")),
        }
    }

    pub fn as_bool(&self) -> Result<bool, String> {
        match self {
            Value::Bool(b) => Ok(*b),
            v => Err(format!("expected bool, got {v:?}")),
        }
    }

    pub fn as_int(&self) -> Result<i64, String> {
        match self {
            Value::Int(i) => Ok(*i),
            v => Err(format!("expected integer, got {v:?}")),
        }
    }

    /// Ints coerce to floats; floats stay floats.
    pub fn as_float(&self) -> Result<f64, String> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            v => Err(format!("expected float, got {v:?}")),
        }
    }

    /// Render back to TOML syntax.
    pub fn render(&self) -> String {
        match self {
            Value::Str(s) => format!("\"{s}\""),
            Value::Bool(b) => b.to_string(),
            Value::Int(i) => i.to_string(),
            Value::Float(f) => format!("{f}"),
        }
    }

    /// Parse a scalar token: quoted string, bool, int, float — falling
    /// back to a bare string (used by CLI overrides).
    pub fn parse_scalar(raw: &str) -> Value {
        let t = raw.trim();
        if let Some(stripped) = t.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
            return Value::Str(stripped.to_string());
        }
        match t {
            "true" => return Value::Bool(true),
            "false" => return Value::Bool(false),
            _ => {}
        }
        if let Ok(i) = t.replace('_', "").parse::<i64>() {
            return Value::Int(i);
        }
        if let Ok(f) = t.replace('_', "").parse::<f64>() {
            return Value::Float(f);
        }
        Value::Str(t.to_string())
    }
}

/// Parse error with line information.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// A parsed document.
#[derive(Debug, Clone, Default)]
pub struct Document {
    entries: Vec<(String, Value)>,
}

impl Document {
    /// All keys flattened to `section.key` form, in file order.
    pub fn flatten(&self) -> Vec<(String, Value)> {
        self.entries.clone()
    }

    /// Lookup a flattened key.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v)
    }

    /// Keys as a map (the parser rejects duplicates, so this is lossless).
    pub fn as_map(&self) -> BTreeMap<String, Value> {
        self.entries.iter().cloned().collect()
    }
}

/// Parse TOML-subset text.
pub fn parse(text: &str) -> Result<Document, ParseError> {
    let mut doc = Document::default();
    let mut section = String::new();
    let mut array_counts: BTreeMap<String, usize> = BTreeMap::new();
    let mut seen_keys: BTreeSet<String> = BTreeSet::new();
    let valid_name = |name: &str| {
        !name.is_empty()
            && name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.' || c == '-')
    };
    for (ln, raw) in text.lines().enumerate() {
        let line_no = ln + 1;
        // Strip comments outside quotes.
        let line = strip_comment(raw);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("[[") {
            let name = rest.strip_suffix("]]").ok_or_else(|| ParseError {
                line: line_no,
                message: "unterminated array-of-tables header".into(),
            })?;
            let name = name.trim();
            if !valid_name(name) {
                return Err(ParseError {
                    line: line_no,
                    message: format!("invalid array-of-tables name `{name}`"),
                });
            }
            let idx = array_counts.entry(name.to_string()).or_insert(0);
            section = format!("{name}.{idx}");
            *idx += 1;
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| ParseError {
                line: line_no,
                message: "unterminated section header".into(),
            })?;
            let name = name.trim();
            if name.is_empty()
                || !name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.' || c == '-')
            {
                return Err(ParseError {
                    line: line_no,
                    message: format!("invalid section name `{name}`"),
                });
            }
            section = name.to_string();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| ParseError {
            line: line_no,
            message: "expected `key = value`".into(),
        })?;
        let key = line[..eq].trim();
        let raw_val = line[eq + 1..].trim();
        if key.is_empty()
            || !key
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.')
        {
            return Err(ParseError {
                line: line_no,
                message: format!("invalid key `{key}`"),
            });
        }
        if raw_val.is_empty() {
            return Err(ParseError {
                line: line_no,
                message: format!("missing value for `{key}`"),
            });
        }
        let value = parse_value(raw_val).map_err(|m| ParseError {
            line: line_no,
            message: m,
        })?;
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        if !seen_keys.insert(full.clone()) {
            return Err(ParseError {
                line: line_no,
                message: format!("duplicate key `{full}`"),
            });
        }
        doc.entries.push((full, value));
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(raw: &str) -> Result<Value, String> {
    if let Some(stripped) = raw.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string {raw}"))?;
        if inner.contains('"') {
            return Err(format!("embedded quote in {raw}"));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    match raw {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let clean = raw.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        if f.is_finite() {
            return Ok(Value::Float(f));
        }
    }
    Err(format!("unparseable value `{raw}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = parse(
            r#"
top = 1
[a]
s = "hello"   # trailing comment
flag = true
f = 2.5
big = 6_500_000
[b.c]
x = -3
"#,
        )
        .unwrap();
        assert_eq!(doc.get("top"), Some(&Value::Int(1)));
        assert_eq!(doc.get("a.s"), Some(&Value::Str("hello".into())));
        assert_eq!(doc.get("a.flag"), Some(&Value::Bool(true)));
        assert_eq!(doc.get("a.f"), Some(&Value::Float(2.5)));
        assert_eq!(doc.get("a.big"), Some(&Value::Int(6_500_000)));
        assert_eq!(doc.get("b.c.x"), Some(&Value::Int(-3)));
    }

    #[test]
    fn array_of_tables_gains_running_index() {
        let doc = parse(
            r#"
[[links]]
name = "nccl"
mu = 1.0
[[links]]
name = "gloo"
mu = 1.65
[cluster]
workers = 8
[[links]]
name = "tcp"
"#,
        )
        .unwrap();
        assert_eq!(doc.get("links.0.name"), Some(&Value::Str("nccl".into())));
        assert_eq!(doc.get("links.1.name"), Some(&Value::Str("gloo".into())));
        assert_eq!(doc.get("links.1.mu"), Some(&Value::Float(1.65)));
        assert_eq!(doc.get("links.2.name"), Some(&Value::Str("tcp".into())));
        assert_eq!(doc.get("cluster.workers"), Some(&Value::Int(8)));
        let err = parse("[[broken\n").unwrap_err();
        assert_eq!(err.line, 1);
        assert!(parse("[[ ]]\n").is_err());
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let doc = parse("# a comment\n\n  \nx = 1 # inline\n").unwrap();
        assert_eq!(doc.flatten().len(), 1);
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = parse("s = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("s"), Some(&Value::Str("a#b".into())));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = parse("[unterminated\n").unwrap_err();
        assert_eq!(err.line, 1);
        let err = parse("x = \n").unwrap_err();
        assert_eq!(err.line, 1);
        let err = parse("x = \"open\n").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn duplicate_keys_are_rejected() {
        let err = parse("x = 1\nx = 2\n").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(err.message.contains("duplicate key `x`"), "{}", err.message);
        // Dotted collisions across section syntaxes are duplicates too.
        let err = parse("[a]\nb = 1\n[a]\nb = 2\n").unwrap_err();
        assert_eq!(err.line, 4);
        // Array-of-tables blocks index independently — no false positive.
        let doc = parse("[[links]]\nmu = 1.0\n[[links]]\nmu = 2.0\n").unwrap();
        assert_eq!(doc.get("links.0.mu"), Some(&Value::Float(1.0)));
        assert_eq!(doc.get("links.1.mu"), Some(&Value::Float(2.0)));
        // But a duplicate inside one block is caught.
        assert!(parse("[[links]]\nmu = 1.0\nmu = 2.0\n").is_err());
    }

    #[test]
    fn scalar_parse_fallbacks() {
        assert_eq!(Value::parse_scalar("8"), Value::Int(8));
        assert_eq!(Value::parse_scalar("8.5"), Value::Float(8.5));
        assert_eq!(Value::parse_scalar("true"), Value::Bool(true));
        assert_eq!(Value::parse_scalar("us-byte"), Value::Str("us-byte".into()));
        assert_eq!(Value::parse_scalar("\"q\""), Value::Str("q".into()));
    }

    #[test]
    fn int_float_coercion() {
        assert_eq!(Value::Int(3).as_float().unwrap(), 3.0);
        assert!(Value::Str("x".into()).as_int().is_err());
        assert!(Value::Bool(true).as_str().is_err());
    }
}
