//! PJRT runtime — loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python never runs at training time: `make artifacts` lowers the L2 JAX
//! model (with its L1 Pallas kernels inlined via `interpret=True`) to
//! **HLO text**, and this module compiles + executes it via the `xla`
//! crate's PJRT CPU client. See `/opt/xla-example/README.md` for why text
//! (not serialized protos) is the interchange format.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Executable, HostTensor};
pub use manifest::{ArtifactManifest, TensorSpec};
