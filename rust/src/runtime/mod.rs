//! PJRT runtime — loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python never runs at training time: `make artifacts` lowers the L2 JAX
//! model (with its L1 Pallas kernels inlined via `interpret=True`) to
//! **HLO text**, and this module compiles + executes it via the `xla`
//! crate's PJRT CPU client. See `/opt/xla-example/README.md` for why text
//! (not serialized protos) is the interchange format.
//!
//! The `xla` crate is not part of the offline image, so real execution is
//! gated behind the **`pjrt` cargo feature**. The default build ships an
//! API-compatible stub whose constructors return descriptive errors;
//! every artifact-dependent test and bench self-skips when
//! `artifacts/manifest.toml` is absent, keeping a bare
//! `cargo build && cargo test` green.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Executable, HostTensor};
pub use manifest::{ArtifactManifest, TensorSpec};
