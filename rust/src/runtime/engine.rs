//! The PJRT engine: compile HLO-text artifacts once, execute many times.
//!
//! Pattern follows `/opt/xla-example/load_hlo/`: text → `HloModuleProto`
//! → `XlaComputation` → `PjRtLoadedExecutable`. Outputs are 1-tuples
//! (jax lowering uses `return_tuple=True`) that decompose into the
//! manifest's declared outputs.
//!
//! The real engine needs the `xla` crate, which the offline image does
//! not ship; it is gated behind the `pjrt` cargo feature. Without the
//! feature, [`Engine`]/[`Executable`] present the same API but
//! construction fails with a descriptive error, so every caller that
//! self-skips on missing artifacts keeps working on a bare checkout.

use super::manifest::{DType, TensorSpec};
use crate::bail;
use crate::util::error::Result;

/// A host-side tensor travelling in/out of executables.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(_) => DType::F32,
            HostTensor::I32(_) => DType::I32,
        }
    }

    /// Check this tensor against a manifest spec (dtype + element count).
    fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!(
                "input `{}` dtype {} != provided {}",
                spec.name,
                spec.dtype.name(),
                self.dtype().name()
            );
        }
        if self.len() != spec.elements() {
            bail!(
                "input `{}` wants {} elements, got {}",
                spec.name,
                spec.elements(),
                self.len()
            );
        }
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
mod imp {
    use std::path::Path;

    use super::super::manifest::{DType, ExeSpec, TensorSpec};
    use super::HostTensor;
    use crate::bail;
    use crate::util::error::{Context, Result};

    /// A compiled executable plus its signature.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub spec: ExeSpec,
    }

    /// The PJRT engine owning the client and compiled executables.
    pub struct Engine {
        client: xla::PjRtClient,
    }

    fn to_literal(t: &HostTensor, spec: &TensorSpec) -> Result<xla::Literal> {
        t.check(spec)?;
        let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
        let lit = match t {
            HostTensor::F32(v) => xla::Literal::vec1(v),
            HostTensor::I32(v) => xla::Literal::vec1(v),
        };
        // Scalars and vectors need no reshape when dims match vec1.
        if spec.dims.len() == 1 {
            Ok(lit)
        } else {
            lit.reshape(&dims)
                .with_context(|| format!("reshaping input `{}`", spec.name))
        }
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
        let t = match spec.dtype {
            DType::F32 => HostTensor::F32(lit.to_vec::<f32>().context("literal to f32")?),
            DType::I32 => HostTensor::I32(lit.to_vec::<i32>().context("literal to i32")?),
        };
        if t.len() != spec.elements() {
            bail!(
                "output `{}` expected {} elements, got {}",
                spec.name,
                spec.elements(),
                t.len()
            );
        }
        Ok(t)
    }

    impl Engine {
        /// Create a CPU PJRT engine.
        pub fn cpu() -> Result<Engine> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Engine { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile one HLO-text artifact.
        pub fn load(&self, spec: &ExeSpec) -> Result<Executable> {
            let path: &Path = &spec.file;
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(Executable {
                exe,
                spec: spec.clone(),
            })
        }
    }

    impl Executable {
        /// Execute with host tensors; returns outputs in manifest order.
        pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            if inputs.len() != self.spec.inputs.len() {
                bail!(
                    "exe `{}` wants {} inputs, got {}",
                    self.spec.name,
                    self.spec.inputs.len(),
                    inputs.len()
                );
            }
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .zip(&self.spec.inputs)
                .map(|(t, s)| to_literal(t, s))
                .collect::<Result<_>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing `{}`", self.spec.name))?;
            let root = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            // jax lowers with return_tuple=True: the root is a tuple of
            // the declared outputs.
            let parts = root.to_tuple().context("decomposing result tuple")?;
            if parts.len() != self.spec.outputs.len() {
                bail!(
                    "exe `{}` returned {} outputs, manifest says {}",
                    self.spec.name,
                    parts.len(),
                    self.spec.outputs.len()
                );
            }
            parts
                .iter()
                .zip(&self.spec.outputs)
                .map(|(lit, spec)| from_literal(lit, spec))
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::super::manifest::ExeSpec;
    use super::HostTensor;
    use crate::bail;
    use crate::util::error::Result;

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: built without the `pjrt` feature (vendor the `xla` \
         crate and enable it to execute HLO artifacts)";

    /// Stub executable: carries the signature, cannot run.
    pub struct Executable {
        pub spec: ExeSpec,
    }

    /// Stub engine: same API as the real one, constructors fail.
    pub struct Engine {
        _priv: (),
    }

    impl Engine {
        pub fn cpu() -> Result<Engine> {
            bail!("{UNAVAILABLE}")
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load(&self, _spec: &ExeSpec) -> Result<Executable> {
            bail!("{UNAVAILABLE}")
        }
    }

    impl Executable {
        pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            // Validate the signature anyway so misuse surfaces first.
            for (t, s) in inputs.iter().zip(&self.spec.inputs) {
                t.check(s)?;
            }
            bail!("{UNAVAILABLE}")
        }
    }
}

pub use imp::{Engine, Executable};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checks() {
        let spec = TensorSpec {
            name: "x".into(),
            dtype: DType::F32,
            dims: vec![2, 2],
        };
        assert!(HostTensor::F32(vec![1.0; 4]).check(&spec).is_ok());
        assert!(HostTensor::F32(vec![1.0; 3]).check(&spec).is_err());
        assert!(HostTensor::I32(vec![1; 4]).check(&spec).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_reports_unavailable() {
        let e = Engine::cpu().unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }

    // Engine-level integration tests live in rust/tests/runtime_e2e.rs —
    // they need the artifacts built by `make artifacts` and the `pjrt`
    // feature.
}
