//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.
//!
//! The manifest (`artifacts/manifest.toml`, TOML-subset) records, per
//! executable, the HLO file and the ordered input/output tensor specs so
//! the runtime can allocate and check buffers without Python present.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::config::toml_lite;
use crate::util::error::{Context, Result};
use crate::{bail, err};

/// Element type of a tensor (the subset our models use).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype `{other}`"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }
}

/// One tensor in an executable's signature.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }

    /// Parse `name:dtype:AxBxC` (scalar = `name:dtype:1`).
    pub fn parse(s: &str) -> Result<TensorSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() != 3 {
            bail!("bad tensor spec `{s}` (want name:dtype:dims)");
        }
        let dims: Vec<usize> = if parts[2].is_empty() {
            vec![]
        } else {
            parts[2]
                .split('x')
                .map(|d| d.parse::<usize>().map_err(|e| err!("dim `{d}`: {e}")))
                .collect::<Result<Vec<usize>>>()?
        };
        Ok(TensorSpec {
            name: parts[0].to_string(),
            dtype: DType::parse(parts[1])?,
            dims,
        })
    }
}

/// One executable entry.
#[derive(Clone, Debug)]
pub struct ExeSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    /// Model metadata (free-form key → string).
    pub meta: BTreeMap<String, String>,
    pub exes: BTreeMap<String, ExeSpec>,
    /// Directory the manifest was loaded from (file paths are relative).
    pub dir: PathBuf,
}

impl ArtifactManifest {
    pub fn load(path: &Path) -> Result<ArtifactManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let dir = path
            .parent()
            .map(|p| p.to_path_buf())
            .unwrap_or_else(|| PathBuf::from("."));
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<ArtifactManifest> {
        let doc = toml_lite::parse(text).map_err(|e| err!("{e}"))?;
        let mut meta = BTreeMap::new();
        let mut raw: BTreeMap<String, BTreeMap<String, String>> = BTreeMap::new();
        for (key, value) in doc.flatten() {
            let sval = match &value {
                toml_lite::Value::Str(s) => s.clone(),
                v => v.render(),
            };
            if let Some(rest) = key.strip_prefix("meta.") {
                meta.insert(rest.to_string(), sval);
            } else if let Some(rest) = key.strip_prefix("exe.") {
                let (exe, field) = rest
                    .rsplit_once('.')
                    .ok_or_else(|| err!("bad exe key `{key}`"))?;
                raw.entry(exe.to_string())
                    .or_default()
                    .insert(field.to_string(), sval);
            } else {
                bail!("unknown manifest key `{key}`");
            }
        }
        let mut exes = BTreeMap::new();
        for (name, fields) in raw {
            let file = fields
                .get("file")
                .ok_or_else(|| err!("exe `{name}` missing file"))?;
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                fields
                    .get(key)
                    .ok_or_else(|| err!("exe `{name}` missing {key}"))?
                    .split(';')
                    .filter(|s| !s.is_empty())
                    .map(TensorSpec::parse)
                    .collect()
            };
            exes.insert(
                name.clone(),
                ExeSpec {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }
        Ok(ArtifactManifest { meta, exes, dir })
    }

    pub fn exe(&self, name: &str) -> Result<&ExeSpec> {
        self.exes
            .get(name)
            .ok_or_else(|| err!("manifest has no executable `{name}`"))
    }

    /// Integer metadata accessor.
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .ok_or_else(|| err!("manifest missing meta.{key}"))?
            .parse::<usize>()
            .with_context(|| format!("meta.{key} not an integer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
[meta]
model = "small_transformer"
n_buckets = 3
vocab = 512

[exe.train_step]
file = "train_step.hlo.txt"
inputs = "b0:f32:100;b1:f32:200;tokens:i32:8x128"
outputs = "loss:f32:1;g0:f32:100;g1:f32:200"
"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.meta.get("model").unwrap(), "small_transformer");
        assert_eq!(m.meta_usize("n_buckets").unwrap(), 3);
        let e = m.exe("train_step").unwrap();
        assert_eq!(e.file, PathBuf::from("/tmp/a/train_step.hlo.txt"));
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[2].dims, vec![8, 128]);
        assert_eq!(e.inputs[2].dtype, DType::I32);
        assert_eq!(e.outputs[0].elements(), 1);
    }

    #[test]
    fn tensor_spec_parsing() {
        let t = TensorSpec::parse("x:f32:4x5x6").unwrap();
        assert_eq!(t.elements(), 120);
        assert!(TensorSpec::parse("bad").is_err());
        assert!(TensorSpec::parse("x:f64:1").is_err());
        assert!(TensorSpec::parse("x:f32:ax2").is_err());
    }

    #[test]
    fn missing_fields_error() {
        let text = "[exe.x]\nfile = \"x.hlo\"\ninputs = \"a:f32:1\"\n";
        assert!(ArtifactManifest::parse(text, PathBuf::new()).is_err());
        let text2 = "[bogus]\nk = 1\n";
        assert!(ArtifactManifest::parse(text2, PathBuf::new()).is_err());
        let m = ArtifactManifest::parse("[meta]\nx = \"1\"\n", PathBuf::new()).unwrap();
        assert!(m.exe("none").is_err());
    }
}
