//! Small self-contained utilities shared by every layer of the crate.
//!
//! Nothing here depends on the rest of the crate. Because this build is
//! fully offline (no `rand`, no `proptest`, no `serde`), this module owns
//! the substrates those crates would normally provide:
//!
//! * [`time`] — fixed-point microsecond arithmetic ([`time::Micros`]); all
//!   scheduling and simulation math uses integer microseconds so that
//!   discrete-event ordering is exactly deterministic.
//! * [`rng`] — splitmix64 / xoshiro256++ deterministic PRNGs.
//! * [`stats`] — mean/median/percentile/stddev helpers for benches.
//! * [`prop`] — a miniature property-based-testing harness (seeded cases,
//!   integer/vec generators, shrinking) used by the test suite.
//! * [`mathx`] — erf/Φ (normal CDF) needed by the Preserver's
//!   Gaussian-walk quantifier.
//! * [`error`] — a string-backed error/context substrate (no `anyhow`)
//!   used by the runtime and trainer layers.
//! * [`json`] — a minimal JSON reader/writer (no `serde`) shared by the
//!   bench trajectory files, the sweep JSONL stream, and the planning
//!   server's query protocol.

pub mod time;
pub mod rng;
pub mod stats;
pub mod prop;
pub mod mathx;
pub mod error;
pub mod json;

pub use rng::Rng;
pub use time::Micros;
