//! Miniature property-based testing harness.
//!
//! The offline build has no `proptest`, so the test suite uses this: a
//! seeded case runner with simple generators and greedy shrinking for the
//! two shapes our invariants need (integer vectors and "workload-like"
//! structured cases built from them).
//!
//! Usage (no_run: doctest binaries miss the xla rpath in this image):
//! ```no_run
//! use deft::util::prop::{check, Gen};
//! check("sum is order independent", 200, |g: &mut Gen| {
//!     let xs = g.vec_u64(0..=20, 0..=1_000);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     let a: u64 = xs.iter().sum();
//!     let b: u64 = ys.iter().sum();
//!     if a != b { return Err(format!("{a} != {b}")); }
//!     Ok(())
//! });
//! ```

use super::rng::Rng;
use std::ops::RangeInclusive;

/// A generation context handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Log of generated vectors, kept so the harness can shrink them.
    trace: Vec<Vec<u64>>,
    /// When replaying a shrunk case, pre-recorded values to return.
    replay: Option<Vec<Vec<u64>>>,
    replay_idx: usize,
}

impl Gen {
    /// Public constructor for reproducing specific property cases outside
    /// the harness (debugging helpers, examples).
    pub fn new_pub(seed: u64) -> Gen {
        Gen::new(seed)
    }

    fn new(seed: u64) -> Gen {
        Gen {
            rng: Rng::new(seed),
            trace: Vec::new(),
            replay: None,
            replay_idx: 0,
        }
    }

    fn replaying(values: Vec<Vec<u64>>) -> Gen {
        Gen {
            rng: Rng::new(0),
            trace: Vec::new(),
            replay: Some(values),
            replay_idx: 0,
        }
    }

    /// A random u64 in the inclusive range.
    pub fn u64_in(&mut self, range: RangeInclusive<u64>) -> u64 {
        let v = self.vec_u64(1..=1, range);
        v[0]
    }

    /// A random usize in the inclusive range.
    pub fn usize_in(&mut self, range: RangeInclusive<usize>) -> usize {
        self.u64_in(*range.start() as u64..=*range.end() as u64) as usize
    }

    /// A random f64 in `[lo, hi)` — derived from a u64 draw so it shrinks.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let raw = self.u64_in(0..=1_000_000);
        lo + (hi - lo) * (raw as f64 / 1_000_000.0)
    }

    /// A vector of u64s with random length in `len` and values in `vals`.
    ///
    /// This is the primitive every other generator is built from; the
    /// harness records it for shrinking (shorter vectors / smaller values).
    pub fn vec_u64(
        &mut self,
        len: RangeInclusive<usize>,
        vals: RangeInclusive<u64>,
    ) -> Vec<u64> {
        if let Some(replay) = &self.replay {
            let v = replay
                .get(self.replay_idx)
                .cloned()
                .unwrap_or_else(|| vec![*vals.start()]);
            self.replay_idx += 1;
            // Clamp replayed values into the requested range so shrinking
            // cannot push a value outside the property's domain.
            let v: Vec<u64> = v
                .into_iter()
                .map(|x| x.clamp(*vals.start(), *vals.end()))
                .collect();
            let lo = *len.start();
            let mut v = v;
            while v.len() < lo {
                v.push(*vals.start());
            }
            self.trace.push(v.clone());
            return v;
        }
        let n = self.rng.range(*len.start(), *len.end());
        let v: Vec<u64> = (0..n)
            .map(|_| self.rng.range_u64(*vals.start(), *vals.end()))
            .collect();
        self.trace.push(v.clone());
        v
    }
}

/// Outcome of a single case: `Ok(())` or a failure description.
pub type CaseResult = Result<(), String>;

/// Run `cases` random cases of `property`; on failure, greedily shrink the
/// generated vectors (drop elements, then halve values) and panic with the
/// smallest failing case found.
pub fn check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Gen) -> CaseResult,
{
    // Fixed base seed => reproducible CI; vary per case index.
    for case in 0..cases {
        let seed = 0xDEF7_0000_0000_0000 ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut gen = Gen::new(seed);
        if let Err(msg) = property(&mut gen) {
            let trace = gen.trace.clone();
            let (small, small_msg) = shrink(&mut property, trace, msg);
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}):\n  {small_msg}\n  minimal input: {small:?}"
            );
        }
    }
}

/// Greedy shrink: try removing each element of each vector, then halving
/// each value, re-running the property; keep any transformation that still
/// fails. Bounded to avoid quadratic blowups on big cases.
fn shrink<F>(
    property: &mut F,
    mut failing: Vec<Vec<u64>>,
    mut msg: String,
) -> (Vec<Vec<u64>>, String)
where
    F: FnMut(&mut Gen) -> CaseResult,
{
    let mut improved = true;
    let mut budget = 2_000usize;
    while improved && budget > 0 {
        improved = false;
        // Phase 1: try dropping single elements.
        'outer: for vi in 0..failing.len() {
            for ei in 0..failing[vi].len() {
                budget = budget.saturating_sub(1);
                if budget == 0 {
                    break 'outer;
                }
                let mut cand = failing.clone();
                cand[vi].remove(ei);
                let mut g = Gen::replaying(cand.clone());
                if let Err(m) = property(&mut g) {
                    failing = g.trace;
                    msg = m;
                    improved = true;
                    break 'outer;
                }
            }
        }
        if improved {
            continue;
        }
        // Phase 2: try halving values.
        'outer2: for vi in 0..failing.len() {
            for ei in 0..failing[vi].len() {
                budget = budget.saturating_sub(1);
                if budget == 0 {
                    break 'outer2;
                }
                if failing[vi][ei] == 0 {
                    continue;
                }
                let mut cand = failing.clone();
                cand[vi][ei] /= 2;
                let mut g = Gen::replaying(cand.clone());
                if let Err(m) = property(&mut g) {
                    failing = g.trace;
                    msg = m;
                    improved = true;
                    break 'outer2;
                }
            }
        }
    }
    (failing, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("tautology", 50, |g| {
            let _ = g.vec_u64(0..=5, 0..=10);
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    fn failing_property_shrinks() {
        // Property: no vector contains a value >= 8. Failing input should
        // shrink toward a single offending element.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check("has big value", 100, |g| {
                let xs = g.vec_u64(0..=10, 0..=20);
                if xs.iter().any(|&x| x >= 8) {
                    Err(format!("found big value in {xs:?}"))
                } else {
                    Ok(())
                }
            });
        }));
        let err = result.expect_err("property should fail");
        let text = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(text.contains("minimal input"), "panic message: {text}");
    }

    #[test]
    fn generators_respect_ranges() {
        check("ranges respected", 100, |g| {
            let n = g.usize_in(2..=6);
            if !(2..=6).contains(&n) {
                return Err(format!("usize {n} out of range"));
            }
            let f = g.f64_in(-1.0, 1.0);
            if !(-1.0..1.0000001).contains(&f) {
                return Err(format!("f64 {f} out of range"));
            }
            let v = g.vec_u64(3..=3, 5..=9);
            if v.len() != 3 || v.iter().any(|&x| !(5..=9).contains(&x)) {
                return Err(format!("vec {v:?} out of spec"));
            }
            Ok(())
        });
    }
}
