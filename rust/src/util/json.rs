//! Minimal JSON reader/writer for the offline build (no serde).
//!
//! Grown for `BENCH_*.json` trajectory files and now shared with the
//! sweep engine's JSONL result stream and the planning server's
//! line-delimited query protocol. Parses the full JSON value grammar
//! (objects, arrays, strings with escapes, numbers as f64, booleans,
//! null); object fields keep document order, and duplicate keys resolve
//! to the first occurrence via [`Json::get`].

use crate::util::error::Result;

/// Escape a string for embedding in a JSON document.
pub fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get<'a>(&'a self, key: &str) -> Option<&'a Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| crate::err!("unexpected end of JSON at byte {}", self.pos))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        let got = self.peek()?;
        if got != c {
            crate::bail!(
                "expected `{}` at byte {}, found `{}`",
                c as char,
                self.pos,
                got as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            crate::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|e| crate::err!("non-utf8 number: {e}"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| crate::err!("bad number `{s}` at byte {start}: {e}"))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(&b) = self.bytes.get(self.pos) else {
                crate::bail!("unterminated string at byte {}", self.pos);
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(&e) = self.bytes.get(self.pos) else {
                        crate::bail!("dangling escape at byte {}", self.pos);
                    };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or_else(|| crate::err!("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|e| crate::err!("bad \\u escape `{hex}`: {e}"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => crate::bail!("unknown escape `\\{}`", other as char),
                    }
                }
                b => {
                    // Re-join multi-byte UTF-8 sequences.
                    let start = self.pos - 1;
                    let len = match b {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|e| crate::err!("non-utf8 string: {e}"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => crate::bail!("expected `,` or `]` at byte {}, found `{}`", self.pos, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                c => crate::bail!("expected `,` or `}}` at byte {}, found `{}`", self.pos, c as char),
            }
        }
    }
}

/// Parse one complete JSON document; trailing non-whitespace is an error.
pub fn parse_json(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        crate::bail!("trailing data after JSON document at byte {}", p.pos);
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_value_grammar() {
        let doc = parse_json(
            r#"{"s": "a\"b", "n": -2.5e3, "b": true, "x": null, "a": [1, {"k": false}]}"#,
        )
        .unwrap();
        assert_eq!(doc.get("s").and_then(Json::as_str), Some("a\"b"));
        assert_eq!(doc.get("n").and_then(Json::as_f64), Some(-2500.0));
        assert_eq!(doc.get("b").and_then(Json::as_bool), Some(true));
        assert_eq!(doc.get("x"), Some(&Json::Null));
        let Some(Json::Arr(items)) = doc.get("a") else {
            panic!("array field");
        };
        assert_eq!(items[0].as_f64(), Some(1.0));
        assert_eq!(items[1].get("k").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn esc_round_trips_through_the_parser() {
        let nasty = "tabs\tquotes\" slashes\\ newlines\n control\u{1}";
        let doc = parse_json(&format!("{{\"k\": \"{}\"}}", esc(nasty))).unwrap();
        assert_eq!(doc.get("k").and_then(Json::as_str), Some(nasty));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_json("{").is_err());
        assert!(parse_json("[1, 2,]").is_err());
        assert!(parse_json("{\"a\": 1} trailing").is_err());
        assert!(parse_json("nope").is_err());
    }
}
