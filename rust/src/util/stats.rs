//! Summary statistics used by the bench harness and the metrics layer.

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation. Returns 0.0 for fewer than 2 samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// `q`-th quantile (0.0..=1.0) by linear interpolation on sorted data.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "quantile out of range");
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in quantile input"));
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Min of a non-empty slice (0.0 when empty).
pub fn min(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

/// Max of a non-empty slice (0.0 when empty).
pub fn max(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Geometric mean of positive values (0.0 when empty).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    assert!(xs.iter().all(|&x| x > 0.0), "geomean needs positive values");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&[1.0, 2.0, 100.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn quantiles_interpolate() {
        let xs = [0.0, 10.0];
        assert!((quantile(&xs, 0.25) - 2.5).abs() < 1e-12);
        assert!((quantile(&xs, 1.0) - 10.0).abs() < 1e-12);
        assert!((quantile(&xs, 0.0) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn min_max() {
        let xs = [3.0, -1.0, 2.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 3.0);
    }
}
