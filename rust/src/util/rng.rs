//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we implement the two standard
//! generators every simulator needs: **splitmix64** (seed expansion) and
//! **xoshiro256++** (the workhorse stream). Both are exactly reproducible
//! across platforms, which the test suite and the synthetic-trace
//! generator rely on.

/// splitmix64 step — used to expand a single u64 seed into a full state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ deterministic PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Lemire unbiased bounded generation.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform in `[lo, hi]` (inclusive) for usize.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal sample via Box–Muller (deterministic given state).
    pub fn gaussian(&mut self) -> f64 {
        // Draw until u1 is non-zero to keep ln() finite.
        let mut u1 = self.f64();
        while u1 <= f64::MIN_POSITIVE {
            u1 = self.f64();
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal sample with the given mean and standard deviation.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Pick a uniformly random element index of a non-empty slice.
    pub fn pick_index<T>(&mut self, v: &[T]) -> usize {
        assert!(!v.is_empty(), "pick from empty slice");
        self.below(v.len() as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4, "streams should be essentially disjoint");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let samples: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean} too far from 0");
        assert!((var - 1.0).abs() < 0.05, "var {var} too far from 1");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(9);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let x = r.range(3, 6);
            assert!((3..=6).contains(&x));
            lo_seen |= x == 3;
            hi_seen |= x == 6;
        }
        assert!(lo_seen && hi_seen);
    }
}
