//! Fixed-point time arithmetic for the scheduling / simulation path.
//!
//! All scheduler and simulator math uses integer **microseconds**. The
//! paper reports bucket times in µs (Table II) and iteration times in ms;
//! floating-point time would make discrete-event tie-breaking platform
//! dependent, so floats only appear at the presentation layer.

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Sub, SubAssign};

/// A non-negative duration or timestamp in microseconds.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Micros(pub u64);

impl Micros {
    pub const ZERO: Micros = Micros(0);
    pub const MAX: Micros = Micros(u64::MAX);

    /// Construct from milliseconds.
    pub const fn from_ms(ms: u64) -> Micros {
        Micros(ms * 1_000)
    }

    /// Construct from (possibly fractional) milliseconds.
    pub fn from_ms_f64(ms: f64) -> Micros {
        debug_assert!(ms >= 0.0, "negative duration");
        Micros((ms * 1_000.0).round() as u64)
    }

    /// Construct from seconds.
    pub const fn from_secs(s: u64) -> Micros {
        Micros(s * 1_000_000)
    }

    /// Construct from (possibly fractional) microseconds.
    pub fn from_us_f64(us: f64) -> Micros {
        debug_assert!(us >= 0.0, "negative duration");
        Micros(us.round() as u64)
    }

    pub fn as_us(self) -> u64 {
        self.0
    }

    pub fn as_ms_f64(self) -> f64 {
        self.0 as f64 / 1_000.0
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1_000_000.0
    }

    /// Saturating subtraction — durations never go negative.
    pub fn saturating_sub(self, rhs: Micros) -> Micros {
        Micros(self.0.saturating_sub(rhs.0))
    }

    /// Multiply by a non-negative float ratio (rounding to nearest µs).
    pub fn scale(self, ratio: f64) -> Micros {
        debug_assert!(ratio >= 0.0, "negative scale");
        Micros((self.0 as f64 * ratio).round() as u64)
    }

    /// Ratio of two durations as f64 (`self / other`).
    pub fn ratio(self, other: Micros) -> f64 {
        assert!(other.0 != 0, "ratio by zero duration");
        self.0 as f64 / other.0 as f64
    }

    pub fn max(self, other: Micros) -> Micros {
        Micros(self.0.max(other.0))
    }

    pub fn min(self, other: Micros) -> Micros {
        Micros(self.0.min(other.0))
    }

    pub fn is_zero(self) -> bool {
        self.0 == 0
    }
}

impl Add for Micros {
    type Output = Micros;
    fn add(self, rhs: Micros) -> Micros {
        Micros(self.0.checked_add(rhs.0).expect("Micros overflow"))
    }
}

impl AddAssign for Micros {
    fn add_assign(&mut self, rhs: Micros) {
        *self = *self + rhs;
    }
}

impl Sub for Micros {
    type Output = Micros;
    fn sub(self, rhs: Micros) -> Micros {
        Micros(self.0.checked_sub(rhs.0).expect("Micros underflow"))
    }
}

impl SubAssign for Micros {
    fn sub_assign(&mut self, rhs: Micros) {
        *self = *self - rhs;
    }
}

impl Mul<u64> for Micros {
    type Output = Micros;
    fn mul(self, rhs: u64) -> Micros {
        Micros(self.0.checked_mul(rhs).expect("Micros overflow"))
    }
}

impl Div<u64> for Micros {
    type Output = Micros;
    fn div(self, rhs: u64) -> Micros {
        Micros(self.0 / rhs)
    }
}

impl Sum for Micros {
    fn sum<I: Iterator<Item = Micros>>(iter: I) -> Micros {
        iter.fold(Micros::ZERO, |a, b| a + b)
    }
}

impl<'a> Sum<&'a Micros> for Micros {
    fn sum<I: Iterator<Item = &'a Micros>>(iter: I) -> Micros {
        iter.fold(Micros::ZERO, |a, b| a + *b)
    }
}

impl fmt::Debug for Micros {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}us", self.0)
    }
}

impl fmt::Display for Micros {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 1_000_000 {
            write!(f, "{:.3}s", self.as_secs_f64())
        } else if self.0 >= 1_000 {
            write!(f, "{:.3}ms", self.as_ms_f64())
        } else {
            write!(f, "{}us", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_roundtrips() {
        assert_eq!(Micros::from_ms(3).as_us(), 3_000);
        assert_eq!(Micros::from_secs(2).as_us(), 2_000_000);
        assert_eq!(Micros::from_ms_f64(1.5).as_us(), 1_500);
        assert_eq!(Micros::from_us_f64(12.4).as_us(), 12);
        assert!((Micros(2_500).as_ms_f64() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn arithmetic() {
        let a = Micros(100);
        let b = Micros(40);
        assert_eq!(a + b, Micros(140));
        assert_eq!(a - b, Micros(60));
        assert_eq!(a * 3, Micros(300));
        assert_eq!(a / 4, Micros(25));
        assert_eq!(b.saturating_sub(a), Micros::ZERO);
        assert_eq!(a.max(b), a);
        assert_eq!(a.min(b), b);
    }

    #[test]
    fn scale_and_ratio() {
        assert_eq!(Micros(100).scale(1.65), Micros(165));
        assert_eq!(Micros(100).scale(0.0), Micros::ZERO);
        assert!((Micros(150).ratio(Micros(100)) - 1.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn sub_underflow_panics() {
        let _ = Micros(1) - Micros(2);
    }

    #[test]
    fn sum_iterator() {
        let v = vec![Micros(1), Micros(2), Micros(3)];
        let s: Micros = v.iter().sum();
        assert_eq!(s, Micros(6));
        let s2: Micros = v.into_iter().sum();
        assert_eq!(s2, Micros(6));
    }

    #[test]
    fn display_units() {
        assert_eq!(format!("{}", Micros(12)), "12us");
        assert_eq!(format!("{}", Micros(12_500)), "12.500ms");
        assert_eq!(format!("{}", Micros(2_000_000)), "2.000s");
    }
}
