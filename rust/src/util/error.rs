//! Minimal error handling for the offline build (no `anyhow`).
//!
//! The runtime and trainer layers need fallible APIs with human-readable
//! context chains; this module provides the small subset of `anyhow` they
//! use: a string-backed [`Error`], a [`Result`] alias, a [`Context`]
//! extension trait for `Result`/`Option`, and the [`crate::bail!`] /
//! [`crate::err!`] macros.

use std::fmt;

/// A string-backed error with accumulated context.
pub struct Error(String);

impl Error {
    pub fn msg(message: impl fmt::Display) -> Error {
        Error(message.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error(s.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error(e.to_string())
    }
}

/// Result alias defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-chaining extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Build a formatted [`Error`] value.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(Error::msg("boom"))
    }

    #[test]
    fn context_chains_messages() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: boom");
        let e = fails().with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "step 3: boom");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        assert_eq!(Some(7u32).context("missing").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                crate::bail!("zero input {x}");
            }
            Ok(x)
        }
        assert_eq!(f(0).unwrap_err().to_string(), "zero input 0");
        assert_eq!(f(2).unwrap(), 2);
        let e = crate::err!("code {}", 9);
        assert_eq!(e.to_string(), "code 9");
    }
}
