//! Scalar special functions not in `std`.
//!
//! The Preserver's Gaussian-walk quantifier (paper §IV.C) needs the
//! standard-normal CDF Φ, which needs `erf`. We use the Abramowitz–Stegun
//! 7.1.26 rational approximation (|error| < 1.5e-7) — four orders of
//! magnitude below the ε = 0.01 threshold the feedback mechanism uses.

/// Error function, |absolute error| < 1.5e-7 (Abramowitz & Stegun 7.1.26).
pub fn erf(x: f64) -> f64 {
    if x == 0.0 {
        return 0.0;
    }
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let ax = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * ax);
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736 + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
    sign * (1.0 - poly * (-ax * ax).exp())
}

/// Complementary error function.
pub fn erfc(x: f64) -> f64 {
    1.0 - erf(x)
}

/// Standard normal CDF Φ(x) = P(Z ≤ x), Z ~ N(0,1).
pub fn phi(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Standard normal PDF.
pub fn normal_pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Natural log of the gamma function (Lanczos, g=7, n=9) — used by the
/// synthetic-workload generators for Zipf/Gamma-distributed layer costs.
pub fn ln_gamma(x: f64) -> f64 {
    assert!(x > 0.0, "ln_gamma domain");
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference values from standard tables.
    #[test]
    fn erf_reference_points() {
        let cases = [
            (0.0, 0.0),
            (0.5, 0.520_499_877_8),
            (1.0, 0.842_700_792_9),
            (1.5, 0.966_105_146_5),
            (2.0, 0.995_322_265_0),
            (3.0, 0.999_977_909_5),
        ];
        for (x, want) in cases {
            let got = erf(x);
            assert!(
                (got - want).abs() < 2e-7,
                "erf({x}) = {got}, want {want}"
            );
            assert!((erf(-x) + want).abs() < 2e-7, "odd symmetry at {x}");
        }
    }

    #[test]
    fn phi_reference_points() {
        let cases = [
            (0.0, 0.5),
            (1.0, 0.841_344_746_1),
            (-1.0, 0.158_655_253_9),
            (1.96, 0.975_002_104_9),
            (-2.575_829, 0.005_000_0),
        ];
        for (x, want) in cases {
            let got = phi(x);
            assert!((got - want).abs() < 1e-5, "phi({x}) = {got}, want {want}");
        }
    }

    #[test]
    fn phi_monotone_and_bounded() {
        let mut prev = 0.0;
        let mut x = -6.0;
        while x <= 6.0 {
            let p = phi(x);
            assert!((0.0..=1.0).contains(&p));
            assert!(p >= prev - 1e-12, "phi not monotone at {x}");
            prev = p;
            x += 0.01;
        }
    }

    #[test]
    fn ln_gamma_factorials() {
        // Γ(n) = (n-1)!
        let facts: [f64; 7] = [1.0, 1.0, 2.0, 6.0, 24.0, 120.0, 720.0];
        for (n, want) in facts.iter().enumerate() {
            let got = ln_gamma(n as f64 + 1.0);
            assert!(
                (got - want.ln()).abs() < 1e-10,
                "ln_gamma({}) = {got}, want {}",
                n + 1,
                want.ln()
            );
        }
        // Γ(1/2) = sqrt(pi)
        let half = ln_gamma(0.5);
        assert!((half - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
    }

    #[test]
    fn pdf_integrates_to_cdf() {
        // Trapezoid integral of the pdf matches phi differences.
        let a = -1.3_f64;
        let b = 0.7_f64;
        let n = 10_000;
        let h = (b - a) / n as f64;
        let mut integral = 0.5 * (normal_pdf(a) + normal_pdf(b));
        for i in 1..n {
            integral += normal_pdf(a + i as f64 * h);
        }
        integral *= h;
        assert!((integral - (phi(b) - phi(a))).abs() < 1e-6);
    }
}
