//! Convergence co-simulation — generates the time-to-solution curves of
//! paper Fig. 10 by combining the DES's timing with the Preserver's
//! Gaussian-walk loss dynamics.
//!
//! Substitution rationale (DESIGN.md): the paper's accuracy curves come
//! from real ImageNet/THUC-News training. Here the *loss* trajectory is
//! evolved with the same Yin-et-al. walk the paper itself uses to reason
//! about convergence (§IV.C), driven by each scheme's simulated update
//! times and batch multipliers; accuracy is a calibrated monotone map of
//! loss. DeFT-without-multilink additionally pays the generalization
//! penalty of oversized effective batches — calibrated to the paper's
//! reported ablation drops (ResNet 76→71%, VGG 71→66%).

use crate::models::TargetMetric;
use crate::preserver::{evolve_sequence, WalkParams};
use crate::util::Micros;

/// Per-workload convergence calibration.
#[derive(Clone, Debug)]
pub struct ConvergenceModel {
    /// Initial training loss.
    pub l0: f64,
    /// Loss floor S*.
    pub s_star: f64,
    /// Learning rate (walk scale).
    pub eta: f64,
    /// Gradient magnitude as a fraction of distance-to-floor.
    pub mu_ratio: f64,
    /// Noise scale as a fraction of distance-to-floor.
    pub sigma_ratio: f64,
    /// Accuracy map: acc(L) = acc_max · (1 − exp(−(l0 − L)/tau)).
    pub acc_max: f64,
    pub acc_tau: f64,
    /// Accuracy lost per doubling of effective batch beyond the safe
    /// multiplier (large-batch generalization gap; calibrated to the
    /// paper's no-multilink ablation).
    pub gen_penalty_per_doubling: f64,
    pub safe_multiplier: f64,
}

impl ConvergenceModel {
    /// Calibrations per workload (targets from paper Fig. 10).
    pub fn for_workload(name: &str) -> ConvergenceModel {
        match name {
            "resnet101" => ConvergenceModel {
                l0: 6.9,
                s_star: 0.8,
                eta: 0.01,
                mu_ratio: 0.00020,
                sigma_ratio: 0.0040,
                acc_max: 0.810,
                acc_tau: 2.2,
                gen_penalty_per_doubling: 0.05,
                safe_multiplier: 1.0,
            },
            "vgg19" => ConvergenceModel {
                l0: 6.9,
                s_star: 1.1,
                eta: 0.01,
                mu_ratio: 0.00025,
                sigma_ratio: 0.0050,
                acc_max: 0.758,
                acc_tau: 2.1,
                gen_penalty_per_doubling: 0.05,
                safe_multiplier: 1.0,
            },
            "gpt2" => ConvergenceModel {
                l0: 9.5,
                s_star: 2.6,
                eta: 0.0006,
                mu_ratio: 0.00040,
                sigma_ratio: 0.0040,
                acc_max: 1.0, // unused (loss target)
                acc_tau: 1.0,
                gen_penalty_per_doubling: 0.0, // shows up as slower early loss
                safe_multiplier: 2.0,
            },
            _ => ConvergenceModel {
                l0: 5.0,
                s_star: 1.0,
                eta: 0.01,
                mu_ratio: 0.02,
                sigma_ratio: 0.3,
                acc_max: 0.8,
                acc_tau: 2.0,
                gen_penalty_per_doubling: 0.02,
                safe_multiplier: 2.0,
            },
        }
    }

    fn accuracy_of_loss(&self, loss: f64, eff_mult: f64) -> f64 {
        let base = self.acc_max * (1.0 - (-(self.l0 - loss).max(0.0) / self.acc_tau).exp());
        let excess = (eff_mult / self.safe_multiplier).max(1.0).log2();
        (base - self.gen_penalty_per_doubling * excess).max(0.0)
    }
}

/// A time-to-solution curve: wall-clock seconds vs metric value.
#[derive(Clone, Debug)]
pub struct TrainingCurve {
    pub scheme: String,
    /// Wall-clock time of each recorded point (seconds).
    pub times_s: Vec<f64>,
    /// Training loss at each point.
    pub loss: Vec<f64>,
    /// Accuracy at each point (classification workloads).
    pub accuracy: Vec<f64>,
    /// Mean effective batch multiplier of the schedule.
    pub eff_multiplier: f64,
}

impl TrainingCurve {
    /// First wall-clock time the metric reaches `target`, if ever.
    pub fn time_to_target(&self, target: TargetMetric) -> Option<f64> {
        match target {
            TargetMetric::Accuracy(a) => self
                .accuracy
                .iter()
                .position(|&x| x >= a)
                .map(|i| self.times_s[i]),
            TargetMetric::Loss(l) => self
                .loss
                .iter()
                .position(|&x| x <= l)
                .map(|i| self.times_s[i]),
        }
    }

    pub fn final_accuracy(&self) -> f64 {
        self.accuracy.last().copied().unwrap_or(0.0)
    }

    pub fn final_loss(&self) -> f64 {
        self.loss.last().copied().unwrap_or(f64::INFINITY)
    }
}

/// Generate a training curve for one scheme.
///
/// * `cycle_time` — simulated wall time of one steady-state schedule
///   cycle (from `SimResult`).
/// * `multipliers` — batch multipliers of the cycle's updates.
/// * `base_batch` — per-update baseline batch size (B in §IV.C.1).
/// * `total_iterations` — training length in iterations.
pub fn training_curve(
    model: &ConvergenceModel,
    scheme: &str,
    cycle_time: Micros,
    cycle_iters: usize,
    multipliers: &[u64],
    base_batch: f64,
    total_iterations: usize,
) -> TrainingCurve {
    assert!(cycle_iters > 0 && !multipliers.is_empty());
    let cycles = total_iterations.div_ceil(cycle_iters);
    let eff_mult =
        multipliers.iter().sum::<u64>() as f64 / multipliers.len() as f64;

    // Build the full batch-size sequence and per-update wall times.
    let mut batches: Vec<f64> = Vec::with_capacity(cycles * multipliers.len());
    let mut times: Vec<f64> = Vec::with_capacity(cycles * multipliers.len());
    let per_iter = cycle_time.as_secs_f64() / cycle_iters as f64;
    let mut iter_cursor = 0.0f64;
    for _ in 0..cycles {
        for &k in multipliers {
            iter_cursor += k as f64;
            batches.push(k as f64 * base_batch);
            times.push(iter_cursor * per_iter);
        }
    }

    // Evolve the expected loss over the update sequence.
    let start = WalkParams {
        s_t: model.l0,
        s_star: model.s_star,
        eta: model.eta,
        mu_t: model.mu_ratio / model.eta * (model.l0 - model.s_star),
        sigma_t: model.sigma_ratio / model.eta * (model.l0 - model.s_star),
    };
    let loss = evolve_sequence(&start, &batches);
    let accuracy: Vec<f64> = loss
        .iter()
        .map(|&l| model.accuracy_of_loss(l, eff_mult))
        .collect();

    TrainingCurve {
        scheme: scheme.to_string(),
        times_s: times,
        loss,
        accuracy,
        eff_multiplier: eff_mult,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faster_cycle_reaches_target_sooner() {
        let m = ConvergenceModel::for_workload("resnet101");
        let slow = training_curve(&m, "slow", Micros::from_ms(400), 1, &[1], 256.0, 30_000);
        let fast = training_curve(&m, "fast", Micros::from_ms(200), 1, &[1], 256.0, 30_000);
        let t_slow = slow.time_to_target(TargetMetric::Accuracy(0.70)).unwrap();
        let t_fast = fast.time_to_target(TargetMetric::Accuracy(0.70)).unwrap();
        assert!(t_fast < t_slow);
        assert!((t_slow / t_fast - 2.0).abs() < 0.2, "{t_slow} vs {t_fast}");
    }

    #[test]
    fn loss_decreases_monotonically_in_expectation() {
        let m = ConvergenceModel::for_workload("gpt2");
        let c = training_curve(&m, "x", Micros::from_ms(600), 1, &[1], 16.0, 500);
        for w in c.loss.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "loss went up: {:?}", &w);
        }
        assert!(c.final_loss() < m.l0);
    }

    #[test]
    fn oversized_batches_hurt_final_accuracy() {
        let m = ConvergenceModel::for_workload("resnet101");
        // Same speed, but one updates with multiplier 8 (no-multilink
        // ablation regime).
        let normal = training_curve(&m, "deft", Micros::from_ms(200), 2, &[1, 1], 256.0, 4000);
        let merged = training_curve(&m, "nolink", Micros::from_ms(800), 8, &[8], 256.0, 4000);
        assert!(
            normal.final_accuracy() - merged.final_accuracy() > 0.03,
            "{} vs {}",
            normal.final_accuracy(),
            merged.final_accuracy()
        );
    }

    #[test]
    fn resnet_final_accuracy_near_paper() {
        // Paper Fig. 10(a): ResNet-101 converges to ~76%.
        let m = ConvergenceModel::for_workload("resnet101");
        let c = training_curve(&m, "ddp", Micros::from_ms(419), 1, &[1], 256.0, 40_000);
        let acc = c.final_accuracy();
        assert!((acc - 0.76).abs() < 0.03, "final acc {acc}");
    }

    #[test]
    fn times_are_monotone() {
        let m = ConvergenceModel::for_workload("vgg19");
        let c = training_curve(&m, "x", Micros::from_ms(300), 3, &[2, 1], 64.0, 300);
        for w in c.times_s.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert_eq!(c.times_s.len(), c.loss.len());
        assert_eq!(c.loss.len(), c.accuracy.len());
    }
}
