//! The **reference scan engine** — the original `simulate` main loop,
//! kept verbatim as the golden oracle for the indexed-event engine in
//! [`super::engine`].
//!
//! Every event round here re-derives its state by scanning: the
//! next-event search walks every link, the completion loop re-scans all
//! in-flight slots per fired completion, k-way re-pricing walks the whole
//! registry per membership change, and the forward dependency gate pays a
//! `BTreeMap` lookup (plus, for barrier schemes, a linear walk over all
//! earlier updates) on every dispatch attempt. That makes it easy to
//! audit against the model semantics documented in `engine` — and slow.
//! [`simulate_scan`] must produce **bit-for-bit** the same [`SimResult`]
//! as [`super::simulate`] on every input (`tests/engine_equivalence.rs`);
//! it also serves as the "before" point of the committed
//! `BENCH_des_hotpath.json` perf trajectory.
//!
//! Semantics (contention models, per-segment streams, codec encode
//! charging) are documented once, in [`super::engine`].

use std::collections::{BTreeMap, BTreeSet};

use super::{Span, SpanKind, StreamId, Timeline};
use crate::faults::{FaultEvent, FaultSpec, FaultTrace, FlapAt};
use crate::links::{ClusterEnv, ContentionModel, LinkId};
use crate::models::BucketProfile;
use crate::sched::{FwdDependency, Schedule, Stage};
use crate::util::Micros;

use super::engine::{SimOptions, SimResult};

/// Internal: one materialized communication op instance.
#[derive(Clone, Debug)]
struct OpInst {
    bucket: usize,
    link: LinkId,
    iter: usize,
    stage: Stage,
    priority: i64,
    grad_age: usize,
    merged: usize,
    /// Global update index this op's gradients feed.
    update_idx: usize,
    /// Uncontended wire time of the full segment path on its home link.
    wire: Micros,
    /// Foreign segment leg (hierarchical topologies): the intra/inter
    /// link that also carries part of this transfer, and for how long.
    seg_extra: Option<(LinkId, Micros)>,
    /// Resolved readiness (None until known).
    ready: Option<Micros>,
    /// Finalized completion time, set at the completion event. None while
    /// queued or in flight — an in-flight transfer's *tentative* end
    /// lives in the engine's flight table, where overlap contention may
    /// still move it (later at a group-mate's dispatch, earlier at a
    /// group-mate's finalize under k-way), so nothing may gate on it
    /// before completion.
    done: Option<Micros>,
}

/// One in-flight transfer on a link. Under the k-way contention model the
/// flight is re-priced piecewise at every group membership change; under
/// the pairwise model `rem`/`factor` stay at their dispatch values and
/// only `end` is one-shot extended.
#[derive(Clone, Copy, Debug)]
struct Flight {
    /// Index into `ops`.
    oi: usize,
    /// Wire start (the home-link span is recorded at completion).
    start: Micros,
    /// Time of the last re-pricing event (dispatch, or any k-way
    /// membership change since).
    at: Micros,
    /// Uncontended wire time still owed as of `at`.
    rem: Micros,
    /// Current slowdown factor (1.0 = uncontended rate).
    factor: f64,
    /// Projected completion: `at + rem · factor`; final once it fires.
    end: Micros,
}

/// Re-price every in-flight member of `group` at event time `t` (k-way
/// model): bank the progress made at the old rate over `[at, t)`, then
/// project the remainder at the factor for the group's new concurrency
/// `k`. Exempt (non-paying) members always run at rate 1 —
/// `contention_factor(k ≤ 1, ·) = 1` covers a payer flying alone.
#[allow(clippy::too_many_arguments)]
fn reprice_group(
    env: &ClusterEnv,
    buckets: &[BucketProfile],
    ops: &[OpInst],
    group_of: &[usize],
    pays: &[bool],
    flights: &mut [Option<Flight>],
    link_free: &mut [Micros],
    group: usize,
    t: Micros,
) {
    let k = flights
        .iter()
        .enumerate()
        .filter(|(j, f)| group_of[*j] == group && f.is_some())
        .count();
    for j in 0..flights.len() {
        if group_of[j] != group {
            continue;
        }
        let Some(f) = flights[j].as_mut() else { continue };
        let elapsed = t.saturating_sub(f.at);
        if !elapsed.is_zero() {
            let done = if f.factor == 1.0 {
                elapsed
            } else {
                elapsed.scale(1.0 / f.factor)
            };
            f.rem = f.rem.saturating_sub(done);
        }
        f.at = f.at.max(t);
        f.factor = if pays[j] {
            env.contention_factor(k, buckets[ops[f.oi].bucket].params)
        } else {
            1.0
        };
        f.end = f.at
            + if f.factor == 1.0 {
                f.rem
            } else {
                f.rem.scale(f.factor)
            };
        link_free[j] = f.end;
    }
}

/// Compute-task cursor: which task the compute stream runs next.
#[derive(Clone, Copy, Debug, PartialEq)]
enum CompTask {
    Fwd { iter: usize, bucket: usize },
    Bwd { iter: usize, bucket: usize },
    Done,
}

/// Execute `schedule` over `buckets` in `env` with the original
/// scan-based main loop and return metrics. The golden reference for
/// [`super::simulate`] — same contract, same panics on malformed
/// schedules.
pub fn simulate_scan(
    buckets: &[BucketProfile],
    schedule: &Schedule,
    env: &ClusterEnv,
    opts: &SimOptions,
) -> SimResult {
    run(buckets, schedule, env, opts, None)
}

/// Scan-engine counterpart of [`super::engine::simulate_faulted`]: same
/// fault semantics (stragglers, compute jitter, link flaps, elastic
/// membership, drift monitor), re-derived by scanning. Must produce
/// bit-for-bit the same [`SimResult`] — including `fault_log` — for any
/// `(spec, opts)` pair (`tests/fault_injection.rs`).
pub fn simulate_scan_faulted(
    buckets: &[BucketProfile],
    schedule: &Schedule,
    env: &ClusterEnv,
    opts: &SimOptions,
    faults: Option<&FaultSpec>,
) -> SimResult {
    let trace =
        faults.map(|spec| FaultTrace::materialize(spec, opts.iterations, buckets, schedule, env));
    run(buckets, schedule, env, opts, trace.as_ref())
}

fn run(
    buckets: &[BucketProfile],
    schedule: &Schedule,
    env: &ClusterEnv,
    opts: &SimOptions,
    faults: Option<&FaultTrace>,
) -> SimResult {
    schedule.validate().expect("invalid schedule");
    let n = buckets.len();
    assert!(n > 0, "no buckets");
    let iters = opts.iterations;
    assert!(iters > 0);
    let n_links = env.n_links();
    assert!(n_links > 0, "environment has no links");

    // ---- Materialize op instances for every iteration. ----
    let cycle_len = schedule.cycle.len();
    // updates_before[t] = number of update markers in iterations < t.
    let mut updates_before = vec![0usize; iters + 1];
    for t in 0..iters {
        let plan = &schedule.cycle[t % cycle_len];
        updates_before[t + 1] = updates_before[t] + usize::from(plan.update_at_end);
    }
    let total_updates = updates_before[iters];

    let mut ops: Vec<OpInst> = Vec::new();
    // Codec bookkeeping: encode overhead charged on the compute stream —
    // keyed to the compute task whose end launches the op (see the
    // `engine` module docs) — plus per-link byte/overhead counters.
    let mut enc_fwd: Vec<Micros> = vec![Micros::ZERO; iters];
    let mut enc_bwd: BTreeMap<(usize, usize), Micros> = BTreeMap::new();
    let mut link_traffic: Vec<super::LinkTraffic> = vec![Default::default(); n_links];
    for t in 0..iters {
        let plan = &schedule.cycle[t % cycle_len];
        for op in plan.all_ops() {
            assert!(
                !(op.grad_age == 0 && op.stage == Stage::Forward),
                "op for current-iter grad cannot launch in forward window"
            );
            assert!(
                op.link.index() < n_links,
                "op targets link {:?} but the environment registers only {n_links} links",
                op.link
            );
            let codec = env.spec(op.link).codec;
            let enc = env.encode_overhead_us(op.link, buckets[op.bucket].params);
            if !enc.is_zero() {
                if op.grad_age == 0 {
                    *enc_bwd.entry((t, op.bucket)).or_insert(Micros::ZERO) += enc;
                } else if op.stage == Stage::Backward {
                    *enc_bwd.entry((t, n - 1)).or_insert(Micros::ZERO) += enc;
                } else {
                    enc_fwd[t] += enc;
                }
            }
            let raw_bytes = buckets[op.bucket].params.saturating_mul(4);
            let traffic = &mut link_traffic[op.link.index()];
            traffic.raw_bytes += raw_bytes;
            traffic.wire_bytes += (raw_bytes as f64 * codec.wire_ratio()).round() as u64;
            traffic.encode += enc;
            // Uncontended segment-path pricing; the dispatch loop adds
            // the contention penalty for actually-overlapping windows.
            let segs = env.wire_segments(op.link, buckets[op.bucket].comm);
            let mut wire: Micros = segs.iter().map(|&(_, t)| t).sum();
            let mut seg_extra = segs.iter().find(|&&(l, _)| l != op.link).copied();
            // Elastic membership: the declared cluster size of this
            // iteration rescales the whole segment path (ring-factor
            // ratio; see `ClusterEnv::elastic_wire_scale`).
            if let Some(ft) = faults {
                let s = ft.wire_scale_at(t);
                if s != 1.0 {
                    wire = wire.scale(s);
                    seg_extra = seg_extra.map(|(l, m)| (l, m.scale(s)));
                }
            }
            ops.push(OpInst {
                bucket: op.bucket,
                link: op.link,
                iter: t,
                stage: op.stage,
                priority: op.priority,
                grad_age: op.grad_age,
                merged: op.merged,
                update_idx: updates_before[t] + op.update_offset,
                wire,
                seg_extra,
                ready: None,
                done: None,
            });
        }
    }

    // Update bookkeeping: iteration whose end carries update u, and the
    // set of ops feeding u.
    let mut update_iter = vec![usize::MAX; total_updates.max(1)];
    {
        let mut u = 0;
        for t in 0..iters {
            if schedule.cycle[t % cycle_len].update_at_end {
                update_iter[u] = t;
                u += 1;
            }
        }
    }
    let mut update_outstanding = vec![0usize; total_updates];
    for op in &ops {
        if op.update_idx < total_updates {
            update_outstanding[op.update_idx] += 1;
        }
        // Ops whose update lies beyond the horizon never gate anything.
    }

    // Coverage map for PerBucket forward dependencies:
    // covered[(iter, bucket)] -> op index whose transfer includes that
    // iteration's gradient of that bucket.
    let mut covers: BTreeMap<(usize, usize), usize> = BTreeMap::new();
    if schedule.fwd_dependency == FwdDependency::PerBucket {
        for (oi, op) in ops.iter().enumerate() {
            let newest = op.iter as i64 - op.grad_age as i64;
            for k in 0..op.merged {
                let covered_iter = newest - k as i64;
                if covered_iter >= 0 {
                    covers.insert((covered_iter as usize, op.bucket), oi);
                }
            }
        }
    }

    // ---- Event-driven execution. ----
    // Resources: compute stream cursor + one server per registry link.
    let mut now = Micros::ZERO;
    let mut timeline = Timeline::default();
    let record = |tl: &mut Timeline, span: Span| {
        if opts.record_timeline {
            tl.spans.push(span);
        }
    };

    // Per-link ready pools (indexed by LinkId), ordered by
    // (priority, iter, bucket, op idx).
    let mut pool: Vec<BTreeSet<(i64, usize, usize, usize)>> = vec![BTreeSet::new(); n_links];
    // Link busy-until (= the in-flight projection's end) and the
    // in-flight transfer itself, indexed by LinkId.
    let mut link_free: Vec<Micros> = vec![Micros::ZERO; n_links];
    let mut in_flight: Vec<Option<Flight>> = vec![None; n_links];
    // Contention bookkeeping: group per link, and whether the link pays
    // shared-NIC contention at all (the non-fastest-group-member rule).
    let group_of: Vec<usize> = (0..n_links)
        .map(|k| env.spec(LinkId(k)).contention_group)
        .collect();
    let pays: Vec<bool> = (0..n_links).map(|k| env.contended(LinkId(k))).collect();
    // Per-link segment occupancy (wire time carried by each link,
    // including foreign legs of hierarchical transfers + contention).
    let mut seg_busy: Vec<Micros> = vec![Micros::ZERO; n_links];

    // Event accounting (must match the indexed engine's definition
    // bit-for-bit): dispatches + completions on links and compute.
    let mut events_processed = 0u64;
    let mut cur_in_flight = 0usize;
    let mut peak_in_flight = 0usize;

    // ---- Fault-injection state. ----
    // Flaps fire as first-class events: the next unfired flap's time is
    // always a candidate in the next-event search, so the clock never
    // jumps past a flap and banking in-flight progress at `now` is
    // exact. `cur_ratio[k]` is link k's current wire-time multiplier.
    let flaps: &[FlapAt] = match faults {
        Some(ft) => ft.flaps.as_slice(),
        None => &[],
    };
    let mut next_flap = 0usize;
    let mut cur_ratio: Vec<f64> = vec![1.0; n_links];
    let mut fault_log: Vec<FaultEvent> = faults.map(|ft| ft.scheduled.clone()).unwrap_or_default();
    // Measured per-(iteration, link) home busy for the drift monitor
    // (only accounted while the monitor is armed).
    let mut iter_link_busy: Vec<Micros> = match faults {
        Some(ft) if ft.monitors_drift() => vec![Micros::ZERO; iters * n_links],
        _ => Vec::new(),
    };

    // Staleness-bound bookkeeping (incremental — a linear scan of all ops
    // per dispatch made the engine quadratic in iterations):
    // `iter_ops_remaining[it]` counts incomplete ops launched in iteration
    // `it`; `watermark` is the first iteration with incomplete ops;
    // `cum_max_done[it]` (valid for it < watermark) is the latest
    // completion time among all ops of iterations ≤ it.
    let mut iter_ops_remaining = vec![0usize; iters];
    for op in &ops {
        iter_ops_remaining[op.iter] += 1;
    }
    let mut iter_max_done = vec![Micros::ZERO; iters];
    let mut cum_max_done = vec![Micros::ZERO; iters];
    let mut watermark = 0usize;
    while watermark < iters && iter_ops_remaining[watermark] == 0 {
        cum_max_done[watermark] = if watermark == 0 {
            Micros::ZERO
        } else {
            cum_max_done[watermark - 1]
        };
        watermark += 1;
    }

    // Compute bookkeeping.
    let mut comp = CompTask::Fwd { iter: 0, bucket: 0 };
    let mut comp_busy_until = Micros::ZERO;
    let mut comp_running = false;
    let mut compute_busy = Micros::ZERO;
    let mut first_comp_start: Option<Micros> = None;
    let mut iter_ends: Vec<Micros> = Vec::with_capacity(iters);
    // Compute end of iteration t (backward fully done).
    let mut comp_iter_end: Vec<Option<Micros>> = vec![None; iters];
    // Fwd window open time per iteration (= compute end of previous iter).
    let mut update_times: Vec<Option<Micros>> = vec![None; total_updates];
    let mut update_pending_end: Vec<Option<Micros>> = vec![None; total_updates];

    // Index ops by (iter, stage) for window-open insertion and by
    // (iter, bucket) for data-ready insertion.
    let mut by_window: BTreeMap<(usize, u8), Vec<usize>> = BTreeMap::new();
    let mut by_data: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
    for (oi, op) in ops.iter().enumerate() {
        if op.grad_age == 0 {
            by_data.entry((op.iter, op.bucket)).or_default().push(oi);
        } else {
            let stage_key = if op.stage == Stage::Forward { 0 } else { 1 };
            by_window.entry((op.iter, stage_key)).or_default().push(oi);
        }
    }

    // Helper: make ops ready and insert into pools.
    macro_rules! make_ready {
        ($indices:expr, $time:expr) => {
            for &oi in $indices.iter() {
                let op = &mut ops[oi];
                debug_assert!(op.ready.is_none());
                op.ready = Some($time);
                pool[op.link.index()].insert((op.priority, op.iter, op.bucket, oi));
            }
        };
    }

    // Iteration 0 forward window opens at t=0.
    if let Some(is) = by_window.get(&(0usize, 0u8)) {
        let is = is.clone();
        make_ready!(is, Micros::ZERO);
    }

    let mut safety = 0u64;
    let safety_cap = 10_000_000u64 + ops.len() as u64 * 16;

    loop {
        safety += 1;
        assert!(safety < safety_cap, "simulator livelock — scheduler bug?");

        let mut progressed = false;

        // --- 1. Dispatch links: serve best ready op if free. ---
        for k in 0..n_links {
            if in_flight[k].is_some() {
                continue;
            }
            let free_at = link_free[k].max(Micros::ZERO);
            // Ops are inserted into the pool at the very event that made
            // them ready (ready ≤ now always), so the best candidate is
            // simply the first element in (priority, iter, bucket) order.
            let candidate = pool[k]
                .first()
                .filter(|&&(_, _, _, oi)| ops[oi].ready.is_some_and(|r| r <= now.max(free_at)))
                .copied();
            if let Some(key) = candidate {
                let oi = key.3;
                pool[k].remove(&key);
                let start = ops[oi].ready.expect("pooled op is ready").max(link_free[k]);
                // A degraded (flapped) link prices the whole transfer at
                // its current ratio; a mid-flight flap re-prices the
                // remainder piecewise at the flap event below.
                let r = cur_ratio[k];
                let wire = if r == 1.0 {
                    ops[oi].wire
                } else {
                    ops[oi].wire.scale(r)
                };
                events_processed += 1;
                cur_in_flight += 1;
                peak_in_flight = peak_in_flight.max(cur_in_flight);
                // `done` stays None until the completion event; while in
                // flight the tentative end lives in the flight table and
                // `link_free`, where contention may still move it.
                match env.contention {
                    ContentionModel::Kway => {
                        in_flight[k] = Some(Flight {
                            oi,
                            start,
                            at: start,
                            rem: wire,
                            factor: 1.0,
                            end: start + wire,
                        });
                        link_free[k] = start + wire;
                        // Aggregate sharing: this dispatch changes the
                        // group's concurrency, so the whole group is
                        // re-priced — the new transfer picks up the
                        // factor for the current k, and every paying
                        // group-mate banks its progress so far and slows
                        // down for the larger k.
                        reprice_group(
                            env,
                            buckets,
                            &ops,
                            &group_of,
                            &pays,
                            &mut in_flight,
                            &mut link_free,
                            group_of[k],
                            start,
                        );
                    }
                    ContentionModel::Pairwise => {
                        let mut end = start + wire;
                        // One-shot overlap charge: a paying link is
                        // slowed by the pairwise penalty for the window
                        // it shares with in-flight same-group transfers.
                        if pays[k] && !wire.is_zero() {
                            let mut overlap = Micros::ZERO;
                            for (j, f) in in_flight.iter().enumerate() {
                                if j == k || group_of[j] != group_of[k] {
                                    continue;
                                }
                                let Some(f) = f else { continue };
                                let lo = start.max(f.start);
                                let hi = end.min(f.end);
                                if hi > lo {
                                    overlap += hi - lo;
                                }
                            }
                            if !overlap.is_zero() {
                                let params = buckets[ops[oi].bucket].params;
                                end += overlap.scale(env.contention_penalty(params));
                            }
                        }
                        link_free[k] = end;
                        in_flight[k] = Some(Flight {
                            oi,
                            start,
                            at: start,
                            rem: wire,
                            factor: 1.0,
                            end,
                        });
                        // Symmetry: this transfer also slows down any
                        // *paying* group-mate already in flight — extend
                        // it by the penalty on the newly shared window
                        // (the fastest member never pays, mirroring the
                        // dispatch-time charge above). Both directions
                        // measure the window against the ends as known at
                        // this dispatch, so the charge is symmetric to
                        // first order only; the k-way model re-prices
                        // these windows exactly instead.
                        for j in 0..n_links {
                            if j == k || group_of[j] != group_of[k] || !pays[j] {
                                continue;
                            }
                            let Some(fj) = in_flight[j] else { continue };
                            let lo = start.max(fj.start);
                            let hi = end.min(fj.end);
                            if hi > lo {
                                let params = buckets[ops[fj.oi].bucket].params;
                                let extra = (hi - lo).scale(env.contention_penalty(params));
                                if !extra.is_zero() {
                                    link_free[j] = fj.end + extra;
                                    in_flight[j].as_mut().expect("flight j is in flight").end = fj.end + extra;
                                }
                            }
                        }
                    }
                }
                // Foreign segment leg: record its occupancy on the
                // segment's own stream (hierarchical topologies). The
                // home-link span is recorded at completion, once the end
                // can no longer move.
                if let Some((seg_link, seg_t)) = ops[oi].seg_extra {
                    seg_busy[seg_link.index()] += seg_t;
                    record(
                        &mut timeline,
                        Span {
                            stream: StreamId::Link(seg_link),
                            kind: SpanKind::Comm {
                                iter: ops[oi].iter,
                                bucket: ops[oi].bucket,
                                merged: ops[oi].merged,
                            },
                            start,
                            end: start + seg_t,
                        },
                    );
                }
                progressed = true;
            }
        }

        // --- 2. Dispatch compute if idle and dependencies resolved. ---
        if !comp_running {
            match comp {
                CompTask::Fwd { iter, bucket } => {
                    // Dependency gate for the very first task of the fwd.
                    let mut dep_time = Some(if iter == 0 {
                        Micros::ZERO
                    } else {
                        comp_iter_end[iter - 1].expect("prev iter must be done")
                    });
                    // Staleness back-pressure: every op launched in
                    // iterations ≤ iter − max_outstanding must be done
                    // (the two-queue memory bound; see Schedule docs).
                    if bucket == 0 && iter >= schedule.max_outstanding_iters.saturating_add(1) {
                        let horizon = iter - schedule.max_outstanding_iters;
                        if watermark >= horizon {
                            dep_time = dep_time.map(|d| d.max(cum_max_done[horizon - 1]));
                        } else {
                            dep_time = None;
                        }
                    }
                    match schedule.fwd_dependency {
                        FwdDependency::Barrier => {
                            if bucket == 0 && iter > 0 {
                                // All updates of iterations < iter.
                                let need = updates_before[iter];
                                for u in 0..need {
                                    match update_times[u] {
                                        Some(t) => {
                                            dep_time = dep_time.map(|d| d.max(t));
                                        }
                                        None => dep_time = None,
                                    }
                                }
                            }
                        }
                        FwdDependency::PerBucket => {
                            if iter > 0 {
                                let oi = *covers.get(&(iter - 1, bucket)).unwrap_or_else(|| {
                                    panic!(
                                        "no op covers grad (iter {}, bucket {bucket})",
                                        iter - 1
                                    )
                                });
                                // `done` is final only after the
                                // completion event — an in-flight op's
                                // tentative end may still be extended by
                                // contention, so wait rather than gate on
                                // it (same wall-clock start either way).
                                match ops[oi].done {
                                    Some(t) => dep_time = dep_time.map(|d| d.max(t)),
                                    None => dep_time = None,
                                }
                            }
                        }
                        FwdDependency::None => {}
                    }
                    if let Some(dep) = dep_time {
                        let start = now.max(dep).max(comp_busy_until);
                        // Forward-window encode kernels run at the head
                        // of the iteration's compute (zero without
                        // lossy codecs).
                        let mut dur = buckets[bucket].fwd;
                        if bucket == 0 {
                            dur += enc_fwd[iter];
                        }
                        // Injected compute jitter / straggler stretch.
                        if let Some(ft) = faults {
                            dur += ft.fwd_extra[iter * n + bucket];
                        }
                        let end = start + dur;
                        first_comp_start.get_or_insert(start);
                        compute_busy += dur;
                        events_processed += 1;
                        record(
                            &mut timeline,
                            Span {
                                stream: StreamId::Compute,
                                kind: SpanKind::Fwd { iter, bucket },
                                start,
                                end,
                            },
                        );
                        comp_busy_until = end;
                        comp_running = true;
                        progressed = true;
                    }
                }
                CompTask::Bwd { iter, bucket } => {
                    let start = now.max(comp_busy_until);
                    // Encode kernels of ops this backward task launches
                    // extend it — the wire cannot start before its
                    // gradient is compressed.
                    let mut dur = buckets[bucket].bwd
                        + enc_bwd.get(&(iter, bucket)).copied().unwrap_or(Micros::ZERO);
                    // Injected compute jitter / straggler stretch.
                    if let Some(ft) = faults {
                        dur += ft.bwd_extra[iter * n + bucket];
                    }
                    let end = start + dur;
                    compute_busy += dur;
                    events_processed += 1;
                    record(
                        &mut timeline,
                        Span {
                            stream: StreamId::Compute,
                            kind: SpanKind::Bwd { iter, bucket },
                            start,
                            end,
                        },
                    );
                    comp_busy_until = end;
                    comp_running = true;
                    progressed = true;
                }
                CompTask::Done => {}
            }
        }

        // --- 3. Advance time to the next event. ---
        let mut next_time: Option<Micros> = None;
        let consider = |t: Micros, next: &mut Option<Micros>| {
            if t > now {
                *next = Some(next.map_or(t, |n: Micros| n.min(t)));
            }
        };
        if comp_running {
            consider(comp_busy_until, &mut next_time);
        }
        for k in 0..n_links {
            if in_flight[k].is_some() {
                consider(link_free[k], &mut next_time);
            }
            // Idle links need no wake-up: pool entries are ready the
            // moment they are inserted (see the dispatch invariant), so
            // an idle link with work is served in the same event round.
        }
        // Pending update whose iteration end passed but ops outstanding:
        // resolved by op-done events, nothing to schedule here.
        // The next unfired flap is always a candidate event, so the
        // clock lands exactly on it (never jumps it) and the mid-flight
        // re-pricing below banks progress at the precise flap instant.
        if next_flap < flaps.len() {
            consider(flaps[next_flap].at, &mut next_time);
        }

        if !progressed {
            match next_time {
                Some(t) => now = t,
                None => break, // nothing running, nothing pending
            }
        } else {
            continue;
        }

        // --- 4. Fire completions at `now`. ---
        // Link completions — chronologically (earliest projected end
        // first), because under the k-way model every finalize re-prices
        // the survivors of its contention group: they speed back up from
        // the departure instant, and their shortened projections may
        // themselves fall due within this same round.
        loop {
            let mut due: Option<(Micros, usize)> = None;
            for k in 0..n_links {
                if let Some(f) = &in_flight[k] {
                    if f.end <= now && due.map_or(true, |(e, j)| (f.end, k) < (e, j)) {
                        due = Some((f.end, k));
                    }
                }
            }
            let Some((done_t, k)) = due else { break };
            let f = in_flight[k].take().expect("due flight exists");
            let oi = f.oi;
            events_processed += 1;
            cur_in_flight -= 1;
            // Finalize: contention can no longer move this transfer.
            ops[oi].done = Some(done_t);
            seg_busy[k] += done_t - f.start;
            if !iter_link_busy.is_empty() {
                // Drift monitor: measured home busy of the op's launch
                // iteration (the full home span — comparable to the
                // planner's `wire_time`, which also prices the whole
                // segment path plus static contention).
                iter_link_busy[ops[oi].iter * n_links + k] += done_t - f.start;
            }
            record(
                &mut timeline,
                Span {
                    stream: StreamId::Link(LinkId(k)),
                    kind: SpanKind::Comm {
                        iter: ops[oi].iter,
                        bucket: ops[oi].bucket,
                        merged: ops[oi].merged,
                    },
                    start: f.start,
                    end: done_t,
                },
            );
            // Advance the staleness watermark.
            let op_iter = ops[oi].iter;
            iter_ops_remaining[op_iter] -= 1;
            iter_max_done[op_iter] = iter_max_done[op_iter].max(done_t);
            while watermark < iters && iter_ops_remaining[watermark] == 0 {
                let prev = if watermark == 0 {
                    Micros::ZERO
                } else {
                    cum_max_done[watermark - 1]
                };
                cum_max_done[watermark] = prev.max(iter_max_done[watermark]);
                // Every comm op of `watermark` has completed: its
                // measured per-link busy is final — compare against the
                // planned busy of its cycle slot.
                if let Some(ft) = faults {
                    if !iter_link_busy.is_empty() {
                        ft.drift_check(
                            watermark,
                            &iter_link_busy[watermark * n_links..(watermark + 1) * n_links],
                            &mut fault_log,
                        );
                    }
                }
                watermark += 1;
            }
            let u = ops[oi].update_idx;
            if u < total_updates {
                update_outstanding[u] -= 1;
                if update_outstanding[u] == 0 {
                    if let Some(iter_end) = update_pending_end[u] {
                        update_times[u] = Some(iter_end.max(done_t));
                    }
                }
            }
            // Finalize-path re-pricing: the departure shrinks the
            // group's concurrency, so surviving paying members speed
            // back up from `done_t` (k-way only — the pairwise model
            // deliberately never revisits its one-shot charge).
            if env.contention == ContentionModel::Kway {
                reprice_group(
                    env,
                    buckets,
                    &ops,
                    &group_of,
                    &pays,
                    &mut in_flight,
                    &mut link_free,
                    group_of[k],
                    done_t,
                );
            }
        }
        // Link flaps due at `now` (after completions: a transfer whose
        // projected end is exactly `now` completes at its pre-flap
        // pricing, which is exact — the flap takes effect from `now`
        // on). The link's wire-time ratio changes and its in-flight
        // transfer is re-priced piecewise: bank the progress made so
        // far, re-project the remainder at the new ratio — the same
        // bank-then-reproject arithmetic k-way membership changes use.
        // Pairwise flights carry one-shot overlap extensions not
        // derivable from `rem`, so their remaining wall-clock window is
        // rescaled one-shot instead, consistent with that model's
        // never-revisit semantics.
        while next_flap < flaps.len() && flaps[next_flap].at <= now {
            let fl = flaps[next_flap];
            next_flap += 1;
            events_processed += 1;
            let j = fl.link;
            if j >= n_links {
                continue;
            }
            let old_r = cur_ratio[j];
            let new_r = fl.ratio;
            cur_ratio[j] = new_r;
            if new_r == old_r {
                continue;
            }
            if let Some(f) = in_flight[j].as_mut() {
                let end = match env.contention {
                    ContentionModel::Kway => {
                        let elapsed = now.saturating_sub(f.at);
                        if !elapsed.is_zero() {
                            let done = if f.factor == 1.0 {
                                elapsed
                            } else {
                                elapsed.scale(1.0 / f.factor)
                            };
                            f.rem = f.rem.saturating_sub(done);
                        }
                        f.at = f.at.max(now);
                        // `rem` is owed wire time priced at the old
                        // ratio; the same physical bytes re-price by
                        // new/old.
                        f.rem = f.rem.scale(new_r / old_r);
                        f.at + if f.factor == 1.0 {
                            f.rem
                        } else {
                            f.rem.scale(f.factor)
                        }
                    }
                    ContentionModel::Pairwise => {
                        let rem_wall = f.end.saturating_sub(now);
                        now + rem_wall.scale(new_r / old_r)
                    }
                };
                f.end = end;
                link_free[j] = end;
            }
        }
        // Compute completion.
        if comp_running && comp_busy_until <= now {
            comp_running = false;
            events_processed += 1;
            // Advance the task cursor and fire boundary effects.
            match comp {
                CompTask::Fwd { iter, bucket } => {
                    if bucket + 1 < n {
                        comp = CompTask::Fwd {
                            iter,
                            bucket: bucket + 1,
                        };
                    } else {
                        // Backward window of this iteration opens.
                        if let Some(is) = by_window.get(&(iter, 1u8)) {
                            let is = is.clone();
                            make_ready!(is, comp_busy_until);
                        }
                        comp = CompTask::Bwd {
                            iter,
                            bucket: n - 1,
                        };
                    }
                }
                CompTask::Bwd { iter, bucket } => {
                    // This bucket's gradient is ready.
                    if let Some(is) = by_data.get(&(iter, bucket)) {
                        let is = is.clone();
                        make_ready!(is, comp_busy_until);
                    }
                    if bucket > 0 {
                        comp = CompTask::Bwd {
                            iter,
                            bucket: bucket - 1,
                        };
                    } else {
                        // Iteration end.
                        comp_iter_end[iter] = Some(comp_busy_until);
                        iter_ends.push(comp_busy_until);
                        if schedule.cycle[iter % cycle_len].update_at_end {
                            let u = updates_before[iter + 1] - 1;
                            update_pending_end[u] = Some(comp_busy_until);
                            if update_outstanding[u] == 0 {
                                update_times[u] = Some(comp_busy_until);
                            }
                        }
                        if iter + 1 < iters {
                            // Next iteration's forward window opens.
                            if let Some(is) = by_window.get(&(iter + 1, 0u8)) {
                                let is = is.clone();
                                make_ready!(is, comp_busy_until);
                            }
                            comp = CompTask::Fwd {
                                iter: iter + 1,
                                bucket: 0,
                            };
                        } else {
                            comp = CompTask::Done;
                        }
                    }
                }
                CompTask::Done => {}
            }
        }
    }

    // ---- Post-conditions & metrics. ----
    assert_eq!(iter_ends.len(), iters, "compute did not finish all iterations");
    for (oi, op) in ops.iter().enumerate() {
        assert!(op.done.is_some(), "op {oi} never executed: {op:?}");
    }
    let update_times: Vec<Micros> = update_times
        .into_iter()
        .enumerate()
        .map(|(u, t)| t.unwrap_or_else(|| panic!("update {u} never fired")))
        .collect();

    let total = iter_ends
        .last()
        .copied()
        .unwrap_or(Micros::ZERO)
        .max(update_times.last().copied().unwrap_or(Micros::ZERO))
        .max(
            ops.iter()
                .map(|o| o.done.expect("all ops completed"))
                .max()
                .unwrap_or(Micros::ZERO),
        );

    // Steady-state iteration time: average over post-warm-up iterations.
    let w = opts.warmup.min(iters - 1);
    let steady_span = iter_ends[iters - 1] - if w == 0 { Micros::ZERO } else { iter_ends[w - 1] };
    let steady_iter_time = steady_span / (iters - w) as u64;

    let compute_span_end = iter_ends[iters - 1];
    let compute_span_start = first_comp_start.unwrap_or(Micros::ZERO);
    let compute_bubbles = (compute_span_end - compute_span_start).saturating_sub(compute_busy);

    // Per-link busy = segment occupancy: home span durations finalized
    // at completion (incl. overlap contention under either model) plus
    // foreign hierarchical legs charged at dispatch. Uncontended flat
    // topologies reduce to the sum of executed wire times.
    let link_busy = seg_busy
        .into_iter()
        .enumerate()
        .map(|(k, busy)| (LinkId(k), busy))
        .collect();

    SimResult {
        scheme: schedule.scheme.clone(),
        iter_ends,
        update_times,
        total,
        compute_bubbles,
        steady_iter_time,
        link_busy,
        link_names: env.link_names(),
        link_codecs: env.link_codec_names(),
        contention: env.contention.name().to_string(),
        link_traffic,
        events_processed,
        peak_in_flight,
        fault_log,
        timeline,
    }
}
