//! The discrete-event engine executing schedules under WFBP rules.
//!
//! ## Indexed event queue
//!
//! The main loop is event-indexed: link completions live in a
//! `BinaryHeap` keyed on `(end, link, generation)` with lazy
//! invalidation — every time contention re-pricing moves a flight's
//! projected end, the link's generation is bumped and a fresh entry
//! pushed; stale entries are discarded at pop time. The heap's
//! `(end, link)` ordering reproduces the scan engine's chronological
//! completion order bit-for-bit, and `tests/engine_equivalence.rs` pins
//! [`simulate`] against the original scan loop
//! ([`super::reference::simulate_scan`]) on every preset × scheme ×
//! contention-model combination. Around the heap, the hot path is
//! arena-indexed: k-way re-pricing walks precomputed contention-group
//! member lists against a memoized [`ContentionStaircase`] instead of
//! re-deriving the penalty ramp per membership change, forward
//! dependency gates read flat arenas instead of `BTreeMap`s, the DDP
//! barrier gate tracks an incremental all-updates-fired prefix instead
//! of rescanning every earlier update per dispatch attempt, and span
//! recording is skipped entirely (no allocation, no construction) when
//! [`SimOptions::record_timeline`] is off.
//!
//! ## Contention: execution model
//!
//! Transfers are priced **uncontended** ([`ClusterEnv::wire_time_uncontended`])
//! and shared-NIC contention is charged only while a transfer actually
//! overlaps in-flight transfers of other links in the same contention
//! group — the planner's static rule ([`ClusterEnv::wire_time`]) is a
//! conservative estimate, not what execution charges. An idle group-mate
//! costs nothing, and only the group's fastest member is never slowed
//! (the paper's NCCL observation). Two execution models exist, selected
//! by [`crate::links::ContentionModel`] on the environment:
//!
//! * **Aggregate k-way sharing** (the default): every in-flight transfer
//!   carries its remaining uncontended wire time, and a paying transfer
//!   progresses at `1 / contention_factor(k, params)` of its uncontended
//!   rate while `k` group members are concurrently in flight
//!   ([`ClusterEnv::contention_factor`] — bit-for-bit the pairwise
//!   Table IV penalty at `k = 2`). The pricing is **piecewise**: at every
//!   membership change — a group member dispatching *or finalizing* —
//!   each member banks the progress made at its old rate and its
//!   projected end is re-derived from the remainder at the new `k`. A
//!   survivor therefore speeds back up the moment a group-mate finishes —
//!   the finalize-path re-check the old one-shot extension lacked.
//! * **Pairwise** (legacy): a paying transfer is slowed by the fixed
//!   pairwise penalty on the overlap window as known at dispatch time — a
//!   transfer starting second pays for the window it shares with flights
//!   already in progress, and a paying flight is one-shot *extended* when
//!   a group-mate starts alongside it. The charge is symmetric to first
//!   order only: it is never revisited when a mate finishes, and three
//!   concurrent transfers still pay the two-transfer penalty — which is
//!   why k-way replaced it as the default (`tests/contention_model.rs`
//!   pins both models).
//!
//! A fully-overlapped pair degrades identically under both models —
//! exactly as the static rule predicts. Home-link spans are recorded at
//! completion, once the end time is final.
//!
//! ## Per-segment streams
//!
//! Under a hierarchical [`crate::links::Topology`] a transfer's
//! node-local legs run on the designated intra link. The transfer's home
//! link stream serializes the whole collective; the foreign legs are
//! recorded as spans on their segment's stream and accounted into that
//! link's busy time, so Gantt rows and the per-link busy table show the
//! shared segment's occupancy.
//!
//! ## Codec encode overhead
//!
//! A link carrying a lossy [`crate::links::Codec`] already ships fewer
//! bytes through the (codec-aware) wire pricing; its encode/decode
//! kernels are charged **on the compute stream** here via
//! `ClusterEnv::encode_overhead_us` (every coded segment leg pays for
//! the tensor fraction it ships). Data-ready ops
//! (`grad_age == 0`) extend their producing bucket's backward task, so
//! their wire cannot start before the encode finished. Window ops
//! (delayed gradients, already encoded in spirit before their window
//! opens) charge their encode as aggregate compute at the window's head
//! — backward-window ops extend the iteration's first backward task,
//! forward-window ops the iteration's first forward task — **without**
//! delaying their own wire start: a planning-level approximation
//! (calibrating encode/compute overlap is an open ROADMAP sub-item).
//! Raw codecs charge nothing, keeping pre-codec schedules bit-for-bit
//! (`tests/codec_parity.rs`). Per-link raw-vs-wire byte counters and the
//! encode totals land in [`SimResult::link_traffic`].

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

use super::{Span, SpanKind, StreamId, Timeline};
use crate::faults::{FaultEvent, FaultSpec, FaultTrace, FlapAt};
use crate::links::{ClusterEnv, ContentionModel, ContentionStaircase, LinkId};
use crate::models::BucketProfile;
use crate::sched::{FwdDependency, Schedule, Stage};
use crate::util::Micros;

/// Simulation options.
#[derive(Clone, Debug)]
pub struct SimOptions {
    /// Number of training iterations to execute.
    pub iterations: usize,
    /// Iterations excluded from the steady-state iteration-time metric
    /// (queue warm-up).
    pub warmup: usize,
    /// Record the span timeline (disable for large metric-only sweeps).
    pub record_timeline: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            iterations: 50,
            warmup: 5,
            record_timeline: true,
        }
    }
}

/// Per-link compression traffic accounting (registry order in
/// [`SimResult::link_traffic`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LinkTraffic {
    /// Raw (uncompressed f32) gradient bytes offered to the link.
    pub raw_bytes: u64,
    /// Bytes actually on the wire after the link's own codec
    /// (home-link accounting; a hierarchical transfer's foreign legs are
    /// priced in wire time but not re-counted here).
    pub wire_bytes: u64,
    /// Encode/decode overhead charged on the compute stream for
    /// transfers homed on this link.
    pub encode: Micros,
}

/// Simulation outputs. All fields are integer/fixed-point, so `==`
/// compares two runs bit-for-bit — the equivalence suite and the bench
/// gate rely on this.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SimResult {
    pub scheme: String,
    /// Wall-clock end of each iteration's *compute* (monotone).
    pub iter_ends: Vec<Micros>,
    /// Time of each parameter update (update u at `update_times[u]`).
    pub update_times: Vec<Micros>,
    /// Total wall time until everything (compute, comm, updates) drained.
    pub total: Micros,
    /// Idle time in the compute stream (the paper's "bubbles").
    pub compute_bubbles: Micros,
    /// Average steady-state iteration time (excluding warm-up).
    pub steady_iter_time: Micros,
    /// Per-link busy time (segment occupancy), in registry order. Under
    /// a hierarchical topology a shared intra link also accumulates the
    /// node-local legs of transfers homed on other links.
    pub link_busy: Vec<(LinkId, Micros)>,
    /// Link names in registry order (for timeline/metric rendering).
    pub link_names: Vec<String>,
    /// Codec names in registry order.
    pub link_codecs: Vec<String>,
    /// Contention model the execution priced shared NICs under
    /// (`"pairwise"` | `"kway"`, from the environment).
    pub contention: String,
    /// Per-link compressed-vs-raw bytes and encode overhead, in registry
    /// order (home-link accounting: a transfer's bytes count on the link
    /// it was scheduled on).
    pub link_traffic: Vec<LinkTraffic>,
    /// Discrete events executed: link dispatches + link completions +
    /// compute-task dispatches + compute-task completions. The
    /// denominator-free workload measure the trajectory bench divides
    /// wall time by (events/sec), replacing the old spans-as-proxy count.
    pub events_processed: u64,
    /// Maximum number of transfers simultaneously in flight across all
    /// links (event-queue pressure indicator).
    pub peak_in_flight: usize,
    /// Every injected fault and drift-monitor alarm of the run, in
    /// scheduled-then-chronological order (empty without fault
    /// injection). Integer-only payloads, so replays stay `Eq`.
    pub fault_log: Vec<FaultEvent>,
    pub timeline: Timeline,
}

impl SimResult {
    /// Throughput in samples/second for the whole cluster.
    pub fn throughput(&self, batch_per_gpu: u32, workers: usize) -> f64 {
        let per_iter = batch_per_gpu as f64 * workers as f64;
        per_iter / self.steady_iter_time.as_secs_f64()
    }

    /// Bubble ratio = compute idle / total compute-stream span.
    pub fn bubble_ratio(&self) -> f64 {
        let busy = self.timeline.busy(StreamId::Compute);
        let span = busy + self.compute_bubbles;
        if span.is_zero() {
            0.0
        } else {
            self.compute_bubbles.ratio(span)
        }
    }
}

/// Internal: one materialized communication op instance.
#[derive(Clone, Debug)]
struct OpInst {
    bucket: usize,
    link: LinkId,
    iter: usize,
    stage: Stage,
    priority: i64,
    grad_age: usize,
    merged: usize,
    /// Global update index this op's gradients feed.
    update_idx: usize,
    /// Uncontended wire time of the full segment path on its home link.
    wire: Micros,
    /// Foreign segment leg (hierarchical topologies): the intra/inter
    /// link that also carries part of this transfer, and for how long.
    seg_extra: Option<(LinkId, Micros)>,
    /// Resolved readiness (None until known).
    ready: Option<Micros>,
    /// Finalized completion time, set at the completion event. None while
    /// queued or in flight — an in-flight transfer's *tentative* end
    /// lives in the engine's flight table, where overlap contention may
    /// still move it (later at a group-mate's dispatch, earlier at a
    /// group-mate's finalize under k-way), so nothing may gate on it
    /// before completion.
    done: Option<Micros>,
}

/// One in-flight transfer on a link. Under the k-way contention model the
/// flight is re-priced piecewise at every group membership change; under
/// the pairwise model `rem`/`factor` stay at their dispatch values and
/// only `end` is one-shot extended.
#[derive(Clone, Copy, Debug)]
struct Flight {
    /// Index into `ops`.
    oi: usize,
    /// Wire start (the home-link span is recorded at completion).
    start: Micros,
    /// Time of the last re-pricing event (dispatch, or any k-way
    /// membership change since).
    at: Micros,
    /// Uncontended wire time still owed as of `at`.
    rem: Micros,
    /// Current slowdown factor (1.0 = uncontended rate).
    factor: f64,
    /// Projected completion: `at + rem · factor`; final once it fires.
    end: Micros,
}

/// Completion-event queue: min-heap on `(projected end, link, generation)`
/// with lazy invalidation. An entry is live iff the link still has a
/// flight and the generation matches the link's current one; re-pricing
/// bumps the generation and pushes a fresh entry, leaving the stale one
/// to be discarded at pop time.
type EventHeap = BinaryHeap<Reverse<(Micros, usize, u64)>>;

/// Re-price every in-flight member of a contention group at event time
/// `t` (k-way model): bank the progress made at the old rate over
/// `[at, t)`, then project the remainder at the staircase factor for the
/// group's new concurrency `k`. Exempt (non-paying) members always run
/// at rate 1 — `factor(k ≤ 1) = 1` covers a payer flying alone. Only
/// members whose projected end actually moved get a fresh heap entry.
#[allow(clippy::too_many_arguments)]
fn reprice_group(
    stair: &[ContentionStaircase],
    ops: &[OpInst],
    members: &[usize],
    k: usize,
    pays: &[bool],
    flights: &mut [Option<Flight>],
    link_free: &mut [Micros],
    events: &mut EventHeap,
    event_gen: &mut [u64],
    t: Micros,
) {
    for &j in members {
        let Some(f) = flights[j].as_mut() else { continue };
        let elapsed = t.saturating_sub(f.at);
        if !elapsed.is_zero() {
            let done = if f.factor == 1.0 {
                elapsed
            } else {
                elapsed.scale(1.0 / f.factor)
            };
            f.rem = f.rem.saturating_sub(done);
        }
        f.at = f.at.max(t);
        f.factor = if pays[j] {
            stair[ops[f.oi].bucket].factor(k)
        } else {
            1.0
        };
        let end = f.at
            + if f.factor == 1.0 {
                f.rem
            } else {
                f.rem.scale(f.factor)
            };
        if end != f.end {
            f.end = end;
            link_free[j] = end;
            event_gen[j] += 1;
            events.push(Reverse((end, j, event_gen[j])));
        }
    }
}

/// Compute-task cursor: which task the compute stream runs next.
#[derive(Clone, Copy, Debug, PartialEq)]
enum CompTask {
    Fwd { iter: usize, bucket: usize },
    Bwd { iter: usize, bucket: usize },
    Done,
}

/// Execute `schedule` over `buckets` in `env` and return metrics.
///
/// Panics on malformed schedules (deadlock, missing gradient coverage for
/// a dependency) — the property tests rely on this to catch scheduler
/// bugs.
pub fn simulate(
    buckets: &[BucketProfile],
    schedule: &Schedule,
    env: &ClusterEnv,
    opts: &SimOptions,
) -> SimResult {
    run(buckets, schedule, env, opts, None)
}

/// Execute `schedule` under an injected fault scenario (stragglers,
/// compute jitter, link flaps, elastic membership — see
/// [`crate::faults`]).
///
/// Deterministic by construction: the spec is first compiled into a
/// [`FaultTrace`] — a pure function of `(spec, iterations, buckets,
/// schedule, env)` with no online randomness — so an identical call
/// replays bit-for-bit, on this engine and on
/// [`super::reference::simulate_scan_faulted`]
/// (`tests/fault_injection.rs`). `faults: None` is exactly
/// [`simulate`].
pub fn simulate_faulted(
    buckets: &[BucketProfile],
    schedule: &Schedule,
    env: &ClusterEnv,
    opts: &SimOptions,
    faults: Option<&FaultSpec>,
) -> SimResult {
    let trace =
        faults.map(|spec| FaultTrace::materialize(spec, opts.iterations, buckets, schedule, env));
    run(buckets, schedule, env, opts, trace.as_ref())
}

fn run(
    buckets: &[BucketProfile],
    schedule: &Schedule,
    env: &ClusterEnv,
    opts: &SimOptions,
    faults: Option<&FaultTrace>,
) -> SimResult {
    schedule.validate().expect("invalid schedule");
    let n = buckets.len();
    assert!(n > 0, "no buckets");
    let iters = opts.iterations;
    assert!(iters > 0);
    let n_links = env.n_links();
    assert!(n_links > 0, "environment has no links");

    // ---- Materialize op instances for every iteration. ----
    let cycle_len = schedule.cycle.len();
    // updates_before[t] = number of update markers in iterations < t.
    let mut updates_before = vec![0usize; iters + 1];
    for t in 0..iters {
        let plan = &schedule.cycle[t % cycle_len];
        updates_before[t + 1] = updates_before[t] + usize::from(plan.update_at_end);
    }
    let total_updates = updates_before[iters];

    let mut ops: Vec<OpInst> = Vec::new();
    // Codec bookkeeping: encode overhead charged on the compute stream —
    // keyed to the compute task whose end launches the op (see the
    // module docs) — plus per-link byte/overhead counters. Flat arenas
    // indexed `iter * n + bucket`.
    let mut enc_fwd: Vec<Micros> = vec![Micros::ZERO; iters];
    let mut enc_bwd: Vec<Micros> = vec![Micros::ZERO; iters * n];
    let mut link_traffic: Vec<LinkTraffic> = vec![LinkTraffic::default(); n_links];
    // Wire pricing and encode overhead only depend on (bucket, link) —
    // memoized so the per-iteration materialization loop stops paying a
    // segment-path walk (and its Vec allocation) per op instance.
    type SegPricing = (Micros, Option<(LinkId, Micros)>);
    let mut seg_memo: Vec<Option<SegPricing>> = vec![None; n * n_links];
    let mut enc_memo: Vec<Option<Micros>> = vec![None; n * n_links];
    let wire_ratio: Vec<f64> = (0..n_links)
        .map(|k| env.spec(LinkId(k)).codec.wire_ratio())
        .collect();
    for t in 0..iters {
        let plan = &schedule.cycle[t % cycle_len];
        for op in plan.all_ops() {
            assert!(
                !(op.grad_age == 0 && op.stage == Stage::Forward),
                "op for current-iter grad cannot launch in forward window"
            );
            assert!(
                op.link.index() < n_links,
                "op targets link {:?} but the environment registers only {n_links} links",
                op.link
            );
            let mi = op.bucket * n_links + op.link.index();
            let enc = *enc_memo[mi]
                .get_or_insert_with(|| env.encode_overhead_us(op.link, buckets[op.bucket].params));
            if !enc.is_zero() {
                if op.grad_age == 0 {
                    enc_bwd[t * n + op.bucket] += enc;
                } else if op.stage == Stage::Backward {
                    enc_bwd[t * n + (n - 1)] += enc;
                } else {
                    enc_fwd[t] += enc;
                }
            }
            let raw_bytes = buckets[op.bucket].params.saturating_mul(4);
            let traffic = &mut link_traffic[op.link.index()];
            traffic.raw_bytes += raw_bytes;
            traffic.wire_bytes += (raw_bytes as f64 * wire_ratio[op.link.index()]).round() as u64;
            traffic.encode += enc;
            // Uncontended segment-path pricing; the dispatch loop adds
            // the contention penalty for actually-overlapping windows.
            let (mut wire, mut seg_extra) = *seg_memo[mi].get_or_insert_with(|| {
                let segs = env.wire_segments(op.link, buckets[op.bucket].comm);
                let wire: Micros = segs.iter().map(|&(_, t)| t).sum();
                let seg_extra = segs.iter().find(|&&(l, _)| l != op.link).copied();
                (wire, seg_extra)
            });
            // Elastic membership: the declared cluster size of this
            // iteration rescales the whole segment path (ring-factor
            // ratio; see `ClusterEnv::elastic_wire_scale`).
            if let Some(ft) = faults {
                let s = ft.wire_scale_at(t);
                if s != 1.0 {
                    wire = wire.scale(s);
                    seg_extra = seg_extra.map(|(l, m)| (l, m.scale(s)));
                }
            }
            ops.push(OpInst {
                bucket: op.bucket,
                link: op.link,
                iter: t,
                stage: op.stage,
                priority: op.priority,
                grad_age: op.grad_age,
                merged: op.merged,
                update_idx: updates_before[t] + op.update_offset,
                wire,
                seg_extra,
                ready: None,
                done: None,
            });
        }
    }

    // Update bookkeeping: outstanding op count per update.
    let mut update_outstanding = vec![0usize; total_updates];
    for op in &ops {
        if op.update_idx < total_updates {
            update_outstanding[op.update_idx] += 1;
        }
        // Ops whose update lies beyond the horizon never gate anything.
    }

    // Coverage arena for PerBucket forward dependencies:
    // covers[iter * n + bucket] -> op index whose transfer includes that
    // iteration's gradient of that bucket (u32::MAX = uncovered).
    let mut covers: Vec<u32> = Vec::new();
    if schedule.fwd_dependency == FwdDependency::PerBucket {
        covers = vec![u32::MAX; iters * n];
        for (oi, op) in ops.iter().enumerate() {
            let newest = op.iter as i64 - op.grad_age as i64;
            for k in 0..op.merged {
                let covered_iter = newest - k as i64;
                if covered_iter >= 0 {
                    covers[covered_iter as usize * n + op.bucket] = oi as u32;
                }
            }
        }
    }

    // ---- Event-driven execution. ----
    // Resources: compute stream cursor + one server per registry link.
    let mut now = Micros::ZERO;
    let mut timeline = Timeline::default();
    if opts.record_timeline {
        // Exact span census: one home span per op, one per foreign
        // segment leg, fwd + bwd compute per (iter, bucket).
        let seg_spans = ops.iter().filter(|o| o.seg_extra.is_some()).count();
        timeline.spans.reserve(ops.len() + seg_spans + 2 * n * iters);
    }

    // Per-link ready pools (indexed by LinkId), min-heaps on
    // (priority, iter, bucket, op idx). Ops only leave a pool by being
    // dispatched, so no lazy deletion is needed.
    type ReadyPool = BinaryHeap<Reverse<(i64, usize, usize, usize)>>;
    let mut pool: Vec<ReadyPool> = vec![ReadyPool::new(); n_links];
    // Link busy-until (= the in-flight projection's end) and the
    // in-flight transfer itself, indexed by LinkId.
    let mut link_free: Vec<Micros> = vec![Micros::ZERO; n_links];
    let mut in_flight: Vec<Option<Flight>> = vec![None; n_links];
    // Contention bookkeeping: dense group ids, per-group member lists in
    // ascending link order (re-pricing and pairwise overlap walk only
    // the group), live in-flight counts per group, and whether each link
    // pays shared-NIC contention (the non-fastest-group-member rule).
    let mut group_ids: Vec<usize> = vec![0; n_links];
    let mut group_members: Vec<Vec<usize>> = Vec::new();
    {
        let mut dense: BTreeMap<usize, usize> = BTreeMap::new();
        for k in 0..n_links {
            let raw = env.spec(LinkId(k)).contention_group;
            let gid = *dense.entry(raw).or_insert_with(|| {
                group_members.push(Vec::new());
                group_members.len() - 1
            });
            group_ids[k] = gid;
            group_members[gid].push(k);
        }
    }
    let mut group_inflight: Vec<usize> = vec![0; group_members.len()];
    let max_group = group_members.iter().map(|m| m.len()).max().unwrap_or(1);
    let pays: Vec<bool> = (0..n_links).map(|k| env.contended(LinkId(k))).collect();
    // Per-bucket pricing memos: the k-way staircase is bit-for-bit
    // `contention_factor(k, params)` for every k up to the largest
    // group's size; the pairwise penalty is memoized separately because
    // recovering it as `staircase(2) − 1` would not round-trip in f64.
    let stair: Vec<ContentionStaircase> = if env.contention == ContentionModel::Kway {
        buckets
            .iter()
            .map(|b| env.contention_staircase(max_group, b.params))
            .collect()
    } else {
        Vec::new()
    };
    let penalty: Vec<f64> = if env.contention == ContentionModel::Pairwise {
        buckets
            .iter()
            .map(|b| env.contention_penalty(b.params))
            .collect()
    } else {
        Vec::new()
    };
    // Per-link segment occupancy (wire time carried by each link,
    // including foreign legs of hierarchical transfers + contention).
    let mut seg_busy: Vec<Micros> = vec![Micros::ZERO; n_links];

    // The completion-event queue (see `EventHeap`).
    let mut events: EventHeap = BinaryHeap::new();
    let mut event_gen: Vec<u64> = vec![0; n_links];
    // Scratch for the next-event search: live entries due at or before
    // `now` (zero-remainder flights) must not advance time — the scan
    // engine only ever advanced to strictly-future events — so they are
    // parked here and re-pushed.
    let mut held: Vec<(Micros, usize, u64)> = Vec::new();

    // Event accounting (identical counting points in the scan engine).
    let mut events_processed = 0u64;
    let mut cur_in_flight = 0usize;
    let mut peak_in_flight = 0usize;

    // ---- Fault-injection state. ----
    // Flaps fire as first-class events: the next unfired flap's time is
    // always a candidate in the next-event search, so the clock never
    // jumps past a flap and banking in-flight progress at `now` is
    // exact. `cur_ratio[k]` is link k's current wire-time multiplier.
    let flaps: &[FlapAt] = match faults {
        Some(ft) => ft.flaps.as_slice(),
        None => &[],
    };
    let mut next_flap = 0usize;
    let mut cur_ratio: Vec<f64> = vec![1.0; n_links];
    let mut fault_log: Vec<FaultEvent> = faults.map(|ft| ft.scheduled.clone()).unwrap_or_default();
    // Measured per-(iteration, link) home busy for the drift monitor
    // (only accounted while the monitor is armed).
    let mut iter_link_busy: Vec<Micros> = match faults {
        Some(ft) if ft.monitors_drift() => vec![Micros::ZERO; iters * n_links],
        _ => Vec::new(),
    };

    // Staleness-bound bookkeeping (incremental — a linear scan of all ops
    // per dispatch made the engine quadratic in iterations):
    // `iter_ops_remaining[it]` counts incomplete ops launched in iteration
    // `it`; `watermark` is the first iteration with incomplete ops;
    // `cum_max_done[it]` (valid for it < watermark) is the latest
    // completion time among all ops of iterations ≤ it.
    let mut iter_ops_remaining = vec![0usize; iters];
    for op in &ops {
        iter_ops_remaining[op.iter] += 1;
    }
    let mut iter_max_done = vec![Micros::ZERO; iters];
    let mut cum_max_done = vec![Micros::ZERO; iters];
    let mut watermark = 0usize;
    while watermark < iters && iter_ops_remaining[watermark] == 0 {
        cum_max_done[watermark] = if watermark == 0 {
            Micros::ZERO
        } else {
            cum_max_done[watermark - 1]
        };
        watermark += 1;
    }

    // Compute bookkeeping.
    let mut comp = CompTask::Fwd { iter: 0, bucket: 0 };
    let mut comp_busy_until = Micros::ZERO;
    let mut comp_running = false;
    let mut compute_busy = Micros::ZERO;
    let mut first_comp_start: Option<Micros> = None;
    let mut iter_ends: Vec<Micros> = Vec::with_capacity(iters);
    // Compute end of iteration t (backward fully done).
    let mut comp_iter_end: Vec<Option<Micros>> = vec![None; iters];
    let mut update_times: Vec<Option<Micros>> = vec![None; total_updates];
    let mut update_pending_end: Vec<Option<Micros>> = vec![None; total_updates];
    // Incremental DDP-barrier gate: `upd_prefix` = length of the maximal
    // prefix of `update_times` that has fired; `prefix_max[u]` = latest
    // fire time among updates 0..=u (valid for u < upd_prefix). The gate
    // on "all updates of iterations < t" becomes two array reads instead
    // of a walk over every earlier update per dispatch attempt.
    let mut upd_prefix = 0usize;
    let mut prefix_max: Vec<Micros> = vec![Micros::ZERO; total_updates];
    macro_rules! advance_upd_prefix {
        () => {
            while upd_prefix < total_updates {
                let Some(t) = update_times[upd_prefix] else { break };
                let prev = if upd_prefix == 0 {
                    Micros::ZERO
                } else {
                    prefix_max[upd_prefix - 1]
                };
                prefix_max[upd_prefix] = prev.max(t);
                upd_prefix += 1;
            }
        };
    }

    // Window-open / data-ready arenas (consumed exactly once each, so the
    // op lists are moved out instead of cloned): fwd/bwd window per iter,
    // data-ready per (iter, bucket).
    let mut fwd_open: Vec<Vec<u32>> = vec![Vec::new(); iters];
    let mut bwd_open: Vec<Vec<u32>> = vec![Vec::new(); iters];
    let mut data_ready: Vec<Vec<u32>> = vec![Vec::new(); iters * n];
    for (oi, op) in ops.iter().enumerate() {
        if op.grad_age == 0 {
            data_ready[op.iter * n + op.bucket].push(oi as u32);
        } else if op.stage == Stage::Forward {
            fwd_open[op.iter].push(oi as u32);
        } else {
            bwd_open[op.iter].push(oi as u32);
        }
    }

    // Helper: make ops ready and insert into pools.
    macro_rules! make_ready {
        ($indices:expr, $time:expr) => {
            for oi in $indices {
                let oi = oi as usize;
                let op = &mut ops[oi];
                debug_assert!(op.ready.is_none());
                op.ready = Some($time);
                pool[op.link.index()].push(Reverse((op.priority, op.iter, op.bucket, oi)));
            }
        };
    }

    // Iteration 0 forward window opens at t=0.
    make_ready!(std::mem::take(&mut fwd_open[0]), Micros::ZERO);

    let mut safety = 0u64;
    let safety_cap = 10_000_000u64 + ops.len() as u64 * 16;

    loop {
        safety += 1;
        assert!(safety < safety_cap, "simulator livelock — scheduler bug?");

        // --- 1. Dispatch links: serve best ready op if free. ---
        // Ascending link order — under the pairwise model the dispatch
        // order determines which overlap windows each charge sees.
        for k in 0..n_links {
            if in_flight[k].is_some() || pool[k].is_empty() {
                continue;
            }
            // Ops are inserted into the pool at the very event that made
            // them ready (ready ≤ now always), so the best candidate is
            // simply the heap minimum in (priority, iter, bucket) order.
            let Reverse((_, _, _, oi)) = pool[k].pop().expect("non-empty pool");
            debug_assert!(ops[oi].ready.is_some_and(|r| r <= now));
            let start = ops[oi].ready.expect("pooled op is ready").max(link_free[k]);
            // A degraded (flapped) link prices the whole transfer at its
            // current ratio; a mid-flight flap re-prices the remainder
            // piecewise at the flap event below.
            let r = cur_ratio[k];
            let wire = if r == 1.0 {
                ops[oi].wire
            } else {
                ops[oi].wire.scale(r)
            };
            events_processed += 1;
            cur_in_flight += 1;
            peak_in_flight = peak_in_flight.max(cur_in_flight);
            let g = group_ids[k];
            // `done` stays None until the completion event; while in
            // flight the tentative end lives in the flight table and
            // `link_free`, where contention may still move it.
            match env.contention {
                ContentionModel::Kway => {
                    in_flight[k] = Some(Flight {
                        oi,
                        start,
                        at: start,
                        rem: wire,
                        factor: 1.0,
                        end: start + wire,
                    });
                    link_free[k] = start + wire;
                    event_gen[k] += 1;
                    events.push(Reverse((start + wire, k, event_gen[k])));
                    // Aggregate sharing: this dispatch changes the
                    // group's concurrency, so the whole group is
                    // re-priced — the new transfer picks up the factor
                    // for the current k, and every paying group-mate
                    // banks its progress so far and slows down for the
                    // larger k.
                    group_inflight[g] += 1;
                    reprice_group(
                        &stair,
                        &ops,
                        &group_members[g],
                        group_inflight[g],
                        &pays,
                        &mut in_flight,
                        &mut link_free,
                        &mut events,
                        &mut event_gen,
                        start,
                    );
                }
                ContentionModel::Pairwise => {
                    let mut end = start + wire;
                    // One-shot overlap charge: a paying link is slowed by
                    // the pairwise penalty for the window it shares with
                    // in-flight same-group transfers.
                    if pays[k] && !wire.is_zero() {
                        let mut overlap = Micros::ZERO;
                        for &j in &group_members[g] {
                            if j == k {
                                continue;
                            }
                            let Some(f) = in_flight[j] else { continue };
                            let lo = start.max(f.start);
                            let hi = end.min(f.end);
                            if hi > lo {
                                overlap += hi - lo;
                            }
                        }
                        if !overlap.is_zero() {
                            end += overlap.scale(penalty[ops[oi].bucket]);
                        }
                    }
                    link_free[k] = end;
                    in_flight[k] = Some(Flight {
                        oi,
                        start,
                        at: start,
                        rem: wire,
                        factor: 1.0,
                        end,
                    });
                    event_gen[k] += 1;
                    events.push(Reverse((end, k, event_gen[k])));
                    group_inflight[g] += 1;
                    // Symmetry: this transfer also slows down any
                    // *paying* group-mate already in flight — extend it
                    // by the penalty on the newly shared window (the
                    // fastest member never pays, mirroring the
                    // dispatch-time charge above). Both directions
                    // measure the window against the ends as known at
                    // this dispatch, so the charge is symmetric to first
                    // order only; the k-way model re-prices these windows
                    // exactly instead.
                    for &j in &group_members[g] {
                        if j == k || !pays[j] {
                            continue;
                        }
                        let Some(fj) = in_flight[j] else { continue };
                        let lo = start.max(fj.start);
                        let hi = end.min(fj.end);
                        if hi > lo {
                            let extra = (hi - lo).scale(penalty[ops[fj.oi].bucket]);
                            if !extra.is_zero() {
                                link_free[j] = fj.end + extra;
                                in_flight[j].as_mut().expect("flight j is in flight").end = fj.end + extra;
                                event_gen[j] += 1;
                                events.push(Reverse((fj.end + extra, j, event_gen[j])));
                            }
                        }
                    }
                }
            }
            // Foreign segment leg: record its occupancy on the segment's
            // own stream (hierarchical topologies). The home-link span is
            // recorded at completion, once the end can no longer move.
            if let Some((seg_link, seg_t)) = ops[oi].seg_extra {
                seg_busy[seg_link.index()] += seg_t;
                if opts.record_timeline {
                    timeline.spans.push(Span {
                        stream: StreamId::Link(seg_link),
                        kind: SpanKind::Comm {
                            iter: ops[oi].iter,
                            bucket: ops[oi].bucket,
                            merged: ops[oi].merged,
                        },
                        start,
                        end: start + seg_t,
                    });
                }
            }
        }

        // --- 2. Dispatch compute if idle and dependencies resolved. ---
        // One attempt per event round, like the scan engine; the gates
        // only change at completion events. (Dispatches never enable
        // other dispatches — readiness and dependency resolution both
        // come from completions — so one links-then-compute pass per
        // round reproduces the scan engine's fixed-point exactly.)
        if !comp_running {
            match comp {
                CompTask::Fwd { iter, bucket } => {
                    // Dependency gate for the very first task of the fwd.
                    let mut dep_time = Some(if iter == 0 {
                        Micros::ZERO
                    } else {
                        comp_iter_end[iter - 1].expect("prev iter must be done")
                    });
                    // Staleness back-pressure: every op launched in
                    // iterations ≤ iter − max_outstanding must be done
                    // (the two-queue memory bound; see Schedule docs).
                    if bucket == 0 && iter >= schedule.max_outstanding_iters.saturating_add(1) {
                        let horizon = iter - schedule.max_outstanding_iters;
                        if watermark >= horizon {
                            dep_time = dep_time.map(|d| d.max(cum_max_done[horizon - 1]));
                        } else {
                            dep_time = None;
                        }
                    }
                    match schedule.fwd_dependency {
                        FwdDependency::Barrier => {
                            if bucket == 0 && iter > 0 {
                                // All updates of iterations < iter: fired
                                // iff the all-fired prefix covers them,
                                // and their max is the prefix max.
                                let need = updates_before[iter];
                                if need > 0 {
                                    if upd_prefix >= need {
                                        dep_time = dep_time.map(|d| d.max(prefix_max[need - 1]));
                                    } else {
                                        dep_time = None;
                                    }
                                }
                            }
                        }
                        FwdDependency::PerBucket => {
                            if iter > 0 {
                                let oi = covers[(iter - 1) * n + bucket];
                                assert!(
                                    oi != u32::MAX,
                                    "no op covers grad (iter {}, bucket {bucket})",
                                    iter - 1
                                );
                                // `done` is final only after the
                                // completion event — an in-flight op's
                                // tentative end may still be extended by
                                // contention, so wait rather than gate on
                                // it (same wall-clock start either way).
                                match ops[oi as usize].done {
                                    Some(t) => dep_time = dep_time.map(|d| d.max(t)),
                                    None => dep_time = None,
                                }
                            }
                        }
                        FwdDependency::None => {}
                    }
                    if let Some(dep) = dep_time {
                        let start = now.max(dep).max(comp_busy_until);
                        // Forward-window encode kernels run at the head
                        // of the iteration's compute (zero without
                        // lossy codecs).
                        let mut dur = buckets[bucket].fwd;
                        if bucket == 0 {
                            dur += enc_fwd[iter];
                        }
                        // Injected compute jitter / straggler stretch.
                        if let Some(ft) = faults {
                            dur += ft.fwd_extra[iter * n + bucket];
                        }
                        let end = start + dur;
                        first_comp_start.get_or_insert(start);
                        compute_busy += dur;
                        events_processed += 1;
                        if opts.record_timeline {
                            timeline.spans.push(Span {
                                stream: StreamId::Compute,
                                kind: SpanKind::Fwd { iter, bucket },
                                start,
                                end,
                            });
                        }
                        comp_busy_until = end;
                        comp_running = true;
                    }
                }
                CompTask::Bwd { iter, bucket } => {
                    let start = now.max(comp_busy_until);
                    // Encode kernels of ops this backward task launches
                    // extend it — the wire cannot start before its
                    // gradient is compressed.
                    let mut dur = buckets[bucket].bwd + enc_bwd[iter * n + bucket];
                    // Injected compute jitter / straggler stretch.
                    if let Some(ft) = faults {
                        dur += ft.bwd_extra[iter * n + bucket];
                    }
                    let end = start + dur;
                    compute_busy += dur;
                    events_processed += 1;
                    if opts.record_timeline {
                        timeline.spans.push(Span {
                            stream: StreamId::Compute,
                            kind: SpanKind::Bwd { iter, bucket },
                            start,
                            end,
                        });
                    }
                    comp_busy_until = end;
                    comp_running = true;
                }
                CompTask::Done => {}
            }
        }

        // --- 3. Advance time to the next event (strictly future). ---
        // Peek past stale heap entries; live entries due at ≤ now (a
        // zero-remainder flight dispatched this round) are parked and
        // re-pushed — they fire only once something else advances the
        // clock, exactly like the scan engine's `t > now` rule.
        let mut next_time: Option<Micros> = None;
        while let Some(&Reverse((t, k, g))) = events.peek() {
            if event_gen[k] != g || in_flight[k].is_none() {
                events.pop();
                continue;
            }
            if t <= now {
                held.push(events.pop().expect("peeked entry").0);
                continue;
            }
            next_time = Some(t);
            break;
        }
        for h in held.drain(..) {
            events.push(Reverse(h));
        }
        if comp_running && comp_busy_until > now {
            next_time = Some(next_time.map_or(comp_busy_until, |t| t.min(comp_busy_until)));
        }
        // The next unfired flap is always a candidate event, so the
        // clock lands exactly on it (never jumps it) and the mid-flight
        // re-pricing below banks progress at the precise flap instant.
        if next_flap < flaps.len() {
            let fa = flaps[next_flap].at;
            if fa > now {
                next_time = Some(next_time.map_or(fa, |t| t.min(fa)));
            }
        }
        let Some(t) = next_time else {
            break; // nothing running, nothing pending
        };
        now = t;

        // --- 4. Fire completions at `now`. ---
        // Link completions — chronologically (earliest projected end
        // first, ties by link index: the heap key), because under the
        // k-way model every finalize re-prices the survivors of its
        // contention group: they speed back up from the departure
        // instant, and their shortened projections (pushed as fresh heap
        // entries) may themselves fall due within this same round.
        while let Some(&Reverse((done_t, k, g))) = events.peek() {
            if event_gen[k] != g || in_flight[k].is_none() {
                events.pop();
                continue;
            }
            if done_t > now {
                break;
            }
            events.pop();
            let f = in_flight[k].take().expect("live event has a flight");
            debug_assert_eq!(f.end, done_t);
            let oi = f.oi;
            events_processed += 1;
            cur_in_flight -= 1;
            group_inflight[group_ids[k]] -= 1;
            // Finalize: contention can no longer move this transfer.
            ops[oi].done = Some(done_t);
            seg_busy[k] += done_t - f.start;
            if !iter_link_busy.is_empty() {
                // Drift monitor: measured home busy of the op's launch
                // iteration (the full home span — comparable to the
                // planner's `wire_time`, which also prices the whole
                // segment path plus static contention).
                iter_link_busy[ops[oi].iter * n_links + k] += done_t - f.start;
            }
            if opts.record_timeline {
                timeline.spans.push(Span {
                    stream: StreamId::Link(LinkId(k)),
                    kind: SpanKind::Comm {
                        iter: ops[oi].iter,
                        bucket: ops[oi].bucket,
                        merged: ops[oi].merged,
                    },
                    start: f.start,
                    end: done_t,
                });
            }
            // Advance the staleness watermark.
            let op_iter = ops[oi].iter;
            iter_ops_remaining[op_iter] -= 1;
            iter_max_done[op_iter] = iter_max_done[op_iter].max(done_t);
            while watermark < iters && iter_ops_remaining[watermark] == 0 {
                let prev = if watermark == 0 {
                    Micros::ZERO
                } else {
                    cum_max_done[watermark - 1]
                };
                cum_max_done[watermark] = prev.max(iter_max_done[watermark]);
                // Every comm op of `watermark` has completed: its
                // measured per-link busy is final — compare against the
                // planned busy of its cycle slot.
                if let Some(ft) = faults {
                    if !iter_link_busy.is_empty() {
                        ft.drift_check(
                            watermark,
                            &iter_link_busy[watermark * n_links..(watermark + 1) * n_links],
                            &mut fault_log,
                        );
                    }
                }
                watermark += 1;
            }
            let u = ops[oi].update_idx;
            if u < total_updates {
                update_outstanding[u] -= 1;
                if update_outstanding[u] == 0 {
                    if let Some(iter_end) = update_pending_end[u] {
                        update_times[u] = Some(iter_end.max(done_t));
                        advance_upd_prefix!();
                    }
                }
            }
            // Finalize-path re-pricing: the departure shrinks the
            // group's concurrency, so surviving paying members speed
            // back up from `done_t` (k-way only — the pairwise model
            // deliberately never revisits its one-shot charge).
            if env.contention == ContentionModel::Kway {
                let g = group_ids[k];
                reprice_group(
                    &stair,
                    &ops,
                    &group_members[g],
                    group_inflight[g],
                    &pays,
                    &mut in_flight,
                    &mut link_free,
                    &mut events,
                    &mut event_gen,
                    done_t,
                );
            }
        }
        // Link flaps due at `now` (after completions: a transfer whose
        // projected end is exactly `now` completes at its pre-flap
        // pricing, which is exact — the flap takes effect from `now`
        // on). The link's wire-time ratio changes and its in-flight
        // transfer is re-priced piecewise: bank the progress made so
        // far, re-project the remainder at the new ratio — the same
        // bank-then-reproject arithmetic k-way membership changes use.
        // Pairwise flights carry one-shot overlap extensions not
        // derivable from `rem`, so their remaining wall-clock window is
        // rescaled one-shot instead, consistent with that model's
        // never-revisit semantics.
        while next_flap < flaps.len() && flaps[next_flap].at <= now {
            let fl = flaps[next_flap];
            next_flap += 1;
            events_processed += 1;
            let j = fl.link;
            if j >= n_links {
                continue;
            }
            let old_r = cur_ratio[j];
            let new_r = fl.ratio;
            cur_ratio[j] = new_r;
            if new_r == old_r {
                continue;
            }
            if let Some(f) = in_flight[j].as_mut() {
                let end = match env.contention {
                    ContentionModel::Kway => {
                        let elapsed = now.saturating_sub(f.at);
                        if !elapsed.is_zero() {
                            let done = if f.factor == 1.0 {
                                elapsed
                            } else {
                                elapsed.scale(1.0 / f.factor)
                            };
                            f.rem = f.rem.saturating_sub(done);
                        }
                        f.at = f.at.max(now);
                        // `rem` is owed wire time priced at the old
                        // ratio; the same physical bytes re-price by
                        // new/old.
                        f.rem = f.rem.scale(new_r / old_r);
                        f.at + if f.factor == 1.0 {
                            f.rem
                        } else {
                            f.rem.scale(f.factor)
                        }
                    }
                    ContentionModel::Pairwise => {
                        let rem_wall = f.end.saturating_sub(now);
                        now + rem_wall.scale(new_r / old_r)
                    }
                };
                if end != f.end {
                    f.end = end;
                    link_free[j] = end;
                    event_gen[j] += 1;
                    events.push(Reverse((end, j, event_gen[j])));
                }
            }
        }
        // Compute completion.
        if comp_running && comp_busy_until <= now {
            comp_running = false;
            events_processed += 1;
            // Advance the task cursor and fire boundary effects.
            match comp {
                CompTask::Fwd { iter, bucket } => {
                    if bucket + 1 < n {
                        comp = CompTask::Fwd {
                            iter,
                            bucket: bucket + 1,
                        };
                    } else {
                        // Backward window of this iteration opens.
                        make_ready!(std::mem::take(&mut bwd_open[iter]), comp_busy_until);
                        comp = CompTask::Bwd {
                            iter,
                            bucket: n - 1,
                        };
                    }
                }
                CompTask::Bwd { iter, bucket } => {
                    // This bucket's gradient is ready.
                    make_ready!(std::mem::take(&mut data_ready[iter * n + bucket]), comp_busy_until);
                    if bucket > 0 {
                        comp = CompTask::Bwd {
                            iter,
                            bucket: bucket - 1,
                        };
                    } else {
                        // Iteration end.
                        comp_iter_end[iter] = Some(comp_busy_until);
                        iter_ends.push(comp_busy_until);
                        if schedule.cycle[iter % cycle_len].update_at_end {
                            let u = updates_before[iter + 1] - 1;
                            update_pending_end[u] = Some(comp_busy_until);
                            if update_outstanding[u] == 0 {
                                update_times[u] = Some(comp_busy_until);
                                advance_upd_prefix!();
                            }
                        }
                        if iter + 1 < iters {
                            // Next iteration's forward window opens.
                            make_ready!(std::mem::take(&mut fwd_open[iter + 1]), comp_busy_until);
                            comp = CompTask::Fwd {
                                iter: iter + 1,
                                bucket: 0,
                            };
                        } else {
                            comp = CompTask::Done;
                        }
                    }
                }
                CompTask::Done => {}
            }
        }
    }

    // ---- Post-conditions & metrics. ----
    assert_eq!(iter_ends.len(), iters, "compute did not finish all iterations");
    for (oi, op) in ops.iter().enumerate() {
        assert!(op.done.is_some(), "op {oi} never executed: {op:?}");
    }
    let update_times: Vec<Micros> = update_times
        .into_iter()
        .enumerate()
        .map(|(u, t)| t.unwrap_or_else(|| panic!("update {u} never fired")))
        .collect();

    let total = iter_ends
        .last()
        .copied()
        .unwrap_or(Micros::ZERO)
        .max(update_times.last().copied().unwrap_or(Micros::ZERO))
        .max(
            ops.iter()
                .map(|o| o.done.expect("all ops completed"))
                .max()
                .unwrap_or(Micros::ZERO),
        );

    // Steady-state iteration time: average over post-warm-up iterations.
    let w = opts.warmup.min(iters - 1);
    let steady_span = iter_ends[iters - 1] - if w == 0 { Micros::ZERO } else { iter_ends[w - 1] };
    let steady_iter_time = steady_span / (iters - w) as u64;

    let compute_span_end = iter_ends[iters - 1];
    let compute_span_start = first_comp_start.unwrap_or(Micros::ZERO);
    let compute_bubbles = (compute_span_end - compute_span_start).saturating_sub(compute_busy);

    // Per-link busy = segment occupancy: home span durations finalized
    // at completion (incl. overlap contention under either model) plus
    // foreign hierarchical legs charged at dispatch. Uncontended flat
    // topologies reduce to the sum of executed wire times.
    let link_busy = seg_busy
        .into_iter()
        .enumerate()
        .map(|(k, busy)| (LinkId(k), busy))
        .collect();

    SimResult {
        scheme: schedule.scheme.clone(),
        iter_ends,
        update_times,
        total,
        compute_bubbles,
        steady_iter_time,
        link_busy,
        link_names: env.link_names(),
        link_codecs: env.link_codec_names(),
        contention: env.contention.name().to_string(),
        link_traffic,
        events_processed,
        peak_in_flight,
        fault_log,
        timeline,
    }
}
