//! Discrete-event cluster simulator — the execution substrate replacing
//! the paper's 16-GPU testbed (see DESIGN.md §Substitutions).
//!
//! The simulator executes a [`Schedule`] over a bucket profile set under
//! exactly the WFBP dependency rules of paper §II.A:
//!
//! * one serial **compute stream** per data-parallel group (forward
//!   bucket 0‥N−1, then backward N−1‥0 each iteration);
//! * one serial **communication stream per registry link** (the paper's
//!   NCCL + gloo pair, or any N-link topology from
//!   [`crate::links::ClusterEnv`]), served by op priority among *ready*
//!   ops (non-preemptive); under a hierarchical
//!   [`crate::links::Topology`] a transfer's node-local segment legs are
//!   additionally recorded on the shared intra link's stream, and
//!   shared-NIC contention is charged only while same-group transfers
//!   actually overlap — by default as an aggregate k-way bandwidth split
//!   re-priced at every dispatch/finalize event, or as the legacy
//!   pairwise one-shot penalty
//!   ([`crate::links::ContentionModel`]; see `engine` docs);
//! * a gradient's communication may not start before its producing
//!   backward finishes (unless it carries an older iteration's gradient —
//!   DeFT's delayed updates);
//! * forward of iteration t+1 depends on gradient communication per the
//!   scheme's [`FwdDependency`] (DDP barrier / per-bucket / none).
//!
//! Outputs: per-iteration wall times, compute-stream bubble time, update
//! times, and a full span timeline for the Gantt renderings of paper
//! Figs. 11–13 and 16.

mod convergence;
mod engine;
mod reference;

pub use convergence::{training_curve, ConvergenceModel, TrainingCurve};
pub use engine::{simulate, simulate_faulted, LinkTraffic, SimOptions, SimResult};
pub use reference::{simulate_scan, simulate_scan_faulted};

use crate::links::LinkId;
use crate::util::Micros;

/// Which resource a timeline span occupied.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamId {
    Compute,
    Link(LinkId),
}

/// What the span did.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Forward compute of `bucket` in `iter`.
    Fwd { iter: usize, bucket: usize },
    /// Backward compute of `bucket` in `iter`.
    Bwd { iter: usize, bucket: usize },
    /// Communication of `bucket` launched in `iter`, carrying `merged`
    /// iterations' gradients.
    Comm {
        iter: usize,
        bucket: usize,
        merged: usize,
    },
}

/// One occupied interval on a stream.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Span {
    pub stream: StreamId,
    pub kind: SpanKind,
    pub start: Micros,
    pub end: Micros,
}

impl Span {
    pub fn duration(&self) -> Micros {
        self.end - self.start
    }
}

/// Full execution trace of a simulation.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Timeline {
    pub spans: Vec<Span>,
}

impl Timeline {
    /// Spans on one stream, in start order.
    pub fn on_stream(&self, stream: StreamId) -> Vec<&Span> {
        let mut v: Vec<&Span> = self.spans.iter().filter(|s| s.stream == stream).collect();
        v.sort_by_key(|s| (s.start, s.end));
        v
    }

    /// Total busy time on a stream.
    pub fn busy(&self, stream: StreamId) -> Micros {
        self.spans
            .iter()
            .filter(|s| s.stream == stream)
            .map(|s| s.duration())
            .sum()
    }

    /// Idle (bubble) time on a stream between its first and last span.
    pub fn bubbles(&self, stream: StreamId) -> Micros {
        let spans = self.on_stream(stream);
        if spans.is_empty() {
            return Micros::ZERO;
        }
        let mut idle = Micros::ZERO;
        let mut cursor = spans[0].start;
        for s in &spans {
            if s.start > cursor {
                idle += s.start - cursor;
            }
            cursor = cursor.max(s.end);
        }
        idle
    }

    pub fn end_time(&self) -> Micros {
        self.spans
            .iter()
            .map(|s| s.end)
            .max()
            .unwrap_or(Micros::ZERO)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeline_busy_and_bubbles() {
        let t = Timeline {
            spans: vec![
                Span {
                    stream: StreamId::Compute,
                    kind: SpanKind::Fwd { iter: 0, bucket: 0 },
                    start: Micros(0),
                    end: Micros(10),
                },
                Span {
                    stream: StreamId::Compute,
                    kind: SpanKind::Fwd { iter: 0, bucket: 1 },
                    start: Micros(15),
                    end: Micros(20),
                },
                Span {
                    stream: StreamId::Link(LinkId(0)),
                    kind: SpanKind::Comm {
                        iter: 0,
                        bucket: 0,
                        merged: 1,
                    },
                    start: Micros(10),
                    end: Micros(30),
                },
            ],
        };
        assert_eq!(t.busy(StreamId::Compute), Micros(15));
        assert_eq!(t.bubbles(StreamId::Compute), Micros(5));
        assert_eq!(t.busy(StreamId::Link(LinkId(0))), Micros(20));
        assert_eq!(t.end_time(), Micros(30));
    }
}
