//! The Preserver — DeFT's accuracy-preserving mechanism (paper §IV.C).
//!
//! DeFT's delayed updates make training equivalent to a **variable batch
//! size sequence**: an update that merges `k` iterations' gradients is an
//! update with batch `k·B` (gradient accumulation). The Preserver
//! quantifies the convergence impact of that sequence with the
//! Gaussian-random-walk-with-rebound model of Yin et al. (KDD'17, paper
//! ref [25]) and drives a feedback loop: if the expected-state ratio
//! between DeFT's sequence `O_D` and the fixed-batch baseline `O_B`
//! leaves `[1−ε, 1+ε]`, the Solver's knapsack capacity is enlarged
//! (allowing more communication per iteration ⇒ higher update frequency)
//! and the schedule is re-solved, up to 10 times.

use crate::util::mathx::phi;

/// Parameters of the Gaussian walk at one training point.
#[derive(Clone, Copy, Debug)]
pub struct WalkParams {
    /// Current state s_t (training loss).
    pub s_t: f64,
    /// Objective value S* (loss floor).
    pub s_star: f64,
    /// Learning rate η.
    pub eta: f64,
    /// μ_t — mean step (square sum of the gradient at iteration t).
    pub mu_t: f64,
    /// σ_t — per-sample noise scale (× covariance), before the 1/√B
    /// batch reduction.
    pub sigma_t: f64,
}

impl WalkParams {
    /// Inject a lossy gradient codec's relative error `e ∈ [0, 1)` (see
    /// [`crate::links::Codec::error`]): the compressed gradient's useful
    /// drift shrinks to `μ_t · (1 − e)` while its noise grows to
    /// `σ_t · (1 + e)`. `e = 0` is the exact identity, so raw codecs
    /// change nothing bit-for-bit.
    pub fn with_gradient_error(mut self, e: f64) -> WalkParams {
        assert!((0.0..1.0).contains(&e), "gradient error {e} must be in [0, 1)");
        self.mu_t *= 1.0 - e;
        self.sigma_t *= 1.0 + e;
        self
    }
}

/// Expected next state `E_B^{s_t}(s_{t+1})` for batch size `b` — the
/// paper's Equation (1):
///
/// ```text
/// E = (s_t − S* − η·μ_t)·{Φ(a) − Φ(−a)} + η·σ_B·√(2/π)·exp(−a²/2) + S*
/// a = (s_t − S* − η·μ_t) / (η·σ_B),   σ_B = σ_t/√B
/// ```
///
/// The walk either descends toward S* or rebounds off it; larger batches
/// shrink the noise term σ_B and tighten the expectation.
pub fn expected_next_state(p: &WalkParams, b: f64) -> f64 {
    assert!(b >= 1.0, "batch size must be ≥ 1");
    let sigma_b = p.sigma_t / b.sqrt();
    let drift = p.s_t - p.s_star - p.eta * p.mu_t;
    if sigma_b <= 0.0 || p.eta <= 0.0 {
        // Deterministic limit: pure descent with rebound.
        return (drift).abs() + p.s_star;
    }
    let a = drift / (p.eta * sigma_b);
    let gauss_term = p.eta * sigma_b * (2.0 / std::f64::consts::PI).sqrt() * (-0.5 * a * a).exp();
    drift * (phi(a) - phi(-a)) + gauss_term + p.s_star
}

/// Evolve the expected state over a batch-size sequence, returning each
/// intermediate expectation (length = sequence length) — the rows of the
/// paper's Table V.
///
/// Gradient magnitude and noise are re-estimated at each step
/// proportionally to the distance from the floor (`μ, σ ∝ s_t − S*`),
/// matching the contraction visible in Table V's E_B column.
pub fn evolve_sequence(start: &WalkParams, batch_sizes: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(batch_sizes.len());
    let mut s = start.s_t;
    // Ratios fixed from the starting point.
    let mu_ratio = if start.s_t > start.s_star {
        start.mu_t / (start.s_t - start.s_star)
    } else {
        0.0
    };
    let sigma_ratio = if start.s_t > start.s_star {
        start.sigma_t / (start.s_t - start.s_star)
    } else {
        0.0
    };
    for &b in batch_sizes {
        let p = WalkParams {
            s_t: s,
            s_star: start.s_star,
            eta: start.eta,
            mu_t: mu_ratio * (s - start.s_star),
            sigma_t: sigma_ratio * (s - start.s_star),
        };
        s = expected_next_state(&p, b);
        out.push(s);
    }
    out
}

/// Convergence comparison between the baseline order `O_B` (N updates of
/// batch `B`) and DeFT's order `O_D` (updates of `k_i·B`, Σk_i = N).
#[derive(Clone, Debug)]
pub struct ConvergenceReport {
    /// E over the baseline sequence (length N).
    pub baseline: Vec<f64>,
    /// E over DeFT's sequence (length m ≤ N).
    pub deft: Vec<f64>,
    /// Final-expectation ratio E_OB / E_OD (paper: must sit in [1−ε,1+ε]).
    pub ratio: f64,
}

/// Quantify DeFT's schedule against the fixed-batch baseline.
///
/// `multipliers` is the k-sequence of one steady-state cycle; `n` = cycle
/// length in iterations (= Σk). Both orders start from the same state.
pub fn quantify(start: &WalkParams, base_batch: f64, multipliers: &[u64]) -> ConvergenceReport {
    quantify_with_error(start, base_batch, multipliers, 0.0)
}

/// [`quantify`], with a lossy-codec gradient error injected into DeFT's
/// walk only (the baseline always ships raw f32): the deft sequence
/// evolves from [`WalkParams::with_gradient_error`]. This is how the
/// Preserver gates lossy links — a codec whose error pushes the ratio
/// out of `[1−ε, 1+ε]` makes [`acceptable`] reject the route, and the
/// lifecycle falls back to the raw registry. `gradient_error = 0` is
/// bit-for-bit [`quantify`].
pub fn quantify_with_error(
    start: &WalkParams,
    base_batch: f64,
    multipliers: &[u64],
    gradient_error: f64,
) -> ConvergenceReport {
    let n: u64 = multipliers.iter().sum();
    assert!(n > 0, "empty multiplier sequence");
    let baseline = evolve_sequence(start, &vec![base_batch; n as usize]);
    let deft_batches: Vec<f64> = multipliers
        .iter()
        .map(|&k| k as f64 * base_batch)
        .collect();
    let lossy = start.with_gradient_error(gradient_error);
    let deft = evolve_sequence(&lossy, &deft_batches);
    let eb = *baseline.last().expect("n > 0");
    let ed = *deft.last().expect("non-empty");
    let ratio = if (ed - start.s_star).abs() < f64::EPSILON {
        1.0
    } else {
        (eb - start.s_star) / (ed - start.s_star)
    };
    ConvergenceReport {
        baseline,
        deft,
        ratio,
    }
}

/// Feedback decision: is the schedule's convergence acceptable?
pub fn acceptable(report: &ConvergenceReport, epsilon: f64) -> bool {
    (report.ratio - 1.0).abs() <= epsilon
}

/// Compose two independent gradient-degradation sources into one
/// effective error for [`quantify_with_error`]: the useful drift each
/// source leaves is `1 − e`, and independent sources multiply —
/// `1 − e_c = (1 − a)(1 − b)`. Clamped below 1 so the composed value
/// stays a legal [`WalkParams::with_gradient_error`] input. Used by the
/// lifecycle's drift re-gate to stack the codec error with the
/// fault-drift error.
pub fn combined_error(a: f64, b: f64) -> f64 {
    assert!((0.0..1.0).contains(&a), "gradient error {a} must be in [0, 1)");
    assert!((0.0..1.0).contains(&b), "gradient error {b} must be in [0, 1)");
    (1.0 - (1.0 - a) * (1.0 - b)).min(0.999_999)
}

/// The paper's default acceptance band ε (§IV.C.3).
pub const EPSILON: f64 = 0.01;

/// Maximum Solver retries before giving up and taking the closest
/// schedule (§IV.C.3: "up to ten times").
pub const MAX_RETRIES: usize = 10;

/// Table V's experimental setting for ResNet-101.
pub fn table5_setting() -> (WalkParams, f64) {
    (
        WalkParams {
            // Loss s_A = 0.2103 at iteration A = 1000 per Table V; the
            // published column actually lists E at each following step.
            s_t: 0.2103,
            s_star: 0.0,
            eta: 0.01,
            // Fit to Table V's per-step contraction (~2.3% per update at
            // B=256): η·μ ≈ 0.0048; σ chosen so the batch-size effect is
            // visible at the 4th decimal, as in the published column.
            mu_t: 0.48,
            sigma_t: 110.0,
        },
        256.0,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn base() -> WalkParams {
        WalkParams {
            s_t: 0.2103,
            s_star: 0.0,
            eta: 0.01,
            mu_t: 0.48,
            sigma_t: 7.0,
        }
    }

    #[test]
    fn expectation_decreases_toward_floor() {
        let p = base();
        let e = expected_next_state(&p, 256.0);
        assert!(e < p.s_t, "E {e} should contract below s_t {}", p.s_t);
        assert!(e > p.s_star, "E {e} stays above the floor");
    }

    #[test]
    fn larger_batch_tightens_expectation() {
        // Far from the floor the noise term hurts; larger batch => smaller
        // noise => smaller expected next loss.
        let p = base();
        let e_small = expected_next_state(&p, 64.0);
        let e_big = expected_next_state(&p, 1024.0);
        assert!(e_big <= e_small, "{e_big} vs {e_small}");
    }

    #[test]
    fn table5_structure_reproduced() {
        // O_B: four updates at B=256; O_D: 512 (merged), skip, 256, 256.
        let (p, b) = table5_setting();
        let rep = quantify(&p, b, &[2, 1, 1]);
        // Paper Table V: E decreases monotonically for both orders and the
        // final ratio ≈ 0.993 (within 1%).
        for w in rep.baseline.windows(2) {
            assert!(w[1] < w[0], "baseline non-monotone: {:?}", rep.baseline);
        }
        for w in rep.deft.windows(2) {
            assert!(w[1] < w[0], "deft non-monotone: {:?}", rep.deft);
        }
        assert!(
            (rep.ratio - 1.0).abs() < 0.03,
            "ratio {} should be near 1 as in Table V (0.993)",
            rep.ratio
        );
        // First baseline step ≈ 0.2054 in the paper; ours within 2%.
        let first = rep.baseline[0];
        assert!((first - 0.2054).abs() / 0.2054 < 0.02, "first E = {first}");
    }

    #[test]
    fn degenerate_sequences_ratio_one() {
        let (p, b) = table5_setting();
        let rep = quantify(&p, b, &[1, 1, 1, 1]);
        assert!(acceptable(&rep, 1e-9), "identical sequences ratio {}", rep.ratio);
    }

    #[test]
    fn extreme_merging_fails_epsilon() {
        // One giant update of 64·B over 64 iterations diverges from 64
        // small updates: the feedback loop must reject it.
        let (p, b) = table5_setting();
        let rep = quantify(&p, b, &[64]);
        assert!(!acceptable(&rep, EPSILON), "ratio {} unexpectedly ok", rep.ratio);
    }

    #[test]
    fn zero_gradient_error_is_bit_for_bit_quantify() {
        let (p, b) = table5_setting();
        let ks = [2u64, 1, 1];
        let a = quantify(&p, b, &ks);
        let z = quantify_with_error(&p, b, &ks, 0.0);
        assert_eq!(a.baseline, z.baseline);
        assert_eq!(a.deft, z.deft);
        assert!(a.ratio == z.ratio, "{} vs {}", a.ratio, z.ratio);
    }

    #[test]
    fn gradient_error_degrades_the_ratio_monotonically() {
        // Injected codec error slows DeFT's walk: the ratio E_OB/E_OD
        // falls below 1 and keeps falling as the error grows, until the
        // acceptance gate trips.
        let (p, b) = table5_setting();
        let ks = [1u64, 1, 1, 1];
        let mut prev = quantify_with_error(&p, b, &ks, 0.0).ratio;
        assert!((prev - 1.0).abs() < 1e-12, "identical sequences, e = 0");
        for e in [0.001, 0.05, 0.2, 0.5, 0.8] {
            let r = quantify_with_error(&p, b, &ks, e).ratio;
            assert!(r < prev, "ratio not decreasing at e={e}: {r} vs {prev}");
            prev = r;
        }
        // fp16-scale error passes the gate; rank-1-scale error trips it.
        let fp16 = quantify_with_error(&p, b, &ks, crate::links::Codec::Fp16.error());
        assert!(acceptable(&fp16, EPSILON), "fp16 ratio {}", fp16.ratio);
        let rank1 = quantify_with_error(&p, b, &ks, crate::links::Codec::RankK { k: 1 }.error());
        assert!(!acceptable(&rank1, EPSILON), "rank1 ratio {}", rank1.ratio);
        // Even the shortest possible sequence trips on a rank-1 error —
        // the lifecycle fallback cannot be dodged by a 1-cycle schedule.
        let rank1_short =
            quantify_with_error(&p, b, &[1], crate::links::Codec::RankK { k: 1 }.error());
        assert!(!acceptable(&rank1_short, EPSILON), "ratio {}", rank1_short.ratio);
    }

    #[test]
    fn combined_error_composes_independent_sources() {
        // Identity on either side, symmetric, and never weaker than the
        // stronger source alone.
        assert_eq!(combined_error(0.0, 0.0), 0.0);
        assert!((combined_error(0.3, 0.0) - 0.3).abs() < 1e-15);
        assert!((combined_error(0.0, 0.3) - 0.3).abs() < 1e-15);
        let c = combined_error(0.2, 0.5);
        assert!((c - 0.6).abs() < 1e-15, "1 - 0.8*0.5 = 0.6, got {c}");
        assert_eq!(combined_error(0.2, 0.5), combined_error(0.5, 0.2));
        // Near-total degradation stays a legal with_gradient_error input.
        let hot = combined_error(0.999_999, 0.999_999);
        assert!(hot < 1.0);
        let (p, _) = table5_setting();
        let _ = p.with_gradient_error(hot);
    }

    #[test]
    #[should_panic(expected = "gradient error")]
    fn gradient_error_out_of_range_panics() {
        let (p, _) = table5_setting();
        let _ = p.with_gradient_error(1.0);
    }

    #[test]
    fn prop_expectation_bounded_and_monotone_in_state() {
        check("E bounded by rebound walls", 200, |g| {
            let s_t = g.f64_in(0.05, 5.0);
            let p = WalkParams {
                s_t,
                s_star: 0.0,
                eta: g.f64_in(0.001, 0.1),
                mu_t: g.f64_in(0.0, 10.0),
                sigma_t: g.f64_in(0.0, 20.0),
            };
            let b = g.f64_in(1.0, 4096.0);
            let e = expected_next_state(&p, b);
            if !(e.is_finite()) {
                return Err(format!("E not finite: {e}"));
            }
            if e < p.s_star - 1e-9 {
                return Err(format!("E {e} below the floor"));
            }
            Ok(())
        });
    }
}
