//! Bucket partition / fusion strategies (paper §II.B, §III.D, Fig. 16).
//!
//! Every scheme first groups layer gradients into **buckets** — the unit
//! of communication. The paper compares four strategies:
//!
//! * [`Strategy::DdpFixed`] — PyTorch DDP: accumulate layers (in backward
//!   order) until `bucket_size_mb` is reached (default 25 MB).
//! * [`Strategy::Uniform`] — Bytescheduler: slice the gradient stream into
//!   equal `partition_size` blocks (tensors may be split).
//! * [`Strategy::UsByte`] — US-Byte: unequal-sized greedy fusion that
//!   keeps each bucket's communication no larger than the computation
//!   available to overlap it, reducing startup-overhead waste.
//! * [`Strategy::DeftConstrained`] — DeFT (§III.D): start from the US-Byte
//!   partition, then re-partition any bucket whose communication time
//!   exceeds the smallest knapsack capacity (forward time divided by the
//!   slowest segment-path factor), so every bucket fits the
//!   multi-knapsack as an item.
//!
//! Output is a `Vec<BucketProfile>` priced in the flat reference-ring
//! unit via the workload's calibrated rate and a [`ClusterEnv`];
//! degenerate workloads yield a typed [`crate::util::error::Error`].

use crate::links::ClusterEnv;
use crate::models::{BucketProfile, Workload};
use crate::util::error::Result;
use crate::util::Micros;

/// Partitioning strategy selector.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Strategy {
    /// PyTorch DDP `bucket_cap_mb`-style fusion (no layer splitting).
    DdpFixed { bucket_size_mb: f64 },
    /// Bytescheduler uniform blocks of `partition_size` parameters
    /// (layers may be split across blocks).
    Uniform { partition_size: u64 },
    /// US-Byte unequal-sized fusion bounded by overlap capacity.
    UsByte { partition_size: u64 },
    /// DeFT: US-Byte fusion + max-item constraint comm(bucket) ≤ fwd/μ.
    DeftConstrained { partition_size: u64 },
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::DdpFixed { .. } => "pytorch-ddp",
            Strategy::Uniform { .. } => "bytescheduler",
            Strategy::UsByte { .. } => "us-byte",
            Strategy::DeftConstrained { .. } => "deft",
        }
    }
}

/// Partition `workload` into priced buckets for `env`.
///
/// Buckets are returned in **forward order** (bucket 0 nearest the input),
/// matching the paper's numbering.
///
/// Degenerate workloads — no layers, or zero total parameters (e.g. a
/// model whose zero-param layers were filtered out) — return a typed
/// error instead of producing an empty partition that downstream
/// `.max()`/`.min()` consumers would panic on.
pub fn partition(
    workload: &Workload,
    strategy: Strategy,
    env: &ClusterEnv,
) -> Result<Vec<BucketProfile>> {
    if workload.layers.is_empty() {
        crate::bail!("cannot partition `{}`: workload has no layers", workload.name);
    }
    if workload.total_params() == 0 {
        crate::bail!(
            "cannot partition `{}`: workload has zero parameters (all layers empty?)",
            workload.name
        );
    }
    let segs = match strategy {
        Strategy::DdpFixed { bucket_size_mb } => {
            let cap_params = (bucket_size_mb * 1024.0 * 1024.0 / 4.0) as u64;
            fuse_by_params(workload, cap_params.max(1))
        }
        Strategy::Uniform { partition_size } => slice_uniform(workload, partition_size.max(1)),
        Strategy::UsByte { partition_size } => usbyte_fuse(workload, partition_size.max(1)),
        Strategy::DeftConstrained { partition_size } => {
            let base = usbyte_fuse(workload, partition_size.max(1));
            deft_constrain(workload, base, env)
        }
    };
    if segs.is_empty() {
        crate::bail!(
            "partitioning `{}` with {} produced no buckets",
            workload.name,
            strategy.name()
        );
    }
    Ok(price(workload, env, segs))
}

/// A partition segment: a contiguous span of (possibly fractional) layers.
/// `params` is the span's gradient size; `fwd`/`bwd` its compute share.
#[derive(Clone, Debug)]
struct Segment {
    params: u64,
    fwd: Micros,
    bwd: Micros,
}

fn price(workload: &Workload, env: &ClusterEnv, segs: Vec<Segment>) -> Vec<BucketProfile> {
    segs.into_iter()
        .enumerate()
        .map(|(id, s)| BucketProfile {
            id,
            params: s.params,
            fwd: s.fwd,
            bwd: s.bwd,
            // The flat reference-ring unit: per-link (and per-segment)
            // factors are applied by schedulers and the engine.
            comm: env.reference_comm(s.params, workload.comm_rate_ref),
        })
        .collect()
}

/// DDP-style fusion: walk layers in forward order, fuse whole layers until
/// the parameter cap is reached, then start a new bucket.
///
/// (PyTorch builds buckets in backward order; bucket *contents* are the
/// same contiguous spans, and we index from the input side like the paper.)
fn fuse_by_params(workload: &Workload, cap_params: u64) -> Vec<Segment> {
    let mut out: Vec<Segment> = Vec::new();
    let mut cur = Segment {
        params: 0,
        fwd: Micros::ZERO,
        bwd: Micros::ZERO,
    };
    for layer in &workload.layers {
        cur.params += layer.params;
        cur.fwd += layer.fwd;
        cur.bwd += layer.bwd;
        if cur.params >= cap_params {
            out.push(cur);
            cur = Segment {
                params: 0,
                fwd: Micros::ZERO,
                bwd: Micros::ZERO,
            };
        }
    }
    if cur.params > 0 {
        out.push(cur);
    }
    out
}

/// Bytescheduler-style uniform slicing: cut the concatenated gradient
/// stream every `partition_size` parameters, splitting layers; compute
/// time of a split layer is apportioned by parameter fraction.
fn slice_uniform(workload: &Workload, partition_size: u64) -> Vec<Segment> {
    let mut out: Vec<Segment> = Vec::new();
    let mut cur = Segment {
        params: 0,
        fwd: Micros::ZERO,
        bwd: Micros::ZERO,
    };
    for layer in &workload.layers {
        let mut remaining = layer.params;
        while remaining > 0 {
            let room = partition_size - cur.params;
            let take = remaining.min(room);
            let frac = take as f64 / layer.params as f64;
            cur.params += take;
            cur.fwd += layer.fwd.scale(frac);
            cur.bwd += layer.bwd.scale(frac);
            remaining -= take;
            if cur.params == partition_size {
                out.push(cur);
                cur = Segment {
                    params: 0,
                    fwd: Micros::ZERO,
                    bwd: Micros::ZERO,
                };
            }
        }
    }
    if cur.params > 0 {
        out.push(cur);
    }
    out
}

/// US-Byte-style unequal-sized fusion.
///
/// US-Byte's insight: equal-sized blocks waste startup overhead on small
/// tensors and stall on large ones. Greedy rule (their Alg. adapted):
/// walk layers in forward order, fusing while the fused bucket's
/// parameter count stays below `partition_size` **and** fusing one more
/// layer does not make the bucket's size exceed the computation of the
/// layers gathered so far by a growing factor — producing small buckets
/// where compute is scarce (input side) and larger ones where compute is
/// plentiful. Whole layers only (gradient tensors are not split), except
/// giant layers which become singleton buckets.
fn usbyte_fuse(workload: &Workload, partition_size: u64) -> Vec<Segment> {
    let mut out: Vec<Segment> = Vec::new();
    let mut cur = Segment {
        params: 0,
        fwd: Micros::ZERO,
        bwd: Micros::ZERO,
    };
    for layer in &workload.layers {
        let would = cur.params + layer.params;
        // Close the current bucket before adding the layer if fusing would
        // blow past the cap and the bucket already has content.
        if cur.params > 0 && would > partition_size {
            out.push(cur);
            cur = Segment {
                params: 0,
                fwd: Micros::ZERO,
                bwd: Micros::ZERO,
            };
        }
        cur.params += layer.params;
        cur.fwd += layer.fwd;
        cur.bwd += layer.bwd;
        // A single layer ≥ cap becomes its own bucket immediately.
        if cur.params >= partition_size {
            out.push(cur);
            cur = Segment {
                params: 0,
                fwd: Micros::ZERO,
                bwd: Micros::ZERO,
            };
        }
    }
    if cur.params > 0 {
        out.push(cur);
    }
    out
}

/// DeFT §III.D constraint: each bucket's *communication time* must be at
/// most the smallest knapsack capacity — the forward time divided by the
/// slowest **segment path** factor ([`ClusterEnv::max_mu`]; the raw μ of
/// the slowest link under a flat topology) — otherwise it can never be
/// packed. Oversized buckets are split into equal parts just small
/// enough to satisfy the constraint.
fn deft_constrain(workload: &Workload, base: Vec<Segment>, env: &ClusterEnv) -> Vec<Segment> {
    let total_fwd = workload.total_fwd();
    let cap = total_fwd.scale(1.0 / env.max_mu());
    if cap.is_zero() {
        return base;
    }
    let mut out = Vec::new();
    for seg in base {
        let comm = env.reference_comm(seg.params, workload.comm_rate_ref);
        if comm <= cap || seg.params <= 1 {
            out.push(seg);
            continue;
        }
        // Split into the fewest equal pieces with comm ≤ cap.
        let pieces = (comm.as_us() + cap.as_us() - 1) / cap.as_us();
        let pieces = pieces.max(2) as usize;
        let per = seg.params / pieces as u64;
        let mut assigned = 0u64;
        for i in 0..pieces {
            let take = if i == pieces - 1 {
                seg.params - assigned
            } else {
                per
            };
            assigned += take;
            let frac = take as f64 / seg.params as f64;
            out.push(Segment {
                params: take,
                fwd: seg.fwd.scale(frac),
                bwd: seg.bwd.scale(frac),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{gpt2, vgg19};
    use crate::util::prop::check;

    fn env() -> ClusterEnv {
        ClusterEnv::paper_testbed()
    }

    fn conserved(workload: &Workload, buckets: &[BucketProfile]) {
        let p: u64 = buckets.iter().map(|b| b.params).sum();
        assert_eq!(p, workload.total_params(), "params conserved");
        let fwd: Micros = buckets.iter().map(|b| b.fwd).sum();
        let bwd: Micros = buckets.iter().map(|b| b.bwd).sum();
        // Rounding of split layers can drop a few µs per bucket.
        let tol = Micros(buckets.len() as u64 * 4 + 8);
        assert!(
            fwd + tol >= workload.total_fwd() && workload.total_fwd() + tol >= fwd,
            "fwd conserved: {fwd:?} vs {:?}",
            workload.total_fwd()
        );
        assert!(
            bwd + tol >= workload.total_bwd() && workload.total_bwd() + tol >= bwd,
            "bwd conserved"
        );
    }

    #[test]
    fn ddp_25mb_vgg_bucket_count() {
        // 25 MB = 6.55M params; VGG-19's 143.65M params with fc6 (102.8M)
        // as one giant bucket → expect ~6–8 buckets.
        let b = partition(&vgg19(), Strategy::DdpFixed { bucket_size_mb: 25.0 }, &env()).unwrap();
        conserved(&vgg19(), &b);
        assert!((4..=8).contains(&b.len()), "got {} buckets", b.len());
        // One bucket should dominate (fc6).
        let max = b.iter().map(|x| x.params).max().unwrap();
        assert!(max > 90_000_000);
    }

    #[test]
    fn uniform_splits_giant_layers() {
        let b = partition(
            &vgg19(),
            Strategy::Uniform { partition_size: 6_500_000 },
            &env(),
        )
        .unwrap();
        conserved(&vgg19(), &b);
        // 143.65M / 6.5M → 23 buckets, every one ≤ 6.5M.
        assert_eq!(b.len(), 23);
        assert!(b.iter().all(|x| x.params <= 6_500_000));
    }

    #[test]
    fn usbyte_unequal_sizes() {
        let b = partition(
            &vgg19(),
            Strategy::UsByte { partition_size: 6_500_000 },
            &env(),
        )
        .unwrap();
        conserved(&vgg19(), &b);
        // Whole-layer fusion keeps fc6 as a giant singleton.
        let max = b.iter().map(|x| x.params).max().unwrap();
        assert!(max > 100_000_000);
        // And sizes genuinely vary.
        let min = b.iter().map(|x| x.params).min().unwrap();
        assert!(max / min.max(1) > 10);
    }

    #[test]
    fn deft_constraint_bounds_every_bucket() {
        let w = vgg19();
        let e = env();
        let b =
            partition(&w, Strategy::DeftConstrained { partition_size: 6_500_000 }, &e).unwrap();
        conserved(&w, &b);
        let cap = w.total_fwd().scale(1.0 / e.max_mu());
        for bucket in &b {
            assert!(
                bucket.comm <= cap + Micros(1),
                "bucket {} comm {:?} exceeds cap {cap:?}",
                bucket.id,
                bucket.comm
            );
        }
    }

    #[test]
    fn gpt2_deft_bucket_count_near_13() {
        let b = partition(
            &gpt2(),
            Strategy::DeftConstrained { partition_size: 6_500_000 },
            &env(),
        )
        .unwrap();
        // Paper mentions bucket #13 for GPT-2 at this partition size (so
        // ≥ 13 buckets); whole-layer fusion of 2.36M/4.72M-param blocks
        // under a 6.5M cap yields up to 22.
        assert!((11..=24).contains(&b.len()), "got {}", b.len());
    }

    #[test]
    fn ids_are_sequential_forward_order() {
        let b = partition(
            &gpt2(),
            Strategy::UsByte { partition_size: 6_500_000 },
            &env(),
        )
        .unwrap();
        for (i, bucket) in b.iter().enumerate() {
            assert_eq!(bucket.id, i);
        }
    }

    #[test]
    fn prop_all_strategies_conserve_params() {
        use crate::models::small_transformer;
        check("partition conserves params", 60, |g| {
            let n_layers = g.usize_in(1..=8) as u32;
            let d = [64u64, 128, 256][g.usize_in(0..=2)];
            let w = small_transformer(n_layers, d, 512, 64);
            let ps = g.u64_in(10_000..=5_000_000);
            for strat in [
                Strategy::DdpFixed { bucket_size_mb: ps as f64 * 4.0 / 1e6 },
                Strategy::Uniform { partition_size: ps },
                Strategy::UsByte { partition_size: ps },
                Strategy::DeftConstrained { partition_size: ps },
            ] {
                let b = match partition(&w, strat, &env()) {
                    Ok(b) => b,
                    Err(e) => return Err(format!("{}: {e}", strat.name())),
                };
                let total: u64 = b.iter().map(|x| x.params).sum();
                if total != w.total_params() {
                    return Err(format!(
                        "{}: params {total} != {}",
                        strat.name(),
                        w.total_params()
                    ));
                }
                if b.is_empty() {
                    return Err("no buckets".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn degenerate_workloads_yield_typed_errors_not_panics() {
        use crate::models::{Layer, TargetMetric};
        let no_layers = Workload {
            name: "empty".into(),
            layers: Vec::new(),
            comm_rate_ref: 1.8e-3,
            batch_size: 1,
            target: TargetMetric::Loss(1.0),
        };
        let zero_params = Workload {
            name: "zero".into(),
            layers: vec![Layer {
                name: "frozen".into(),
                params: 0,
                fwd: Micros(100),
                bwd: Micros(200),
            }],
            comm_rate_ref: 1.8e-3,
            batch_size: 1,
            target: TargetMetric::Loss(1.0),
        };
        for strat in [
            Strategy::DdpFixed { bucket_size_mb: 25.0 },
            Strategy::Uniform { partition_size: 1_000 },
            Strategy::UsByte { partition_size: 1_000 },
            Strategy::DeftConstrained { partition_size: 1_000 },
        ] {
            let e = partition(&no_layers, strat, &env()).unwrap_err();
            assert!(e.to_string().contains("no layers"), "{}: {e}", strat.name());
            let e = partition(&zero_params, strat, &env()).unwrap_err();
            assert!(
                e.to_string().contains("zero parameters"),
                "{}: {e}",
                strat.name()
            );
        }
    }
}
