//! Synthetic raw-trace generator with the Nsight-style schema the paper's
//! Profiler consumes.
//!
//! A trace is a flat list of [`RawEvent`]s across four threads:
//! forward host thread, backward host thread, the GPU computing stream
//! and the communication stream. Host-side (autograd) operators carry an
//! **External ID**; each communication operator's External ID matches the
//! backward operator that filled its bucket — the hook the 4-step
//! reconstruction keys on (Fig. 8).

use crate::models::Workload;
use crate::util::{Micros, Rng};

/// Trace thread identifiers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ThreadId {
    /// Host thread issuing forward operators.
    ForwardHost,
    /// Host thread issuing backward (autograd) operators.
    BackwardHost,
    /// Device computing stream (kernels).
    ComputeStream,
    /// Device communication stream (allreduce kernels).
    CommStream,
}

/// One raw log record (the paper's "kernel name, thread ID, timestamp,
/// External ID" tuple).
#[derive(Clone, Debug)]
pub struct RawEvent {
    pub name: String,
    pub thread: ThreadId,
    pub start: Micros,
    pub end: Micros,
    /// Correlation id linking host ops to device kernels and comm ops to
    /// the backward op that filled the bucket. 0 = none.
    pub external_id: u64,
}

/// Options for the generator.
#[derive(Clone, Debug)]
pub struct TraceOptions {
    /// Bucket boundaries: layer count per bucket (forward order). Must sum
    /// to the workload's layer count.
    pub layers_per_bucket: Vec<usize>,
    /// Gap between host-op issue and kernel start (launch latency).
    pub launch_delay: Micros,
    /// Random jitter (µs) added to operator durations.
    pub jitter_us: u64,
    pub seed: u64,
}

impl TraceOptions {
    pub fn uniform(workload: &Workload, n_buckets: usize) -> TraceOptions {
        let n = workload.num_layers();
        assert!(n_buckets >= 1 && n_buckets <= n);
        let base = n / n_buckets;
        let extra = n % n_buckets;
        let layers_per_bucket = (0..n_buckets)
            .map(|i| base + usize::from(i < extra))
            .collect();
        TraceOptions {
            layers_per_bucket,
            launch_delay: Micros(6),
            jitter_us: 2,
            seed: 17,
        }
    }
}

/// Ground truth attached to a generated trace for test validation.
#[derive(Clone, Debug)]
pub struct TraceGroundTruth {
    /// Per-bucket (fwd, bwd, comm) times actually generated.
    pub buckets: Vec<(Micros, Micros, Micros)>,
}

/// Generate one training iteration's raw trace for `workload`.
///
/// Returns the events (shuffled — raw logs are not conveniently ordered)
/// and the ground truth the reconstruction must recover.
pub fn generate_trace(
    workload: &Workload,
    opts: &TraceOptions,
) -> (Vec<RawEvent>, TraceGroundTruth) {
    let total: usize = opts.layers_per_bucket.iter().sum();
    assert_eq!(
        total,
        workload.num_layers(),
        "layers_per_bucket must cover the workload"
    );
    let mut rng = Rng::new(opts.seed);
    let mut events: Vec<RawEvent> = Vec::new();
    let mut ext_id = 1u64;

    // Assign layers to buckets (forward order).
    let mut bucket_of_layer = Vec::with_capacity(total);
    for (b, &k) in opts.layers_per_bucket.iter().enumerate() {
        for _ in 0..k {
            bucket_of_layer.push(b);
        }
    }
    let n_buckets = opts.layers_per_bucket.len();

    let jitter = |rng: &mut Rng, d: Micros| -> Micros {
        if opts.jitter_us == 0 {
            d
        } else {
            let j = rng.range_u64(0, opts.jitter_us);
            d + Micros(j)
        }
    };

    // --- Forward pass: host issues op, kernel follows on compute stream.
    let mut host_t = Micros::ZERO;
    let mut dev_t = Micros::ZERO;
    let mut fwd_true = vec![Micros::ZERO; n_buckets];
    let mut fwd_last_ext = vec![0u64; n_buckets]; // last fwd op ext id per bucket
    for (li, layer) in workload.layers.iter().enumerate() {
        let d = jitter(&mut rng, layer.fwd);
        let id = ext_id;
        ext_id += 1;
        let h_start = host_t;
        let h_end = h_start + Micros(2);
        events.push(RawEvent {
            name: format!("aten::{}_fwd", layer.name),
            thread: ThreadId::ForwardHost,
            start: h_start,
            end: h_end,
            external_id: id,
        });
        let k_start = dev_t.max(h_end + opts.launch_delay);
        let k_end = k_start + d;
        events.push(RawEvent {
            name: format!("kernel::{}_fwd", layer.name),
            thread: ThreadId::ComputeStream,
            start: k_start,
            end: k_end,
            external_id: id,
        });
        host_t = h_end;
        dev_t = k_end;
        let b = bucket_of_layer[li];
        fwd_true[b] += d;
        fwd_last_ext[b] = id;
    }

    // --- Backward pass: reverse layer order on a separate host thread.
    let mut bwd_true = vec![Micros::ZERO; n_buckets];
    let mut comm_true = vec![Micros::ZERO; n_buckets];
    let mut bwd_last_ext = vec![0u64; n_buckets]; // ext id of the bucket's LAST bwd op
    let mut comm_t = dev_t;
    host_t = dev_t; // backward host follows forward completion
    for li in (0..workload.num_layers()).rev() {
        let layer = &workload.layers[li];
        let d = jitter(&mut rng, layer.bwd);
        let id = ext_id;
        ext_id += 1;
        let h_start = host_t;
        let h_end = h_start + Micros(2);
        events.push(RawEvent {
            name: format!("autograd::{}_bwd", layer.name),
            thread: ThreadId::BackwardHost,
            start: h_start,
            end: h_end,
            external_id: id,
        });
        let k_start = dev_t.max(h_end + opts.launch_delay);
        let k_end = k_start + d;
        events.push(RawEvent {
            name: format!("kernel::{}_bwd", layer.name),
            thread: ThreadId::ComputeStream,
            start: k_start,
            end: k_end,
            external_id: id,
        });
        host_t = h_end;
        dev_t = k_end;
        let b = bucket_of_layer[li];
        bwd_true[b] += d;
        // Bucket finished when its input-most layer's backward is done
        // (layers are visited in reverse, so the last visit per bucket is
        // its first layer).
        bwd_last_ext[b] = id;
        let bucket_done = li == 0 || bucket_of_layer[li - 1] != b;
        if bucket_done {
            // Emit the bucket's allreduce on the comm stream, correlated
            // to this backward op's external id.
            let c = jitter(
                &mut rng,
                Micros::from_us_f64(
                    workload.layers.iter().enumerate()
                        .filter(|(lj, _)| bucket_of_layer[*lj] == b)
                        .map(|(_, l)| l.params as f64)
                        .sum::<f64>()
                        * workload.comm_rate_ref,
                ),
            );
            let c_start = comm_t.max(k_end);
            let c_end = c_start + c;
            events.push(RawEvent {
                name: format!("nccl::AllReduce_bucket{b}"),
                thread: ThreadId::CommStream,
                start: c_start,
                end: c_end,
                external_id: id,
            });
            comm_t = c_end;
            comm_true[b] = c;
        }
    }

    // Shuffle: raw logs arrive unordered across threads.
    rng.shuffle(&mut events);

    let buckets = (0..n_buckets)
        .map(|b| (fwd_true[b], bwd_true[b], comm_true[b]))
        .collect();
    (events, TraceGroundTruth { buckets })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::vgg19;

    #[test]
    fn trace_has_all_threads_and_comm_ops() {
        let w = vgg19();
        let opts = TraceOptions::uniform(&w, 6);
        let (events, truth) = generate_trace(&w, &opts);
        assert_eq!(truth.buckets.len(), 6);
        for t in [
            ThreadId::ForwardHost,
            ThreadId::BackwardHost,
            ThreadId::ComputeStream,
            ThreadId::CommStream,
        ] {
            assert!(events.iter().any(|e| e.thread == t), "missing {t:?}");
        }
        let comm_count = events
            .iter()
            .filter(|e| e.thread == ThreadId::CommStream)
            .count();
        assert_eq!(comm_count, 6, "one allreduce per bucket");
    }

    #[test]
    fn ground_truth_totals_match_workload() {
        let w = vgg19();
        let mut opts = TraceOptions::uniform(&w, 4);
        opts.jitter_us = 0;
        let (_, truth) = generate_trace(&w, &opts);
        let fwd: Micros = truth.buckets.iter().map(|b| b.0).sum();
        let bwd: Micros = truth.buckets.iter().map(|b| b.1).sum();
        assert_eq!(fwd, w.total_fwd());
        assert_eq!(bwd, w.total_bwd());
    }

    #[test]
    fn comm_external_ids_match_backward_ops() {
        let w = vgg19();
        let opts = TraceOptions::uniform(&w, 6);
        let (events, _) = generate_trace(&w, &opts);
        for comm in events.iter().filter(|e| e.thread == ThreadId::CommStream) {
            assert!(
                events
                    .iter()
                    .any(|e| e.thread == ThreadId::BackwardHost
                        && e.external_id == comm.external_id),
                "comm op {} has no matching backward host op",
                comm.name
            );
        }
    }
}
