//! The 4-step operator→bucket reconstruction of paper Fig. 8.
//!
//! Steps (quoting §IV.B):
//! 1. identify the External ID of each communication operator — one per
//!    bucket;
//! 2. via that External ID, find the bucket's **last backward operator**
//!    in the backward host thread, and its kernel in the computing
//!    stream → the bucket's backward endpoint;
//! 3. find the corresponding **first forward operator** of the bucket in
//!    the forward thread (the backward op's layer), and its kernel → the
//!    bucket's forward start;
//! 4. difference consecutive boundaries to obtain per-bucket forward /
//!    backward times; communication time is the comm op's own span.

use std::collections::BTreeMap;

use super::trace::{RawEvent, ThreadId};
use crate::util::Micros;

/// Reconstructed per-bucket times (the Profiler's output, which feeds
/// the Solver).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReconstructedBucket {
    /// Bucket id in forward order (0 = input side).
    pub id: usize,
    pub fwd: Micros,
    pub bwd: Micros,
    pub comm: Micros,
}

/// Strip the generator's operator-name decorations to recover the layer
/// name shared between a backward host op and its forward counterpart.
fn layer_of_bwd_name(name: &str) -> Option<&str> {
    name.strip_prefix("autograd::")?.strip_suffix("_bwd")
}

/// Run the reconstruction over one iteration's raw events.
///
/// Returns buckets in forward order. Panics on malformed traces (missing
/// correlation ids) — tests feed both clean and adversarial traces.
pub fn reconstruct(events: &[RawEvent]) -> Vec<ReconstructedBucket> {
    // Index events.
    let mut comm_ops: Vec<&RawEvent> = events
        .iter()
        .filter(|e| e.thread == ThreadId::CommStream)
        .collect();
    comm_ops.sort_by_key(|e| e.start);
    let n = comm_ops.len();
    assert!(n > 0, "trace has no communication operators");

    let by_ext_host_bwd: BTreeMap<u64, &RawEvent> = events
        .iter()
        .filter(|e| e.thread == ThreadId::BackwardHost)
        .map(|e| (e.external_id, e))
        .collect();
    let by_ext_kernel: BTreeMap<u64, &RawEvent> = events
        .iter()
        .filter(|e| e.thread == ThreadId::ComputeStream)
        .map(|e| (e.external_id, e))
        .collect();
    let fwd_host_by_name: BTreeMap<&str, &RawEvent> = events
        .iter()
        .filter(|e| e.thread == ThreadId::ForwardHost)
        .map(|e| (e.name.as_str(), e))
        .collect();

    // Forward/backward kernel regions on the compute stream.
    let fwd_kernels: Vec<&RawEvent> = events
        .iter()
        .filter(|e| e.thread == ThreadId::ComputeStream && e.name.ends_with("_fwd"))
        .collect();
    let fwd_region_start = fwd_kernels.iter().map(|e| e.start).min().expect("trace has forward kernels");
    let fwd_region_end = fwd_kernels.iter().map(|e| e.end).max().expect("trace has forward kernels");

    // Step 1+2: comm op → last backward op → backward endpoint kernel.
    // Comm ops appear in backward order: first comm = output-most bucket.
    struct B {
        bucket: usize,
        comm: Micros,
        bwd_end: Micros,
        fwd_start: Micros,
    }
    let mut recs: Vec<B> = Vec::with_capacity(n);
    for (i, comm) in comm_ops.iter().enumerate() {
        let bucket = n - 1 - i; // forward-order id
        let host_bwd = by_ext_host_bwd
            .get(&comm.external_id)
            .unwrap_or_else(|| panic!("comm op {} lacks backward host op", comm.name));
        let bwd_kernel = by_ext_kernel
            .get(&host_bwd.external_id)
            .unwrap_or_else(|| panic!("backward op {} lacks kernel", host_bwd.name));
        // Step 3: the backward op's layer → its forward op → fwd kernel.
        let layer = layer_of_bwd_name(&host_bwd.name)
            .unwrap_or_else(|| panic!("unparseable backward op name {}", host_bwd.name));
        let fwd_name = format!("aten::{layer}_fwd");
        let host_fwd = fwd_host_by_name
            .get(fwd_name.as_str())
            .unwrap_or_else(|| panic!("no forward host op {fwd_name}"));
        let fwd_kernel = by_ext_kernel
            .get(&host_fwd.external_id)
            .unwrap_or_else(|| panic!("forward op {fwd_name} lacks kernel"));
        recs.push(B {
            bucket,
            comm: comm.end - comm.start,
            bwd_end: bwd_kernel.end,
            fwd_start: fwd_kernel.start,
        });
    }
    recs.sort_by_key(|r| r.bucket);

    // Step 4: difference boundaries.
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        // Backward: buckets complete in order n-1, n-2, …, 0; bucket i's
        // backward spans from bucket i+1's endpoint (or the backward
        // region start = forward region end).
        let bwd_start = if i + 1 < n {
            recs[i + 1].bwd_end
        } else {
            fwd_region_end
        };
        let bwd = recs[i].bwd_end.saturating_sub(bwd_start);
        // Forward: bucket i spans from its first kernel to bucket i+1's
        // first kernel (or the forward region end).
        let fwd_end = if i + 1 < n {
            recs[i + 1].fwd_start
        } else {
            fwd_region_end
        };
        let fwd_start = if i == 0 {
            fwd_region_start
        } else {
            recs[i].fwd_start
        };
        let fwd = fwd_end.saturating_sub(fwd_start);
        out.push(ReconstructedBucket {
            id: i,
            fwd,
            bwd,
            comm: recs[i].comm,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::trace::{generate_trace, TraceOptions};
    use super::*;
    use crate::models::{gpt2, resnet101, vgg19};

    fn close(a: Micros, b: Micros, tol: Micros) -> bool {
        a.max(b) - a.min(b) <= tol
    }

    #[test]
    fn reconstruction_matches_ground_truth_vgg() {
        let w = vgg19();
        let mut opts = TraceOptions::uniform(&w, 6);
        opts.jitter_us = 0;
        let (events, truth) = generate_trace(&w, &opts);
        let rec = reconstruct(&events);
        assert_eq!(rec.len(), 6);
        // Launch-latency slack: a few gaps of (host 2µs + delay 6µs).
        let tol = Micros(40);
        for (r, (fwd, bwd, comm)) in rec.iter().zip(truth.buckets.iter()) {
            assert!(close(r.fwd, *fwd, tol), "bucket {} fwd {:?} vs {:?}", r.id, r.fwd, fwd);
            assert!(close(r.bwd, *bwd, tol), "bucket {} bwd {:?} vs {:?}", r.id, r.bwd, bwd);
            assert!(close(r.comm, *comm, tol), "bucket {} comm", r.id);
        }
    }

    #[test]
    fn reconstruction_robust_to_jitter_and_models() {
        for w in [resnet101(), gpt2()] {
            let opts = TraceOptions::uniform(&w, 8);
            let (events, truth) = generate_trace(&w, &opts);
            let rec = reconstruct(&events);
            assert_eq!(rec.len(), 8);
            let total_bwd_true: Micros = truth.buckets.iter().map(|b| b.1).sum();
            let total_bwd_rec: Micros = rec.iter().map(|r| r.bwd).sum();
            // Totals agree within 1%.
            let diff = total_bwd_true.max(total_bwd_rec) - total_bwd_true.min(total_bwd_rec);
            assert!(
                diff.as_us() as f64 <= 0.01 * total_bwd_true.as_us() as f64 + 100.0,
                "{}: bwd {total_bwd_rec:?} vs {total_bwd_true:?}",
                w.name
            );
        }
    }

    #[test]
    #[should_panic(expected = "no communication operators")]
    fn empty_trace_panics() {
        reconstruct(&[]);
    }

    #[test]
    fn profile_feeds_scheduler() {
        // End-to-end: trace → reconstruction → BucketProfile → DeFT.
        use crate::models::BucketProfile;
        use crate::sched::{Deft, DeftOptions, Scheduler};
        let w = vgg19();
        let opts = TraceOptions::uniform(&w, 6);
        let (events, _) = generate_trace(&w, &opts);
        let rec = reconstruct(&events);
        let buckets: Vec<BucketProfile> = rec
            .iter()
            .map(|r| BucketProfile {
                id: r.id,
                params: 1_000_000,
                fwd: r.fwd,
                bwd: r.bwd,
                comm: r.comm,
            })
            .collect();
        let s = Deft::new(DeftOptions {
            preserver: false,
            ..DeftOptions::default()
        })
        .schedule(&buckets);
        s.validate().unwrap();
    }
}
