//! The Profiler — operator-trace collection and bucket-level
//! reconstruction (paper §IV.B, Fig. 8).
//!
//! The paper drives NVIDIA Nsight Systems and reconstructs bucket-level
//! times from raw operator logs via External IDs and timestamps. Here the
//! raw-trace *producer* is a synthetic generator (same schema: kernel
//! name, thread id, External ID, timestamp) driven by a ground-truth
//! workload, and the *consumer* implements the paper's 4-step analysis.
//! Tests check reconstruction == ground truth.

mod reconstruct;
mod trace;

pub use reconstruct::{reconstruct, ReconstructedBucket};
pub use trace::{generate_trace, RawEvent, ThreadId, TraceOptions};
