//! Static verification of DeFT schedule artifacts.
//!
//! The DES engine discovers a malformed or infeasible plan only while
//! executing it — an `assert!` deep in materialization, or a silently
//! mispriced run. This module proves (or refutes) the paper's invariants
//! over [`Schedule`]/[`crate::sched::IterPlan`] values **without running
//! the simulator**:
//!
//! * **dependency soundness** — no wire departs before its producing
//!   backward's data-ready point (a fresh gradient cannot ship in the
//!   forward window), and `FwdDependency::PerBucket` coverage is
//!   satisfiable within the window that consumes it (no deadlock);
//! * **staleness** — delayed updates stay inside the schedule's
//!   `max_outstanding_iters` bound and the update bookkeeping
//!   (`updates_per_cycle`, batch multipliers, `update_offset`) is
//!   consistent (§IV.C.1);
//! * **capacity** — per-link, per-window communication load fits the
//!   knapsack capacity under the static contention factor and the
//!   codec-effective μ (§III.D), reproducing the solver's own `Micros`
//!   arithmetic exactly;
//! * **precision** — a schedule routing over a lossy
//!   [`crate::links::Codec`] must carry a passing Preserver verdict
//!   (§IV.C.3).
//!
//! Findings are typed [`Diagnostic`]s with stable codes (`DEFT-E001`…)
//! rendered human-readably and as JSON lines; see `docs/diagnostics.md`
//! for the full table. [`lint_schedule`] runs the plan-only structural
//! checks (it backs [`Schedule::validate`]); [`lint_plan`] adds every
//! check that needs the bucket profile and cluster environment. The
//! verifier is itself verified differentially: [`apply_mutation`]
//! perturbs known-good plans and the test suite asserts each mutation
//! class trips its designated code.

mod mutate;
mod verifier;

pub use mutate::{apply_mutation, MutatedCase, MutationClass};
pub use verifier::{lint_plan, lint_schedule, LintOptions};

use crate::links::LinkId;
use crate::sched::{Schedule, Stage};
use crate::util::Micros;
use std::fmt;

/// Severity of a [`Diagnostic`]. Errors make a plan unrunnable or
/// mispriced; warnings flag suspicious-but-executable structure.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Stable diagnostic codes. The wire strings (`DEFT-E001`…) are frozen:
/// tests, CI reports, and docs key on them, so new checks append new
/// numbers and retired checks leave holes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Code {
    /// `DEFT-E001` — op routes over a link the registry does not have.
    UnknownLink,
    /// `DEFT-E002` — op references a bucket outside the profile.
    UnknownBucket,
    /// `DEFT-E003` — a current-iteration gradient ships in the forward
    /// window (its producing backward has not run: no data-ready point).
    FreshGradInForward,
    /// `DEFT-E004` — `PerBucket`: some (iteration, bucket) gradient is
    /// never covered by any transfer, deadlocking the next forward.
    UncoveredGradient,
    /// `DEFT-E005` — `PerBucket`: the covering transfer launches after
    /// the forward that consumes it.
    LateCoverage,
    /// `DEFT-E006` — the steady-state cycle has no iterations.
    EmptyCycle,
    /// `DEFT-E007` — `update_at_end` markers disagree with
    /// `updates_per_cycle`.
    UpdateMarkerMismatch,
    /// `DEFT-E008` — batch multipliers don't partition the cycle
    /// (count ≠ updates, Σk ≠ cycle length, or some k = 0).
    MultiplierMismatch,
    /// `DEFT-E009` — the identical op appears twice in one window.
    DuplicateOp,
    /// `DEFT-E010` — a bucket ships more gradients per cycle than the
    /// cycle produces.
    OverShippedGradient,
    /// `DEFT-E011` — a bucket ships fewer gradients per cycle than the
    /// cycle produces (gradients silently dropped).
    UnderShippedGradient,
    /// `DEFT-E012` — an op's oldest merged gradient exceeds the
    /// schedule's `max_outstanding_iters` staleness bound.
    StalenessBound,
    /// `DEFT-E013` — `update_offset` points past `updates_per_cycle`.
    UpdateOffsetOutOfRange,
    /// `DEFT-E014` — per-link window load exceeds the knapsack capacity
    /// (§III.D) under the recorded solver scale.
    CapacityOverflow,
    /// `DEFT-E015` — a force-shipped oversized bucket is not amortized
    /// by the iterations it merges (the debt can never be repaid).
    ForceShipUnamortized,
    /// `DEFT-E016` — the schedule routes over a lossy codec without a
    /// passing Preserver verdict.
    UngatedLossyRoute,
    /// `DEFT-W001` — an iteration ships nothing and applies no update.
    EmptyIteration,
    /// `DEFT-W002` — an op's `stage` disagrees with the window vector
    /// holding it (the engine windows by `stage`; the vec is ordering).
    WindowMismatch,
    /// `DEFT-W003` — an op merges zero gradients (ships nothing).
    DegenerateOp,
    /// `DEFT-W004` — a window load fits the healthy capacity but not the
    /// capacity left under the declared fault envelope's worst link
    /// degradation (the plan's staleness bound breaks if the envelope is
    /// realized).
    FaultEnvelopeCapacity,
}

impl Code {
    pub const ALL: [Code; 20] = [
        Code::UnknownLink,
        Code::UnknownBucket,
        Code::FreshGradInForward,
        Code::UncoveredGradient,
        Code::LateCoverage,
        Code::EmptyCycle,
        Code::UpdateMarkerMismatch,
        Code::MultiplierMismatch,
        Code::DuplicateOp,
        Code::OverShippedGradient,
        Code::UnderShippedGradient,
        Code::StalenessBound,
        Code::UpdateOffsetOutOfRange,
        Code::CapacityOverflow,
        Code::ForceShipUnamortized,
        Code::UngatedLossyRoute,
        Code::EmptyIteration,
        Code::WindowMismatch,
        Code::DegenerateOp,
        Code::FaultEnvelopeCapacity,
    ];

    /// The frozen wire string.
    pub fn as_str(self) -> &'static str {
        match self {
            Code::UnknownLink => "DEFT-E001",
            Code::UnknownBucket => "DEFT-E002",
            Code::FreshGradInForward => "DEFT-E003",
            Code::UncoveredGradient => "DEFT-E004",
            Code::LateCoverage => "DEFT-E005",
            Code::EmptyCycle => "DEFT-E006",
            Code::UpdateMarkerMismatch => "DEFT-E007",
            Code::MultiplierMismatch => "DEFT-E008",
            Code::DuplicateOp => "DEFT-E009",
            Code::OverShippedGradient => "DEFT-E010",
            Code::UnderShippedGradient => "DEFT-E011",
            Code::StalenessBound => "DEFT-E012",
            Code::UpdateOffsetOutOfRange => "DEFT-E013",
            Code::CapacityOverflow => "DEFT-E014",
            Code::ForceShipUnamortized => "DEFT-E015",
            Code::UngatedLossyRoute => "DEFT-E016",
            Code::EmptyIteration => "DEFT-W001",
            Code::WindowMismatch => "DEFT-W002",
            Code::DegenerateOp => "DEFT-W003",
            Code::FaultEnvelopeCapacity => "DEFT-W004",
        }
    }

    pub fn severity(self) -> Severity {
        match self {
            Code::EmptyIteration
            | Code::WindowMismatch
            | Code::DegenerateOp
            | Code::FaultEnvelopeCapacity => Severity::Warning,
            _ => Severity::Error,
        }
    }

    /// One-line statement of the invariant the code enforces (shared by
    /// `docs/diagnostics.md` and rendered reports).
    pub fn invariant(self) -> &'static str {
        match self {
            Code::UnknownLink => "every op routes over a registered link",
            Code::UnknownBucket => "every op references a profiled bucket",
            Code::FreshGradInForward => {
                "a wire never starts before its producing backward's data-ready point"
            }
            Code::UncoveredGradient => {
                "per-bucket forward dependencies are covered by some transfer"
            }
            Code::LateCoverage => "the covering transfer launches no later than the \
                 forward window that consumes it",
            Code::EmptyCycle => "the steady-state cycle is non-empty",
            Code::UpdateMarkerMismatch => "update markers count updates_per_cycle exactly",
            Code::MultiplierMismatch => "batch multipliers k_i partition the cycle (Σk = L)",
            Code::DuplicateOp => "no window launches the identical op twice",
            Code::OverShippedGradient => "a cycle ships at most one gradient set per iteration",
            Code::UnderShippedGradient => "every produced gradient is eventually shipped",
            Code::StalenessBound => "merged gradient age stays within max_outstanding_iters",
            Code::UpdateOffsetOutOfRange => "update offsets resolve within the cycle's updates",
            Code::CapacityOverflow => {
                "per-link window load fits the knapsack capacity (§III.D)"
            }
            Code::ForceShipUnamortized => {
                "a force-shipped oversized bucket is amortized by its merged iterations"
            }
            Code::UngatedLossyRoute => "lossy codec routes carry a passing Preserver verdict",
            Code::EmptyIteration => "iterations do useful work (ship or update)",
            Code::WindowMismatch => "op stage agrees with its window vector",
            Code::DegenerateOp => "every op ships at least one merged gradient",
            Code::FaultEnvelopeCapacity => {
                "window loads survive the declared fault envelope's worst link degradation"
            }
        }
    }
}

impl fmt::Display for Code {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Where in the schedule a diagnostic anchors. All fields optional: a
/// schedule-level finding leaves everything `None`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Location {
    /// Cycle position (0-based iteration within the steady cycle).
    pub iter: Option<usize>,
    /// Launch window.
    pub stage: Option<Stage>,
    pub bucket: Option<usize>,
    pub link: Option<LinkId>,
}

impl Location {
    pub fn schedule() -> Location {
        Location::default()
    }

    pub fn iteration(iter: usize) -> Location {
        Location {
            iter: Some(iter),
            ..Location::default()
        }
    }

    pub fn bucket(bucket: usize) -> Location {
        Location {
            bucket: Some(bucket),
            ..Location::default()
        }
    }

    pub fn iter_bucket(iter: usize, bucket: usize) -> Location {
        Location {
            iter: Some(iter),
            bucket: Some(bucket),
            ..Location::default()
        }
    }

    pub fn window_link(iter: usize, stage: Stage, link: LinkId) -> Location {
        Location {
            iter: Some(iter),
            stage: Some(stage),
            link: Some(link),
            ..Location::default()
        }
    }

    pub fn op(iter: usize, stage: Stage, bucket: usize, link: LinkId) -> Location {
        Location {
            iter: Some(iter),
            stage: Some(stage),
            bucket: Some(bucket),
            link: Some(link),
        }
    }

    pub fn link(link: LinkId) -> Location {
        Location {
            link: Some(link),
            ..Location::default()
        }
    }
}

fn stage_str(stage: Stage) -> &'static str {
    match stage {
        Stage::Forward => "fwd",
        Stage::Backward => "bwd",
    }
}

impl fmt::Display for Location {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fn sep(f: &mut fmt::Formatter<'_>, wrote: &mut bool) -> fmt::Result {
            if *wrote {
                f.write_str(" ")?;
            }
            *wrote = true;
            Ok(())
        }
        let mut wrote = false;
        if let Some(t) = self.iter {
            sep(f, &mut wrote)?;
            write!(f, "iter {t}")?;
        }
        if let Some(s) = self.stage {
            sep(f, &mut wrote)?;
            f.write_str(stage_str(s))?;
        }
        if let Some(b) = self.bucket {
            sep(f, &mut wrote)?;
            write!(f, "bucket {b}")?;
        }
        if let Some(l) = self.link {
            sep(f, &mut wrote)?;
            write!(f, "link#{}", l.index())?;
        }
        if !wrote {
            f.write_str("schedule")?;
        }
        Ok(())
    }
}

/// One typed lint finding.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    pub code: Code,
    pub severity: Severity,
    pub location: Location,
    pub message: String,
}

impl Diagnostic {
    pub fn new(code: Code, location: Location, message: impl Into<String>) -> Diagnostic {
        Diagnostic {
            code,
            severity: code.severity(),
            location,
            message: message.into(),
        }
    }

    /// The diagnostic's JSON fields, brace-less (`"code":…,"message":…`)
    /// so callers can prepend run context (workload, preset, scheme)
    /// into the same object.
    pub fn to_json_fields(&self) -> String {
        let mut out = String::with_capacity(96);
        out.push_str("\"code\":\"");
        out.push_str(self.code.as_str());
        out.push_str("\",\"severity\":\"");
        out.push_str(self.severity.as_str());
        out.push('"');
        if let Some(t) = self.location.iter {
            out.push_str(&format!(",\"iter\":{t}"));
        }
        if let Some(s) = self.location.stage {
            out.push_str(&format!(",\"stage\":\"{}\"", stage_str(s)));
        }
        if let Some(b) = self.location.bucket {
            out.push_str(&format!(",\"bucket\":{b}"));
        }
        if let Some(l) = self.location.link {
            out.push_str(&format!(",\"link\":{}", l.index()));
        }
        out.push_str(",\"message\":\"");
        out.push_str(&esc(&self.message));
        out.push('"');
        out
    }

    /// The diagnostic as one standalone JSON object.
    pub fn to_json(&self) -> String {
        format!("{{{}}}", self.to_json_fields())
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} {} [{}]: {}",
            self.code, self.severity, self.location, self.message
        )
    }
}

/// JSON string escaping (same dialect as `bench::trajectory`'s writer:
/// backslash, quote, and control characters only).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Per-(iteration, window, link) capacity accounting emitted by the
/// capacity lint: `load` = Σ reference-time comm of the window's
/// regularly-packed ops, `cap` = the knapsack capacity the solver packed
/// against (codec-effective μ, static contention, recorded scale).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowLoad {
    pub iter: usize,
    pub stage: Stage,
    pub link: LinkId,
    pub load: Micros,
    pub cap: Micros,
}

/// The full result of a lint pass.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LintReport {
    pub diagnostics: Vec<Diagnostic>,
    /// Capacity accounting (only populated for knapsack-governed —
    /// `FwdDependency::None` — schedules linted with a profile).
    pub loads: Vec<WindowLoad>,
    /// Per-link reference-time communication launched per cycle.
    pub link_ref_comm: Vec<Micros>,
    /// Per-link raw gradient bytes launched per cycle (4 B/param per
    /// transfer, matching `SimResult::link_traffic` accounting).
    pub link_raw_bytes: Vec<u64>,
}

impl LintReport {
    pub(crate) fn push(&mut self, code: Code, location: Location, message: impl Into<String>) {
        self.diagnostics.push(Diagnostic::new(code, location, message));
    }

    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Error)
    }

    pub fn error_count(&self) -> usize {
        self.errors().count()
    }

    pub fn warning_count(&self) -> usize {
        self.diagnostics.len() - self.error_count()
    }

    /// Clean = zero error-severity diagnostics (warnings allowed).
    pub fn is_clean(&self) -> bool {
        self.error_count() == 0
    }

    pub fn first_error(&self) -> Option<&Diagnostic> {
        self.errors().next()
    }

    pub fn has_code(&self, code: Code) -> bool {
        self.diagnostics.iter().any(|d| d.code == code)
    }

    /// Human-readable multi-line rendering.
    pub fn render_text(&self) -> String {
        let mut out = format!(
            "lint: {} error(s), {} warning(s)\n",
            self.error_count(),
            self.warning_count()
        );
        for d in &self.diagnostics {
            out.push_str("  ");
            out.push_str(&d.to_string());
            out.push('\n');
        }
        out
    }

    /// One JSON object per diagnostic, newline-separated.
    pub fn to_json_lines(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&d.to_json());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable_and_unique() {
        let mut seen = std::collections::BTreeSet::new();
        for code in Code::ALL {
            assert!(seen.insert(code.as_str()), "duplicate wire string for {code:?}");
            let s = code.as_str();
            assert!(s.starts_with("DEFT-E") || s.starts_with("DEFT-W"));
            assert_eq!(
                code.severity(),
                if s.starts_with("DEFT-W") {
                    Severity::Warning
                } else {
                    Severity::Error
                },
                "{s}: wire prefix disagrees with severity"
            );
            assert!(!code.invariant().is_empty());
        }
        assert_eq!(seen.len(), Code::ALL.len());
    }

    #[test]
    fn rendering_is_stable() {
        let d = Diagnostic::new(
            Code::CapacityOverflow,
            Location::window_link(3, Stage::Backward, LinkId(1)),
            "load 12000 µs exceeds capacity 9000 µs",
        );
        assert_eq!(
            d.to_string(),
            "DEFT-E014 error [iter 3 bwd link#1]: load 12000 µs exceeds capacity 9000 µs"
        );
        let d2 = Diagnostic::new(Code::EmptyCycle, Location::schedule(), "no iterations");
        assert_eq!(d2.to_string(), "DEFT-E006 error [schedule]: no iterations");
    }

    #[test]
    fn json_lines_escape_and_omit_absent_fields() {
        let d = Diagnostic::new(
            Code::UnknownBucket,
            Location::iter_bucket(0, 7),
            "bucket \"7\" \\ missing",
        );
        assert_eq!(
            d.to_json(),
            "{\"code\":\"DEFT-E002\",\"severity\":\"error\",\"iter\":0,\"bucket\":7,\
             \"message\":\"bucket \\\"7\\\" \\\\ missing\"}"
        );
        let mut r = LintReport::default();
        r.push(Code::EmptyIteration, Location::iteration(1), "idle");
        assert!(r.is_clean());
        assert_eq!(r.warning_count(), 1);
        assert!(r.to_json_lines().ends_with("\"idle\"}\n"));
        assert!(r.render_text().contains("DEFT-W001 warning [iter 1]: idle"));
    }
}
