//! Differential verification of the verifier: deterministic mutations
//! of known-good plans, each designed to trip exactly one designated
//! diagnostic code. The test suite asserts every class fires its code
//! (and never lints clean) — if a lint check rots, its mutation class
//! catches the regression.

use super::Code;
use crate::links::{ClusterEnv, Codec, LinkId};
use crate::models::BucketProfile;
use crate::sched::{FwdDependency, Schedule, Stage};
use crate::util::Micros;

/// One way to break a known-good plan. Every class is deterministic in
/// `(input, seed)`: the seed only selects *which* op/link/multiplier is
/// perturbed, never whether the perturbation happens.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MutationClass {
    /// Remove one op: its bucket's gradients are silently dropped.
    DropOp,
    /// Push an exact clone of one op into its own window.
    DuplicateOp,
    /// Move one backward op into the forward window with `grad_age = 0`
    /// — a wire with no data-ready point.
    FreshGradInForward,
    /// Point one op at a link the registry does not have.
    UnknownLink,
    /// Inflate one regularly-packed bucket's comm past every window
    /// capacity (knapsack-governed schedules only).
    InflateBucket,
    /// Swap a lossy rank-1 codec onto a used link without re-gating.
    SwapCodecUngated,
    /// Bump one batch multiplier so Σk no longer partitions the cycle.
    BreakMultipliers,
    /// Zero the staleness bound while aging one shipped gradient.
    TightenStaleness,
    /// Point one op's update offset past the cycle's updates.
    SkewUpdateOffset,
}

impl MutationClass {
    pub const ALL: [MutationClass; 9] = [
        MutationClass::DropOp,
        MutationClass::DuplicateOp,
        MutationClass::FreshGradInForward,
        MutationClass::UnknownLink,
        MutationClass::InflateBucket,
        MutationClass::SwapCodecUngated,
        MutationClass::BreakMultipliers,
        MutationClass::TightenStaleness,
        MutationClass::SkewUpdateOffset,
    ];

    pub fn name(self) -> &'static str {
        match self {
            MutationClass::DropOp => "drop-op",
            MutationClass::DuplicateOp => "duplicate-op",
            MutationClass::FreshGradInForward => "fresh-grad-in-forward",
            MutationClass::UnknownLink => "unknown-link",
            MutationClass::InflateBucket => "inflate-bucket",
            MutationClass::SwapCodecUngated => "swap-codec-ungated",
            MutationClass::BreakMultipliers => "break-multipliers",
            MutationClass::TightenStaleness => "tighten-staleness",
            MutationClass::SkewUpdateOffset => "skew-update-offset",
        }
    }

    /// The diagnostic code this mutation is designed to trip. (Side
    /// effects may trip more; the designated one must always fire.)
    pub fn expected(self) -> Code {
        match self {
            MutationClass::DropOp => Code::UnderShippedGradient,
            MutationClass::DuplicateOp => Code::DuplicateOp,
            MutationClass::FreshGradInForward => Code::FreshGradInForward,
            MutationClass::UnknownLink => Code::UnknownLink,
            MutationClass::InflateBucket => Code::CapacityOverflow,
            MutationClass::SwapCodecUngated => Code::UngatedLossyRoute,
            MutationClass::BreakMultipliers => Code::MultiplierMismatch,
            MutationClass::TightenStaleness => Code::StalenessBound,
            MutationClass::SkewUpdateOffset => Code::UpdateOffsetOutOfRange,
        }
    }

    /// Classes that need a knapsack-governed (`FwdDependency::None`,
    /// i.e. DeFT-shaped) input; the rest apply to any schedule.
    pub fn requires_knapsack(self) -> bool {
        matches!(self, MutationClass::InflateBucket)
    }
}

/// A mutated plan plus everything needed to lint it and check the
/// verdict.
#[derive(Clone, Debug)]
pub struct MutatedCase {
    pub class: MutationClass,
    pub expected: Code,
    pub schedule: Schedule,
    pub buckets: Vec<BucketProfile>,
    pub env: ClusterEnv,
}

fn pick(seed: u64, len: usize) -> usize {
    assert!(len > 0, "nothing to pick a mutation target from");
    (seed % len as u64) as usize
}

/// Addresses of every op as (iteration, window, index-in-window);
/// window 0 = fwd, 1 = bwd.
fn op_addrs(s: &Schedule) -> Vec<(usize, usize, usize)> {
    let mut addrs = Vec::new();
    for (t, p) in s.cycle.iter().enumerate() {
        for i in 0..p.fwd_ops.len() {
            addrs.push((t, 0, i));
        }
        for i in 0..p.bwd_ops.len() {
            addrs.push((t, 1, i));
        }
    }
    addrs
}

fn bwd_addrs(s: &Schedule) -> Vec<(usize, usize)> {
    let mut addrs = Vec::new();
    for (t, p) in s.cycle.iter().enumerate() {
        for i in 0..p.bwd_ops.len() {
            addrs.push((t, i));
        }
    }
    addrs
}

/// Apply `class` to a known-good plan. Panics if the input is not
/// eligible (e.g. `InflateBucket` on a barrier schedule) — the harness
/// mutates plans it knows, it does not probe arbitrary ones.
pub fn apply_mutation(
    class: MutationClass,
    schedule: &Schedule,
    buckets: &[BucketProfile],
    env: &ClusterEnv,
    seed: u64,
) -> MutatedCase {
    let mut schedule = schedule.clone();
    let mut buckets = buckets.to_vec();
    let mut env = env.clone();
    match class {
        MutationClass::DropOp => {
            let addrs = op_addrs(&schedule);
            let (t, w, i) = addrs[pick(seed, addrs.len())];
            let plan = &mut schedule.cycle[t];
            if w == 0 {
                plan.fwd_ops.remove(i);
            } else {
                plan.bwd_ops.remove(i);
            }
        }
        MutationClass::DuplicateOp => {
            let addrs = op_addrs(&schedule);
            let (t, w, i) = addrs[pick(seed, addrs.len())];
            let plan = &mut schedule.cycle[t];
            if w == 0 {
                let dup = plan.fwd_ops[i].clone();
                plan.fwd_ops.push(dup);
            } else {
                let dup = plan.bwd_ops[i].clone();
                plan.bwd_ops.push(dup);
            }
        }
        MutationClass::FreshGradInForward => {
            let addrs = bwd_addrs(&schedule);
            let (t, i) = addrs[pick(seed, addrs.len())];
            let plan = &mut schedule.cycle[t];
            let mut op = plan.bwd_ops.remove(i);
            op.stage = Stage::Forward;
            op.grad_age = 0;
            plan.fwd_ops.push(op);
        }
        MutationClass::UnknownLink => {
            let addrs = op_addrs(&schedule);
            let (t, w, i) = addrs[pick(seed, addrs.len())];
            let bogus = LinkId(env.n_links() + 7);
            let plan = &mut schedule.cycle[t];
            if w == 0 {
                plan.fwd_ops[i].link = bogus;
            } else {
                plan.bwd_ops[i].link = bogus;
            }
        }
        MutationClass::InflateBucket => {
            assert_eq!(
                schedule.fwd_dependency,
                FwdDependency::None,
                "InflateBucket needs a knapsack-governed (DeFT) schedule"
            );
            // Regularly-packed ops only: force-shipped (priority < 0)
            // buckets are exempt from the window cap by design.
            let regular: Vec<usize> = {
                let mut bs = Vec::new();
                for p in &schedule.cycle {
                    for op in p.fwd_ops.iter() {
                        bs.push(op.bucket);
                    }
                    for op in p.bwd_ops.iter().filter(|o| o.priority >= 0) {
                        bs.push(op.bucket);
                    }
                }
                bs
            };
            let b = regular[pick(seed, regular.len())];
            // Larger than the largest window capacity the lint will
            // compute, whatever the planning μs (codec-effective μ < 1
            // enlarges caps, so derive the bound from the μs themselves).
            let scale = schedule.capacity_scale();
            let scale = if scale.is_finite() && scale > 0.0 { scale } else { 1.0 };
            let fwd: Micros = buckets.iter().map(|b| b.fwd).sum();
            let bwd: Micros = buckets.iter().map(|b| b.bwd).sum();
            let window = fwd.max(bwd).scale(scale);
            let min_mu = env
                .link_planning_mus()
                .into_iter()
                .fold(f64::INFINITY, f64::min)
                .min(1.0);
            let max_cap = window.scale(1.0 / min_mu);
            buckets[b].comm = Micros(max_cap.as_us().saturating_mul(2)) + Micros(10_000);
        }
        MutationClass::SwapCodecUngated => {
            let used = schedule.links_used();
            let valid: Vec<LinkId> = used
                .into_iter()
                .filter(|l| l.index() < env.n_links())
                .collect();
            let link = valid[pick(seed, valid.len())];
            env = env.with_codec(link, Codec::RankK { k: 1 });
        }
        MutationClass::BreakMultipliers => {
            assert!(
                !schedule.batch_multipliers.is_empty(),
                "BreakMultipliers needs at least one update"
            );
            let i = pick(seed, schedule.batch_multipliers.len());
            schedule.batch_multipliers[i] += 1;
        }
        MutationClass::TightenStaleness => {
            let addrs = bwd_addrs(&schedule);
            let (t, i) = addrs[pick(seed, addrs.len())];
            schedule.max_outstanding_iters = 0;
            // Age the picked gradient one iteration so its staleness
            // span (grad_age + merged − 1 ≥ 1) exceeds the zero bound
            // on any input, DeFT or baseline.
            schedule.cycle[t].bwd_ops[i].grad_age = 1;
        }
        MutationClass::SkewUpdateOffset => {
            let addrs = op_addrs(&schedule);
            let (t, w, i) = addrs[pick(seed, addrs.len())];
            let bogus = schedule.updates_per_cycle + 2;
            let plan = &mut schedule.cycle[t];
            if w == 0 {
                plan.fwd_ops[i].update_offset = bogus;
            } else {
                plan.bwd_ops[i].update_offset = bogus;
            }
        }
    }
    MutatedCase {
        class,
        expected: class.expected(),
        schedule,
        buckets,
        env,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{lint_plan, LintOptions};
    use crate::links::LinkPreset;
    use crate::sched::{CommOp, IterPlan};

    fn base() -> (Schedule, Vec<BucketProfile>, ClusterEnv) {
        let env = LinkPreset::Paper2Link.env();
        let buckets: Vec<BucketProfile> = (0..4)
            .map(|id| BucketProfile {
                id,
                params: 2_000_000,
                fwd: Micros(9_000),
                bwd: Micros(11_000),
                comm: Micros(5_000),
            })
            .collect();
        let schedule = Schedule {
            scheme: "probe".into(),
            cycle: vec![IterPlan {
                fwd_ops: Vec::new(),
                bwd_ops: (0..4)
                    .map(|b| CommOp {
                        bucket: b,
                        link: LinkId(b % 2),
                        stage: Stage::Backward,
                        priority: b as i64,
                        grad_age: 0,
                        merged: 1,
                        update_offset: 0,
                    })
                    .collect(),
                update_at_end: true,
            }],
            fwd_dependency: FwdDependency::None,
            updates_per_cycle: 1,
            batch_multipliers: vec![1],
            warmup_iters: 0,
            max_outstanding_iters: usize::MAX,
            capacity_scale_bits: (1.0f64).to_bits(),
        };
        (schedule, buckets, env)
    }

    #[test]
    fn base_plan_is_clean_and_every_class_trips_its_code() {
        let (schedule, buckets, env) = base();
        let opts = LintOptions::default();
        let r = lint_plan(&schedule, &buckets, &env, &opts);
        assert!(r.is_clean(), "base must lint clean:\n{}", r.render_text());
        for class in MutationClass::ALL {
            for seed in [0u64, 1, 5] {
                let case = apply_mutation(class, &schedule, &buckets, &env, seed);
                let r = lint_plan(&case.schedule, &case.buckets, &case.env, &opts);
                assert!(
                    r.has_code(case.expected),
                    "{} (seed {seed}) must trip {}:\n{}",
                    class.name(),
                    case.expected.as_str(),
                    r.render_text()
                );
                assert!(
                    !r.is_clean(),
                    "{} (seed {seed}) lints clean — silent acceptance",
                    class.name()
                );
            }
        }
    }

    #[test]
    fn mutations_are_deterministic_in_the_seed() {
        let (schedule, buckets, env) = base();
        for class in MutationClass::ALL {
            let a = apply_mutation(class, &schedule, &buckets, &env, 3);
            let b = apply_mutation(class, &schedule, &buckets, &env, 3);
            assert_eq!(a.schedule, b.schedule, "{}", class.name());
            assert_eq!(a.buckets, b.buckets, "{}", class.name());
        }
    }
}
