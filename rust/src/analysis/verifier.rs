//! The lint passes: structural checks over a bare [`Schedule`]
//! ([`lint_schedule`]) and the full profile/environment-aware verifier
//! ([`lint_plan`]).

use super::{Code, LintReport, Location, WindowLoad};
use crate::faults::FaultSpec;
use crate::links::{ClusterEnv, LinkId};
use crate::models::BucketProfile;
use crate::preserver::{self, WalkParams};
use crate::sched::{cap_loss, CommOp, FwdDependency, Schedule, Stage};
use crate::util::Micros;

/// Options for [`lint_plan`]. Defaults mirror the lifecycle driver:
/// Table V's walk, the paper's ε, precision checking on.
#[derive(Clone, Debug)]
pub struct LintOptions {
    /// Run the Preserver precision lint (`DEFT-E016`). The lifecycle's
    /// pre-walk gate turns this off — the walk itself runs next.
    pub check_precision: bool,
    pub walk: WalkParams,
    pub base_batch: f64,
    pub epsilon: f64,
    /// Declared fault envelope: when set, the capacity pass additionally
    /// prices each link's planning μ at the envelope's worst wire
    /// inflation ([`FaultSpec::worst_wire_inflation`]) and warns
    /// (`DEFT-W004`) on windows that fit only the healthy capacity.
    pub fault_envelope: Option<FaultSpec>,
}

impl Default for LintOptions {
    fn default() -> LintOptions {
        let (walk, base_batch) = preserver::table5_setting();
        LintOptions {
            check_precision: true,
            walk,
            base_batch,
            epsilon: preserver::EPSILON,
            fault_envelope: None,
        }
    }
}

/// Structural lint: every invariant provable from the [`Schedule`] value
/// alone (no bucket profile, no environment). Backs
/// [`Schedule::validate`]; cheap enough for the simulator's entry check.
pub fn lint_schedule(schedule: &Schedule) -> LintReport {
    let mut r = LintReport::default();
    structural(schedule, &mut r);
    r
}

fn structural(s: &Schedule, r: &mut LintReport) {
    if s.cycle.is_empty() {
        r.push(
            Code::EmptyCycle,
            Location::schedule(),
            "the steady-state cycle contains no iterations",
        );
        return;
    }
    let len = s.cycle.len();
    let marks = s.cycle.iter().filter(|p| p.update_at_end).count();
    if marks != s.updates_per_cycle {
        r.push(
            Code::UpdateMarkerMismatch,
            Location::schedule(),
            format!(
                "{marks} update_at_end marker(s) but updates_per_cycle = {}",
                s.updates_per_cycle
            ),
        );
    }
    if s.batch_multipliers.len() != s.updates_per_cycle {
        r.push(
            Code::MultiplierMismatch,
            Location::schedule(),
            format!(
                "{} batch multiplier(s) for {} update(s)",
                s.batch_multipliers.len(),
                s.updates_per_cycle
            ),
        );
    }
    if let Some(i) = s.batch_multipliers.iter().position(|&k| k == 0) {
        r.push(
            Code::MultiplierMismatch,
            Location::schedule(),
            format!("batch multiplier #{i} is zero (every update must absorb ≥ 1 iteration)"),
        );
    }
    let ksum: u64 = s.batch_multipliers.iter().sum();
    if ksum != len as u64 {
        r.push(
            Code::MultiplierMismatch,
            Location::schedule(),
            format!("batch multipliers sum to {ksum} but the cycle has {len} iteration(s)"),
        );
    }
    for (t, plan) in s.cycle.iter().enumerate() {
        if plan.num_ops() == 0 && !plan.update_at_end {
            r.push(
                Code::EmptyIteration,
                Location::iteration(t),
                "iteration ships nothing and applies no update",
            );
        }
        for (ops, stage) in [
            (&plan.fwd_ops, Stage::Forward),
            (&plan.bwd_ops, Stage::Backward),
        ] {
            for (i, op) in ops.iter().enumerate() {
                let loc = Location::op(t, stage, op.bucket, op.link);
                if op.stage != stage {
                    r.push(
                        Code::WindowMismatch,
                        loc,
                        format!(
                            "op with stage {} sits in the {} window vector",
                            super::stage_str(op.stage),
                            super::stage_str(stage)
                        ),
                    );
                }
                if op.stage == Stage::Forward && op.grad_age == 0 {
                    r.push(
                        Code::FreshGradInForward,
                        loc,
                        "a current-iteration gradient cannot ship in the forward window \
                         (its producing backward has not run)",
                    );
                }
                if op.merged == 0 {
                    r.push(Code::DegenerateOp, loc, "op merges zero gradients");
                } else if s.max_outstanding_iters != usize::MAX {
                    let span = op.grad_age + op.merged - 1;
                    if span > s.max_outstanding_iters {
                        r.push(
                            Code::StalenessBound,
                            loc,
                            format!(
                                "oldest merged gradient is {span} iteration(s) stale, \
                                 over the bound {}",
                                s.max_outstanding_iters
                            ),
                        );
                    }
                }
                if op.update_offset > s.updates_per_cycle {
                    r.push(
                        Code::UpdateOffsetOutOfRange,
                        loc,
                        format!(
                            "update_offset {} exceeds updates_per_cycle {}",
                            op.update_offset, s.updates_per_cycle
                        ),
                    );
                }
                if ops[..i].iter().any(|prev| prev == op) {
                    r.push(
                        Code::DuplicateOp,
                        loc,
                        "the identical op appears twice in the same window",
                    );
                }
            }
        }
    }
}

/// Full static verification of a plan against its bucket profile and
/// target environment: structural checks plus registry references,
/// gradient-volume conservation, `PerBucket` coverage, §III.D knapsack
/// capacity (reproducing the solver's `Micros` arithmetic exactly), and
/// the Preserver precision gate for lossy codec routes.
pub fn lint_plan(
    schedule: &Schedule,
    buckets: &[BucketProfile],
    env: &ClusterEnv,
    opts: &LintOptions,
) -> LintReport {
    let mut r = LintReport::default();
    structural(schedule, &mut r);
    if schedule.cycle.is_empty() {
        return r;
    }
    let n_links = env.n_links();
    let n_buckets = buckets.len();
    let len = schedule.cycle.len();

    // Every (cycle position, window stage, op) triple, in engine
    // materialization order (fwd vector first, then bwd).
    let ops: Vec<(usize, Stage, &CommOp)> = schedule
        .cycle
        .iter()
        .enumerate()
        .flat_map(|(t, p)| {
            p.fwd_ops
                .iter()
                .map(move |o| (t, Stage::Forward, o))
                .chain(p.bwd_ops.iter().map(move |o| (t, Stage::Backward, o)))
        })
        .collect();

    // ---- Registry soundness (DEFT-E001/E002). ----
    let mut registry_ok = true;
    for &(t, stage, op) in &ops {
        if op.link.index() >= n_links {
            registry_ok = false;
            r.push(
                Code::UnknownLink,
                Location::op(t, stage, op.bucket, op.link),
                format!(
                    "op routes over link #{} but the registry has {n_links} link(s)",
                    op.link.index()
                ),
            );
        }
        if op.bucket >= n_buckets {
            registry_ok = false;
            r.push(
                Code::UnknownBucket,
                Location::op(t, stage, op.bucket, op.link),
                format!(
                    "op references bucket {} but the profile has {n_buckets} bucket(s)",
                    op.bucket
                ),
            );
        }
    }

    // ---- Gradient-volume conservation (DEFT-E010/E011): over one
    // steady cycle each bucket produces `len` gradients and must ship
    // exactly `len` (merged transfers count their merge width). ----
    let mut shipped = vec![0u64; n_buckets];
    for &(_, _, op) in &ops {
        if op.bucket < n_buckets {
            shipped[op.bucket] += op.merged as u64;
        }
    }
    for (b, &ship) in shipped.iter().enumerate() {
        use std::cmp::Ordering;
        match ship.cmp(&(len as u64)) {
            Ordering::Greater => r.push(
                Code::OverShippedGradient,
                Location::bucket(b),
                format!("bucket {b} ships {ship} gradient sets per {len}-iteration cycle"),
            ),
            Ordering::Less => r.push(
                Code::UnderShippedGradient,
                Location::bucket(b),
                format!(
                    "bucket {b} ships only {ship} of {len} gradient sets per cycle \
                     (gradients silently dropped)"
                ),
            ),
            Ordering::Equal => {}
        }
    }

    if schedule.fwd_dependency == FwdDependency::PerBucket && registry_ok && n_buckets > 0 {
        coverage(schedule, n_buckets, &mut r);
    }
    if schedule.fwd_dependency == FwdDependency::None && registry_ok {
        capacity(schedule, buckets, env, &ops, opts, &mut r);
    }

    // ---- Per-link per-cycle volume accounting (consumed by the
    // sim-consistency tests and the explorer's lint table). ----
    let mut ref_comm = vec![Micros::ZERO; n_links];
    let mut raw_bytes = vec![0u64; n_links];
    for &(_, _, op) in &ops {
        if op.link.index() < n_links && op.bucket < n_buckets {
            ref_comm[op.link.index()] += buckets[op.bucket].comm;
            raw_bytes[op.link.index()] += buckets[op.bucket].params.saturating_mul(4);
        }
    }
    r.link_ref_comm = ref_comm;
    r.link_raw_bytes = raw_bytes;

    // ---- Precision (DEFT-E016): a lossy route needs a passing
    // Preserver verdict on this schedule's update sequence. ----
    let ksum: u64 = schedule.batch_multipliers.iter().sum();
    if opts.check_precision && ksum > 0 {
        let errs = env.link_path_codec_errors();
        let worst = schedule.worst_codec_error(&errs);
        if worst > 0.0 {
            let report = preserver::quantify_with_error(
                &opts.walk,
                opts.base_batch,
                &schedule.batch_multipliers,
                worst,
            );
            if !preserver::acceptable(&report, opts.epsilon) {
                let link = schedule
                    .links_used()
                    .into_iter()
                    .filter(|l| l.index() < errs.len())
                    .max_by(|a, b| errs[a.index()].total_cmp(&errs[b.index()]));
                r.push(
                    Code::UngatedLossyRoute,
                    Location {
                        link,
                        ..Location::default()
                    },
                    format!(
                        "lossy codec route (worst gradient error {worst:.4}) fails the \
                         Preserver gate: convergence ratio {:.4} outside 1 ± {}",
                        report.ratio, opts.epsilon
                    ),
                );
            }
        }
    }
    r
}

/// `PerBucket` dependency soundness over the steady window: replay the
/// engine's coverage-arena construction (last covering op wins, in
/// materialization order) for a horizon long enough that every cyclic
/// writer of the mid window exists, then require each (iteration,
/// bucket) gradient of the mid window to be covered by a transfer that
/// launches no later than the forward consuming it. A covering op in
/// the *forward* window of t+1 is legal (DeFT Case 1: the forward
/// waits on it); one in the backward window of t+1 or later deadlocks.
fn coverage(schedule: &Schedule, n: usize, r: &mut LintReport) {
    let len = schedule.cycle.len();
    let span = schedule
        .cycle
        .iter()
        .flat_map(|p| p.all_ops())
        .map(|o| o.grad_age + o.merged)
        .max()
        .unwrap_or(1);
    let horizon = 3 * len + span;
    let mut cover: Vec<Option<(usize, Stage)>> = vec![None; horizon * n];
    for t in 0..horizon {
        let plan = &schedule.cycle[t % len];
        let windowed = plan
            .fwd_ops
            .iter()
            .map(|o| (Stage::Forward, o))
            .chain(plan.bwd_ops.iter().map(|o| (Stage::Backward, o)));
        for (stage, op) in windowed {
            if t < op.grad_age {
                continue;
            }
            let newest = t - op.grad_age;
            for k in 0..op.merged {
                if k > newest {
                    break;
                }
                cover[(newest - k) * n + op.bucket] = Some((t, stage));
            }
        }
    }
    for t in len..2 * len {
        let p = t % len;
        for b in 0..n {
            match cover[t * n + b] {
                None => r.push(
                    Code::UncoveredGradient,
                    Location::iter_bucket(p, b),
                    format!(
                        "gradient (cycle iter {p}, bucket {b}) is never shipped: \
                         the next forward for bucket {b} deadlocks"
                    ),
                ),
                Some((u, stage)) if u > t + 1 || (u == t + 1 && stage == Stage::Backward) => r
                    .push(
                        Code::LateCoverage,
                        Location::iter_bucket(p, b),
                        format!(
                            "gradient (cycle iter {p}, bucket {b}) is covered only at \
                             iteration +{} in the {} window — after the forward that \
                             consumes it",
                            u - t,
                            super::stage_str(stage)
                        ),
                    ),
                Some(_) => {}
            }
        }
    }
}

/// §III.D capacity verification for knapsack-governed schedules
/// (`FwdDependency::None`), reproducing `Deft`'s packing arithmetic
/// exactly: per window, the regularly-packed reference-time load on each
/// link must fit `cap_loss(window_compute × scale, planning μ)`, where
/// `scale` is the solver's recorded capacity scale and planning μ is the
/// codec-effective segment-path slowdown times the static contention
/// factor. Force-shipped oversized buckets (priority < 0) are exempt
/// from the window cap but must be amortized by their merge width:
/// `merged × (fwd + bwd) × scale ≥ comm`, else the solver's debt can
/// never be repaid.
fn capacity(
    schedule: &Schedule,
    buckets: &[BucketProfile],
    env: &ClusterEnv,
    ops: &[(usize, Stage, &CommOp)],
    opts: &LintOptions,
    r: &mut LintReport,
) {
    let raw_scale = schedule.capacity_scale();
    let scale = if raw_scale.is_finite() && raw_scale > 0.0 {
        raw_scale
    } else {
        1.0
    };
    let mus = env.link_planning_mus();
    let n_links = env.n_links();
    let names = env.link_names();
    // Declared fault envelope: worst wire-time inflation per link (flaps
    // + elastic membership; 1.0 when no envelope is declared). Straggler
    // stretch only grows the compute windows, so it cannot shrink a
    // capacity — wire inflation is the whole degradation story here.
    let envelope_mus: Option<Vec<f64>> = opts.fault_envelope.as_ref().map(|spec| {
        (0..n_links)
            .map(|k| mus[k] * spec.worst_wire_inflation(LinkId(k), env))
            .collect()
    });
    let fwd_compute: Micros = buckets.iter().map(|b| b.fwd).sum();
    let bwd_compute: Micros = buckets.iter().map(|b| b.bwd).sum();
    let cap_iter = (fwd_compute + bwd_compute).scale(scale);
    for (t, plan) in schedule.cycle.iter().enumerate() {
        for (window_ops, stage, window_compute) in [
            (&plan.fwd_ops, Stage::Forward, fwd_compute),
            (&plan.bwd_ops, Stage::Backward, bwd_compute),
        ] {
            let scaled = window_compute.scale(scale);
            let caps: Vec<Micros> = mus.iter().map(|&mu| cap_loss(scaled, mu)).collect();
            let mut load = vec![Micros::ZERO; n_links];
            for op in window_ops {
                let comm = buckets[op.bucket].comm;
                if stage == Stage::Backward && op.priority < 0 {
                    let amortized = Micros(cap_iter.as_us().saturating_mul(op.merged as u64));
                    if amortized < comm {
                        r.push(
                            Code::ForceShipUnamortized,
                            Location::op(t, stage, op.bucket, op.link),
                            format!(
                                "force-shipped bucket {} needs {} µs of wire but its {} \
                                 merged iteration(s) amortize only {} µs",
                                op.bucket,
                                comm.as_us(),
                                op.merged,
                                amortized.as_us()
                            ),
                        );
                    }
                    continue;
                }
                load[op.link.index()] += comm;
            }
            for (k, (&l, &cap)) in load.iter().zip(caps.iter()).enumerate() {
                r.loads.push(WindowLoad {
                    iter: t,
                    stage,
                    link: LinkId(k),
                    load: l,
                    cap,
                });
                if l > cap {
                    r.push(
                        Code::CapacityOverflow,
                        Location::window_link(t, stage, LinkId(k)),
                        format!(
                            "link {} carries {} µs of reference comm in a {} window \
                             with knapsack capacity {} µs (scale {scale:.3})",
                            names.get(k).map(String::as_str).unwrap_or("?"),
                            l.as_us(),
                            super::stage_str(stage),
                            cap.as_us()
                        ),
                    );
                } else if let Some(emus) = &envelope_mus {
                    let degraded = cap_loss(scaled, emus[k]);
                    if l > degraded {
                        r.push(
                            Code::FaultEnvelopeCapacity,
                            Location::window_link(t, stage, LinkId(k)),
                            format!(
                                "link {} carries {} µs of reference comm in a {} window: \
                                 fits the healthy capacity {} µs but not the {} µs left \
                                 under the declared fault envelope (worst wire inflation \
                                 {:.3}×)",
                                names.get(k).map(String::as_str).unwrap_or("?"),
                                l.as_us(),
                                super::stage_str(stage),
                                cap.as_us(),
                                degraded.as_us(),
                                emus[k] / mus[k].max(f64::MIN_POSITIVE)
                            ),
                        );
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::links::{Codec, LinkPreset};
    use crate::sched::IterPlan;

    fn op(bucket: usize, link: usize, stage: Stage, grad_age: usize) -> CommOp {
        CommOp {
            bucket,
            link: LinkId(link),
            stage,
            priority: 0,
            grad_age,
            merged: 1,
            update_offset: 0,
        }
    }

    /// One-iteration WFBP-shaped schedule over `n` buckets on link 0.
    fn wfbp_like(n: usize, dep: FwdDependency) -> Schedule {
        Schedule {
            scheme: "probe".into(),
            cycle: vec![IterPlan {
                fwd_ops: Vec::new(),
                bwd_ops: (0..n).map(|b| op(b, 0, Stage::Backward, 0)).collect(),
                update_at_end: true,
            }],
            fwd_dependency: dep,
            updates_per_cycle: 1,
            batch_multipliers: vec![1],
            warmup_iters: 0,
            max_outstanding_iters: usize::MAX,
            capacity_scale_bits: (1.0f64).to_bits(),
        }
    }

    fn probe_buckets(n: usize) -> Vec<BucketProfile> {
        (0..n)
            .map(|id| BucketProfile {
                id,
                params: 1_000_000,
                fwd: Micros(10_000),
                bwd: Micros(12_000),
                comm: Micros(4_000),
            })
            .collect()
    }

    #[test]
    fn clean_plan_lints_clean() {
        let env = LinkPreset::Paper2Link.env();
        let s = wfbp_like(3, FwdDependency::Barrier);
        let r = lint_plan(&s, &probe_buckets(3), &env, &LintOptions::default());
        assert!(r.is_clean(), "{}", r.render_text());
        assert_eq!(r.diagnostics.len(), 0);
        assert_eq!(r.link_ref_comm[0], Micros(12_000));
        assert_eq!(r.link_raw_bytes[0], 3 * 4_000_000);
        assert_eq!(r.link_ref_comm[1], Micros::ZERO);
    }

    #[test]
    fn structural_codes_fire() {
        let env = LinkPreset::Paper2Link.env();
        let buckets = probe_buckets(3);
        let lint = |s: &Schedule| lint_plan(s, &buckets, &env, &LintOptions::default());

        let mut s = wfbp_like(3, FwdDependency::Barrier);
        s.cycle.clear();
        assert!(lint(&s).has_code(Code::EmptyCycle));

        let mut s = wfbp_like(3, FwdDependency::Barrier);
        s.updates_per_cycle = 2;
        let r = lint(&s);
        assert!(r.has_code(Code::UpdateMarkerMismatch));
        assert!(r.has_code(Code::MultiplierMismatch));

        let mut s = wfbp_like(3, FwdDependency::Barrier);
        s.batch_multipliers = vec![0];
        let r = lint(&s);
        assert!(r.has_code(Code::MultiplierMismatch));

        let mut s = wfbp_like(3, FwdDependency::Barrier);
        let dup = s.cycle[0].bwd_ops[1].clone();
        s.cycle[0].bwd_ops.push(dup);
        let r = lint(&s);
        assert!(r.has_code(Code::DuplicateOp));
        assert!(r.has_code(Code::OverShippedGradient));

        let mut s = wfbp_like(3, FwdDependency::Barrier);
        s.cycle[0].fwd_ops.push(op(0, 0, Stage::Forward, 0));
        let r = lint(&s);
        assert!(r.has_code(Code::FreshGradInForward));

        let mut s = wfbp_like(3, FwdDependency::Barrier);
        s.cycle[0].bwd_ops[0].merged = 0;
        let r = lint(&s);
        assert!(r.has_code(Code::DegenerateOp));
        assert!(r.has_code(Code::UnderShippedGradient));

        let mut s = wfbp_like(3, FwdDependency::Barrier);
        s.max_outstanding_iters = 1;
        s.cycle[0].bwd_ops[0].grad_age = 3;
        assert!(lint(&s).has_code(Code::StalenessBound));

        let mut s = wfbp_like(3, FwdDependency::Barrier);
        s.cycle[0].bwd_ops[2].update_offset = 9;
        assert!(lint(&s).has_code(Code::UpdateOffsetOutOfRange));

        let mut s = wfbp_like(3, FwdDependency::Barrier);
        s.cycle[0].bwd_ops[0].link = LinkId(9);
        assert!(lint(&s).has_code(Code::UnknownLink));

        let mut s = wfbp_like(3, FwdDependency::Barrier);
        s.cycle[0].bwd_ops[0].bucket = 7;
        let r = lint(&s);
        assert!(r.has_code(Code::UnknownBucket));
        assert!(r.has_code(Code::UnderShippedGradient));
    }

    #[test]
    fn stage_window_mismatch_is_a_warning_only() {
        let mut s = wfbp_like(2, FwdDependency::Barrier);
        let moved = s.cycle[0].bwd_ops.pop().expect("two ops");
        s.cycle[0].fwd_ops.push(moved); // stage stays Backward
        let r = lint_schedule(&s);
        assert!(r.has_code(Code::WindowMismatch));
        assert!(r.is_clean(), "{}", r.render_text());
    }

    #[test]
    fn perbucket_coverage_catches_missing_and_late_transfers() {
        let env = LinkPreset::Paper2Link.env();
        let buckets = probe_buckets(2);
        // Self-covering one-iteration cycle: clean.
        let s = wfbp_like(2, FwdDependency::PerBucket);
        let r = lint_plan(&s, &buckets, &env, &LintOptions::default());
        assert!(r.is_clean(), "{}", r.render_text());

        // Bucket 1's transfer dropped: both conservation and coverage
        // must fire.
        let mut s = wfbp_like(2, FwdDependency::PerBucket);
        s.cycle[0].bwd_ops.retain(|o| o.bucket != 1);
        let r = lint_plan(&s, &buckets, &env, &LintOptions::default());
        assert!(r.has_code(Code::UncoveredGradient), "{}", r.render_text());
        assert!(r.has_code(Code::UnderShippedGradient));

        // Bucket 1 shipped one iteration late **in the backward window**:
        // the consuming forward has already passed — deadlock.
        let mut s = wfbp_like(2, FwdDependency::PerBucket);
        s.cycle[0].bwd_ops[1].grad_age = 1;
        let r = lint_plan(&s, &buckets, &env, &LintOptions::default());
        assert!(r.has_code(Code::LateCoverage), "{}", r.render_text());

        // The same one-iteration lag in the **forward** window is DeFT
        // Case 1 and legal: the forward waits on the arriving wire.
        let mut s = wfbp_like(2, FwdDependency::PerBucket);
        let mut moved = s.cycle[0].bwd_ops.remove(1);
        moved.stage = Stage::Forward;
        moved.grad_age = 1;
        s.cycle[0].fwd_ops.push(moved);
        let r = lint_plan(&s, &buckets, &env, &LintOptions::default());
        assert!(r.is_clean(), "{}", r.render_text());
    }

    #[test]
    fn capacity_overflow_and_force_amortization() {
        let env = LinkPreset::Paper2Link.env();
        let mut buckets = probe_buckets(2);
        let s = wfbp_like(2, FwdDependency::None);
        // Window capacity on link 0 = Σbwd = 24 000 µs (μ = 1, scale 1);
        // the 8 000 µs load fits.
        let r = lint_plan(&s, &buckets, &env, &LintOptions::default());
        assert!(r.is_clean(), "{}", r.render_text());
        let bwd0 = r
            .loads
            .iter()
            .find(|w| w.stage == Stage::Backward && w.link == LinkId(0))
            .expect("bwd window load");
        assert_eq!(bwd0.load, Micros(8_000));
        assert_eq!(bwd0.cap, Micros(24_000));

        // Inflate bucket 1 past every window capacity.
        buckets[1].comm = Micros(60_000);
        let r = lint_plan(&s, &buckets, &env, &LintOptions::default());
        assert!(r.has_code(Code::CapacityOverflow), "{}", r.render_text());

        // A force-shipped (priority < 0) op is exempt from the window cap
        // but must amortize: merged = 3 × cap_iter 44 000 ≥ 60 000 ✓.
        let mut s2 = wfbp_like(2, FwdDependency::None);
        s2.cycle[0].bwd_ops[1].priority = -1;
        s2.cycle[0].bwd_ops[1].merged = 3;
        // (merged 3 over a 1-iteration cycle trips over-shipping too —
        // this probe only asserts the two capacity codes.)
        let r = lint_plan(&s2, &buckets, &env, &LintOptions::default());
        assert!(!r.has_code(Code::CapacityOverflow), "{}", r.render_text());
        assert!(!r.has_code(Code::ForceShipUnamortized));

        // merged = 1 only amortizes 44 000 µs < 60 000 µs.
        s2.cycle[0].bwd_ops[1].merged = 1;
        let r = lint_plan(&s2, &buckets, &env, &LintOptions::default());
        assert!(r.has_code(Code::ForceShipUnamortized), "{}", r.render_text());
    }

    #[test]
    fn fault_envelope_warns_on_degraded_capacity_only() {
        use crate::faults::{FaultSpec, Flap};
        let env = LinkPreset::Paper2Link.env();
        let buckets = probe_buckets(2);
        let s = wfbp_like(2, FwdDependency::None);
        let envelope = |factor: f64| LintOptions {
            fault_envelope: Some(FaultSpec {
                flaps: vec![Flap {
                    link: LinkId(0),
                    at: Micros(10_000),
                    factor,
                }],
                ..FaultSpec::default()
            }),
            ..LintOptions::default()
        };
        // Load 8 000 µs on link 0, healthy cap 24 000 µs. A 4× flap
        // shrinks the envelope cap to 6 000 µs: W004, still clean
        // (warning severity).
        let r = lint_plan(&s, &buckets, &env, &envelope(4.0));
        assert!(r.has_code(Code::FaultEnvelopeCapacity), "{}", r.render_text());
        assert!(r.is_clean(), "W004 must stay a warning: {}", r.render_text());
        // A 2× flap leaves 12 000 µs — the load survives the envelope.
        let r = lint_plan(&s, &buckets, &env, &envelope(2.0));
        assert!(!r.has_code(Code::FaultEnvelopeCapacity), "{}", r.render_text());
        // No envelope declared: no W004 path at all.
        let r = lint_plan(&s, &buckets, &env, &LintOptions::default());
        assert!(r.diagnostics.is_empty(), "{}", r.render_text());
    }

    #[test]
    fn recorded_capacity_scale_governs_the_cap() {
        let env = LinkPreset::Paper2Link.env();
        let mut buckets = probe_buckets(2);
        buckets[0].comm = Micros(30_000); // > Σbwd 24 000 at scale 1
        let mut s = wfbp_like(2, FwdDependency::None);
        let r = lint_plan(&s, &buckets, &env, &LintOptions::default());
        assert!(r.has_code(Code::CapacityOverflow));
        // The solver recorded an enlarged capacity: 24 000 × 1.5 fits.
        s.capacity_scale_bits = (1.5f64).to_bits();
        let r = lint_plan(&s, &buckets, &env, &LintOptions::default());
        assert!(r.is_clean(), "{}", r.render_text());
    }

    #[test]
    fn lossy_route_without_verdict_errors() {
        let env = LinkPreset::Paper2Link
            .env()
            .with_codec(LinkId(0), Codec::RankK { k: 1 });
        let buckets = probe_buckets(2);
        let s = wfbp_like(2, FwdDependency::Barrier);
        let r = lint_plan(&s, &buckets, &env, &LintOptions::default());
        assert!(r.has_code(Code::UngatedLossyRoute), "{}", r.render_text());
        // Precision off (the lifecycle's pre-walk gate): no E016.
        let opts = LintOptions {
            check_precision: false,
            ..LintOptions::default()
        };
        let r = lint_plan(&s, &buckets, &env, &opts);
        assert!(r.is_clean(), "{}", r.render_text());
        // fp16's error passes the walk: clean even with precision on.
        let env = LinkPreset::Paper2Link
            .env()
            .with_codec(LinkId(0), Codec::Fp16);
        let r = lint_plan(&s, &buckets, &env, &LintOptions::default());
        assert!(r.is_clean(), "{}", r.render_text());
    }
}
