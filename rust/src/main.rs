//! `deft` — the CLI / launcher for the DeFT reproduction.
//!
//! Subcommands:
//!   simulate   run workload × scheme through the DES, print metrics + Gantt
//!   compare    all four schemes side by side on one workload
//!   train      real end-to-end DP training via the PJRT runtime
//!   features   print the Table III feature matrix
//!
//! Options are `--key=value` overrides of the experiment config (see
//! `deft::config::ExperimentConfig`), plus `--config=FILE` to load a
//! TOML-subset config.

use std::collections::BTreeMap;
use std::process::ExitCode;

use deft::bench::{run_pipeline, workload_by_name};
use deft::config::{ExperimentConfig, Scheme};
use deft::metrics::{gantt_steady, Table};
use deft::train::{TrainOptions, Trainer};

fn usage() -> &'static str {
    "usage: deft <simulate|compare|train|features> [--config=FILE] [--key=value ...]\n\
     keys: workload scheme workers bandwidth_gbps multi_link links_preset\n\
           partition_size ddp_bucket_mb iterations warmup mu preserver\n\
           epsilon seed   (links_preset: paper-2link | single-nic | nvlink-ib-tcp)\n\
     topology: ranks_per_node topology.intra topology.inter topology.codec\n\
           (hierarchical rank-level topology; intra/inter name registry links;\n\
            codec compresses the inter fabric: raw | fp16 | rank<k>)\n\
     codecs: per-link compression via [[links]] codec entries in a config\n\
           file (fp16 halves wire bytes; rank<k> is PowerSGD-style low-rank;\n\
           lossy codecs are gated by the Preserver)\n\
     train-only: --manifest=PATH --lr=F --momentum=F --log-every=N"
}

fn parse_args(args: &[String]) -> Result<(BTreeMap<String, String>, Option<String>), String> {
    let mut overrides = BTreeMap::new();
    let mut config_file = None;
    for a in args {
        let Some(body) = a.strip_prefix("--") else {
            return Err(format!("unexpected argument `{a}`\n{}", usage()));
        };
        let (k, v) = body
            .split_once('=')
            .ok_or_else(|| format!("expected --key=value, got `{a}`"))?;
        if k == "config" {
            config_file = Some(v.to_string());
        } else {
            overrides.insert(k.replace('-', "_"), v.to_string());
        }
    }
    Ok((overrides, config_file))
}

fn load_config(
    overrides: &BTreeMap<String, String>,
    config_file: &Option<String>,
) -> Result<ExperimentConfig, String> {
    let mut cfg = match config_file {
        Some(f) => {
            let text = std::fs::read_to_string(f).map_err(|e| format!("reading {f}: {e}"))?;
            ExperimentConfig::from_toml(&text)?
        }
        None => ExperimentConfig::default(),
    };
    // Train-only keys are consumed elsewhere; filter them here.
    let mut core = overrides.clone();
    for k in ["manifest", "lr", "momentum", "log_every"] {
        core.remove(k);
    }
    cfg.apply_overrides(&core)?;
    Ok(cfg)
}

fn cmd_simulate(cfg: &ExperimentConfig) -> Result<(), String> {
    let w = workload_by_name(&cfg.workload).map_err(|e| format!("{e:#}"))?;
    let env = cfg.env();
    let r = run_pipeline(
        &w,
        cfg.scheme,
        &env,
        cfg.partition_size,
        cfg.ddp_bucket_mb,
        cfg.iterations,
    )
    .map_err(|e| format!("{e:#}"))?;
    println!(
        "workload={} scheme={} workers={} bw={}Gbps links={}",
        w.name,
        cfg.scheme.name(),
        cfg.workers,
        cfg.bandwidth_gbps,
        env.link_names().join("+")
    );
    println!(
        "buckets={} cycle={} updates/cycle={} k={:?}",
        r.buckets.len(),
        r.schedule.cycle.len(),
        r.schedule.updates_per_cycle,
        r.schedule.batch_multipliers
    );
    println!(
        "steady iter time = {}   bubble ratio = {:.1}%   throughput = {:.1} samples/s",
        r.sim.steady_iter_time,
        r.sim.bubble_ratio() * 100.0,
        r.sim.throughput(w.batch_size, cfg.workers)
    );
    println!("\n{}", gantt_steady(&r.sim, r.schedule.cycle.len(), 110));
    Ok(())
}

fn cmd_compare(cfg: &ExperimentConfig) -> Result<(), String> {
    let w = workload_by_name(&cfg.workload).map_err(|e| format!("{e:#}"))?;
    let env = cfg.env();
    let mut table = Table::new(&[
        "scheme",
        "iter time",
        "bubble %",
        "samples/s",
        "updates/iter",
        "speedup vs ddp",
    ]);
    let mut ddp_time = None;
    let mut schemes = Scheme::ALL.to_vec();
    schemes.push(Scheme::DeftNoMultilink);
    for scheme in schemes {
        let r = run_pipeline(
            &w,
            scheme,
            &env,
            cfg.partition_size,
            cfg.ddp_bucket_mb,
            cfg.iterations,
        )
        .map_err(|e| format!("{e:#}"))?;
        let t = r.sim.steady_iter_time;
        if scheme == Scheme::PytorchDdp {
            ddp_time = Some(t);
        }
        let speedup = ddp_time
            .map(|d| format!("{:.2}x", d.ratio(t)))
            .unwrap_or_else(|| "-".into());
        table.row(&[
            scheme.name().to_string(),
            format!("{t}"),
            format!("{:.1}", r.sim.bubble_ratio() * 100.0),
            format!("{:.1}", r.sim.throughput(w.batch_size, cfg.workers)),
            format!("{:.2}", r.schedule.update_frequency()),
            speedup,
        ]);
    }
    println!(
        "workload={} workers={} bw={}Gbps links={}",
        w.name,
        cfg.workers,
        cfg.bandwidth_gbps,
        env.link_names().join("+")
    );
    println!("{}", table.render());
    Ok(())
}

fn cmd_train(
    cfg: &ExperimentConfig,
    overrides: &BTreeMap<String, String>,
) -> Result<(), String> {
    let mut opts = TrainOptions {
        scheme: cfg.scheme,
        workers: cfg.workers.min(8),
        iterations: cfg.iterations,
        env: cfg.env(),
        ..TrainOptions::default()
    };
    if let Some(m) = overrides.get("manifest") {
        opts.manifest = m.clone();
    }
    if let Some(lr) = overrides.get("lr") {
        opts.lr = lr.parse().map_err(|e| format!("lr: {e}"))?;
    }
    if let Some(m) = overrides.get("momentum") {
        opts.momentum = m.parse().map_err(|e| format!("momentum: {e}"))?;
    }
    if let Some(l) = overrides.get("log_every") {
        opts.log_every = l.parse().map_err(|e| format!("log_every: {e}"))?;
    }

    let mut trainer = Trainer::new(opts.clone()).map_err(|e| format!("{e:#}"))?;
    let profiles = trainer.profile_buckets(2).map_err(|e| format!("{e:#}"))?;
    let scheduler = deft::bench::scheduler_for(cfg.scheme, cfg.preserver, &opts.env);
    let schedule = scheduler.schedule(&profiles);
    let report = trainer.run(&schedule, &profiles).map_err(|e| format!("{e:#}"))?;

    println!(
        "scheme={} workers={} iters={} updates={}",
        report.scheme, opts.workers, opts.iterations, report.updates
    );
    println!(
        "measured step = {}   simulated iter = {}",
        report.measured_step, report.sim_iter_time
    );
    println!(
        "loss curve (iter, loss):  [uniform baseline = {:.3}]",
        report.uniform_loss
    );
    for (it, loss) in &report.losses {
        println!("  {it:>5}  {loss:.4}");
    }
    println!("final loss = {:.4}", report.final_loss);
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    };
    let (overrides, config_file) = match parse_args(&args[1..]) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd.as_str() {
        "features" => {
            println!("{}", deft::sched::feature_matrix());
            Ok(())
        }
        "simulate" | "compare" | "train" => match load_config(&overrides, &config_file) {
            Ok(cfg) => match cmd.as_str() {
                "simulate" => cmd_simulate(&cfg),
                "compare" => cmd_compare(&cfg),
                _ => cmd_train(&cfg, &overrides),
            },
            Err(e) => Err(e),
        },
        other => Err(format!("unknown command `{other}`\n{}", usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
