//! US-Byte baseline: non-sequential greedy scheduling of unequal-sized
//! tensor blocks (paper §II.B, TPDS'23 ref [12]).
//!
//! US-Byte's observation: with unequal block sizes, strict layer-priority
//! order is sub-optimal — sometimes a longer, later-needed block should
//! transmit first to reduce the total stall of the next iteration's
//! forward. We reconstruct their low-complexity greedy as a one-step
//! lookahead: at each link-free instant, among *ready* blocks pick the
//! one whose selection minimizes the projected forward makespan of the
//! next iteration (remaining blocks ordered by deadline). O(N³) offline,
//! once per schedule.

use super::{CommOp, FwdDependency, IterPlan, Schedule, Scheduler, Stage};
use crate::links::{ClusterEnv, LinkId};
use crate::models::BucketProfile;
use crate::util::Micros;

/// Non-sequential greedy scheduler à la US-Byte.
///
/// US-Byte drives a single communication queue; which link carries it —
/// and how expensive the greedy lookahead should assume its wires are —
/// comes from the environment's conservative static estimate
/// ([`UsByte::for_env`]): the planning-fastest registry link, with
/// projected wire times scaled by that link's planning slowdown
/// (`ClusterEnv::planning_mu` — path μ × static shared-NIC contention
/// factor of the configured contention model). The default is the
/// reference link at scale 1, which every preset resolves to.
#[derive(Clone, Copy, Debug)]
pub struct UsByte {
    /// Registry link the single comm queue rides.
    pub link: LinkId,
    /// Static planning slowdown of that link, applied to the greedy
    /// lookahead's projected wire times (1.0 = reference pricing).
    pub comm_scale: f64,
}

impl Default for UsByte {
    fn default() -> Self {
        UsByte {
            link: LinkId::REFERENCE,
            comm_scale: 1.0,
        }
    }
}

impl UsByte {
    /// US-Byte for a concrete environment: ride the planning-fastest
    /// link and project its wires at that link's planning slowdown.
    pub fn for_env(env: &ClusterEnv) -> UsByte {
        let link = env.planning_fastest_link();
        UsByte {
            link,
            comm_scale: env.planning_mu(link),
        }
    }

    /// Projected wire time of a bucket under the planning estimate.
    fn wire(&self, comm: Micros) -> Micros {
        if self.comm_scale == 1.0 {
            comm
        } else {
            comm.scale(self.comm_scale)
        }
    }

    /// Compute the transmission order for one steady-state iteration.
    ///
    /// Inputs are the steady-state readiness times of each bucket's
    /// gradient (relative to backward start) and the forward/comm times;
    /// output is the bucket order the link should follow.
    fn greedy_order(&self, buckets: &[BucketProfile]) -> Vec<usize> {
        let n = buckets.len();
        // Gradient readiness: backward runs n-1 .. 0.
        let mut ready = vec![Micros::ZERO; n];
        let mut cursor = Micros::ZERO;
        for b in (0..n).rev() {
            cursor += buckets[b].bwd;
            ready[b] = cursor;
        }
        let bwd_total = cursor;

        // Evaluate a complete order: simulated comm finish times, then the
        // next iteration's forward makespan (fwd_b waits for comm_b).
        let eval = |order: &[usize]| -> Micros {
            let mut link_t = Micros::ZERO;
            let mut done = vec![Micros::ZERO; n];
            for &b in order {
                link_t = link_t.max(ready[b]) + self.wire(buckets[b].comm);
                done[b] = link_t;
            }
            let mut fwd_cursor = bwd_total; // forward starts after backward
            for b in 0..n {
                fwd_cursor = fwd_cursor.max(done[b]) + buckets[b].fwd;
            }
            fwd_cursor
        };

        let mut remaining: Vec<usize> = (0..n).collect();
        let mut order: Vec<usize> = Vec::with_capacity(n);
        let mut link_t = Micros::ZERO;
        while !remaining.is_empty() {
            // Ready candidates at the link's current free time (or the
            // earliest-ready if none).
            let min_ready = remaining.iter().map(|&b| ready[b]).min().expect("remaining is non-empty");
            let decision_t = link_t.max(min_ready);
            let candidates: Vec<usize> = remaining
                .iter()
                .copied()
                .filter(|&b| ready[b] <= decision_t)
                .collect();
            let mut best: Option<(Micros, usize)> = None;
            for &c in &candidates {
                // Tentative full order: c, then the rest by layer index
                // (deadline order).
                let mut tail: Vec<usize> =
                    remaining.iter().copied().filter(|&b| b != c).collect();
                tail.sort_unstable();
                let mut cand_order = order.clone();
                cand_order.push(c);
                cand_order.extend(tail);
                let makespan = eval(&cand_order);
                if best.map_or(true, |(m, bb)| (makespan, c) < (m, bb)) {
                    best = Some((makespan, c));
                }
            }
            let (_, chosen) = best.expect("candidates nonempty");
            link_t = link_t.max(ready[chosen]) + self.wire(buckets[chosen].comm);
            order.push(chosen);
            remaining.retain(|&b| b != chosen);
        }
        order
    }
}

impl Scheduler for UsByte {
    fn name(&self) -> &'static str {
        "us-byte"
    }

    fn schedule(&self, buckets: &[BucketProfile]) -> Schedule {
        let n = buckets.len();
        assert!(n > 0);
        let order = self.greedy_order(buckets);
        let bwd_ops = order
            .iter()
            .enumerate()
            .map(|(pos, &bucket)| CommOp {
                bucket,
                link: self.link,
                stage: Stage::Backward,
                priority: pos as i64,
                grad_age: 0,
                merged: 1,
                update_offset: 0,
            })
            .collect();
        Schedule {
            scheme: self.name().into(),
            cycle: vec![IterPlan {
                fwd_ops: Vec::new(),
                bwd_ops,
                update_at_end: true,
            }],
            fwd_dependency: FwdDependency::PerBucket,
            updates_per_cycle: 1,
            batch_multipliers: vec![1],
            warmup_iters: 1,
            max_outstanding_iters: usize::MAX,
            capacity_scale_bits: (1.0f64).to_bits(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{vgg19_table2_buckets, BucketProfile};

    #[test]
    fn order_is_a_permutation() {
        let buckets = vgg19_table2_buckets();
        let order = UsByte::default().greedy_order(&buckets);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..buckets.len()).collect::<Vec<_>>());
    }

    #[test]
    fn schedule_validates() {
        let s = UsByte::default().schedule(&vgg19_table2_buckets());
        s.validate().unwrap();
        assert_eq!(s.ops_per_cycle(), 6);
    }

    #[test]
    fn for_env_rides_the_planning_fastest_link() {
        use crate::links::{ClusterEnv, LinkPreset, LinkSpec};
        // Every preset resolves to the reference link at scale 1 — the
        // historical behaviour, bit-for-bit.
        for preset in LinkPreset::ALL {
            let s = UsByte::for_env(&preset.env());
            assert_eq!(s.link, LinkId::REFERENCE, "{}", preset.name());
            assert!((s.comm_scale - 1.0).abs() < 1e-12, "{}", preset.name());
        }
        // A registry whose reference link pays shared-NIC contention:
        // the static estimate routes the queue onto the exempt peer.
        let env = ClusterEnv::paper_testbed().with_links(vec![
            LinkSpec::new("ref", 1.0).with_alpha(Micros(300)).with_group(0),
            LinkSpec::new("peer", 1.0).with_alpha(Micros(100)).with_group(0),
        ]);
        let s = UsByte::for_env(&env);
        assert_eq!(s.link, LinkId(1), "exempt peer must win the planning order");
        assert!((s.comm_scale - 1.0).abs() < 1e-12);
        let schedule = s.schedule(&vgg19_table2_buckets());
        assert!(schedule.cycle[0].bwd_ops.iter().all(|op| op.link == LinkId(1)));
    }

    #[test]
    fn non_sequential_when_sizes_are_unequal() {
        // A case where strict priority is sub-optimal: a tiny bucket 0
        // ready last, a huge bucket 1 ready earlier. The greedy should
        // transmit the huge one first (it is ready first anyway) — i.e.
        // NOT hold the link idle for priority order.
        let buckets = vec![
            BucketProfile {
                id: 0,
                params: 1,
                fwd: Micros(10),
                bwd: Micros(100),
                comm: Micros(5),
            },
            BucketProfile {
                id: 1,
                params: 1,
                fwd: Micros(10),
                bwd: Micros(10),
                comm: Micros(200),
            },
        ];
        let order = UsByte::default().greedy_order(&buckets);
        assert_eq!(order[0], 1, "greedy should ship the ready bucket first");
    }
}
