//! Bytescheduler baseline: priority (sequential) scheduling (paper §II.B,
//! SOSP'19 ref [8]).
//!
//! Gradient blocks are uniform partitions (see
//! `partition::Strategy::Uniform`); the communication queue serves blocks
//! by **layer priority** — the block nearest the input (bucket 0) always
//! preempts queue order — so the next iteration's forward can begin as
//! early as possible, and lower-priority blocks spill naturally into the
//! forward window (overlapping forward compute).

use super::{CommOp, FwdDependency, IterPlan, Schedule, Scheduler, Stage};
use crate::links::{ClusterEnv, LinkId};
use crate::models::BucketProfile;

/// Priority / sequential scheduler à la Bytescheduler & P3.
///
/// Bytescheduler drives a single priority queue; which registry link
/// carries it comes from the environment's conservative static estimate
/// ([`Bytescheduler::for_env`] picks the planning-fastest link —
/// `ClusterEnv::planning_mu`, i.e. path μ × static shared-NIC contention
/// factor of the configured contention model). The default is the
/// reference link, which every preset resolves to.
#[derive(Clone, Copy, Debug)]
pub struct Bytescheduler {
    /// Registry link the priority queue rides.
    pub link: LinkId,
}

impl Default for Bytescheduler {
    fn default() -> Self {
        Bytescheduler {
            link: LinkId::REFERENCE,
        }
    }
}

impl Bytescheduler {
    /// Bytescheduler for a concrete environment: ride the
    /// planning-fastest link.
    pub fn for_env(env: &ClusterEnv) -> Bytescheduler {
        Bytescheduler {
            link: env.planning_fastest_link(),
        }
    }
}

impl Scheduler for Bytescheduler {
    fn name(&self) -> &'static str {
        "bytescheduler"
    }

    fn schedule(&self, buckets: &[BucketProfile]) -> Schedule {
        let n = buckets.len();
        assert!(n > 0);
        // All ops launch in the backward window when their gradient is
        // ready; the link's priority queue (smallest bucket index first)
        // realises the sequential-priority policy, and unfinished ops
        // keep transmitting through the next forward window.
        let bwd_ops = (0..n)
            .map(|bucket| CommOp {
                bucket,
                link: self.link,
                stage: Stage::Backward,
                priority: bucket as i64, // input-side first
                grad_age: 0,
                merged: 1,
                update_offset: 0,
            })
            .collect();
        Schedule {
            scheme: self.name().into(),
            cycle: vec![IterPlan {
                fwd_ops: Vec::new(),
                bwd_ops,
                update_at_end: true,
            }],
            fwd_dependency: FwdDependency::PerBucket,
            updates_per_cycle: 1,
            batch_multipliers: vec![1],
            warmup_iters: 1,
            max_outstanding_iters: usize::MAX,
            capacity_scale_bits: (1.0f64).to_bits(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::vgg19_table2_buckets;

    #[test]
    fn priorities_follow_layer_order() {
        let buckets = vgg19_table2_buckets();
        let s = Bytescheduler::default().schedule(&buckets);
        s.validate().unwrap();
        assert_eq!(s.fwd_dependency, FwdDependency::PerBucket);
        for (i, op) in s.cycle[0].bwd_ops.iter().enumerate() {
            assert_eq!(op.bucket, i);
            assert_eq!(op.priority, i as i64);
        }
    }

    #[test]
    fn for_env_resolves_presets_to_the_reference_link() {
        use crate::links::LinkPreset;
        for preset in LinkPreset::ALL {
            let s = Bytescheduler::for_env(&preset.env());
            assert_eq!(s.link, LinkId::REFERENCE, "{}", preset.name());
        }
    }
}
