//! The DeFT system lifecycle (paper Fig. 7 and §IV.A).
//!
//! During the early stage of training:
//! 1. the **Profiler** collects raw operator logs and reconstructs them
//!    at bucket level;
//! 2. the **Solver** produces a scheduling result, which DeFT
//!    *temporarily applies* for several trial iterations;
//! 3. the **Preserver** quantifies the expected convergence difference;
//!    if it exceeds ε the Solver's knapsack capacity is enlarged and the
//!    schedule re-solved (≤ 10 retries);
//! 4. the accepted schedule is applied to the rest of training.
//!
//! This module wires those stages together over the simulator (or, via
//! the same `BucketProfile` contract, over the real trainer), so the
//! full closed loop of the paper is executable and testable — not just
//! the solver in isolation.

use crate::links::ClusterEnv;
use crate::models::{BucketProfile, Workload};
use crate::preserver::{self, WalkParams};
use crate::profiler::{generate_trace, reconstruct, TraceOptions};
use crate::sched::{Deft, DeftOptions, Schedule, Scheduler};
use crate::sim::{simulate, SimOptions, SimResult};

/// Outcome of one lifecycle run.
pub struct LifecycleReport {
    /// Bucket profile recovered by the Profiler.
    pub profile: Vec<BucketProfile>,
    /// The accepted schedule.
    pub schedule: Schedule,
    /// Preserver verdicts per Solver attempt: (capacity scale, ratio).
    pub attempts: Vec<(f64, f64)>,
    /// Trial simulation of the accepted schedule.
    pub trial: SimResult,
}

/// Options for the lifecycle driver.
pub struct LifecycleOptions {
    /// Number of buckets the Profiler aggregates operators into.
    pub n_buckets: usize,
    /// Trial iterations per candidate schedule.
    pub trial_iters: usize,
    pub epsilon: f64,
    pub walk: WalkParams,
    pub base_batch: f64,
    pub deft: DeftOptions,
}

impl Default for LifecycleOptions {
    fn default() -> Self {
        let (walk, base_batch) = preserver::table5_setting();
        LifecycleOptions {
            n_buckets: 8,
            trial_iters: 24,
            epsilon: preserver::EPSILON,
            walk,
            base_batch,
            deft: DeftOptions {
                preserver: false, // the lifecycle drives the feedback itself
                ..DeftOptions::default()
            },
        }
    }
}

/// Run the full Fig. 7 loop for `workload` on `env`.
///
/// The Profiler consumes a synthetic raw trace of the workload (same
/// schema as the paper's Nsight logs) and prices communication through
/// the link model; the Solver/Preserver loop then converges on a
/// schedule, which is trial-simulated and returned.
pub fn run_lifecycle(
    workload: &Workload,
    env: &ClusterEnv,
    opts: &LifecycleOptions,
) -> LifecycleReport {
    // --- 1. Profile: raw operator logs → bucket-level times. ---
    let topts = TraceOptions::uniform(workload, opts.n_buckets);
    let (events, _truth) = generate_trace(workload, &topts);
    let rec = reconstruct(&events);
    // Attach parameter counts (the trace carries layer spans; params per
    // bucket follow the same uniform layer split the trace used).
    let mut profile: Vec<BucketProfile> = Vec::with_capacity(rec.len());
    let mut layer = 0usize;
    for (b, r) in rec.iter().enumerate() {
        let count = topts.layers_per_bucket[b];
        let params: u64 = workload.layers[layer..layer + count]
            .iter()
            .map(|l| l.params)
            .sum();
        layer += count;
        profile.push(BucketProfile {
            id: r.id,
            params,
            fwd: r.fwd,
            bwd: r.bwd,
            // Price in the flat reference-ring unit for the *target*
            // environment (the trace's comm column is from the profiling
            // run); link/segment factors apply downstream.
            comm: env.reference_comm(params, workload.comm_rate_ref),
        });
    }

    // --- 2+3. Solve → trial → preserve, with capacity feedback. ---
    let mut scale = opts.deft.capacity_scale;
    let mut attempts = Vec::new();
    let mut accepted: Option<Schedule> = None;
    for _ in 0..=preserver::MAX_RETRIES {
        let deft = Deft::new(DeftOptions {
            capacity_scale: scale,
            preserver: false,
            // The knapsack set always follows the target environment's
            // link registry (one knapsack per link, capacities from the
            // segment-path slowdowns).
            link_mus: env.link_path_mus(),
            ..opts.deft.clone()
        });
        let schedule = deft.schedule(&profile);
        let report = preserver::quantify(&opts.walk, opts.base_batch, &schedule.batch_multipliers);
        attempts.push((scale, report.ratio));
        if preserver::acceptable(&report, opts.epsilon) {
            accepted = Some(schedule);
            break;
        }
        accepted = Some(schedule); // keep the closest so far
        scale *= 1.15;
    }
    let schedule = accepted.expect("at least one attempt");

    // --- 4. Trial application (simulated). ---
    let trial = simulate(
        &profile,
        &schedule,
        env,
        &SimOptions {
            iterations: opts.trial_iters.max(schedule.cycle.len() * 3),
            warmup: schedule.cycle.len().max(2),
            record_timeline: false,
        },
    );

    LifecycleReport {
        profile,
        schedule,
        attempts,
        trial,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{gpt2, vgg19};

    #[test]
    fn lifecycle_converges_on_gpt2() {
        let env = ClusterEnv::paper_testbed();
        let rep = run_lifecycle(&gpt2(), &env, &LifecycleOptions::default());
        assert_eq!(rep.profile.len(), 8);
        rep.schedule.validate().unwrap();
        assert!(!rep.attempts.is_empty());
        // CR ≈ 1 ⇒ the first or second attempt should already pass ε.
        assert!(
            rep.attempts.len() <= 3,
            "too many retries on CR≈1: {:?}",
            rep.attempts
        );
        assert!(rep.trial.steady_iter_time.as_us() > 0);
    }

    #[test]
    fn lifecycle_feedback_fires_on_vgg19() {
        // CR ≈ 2: the raw schedule lowers update frequency enough that
        // the Preserver must enlarge capacity at least once.
        let env = ClusterEnv::paper_testbed();
        let mut opts = LifecycleOptions::default();
        opts.deft.heterogeneous = false; // harsher: single link
        let rep = run_lifecycle(&vgg19(), &env, &opts);
        assert!(
            rep.attempts.len() >= 2,
            "expected capacity feedback on CR≈2, attempts {:?}",
            rep.attempts
        );
        // Capacity scales must be increasing.
        for w in rep.attempts.windows(2) {
            assert!(w[1].0 > w[0].0);
        }
        rep.schedule.validate().unwrap();
    }

    #[test]
    fn lifecycle_profile_matches_workload_totals() {
        let env = ClusterEnv::paper_testbed();
        let w = gpt2();
        let rep = run_lifecycle(&w, &env, &LifecycleOptions::default());
        let params: u64 = rep.profile.iter().map(|b| b.params).sum();
        assert_eq!(params, w.total_params());
        let fwd: crate::util::Micros = rep.profile.iter().map(|b| b.fwd).sum();
        // Reconstruction slack ≤ 1%.
        let err = (fwd.as_us() as f64 - w.total_fwd().as_us() as f64).abs()
            / w.total_fwd().as_us() as f64;
        assert!(err < 0.02, "fwd reconstruction off by {err}");
    }
}
