//! The DeFT system lifecycle (paper Fig. 7 and §IV.A).
//!
//! During the early stage of training:
//! 1. the **Profiler** collects raw operator logs and reconstructs them
//!    at bucket level;
//! 2. the **Solver** produces a scheduling result, which DeFT
//!    *temporarily applies* for several trial iterations;
//! 3. the **Preserver** quantifies the expected convergence difference —
//!    including, for links carrying a lossy [`crate::links::Codec`], the
//!    codec's gradient error injected into DeFT's walk; if it exceeds ε
//!    and the schedule routes over a lossy link, the registry **falls
//!    back to raw codecs** and re-solves at the same capacity (the lossy
//!    route was the problem, not the overlap budget); otherwise the
//!    Solver's knapsack capacity is enlarged and the schedule re-solved
//!    (≤ 10 retries);
//! 4. the accepted schedule is applied to the rest of training.
//!
//! This module wires those stages together over the simulator (or, via
//! the same `BucketProfile` contract, over the real trainer), so the
//! full closed loop of the paper is executable and testable — not just
//! the solver in isolation.

use crate::analysis::{lint_plan, LintOptions, LintReport};
use crate::bail;
use crate::faults::{to_ppm, FaultEvent, FaultSpec};
use crate::links::ClusterEnv;
use crate::models::{BucketProfile, Workload};
use crate::preserver::{self, WalkParams};
use crate::profiler::{generate_trace, reconstruct, TraceOptions};
use crate::sched::replan::{self, MeasuredEnv, ReplanOptions, ReplanRequest};
use crate::sched::{Deft, DeftOptions, Schedule, Scheduler};
use crate::sim::{simulate_faulted, SimOptions, SimResult};
use crate::util::error::Result;

/// Why the lifecycle abandoned its first-choice plan for the raw
/// (codec-stripped) replay — the context behind
/// [`LifecycleReport::codec_fallback`], which stays a bare flag for
/// compatibility. `None` means the first-choice registry's plan was
/// accepted as-is.
#[derive(Clone, Debug, PartialEq)]
pub enum FallbackReason {
    /// No fallback happened.
    None,
    /// The Preserver's walk rejected a lossy-codec route: the ratio fell
    /// outside ε while the clean (raw) walk passed, so the lossy codecs
    /// were the problem and the registry fell back to raw.
    CodecGateRejected {
        /// The rejected lossy walk's final-expectation ratio.
        ratio: f64,
    },
    /// The accepted lossy plan failed the full-precision static verifier
    /// against the trial environment; the lifecycle re-solved on the raw
    /// registry instead of erroring out.
    LintRejected {
        /// Rendered diagnostics of the rejected plan.
        diagnostics: String,
    },
    /// The trial's drift monitor tripped (measured per-link busy left
    /// the declared band) and the Preserver re-gate — run with the codec
    /// and drift errors composed — rejected the schedule under the
    /// degraded topology. The raw/fallback plan replaces it.
    DriftGateRejected {
        /// Iteration of the worst compounded drift error that drove the
        /// re-gate.
        alarm_iter: usize,
        /// Composed gradient error fed to the re-gate walk, in ppm.
        error_ppm: u64,
        /// The rejected re-gate walk's final-expectation ratio.
        ratio: f64,
    },
    /// Like [`FallbackReason::DriftGateRejected`], but instead of the
    /// raw replay the lifecycle re-solved the §III.D knapsacks against
    /// the capacities the trial actually measured
    /// ([`crate::sched::replan`]) and that re-plan passed both the
    /// Preserver walk and the static verifier.
    Replanned {
        /// Iteration of the worst compounded drift error that drove the
        /// re-gate.
        alarm_iter: usize,
        /// Composed gradient error of the *rejected* re-gate walk, ppm.
        error_ppm: u64,
        /// The accepting re-plan walk's final-expectation ratio.
        ratio: f64,
    },
}

impl FallbackReason {
    /// True when the accepted schedule is not the first-choice plan —
    /// the raw-registry replay, or (for
    /// [`FallbackReason::Replanned`]) the measured-capacity re-solve.
    pub fn is_fallback(&self) -> bool {
        *self != FallbackReason::None
    }
}

/// Outcome of one lifecycle run.
pub struct LifecycleReport {
    /// Bucket profile recovered by the Profiler.
    pub profile: Vec<BucketProfile>,
    /// The accepted schedule.
    pub schedule: Schedule,
    /// Preserver verdicts per Solver attempt: (capacity scale, ratio).
    pub attempts: Vec<(f64, f64)>,
    /// Trial simulation of the accepted schedule (under
    /// [`LifecycleOptions::faults`] when set; its `fault_log` then also
    /// carries the drift re-gate's [`FaultEvent::GateDecision`]).
    pub trial: SimResult,
    /// True when the accepted schedule is the raw (codec-stripped)
    /// replay — byte-identical to the no-codec plan. `fallback` says
    /// why.
    pub codec_fallback: bool,
    /// Why the lifecycle fell back to the raw plan (or
    /// [`FallbackReason::None`]).
    pub fallback: FallbackReason,
    /// Full static-verifier report of the accepted schedule against the
    /// trial environment (precision lint included). Always clean when
    /// `run_lifecycle` returns `Ok` — kept for its capacity and volume
    /// accounting.
    pub lint: LintReport,
}

/// The lifecycle's static gate: lint `schedule` against its profile and
/// environment, failing with the rendered diagnostics when any
/// error-severity finding exists. A plan that fails here never reaches
/// the Preserver walk or the simulator.
pub fn lint_gate(
    schedule: &Schedule,
    profile: &[BucketProfile],
    env: &ClusterEnv,
    opts: &LintOptions,
) -> Result<LintReport> {
    let lint = lint_plan(schedule, profile, env, opts);
    if !lint.is_clean() {
        bail!(
            "schedule '{}' rejected by the static verifier before simulation:\n{}",
            schedule.scheme,
            lint.render_text()
        );
    }
    Ok(lint)
}

/// Options for the lifecycle driver.
pub struct LifecycleOptions {
    /// Number of buckets the Profiler aggregates operators into.
    pub n_buckets: usize,
    /// Trial iterations per candidate schedule.
    pub trial_iters: usize,
    pub epsilon: f64,
    pub walk: WalkParams,
    pub base_batch: f64,
    pub deft: DeftOptions,
    /// Fault scenario injected into the trial simulation. When its
    /// drift band trips there, the Preserver re-gates the schedule with
    /// the drift error composed into the walk (see
    /// [`FallbackReason::DriftGateRejected`]). `None` = healthy trial.
    pub faults: Option<FaultSpec>,
    /// Measured-drift re-planning knobs (the `[replan]` TOML table).
    /// Disabled by default: a drift-gate rejection then degrades to the
    /// raw replay exactly as before.
    pub replan: ReplanOptions,
}

impl Default for LifecycleOptions {
    fn default() -> Self {
        let (walk, base_batch) = preserver::table5_setting();
        LifecycleOptions {
            n_buckets: 8,
            trial_iters: 24,
            epsilon: preserver::EPSILON,
            walk,
            base_batch,
            deft: DeftOptions {
                preserver: false, // the lifecycle drives the feedback itself
                ..DeftOptions::default()
            },
            faults: None,
            replan: ReplanOptions::default(),
        }
    }
}

/// Run the full Fig. 7 loop for `workload` on `env`.
///
/// The Profiler consumes a synthetic raw trace of the workload (same
/// schema as the paper's Nsight logs) and prices communication through
/// the link model; the Solver/Preserver loop then converges on a
/// schedule, which is trial-simulated and returned.
pub fn run_lifecycle(
    workload: &Workload,
    env: &ClusterEnv,
    opts: &LifecycleOptions,
) -> Result<LifecycleReport> {
    // --- 1. Profile: raw operator logs → bucket-level times. ---
    let topts = TraceOptions::uniform(workload, opts.n_buckets);
    let (events, _truth) = generate_trace(workload, &topts);
    let rec = reconstruct(&events);
    // Attach parameter counts (the trace carries layer spans; params per
    // bucket follow the same uniform layer split the trace used).
    let mut profile: Vec<BucketProfile> = Vec::with_capacity(rec.len());
    let mut layer = 0usize;
    for (b, r) in rec.iter().enumerate() {
        let count = topts.layers_per_bucket[b];
        let params: u64 = workload.layers[layer..layer + count]
            .iter()
            .map(|l| l.params)
            .sum();
        layer += count;
        profile.push(BucketProfile {
            id: r.id,
            params,
            fwd: r.fwd,
            bwd: r.bwd,
            // Price in the flat reference-ring unit for the *target*
            // environment (the trace's comm column is from the profiling
            // run); link/segment factors apply downstream.
            comm: env.reference_comm(params, workload.comm_rate_ref),
        });
    }

    // --- 2+3. Solve → trial → preserve, with capacity feedback. ---
    // Lossy codecs are tried first (their codec-effective μ enlarges
    // capacities); if the Preserver rejects a route over a lossy link,
    // the registry falls back to raw codecs and the loop continues at
    // the same capacity scale.
    let raw_env = env.clone().with_raw_codecs();
    // Segment-path errors: a lossy codec on a shared intra link must
    // gate transfers homed on other links too.
    let codec_errors = env.link_path_codec_errors();
    let mut use_codecs = env.has_lossy_codec();
    let mut codec_fallback = false;
    let mut fallback = FallbackReason::None;
    let mut scale = opts.deft.capacity_scale;
    let mut attempts = Vec::new();
    let mut accepted: Option<Schedule> = None;
    let mut retry = 0usize;
    while retry <= preserver::MAX_RETRIES {
        let solve_env = if use_codecs { env } else { &raw_env };
        let deft = Deft::new(DeftOptions {
            capacity_scale: scale,
            preserver: false,
            // The knapsack set always follows the target environment's
            // link registry (one knapsack per link, capacities from the
            // codec-effective segment-path slowdowns times the static
            // shared-NIC contention factor of the contention model).
            link_mus: solve_env.link_planning_mus(),
            ..opts.deft.clone()
        });
        let schedule = deft.schedule(&profile);
        // Static gate (before the Preserver walk): a structurally
        // unsound or §III.D-infeasible plan reports its diagnostics
        // instead of simulating. Precision is off here — the walk that
        // decides the lossy-route verdict runs right below.
        lint_gate(
            &schedule,
            &profile,
            solve_env,
            &LintOptions {
                check_precision: false,
                walk: opts.walk,
                base_batch: opts.base_batch,
                epsilon: opts.epsilon,
                fault_envelope: opts.faults.clone(),
            },
        )?;
        // Gradient error of the worst lossy link the schedule routes
        // over (zero on the raw registry).
        let err = if use_codecs {
            schedule.worst_codec_error(&codec_errors)
        } else {
            0.0
        };
        let report = preserver::quantify_with_error(
            &opts.walk,
            opts.base_batch,
            &schedule.batch_multipliers,
            err,
        );
        attempts.push((scale, report.ratio));
        if preserver::acceptable(&report, opts.epsilon) {
            accepted = Some(schedule);
            break;
        }
        accepted = Some(schedule.clone()); // keep the closest so far
        if use_codecs && err > 0.0 {
            // Codec-driven rejection (the same k-sequence passes with a
            // clean walk): fall back to the raw registry at the same
            // capacity and re-solve. A rejection the clean walk shares
            // is a capacity problem — grow capacity, keep the codecs.
            let clean =
                preserver::quantify(&opts.walk, opts.base_batch, &schedule.batch_multipliers);
            if preserver::acceptable(&clean, opts.epsilon) {
                use_codecs = false;
                codec_fallback = true;
                fallback = FallbackReason::CodecGateRejected {
                    ratio: report.ratio,
                };
                // The raw re-solve is free (same capacity, and it can
                // happen at most once): not counting it as a retry
                // guarantees the accepted schedule really is a raw-plan
                // re-solve even when the rejection lands on the last
                // retry.
                continue;
            }
        }
        scale *= 1.15;
        retry += 1;
    }
    let mut schedule = accepted.expect("at least one attempt");

    // --- 4. Trial application (simulated). ---
    // After a codec fallback the accepted schedule assumes raw links, so
    // the trial prices raw wires too. The accepted plan passes the full
    // verifier — precision lint included — against the trial
    // environment before it is allowed to simulate.
    let precision_lint = LintOptions {
        check_precision: true,
        walk: opts.walk,
        base_batch: opts.base_batch,
        epsilon: opts.epsilon,
        fault_envelope: opts.faults.clone(),
    };
    let resolve_raw = |scale: f64| -> Schedule {
        Deft::new(DeftOptions {
            capacity_scale: scale,
            preserver: false,
            link_mus: raw_env.link_planning_mus(),
            ..opts.deft.clone()
        })
        .schedule(&profile)
    };
    let mut trial_env = if codec_fallback { &raw_env } else { env };
    let mut lint = match lint_gate(&schedule, &profile, trial_env, &precision_lint) {
        Ok(lint) => lint,
        // A lossy plan the precision lint rejects degrades to the raw
        // replay (same capacity) instead of erroring out — the raw plan
        // must still pass, so a structurally broken plan keeps failing.
        Err(e) if !codec_fallback && env.has_lossy_codec() => {
            fallback = FallbackReason::LintRejected {
                diagnostics: e.to_string(),
            };
            codec_fallback = true;
            trial_env = &raw_env;
            schedule = resolve_raw(scale);
            lint_gate(&schedule, &profile, trial_env, &precision_lint)?
        }
        Err(e) => return Err(e),
    };
    let sim_opts = |schedule: &Schedule| SimOptions {
        iterations: opts.trial_iters.max(schedule.cycle.len() * 3),
        warmup: schedule.cycle.len().max(2),
        record_timeline: false,
    };
    let mut trial = simulate_faulted(
        &profile,
        &schedule,
        trial_env,
        &sim_opts(&schedule),
        opts.faults.as_ref(),
    );

    // --- 5. Drift-aware Preserver re-gate. ---
    // If the trial's drift monitor tripped (measured per-link busy left
    // the declared band), the planned schedule's staleness/convergence
    // reasoning no longer holds as priced: re-run the Preserver walk
    // with the drift excess composed into the gradient error.
    // Simultaneous drift on several links in one iteration compounds
    // through `combined_error`, like independent codec errors — taking
    // only the worst single alarm under-counts multi-link drift. On
    // rejection the lifecycle first tries to *re-plan* against the
    // measured capacities (when [`ReplanOptions::enabled`]); only when
    // that is off or fails does it degrade to the raw replay — rather
    // than silently executing a now-unsafe schedule. Exactly one
    // [`FaultEvent::GateDecision`] is recorded on the returned trial's
    // `fault_log` either way.
    if let Some((alarm_iter, drift_err)) = replan::compounded_drift_error(&trial.fault_log) {
        let codec_err = if codec_fallback {
            0.0
        } else {
            schedule.worst_codec_error(&codec_errors)
        };
        let combined = preserver::combined_error(codec_err, drift_err);
        let regate = preserver::quantify_with_error(
            &opts.walk,
            opts.base_batch,
            &schedule.batch_multipliers,
            combined,
        );
        if preserver::acceptable(&regate, opts.epsilon) {
            trial.fault_log.push(FaultEvent::GateDecision {
                iter: alarm_iter,
                error_ppm: to_ppm(combined),
                accepted: true,
            });
        } else {
            let mut replanned = false;
            if opts.replan.enabled && to_ppm(drift_err) >= opts.replan.min_excess_ppm {
                if let Some(measured) = MeasuredEnv::from_trial(&trial) {
                    let req = ReplanRequest {
                        profile: &profile,
                        env: trial_env,
                        measured: &measured,
                        scale,
                        deft: &opts.deft,
                        walk: &opts.walk,
                        base_batch: opts.base_batch,
                        epsilon: opts.epsilon,
                        lint: &precision_lint,
                        max_retries: opts.replan.max_retries,
                    };
                    if let Some(out) = replan::replan(&req) {
                        fallback = FallbackReason::Replanned {
                            alarm_iter,
                            error_ppm: to_ppm(combined),
                            ratio: out.ratio,
                        };
                        attempts.extend(out.attempts.iter().copied());
                        schedule = out.schedule;
                        lint = out.lint;
                        // Re-trial the re-plan under the same seeded
                        // scenario. Its residual alarms stay visible on
                        // the fresh log; the gate decision records the
                        // re-plan's accepting Preserver verdict.
                        trial = simulate_faulted(
                            &profile,
                            &schedule,
                            trial_env,
                            &sim_opts(&schedule),
                            opts.faults.as_ref(),
                        );
                        trial.fault_log.push(FaultEvent::GateDecision {
                            iter: alarm_iter,
                            error_ppm: to_ppm(out.error),
                            accepted: true,
                        });
                        replanned = true;
                    }
                }
            }
            if !replanned {
                fallback = FallbackReason::DriftGateRejected {
                    alarm_iter,
                    error_ppm: to_ppm(combined),
                    ratio: regate.ratio,
                };
                if !codec_fallback && env.has_lossy_codec() {
                    // Degrade to the raw replay and re-trial it under
                    // the same fault scenario (its own drift alarms, if
                    // any, land on the fresh fault log).
                    codec_fallback = true;
                    trial_env = &raw_env;
                    schedule = resolve_raw(scale);
                    lint = lint_gate(&schedule, &profile, trial_env, &precision_lint)?;
                    trial = simulate_faulted(
                        &profile,
                        &schedule,
                        trial_env,
                        &sim_opts(&schedule),
                        opts.faults.as_ref(),
                    );
                }
                // Else: already on the raw plan — nothing safer to
                // degrade to; the recorded rejection flags the breach.
                trial.fault_log.push(FaultEvent::GateDecision {
                    iter: alarm_iter,
                    error_ppm: to_ppm(combined),
                    accepted: false,
                });
            }
        }
    }

    Ok(LifecycleReport {
        profile,
        schedule,
        attempts,
        trial,
        codec_fallback,
        fallback,
        lint,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{gpt2, vgg19};

    #[test]
    fn lifecycle_converges_on_gpt2() {
        let env = ClusterEnv::paper_testbed();
        let rep = run_lifecycle(&gpt2(), &env, &LifecycleOptions::default()).expect("lifecycle");
        assert_eq!(rep.profile.len(), 8);
        rep.schedule.validate().unwrap();
        assert!(!rep.attempts.is_empty());
        // CR ≈ 1 ⇒ the first or second attempt should already pass ε.
        assert!(
            rep.attempts.len() <= 3,
            "too many retries on CR≈1: {:?}",
            rep.attempts
        );
        assert!(rep.trial.steady_iter_time.as_us() > 0);
    }

    #[test]
    fn lifecycle_feedback_fires_on_vgg19() {
        // CR ≈ 2: the raw schedule lowers update frequency enough that
        // the Preserver must enlarge capacity at least once.
        let env = ClusterEnv::paper_testbed();
        let mut opts = LifecycleOptions::default();
        opts.deft.heterogeneous = false; // harsher: single link
        let rep = run_lifecycle(&vgg19(), &env, &opts).expect("lifecycle");
        assert!(
            rep.attempts.len() >= 2,
            "expected capacity feedback on CR≈2, attempts {:?}",
            rep.attempts
        );
        // Capacity scales must be increasing.
        for w in rep.attempts.windows(2) {
            assert!(w[1].0 > w[0].0);
        }
        rep.schedule.validate().unwrap();
    }

    #[test]
    fn lossy_codec_forces_fallback_to_the_raw_plan() {
        use crate::links::{Codec, LinkId};
        // A rank-1 codec on gloo injects a gradient error far outside ε:
        // the Preserver must reject the lossy route, fall back to raw
        // links, and accept a plan byte-identical to the no-codec run.
        let raw = ClusterEnv::paper_testbed();
        let lossy = ClusterEnv::paper_testbed().with_codec(LinkId(1), Codec::RankK { k: 1 });
        let opts = LifecycleOptions::default();
        let w = vgg19();
        let r_raw = run_lifecycle(&w, &raw, &opts).expect("raw lifecycle");
        let r_lossy = run_lifecycle(&w, &lossy, &opts).expect("lossy lifecycle");
        assert!(!r_raw.codec_fallback);
        assert_eq!(r_raw.fallback, FallbackReason::None);
        assert!(r_lossy.codec_fallback, "rank-1 error must trip the gate");
        let rejected_ratio =
            matches!(r_lossy.fallback, FallbackReason::CodecGateRejected { ratio }
                if (ratio - 1.0).abs() > opts.epsilon);
        assert!(
            rejected_ratio,
            "fallback reason must carry the rejected ratio: {:?}",
            r_lossy.fallback
        );
        assert_eq!(r_lossy.schedule, r_raw.schedule, "fallback plan must be the raw plan");
        assert_eq!(r_lossy.trial.steady_iter_time, r_raw.trial.steady_iter_time);
        assert_eq!(r_lossy.trial.iter_ends, r_raw.trial.iter_ends);
        // Exactly one extra (rejected) lossy attempt precedes the raw
        // replay.
        assert_eq!(r_lossy.attempts.len(), r_raw.attempts.len() + 1);
        assert!((r_lossy.attempts[0].1 - 1.0).abs() > opts.epsilon);
        // Regression: the raw-fallback plan passes the full verifier —
        // precision lint included — against the raw trial environment.
        assert!(
            r_lossy.lint.is_clean(),
            "fallback plan must lint clean:\n{}",
            r_lossy.lint.render_text()
        );
        assert!(!r_lossy.lint.loads.is_empty(), "capacity accounting recorded");
    }

    #[test]
    fn lint_gate_rejects_a_mutated_plan_before_simulation() {
        use crate::analysis::{apply_mutation, MutationClass};
        let env = ClusterEnv::paper_testbed();
        let rep =
            run_lifecycle(&gpt2(), &env, &LifecycleOptions::default()).expect("lifecycle");
        let opts = LintOptions::default();
        // The accepted plan passes the gate…
        lint_gate(&rep.schedule, &rep.profile, &env, &opts).expect("accepted plan is clean");
        // …and any harness mutation of it is rejected with its
        // diagnostic code in the error text, before any simulation.
        for class in [MutationClass::DropOp, MutationClass::InflateBucket] {
            let case = apply_mutation(class, &rep.schedule, &rep.profile, &env, 0);
            let err = lint_gate(&case.schedule, &case.buckets, &case.env, &opts)
                .expect_err("mutated plan must be rejected");
            assert!(
                err.to_string().contains(case.expected.as_str()),
                "{}: {err}",
                class.name()
            );
        }
    }

    #[test]
    fn fp16_codec_passes_the_gate_without_fallback() {
        use crate::links::{Codec, LinkId};
        // fp16's rounding error sits far below ε: the lossy route is
        // accepted and no fallback happens.
        let env = ClusterEnv::paper_testbed().with_codec(LinkId(1), Codec::Fp16);
        let rep = run_lifecycle(&gpt2(), &env, &LifecycleOptions::default()).expect("lifecycle");
        assert!(!rep.codec_fallback);
        assert_eq!(rep.fallback, FallbackReason::None);
        rep.schedule.validate().unwrap();
        assert!(rep.trial.steady_iter_time.as_us() > 0);
        assert!(rep.lint.is_clean());
        assert!(rep.trial.fault_log.is_empty(), "healthy trial logs no faults");
    }

    #[test]
    fn lifecycle_profile_matches_workload_totals() {
        let env = ClusterEnv::paper_testbed();
        let w = gpt2();
        let rep = run_lifecycle(&w, &env, &LifecycleOptions::default()).expect("lifecycle");
        let params: u64 = rep.profile.iter().map(|b| b.params).sum();
        assert_eq!(params, w.total_params());
        let fwd: crate::util::Micros = rep.profile.iter().map(|b| b.fwd).sum();
        // Reconstruction slack ≤ 1%.
        let err = (fwd.as_us() as f64 - w.total_fwd().as_us() as f64).abs()
            / w.total_fwd().as_us() as f64;
        assert!(err < 0.02, "fwd reconstruction off by {err}");
    }
}
