//! DeFT — the paper's scheduler (§III, Algorithms 1–2, Fig. 4).
//!
//! Key mechanisms, all implemented here:
//!
//! * **Delayed updates** eliminate hard dependencies: bucket #1 (input
//!   side, id 0) is never shipped in the backward window that produced
//!   it; buckets that do not fit this iteration's overlap capacity wait
//!   in the *current/future task queues* and ship under later compute.
//! * **Adaptive update frequency**: when queues accumulate a full old
//!   iteration, its gradients are *merged* (gradient accumulation) with
//!   the new iteration's — one transfer carries several iterations'
//!   gradients, cutting communication volume (coverage-rate reduction).
//! * **Two-stage 0/1 (multi-)knapsack**: the forward stage packs old
//!   buckets into the forward-compute capacity (Case 1); the backward
//!   stage packs old buckets first (Cases 2–3) and then this iteration's
//!   buckets via Algorithm 1's recursive knapsack (Cases 3–4).
//! * **Heterogeneous links**: with `heterogeneous`, every pack is an
//!   N-knapsack problem — one knapsack per registry link, the capacity of
//!   a μ-slower link being C/μ (it holds μ× less reference-time
//!   communication). The paper's NCCL+gloo pair is the N = 2 case.
//! * **Preserver feedback**: the resulting batch-multiplier sequence is
//!   quantified with the Gaussian-walk model; if the expected-state ratio
//!   leaves `[1−ε, 1+ε]`, knapsack capacities grow 15% and the schedule
//!   is re-solved (≤ 10 retries, §IV.C.3).
//!
//! The steady-state cycle is found by running the queue state machine
//! until its state signature repeats.

use std::collections::BTreeMap;

use super::{CommOp, FwdDependency, IterPlan, Schedule, Scheduler, Stage};
use crate::links::{ClusterEnv, LinkId};
use crate::models::BucketProfile;
use crate::preserver::{self, WalkParams};
use crate::solver::{multi_knapsack_greedy, Item};
use crate::util::Micros;

/// DeFT configuration.
#[derive(Clone, Debug)]
pub struct DeftOptions {
    /// Per-link effective slowdown factors in registry order (index =
    /// `LinkId`; paper default: `[1.0, 1.65]` for NCCL + gloo). Under a
    /// hierarchical topology these are the **segment-path** factors, not
    /// the raw μs, and links sharing a NIC additionally budget the
    /// conservative static contention factor of the environment's
    /// [`crate::links::ContentionModel`] (k-way: every group-mate
    /// presumed concurrently in flight) — build from an environment via
    /// [`Deft::for_env`] / `ClusterEnv::link_planning_mus`, so every
    /// knapsack capacity is compute time divided by its link's planning
    /// slowdown. Registries without shared NICs reduce to the path μs.
    pub link_mus: Vec<f64>,
    /// Per-link codec gradient errors in registry order (index =
    /// `LinkId`; see [`crate::links::Codec::error`]). Empty — the default
    /// — means every link ships raw f32. The Preserver feedback loop
    /// injects the largest error among links the candidate schedule
    /// actually uses into its Gaussian walk, so a lossy route must clear
    /// `acceptable` like any other schedule. Build from an environment
    /// via [`Deft::for_env`] / `ClusterEnv::link_path_codec_errors`
    /// (segment-path errors, so a coded intra link gates fabric-homed
    /// transfers too).
    pub link_errors: Vec<f64>,
    /// Use every registry link (true) or only the reference link (false —
    /// the paper's §V.B.4 single-link ablation).
    pub heterogeneous: bool,
    /// Run the Preserver feedback loop (§IV.C.3).
    pub preserver: bool,
    /// Preserver acceptance band ε.
    pub epsilon: f64,
    /// Baseline batch size B for the Preserver's walk.
    pub base_batch: f64,
    /// Walk parameters at the profiling point (defaults to the Table V
    /// ResNet setting scaled to the workload).
    pub walk: WalkParams,
    /// Initial knapsack capacity multiplier (1.0 = exactly the compute
    /// time; the Preserver may raise it).
    pub capacity_scale: f64,
    /// Maximum iterations to search for a steady-state cycle.
    pub max_cycle_search: usize,
}

impl Default for DeftOptions {
    fn default() -> Self {
        let (walk, base_batch) = preserver::table5_setting();
        DeftOptions {
            link_mus: vec![1.0, crate::links::PAPER_MU],
            link_errors: Vec::new(),
            heterogeneous: true,
            preserver: true,
            epsilon: preserver::EPSILON,
            base_batch,
            walk,
            capacity_scale: 1.0,
            max_cycle_search: 512,
        }
    }
}

/// The DeFT scheduler.
#[derive(Clone, Debug, Default)]
pub struct Deft {
    pub opts: DeftOptions,
}

impl Deft {
    pub fn new(opts: DeftOptions) -> Deft {
        assert!(!opts.link_mus.is_empty(), "DeFT needs at least one link");
        assert!(
            opts.link_mus.iter().all(|&mu| mu > 0.0),
            "link μ must be positive"
        );
        Deft { opts }
    }

    /// DeFT for a concrete cluster environment: the knapsack set follows
    /// the environment's link registry (one knapsack per link), each
    /// capacity derived from the link's **planning** slowdown — the
    /// codec-effective segment-path μ times the static shared-NIC
    /// contention factor of the environment's contention model.
    pub fn for_env(env: &ClusterEnv, preserver: bool) -> Deft {
        Deft::new(DeftOptions {
            link_mus: env.link_planning_mus(),
            link_errors: env.link_path_codec_errors(),
            preserver,
            ..DeftOptions::default()
        })
    }

    /// DeFT without the heterogeneous links (the paper's §V.B.4 ablation,
    /// which also disables the Preserver guard).
    pub fn without_multilink() -> Deft {
        Deft {
            opts: DeftOptions {
                heterogeneous: false,
                preserver: false,
                ..DeftOptions::default()
            },
        }
    }

    /// The μ factors of the links the scheduler may use: every registry
    /// link, or just the reference link under the single-link ablation.
    fn mus(&self) -> &[f64] {
        if self.opts.heterogeneous {
            &self.opts.link_mus
        } else {
            &self.opts.link_mus[..1]
        }
    }

    /// Largest codec gradient error among the links `schedule` routes
    /// over (0 when no errors were configured or only raw links are hit).
    fn codec_error_of(&self, schedule: &Schedule) -> f64 {
        schedule.worst_codec_error(&self.opts.link_errors)
    }
}

/// Reference-time capacity lost on a μ-slower link when `release` of
/// overlap compute disappears (the μ-slower knapsack holds μ× less).
/// Shared with `crate::analysis`, whose capacity lint must reproduce
/// the solver's rounding bit-for-bit.
pub(crate) fn cap_loss(release: Micros, mu: f64) -> Micros {
    if mu == 1.0 {
        release
    } else {
        release.scale(1.0 / mu)
    }
}

/// A queued (delayed) gradient bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct QItem {
    bucket: usize,
    /// Iterations' gradients merged into this pending transfer.
    merged: usize,
}

/// One stage's pack result: per-link chosen items.
struct PackOut {
    per_link: Vec<(LinkId, Vec<QItem>)>,
}

impl PackOut {
    fn shipped(&self) -> impl Iterator<Item = (LinkId, QItem)> + '_ {
        self.per_link
            .iter()
            .flat_map(|(l, v)| v.iter().map(move |q| (*l, *q)))
    }
}

/// Queue state machine state (the cycle-detection signature).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
struct QueueState {
    current: Vec<QItem>,
    future: Vec<QItem>,
    active_iters: usize,
    forming_iters: usize,
    /// NCCL wire time owed from force-shipped oversized items whose
    /// communication exceeded the window that launched them; it is paid
    /// off from subsequent iterations' overlap capacity so the planner
    /// never claims more overlap than exists.
    debt: Micros,
}

impl Deft {
    /// Capacities (reference-link time units) for one stage with compute
    /// window `compute` — one knapsack per usable link, a μ-slower link
    /// holding μ× less reference-time communication.
    fn capacities(&self, compute: Micros, scale: f64) -> Vec<Micros> {
        let c = compute.scale(scale);
        self.mus().iter().map(|&mu| cap_loss(c, mu)).collect()
    }

    fn link_of(&self, sack: usize) -> LinkId {
        LinkId(sack)
    }

    /// Greedy multi-knapsack pack of queue items (Cases 1–2, order1).
    fn pack(&self, items: &[QItem], buckets: &[BucketProfile], caps: &[Micros]) -> PackOut {
        let solver_items: Vec<Item> = items
            .iter()
            .enumerate()
            .map(|(i, q)| Item::new(i, buckets[q.bucket].comm))
            .collect();
        let r = multi_knapsack_greedy(&solver_items, caps);
        let per_link = r
            .assignments
            .iter()
            .enumerate()
            .map(|(k, ids)| {
                (
                    self.link_of(k),
                    ids.iter().map(|&i| items[i]).collect::<Vec<_>>(),
                )
            })
            .collect();
        PackOut { per_link }
    }

    /// Algorithm 1 generalised to multiple knapsacks: compare packing the
    /// whole readiness-ordered suffix now against deferring the head item
    /// (losing the next bucket's backward time from every capacity).
    fn recursive_pack(
        &self,
        items: &[QItem],
        release: &[Micros],
        buckets: &[BucketProfile],
        caps: &[Micros],
    ) -> PackOut {
        assert_eq!(items.len(), release.len());
        if items.is_empty() {
            return PackOut {
                per_link: Vec::new(),
            };
        }
        let now = self.pack(items, buckets, caps);
        let now_total: Micros = now
            .shipped()
            .map(|(_, q)| buckets[q.bucket].comm)
            .sum();
        let deferred = if items.len() > 1 {
            let mus = self.mus();
            let reduced: Vec<Micros> = caps
                .iter()
                .enumerate()
                .map(|(k, &c)| {
                    // The reference link loses `release` of overlap; a
                    // μ-slower sack loses release/μ in reference units.
                    c.saturating_sub(cap_loss(release[1], mus[k]))
                })
                .collect();
            Some(self.recursive_pack(&items[1..], &release[1..], buckets, &reduced))
        } else {
            None
        };
        match deferred {
            Some(d) => {
                let d_total: Micros = d.shipped().map(|(_, q)| buckets[q.bucket].comm).sum();
                if now_total >= d_total {
                    now
                } else {
                    d
                }
            }
            None => now,
        }
    }

    /// Run the queue state machine once with fixed capacity scale and
    /// return the steady-state schedule.
    fn solve_with_scale(&self, buckets: &[BucketProfile], scale: f64) -> Schedule {
        let n = buckets.len();
        let fwd_compute: Micros = buckets.iter().map(|b| b.fwd).sum();
        let bwd_compute: Micros = buckets.iter().map(|b| b.bwd).sum();

        let mut st = QueueState::default();
        let mut plans: Vec<IterPlan> = Vec::new();
        let mut multipliers_log: Vec<u64> = Vec::new(); // k at each update
        let mut seen: BTreeMap<String, usize> = BTreeMap::new();

        // Steady state can take ~CR iterations to reach (merge counts grow
        // until volume fits capacity), and an oversized bucket needs
        // ~comm/max_cap iterations before its first force-ship — scale the
        // search horizon accordingly.
        let cap_per_iter = (fwd_compute + bwd_compute).scale(scale).as_us().max(1);
        let total_comm: u64 = buckets.iter().map(|b| b.comm.as_us()).sum();
        let max_bucket_comm = buckets.iter().map(|b| b.comm.as_us()).max().unwrap_or(0);
        let cr_bound = total_comm / cap_per_iter + max_bucket_comm / cap_per_iter;
        let search_limit = self
            .opts
            .max_cycle_search
            .max(64 + 6 * cr_bound as usize);

        let debug = std::env::var_os("DEFT_DEBUG").is_some();
        let mut cycle: Option<(usize, usize)> = None; // [start, end)
        for t in 0..search_limit {
            // Cycle signature: queue contents + group counters. The debt
            // is deliberately excluded — it is a planning heuristic whose
            // exact µs value decays aperiodically; two iterations with
            // equal queue states bracket a window in which every produced
            // gradient was shipped exactly once (inflow = outflow), which
            // is what the steady-state cycle must guarantee. Debt is
            // quantised into the signature coarsely so grossly different
            // regimes are still distinguished.
            let sig = format!(
                "{:?}|{:?}|{}|{}|{}",
                st.current,
                st.future,
                st.active_iters,
                st.forming_iters,
                st.debt.as_us() / (fwd_compute + bwd_compute).as_us().max(1) / 4
            );
            if debug && t < 80 {
                eprintln!("[deft] t={t} {st:?}");
            }
            if let Some(&prev) = seen.get(&sig) {
                cycle = Some((prev, t));
                break;
            }
            seen.insert(sig, t);

            let mut plan = IterPlan::default();

            // ---- Forward stage (Case 1): ship old buckets. ----
            if !st.current.is_empty() {
                let mut caps = self.capacities(fwd_compute, scale);
                let pay = caps[0].min(st.debt);
                caps[0] = caps[0] - pay;
                st.debt = st.debt - pay;
                let out = self.pack(&st.current, buckets, &caps);
                let mut prio = 0i64;
                for (link, q) in out.shipped() {
                    plan.fwd_ops.push(CommOp {
                        bucket: q.bucket,
                        link,
                        stage: Stage::Forward,
                        priority: prio,
                        grad_age: 1,
                        merged: q.merged,
                        update_offset: 0,
                    });
                    prio += 1;
                    st.current.retain(|c| c != &q);
                }
            }

            // ---- Backward stage. ----
            // This iteration's gradients join the forming group.
            st.forming_iters += 1;
            merge_iteration(&mut st.future, n);

            let mut caps = self.capacities(bwd_compute, scale);
            {
                let pay = caps[0].min(st.debt);
                caps[0] = caps[0] - pay;
                st.debt = st.debt - pay;
            }
            // Robustness fallback: an item whose communication exceeds
            // every knapsack (forward and backward) can never be packed.
            // §III.D's constrained re-partition prevents this, but raw
            // DDP-style profiles (e.g. Table II's 178 ms fc6 bucket) can
            // contain such giants. DeFT's recourse is pure merging: the
            // stuck item absorbs each new iteration's gradient of the
            // same bucket (volume amortisation) and is force-shipped once
            // enough compute has accumulated to pay for its wire time
            // (merged · max_cap ≥ comm); the shipment consumes backward
            // capacity, so everything else keeps queueing honestly.
            let max_cap = self
                .capacities(bwd_compute.max(fwd_compute), scale)
                .into_iter()
                .max()
                .unwrap_or(Micros::ZERO);
            if !max_cap.is_zero() {
                let stuck: Vec<usize> = st
                    .current
                    .iter()
                    .enumerate()
                    .filter(|(_, q)| buckets[q.bucket].comm > max_cap)
                    .map(|(i, _)| i)
                    .collect();
                for i in stuck {
                    // Absorb the forming group's gradient of this bucket.
                    let bucket = st.current[i].bucket;
                    if let Some(pos) = st.future.iter().position(|f| f.bucket == bucket) {
                        st.current[i].merged += st.future[pos].merged;
                        st.future.remove(pos);
                    }
                }
                // Self-regulating threshold: an oversized item ships only
                // once it has merged enough iterations that the NCCL
                // capacity accumulated over them covers both its own wire
                // time and the outstanding debt — otherwise debt would
                // grow without bound and no steady state would exist.
                let cap_iter = (fwd_compute + bwd_compute).scale(scale);
                let ready: Vec<QItem> = st
                    .current
                    .iter()
                    .copied()
                    .filter(|q| {
                        buckets[q.bucket].comm > max_cap
                            && Micros(cap_iter.as_us().saturating_mul(q.merged as u64))
                                >= buckets[q.bucket].comm + st.debt
                    })
                    .collect();
                for q in ready {
                    plan.bwd_ops.push(CommOp {
                        bucket: q.bucket,
                        link: LinkId::REFERENCE,
                        stage: Stage::Backward,
                        priority: -1, // it blocks the whole queue: go first
                        grad_age: 1,
                        merged: q.merged,
                        update_offset: 0,
                    });
                    st.current.retain(|c| c != &q);
                    // Its wire time eats the backward overlap window; any
                    // overflow is owed by future iterations.
                    let comm = buckets[q.bucket].comm;
                    let covered = caps[0].min(comm);
                    caps[0] = caps[0] - covered;
                    st.debt += comm - covered;
                }
            }
            // Old buckets first (Cases 2–3, order1).
            if !st.current.is_empty() {
                let out = self.pack(&st.current, buckets, &caps);
                let mut prio = 0i64;
                for (link, q) in out.shipped() {
                    plan.bwd_ops.push(CommOp {
                        bucket: q.bucket,
                        link,
                        stage: Stage::Backward,
                        priority: prio,
                        grad_age: 1,
                        merged: q.merged,
                        update_offset: 0,
                    });
                    prio += 1;
                    st.current.retain(|c| c != &q);
                    // Consume capacity.
                    caps[link.index()] =
                        caps[link.index()].saturating_sub(buckets[q.bucket].comm);
                }
            }

            // New buckets via Algorithm 1 (Cases 3–4, order2) — only when
            // the old queue fully drained, and never bucket 0 (hard dep).
            if st.current.is_empty() {
                // Readiness order n-1 .. 1; release = own backward time.
                let mut items: Vec<QItem> = Vec::new();
                let mut release: Vec<Micros> = Vec::new();
                for b in (1..n).rev() {
                    if let Some(q) = st.future.iter().find(|q| q.bucket == b) {
                        items.push(*q);
                        release.push(buckets[b].bwd);
                    }
                }
                // Capacity excludes bucket n-1's backward (nothing is
                // ready while it runs) — paper Alg. 2 line 15.
                let mus = self.mus();
                let caps2: Vec<Micros> = caps
                    .iter()
                    .enumerate()
                    .map(|(k, &c)| c.saturating_sub(cap_loss(buckets[n - 1].bwd, mus[k])))
                    .collect();
                let out = self.recursive_pack(&items, &release, buckets, &caps2);
                let offset = usize::from(st.active_iters > 0);
                let mut prio = 1000; // after order1 ops
                for (link, q) in out.shipped() {
                    plan.bwd_ops.push(CommOp {
                        bucket: q.bucket,
                        link,
                        stage: Stage::Backward,
                        priority: prio,
                        grad_age: 0,
                        merged: q.merged,
                        update_offset: offset,
                    });
                    prio += 1;
                    st.future.retain(|c| c != &q);
                }
            }

            // ---- Iteration end: update & queue promotion. ----
            let mut update = false;
            if st.current.is_empty() {
                if st.active_iters > 0 {
                    update = true;
                    multipliers_log.push(st.active_iters as u64);
                }
                st.current = std::mem::take(&mut st.future);
                st.current.sort();
                st.active_iters = st.forming_iters;
                st.forming_iters = 0;
            }
            plan.update_at_end = update;
            plans.push(plan);
        }

        let (start, end) = cycle.unwrap_or_else(|| {
            panic!("no steady-state cycle within {search_limit} iterations")
        });
        let cycle_plans: Vec<IterPlan> = plans[start..end].to_vec();
        // Multipliers of updates inside the cycle window.
        let updates_before: usize = plans[..start].iter().filter(|p| p.update_at_end).count();
        let updates_in: usize = cycle_plans.iter().filter(|p| p.update_at_end).count();
        let ks: Vec<u64> =
            multipliers_log[updates_before..updates_before + updates_in].to_vec();

        let schedule = Schedule {
            scheme: if self.opts.heterogeneous {
                "deft".into()
            } else {
                "deft-nolink".into()
            },
            cycle: cycle_plans,
            fwd_dependency: FwdDependency::None,
            updates_per_cycle: updates_in,
            batch_multipliers: ks,
            warmup_iters: start,
            // Two-queue staleness bound: at most the active + forming
            // groups' communications may be in flight.
            max_outstanding_iters: (2 * (end - start)).max(2),
            capacity_scale_bits: scale.to_bits(),
        };
        debug_assert!(schedule.validate().is_ok(), "{:?}", schedule.validate());
        schedule
    }
}

/// Merge one fresh iteration's gradients (all buckets) into the forming
/// queue: existing entries accumulate, absent buckets appear with count 1.
fn merge_iteration(future: &mut Vec<QItem>, n: usize) {
    for b in 0..n {
        if let Some(q) = future.iter_mut().find(|q| q.bucket == b) {
            q.merged += 1;
        } else {
            future.push(QItem { bucket: b, merged: 1 });
        }
    }
    future.sort();
}

impl Scheduler for Deft {
    fn name(&self) -> &'static str {
        if self.opts.heterogeneous {
            "deft"
        } else {
            "deft-nolink"
        }
    }

    fn schedule(&self, buckets: &[BucketProfile]) -> Schedule {
        let mut scale = self.opts.capacity_scale;
        let mut best = self.solve_with_scale(buckets, scale);
        if !self.opts.preserver {
            return best;
        }
        // Preserver feedback loop (§IV.C.3): enlarge capacities until the
        // expected-state ratio is inside [1−ε, 1+ε] or retries exhaust.
        // Lossy-codec schedules additionally inject the largest gradient
        // error among the links they use into DeFT's walk.
        for _ in 0..preserver::MAX_RETRIES {
            let err = self.codec_error_of(&best);
            let report = preserver::quantify_with_error(
                &self.opts.walk,
                self.opts.base_batch,
                &best.batch_multipliers,
                err,
            );
            if preserver::acceptable(&report, self.opts.epsilon) {
                break;
            }
            // A codec error that fails even the all-ones sequence is
            // irreducible: no knapsack capacity can fix it. Stop here —
            // routing off the lossy link entirely is the lifecycle
            // driver's fallback, not a capacity decision.
            if err > 0.0 {
                let floor = preserver::quantify_with_error(
                    &self.opts.walk,
                    self.opts.base_batch,
                    &[1],
                    err,
                );
                if !preserver::acceptable(&floor, self.opts.epsilon) {
                    break;
                }
            }
            scale *= 1.15;
            best = self.solve_with_scale(buckets, scale);
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{gpt2_buckets_calibrated, vgg19_table2_buckets};

    fn vgg() -> Vec<BucketProfile> {
        vgg19_table2_buckets()
    }

    #[test]
    fn schedule_validates_and_has_delayed_updates_on_vgg() {
        // VGG CR ≈ 1.9: with heterogeneous links + merging of the fc6
        // giant, DeFT amortises volume via merged transfers; without the
        // second link the capacity deficit must lower update frequency.
        let d = Deft::new(DeftOptions {
            preserver: false,
            ..DeftOptions::default()
        });
        let s = d.schedule(&vgg());
        s.validate().unwrap();
        assert_eq!(s.fwd_dependency, FwdDependency::None);
        assert!(s.update_frequency() <= 1.0);
        // Volume reduction: some transfer carries ≥ 2 iterations' grads.
        assert!(
            s.cycle
                .iter()
                .flat_map(|p| p.all_ops())
                .any(|op| op.merged >= 2),
            "no merged transfers on a CR≈1.9 workload"
        );
        let solo = Deft::without_multilink().schedule(&vgg());
        solo.validate().unwrap();
        assert!(
            solo.update_frequency() < 1.0,
            "single-link freq = {} (cycle {} updates {})",
            solo.update_frequency(),
            solo.cycle.len(),
            solo.updates_per_cycle
        );
    }

    #[test]
    fn bucket0_never_ships_with_age_zero() {
        // The paper's hard dependency: bucket #1's gradient (ready at the
        // very end of backward) is always delayed.
        let d = Deft::new(DeftOptions {
            preserver: false,
            ..DeftOptions::default()
        });
        for bs in [vgg(), gpt2_buckets_calibrated()] {
            let s = d.schedule(&bs);
            for plan in &s.cycle {
                for op in plan.all_ops() {
                    if op.bucket == 0 {
                        assert!(
                            op.grad_age >= 1,
                            "bucket 0 shipped in its own backward window"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn every_gradient_is_shipped_exactly_once_per_cycle() {
        // Volume conservation: over one cycle, the merged iteration count
        // shipped per bucket equals the cycle length (each iteration's
        // gradient leaves exactly once, possibly merged).
        let d = Deft::new(DeftOptions {
            preserver: false,
            ..DeftOptions::default()
        });
        for bs in [vgg(), gpt2_buckets_calibrated()] {
            let s = d.schedule(&bs);
            let n = bs.len();
            for b in 0..n {
                let shipped: usize = s
                    .cycle
                    .iter()
                    .flat_map(|p| p.all_ops())
                    .filter(|op| op.bucket == b)
                    .map(|op| op.merged)
                    .sum();
                assert_eq!(
                    shipped,
                    s.cycle.len(),
                    "bucket {b}: shipped {shipped} iterations' grads over a {}-iter cycle",
                    s.cycle.len()
                );
            }
        }
    }

    #[test]
    fn heterogeneous_uses_both_links() {
        let d = Deft::new(DeftOptions {
            preserver: false,
            ..DeftOptions::default()
        });
        let s = d.schedule(&vgg());
        let slow_ops = s
            .cycle
            .iter()
            .flat_map(|p| p.all_ops())
            .filter(|op| op.link != LinkId::REFERENCE)
            .count();
        assert!(slow_ops > 0, "heterogeneous schedule never used the slow link");
    }

    #[test]
    fn three_link_registry_spreads_load() {
        // An N = 3 topology (nvlink + ib + tcp μs): DeFT must produce a
        // valid, volume-conserving schedule whose ops only reference
        // registered links.
        let three = Deft::new(DeftOptions {
            link_mus: vec![1.0, 2.5, 6.0],
            preserver: false,
            ..DeftOptions::default()
        });
        let s3 = three.schedule(&vgg());
        s3.validate().unwrap();
        for plan in &s3.cycle {
            for op in plan.all_ops() {
                assert!(op.link.index() < 3, "unregistered link {:?}", op.link);
            }
        }
        // Volume conservation still holds with three knapsacks.
        for b in 0..vgg().len() {
            let shipped: usize = s3
                .cycle
                .iter()
                .flat_map(|p| p.all_ops())
                .filter(|op| op.bucket == b)
                .map(|op| op.merged)
                .sum();
            assert_eq!(shipped, s3.cycle.len(), "bucket {b}");
        }
        assert!(s3.update_frequency() > 0.0);
    }

    #[test]
    fn nolink_reduces_update_frequency_further() {
        let het = Deft::new(DeftOptions {
            preserver: false,
            ..DeftOptions::default()
        });
        let solo = Deft::without_multilink();
        let f_het = het.schedule(&vgg()).update_frequency();
        let f_solo = solo.schedule(&vgg()).update_frequency();
        assert!(
            f_solo <= f_het + 1e-9,
            "single-link should update no more often: {f_solo} vs {f_het}"
        );
    }

    #[test]
    fn gpt2_near_full_frequency() {
        // CR ≈ 0.99: with heterogeneous links DeFT should keep the update
        // frequency high (≥ 0.5).
        let d = Deft::new(DeftOptions {
            preserver: false,
            ..DeftOptions::default()
        });
        let s = d.schedule(&gpt2_buckets_calibrated());
        assert!(
            s.update_frequency() >= 0.5,
            "freq = {}",
            s.update_frequency()
        );
    }

    #[test]
    fn irreducible_codec_error_breaks_preserver_loop_immediately() {
        // A rank-1-scale error on the slow link fails ε even for the
        // all-ones sequence, so no capacity enlargement can help: the
        // loop must return the first solve — byte-identical to the
        // preserver-off schedule — instead of burning all ten retries.
        let lossy = Deft::new(DeftOptions {
            link_errors: vec![0.0, crate::links::Codec::RankK { k: 1 }.error()],
            ..DeftOptions::default()
        });
        let off = Deft::new(DeftOptions {
            preserver: false,
            ..DeftOptions::default()
        });
        let s = lossy.schedule(&vgg());
        assert!(
            s.links_used().iter().any(|l| l.index() == 1),
            "premise: the schedule must route over the lossy link"
        );
        assert_eq!(s, off.schedule(&vgg()));
    }

    #[test]
    fn preserver_feedback_raises_frequency_or_accepts() {
        let with = Deft::new(DeftOptions::default());
        let without = Deft::new(DeftOptions {
            preserver: false,
            ..DeftOptions::default()
        });
        let f_with = with.schedule(&vgg()).update_frequency();
        let f_without = without.schedule(&vgg()).update_frequency();
        assert!(
            f_with + 1e-9 >= f_without,
            "preserver should never lower frequency: {f_with} vs {f_without}"
        );
    }
}
