//! PyTorch DDP baseline: WFBP + tensor fusion (paper §II.A, baseline 1).
//!
//! Every bucket's allreduce launches as soon as its backward finishes
//! (FIFO readiness order, all on NCCL); the optimizer steps after all
//! allreduces of the iteration complete, and the next iteration's forward
//! waits for the step — the full barrier that creates Fig. 1(a)'s hard
//! dependencies.

use super::{CommOp, FwdDependency, IterPlan, Schedule, Scheduler, Stage};
use crate::links::LinkId;
use crate::models::BucketProfile;

/// PyTorch DistributedDataParallel-style scheduler.
#[derive(Clone, Copy, Debug, Default)]
pub struct Wfbp;

impl Scheduler for Wfbp {
    fn name(&self) -> &'static str {
        "pytorch-ddp"
    }

    fn schedule(&self, buckets: &[BucketProfile]) -> Schedule {
        let n = buckets.len();
        assert!(n > 0);
        // Backward produces gradients for bucket n-1 first; FIFO service.
        let bwd_ops = (0..n)
            .rev()
            .enumerate()
            .map(|(rank, bucket)| CommOp {
                bucket,
                link: LinkId::REFERENCE,
                stage: Stage::Backward,
                priority: rank as i64, // readiness order
                grad_age: 0,
                merged: 1,
                update_offset: 0,
            })
            .collect();
        Schedule {
            scheme: self.name().into(),
            cycle: vec![IterPlan {
                fwd_ops: Vec::new(),
                bwd_ops,
                update_at_end: true,
            }],
            fwd_dependency: FwdDependency::Barrier,
            updates_per_cycle: 1,
            batch_multipliers: vec![1],
            warmup_iters: 0,
            max_outstanding_iters: usize::MAX,
            capacity_scale_bits: (1.0f64).to_bits(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::vgg19_table2_buckets;

    #[test]
    fn one_op_per_bucket_every_iteration() {
        let buckets = vgg19_table2_buckets();
        let s = Wfbp.schedule(&buckets);
        s.validate().unwrap();
        assert_eq!(s.cycle.len(), 1);
        assert_eq!(s.ops_per_cycle(), buckets.len());
        assert_eq!(s.fwd_dependency, FwdDependency::Barrier);
        // Readiness order: bucket 5 first.
        assert_eq!(s.cycle[0].bwd_ops[0].bucket, 5);
        assert_eq!(s.cycle[0].bwd_ops.last().unwrap().bucket, 0);
    }
}
