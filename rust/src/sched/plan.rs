//! The schedule plan language shared by all four schemes.

use crate::links::LinkId;
use crate::util::Micros;

/// Launch window of a communication op within an iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Launched once the iteration's forward stage begins (ops carrying
    /// *old* gradients — priority scheduling / DeFT Case 1).
    Forward,
    /// Launched during the backward stage (classic WFBP window).
    Backward,
}

/// One scheduled bucket communication.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CommOp {
    /// Bucket id (forward order, 0 = input side — paper bucket #1).
    pub bucket: usize,
    /// Transport link (index into the environment's link registry).
    pub link: LinkId,
    /// Launch window.
    pub stage: Stage,
    /// Link-queue priority: when several ops are ready, the link serves
    /// the smallest priority value first.
    pub priority: i64,
    /// 0 ⇒ the transfer includes the **current** iteration's gradient
    /// (data ready only when this iteration's backward for the bucket
    /// finishes); k ≥ 1 ⇒ it carries only gradients from ≥ k iterations
    /// ago (ready immediately — DeFT's delayed communication).
    pub grad_age: usize,
    /// How many iterations' gradients are merged into this transfer
    /// (gradient accumulation; 1 for baselines). Merged transfers are the
    /// same byte size — that is DeFT's communication-volume saving.
    pub merged: usize,
    /// Which future parameter update consumes this transfer: 0 = the next
    /// update to fire, 1 = the one after, … The simulator blocks update
    /// `u` until every op with `update_offset` resolving to `u` is done.
    pub update_offset: usize,
}

/// How the next iteration's forward depends on gradient communication.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FwdDependency {
    /// DDP: a global barrier — forward of iteration t+1 starts only after
    /// every communication of iteration t completed (allreduce + step).
    Barrier,
    /// Priority schemes: forward of bucket b in iteration t+1 waits only
    /// for bucket b's own gradient communication of iteration t.
    PerBucket,
    /// DeFT delayed updates: forward never waits on communication (it
    /// runs with the previous parameter version when needed).
    None,
}

/// Plan for one iteration of the steady-state cycle.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct IterPlan {
    /// Ops launched in the forward window, served by priority.
    pub fwd_ops: Vec<CommOp>,
    /// Ops launched in the backward window, served by priority.
    pub bwd_ops: Vec<CommOp>,
    /// Does a parameter update fire at the end of this iteration?
    pub update_at_end: bool,
}

impl IterPlan {
    pub fn all_ops(&self) -> impl Iterator<Item = &CommOp> {
        self.fwd_ops.iter().chain(self.bwd_ops.iter())
    }

    pub fn num_ops(&self) -> usize {
        self.fwd_ops.len() + self.bwd_ops.len()
    }
}

/// A steady-state schedule: `cycle` repeats forever.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schedule {
    pub scheme: String,
    pub cycle: Vec<IterPlan>,
    /// Forward-dependency regime of the scheme.
    pub fwd_dependency: FwdDependency,
    /// Number of parameter updates per cycle (= `cycle` entries with
    /// `update_at_end`).
    pub updates_per_cycle: usize,
    /// Batch-size multipliers `k_1..k_m` of the updates in one cycle
    /// (paper §IV.C.1): update i applies gradients of `k_i` iterations.
    /// Baselines: all 1. Σk_i = cycle length.
    pub batch_multipliers: Vec<u64>,
    /// Warm-up iterations before the steady-state cycle applies (DeFT's
    /// queue fill); informational.
    pub warmup_iters: usize,
    /// Staleness bound: iteration `t` may not begin until every comm op
    /// launched in iterations `≤ t − max_outstanding_iters` has completed.
    /// DeFT's two-queue structure holds at most the active + forming
    /// groups in flight, so its bound is ~2 cycles; schemes whose forward
    /// dependencies are already stricter use `usize::MAX`.
    pub max_outstanding_iters: usize,
    /// The Solver's knapsack capacity scale when this schedule was
    /// produced, stored as `f64::to_bits` so `Schedule` stays `Eq` and
    /// byte-identical plans compare equal. Baselines (which never scale
    /// capacities) record 1.0; `crate::analysis`'s capacity lint replays
    /// the §III.D packing arithmetic at exactly this scale.
    pub capacity_scale_bits: u64,
}

impl Schedule {
    /// Effective update frequency = updates per iteration.
    pub fn update_frequency(&self) -> f64 {
        self.updates_per_cycle as f64 / self.cycle.len() as f64
    }

    /// Total communications launched per cycle.
    pub fn ops_per_cycle(&self) -> usize {
        self.cycle.iter().map(|p| p.num_ops()).sum()
    }

    /// The Solver capacity scale recorded at plan time (see
    /// [`Schedule::capacity_scale_bits`]).
    pub fn capacity_scale(&self) -> f64 {
        f64::from_bits(self.capacity_scale_bits)
    }

    /// Validate internal consistency (used by tests and debug asserts).
    ///
    /// Back-compat wrapper over [`crate::analysis::lint_schedule`]: runs
    /// the full structural lint (update bookkeeping, multipliers,
    /// duplicate ops, staleness bound, forward-window data readiness)
    /// and returns the first **error**-severity diagnostic as a string.
    /// Callers wanting the complete typed report — warnings, capacity
    /// accounting, profile/environment-aware checks — use
    /// `analysis::lint_schedule` / `analysis::lint_plan` directly.
    pub fn validate(&self) -> Result<(), String> {
        match crate::analysis::lint_schedule(self).first_error() {
            None => Ok(()),
            Some(d) => Err(d.to_string()),
        }
    }

    /// The set of registry links this schedule actually routes over, in
    /// registry order — what the Preserver's codec gate inspects (only
    /// the codecs of *used* links can hurt convergence).
    pub fn links_used(&self) -> Vec<LinkId> {
        let mut links: Vec<LinkId> = self
            .cycle
            .iter()
            .flat_map(|p| p.all_ops())
            .map(|op| op.link)
            .collect();
        links.sort();
        links.dedup();
        links
    }

    /// Largest codec gradient error among the links this schedule routes
    /// over, given per-link errors in registry order (see
    /// `ClusterEnv::link_codec_errors`; links beyond the slice — or an
    /// empty slice — count as raw). This is the single error the
    /// Preserver gate injects into DeFT's walk.
    pub fn worst_codec_error(&self, link_errors: &[f64]) -> f64 {
        self.links_used()
            .iter()
            .map(|l| link_errors.get(l.index()).copied().unwrap_or(0.0))
            .fold(0.0, f64::max)
    }

    /// Total reference-link communication time launched per cycle, given
    /// per-bucket comm times (diagnostics; gloo ops are still counted in
    /// reference units).
    pub fn comm_per_cycle(&self, comm: &[Micros]) -> Micros {
        self.cycle
            .iter()
            .flat_map(|p| p.all_ops())
            .map(|op| comm[op.bucket])
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(bucket: usize) -> CommOp {
        CommOp {
            bucket,
            link: LinkId::REFERENCE,
            stage: Stage::Backward,
            priority: 0,
            grad_age: 0,
            merged: 1,
            update_offset: 0,
        }
    }

    #[test]
    fn validate_catches_mismatches() {
        let mut s = Schedule {
            scheme: "test".into(),
            cycle: vec![IterPlan {
                fwd_ops: vec![],
                bwd_ops: vec![op(0)],
                update_at_end: true,
            }],
            fwd_dependency: FwdDependency::Barrier,
            updates_per_cycle: 1,
            batch_multipliers: vec![1],
            warmup_iters: 0,
            max_outstanding_iters: usize::MAX,
            capacity_scale_bits: (1.0f64).to_bits(),
        };
        assert!(s.validate().is_ok());
        s.updates_per_cycle = 2;
        assert!(s.validate().is_err());
        s.updates_per_cycle = 1;
        s.batch_multipliers = vec![2];
        assert!(s.validate().is_err());
        // Gaps the old string check missed, now caught by the typed
        // lint behind the wrapper: duplicate ops and a fresh gradient
        // in the forward window (error strings carry stable codes).
        s.batch_multipliers = vec![1];
        s.cycle[0].bwd_ops.push(op(0));
        let err = s.validate().expect_err("duplicate op must fail");
        assert!(err.contains("DEFT-E009"), "{err}");
        s.cycle[0].bwd_ops.pop();
        let mut fresh = op(1);
        fresh.stage = Stage::Forward;
        s.cycle[0].fwd_ops.push(fresh);
        let err = s.validate().expect_err("fresh grad in fwd must fail");
        assert!(err.contains("DEFT-E003"), "{err}");
    }

    #[test]
    fn frequency_and_ops() {
        let plan = IterPlan {
            fwd_ops: vec![op(1)],
            bwd_ops: vec![op(0), op(2)],
            update_at_end: false,
        };
        let s = Schedule {
            scheme: "t".into(),
            cycle: vec![
                plan,
                IterPlan {
                    fwd_ops: vec![],
                    bwd_ops: vec![op(0)],
                    update_at_end: true,
                },
            ],
            fwd_dependency: FwdDependency::None,
            updates_per_cycle: 1,
            batch_multipliers: vec![2],
            warmup_iters: 0,
            max_outstanding_iters: usize::MAX,
            capacity_scale_bits: (1.0f64).to_bits(),
        };
        assert!((s.update_frequency() - 0.5).abs() < 1e-12);
        assert_eq!(s.ops_per_cycle(), 4);
        assert_eq!(s.links_used(), vec![LinkId::REFERENCE]);
        // Only the codecs of *used* links matter; missing entries and
        // empty slices read as raw.
        assert_eq!(s.worst_codec_error(&[0.0, 0.5]), 0.0);
        assert_eq!(s.worst_codec_error(&[0.25, 0.5]), 0.25);
        assert_eq!(s.worst_codec_error(&[]), 0.0);
        assert!(s.validate().is_ok());
        let comm = vec![Micros(10), Micros(20), Micros(30)];
        assert_eq!(s.comm_per_cycle(&comm), Micros(10 + 20 + 30 + 10));
    }
}
