//! Communication schedulers — PyTorch-DDP WFBP, Bytescheduler, US-Byte,
//! and DeFT itself (paper §II.B, §III).
//!
//! Every scheme consumes a bucket profile set (`Vec<BucketProfile>`) and
//! produces a [`Schedule`]: a steady-state **cycle** of per-iteration
//! plans. Baselines have a cycle of length 1 (every iteration identical,
//! one update per iteration); DeFT's delayed updates make its steady
//! state span several iterations with fewer updates (the paper's N:M
//! coverage-ratio reduction).
//!
//! The plan language is deliberately small — buckets, links, launch
//! stages, gradient ages, merge counts, update markers — and the
//! discrete-event simulator ([`crate::sim`]) is the single executor of
//! plans, so all four schemes are compared under identical dependency
//! rules (WFBP's DAG, §II.A).

mod bytescheduler;
mod deft;
pub mod lifecycle;
mod plan;
pub mod replan;
mod usbyte;
mod wfbp;

pub use bytescheduler::Bytescheduler;
pub(crate) use deft::cap_loss;
pub use deft::{Deft, DeftOptions};
pub use lifecycle::{lint_gate, run_lifecycle, FallbackReason, LifecycleOptions, LifecycleReport};
pub use plan::{CommOp, FwdDependency, IterPlan, Schedule, Stage};
pub use replan::{MeasuredEnv, ReplanOptions};
pub use usbyte::UsByte;
pub use wfbp::Wfbp;

use crate::models::BucketProfile;

/// A communication-scheduling scheme.
pub trait Scheduler {
    /// Scheme name as used in the paper's tables.
    fn name(&self) -> &'static str;

    /// Build the steady-state schedule for `buckets`.
    ///
    /// `buckets` are in forward order (bucket 0 nearest the input); comm
    /// times are reference-link (NCCL) µs. Schedulers that use the slow
    /// link must account for its μ slowdown themselves via the options
    /// they were constructed with.
    fn schedule(&self, buckets: &[BucketProfile]) -> Schedule;
}

/// Table III — the qualitative feature matrix, printable by benches.
pub fn feature_matrix() -> String {
    let mut s = String::new();
    s.push_str("scheme         | fwd overlap | tensor fusion           | strategy           | hard dependency | convergence\n");
    s.push_str("---------------+-------------+-------------------------+--------------------+-----------------+------------\n");
    s.push_str("pytorch-ddp    | no          | regular & uniform       | -                  | exists          | baseline\n");
    s.push_str("bytescheduler  | yes         | auto-tune & uniform     | sequential         | exists          | exact\n");
    s.push_str("us-byte        | yes         | unequal-sized           | non-sequential     | exists          | exact\n");
    s.push_str("deft           | yes         | unequal (constrained)   | 0/1 multi-knapsack | eliminated      | approximate\n");
    s
}
