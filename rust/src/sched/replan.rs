//! Measured-drift adaptive re-planning — the control loop that closes
//! the drift monitor (paper §V: the scheduling strategy adjusts at
//! runtime because convergence loss is quantified, not assumed).
//!
//! The trial simulation's drift monitor compares measured per-link busy
//! against the plan's priced busy every iteration and raises
//! [`FaultEvent::DriftAlarm`] (and, when low-side monitoring is on,
//! [`FaultEvent::DriftAlarmLow`]) events carrying integer-µs
//! measured/planned pairs. This module harvests those alarms into a
//! [`MeasuredEnv`] — per-link measured/planned ratios in ppm — and
//! re-solves the §III.D knapsacks against the *measured* capacities
//! (`planning_mu × ratio`), instead of abandoning the adaptive plan for
//! the raw replay. The re-planned schedule must pass the same Preserver
//! walk and the same `DEFT-E…` static verifier as any first-choice plan
//! before the lifecycle adopts it.
//!
//! Everything here is deterministic: the inputs are integer-µs alarm
//! events from seeded fault traces, the solver is deterministic, and no
//! wall clock is consulted — so the engine-equivalence and sweep
//! serial-vs-parallel bit-for-bit suites extend to re-planned runs
//! unchanged.

use crate::analysis::{lint_plan, LintOptions, LintReport};
use crate::faults::FaultEvent;
use crate::links::ClusterEnv;
use crate::models::BucketProfile;
use crate::preserver::{self, WalkParams};
use crate::sched::{Deft, DeftOptions, Schedule, Scheduler};
use crate::sim::SimResult;

/// Ratio cap (ppm) for a single measured link: a drift alarm against a
/// zero-planned link saturates its excess, and an unbounded ratio would
/// ask the knapsack for a capacity of effectively zero. 20× is already
/// far beyond any modeled degradation (the worst preset flap is 4×).
const MAX_RATIO_PPM: u64 = 20_000_000;

/// ppm identity: measured == planned.
const UNIT_PPM: u64 = 1_000_000;

/// Knobs for the lifecycle's re-plan step (the `[replan]` TOML table).
#[derive(Clone, Debug, PartialEq)]
pub struct ReplanOptions {
    /// Master switch. Off by default: the drift gate then behaves
    /// exactly as before (reject ⇒ raw fallback), which keeps every
    /// pre-existing pin byte-identical.
    pub enabled: bool,
    /// Minimum compounded drift error (ppm) before a re-plan is
    /// attempted; smaller breaches keep the plain fallback path. 0 =
    /// re-plan on any gate rejection with alarms.
    pub min_excess_ppm: u64,
    /// Capacity-feedback retries (×1.15 per retry) the re-plan solve
    /// loop may take before giving up and falling back.
    pub max_retries: usize,
}

impl Default for ReplanOptions {
    fn default() -> Self {
        ReplanOptions {
            enabled: false,
            min_excess_ppm: 0,
            max_retries: preserver::MAX_RETRIES,
        }
    }
}

/// Per-link measured/planned busy ratios harvested from a trial's drift
/// alarms, in ppm (`1_000_000` = exactly as planned). This is the
/// integer-µs-derived "what execution actually saw" that overrides
/// [`ClusterEnv::link_planning_mus`] for the re-solve.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MeasuredEnv {
    /// One ratio per registered link, indexed by `LinkId`.
    pub link_ratio_ppm: Vec<u64>,
}

impl MeasuredEnv {
    /// Harvest from a trial's fault log. Per link the rule is:
    /// - any high-side [`FaultEvent::DriftAlarm`]s ⇒ the *largest*
    ///   implied ratio (`1e6 + excess_ppm`, capped) — plan for the worst
    ///   degradation actually observed;
    /// - else any low-side [`FaultEvent::DriftAlarmLow`]s ⇒ the largest
    ///   implied ratio (`1e6 − deficit_ppm`), i.e. the *least*
    ///   tightening — over-claiming reclaimed capacity is how a
    ///   re-planner overshoots;
    /// - no alarms ⇒ the link stays at its planned µ (`1e6`).
    ///
    /// Returns `None` when the log carries no drift alarms at all: with
    /// nothing measured off-plan there is nothing to re-plan against.
    pub fn from_alarms(fault_log: &[FaultEvent], n_links: usize) -> Option<MeasuredEnv> {
        let mut hi = vec![0u64; n_links];
        let mut lo = vec![0u64; n_links];
        let mut saw = false;
        for e in fault_log {
            match e {
                FaultEvent::DriftAlarm {
                    link, excess_ppm, ..
                } if link.index() < n_links => {
                    saw = true;
                    let ratio = UNIT_PPM.saturating_add(*excess_ppm).min(MAX_RATIO_PPM);
                    hi[link.index()] = hi[link.index()].max(ratio);
                }
                FaultEvent::DriftAlarmLow {
                    link, deficit_ppm, ..
                } if link.index() < n_links => {
                    saw = true;
                    let ratio = UNIT_PPM.saturating_sub(*deficit_ppm);
                    lo[link.index()] = lo[link.index()].max(ratio);
                }
                _ => {}
            }
        }
        if !saw {
            return None;
        }
        let link_ratio_ppm = (0..n_links)
            .map(|k| {
                if hi[k] > 0 {
                    hi[k]
                } else if lo[k] > 0 {
                    lo[k]
                } else {
                    UNIT_PPM
                }
            })
            .collect();
        Some(MeasuredEnv { link_ratio_ppm })
    }

    /// Harvest from a finished trial.
    pub fn from_trial(trial: &SimResult) -> Option<MeasuredEnv> {
        MeasuredEnv::from_alarms(&trial.fault_log, trial.link_busy.len())
    }

    /// True when any link measured slower than planned.
    pub fn is_degraded(&self) -> bool {
        self.link_ratio_ppm.iter().any(|&r| r > UNIT_PPM)
    }

    /// Largest per-link over-plan excess (ppm); 0 when nothing measured
    /// high. This is what [`ReplanOptions::min_excess_ppm`] gates on.
    pub fn worst_excess_ppm(&self) -> u64 {
        self.link_ratio_ppm
            .iter()
            .map(|&r| r.saturating_sub(UNIT_PPM))
            .max()
            .unwrap_or(0)
    }

    /// The measured planning µs: `env`'s healthy per-link planning µ
    /// scaled by the measured ratio. Links that drifted high get a
    /// larger µ (smaller knapsack capacity — less merged per window);
    /// links that drifted low (low-side monitoring) get a smaller one.
    pub fn link_mus(&self, env: &ClusterEnv) -> Vec<f64> {
        env.link_planning_mus()
            .iter()
            .zip(&self.link_ratio_ppm)
            .map(|(mu, &ratio)| mu * (ratio as f64 / 1e6))
            .collect()
    }
}

/// Compound every same-iteration per-link drift excess into one gradient
/// error via [`preserver::combined_error`], and return the worst
/// iteration's `(iter, error)`.
///
/// This is the drift-gate error model: simultaneous drift on two links
/// degrades the gradient stream on *both* routes in the same update, so
/// the errors compose like independent codec errors rather than taking
/// the single worst alarm (the old rule, which under-counted multi-link
/// drift). Low-side alarms carry no convergence risk (the plan was
/// merely over-conservative) and are excluded. Ties pick the earliest
/// iteration; every input is integer ppm so the fold is deterministic.
pub fn compounded_drift_error(fault_log: &[FaultEvent]) -> Option<(usize, f64)> {
    use std::collections::BTreeMap;
    let mut per_iter: BTreeMap<usize, f64> = BTreeMap::new();
    for e in fault_log {
        if let FaultEvent::DriftAlarm {
            iter, excess_ppm, ..
        } = e
        {
            let err = (*excess_ppm as f64 / 1e6).min(0.95);
            let slot = per_iter.entry(*iter).or_insert(0.0);
            *slot = preserver::combined_error(*slot, err);
        }
    }
    // BTreeMap iterates in iteration order, and only a strictly larger
    // error displaces the champion — ties keep the earliest iteration.
    let mut best: Option<(usize, f64)> = None;
    for (&iter, &err) in &per_iter {
        let better = match best {
            None => true,
            Some((_, b)) => err > b,
        };
        if better {
            best = Some((iter, err));
        }
    }
    best
}

/// Everything the re-plan solve loop needs, borrowed from the lifecycle.
pub struct ReplanRequest<'a> {
    pub profile: &'a [BucketProfile],
    /// The trial environment the re-planned schedule will run on (codecs
    /// included when the lifecycle did not fall back to raw).
    pub env: &'a ClusterEnv,
    pub measured: &'a MeasuredEnv,
    /// Capacity scale the rejected schedule was accepted at; the re-plan
    /// starts here and grows ×1.15 per retry.
    pub scale: f64,
    pub deft: &'a DeftOptions,
    pub walk: &'a WalkParams,
    pub base_batch: f64,
    pub epsilon: f64,
    /// Full-precision lint options (the same gate the first-choice plan
    /// passed); the re-planned schedule must come back clean.
    pub lint: &'a LintOptions,
    pub max_retries: usize,
}

/// An accepted re-plan.
pub struct ReplanOutcome {
    pub schedule: Schedule,
    /// Clean static-verifier report against the trial environment.
    pub lint: LintReport,
    /// The accepting Preserver walk's final-expectation ratio…
    pub ratio: f64,
    /// …and the gradient error it ran with (codec error of the routes
    /// the re-planned schedule uses; the drift excess is already priced
    /// into the capacities, so it no longer perturbs the walk).
    pub error: f64,
    /// `(capacity scale, ratio)` per solve attempt, for
    /// `LifecycleReport::attempts`.
    pub attempts: Vec<(f64, f64)>,
}

/// Re-solve the §III.D knapsacks against the measured capacities, with
/// the same capacity-feedback loop and the same acceptance bar as the
/// first-choice solve: the Preserver walk must land within ε and the
/// static verifier must come back clean. `None` when no candidate passes
/// within `max_retries` — the caller then takes the plain fallback path.
pub fn replan(req: &ReplanRequest) -> Option<ReplanOutcome> {
    let link_mus = req.measured.link_mus(req.env);
    let codec_errors = req.env.link_path_codec_errors();
    let mut scale = req.scale;
    let mut attempts = Vec::new();
    for _ in 0..=req.max_retries {
        let deft = Deft::new(DeftOptions {
            capacity_scale: scale,
            preserver: false,
            link_mus: link_mus.clone(),
            ..req.deft.clone()
        });
        let schedule = deft.schedule(req.profile);
        let err = schedule.worst_codec_error(&codec_errors);
        let report = preserver::quantify_with_error(
            req.walk,
            req.base_batch,
            &schedule.batch_multipliers,
            err,
        );
        attempts.push((scale, report.ratio));
        if preserver::acceptable(&report, req.epsilon) {
            let lint = lint_plan(&schedule, req.profile, req.env, req.lint);
            if lint.is_clean() {
                return Some(ReplanOutcome {
                    schedule,
                    lint,
                    ratio: report.ratio,
                    error: err,
                    attempts,
                });
            }
        }
        scale *= 1.15;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::links::LinkId;
    use crate::util::Micros;

    fn high(iter: usize, link: usize, excess_ppm: u64) -> FaultEvent {
        FaultEvent::DriftAlarm {
            iter,
            link: LinkId(link),
            measured: Micros(0),
            planned: Micros(0),
            excess_ppm,
        }
    }

    fn low(iter: usize, link: usize, deficit_ppm: u64) -> FaultEvent {
        FaultEvent::DriftAlarmLow {
            iter,
            link: LinkId(link),
            measured: Micros(0),
            planned: Micros(0),
            deficit_ppm,
        }
    }

    #[test]
    fn two_link_same_iteration_excesses_compound() {
        // Hand-computed oracle: excesses of 20% and 30% in the same
        // iteration compose like independent errors,
        // 1 − (1 − 0.2)(1 − 0.3) = 0.44 — strictly more than either
        // alone, which is exactly what the single-worst-alarm rule
        // under-counted.
        let log = vec![high(7, 0, 200_000), high(7, 1, 300_000)];
        let (iter, err) = compounded_drift_error(&log).expect("alarms compound");
        assert_eq!(iter, 7);
        assert!((err - 0.44).abs() < 1e-9, "combined error {err}");

        // A later single-link 45% excess beats the compounded 44%…
        let mut log2 = log.clone();
        log2.push(high(9, 0, 450_000));
        let (iter, err) = compounded_drift_error(&log2).expect("alarms compound");
        assert_eq!(iter, 9);
        assert!((err - 0.45).abs() < 1e-9);

        // …but a 43% excess does not, and the compounded iteration wins.
        let mut log3 = log.clone();
        log3.push(high(9, 0, 430_000));
        let (iter, err) = compounded_drift_error(&log3).expect("alarms compound");
        assert_eq!(iter, 7);
        assert!((err - 0.44).abs() < 1e-9);
    }

    #[test]
    fn drift_error_caps_per_link_and_ignores_low_alarms() {
        // A saturated excess (zero-planned link) caps at 0.95 instead of
        // blowing past the combined_error domain…
        let log = vec![high(3, 0, 5_000_000)];
        let (_, err) = compounded_drift_error(&log).expect("alarm");
        assert!((err - 0.95).abs() < 1e-9);
        // …and low-side alarms carry no convergence risk.
        assert_eq!(compounded_drift_error(&[low(3, 0, 400_000)]), None);
    }

    #[test]
    fn measured_env_harvests_worst_high_and_gentlest_low() {
        let log = vec![
            high(2, 0, 300_000),
            high(5, 0, 1_500_000), // worst high on link 0 wins
            low(4, 1, 400_000),
            low(6, 1, 100_000), // least tightening on link 1 wins
        ];
        let m = MeasuredEnv::from_alarms(&log, 3).expect("alarms harvest");
        assert_eq!(m.link_ratio_ppm, vec![2_500_000, 900_000, 1_000_000]);
        assert!(m.is_degraded());
        assert_eq!(m.worst_excess_ppm(), 1_500_000);

        // A high alarm outranks any low alarm on the same link.
        let log = vec![low(1, 0, 300_000), high(2, 0, 100_000)];
        let m = MeasuredEnv::from_alarms(&log, 1).expect("alarms harvest");
        assert_eq!(m.link_ratio_ppm, vec![1_100_000]);

        // No alarms ⇒ nothing to re-plan against.
        assert_eq!(MeasuredEnv::from_alarms(&[], 2), None);
    }

    #[test]
    fn measured_mus_scale_the_healthy_planning_mus() {
        let env = ClusterEnv::paper_testbed();
        let healthy = env.link_planning_mus();
        let m = MeasuredEnv {
            link_ratio_ppm: vec![2_500_000, 1_000_000],
        };
        let mus = m.link_mus(&env);
        assert_eq!(mus.len(), healthy.len());
        assert!((mus[0] - healthy[0] * 2.5).abs() < 1e-12);
        assert!((mus[1] - healthy[1]).abs() < 1e-12);

        // The saturated-excess cap holds the ratio at 20×.
        let log = vec![high(0, 0, u64::MAX)];
        let m = MeasuredEnv::from_alarms(&log, 1).expect("alarm");
        assert_eq!(m.link_ratio_ppm, vec![20_000_000]);
    }
}
