//! Metrics presentation: ASCII Gantt rendering (paper Figs. 11–13, 16),
//! CSV export, and summary tables.

use crate::links::LinkId;
use crate::sim::{SimResult, SpanKind, StreamId, Timeline};
use crate::util::Micros;

/// Number of link rows to render: every named link plus any extra link
/// index present in the timeline.
fn link_row_count(timeline: &Timeline, link_names: &[String]) -> usize {
    let in_timeline = timeline
        .spans
        .iter()
        .filter_map(|s| match s.stream {
            StreamId::Link(id) => Some(id.index() + 1),
            StreamId::Compute => None,
        })
        .max()
        .unwrap_or(0);
    link_names.len().max(in_timeline)
}

fn link_label(link_names: &[String], k: usize) -> String {
    link_names
        .get(k)
        .cloned()
        .unwrap_or_else(|| format!("link{k}"))
}

/// Render a timeline window as an ASCII Gantt chart: one row per stream
/// (compute + one per link, labelled from `link_names`), bucket ids as
/// glyphs (`0`-`9`, `a`-`z`), `.` for idle.
///
/// `window` selects the wall-clock range; `cols` the chart width.
pub fn gantt(
    timeline: &Timeline,
    window: (Micros, Micros),
    cols: usize,
    link_names: &[String],
) -> String {
    assert!(window.1 > window.0 && cols > 0);
    let span = (window.1 - window.0).as_us() as f64;
    let glyph = |bucket: usize, upper: bool| -> char {
        let c = match bucket {
            0..=9 => (b'0' + bucket as u8) as char,
            10..=35 => (b'a' + (bucket - 10) as u8) as char,
            _ => '#',
        };
        if upper {
            c.to_ascii_uppercase()
        } else {
            c
        }
    };

    let n_links = link_row_count(timeline, link_names);
    let mut streams: Vec<(StreamId, String)> = vec![(StreamId::Compute, "compute".to_string())];
    for k in 0..n_links {
        streams.push((StreamId::Link(LinkId(k)), link_label(link_names, k)));
    }
    let label_width = streams.iter().map(|(_, l)| l.len()).max().unwrap_or(7).max(7);
    let mut out = String::new();
    for (stream, label) in streams {
        let mut row = vec!['.'; cols];
        for s in timeline.on_stream(stream) {
            if s.end <= window.0 || s.start >= window.1 {
                continue;
            }
            let a = ((s.start.max(window.0) - window.0).as_us() as f64 / span * cols as f64)
                as usize;
            let b = ((s.end.min(window.1) - window.0).as_us() as f64 / span * cols as f64)
                .ceil() as usize;
            let (bucket, upper) = match &s.kind {
                SpanKind::Fwd { bucket, .. } => (*bucket, false),
                SpanKind::Bwd { bucket, .. } => (*bucket, true),
                SpanKind::Comm { bucket, .. } => (*bucket, false),
            };
            for c in row.iter_mut().take(b.min(cols)).skip(a) {
                *c = glyph(bucket, upper);
            }
        }
        out.push_str(&format!("{label:<label_width$}"));
        out.push_str(" |");
        out.extend(row);
        out.push_str("|\n");
    }
    out.push_str(&format!(
        "window {} .. {}  (fwd = lowercase/digits, bwd = uppercase, comm = bucket glyph)\n",
        window.0, window.1
    ));
    out
}

/// Render the steady-state window (one cycle after warm-up) of a result.
pub fn gantt_steady(result: &SimResult, cycle_iters: usize, cols: usize) -> String {
    let iters = result.iter_ends.len();
    if iters < cycle_iters + 2 {
        return gantt(
            &result.timeline,
            (Micros::ZERO, result.total.max(Micros(1))),
            cols,
            &result.link_names,
        );
    }
    let mid = iters / 2;
    let start = result.iter_ends[mid.saturating_sub(1)];
    let end = result.iter_ends[(mid + cycle_iters).min(iters - 1)];
    gantt(
        &result.timeline,
        (start, end.max(start + Micros(1))),
        cols,
        &result.link_names,
    )
}

/// CSV export of a timeline (stream,kind,iter,bucket,start_us,end_us);
/// link streams are labelled from `link_names` (registry order).
pub fn timeline_csv(timeline: &Timeline, link_names: &[String]) -> String {
    let mut out = String::from("stream,kind,iter,bucket,merged,start_us,end_us\n");
    for s in &timeline.spans {
        let stream = match s.stream {
            StreamId::Compute => "compute".to_string(),
            StreamId::Link(id) => link_label(link_names, id.index()),
        };
        let (kind, iter, bucket, merged) = match &s.kind {
            SpanKind::Fwd { iter, bucket } => ("fwd", *iter, *bucket, 1),
            SpanKind::Bwd { iter, bucket } => ("bwd", *iter, *bucket, 1),
            SpanKind::Comm {
                iter,
                bucket,
                merged,
            } => ("comm", *iter, *bucket, *merged),
        };
        out.push_str(&format!(
            "{stream},{kind},{iter},{bucket},{merged},{},{}\n",
            s.start.as_us(),
            s.end.as_us()
        ));
    }
    out
}

/// Per-link busy/bubble/utilization table computed from a simulation
/// result's timeline, plus the link's codec and its compressed-vs-raw
/// traffic. Under a hierarchical topology the shared intra link's row
/// also accumulates the node-local legs of transfers homed on other
/// links, so its utilization reads as segment pressure; busy times
/// include shared-NIC contention as the execution's contention model
/// priced it (the trailer names the model).
pub fn link_table(result: &SimResult) -> String {
    let mut t = Table::new(&[
        "link",
        "codec",
        "busy",
        "bubbles",
        "utilization",
        "raw MB",
        "wire MB",
        "encode",
    ]);
    for (k, name) in result.link_names.iter().enumerate() {
        let stream = StreamId::Link(LinkId(k));
        let busy = result.timeline.busy(stream);
        let bubbles = result.timeline.bubbles(stream);
        let span = busy + bubbles;
        let util = if span.is_zero() {
            "-".to_string()
        } else {
            format!("{:.1}%", busy.ratio(span) * 100.0)
        };
        let codec = result
            .link_codecs
            .get(k)
            .cloned()
            .unwrap_or_else(|| "raw".to_string());
        let traffic = result.link_traffic.get(k).copied().unwrap_or_default();
        t.row(&[
            name.clone(),
            codec,
            format!("{busy}"),
            format!("{bubbles}"),
            util,
            format!("{:.1}", traffic.raw_bytes as f64 / 1e6),
            format!("{:.1}", traffic.wire_bytes as f64 / 1e6),
            format!("{}", traffic.encode),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!("(contention model: {})\n", result.contention));
    out
}

/// CSV export of the per-link codec traffic accounting
/// (link,codec,raw_bytes,wire_bytes,encode_us,busy_us).
pub fn link_traffic_csv(result: &SimResult) -> String {
    let mut out = String::from("link,codec,raw_bytes,wire_bytes,encode_us,busy_us\n");
    for (k, name) in result.link_names.iter().enumerate() {
        let codec = result
            .link_codecs
            .get(k)
            .cloned()
            .unwrap_or_else(|| "raw".to_string());
        let traffic = result.link_traffic.get(k).copied().unwrap_or_default();
        let busy = result
            .link_busy
            .get(k)
            .map(|&(_, b)| b)
            .unwrap_or(Micros::ZERO);
        out.push_str(&format!(
            "{name},{codec},{},{},{},{}\n",
            traffic.raw_bytes,
            traffic.wire_bytes,
            traffic.encode.as_us(),
            busy.as_us()
        ));
    }
    out
}

/// A fixed-width table printer for bench outputs.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join(" | ")
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 3 * (widths.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Span;

    fn names(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn gantt_renders_spans() {
        let tl = Timeline {
            spans: vec![
                Span {
                    stream: StreamId::Compute,
                    kind: SpanKind::Fwd { iter: 0, bucket: 1 },
                    start: Micros(0),
                    end: Micros(50),
                },
                Span {
                    stream: StreamId::Link(LinkId(0)),
                    kind: SpanKind::Comm {
                        iter: 0,
                        bucket: 2,
                        merged: 1,
                    },
                    start: Micros(50),
                    end: Micros(100),
                },
            ],
        };
        let g = gantt(&tl, (Micros(0), Micros(100)), 20, &names(&["nccl", "gloo"]));
        assert!(g.contains('1'), "fwd glyph missing: {g}");
        assert!(g.contains('2'), "comm glyph missing: {g}");
        assert!(g.contains("nccl") && g.contains("gloo"), "labels missing: {g}");
        assert!(g.lines().count() >= 4);
    }

    #[test]
    fn gantt_renders_a_row_per_registry_link() {
        let tl = Timeline {
            spans: vec![Span {
                stream: StreamId::Link(LinkId(2)),
                kind: SpanKind::Comm {
                    iter: 0,
                    bucket: 3,
                    merged: 1,
                },
                start: Micros(0),
                end: Micros(10),
            }],
        };
        // Three named links → compute + 3 link rows + trailer.
        let g = gantt(&tl, (Micros(0), Micros(10)), 10, &names(&["nvlink", "ib", "tcp"]));
        assert!(g.contains("nvlink") && g.contains("ib") && g.contains("tcp"));
        assert_eq!(g.lines().count(), 5, "{g}");
        // Unnamed links fall back to an index label.
        let g2 = gantt(&tl, (Micros(0), Micros(10)), 10, &[]);
        assert!(g2.contains("link2"), "{g2}");
    }

    #[test]
    fn csv_has_all_spans() {
        let tl = Timeline {
            spans: vec![
                Span {
                    stream: StreamId::Compute,
                    kind: SpanKind::Bwd { iter: 3, bucket: 7 },
                    start: Micros(10),
                    end: Micros(30),
                },
                Span {
                    stream: StreamId::Link(LinkId(1)),
                    kind: SpanKind::Comm {
                        iter: 3,
                        bucket: 7,
                        merged: 2,
                    },
                    start: Micros(30),
                    end: Micros(60),
                },
            ],
        };
        let csv = timeline_csv(&tl, &names(&["nccl", "gloo"]));
        assert!(csv.contains("compute,bwd,3,7,1,10,30"));
        assert!(csv.contains("gloo,comm,3,7,2,30,60"));
    }

    #[test]
    fn link_table_and_traffic_csv_show_codec_columns() {
        use crate::sim::{LinkTraffic, SimResult};
        let result = SimResult {
            scheme: "t".into(),
            iter_ends: vec![Micros(100)],
            update_times: vec![Micros(100)],
            total: Micros(100),
            compute_bubbles: Micros::ZERO,
            steady_iter_time: Micros(100),
            link_busy: vec![(LinkId(0), Micros(50)), (LinkId(1), Micros(30))],
            link_names: names(&["nccl", "gloo"]),
            link_codecs: vec!["raw".into(), "fp16".into()],
            contention: "kway".into(),
            link_traffic: vec![
                LinkTraffic {
                    raw_bytes: 4_000_000,
                    wire_bytes: 4_000_000,
                    encode: Micros::ZERO,
                },
                LinkTraffic {
                    raw_bytes: 4_000_000,
                    wire_bytes: 2_000_000,
                    encode: Micros(8),
                },
            ],
            events_processed: 4,
            peak_in_flight: 2,
            fault_log: Vec::new(),
            timeline: Timeline::default(),
        };
        let table = link_table(&result);
        assert!(table.contains("fp16"), "{table}");
        assert!(table.contains("wire MB"), "{table}");
        assert!(table.contains("contention model: kway"), "{table}");
        let csv = link_traffic_csv(&result);
        assert!(csv.contains("nccl,raw,4000000,4000000,0,50"), "{csv}");
        assert!(csv.contains("gloo,fp16,4000000,2000000,8,30"), "{csv}");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("a   | bb"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
