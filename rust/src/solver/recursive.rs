//! Paper **Algorithm 1** — `RecursiveKnapsack`.
//!
//! During the backward stage, bucket gradients become ready progressively
//! (bucket N first, bucket 1 last). Packing at bucket N's ready point sees
//! all later buckets as *future* items but the full remaining backward
//! time as capacity; deferring the decision to bucket N-1's ready point
//! shrinks the capacity by bucket N-1's backward computation time but can
//! yield a better packing of the still-unready tail. Algorithm 1 explores
//! exactly this trade-off: compare the greedy packing of the current
//! suffix against the best packing of the next suffix with reduced
//! capacity, recursively.

use super::{greedy::naive_knapsack, Item, PackResult};
use crate::util::Micros;

/// Recursive two-way choice of paper Algorithm 1.
///
/// * `items` — pending bucket communications in **readiness order**
///   (`items[0]` is ready first; for a backward stage this is
///   `{C_N, C_{N-1}, …}`).
/// * `release` — `release[i]` is the computation time that elapses between
///   `items[i-1]`'s ready point and `items[i]`'s ready point (for the
///   backward stage, bucket `i`'s backward time). `release[0]` is unused
///   by the recursion (capacity is already measured from `items[0]`'s
///   ready point).
/// * `capacity` — overlap capacity measured from `items[0]`'s ready point.
///
/// Returns the better of: greedily packing the whole suffix now, or
/// dropping the head item (deferring it to a later stage / iteration — in
/// DeFT it lands in the task queues) and recursing with the capacity that
/// remains once the next bucket is ready.
pub fn recursive_knapsack(items: &[Item], release: &[Micros], capacity: Micros) -> PackResult {
    assert_eq!(
        items.len(),
        release.len(),
        "items and release times must align"
    );
    if items.is_empty() {
        return PackResult::default();
    }
    // order1: pack everything visible now into the current capacity.
    let order1 = naive_knapsack(items, capacity);
    // order2: defer the head item; the next bucket's backward computation
    // elapses, shrinking the capacity.
    let order2 = if items.len() > 1 {
        let reduced = capacity.saturating_sub(release[1]);
        recursive_knapsack(&items[1..], &release[1..], reduced)
    } else {
        PackResult::default()
    };
    if order1.total >= order2.total {
        order1
    } else {
        order2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::total_comm;
    use crate::util::prop::check;

    fn mk(comms: &[u64]) -> Vec<Item> {
        comms
            .iter()
            .enumerate()
            .map(|(i, &c)| Item::new(i, Micros(c)))
            .collect()
    }

    fn rel(times: &[u64]) -> Vec<Micros> {
        times.iter().map(|&t| Micros(t)).collect()
    }

    #[test]
    fn empty_returns_empty() {
        let r = recursive_knapsack(&[], &[], Micros(100));
        assert!(r.is_empty());
    }

    #[test]
    fn single_item_fits_or_not() {
        let its = mk(&[10]);
        let r = recursive_knapsack(&its, &rel(&[5]), Micros(10));
        assert_eq!(r.total, Micros(10));
        let r = recursive_knapsack(&its, &rel(&[5]), Micros(9));
        assert!(r.is_empty());
    }

    #[test]
    fn defer_wins_when_tail_packs_better() {
        // Head item is huge and blocks the sack; deferring to the tail
        // (capacity - release) packs more total communication.
        // capacity 120; items [90, 60, 45]; release [_, 5, 5].
        // order1: greedy packs 90 only (remaining 30 fits nothing) => 90.
        // defer: capacity 115, items [60, 45] => packs both = 105. Wins.
        let its = mk(&[90, 60, 45]);
        let r = recursive_knapsack(&its, &rel(&[0, 5, 5]), Micros(120));
        assert_eq!(r.total, Micros(105));
        assert!(!r.chosen.contains(&0));
    }

    #[test]
    fn keep_wins_when_release_cost_high() {
        // Deferring loses so much capacity the tail can't compete.
        let its = mk(&[50, 60]);
        // order1: packs 60 + 50 = 110 if capacity 110.
        let r = recursive_knapsack(&its, &rel(&[0, 100]), Micros(110));
        assert_eq!(r.total, Micros(110));
    }

    #[test]
    fn matches_paper_structure_on_backward_list() {
        // Backward readiness order {C_6..C_1} for VGG-like imbalance:
        // deferring should never *reduce* the packed total below the plain
        // greedy answer.
        let its = mk(&[8651, 31754, 178643, 15447, 11262, 1968]);
        let release = rel(&[162, 484, 2319, 4872, 12786, 72496]);
        let cap = Micros(93119);
        let r = recursive_knapsack(&its, &release, cap);
        let greedy = naive_knapsack(&its, cap);
        assert!(r.total >= greedy.total);
        assert!(r.total <= cap);
    }

    #[test]
    fn prop_never_worse_than_naive_and_within_capacity() {
        check("recursive >= naive, within capacity", 300, |g| {
            let comms = g.vec_u64(0..=12, 0..=400);
            let its = mk(&comms);
            let release: Vec<Micros> = comms
                .iter()
                .map(|&c| Micros(c / 3)) // arbitrary but deterministic
                .collect();
            let cap = Micros(g.u64_in(0..=1_500));
            let r = recursive_knapsack(&its, &release, cap);
            if r.total > cap {
                return Err(format!("over capacity: {:?} > {cap:?}", r.total));
            }
            let naive = naive_knapsack(&its, cap);
            if r.total < naive.total {
                return Err(format!(
                    "recursive {:?} worse than naive {:?}",
                    r.total, naive.total
                ));
            }
            // chosen ids must be valid and unique
            let mut seen = std::collections::HashSet::new();
            for &id in &r.chosen {
                if id >= its.len() || !seen.insert(id) {
                    return Err(format!("bad id {id}"));
                }
            }
            let sum: Micros = r.chosen.iter().map(|&id| its[id].comm).sum();
            if sum != r.total {
                return Err("sum mismatch".into());
            }
            if r.total > total_comm(&its) {
                return Err("packed more than exists".into());
            }
            Ok(())
        });
    }
}
