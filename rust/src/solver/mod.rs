//! Knapsack solvers — the optimization core of DeFT (paper §III.B–C).
//!
//! DeFT transforms two-stage communication scheduling into 0/1 knapsack
//! problems: knapsack **capacity** is computation time available for
//! overlap, **items** are bucket communications, and an item's weight and
//! profit are both its communication time (we maximize overlapped
//! communication).
//!
//! Four solvers are provided:
//!
//! * [`naive_knapsack`] — the paper's `NaiveKnapsack`: a greedy
//!   largest-first packing (the paper's low-cost heuristic).
//! * [`recursive_knapsack`] — paper **Algorithm 1**: recursion over the
//!   suffix of the ready-ordered item list, comparing "pack everything
//!   available now" against "drop the newest item and recurse with the
//!   capacity that excludes its producing computation".
//! * [`multi_knapsack_greedy`] — paper **Problem 2**: the 0/1
//!   multi-knapsack across heterogeneous links (NCCL + gloo), solved with
//!   the paper's greedy (sort capacities ascending, place longest items
//!   first).
//! * [`knapsack_exact`] / [`multi_knapsack_exact`] — branch-and-bound
//!   exact solvers used as test oracles and for the ablation bench
//!   (`bench_solver_overhead`): they certify how far the paper's greedy
//!   heuristics sit from optimal on real workload instances.
//!
//! All capacities/weights are [`Micros`] — integer µs — so DP/B&B are
//! exact.

mod exact;
mod greedy;
mod recursive;

pub use exact::{knapsack_exact, multi_knapsack_exact};
pub use greedy::{multi_knapsack_greedy, naive_knapsack, MultiKnapsackResult};
pub use recursive::recursive_knapsack;

use crate::util::Micros;

/// An item offered to a knapsack: one bucket's pending communication.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Item {
    /// Caller-side identifier (bucket id); opaque to the solver.
    pub id: usize,
    /// Communication time on the *reference* (NCCL) link. Heterogeneous
    /// solvers rescale per link via the link's slowdown factor.
    pub comm: Micros,
}

impl Item {
    pub fn new(id: usize, comm: Micros) -> Item {
        Item { id, comm }
    }
}

/// Result of a single-knapsack solve: chosen item ids (in packing order)
/// and the total packed communication time.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PackResult {
    pub chosen: Vec<usize>,
    pub total: Micros,
}

impl PackResult {
    pub fn is_empty(&self) -> bool {
        self.chosen.is_empty()
    }
}

/// Sum of communication times of a set of items.
pub fn total_comm(items: &[Item]) -> Micros {
    items.iter().map(|i| i.comm).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn item_total() {
        let items = vec![Item::new(0, Micros(5)), Item::new(1, Micros(7))];
        assert_eq!(total_comm(&items), Micros(12));
    }
}
