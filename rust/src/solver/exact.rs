//! Exact knapsack solvers — test oracles and ablation references.
//!
//! These certify the quality of the paper's greedy heuristics on real
//! workload instances (`bench_solver_overhead` reports greedy/optimal
//! ratios). Branch-and-bound with a fractional (LP) upper bound: item
//! profits equal weights, so the fractional bound is simply
//! `min(capacity, sum of remaining items)` — cheap and tight.
//!
//! Instance sizes in this problem are tiny (the paper caps N < 20 buckets,
//! M = 2 links), so exponential worst cases are irrelevant; we still guard
//! with an explicit node budget and assert on instance size.

use super::{Item, PackResult};
use crate::util::Micros;

const MAX_EXACT_ITEMS: usize = 28;
const NODE_BUDGET: u64 = 20_000_000;

/// Exact single 0/1 knapsack (profit = weight = comm time).
///
/// Panics if given more than `MAX_EXACT_ITEMS` items — this is an oracle,
/// not a production solver.
pub fn knapsack_exact(items: &[Item], capacity: Micros) -> PackResult {
    assert!(
        items.len() <= MAX_EXACT_ITEMS,
        "exact solver limited to {MAX_EXACT_ITEMS} items"
    );
    // Sort descending for a tighter first incumbent.
    let mut order: Vec<&Item> = items.iter().collect();
    order.sort_by(|a, b| b.comm.cmp(&a.comm).then(a.id.cmp(&b.id)));

    // suffix[i] = total comm of order[i..]
    let mut suffix = vec![Micros::ZERO; order.len() + 1];
    for i in (0..order.len()).rev() {
        suffix[i] = suffix[i + 1] + order[i].comm;
    }

    struct Ctx<'a> {
        order: &'a [&'a Item],
        suffix: &'a [Micros],
        best: Micros,
        best_set: Vec<usize>,
        cur_set: Vec<usize>,
        nodes: u64,
    }

    fn dfs(ctx: &mut Ctx, i: usize, used: Micros, capacity: Micros) {
        ctx.nodes += 1;
        assert!(ctx.nodes < NODE_BUDGET, "exact solver node budget blown");
        if used > ctx.best {
            ctx.best = used;
            ctx.best_set = ctx.cur_set.clone();
        }
        if i == ctx.order.len() {
            return;
        }
        // Bound: even taking every remaining item can't beat incumbent.
        if used + ctx.suffix[i] <= ctx.best {
            return;
        }
        let item = ctx.order[i];
        // Branch: take (if it fits), then skip.
        if used + item.comm <= capacity {
            ctx.cur_set.push(item.id);
            dfs(ctx, i + 1, used + item.comm, capacity);
            ctx.cur_set.pop();
        }
        dfs(ctx, i + 1, used, capacity);
    }

    let mut ctx = Ctx {
        order: &order,
        suffix: &suffix,
        best: Micros::ZERO,
        best_set: Vec::new(),
        cur_set: Vec::new(),
        nodes: 0,
    };
    dfs(&mut ctx, 0, Micros::ZERO, capacity);
    PackResult {
        chosen: ctx.best_set,
        total: ctx.best,
    }
}

/// Exact 0/1 multi-knapsack: maximize total packed comm across `capacities`.
///
/// Returns `(assignments, total)` where `assignments[k]` lists the ids in
/// knapsack `k`. Exhaustive DFS over (M+1)-way item placement with the
/// fractional bound; intended for M ≤ 4 (the N-link topology registry's
/// test range), N ≤ 18 (test/bench scale).
pub fn multi_knapsack_exact(
    items: &[Item],
    capacities: &[Micros],
) -> (Vec<Vec<usize>>, Micros) {
    assert!(items.len() <= 18, "exact multi-knapsack limited to 18 items");
    assert!(capacities.len() <= 4, "exact multi-knapsack limited to 4 sacks");

    let mut order: Vec<&Item> = items.iter().collect();
    order.sort_by(|a, b| b.comm.cmp(&a.comm).then(a.id.cmp(&b.id)));
    let mut suffix = vec![Micros::ZERO; order.len() + 1];
    for i in (0..order.len()).rev() {
        suffix[i] = suffix[i + 1] + order[i].comm;
    }

    struct Ctx<'a> {
        order: &'a [&'a Item],
        suffix: &'a [Micros],
        best: Micros,
        best_assign: Vec<Vec<usize>>,
        cur_assign: Vec<Vec<usize>>,
        nodes: u64,
    }

    fn dfs(ctx: &mut Ctx, i: usize, used: Micros, remaining: &mut Vec<Micros>) {
        ctx.nodes += 1;
        assert!(ctx.nodes < NODE_BUDGET, "exact solver node budget blown");
        if used > ctx.best {
            ctx.best = used;
            ctx.best_assign = ctx.cur_assign.clone();
        }
        if i == ctx.order.len() {
            return;
        }
        if used + ctx.suffix[i] <= ctx.best {
            return;
        }
        let item = ctx.order[i];
        // Try each knapsack (skip symmetric identical-capacity repeats).
        let mut tried: Vec<Micros> = Vec::with_capacity(remaining.len());
        for k in 0..remaining.len() {
            if item.comm <= remaining[k] && !tried.contains(&remaining[k]) {
                tried.push(remaining[k]);
                remaining[k] = remaining[k] - item.comm;
                ctx.cur_assign[k].push(item.id);
                dfs(ctx, i + 1, used + item.comm, remaining);
                ctx.cur_assign[k].pop();
                remaining[k] = remaining[k] + item.comm;
            }
        }
        // Skip the item.
        dfs(ctx, i + 1, used, remaining);
    }

    let mut ctx = Ctx {
        order: &order,
        suffix: &suffix,
        best: Micros::ZERO,
        best_assign: vec![Vec::new(); capacities.len()],
        cur_assign: vec![Vec::new(); capacities.len()],
        nodes: 0,
    };
    let mut remaining = capacities.to_vec();
    dfs(&mut ctx, 0, Micros::ZERO, &mut remaining);
    (ctx.best_assign, ctx.best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{multi_knapsack_greedy, naive_knapsack};
    use crate::util::prop::check;

    fn mk(comms: &[u64]) -> Vec<Item> {
        comms
            .iter()
            .enumerate()
            .map(|(i, &c)| Item::new(i, Micros(c)))
            .collect()
    }

    #[test]
    fn exact_beats_greedy_on_adversarial_instance() {
        // Greedy longest-first packs 7 then nothing else fits (cap 10);
        // optimal is 6+4 = 10.
        let its = mk(&[7, 6, 4]);
        let greedy = naive_knapsack(&its, Micros(10));
        let exact = knapsack_exact(&its, Micros(10));
        assert_eq!(greedy.total, Micros(7));
        assert_eq!(exact.total, Micros(10));
    }

    #[test]
    fn exact_multi_simple() {
        let its = mk(&[5, 4, 3]);
        let (assign, total) = multi_knapsack_exact(&its, &[Micros(5), Micros(7)]);
        assert_eq!(total, Micros(12));
        let all: usize = assign.iter().map(|a| a.len()).sum();
        assert_eq!(all, 3);
    }

    #[test]
    fn prop_exact_dominates_greedy_single() {
        check("exact >= greedy (single)", 150, |g| {
            let comms = g.vec_u64(0..=10, 0..=200);
            let cap = Micros(g.u64_in(0..=800));
            let its = mk(&comms);
            let e = knapsack_exact(&its, cap);
            let n = naive_knapsack(&its, cap);
            if e.total < n.total {
                return Err(format!("exact {:?} < greedy {:?}", e.total, n.total));
            }
            if e.total > cap {
                return Err("exact over capacity".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_exact_dominates_greedy_multi() {
        check("exact >= greedy (multi)", 80, |g| {
            let comms = g.vec_u64(0..=9, 0..=150);
            let caps_raw = g.vec_u64(1..=2, 0..=400);
            let caps: Vec<Micros> = caps_raw.iter().map(|&c| Micros(c)).collect();
            let its = mk(&comms);
            let (_, e_total) = multi_knapsack_exact(&its, &caps);
            let gr = multi_knapsack_greedy(&its, &caps);
            if e_total < gr.total {
                return Err(format!("exact {e_total:?} < greedy {:?}", gr.total));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_greedy_never_exceeds_exact_on_n_link_instances() {
        // The registry generalizes the solver path to N knapsacks (one
        // per link). For N ∈ {2, 3, 4}: the paper's greedy must never
        // pack more total time than the exact optimum, must respect every
        // capacity, and the exact optimum must fit the capacities too.
        check("greedy <= exact (N-link)", 50, |g| {
            for n_links in 2..=4usize {
                let comms = g.vec_u64(0..=9, 0..=120);
                let caps_raw = g.vec_u64(n_links..=n_links, 0..=360);
                let caps: Vec<Micros> = caps_raw.iter().map(|&c| Micros(c)).collect();
                let its = mk(&comms);
                let (assign, e_total) = multi_knapsack_exact(&its, &caps);
                let gr = multi_knapsack_greedy(&its, &caps);
                if gr.total > e_total {
                    return Err(format!(
                        "N={n_links}: greedy {:?} beats exact {e_total:?}",
                        gr.total
                    ));
                }
                for (k, sack) in assign.iter().enumerate() {
                    let used: Micros = sack.iter().map(|&id| its[id].comm).sum();
                    if used > caps[k] {
                        return Err(format!("N={n_links}: exact sack {k} over capacity"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_greedy_never_exceeds_exact_on_segment_derived_capacities() {
        use crate::links::{ClusterEnv, LinkId, LinkPreset, Topology};
        // Capacities as the schedulers now derive them: one knapsack per
        // registry link, capacity = compute window ÷ the link's
        // **segment-path** slowdown under a hierarchical topology (not a
        // global μ). Greedy must stay within the exact optimum and every
        // capacity must be respected.
        check("greedy <= exact (segment-derived caps)", 30, |g| {
            let rpn = [2usize, 4, 8][g.usize_in(0..=2)];
            let env: ClusterEnv = LinkPreset::NvlinkIbTcp
                .env()
                .with_topology(Topology::hierarchical(rpn, LinkId(0), LinkId(1)));
            let compute = Micros(g.u64_in(1_000..=100_000));
            let caps: Vec<Micros> = env
                .link_path_mus()
                .iter()
                .map(|&mu| compute.scale(1.0 / mu))
                .collect();
            let comms = g.vec_u64(0..=9, 0..=60_000);
            let its = mk(&comms);
            let (assign, e_total) = multi_knapsack_exact(&its, &caps);
            let gr = multi_knapsack_greedy(&its, &caps);
            if gr.total > e_total {
                return Err(format!(
                    "rpn={rpn}: greedy {:?} beats exact {e_total:?}",
                    gr.total
                ));
            }
            for (k, sack) in assign.iter().enumerate() {
                let used: Micros = sack.iter().map(|&id| its[id].comm).sum();
                if used > caps[k] {
                    return Err(format!("rpn={rpn}: exact sack {k} over capacity"));
                }
            }
            for (k, sack) in gr.assignments.iter().enumerate() {
                let used: Micros = sack.iter().map(|&id| its[id].comm).sum();
                if used > caps[k] {
                    return Err(format!("rpn={rpn}: greedy sack {k} over capacity"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn greedy_single_within_half_of_optimal() {
        // Classic bound: profit=weight greedy (longest-first) achieves
        // >= 1/2 of optimal. Verify on random instances.
        check("greedy 1/2-approximation", 150, |g| {
            let comms = g.vec_u64(1..=10, 1..=200);
            let cap = Micros(g.u64_in(1..=800));
            let its = mk(&comms);
            let e = knapsack_exact(&its, cap);
            let n = naive_knapsack(&its, cap);
            if e.total.as_us() > 0 && (n.total.as_us() as f64) < 0.5 * e.total.as_us() as f64 {
                return Err(format!(
                    "greedy {:?} below half of optimal {:?}",
                    n.total, e.total
                ));
            }
            Ok(())
        });
    }
}
