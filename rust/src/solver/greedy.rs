//! Greedy knapsack heuristics — the solvers DeFT actually runs online.
//!
//! The paper (§III.C) argues exact multi-knapsack is NP-hard and uses a
//! greedy strategy: *"we first sort the capacity of each knapsack and the
//! time of each bucket, and then start with the backpack with smaller
//! capacity, and try to prioritize placing the bucket with longer time"*.
//! Placement is O(N·M) for N items, M knapsacks.

use super::{Item, PackResult};
use crate::util::Micros;

/// The paper's `NaiveKnapsack`: greedily pack items, longest
/// communication first, into a single knapsack of capacity `capacity`.
///
/// Since every item's weight equals its profit, longest-first greedy is a
/// 1/2-approximation; on the paper's instances (≤ 20 items whose sizes are
/// bounded by the capacity constraint of §III.D) it is usually optimal —
/// `solver::knapsack_exact` certifies the gap in tests and benches.
pub fn naive_knapsack(items: &[Item], capacity: Micros) -> PackResult {
    let mut order: Vec<&Item> = items.iter().collect();
    // Longest first; tie-break on id for determinism.
    order.sort_by(|a, b| b.comm.cmp(&a.comm).then(a.id.cmp(&b.id)));
    let mut remaining = capacity;
    let mut chosen = Vec::new();
    let mut total = Micros::ZERO;
    for item in order {
        if item.comm <= remaining {
            remaining = remaining - item.comm;
            total += item.comm;
            chosen.push(item.id);
        }
    }
    PackResult { chosen, total }
}

/// Per-knapsack assignment produced by the multi-knapsack solvers.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MultiKnapsackResult {
    /// `assignments[k]` = ids packed into knapsack `k` (original index
    /// into the `capacities` argument, not the sorted order).
    pub assignments: Vec<Vec<usize>>,
    /// Total packed communication time in *reference-link* units.
    pub total: Micros,
    /// Ids that did not fit anywhere.
    pub leftover: Vec<usize>,
}

/// Paper **Problem 2** greedy: 0/1 multi-knapsack over heterogeneous
/// links.
///
/// `capacities[k]` is the overlap capacity of link `k` *in reference-link
/// time units* (the caller divides a slow link's raw compute window by its
/// slowdown μ, per §III.C/III.D: the gloo knapsack holds `capacity/μ`
/// worth of NCCL-time communication).
///
/// Strategy (verbatim from the paper): sort knapsacks by ascending
/// capacity, items by descending time; fill the smallest knapsack first
/// with the longest items that fit. O(N·M) placement after the sorts.
pub fn multi_knapsack_greedy(items: &[Item], capacities: &[Micros]) -> MultiKnapsackResult {
    let mut result = MultiKnapsackResult {
        assignments: vec![Vec::new(); capacities.len()],
        total: Micros::ZERO,
        leftover: Vec::new(),
    };
    if capacities.is_empty() {
        result.leftover = items.iter().map(|i| i.id).collect();
        return result;
    }

    // Knapsacks ascending by capacity (remember original index).
    let mut sacks: Vec<(usize, Micros)> =
        capacities.iter().copied().enumerate().collect();
    sacks.sort_by(|a, b| a.1.cmp(&b.1).then(a.0.cmp(&b.0)));

    // Items descending by comm time.
    let mut order: Vec<&Item> = items.iter().collect();
    order.sort_by(|a, b| b.comm.cmp(&a.comm).then(a.id.cmp(&b.id)));

    let mut remaining: Vec<Micros> = sacks.iter().map(|&(_, c)| c).collect();
    let mut placed = vec![false; order.len()];

    // Fill the smallest knapsack first with the longest items that fit.
    for (si, &(orig_k, _)) in sacks.iter().enumerate() {
        for (ii, item) in order.iter().enumerate() {
            if placed[ii] {
                continue;
            }
            if item.comm <= remaining[si] {
                remaining[si] = remaining[si] - item.comm;
                result.assignments[orig_k].push(item.id);
                result.total += item.comm;
                placed[ii] = true;
            }
        }
    }
    for (ii, item) in order.iter().enumerate() {
        if !placed[ii] {
            result.leftover.push(item.id);
        }
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn items(comms: &[u64]) -> Vec<Item> {
        comms
            .iter()
            .enumerate()
            .map(|(i, &c)| Item::new(i, Micros(c)))
            .collect()
    }

    #[test]
    fn naive_packs_all_when_capacity_large() {
        let its = items(&[3, 5, 2]);
        let r = naive_knapsack(&its, Micros(100));
        assert_eq!(r.total, Micros(10));
        assert_eq!(r.chosen.len(), 3);
        // Longest-first order: item 1 (5), item 0 (3), item 2 (2).
        assert_eq!(r.chosen, vec![1, 0, 2]);
    }

    #[test]
    fn naive_respects_capacity() {
        let its = items(&[6, 5, 4]);
        let r = naive_knapsack(&its, Micros(10));
        assert!(r.total <= Micros(10));
        // Greedy: 6 then 4 fits => total 10 (optimal here).
        assert_eq!(r.total, Micros(10));
    }

    #[test]
    fn naive_empty_inputs() {
        assert!(naive_knapsack(&[], Micros(10)).is_empty());
        let r = naive_knapsack(&items(&[5]), Micros::ZERO);
        assert!(r.is_empty());
        assert_eq!(r.total, Micros::ZERO);
    }

    #[test]
    fn multi_fills_smallest_first() {
        let its = items(&[8, 6, 4, 2]);
        // capacities: [10 (nccl), 6 (gloo, already divided by mu)]
        let r = multi_knapsack_greedy(&its, &[Micros(10), Micros(6)]);
        // Smallest sack (cap 6, original index 1) takes item 1 (6).
        assert_eq!(r.assignments[1], vec![1]);
        // Larger sack takes 8 then 2.
        assert_eq!(r.assignments[0], vec![0, 3]);
        assert_eq!(r.total, Micros(16));
        assert_eq!(r.leftover, vec![2]);
    }

    #[test]
    fn multi_no_knapsacks() {
        let its = items(&[1, 2]);
        let r = multi_knapsack_greedy(&its, &[]);
        assert_eq!(r.leftover, vec![0, 1]);
        assert_eq!(r.total, Micros::ZERO);
    }

    #[test]
    fn prop_naive_within_capacity_and_no_duplicates() {
        check("naive knapsack invariants", 300, |g| {
            let comms = g.vec_u64(0..=20, 0..=500);
            let cap = Micros(g.u64_in(0..=2_000));
            let its = items(&comms);
            let r = naive_knapsack(&its, cap);
            if r.total > cap {
                return Err(format!("total {:?} exceeds capacity {cap:?}", r.total));
            }
            let mut seen = std::collections::HashSet::new();
            for &id in &r.chosen {
                if !seen.insert(id) {
                    return Err(format!("duplicate id {id}"));
                }
                if id >= its.len() {
                    return Err(format!("unknown id {id}"));
                }
            }
            let sum: Micros = r.chosen.iter().map(|&id| its[id].comm).sum();
            if sum != r.total {
                return Err("total mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_multi_each_item_at_most_once_and_capacity() {
        check("multi knapsack invariants", 300, |g| {
            let comms = g.vec_u64(0..=15, 0..=300);
            let caps_raw = g.vec_u64(1..=3, 0..=600);
            let caps: Vec<Micros> = caps_raw.iter().map(|&c| Micros(c)).collect();
            let its = items(&comms);
            let r = multi_knapsack_greedy(&its, &caps);
            let mut seen = std::collections::HashSet::new();
            for (k, sack) in r.assignments.iter().enumerate() {
                let sum: Micros = sack.iter().map(|&id| its[id].comm).sum();
                if sum > caps[k] {
                    return Err(format!("sack {k} over capacity"));
                }
                for &id in sack {
                    if !seen.insert(id) {
                        return Err(format!("item {id} placed twice"));
                    }
                }
            }
            for &id in &r.leftover {
                if !seen.insert(id) {
                    return Err(format!("leftover {id} also placed"));
                }
            }
            if seen.len() != its.len() {
                return Err("items lost".into());
            }
            Ok(())
        });
    }
}
