//! Deterministic, seeded fault injection for dynamic clusters.
//!
//! Every run so far simulated a static cluster at steady state. This
//! module injects the three fault families the ROADMAP's
//! dynamic-cluster item calls for, as a **pure function of the spec**
//! — no online randomness inside the engines, so the indexed and scan
//! engines replay the identical trace bit-for-bit:
//!
//! * **Compute jitter and stragglers** — per-(iteration, bucket)
//!   forward/backward stretch, drawn once from a seeded xoshiro stream
//!   (`jitter_pct`) plus persistent per-iteration stretch factors
//!   (`stragglers`).
//! * **Link flaps** — a link's wire-time ratio changes at scheduled sim
//!   times; in-flight transfers are re-priced piecewise exactly like
//!   k-way membership changes are today (bank progress at the old rate,
//!   re-project the remainder at the new rate).
//! * **Elastic membership** — ranks join/leave between iterations;
//!   allreduce wire times rescale by the ring-factor ratio
//!   ([`ClusterEnv::elastic_wire_scale`]).
//!
//! A [`FaultSpec`] is declarative and engine-agnostic;
//! [`FaultTrace::materialize`] compiles it against a concrete
//! (profile, schedule, environment, iteration count) into the flat
//! arrays both engines consume. The trace also carries the **drift
//! monitor**: planned per-link busy per cycle slot, compared against
//! measured busy as each iteration completes; breaches land on
//! [`SimResult::fault_log`](crate::sim::SimResult) as
//! [`FaultEvent::DriftAlarm`]s, and the lifecycle re-runs the Preserver
//! gate against the drifted topology (see `docs/faults.md`).

mod log;
mod trace;

pub use log::{to_ppm, FaultEvent};
pub use trace::{FaultTrace, FlapAt};

use crate::links::{ClusterEnv, LinkId};
use crate::util::Micros;

/// A persistent compute straggler on one rank: from iteration
/// `from_iter` on, every bucket's forward and backward on rank `rank`
/// stretch by `factor` (≥ 1).
///
/// Data-parallel ranks all run the same buckets, so the compute window
/// the engines simulate extends by the **slowest rank's** total excess:
/// stragglers on the *same* rank compound additively, stragglers on
/// *different* ranks do not — only the worst rank sits on the critical
/// path (the slowest-rank rule; see `docs/faults.md`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Straggler {
    pub from_iter: usize,
    pub factor: f64,
    /// Rank the straggler lives on (must be `< env.workers`).
    pub rank: usize,
}

/// A scheduled link-speed change: from sim time `at` on, wire times on
/// `link` are priced at `factor ×` their healthy value. `factor > 1`
/// degrades the link, `factor = 1` recovers it. Factors are absolute
/// (vs the healthy link), not cumulative.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Flap {
    pub link: LinkId,
    pub at: Micros,
    pub factor: f64,
}

/// An elastic-membership change: from iteration `at_iter` on the
/// cluster has `workers` ranks, rescaling ring-allreduce wire times by
/// the ratio of ring factors `2(k−1)/k`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MembershipChange {
    pub at_iter: usize,
    pub workers: usize,
}

/// Declarative fault scenario: what goes wrong, when, and how tightly
/// the drift monitor watches the consequences.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    /// Seed of the jitter stream (xoshiro256++ via splitmix64).
    pub seed: u64,
    /// Uniform per-(iteration, bucket) compute jitter: each forward and
    /// backward independently stretches by `[0, jitter_pct]`. 0 = off.
    pub jitter_pct: f64,
    pub stragglers: Vec<Straggler>,
    pub flaps: Vec<Flap>,
    pub membership: Vec<MembershipChange>,
    /// Relative drift band of the monitor: an iteration whose measured
    /// per-link busy exceeds `planned × (1 + drift_band)` raises a
    /// [`FaultEvent::DriftAlarm`]. 0 disables monitoring.
    pub drift_band: f64,
    /// Also raise band-symmetric low-side alarms
    /// ([`FaultEvent::DriftAlarmLow`]) when measured busy falls under
    /// `planned × (1 − drift_band)` — the re-planner's
    /// over-conservative-plan signal. Off by default: the classic
    /// monitor is strictly one-sided, and every existing pin stays
    /// byte-identical.
    pub drift_low_side: bool,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            seed: 17,
            jitter_pct: 0.0,
            stragglers: Vec::new(),
            flaps: Vec::new(),
            membership: Vec::new(),
            drift_band: 0.0,
            drift_low_side: false,
        }
    }
}

impl FaultSpec {
    /// Named scenario presets, parameterized by the cluster size (the
    /// elastic scenarios shrink/restore relative to it). Used by
    /// `schedule_explorer --faults <name>` and the CI fault grid.
    pub fn preset(name: &str, workers: usize) -> Option<FaultSpec> {
        let spec = match name {
            "straggler" => FaultSpec {
                stragglers: vec![Straggler {
                    from_iter: 2,
                    factor: 1.5,
                    rank: 0,
                }],
                drift_band: 0.25,
                ..FaultSpec::default()
            },
            "flap" => FaultSpec {
                // Degrade the reference link 4× mid-run, recover later.
                flaps: vec![
                    Flap {
                        link: LinkId::REFERENCE,
                        at: Micros(15_000),
                        factor: 4.0,
                    },
                    Flap {
                        link: LinkId::REFERENCE,
                        at: Micros(400_000),
                        factor: 1.0,
                    },
                ],
                drift_band: 0.25,
                ..FaultSpec::default()
            },
            "elastic" => FaultSpec {
                membership: vec![
                    MembershipChange {
                        at_iter: 3,
                        workers: (workers - workers / 4).max(2),
                    },
                    MembershipChange {
                        at_iter: 8,
                        workers,
                    },
                ],
                drift_band: 0.25,
                ..FaultSpec::default()
            },
            "mixed" => FaultSpec {
                jitter_pct: 0.02,
                stragglers: vec![Straggler {
                    from_iter: 4,
                    factor: 1.3,
                    rank: 0,
                }],
                flaps: vec![
                    Flap {
                        link: LinkId::REFERENCE,
                        at: Micros(20_000),
                        factor: 2.5,
                    },
                    Flap {
                        link: LinkId::REFERENCE,
                        at: Micros(600_000),
                        factor: 1.0,
                    },
                ],
                membership: vec![MembershipChange {
                    at_iter: 6,
                    workers: (workers - workers / 4).max(2),
                }],
                drift_band: 0.25,
                ..FaultSpec::default()
            },
            _ => return None,
        };
        Some(spec)
    }

    /// Names [`FaultSpec::preset`] accepts.
    pub fn preset_names() -> &'static [&'static str] {
        &["straggler", "flap", "elastic", "mixed"]
    }

    /// No injected faults at all (drift monitoring may still be on).
    pub fn is_noop(&self) -> bool {
        self.jitter_pct == 0.0
            && self.stragglers.is_empty()
            && self.flaps.is_empty()
            && self.membership.is_empty()
    }

    /// Validate the spec against the environment it will run in.
    pub fn validate(&self, env: &ClusterEnv) -> Result<(), String> {
        if !(0.0..10.0).contains(&self.jitter_pct) {
            return Err(format!(
                "faults: jitter_pct {} must be in [0, 10)",
                self.jitter_pct
            ));
        }
        if !(0.0..10.0).contains(&self.drift_band) {
            return Err(format!(
                "faults: drift_band {} must be in [0, 10)",
                self.drift_band
            ));
        }
        for (i, s) in self.stragglers.iter().enumerate() {
            if !(s.factor >= 1.0 && s.factor.is_finite()) {
                return Err(format!(
                    "faults: stragglers[{i}] factor {} must be ≥ 1",
                    s.factor
                ));
            }
            if s.rank >= env.workers {
                return Err(format!(
                    "faults: stragglers[{i}] rank {} outside the {}-rank cluster",
                    s.rank, env.workers
                ));
            }
        }
        for (i, f) in self.flaps.iter().enumerate() {
            if !(f.factor > 0.0 && f.factor.is_finite()) {
                return Err(format!(
                    "faults: flaps[{i}] factor {} must be positive",
                    f.factor
                ));
            }
            if f.link.index() >= env.n_links() {
                return Err(format!(
                    "faults: flaps[{i}] link {} outside the {}-link registry",
                    f.link.index(),
                    env.n_links()
                ));
            }
        }
        for (i, m) in self.membership.iter().enumerate() {
            if m.workers < 2 {
                return Err(format!(
                    "faults: membership[{i}] workers {} must be ≥ 2",
                    m.workers
                ));
            }
        }
        Ok(())
    }

    /// Worst (largest) wire-time inflation the envelope declares for a
    /// link: the maximum over its flap ratios and every membership
    /// change's wire rescale, floored at 1. The static verifier uses
    /// this to warn when a window that fits its §III.D cap today would
    /// overrun under the declared envelope (`DEFT-W004`).
    pub fn worst_wire_inflation(&self, link: LinkId, env: &ClusterEnv) -> f64 {
        let mut worst = 1.0f64;
        for f in &self.flaps {
            if f.link == link && f.factor > worst {
                worst = f.factor;
            }
        }
        for m in &self.membership {
            let s = env.elastic_wire_scale(m.workers);
            if s > worst {
                worst = s;
            }
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse_and_validate() {
        let env = ClusterEnv::paper_testbed();
        for name in FaultSpec::preset_names() {
            let spec = FaultSpec::preset(name, env.workers).expect("known preset");
            spec.validate(&env).expect("preset validates");
            assert!(!spec.is_noop(), "preset {name} must inject something");
        }
        assert!(FaultSpec::preset("meteor-strike", 16).is_none());
        assert!(FaultSpec::default().is_noop());
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let env = ClusterEnv::paper_testbed();
        let bad = FaultSpec {
            stragglers: vec![Straggler {
                from_iter: 0,
                factor: 0.5,
                rank: 0,
            }],
            ..FaultSpec::default()
        };
        assert!(bad.validate(&env).is_err());
        let bad = FaultSpec {
            stragglers: vec![Straggler {
                from_iter: 0,
                factor: 1.5,
                rank: env.workers,
            }],
            ..FaultSpec::default()
        };
        assert!(bad.validate(&env).is_err(), "out-of-cluster rank must be rejected");
        let bad = FaultSpec {
            flaps: vec![Flap {
                link: LinkId(99),
                at: Micros(1),
                factor: 2.0,
            }],
            ..FaultSpec::default()
        };
        assert!(bad.validate(&env).is_err());
        let bad = FaultSpec {
            membership: vec![MembershipChange {
                at_iter: 1,
                workers: 1,
            }],
            ..FaultSpec::default()
        };
        assert!(bad.validate(&env).is_err());
        let bad = FaultSpec {
            jitter_pct: -0.1,
            ..FaultSpec::default()
        };
        assert!(bad.validate(&env).is_err());
    }

    #[test]
    fn worst_wire_inflation_covers_flaps_and_membership() {
        let env = ClusterEnv::paper_testbed();
        let spec = FaultSpec::preset("flap", env.workers).unwrap();
        assert!((spec.worst_wire_inflation(LinkId::REFERENCE, &env) - 4.0).abs() < 1e-12);
        // A link the envelope never touches keeps inflation 1.
        let other = LinkId(env.n_links() - 1);
        if other != LinkId::REFERENCE {
            assert!((spec.worst_wire_inflation(other, &env) - 1.0).abs() < 1e-12);
        }
        // Growing the cluster inflates wire times on every link.
        let grow = FaultSpec {
            membership: vec![MembershipChange {
                at_iter: 2,
                workers: env.workers * 4,
            }],
            ..FaultSpec::default()
        };
        assert!(grow.worst_wire_inflation(LinkId::REFERENCE, &env) > 1.0);
    }
}
