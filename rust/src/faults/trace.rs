//! Compilation of a [`FaultSpec`] into the flat, engine-agnostic trace
//! both DES engines replay.
//!
//! Everything here is a **pure function** of the spec and the
//! (profile, schedule, environment, iteration count) it is compiled
//! against: the jitter stream is drawn up front in a fixed order
//! (iteration → bucket → forward-then-backward), flaps are sorted and
//! clamped, and the drift monitor's planned busy is priced once with
//! the planner's own [`ClusterEnv::wire_time`] rule. The indexed and
//! scan engines therefore consume byte-identical inputs, which is what
//! makes bit-for-bit replay equality under faults possible at all.

use super::{to_ppm, FaultEvent, FaultSpec};
use crate::links::{ClusterEnv, LinkId};
use crate::models::BucketProfile;
use crate::sched::Schedule;
use crate::util::{Micros, Rng};

/// One materialized link flap, in engine-ready form: `ratio` is the
/// absolute wire-time multiplier (vs the healthy link) from `at` on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FlapAt {
    pub at: Micros,
    /// Link registry index.
    pub link: usize,
    /// Absolute wire-time ratio from `at` on (1.0 = healthy).
    pub ratio: f64,
    /// `ratio` in parts-per-million (what the fault log records).
    pub ratio_ppm: u64,
}

/// A fully materialized fault trace for one simulation run.
#[derive(Clone, Debug)]
pub struct FaultTrace {
    n_buckets: usize,
    n_links: usize,
    cycle_len: usize,
    /// Extra forward compute per `(iteration, bucket)`, flattened
    /// `iter * n_buckets + bucket` (jitter + straggler stretch).
    pub fwd_extra: Vec<Micros>,
    /// Extra backward compute per `(iteration, bucket)`.
    pub bwd_extra: Vec<Micros>,
    /// Link flaps sorted by `(at, link)`; ties keep spec order, so for
    /// two same-instant flaps on one link the later entry wins.
    pub flaps: Vec<FlapAt>,
    /// Per-iteration wire-time rescale from elastic membership (1.0
    /// when the configured cluster is intact).
    pub wire_scale: Vec<f64>,
    /// Planner-priced per-link busy of each cycle slot, flattened
    /// `slot * n_links + link` — the drift monitor's "planned" side.
    pub planned_cycle_busy: Vec<Micros>,
    /// Drift band in parts-per-million; 0 disables the monitor.
    pub drift_band_ppm: u64,
    /// Raise band-symmetric low-side alarms too (measured busy under
    /// `planned × (1 − band)`). Off by default for back-compat: the
    /// classic monitor is strictly one-sided.
    pub drift_low_side: bool,
    /// The scheduled fault events, pre-formatted for the fault log.
    pub scheduled: Vec<FaultEvent>,
}

impl FaultTrace {
    /// Compile `spec` against a concrete run.
    pub fn materialize(
        spec: &FaultSpec,
        iterations: usize,
        buckets: &[BucketProfile],
        schedule: &Schedule,
        env: &ClusterEnv,
    ) -> FaultTrace {
        let n = buckets.len();
        let n_links = env.n_links();
        let iters = iterations.max(1);

        // Compute stretch: one jitter draw per (iteration, bucket,
        // fwd/bwd) in fixed order, plus the persistent stragglers.
        let mut rng = Rng::new(spec.seed);
        let mut fwd_extra = vec![Micros::ZERO; iters * n];
        let mut bwd_extra = vec![Micros::ZERO; iters * n];
        let mut per_rank: Vec<(usize, f64)> = Vec::new();
        for t in 0..iters {
            // Slowest-rank rule: a straggler stretches only its own
            // rank's contribution, and data-parallel ranks run the same
            // buckets, so the window extends by the worst rank's total
            // excess — excesses on one rank sum, excesses on different
            // ranks do not (the non-straggling ranks finish earlier and
            // wait).
            per_rank.clear();
            for s in spec.stragglers.iter().filter(|s| t >= s.from_iter) {
                match per_rank.iter_mut().find(|(r, _)| *r == s.rank) {
                    Some((_, excess)) => *excess += s.factor - 1.0,
                    None => per_rank.push((s.rank, s.factor - 1.0)),
                }
            }
            let straggle = per_rank.iter().fold(0.0f64, |m, &(_, e)| m.max(e));
            for (b, bucket) in buckets.iter().enumerate() {
                let (jf, jb) = if spec.jitter_pct > 0.0 {
                    (
                        rng.range_f64(0.0, spec.jitter_pct),
                        rng.range_f64(0.0, spec.jitter_pct),
                    )
                } else {
                    (0.0, 0.0)
                };
                let ef = jf + straggle;
                if ef > 0.0 {
                    fwd_extra[t * n + b] = bucket.fwd.scale(ef);
                }
                let eb = jb + straggle;
                if eb > 0.0 {
                    bwd_extra[t * n + b] = bucket.bwd.scale(eb);
                }
            }
        }

        // Flaps: clamp to t ≥ 1 µs (time 0 would race the first
        // dispatch; a degradation meant "from the start" belongs in the
        // LinkSpec itself), sort by (at, link) keeping spec order on
        // ties so the later same-instant entry wins.
        let mut flaps: Vec<FlapAt> = spec
            .flaps
            .iter()
            .map(|f| FlapAt {
                at: f.at.max(Micros(1)),
                link: f.link.index(),
                ratio: f.factor,
                ratio_ppm: to_ppm(f.factor),
            })
            .collect();
        flaps.sort_by_key(|f| (f.at, f.link));

        // Elastic membership → per-iteration wire rescale.
        let mut membership = spec.membership.clone();
        membership.sort_by_key(|m| m.at_iter);
        let mut wire_scale = vec![1.0f64; iters];
        for (t, ws) in wire_scale.iter_mut().enumerate() {
            if let Some(m) = membership.iter().rev().find(|m| m.at_iter <= t) {
                *ws = env.elastic_wire_scale(m.workers);
            }
        }

        // Drift monitor: planner-priced busy per (cycle slot, link).
        let cycle_len = schedule.cycle.len().max(1);
        let mut planned_cycle_busy = vec![Micros::ZERO; cycle_len * n_links];
        for (ci, plan) in schedule.cycle.iter().enumerate() {
            for op in plan.all_ops() {
                if let Some(bucket) = buckets.get(op.bucket) {
                    if op.link.index() < n_links {
                        planned_cycle_busy[ci * n_links + op.link.index()] +=
                            env.wire_time(op.link, bucket.comm, bucket.params);
                    }
                }
            }
        }

        // Pre-format the scheduled events for the fault log.
        let mut scheduled = Vec::new();
        for s in &spec.stragglers {
            scheduled.push(FaultEvent::StragglerOnset {
                iter: s.from_iter,
                factor_ppm: to_ppm(s.factor),
            });
        }
        for f in &flaps {
            scheduled.push(FaultEvent::LinkFlap {
                link: LinkId(f.link),
                at: f.at,
                ratio_ppm: f.ratio_ppm,
            });
        }
        for m in &membership {
            scheduled.push(FaultEvent::Membership {
                iter: m.at_iter,
                workers: m.workers,
                wire_scale_ppm: to_ppm(env.elastic_wire_scale(m.workers)),
            });
        }

        FaultTrace {
            n_buckets: n,
            n_links,
            cycle_len,
            fwd_extra,
            bwd_extra,
            flaps,
            wire_scale,
            planned_cycle_busy,
            drift_band_ppm: to_ppm(spec.drift_band),
            drift_low_side: spec.drift_low_side,
            scheduled,
        }
    }

    pub fn n_buckets(&self) -> usize {
        self.n_buckets
    }

    pub fn n_links(&self) -> usize {
        self.n_links
    }

    /// Is the drift monitor armed? Engines only account per-iteration
    /// measured busy when it is.
    pub fn monitors_drift(&self) -> bool {
        self.drift_band_ppm > 0
    }

    /// Wire rescale of iteration `t` (membership changes past the last
    /// materialized iteration keep the final scale).
    pub fn wire_scale_at(&self, t: usize) -> f64 {
        self.wire_scale[t.min(self.wire_scale.len() - 1)]
    }

    /// Compare iteration `iter`'s measured per-link busy against the
    /// planned busy of its cycle slot (rescaled for declared
    /// membership), appending a [`FaultEvent::DriftAlarm`] per link
    /// whose measured busy exceeds `planned × (1 + band)`. One-sided by
    /// default: running *faster* than planned is never drift — unless
    /// [`FaultTrace::drift_low_side`] opts into the band-symmetric
    /// check, which appends a [`FaultEvent::DriftAlarmLow`] per link
    /// whose measured busy falls under `planned × (1 − band)` (the
    /// re-planner's signal that the plan is over-conservative). Integer
    /// arithmetic throughout so both engines log identical alarms.
    pub fn drift_check(&self, iter: usize, measured: &[Micros], log: &mut Vec<FaultEvent>) {
        if self.drift_band_ppm == 0 {
            return;
        }
        debug_assert_eq!(measured.len(), self.n_links);
        let slot = iter % self.cycle_len;
        let ws = self.wire_scale_at(iter);
        for (k, &m) in measured.iter().enumerate() {
            let mut planned = self.planned_cycle_busy[slot * self.n_links + k];
            if ws != 1.0 {
                planned = planned.scale(ws);
            }
            let lhs = m.as_us() as u128 * 1_000_000;
            let rhs = planned.as_us() as u128 * (1_000_000 + self.drift_band_ppm as u128);
            if lhs > rhs {
                let excess_ppm = if planned.is_zero() {
                    // No planned traffic at all: report a saturated
                    // 1000× excess rather than dividing by zero.
                    1_000_000_000
                } else {
                    let ratio_ppm = m.as_us() as u128 * 1_000_000 / planned.as_us() as u128;
                    (ratio_ppm.saturating_sub(1_000_000)).min(u64::MAX as u128) as u64
                };
                log.push(FaultEvent::DriftAlarm {
                    iter,
                    link: LinkId(k),
                    measured: m,
                    planned,
                    excess_ppm,
                });
            } else if self.drift_low_side && !planned.is_zero() {
                // Band-symmetric low side (strict, like the high side):
                // measured × 1e6 < planned × (1e6 − band). A band ≥ 1
                // makes the floor zero and the check vacuous.
                let floor = planned.as_us() as u128
                    * 1_000_000u128.saturating_sub(self.drift_band_ppm as u128);
                if lhs < floor {
                    let ratio_ppm = m.as_us() as u128 * 1_000_000 / planned.as_us() as u128;
                    let deficit_ppm = (1_000_000u128 - ratio_ppm.min(1_000_000)) as u64;
                    log.push(FaultEvent::DriftAlarmLow {
                        iter,
                        link: LinkId(k),
                        measured: m,
                        planned,
                        deficit_ppm,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::{Flap, MembershipChange, Straggler};

    fn bucket(id: usize, fwd: u64, bwd: u64, comm: u64) -> BucketProfile {
        BucketProfile {
            id,
            params: 1_000_000,
            fwd: Micros(fwd),
            bwd: Micros(bwd),
            comm: Micros(comm),
        }
    }

    fn tiny_schedule(n_buckets: usize) -> Schedule {
        use crate::sched::{CommOp, FwdDependency, IterPlan, Stage};
        let bwd_ops = (0..n_buckets)
            .map(|b| CommOp {
                bucket: b,
                link: LinkId::REFERENCE,
                stage: Stage::Backward,
                priority: b as i64,
                grad_age: 0,
                merged: 1,
                update_offset: 0,
            })
            .collect();
        Schedule {
            scheme: "test".into(),
            cycle: vec![IterPlan {
                fwd_ops: Vec::new(),
                bwd_ops,
                update_at_end: true,
            }],
            fwd_dependency: FwdDependency::Barrier,
            updates_per_cycle: 1,
            batch_multipliers: vec![1],
            warmup_iters: 0,
            max_outstanding_iters: 1,
            capacity_scale_bits: (1.0f64).to_bits(),
        }
    }

    #[test]
    fn materialize_is_deterministic() {
        let env = ClusterEnv::paper_testbed();
        let buckets = vec![bucket(0, 1_000, 2_000, 5_000), bucket(1, 1_500, 2_500, 6_000)];
        let schedule = tiny_schedule(2);
        let spec = FaultSpec {
            jitter_pct: 0.1,
            stragglers: vec![Straggler {
                from_iter: 3,
                factor: 1.4,
                rank: 0,
            }],
            drift_band: 0.2,
            ..FaultSpec::default()
        };
        let a = FaultTrace::materialize(&spec, 8, &buckets, &schedule, &env);
        let b = FaultTrace::materialize(&spec, 8, &buckets, &schedule, &env);
        assert_eq!(a.fwd_extra, b.fwd_extra);
        assert_eq!(a.bwd_extra, b.bwd_extra);
        assert_eq!(a.scheduled, b.scheduled);
        // Straggler stretch kicks in at its onset iteration.
        assert!(a.bwd_extra[3 * 2] >= Micros(2_000).scale(0.4));
        assert!(a.bwd_extra[0] < Micros(2_000).scale(0.4));
    }

    #[test]
    fn stragglers_on_distinct_ranks_take_the_max_not_the_sum() {
        let env = ClusterEnv::paper_testbed();
        let buckets = vec![bucket(0, 10_000, 10_000, 5_000)];
        let schedule = tiny_schedule(1);
        let mk = |rank_b: usize| FaultSpec {
            stragglers: vec![
                Straggler {
                    from_iter: 0,
                    factor: 1.5,
                    rank: 0,
                },
                Straggler {
                    from_iter: 0,
                    factor: 1.25,
                    rank: rank_b,
                },
            ],
            ..FaultSpec::default()
        };
        // Different ranks: the window follows the slowest rank (+50%).
        let tr = FaultTrace::materialize(&mk(1), 2, &buckets, &schedule, &env);
        assert_eq!(tr.fwd_extra[0], Micros(5_000));
        assert_eq!(tr.bwd_extra[0], Micros(5_000));
        // Same rank: the excesses compound additively (+75%).
        let tr = FaultTrace::materialize(&mk(0), 2, &buckets, &schedule, &env);
        assert_eq!(tr.fwd_extra[0], Micros(7_500));
        assert_eq!(tr.bwd_extra[0], Micros(7_500));
    }

    #[test]
    fn flaps_sort_and_clamp() {
        let env = ClusterEnv::paper_testbed();
        let buckets = vec![bucket(0, 1_000, 2_000, 5_000)];
        let schedule = tiny_schedule(1);
        let spec = FaultSpec {
            flaps: vec![
                Flap {
                    link: LinkId(1),
                    at: Micros(9_000),
                    factor: 2.0,
                },
                Flap {
                    link: LinkId::REFERENCE,
                    at: Micros(0),
                    factor: 3.0,
                },
            ],
            ..FaultSpec::default()
        };
        let tr = FaultTrace::materialize(&spec, 4, &buckets, &schedule, &env);
        assert_eq!(tr.flaps[0].at, Micros(1), "time-0 flap clamps to 1 µs");
        assert_eq!(tr.flaps[0].link, 0);
        assert_eq!(tr.flaps[1].at, Micros(9_000));
        assert_eq!(tr.scheduled.len(), 2);
    }

    #[test]
    fn membership_rescales_by_iteration() {
        let env = ClusterEnv::paper_testbed();
        let buckets = vec![bucket(0, 1_000, 2_000, 5_000)];
        let schedule = tiny_schedule(1);
        let spec = FaultSpec {
            membership: vec![MembershipChange {
                at_iter: 2,
                workers: 8,
            }],
            ..FaultSpec::default()
        };
        let tr = FaultTrace::materialize(&spec, 5, &buckets, &schedule, &env);
        assert!((tr.wire_scale[0] - 1.0).abs() < 1e-12);
        assert!((tr.wire_scale[1] - 1.0).abs() < 1e-12);
        let shrunk = env.elastic_wire_scale(8);
        assert!(shrunk < 1.0, "16 → 8 ranks shrinks the ring factor");
        assert!((tr.wire_scale[2] - shrunk).abs() < 1e-12);
        assert!((tr.wire_scale_at(99) - shrunk).abs() < 1e-12);
    }

    #[test]
    fn drift_check_is_one_sided_and_banded() {
        let env = ClusterEnv::paper_testbed();
        let buckets = vec![bucket(0, 1_000, 2_000, 5_000)];
        let schedule = tiny_schedule(1);
        let spec = FaultSpec {
            drift_band: 0.25,
            ..FaultSpec::default()
        };
        let tr = FaultTrace::materialize(&spec, 4, &buckets, &schedule, &env);
        assert!(tr.monitors_drift());
        let planned = tr.planned_cycle_busy[0];
        assert!(!planned.is_zero());
        let n = tr.n_links();
        let mut log = Vec::new();
        // At the band edge: no alarm (strict inequality).
        let mut measured = vec![Micros::ZERO; n];
        measured[0] = planned.scale(1.25);
        tr.drift_check(0, &measured, &mut log);
        // Slower than planned but inside the band: no alarm either.
        measured[0] = planned.scale(1.1);
        tr.drift_check(1, &measured, &mut log);
        // Faster than planned: never drift.
        measured[0] = planned.scale(0.5);
        tr.drift_check(2, &measured, &mut log);
        assert!(log.is_empty());
        // Past the band: one alarm with the right excess.
        measured[0] = planned.scale(1.5) + Micros(1);
        tr.drift_check(3, &measured, &mut log);
        assert_eq!(log.len(), 1);
        match log[0] {
            FaultEvent::DriftAlarm {
                iter,
                link,
                excess_ppm,
                ..
            } => {
                assert_eq!(iter, 3);
                assert_eq!(link, LinkId::REFERENCE);
                assert!(excess_ppm >= 500_000 - 2_000 && excess_ppm <= 500_000 + 2_000);
            }
            _ => panic!("expected a drift alarm"),
        }
    }

    #[test]
    fn low_side_alarms_are_opt_in_and_band_symmetric() {
        let env = ClusterEnv::paper_testbed();
        let buckets = vec![bucket(0, 1_000, 2_000, 5_000)];
        let schedule = tiny_schedule(1);
        let spec = FaultSpec {
            drift_band: 0.25,
            drift_low_side: true,
            ..FaultSpec::default()
        };
        let tr = FaultTrace::materialize(&spec, 4, &buckets, &schedule, &env);
        let planned = tr.planned_cycle_busy[0];
        assert!(!planned.is_zero());
        let n = tr.n_links();
        let mut log = Vec::new();
        // Inside the band (just above the 0.75 floor): no alarm.
        let mut measured = vec![Micros::ZERO; n];
        measured[0] = planned.scale(0.75) + Micros(1);
        tr.drift_check(0, &measured, &mut log);
        // Faster than planned but within the band: still no alarm.
        measured[0] = planned.scale(0.9);
        tr.drift_check(1, &measured, &mut log);
        assert!(log.is_empty());
        // Under the floor: one low-side alarm with the right deficit.
        measured[0] = planned.scale(0.5);
        tr.drift_check(2, &measured, &mut log);
        assert_eq!(log.len(), 1);
        match log[0] {
            FaultEvent::DriftAlarmLow {
                iter,
                link,
                deficit_ppm,
                ..
            } => {
                assert_eq!(iter, 2);
                assert_eq!(link, LinkId::REFERENCE);
                assert!(deficit_ppm >= 500_000 - 2_000 && deficit_ppm <= 500_000 + 2_000);
            }
            _ => panic!("expected a low-side drift alarm"),
        }
        // The same measurements under the default (one-sided) spec log
        // nothing at all — back-compat is field-gated.
        let one_sided = FaultSpec {
            drift_band: 0.25,
            ..FaultSpec::default()
        };
        let tr = FaultTrace::materialize(&one_sided, 4, &buckets, &schedule, &env);
        let mut log = Vec::new();
        measured[0] = planned.scale(0.5);
        tr.drift_check(2, &measured, &mut log);
        assert!(log.is_empty());
    }
}
