//! The fault log: every injected fault and every drift-gate decision a
//! run records on [`SimResult::fault_log`](crate::sim::SimResult).
//!
//! Events carry **integer-only** payloads (µs, ppm) so the log — and
//! therefore `SimResult` — stays `Eq` and bit-for-bit comparable across
//! engines and replays. Ratios are parts-per-million (`1_500_000` =
//! 1.5×).

use crate::links::LinkId;
use crate::util::Micros;

/// Convert a non-negative ratio to parts-per-million.
pub fn to_ppm(ratio: f64) -> u64 {
    debug_assert!(ratio >= 0.0, "negative ratio");
    (ratio * 1e6).round() as u64
}

/// One entry of a run's fault log.
///
/// Scheduled faults (straggler onsets, link flaps, membership changes)
/// are recorded up front by
/// [`FaultTrace::materialize`](crate::faults::FaultTrace::materialize);
/// `DriftAlarm`s are appended by the engines as iterations complete and
/// `GateDecision`s by the lifecycle's drift re-gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultEvent {
    /// A persistent compute straggler becomes active at `iter`.
    StragglerOnset { iter: usize, factor_ppm: u64 },
    /// A link's wire-time ratio changes to `ratio_ppm` (vs its healthy
    /// pricing) at sim time `at`.
    LinkFlap {
        link: LinkId,
        at: Micros,
        ratio_ppm: u64,
    },
    /// Cluster membership changes before `iter`: allreduce wire times
    /// rescale by `wire_scale_ppm` from this iteration on.
    Membership {
        iter: usize,
        workers: usize,
        wire_scale_ppm: u64,
    },
    /// Measured per-link busy of `iter` exceeded the planned busy by
    /// more than the configured drift band.
    DriftAlarm {
        iter: usize,
        link: LinkId,
        measured: Micros,
        planned: Micros,
        excess_ppm: u64,
    },
    /// Measured per-link busy of `iter` fell below the planned busy by
    /// more than the configured drift band — the plan was
    /// over-conservative on this link. Only raised when the spec opts
    /// into low-side monitoring
    /// ([`FaultSpec::drift_low_side`](crate::faults::FaultSpec)); it
    /// feeds the re-planner's capacity tightening, never the
    /// convergence gate.
    DriftAlarmLow {
        iter: usize,
        link: LinkId,
        measured: Micros,
        planned: Micros,
        deficit_ppm: u64,
    },
    /// The lifecycle re-ran the Preserver gate against the drifted
    /// topology (error = codec error compounded with measured drift).
    GateDecision {
        iter: usize,
        error_ppm: u64,
        accepted: bool,
    },
}

impl FaultEvent {
    /// Stable kind tag (the `"event"` field of [`FaultEvent::to_json`]).
    pub fn kind(&self) -> &'static str {
        match self {
            FaultEvent::StragglerOnset { .. } => "straggler_onset",
            FaultEvent::LinkFlap { .. } => "link_flap",
            FaultEvent::Membership { .. } => "membership",
            FaultEvent::DriftAlarm { .. } => "drift_alarm",
            FaultEvent::DriftAlarmLow { .. } => "drift_alarm_low",
            FaultEvent::GateDecision { .. } => "gate_decision",
        }
    }

    /// One JSON object (no trailing newline) for the JSON-lines fault
    /// log artifact.
    pub fn to_json(&self) -> String {
        match self {
            FaultEvent::StragglerOnset { iter, factor_ppm } => format!(
                "{{\"event\":\"straggler_onset\",\"iter\":{iter},\"factor_ppm\":{factor_ppm}}}"
            ),
            FaultEvent::LinkFlap { link, at, ratio_ppm } => format!(
                "{{\"event\":\"link_flap\",\"link\":{},\"at_us\":{},\"ratio_ppm\":{ratio_ppm}}}",
                link.index(),
                at.as_us()
            ),
            FaultEvent::Membership {
                iter,
                workers,
                wire_scale_ppm,
            } => format!(
                "{{\"event\":\"membership\",\"iter\":{iter},\"workers\":{workers},\
                 \"wire_scale_ppm\":{wire_scale_ppm}}}"
            ),
            FaultEvent::DriftAlarm {
                iter,
                link,
                measured,
                planned,
                excess_ppm,
            } => format!(
                "{{\"event\":\"drift_alarm\",\"iter\":{iter},\"link\":{},\"measured_us\":{},\
                 \"planned_us\":{},\"excess_ppm\":{excess_ppm}}}",
                link.index(),
                measured.as_us(),
                planned.as_us()
            ),
            FaultEvent::DriftAlarmLow {
                iter,
                link,
                measured,
                planned,
                deficit_ppm,
            } => format!(
                "{{\"event\":\"drift_alarm_low\",\"iter\":{iter},\"link\":{},\"measured_us\":{},\
                 \"planned_us\":{},\"deficit_ppm\":{deficit_ppm}}}",
                link.index(),
                measured.as_us(),
                planned.as_us()
            ),
            FaultEvent::GateDecision {
                iter,
                error_ppm,
                accepted,
            } => format!(
                "{{\"event\":\"gate_decision\",\"iter\":{iter},\"error_ppm\":{error_ppm},\
                 \"accepted\":{accepted}}}"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_shapes_are_stable() {
        let e = FaultEvent::LinkFlap {
            link: LinkId(1),
            at: Micros(40_000),
            ratio_ppm: 3_000_000,
        };
        assert_eq!(
            e.to_json(),
            "{\"event\":\"link_flap\",\"link\":1,\"at_us\":40000,\"ratio_ppm\":3000000}"
        );
        assert_eq!(e.kind(), "link_flap");
        let g = FaultEvent::GateDecision {
            iter: 5,
            error_ppm: 230_000,
            accepted: false,
        };
        assert!(g.to_json().contains("\"accepted\":false"));
        let lo = FaultEvent::DriftAlarmLow {
            iter: 6,
            link: LinkId(0),
            measured: Micros(500),
            planned: Micros(1_000),
            deficit_ppm: 500_000,
        };
        assert_eq!(
            lo.to_json(),
            "{\"event\":\"drift_alarm_low\",\"iter\":6,\"link\":0,\"measured_us\":500,\
             \"planned_us\":1000,\"deficit_ppm\":500000}"
        );
        assert_eq!(lo.kind(), "drift_alarm_low");
    }

    #[test]
    fn ppm_rounds_to_nearest() {
        assert_eq!(to_ppm(1.0), 1_000_000);
        assert_eq!(to_ppm(1.5), 1_500_000);
        assert_eq!(to_ppm(0.977_777_9), 977_778);
    }
}
