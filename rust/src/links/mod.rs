//! Communication-link cost models (paper §III.C, Table IV, Fig. 6) —
//! generalized to an **N-link heterogeneous topology registry**.
//!
//! The paper runs two collective libraries concurrently: **NCCL** on one
//! NIC and **gloo** on a second ("heterogeneous multi-link"). Earlier
//! revisions hard-coded that pair as a two-variant enum; this module now
//! models a cluster as an ordered registry of [`LinkSpec`]s owned by
//! [`ClusterEnv`], addressed by [`LinkId`] (a plain index newtype). Each
//! link carries a name, a startup latency α, a wire bandwidth, a slowdown
//! factor μ relative to the *reference link* (index 0, μ = 1), a
//! **contention group** (links in the same group share a NIC — the
//! paper's Table IV single-NIC degradation becomes the general rule
//! "every link but the fastest of a shared group pays the contention
//! penalty"), and a CPU-staging ramp for transports that degrade
//! superlinearly on very large tensors.
//!
//! The transports themselves are replaced by calibrated ring-allreduce
//! α–β cost models — the scheduler only ever consumes *times*, so a model
//! fit to the paper's own Table IV measurements preserves every
//! scheduling decision (see DESIGN.md §Substitutions).
//!
//! Model: `T(p) = α + μ · p · 4 B · 2(W−1)/W / (η · BW)` for `p` f32
//! parameters over `W` workers at reference wire bandwidth `BW`, with
//! reference link efficiency `η`. The paper's gloo is `μ ≈ 1.65×` slower
//! than NCCL (Fig. 6); in **single-NIC** mode concurrent large transfers
//! contend and the slower link degrades ~20% further (Table IV).
//!
//! Built-in presets ([`LinkPreset`]):
//!
//! * `paper-2link`   — NCCL + gloo on distinct NICs; bit-for-bit the
//!   numbers of the pre-registry enum (see `tests/link_parity.rs`).
//! * `single-nic`    — the same pair sharing one NIC (Table IV rows).
//! * `nvlink-ib-tcp` — a 3-link profile (intra-node NVLink-class link,
//!   InfiniBand, TCP fallback) that the old enum could never express.
//!
//! ## Rank-level topology
//!
//! Real clusters are hierarchical: ranks on one node talk over an
//! NVLink-class segment while cross-node traffic rides a fabric. A
//! [`Topology`] maps rank pairs onto segments: with `ranks_per_node = n`
//! ranks per node, node-local pairs use the designated `intra` registry
//! link and cross-node pairs the transfer's fabric link. A collective
//! launched on fabric `l` then decomposes into a hierarchical allreduce
//! (node-local reduce-scatter → cross-node shard allreduce → node-local
//! allgather) whose per-segment α–β terms compose into one bucket time:
//! the intra leg moves `2(n−1)/n · p` bytes on `intra`, the inter leg
//! `2(M−1)/M · p/n` bytes on `l` (`M` nodes). The traffic fractions sum
//! to exactly the flat ring factor, so [`Topology::Flat`] — and the
//! degenerate `ranks_per_node = 1` — reproduce the flat registry pricing
//! bit-for-bit (see `tests/topology_parity.rs`).
//!
//! ## Per-link gradient compression codecs
//!
//! Every link can carry a [`Codec`] (default [`Codec::Raw`]): slow links
//! (the `tcp` preset link, hierarchical `inter` fabrics) trade gradient
//! precision for coverage. A codec contributes three terms:
//!
//! * a **bytes-on-wire ratio** ([`Codec::wire_ratio`]) scaling every wire
//!   time and the codec-effective μ ([`ClusterEnv::path_mu`] multiplies
//!   each leg's μ by its link's ratio, so knapsack capacities and the
//!   §III.D partition constraint see the compressed per-byte cost);
//! * an **encode/decode compute overhead** ([`Codec::encode_overhead`],
//!   µs per MB of raw gradient) charged on the compute stream by the DES
//!   engine — *not* folded into [`ClusterEnv::wire_time`], which prices
//!   link occupancy only (calibrating the overlap cost of encode kernels
//!   is an open ROADMAP sub-item);
//! * a **relative gradient error** ([`Codec::error`]) injected into the
//!   Preserver's Gaussian walk
//!   ([`crate::preserver::WalkParams::with_gradient_error`]) so
//!   `quantify`/`acceptable` gate whether a schedule may route a bucket
//!   over a lossy link at all (the lifecycle falls back to raw links on
//!   rejection).
//!
//! `Codec::Raw` is the identity on all three terms, so a registry without
//! codecs prices **bit-for-bit** as before (`tests/codec_parity.rs`).
//!
//! ## Contention: pairwise vs aggregate k-way sharing
//!
//! Links in one contention group share a NIC. Two models price that
//! sharing, selectable per environment via [`ContentionModel`]
//! (TOML `[contention] model = "pairwise" | "kway"`, explorer
//! `--contention-model`):
//!
//! * **Pairwise** — the legacy Table IV rule: a paying transfer that
//!   overlaps *any* in-flight group-mate degrades by the fixed pairwise
//!   penalty ([`ClusterEnv::contention_penalty`]), no matter how many
//!   mates are in flight. Cheap, and exact for the paper's two-link
//!   testbed, but it underprices three-plus concurrent transfers.
//! * **K-way** (the default) — aggregate bandwidth sharing: with `k`
//!   group members concurrently in flight, every paying member is slowed
//!   by [`ClusterEnv::contention_factor`]`(k, params)`. The curve is the
//!   capacity story behind Table IV: the measured single-NIC pair serves
//!   the exempt (fastest) member at full rate plus one payer at
//!   `1/(1+penalty)` of its uncontended rate, so the NIC's calibrated
//!   spare capacity beyond the exempt member is exactly `1/(1+penalty)`
//!   of one transfer — and `k−1` payers split it evenly:
//!
//!   ```text
//!   contention_factor(1, p) = 1                       (uncontended)
//!   contention_factor(k, p) = (k−1) · (1 + penalty(p))  for k ≥ 2
//!   ```
//!
//!   At `k = 2` this is **bit-for-bit** the pairwise penalty (so the
//!   Table IV single-NIC rows are reproduced unchanged — see
//!   `tests/contention_model.rs`) and it is monotone in `k`. Throughput
//!   caps: with the exempt member among the `k` in-flight transfers, the
//!   paying cohort's aggregate `(k−1)/factor = 1/(1+penalty)` never
//!   exceeds one uncontended transfer's bandwidth share; and in **every**
//!   composition — exempt riding along or idle — the group's aggregate
//!   stays within the NIC's calibrated capacity `1 + 1/(1+penalty)`
//!   (payers-only concurrency: `k/factor(k) ≤ 2/(1+penalty) ≤` capacity).
//!
//! Either model is applied at two distinct layers:
//!
//! * **Planning estimate** ([`ClusterEnv::wire_time`], `bucket_comm`,
//!   `allreduce_us`, and the schedulers' knapsack capacities via
//!   [`ClusterEnv::link_planning_mus`]): the conservative static rule —
//!   every link except its group's fastest member pays the full
//!   contention factor whenever group-mates merely *exist* (pairwise:
//!   factor at `k = 2`; k-way: factor at `k =` group size, i.e. all
//!   members presumed concurrently active).
//! * **Execution model** (the DES engine, via
//!   [`ClusterEnv::wire_time_uncontended`] + per-link flight tracking):
//!   contention is charged only while same-group transfers actually
//!   overlap — pairwise as a one-shot penalty on the overlap window,
//!   k-way as a piecewise re-pricing at every dispatch/finalize event
//!   (see `sim::engine` docs). An idle group-mate costs nothing in
//!   either model.

use crate::util::Micros;

/// Index of a link in a [`ClusterEnv`]'s registry.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LinkId(pub usize);

impl LinkId {
    /// The reference link: μ = 1, and all bucket communication times are
    /// priced in its time units.
    pub const REFERENCE: LinkId = LinkId(0);

    pub fn index(self) -> usize {
        self.0
    }
}

/// Gradient compression codec attached to a link (module docs,
/// "Per-link gradient compression codecs").
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Codec {
    /// Uncompressed f32 gradients — the identity codec every link
    /// defaults to. Zero overhead, zero error, ratio 1.
    #[default]
    Raw,
    /// Half-precision cast: half the bytes on the wire, a cheap cast
    /// kernel, and a rounding error far below the Preserver's ε band.
    Fp16,
    /// PowerSGD-style low-rank factorization (Vogels et al.): a gradient
    /// matrix ships as two rank-`k` factors. Calibrated at a reference
    /// factor dimension [`RANKK_REF_DIM`]; higher rank means more bytes,
    /// more encode work, and less truncation error. `k` must be ≥ 1 —
    /// [`Codec::parse`] rejects `rank0` and the `with_codec` builders
    /// assert it (a rank-0 codec would zero the wire and blow up the
    /// error term).
    RankK { k: u32 },
}

/// Reference gradient-matrix factor dimension for [`Codec::RankK`]: a
/// rank-`k` factorization of an n×n matrix ships `2kn` of `n²` entries,
/// so the wire ratio is `2k / n` at `n = RANKK_REF_DIM`.
pub const RANKK_REF_DIM: f64 = 1024.0;

/// fp16 cast cost on the compute stream, µs per MB of raw gradient.
pub const FP16_ENCODE_US_PER_MB: f64 = 2.0;

/// Rank-k encode cost, µs per MB: a fixed orthogonalization part plus a
/// per-rank GEMM part (cost grows with the factor width).
pub const RANKK_ENCODE_BASE_US_PER_MB: f64 = 24.0;
pub const RANKK_ENCODE_US_PER_MB_PER_RANK: f64 = 6.0;

/// fp16 relative gradient error (rounding): negligible next to the
/// Preserver's default ε band.
pub const FP16_ERROR: f64 = 1e-3;

/// Rank-k truncation error at rank 1; decays as `1/√k`.
pub const RANKK_ERROR_BASE: f64 = 0.5;

impl Codec {
    /// Parse a codec name: `raw`, `fp16`, or `rank<k>` (e.g. `rank4`).
    /// The rank suffix must be canonical decimal digits — `rank+4`,
    /// `rank007`, and `rank0` are rejected, so `parse` and [`Codec::name`]
    /// round-trip exactly.
    pub fn parse(s: &str) -> Option<Codec> {
        match s {
            "raw" | "f32" | "none" => Some(Codec::Raw),
            "fp16" | "f16" | "half" => Some(Codec::Fp16),
            other => {
                let digits = other.strip_prefix("rank")?;
                let canonical = !digits.is_empty()
                    && digits.bytes().all(|b| b.is_ascii_digit())
                    && !digits.starts_with('0');
                if !canonical {
                    return None;
                }
                let k = digits.parse::<u32>().ok()?;
                Some(Codec::RankK { k })
            }
        }
    }

    pub fn name(self) -> String {
        match self {
            Codec::Raw => "raw".into(),
            Codec::Fp16 => "fp16".into(),
            Codec::RankK { k } => format!("rank{k}"),
        }
    }

    /// Bytes-on-wire ratio relative to raw f32 (1.0 for [`Codec::Raw`],
    /// monotone non-decreasing in `k` for [`Codec::RankK`], never > 1).
    pub fn wire_ratio(self) -> f64 {
        match self {
            Codec::Raw => 1.0,
            Codec::Fp16 => 0.5,
            Codec::RankK { k } => (2.0 * k as f64 / RANKK_REF_DIM).min(1.0),
        }
    }

    /// Encode + decode compute overhead for a transfer of `params` f32
    /// parameters, charged on the compute stream by the DES engine.
    pub fn encode_overhead(self, params: u64) -> Micros {
        let per_mb = match self {
            Codec::Raw => return Micros::ZERO,
            Codec::Fp16 => FP16_ENCODE_US_PER_MB,
            Codec::RankK { k } => {
                RANKK_ENCODE_BASE_US_PER_MB + RANKK_ENCODE_US_PER_MB_PER_RANK * k as f64
            }
        };
        let mb = params as f64 * 4.0 / 1e6;
        Micros::from_us_f64(mb * per_mb)
    }

    /// Relative gradient error fed to the Preserver's Gaussian walk
    /// ([`crate::preserver::WalkParams::with_gradient_error`]).
    pub fn error(self) -> f64 {
        match self {
            Codec::Raw => 0.0,
            Codec::Fp16 => FP16_ERROR,
            Codec::RankK { k } => RANKK_ERROR_BASE / (k as f64).sqrt(),
        }
    }

    /// Does this codec lose information at all (error > 0)?
    pub fn is_lossy(self) -> bool {
        self.error() > 0.0
    }

    /// Panic on the degenerate `RankK { k: 0 }` (zero wire bytes,
    /// infinite error) — called by the `with_codec` builders so the
    /// invariant [`Codec::parse`] enforces holds for programmatic
    /// construction too.
    fn assert_valid(self) {
        if let Codec::RankK { k } = self {
            assert!(k >= 1, "RankK codec needs k >= 1");
        }
    }
}

/// One communication link of the cluster.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkSpec {
    /// Human-readable transport name ("nccl", "gloo", "ib", …).
    pub name: String,
    /// Slowdown factor relative to the reference link (reference: 1.0).
    /// Authoritative for all pricing; presets keep it consistent with
    /// `bandwidth_gbps`.
    pub mu: f64,
    /// Fixed startup latency per collective.
    pub alpha: Micros,
    /// Wire bandwidth in Gbps (informational / config round-trip; μ is
    /// what the schedulers and the simulator consume).
    pub bandwidth_gbps: f64,
    /// Links in the same contention group share a NIC: every link except
    /// the group's fastest pays [`ClusterEnv::contention_factor`] on
    /// large tensors (pairwise penalty at k = 2, aggregate k-way split
    /// beyond — see the module docs).
    pub contention_group: usize,
    /// CPU-staged transports degrade superlinearly on very large tensors
    /// (Table IV: the NCCL:gloo ratio climbs from ~1.65 to ~1.85 at 67M
    /// params). Ramp coefficient applied beyond `STAGING_KNEE` params;
    /// 0.0 disables the ramp.
    pub staging_ramp: f64,
    /// Gradient compression codec applied to the bytes this link
    /// carries — its leg of every segment path, so under a hierarchical
    /// topology a coded intra link compresses the node-local leg of
    /// transfers homed elsewhere too (default [`Codec::Raw`] — no
    /// compression).
    pub codec: Codec,
}

impl LinkSpec {
    /// A link with the given name and μ; other fields get neutral
    /// defaults: α = 300 µs, 40 Gbps, no staging ramp, and contention
    /// group **0**. Note the group default means links built only from
    /// `new()` share one NIC — call [`LinkSpec::with_group`] to place
    /// links on separate NICs (as every preset does).
    pub fn new(name: &str, mu: f64) -> LinkSpec {
        assert!(mu > 0.0, "link μ must be positive");
        LinkSpec {
            name: name.to_string(),
            mu,
            alpha: Micros(300),
            bandwidth_gbps: 40.0,
            contention_group: 0,
            staging_ramp: 0.0,
            codec: Codec::Raw,
        }
    }

    pub fn with_alpha(mut self, alpha: Micros) -> LinkSpec {
        self.alpha = alpha;
        self
    }

    pub fn with_bandwidth(mut self, gbps: f64) -> LinkSpec {
        self.bandwidth_gbps = gbps;
        self
    }

    pub fn with_group(mut self, group: usize) -> LinkSpec {
        self.contention_group = group;
        self
    }

    pub fn with_staging_ramp(mut self, ramp: f64) -> LinkSpec {
        self.staging_ramp = ramp;
        self
    }

    pub fn with_codec(mut self, codec: Codec) -> LinkSpec {
        codec.assert_valid();
        self.codec = codec;
        self
    }
}

/// Built-in link-topology presets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkPreset {
    /// Paper testbed: NCCL + gloo on two NICs (no contention).
    Paper2Link,
    /// NCCL + gloo sharing one NIC (Table IV "single-link" rows).
    SingleNic,
    /// Three heterogeneous links: an NVLink-class intra-node link at the
    /// reference speed, InfiniBand at μ = 2.5, and a TCP fallback at
    /// μ = 6 with CPU staging — a modern shape the old two-variant enum
    /// could not express.
    NvlinkIbTcp,
}

impl LinkPreset {
    pub const ALL: [LinkPreset; 3] = [
        LinkPreset::Paper2Link,
        LinkPreset::SingleNic,
        LinkPreset::NvlinkIbTcp,
    ];

    pub fn parse(s: &str) -> Option<LinkPreset> {
        match s {
            "paper-2link" | "paper2link" | "paper" => Some(LinkPreset::Paper2Link),
            "single-nic" | "single_nic" | "single" => Some(LinkPreset::SingleNic),
            "nvlink-ib-tcp" | "nvlink_ib_tcp" | "3link" => Some(LinkPreset::NvlinkIbTcp),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LinkPreset::Paper2Link => "paper-2link",
            LinkPreset::SingleNic => "single-nic",
            LinkPreset::NvlinkIbTcp => "nvlink-ib-tcp",
        }
    }

    /// The preset's link registry.
    pub fn links(self) -> Vec<LinkSpec> {
        match self {
            LinkPreset::Paper2Link => vec![
                LinkSpec {
                    name: "nccl".into(),
                    mu: 1.0,
                    alpha: Micros(300),
                    bandwidth_gbps: 40.0,
                    contention_group: 0,
                    staging_ramp: 0.0,
                    codec: Codec::Raw,
                },
                LinkSpec {
                    name: "gloo".into(),
                    mu: PAPER_MU,
                    alpha: Micros(900),
                    bandwidth_gbps: 40.0,
                    contention_group: 1,
                    staging_ramp: 0.12,
                    codec: Codec::Raw,
                },
            ],
            LinkPreset::SingleNic => {
                let mut links = LinkPreset::Paper2Link.links();
                for l in &mut links {
                    l.contention_group = 0;
                }
                links
            }
            LinkPreset::NvlinkIbTcp => vec![
                LinkSpec {
                    name: "nvlink".into(),
                    mu: 1.0,
                    alpha: Micros(150),
                    bandwidth_gbps: 40.0,
                    contention_group: 0,
                    staging_ramp: 0.0,
                    codec: Codec::Raw,
                },
                LinkSpec {
                    name: "ib".into(),
                    mu: 2.5,
                    alpha: Micros(600),
                    bandwidth_gbps: 16.0,
                    contention_group: 1,
                    staging_ramp: 0.0,
                    codec: Codec::Raw,
                },
                LinkSpec {
                    name: "tcp".into(),
                    mu: 6.0,
                    alpha: Micros(1500),
                    bandwidth_gbps: 6.7,
                    contention_group: 2,
                    staging_ramp: 0.12,
                    codec: Codec::Raw,
                },
            ],
        }
    }

    /// The paper testbed environment with this preset's links.
    pub fn env(self) -> ClusterEnv {
        let mut env = ClusterEnv::paper_testbed();
        env.links = self.links();
        env
    }
}

/// Precomputed [`ClusterEnv::contention_factor`] staircase for one
/// transfer size (see [`ClusterEnv::contention_staircase`]): index `k` is
/// the group's in-flight concurrency.
#[derive(Clone, Debug, PartialEq)]
pub struct ContentionStaircase {
    factors: Vec<f64>,
}

impl ContentionStaircase {
    /// The degradation factor at concurrency `k`. Panics beyond the
    /// `max_k` the staircase was built for — the engine builds it for the
    /// registry size, which bounds any group's concurrency.
    #[inline]
    pub fn factor(&self, k: usize) -> f64 {
        self.factors[k]
    }

    /// Largest concurrency this staircase covers.
    pub fn max_k(&self) -> usize {
        self.factors.len() - 1
    }
}

/// How concurrent same-group (shared-NIC) transfers are priced — see the
/// module docs, "Contention: pairwise vs aggregate k-way sharing".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ContentionModel {
    /// Legacy Table IV rule: any overlap costs the fixed pairwise
    /// penalty, regardless of how many group-mates are in flight.
    Pairwise,
    /// Aggregate k-way bandwidth sharing: `k` concurrent group members
    /// slow every paying member by [`ClusterEnv::contention_factor`],
    /// re-priced piecewise as membership changes (the default).
    #[default]
    Kway,
}

impl ContentionModel {
    pub const ALL: [ContentionModel; 2] = [ContentionModel::Pairwise, ContentionModel::Kway];

    pub fn parse(s: &str) -> Option<ContentionModel> {
        match s {
            "pairwise" => Some(ContentionModel::Pairwise),
            "kway" | "k-way" => Some(ContentionModel::Kway),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ContentionModel::Pairwise => "pairwise",
            ContentionModel::Kway => "kway",
        }
    }
}

/// How the cluster's ranks map onto nodes, i.e. which registry link
/// serves each rank pair (see the module docs, "Rank-level topology").
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Topology {
    /// Every link is a flat ring over all workers — the single-segment
    /// model all earlier revisions used, and the pricing unit
    /// (`BucketProfile::comm` is flat-reference-ring time).
    #[default]
    Flat,
    /// `ranks_per_node` ranks share a node (must divide the worker
    /// count). Node-local segments run on the `intra` registry link; the
    /// cross-node shard allreduce runs on the transfer's own link — or on
    /// `inter` for transfers scheduled on the intra link itself.
    Hierarchical {
        ranks_per_node: usize,
        intra: LinkId,
        inter: LinkId,
    },
}

impl Topology {
    /// Hierarchical topology constructor (`intra` ≠ `inter`).
    pub fn hierarchical(ranks_per_node: usize, intra: LinkId, inter: LinkId) -> Topology {
        assert!(ranks_per_node >= 1, "ranks_per_node must be ≥ 1");
        assert!(intra != inter, "intra and inter segments need distinct links");
        Topology::Hierarchical {
            ranks_per_node,
            intra,
            inter,
        }
    }

    /// Ranks per node: 1 for flat topologies.
    pub fn ranks_per_node(&self) -> usize {
        match self {
            Topology::Flat => 1,
            Topology::Hierarchical { ranks_per_node, .. } => *ranks_per_node,
        }
    }
}

/// One leg of a collective's segment path: the link that carries it, the
/// fraction of the flat all-worker ring traffic it moves, and the tensor
/// fraction each of its transfers sees (for the staging ramp).
#[derive(Clone, Copy, Debug)]
struct SegmentLeg {
    link: LinkId,
    traffic: f64,
    tensor_frac: f64,
}

/// Ring-allreduce traffic factor 2(k−1)/k for `k` participants.
fn ring_factor_of(k: usize) -> f64 {
    if k <= 1 {
        0.0
    } else {
        2.0 * (k as f64 - 1.0) / k as f64
    }
}

/// The cluster communication environment: worker count, reference NIC
/// bandwidth/efficiency, the link registry, and the rank-level topology.
#[derive(Clone, Debug)]
pub struct ClusterEnv {
    /// Number of data-parallel workers (GPUs).
    pub workers: usize,
    /// Reference NIC wire bandwidth in Gbps (paper testbed: 40).
    pub bandwidth_gbps: f64,
    /// Reference link efficiency η at the microbenchmark scale (fit to
    /// Table IV: β ≈ 3.2 ns/param at 16 GPUs / 40 Gbps ⇒ η ≈ 0.469).
    pub efficiency: f64,
    /// The link registry; index = [`LinkId`]. Link 0 is the reference
    /// link (μ = 1) that bucket comm times are priced on.
    pub links: Vec<LinkSpec>,
    /// Rank-pair → segment mapping (default: flat).
    pub topology: Topology,
    /// How shared-NIC contention is priced (default: aggregate k-way).
    pub contention: ContentionModel,
}

/// Speed ratio between the paper's NCCL and gloo (1.59–1.69, set 1.65).
pub const PAPER_MU: f64 = 1.65;

/// Plateau of the Table IV shared-NIC degradation ramp (+21% for paying
/// transfers ≥ 8.4M params) — the pairwise calibration point of
/// [`ClusterEnv::contention_factor`].
pub const CONTENTION_PEAK: f64 = 0.21;

/// Params beyond which CPU-staged transports start their degradation ramp.
const STAGING_KNEE: f64 = 33.6e6;

impl Default for ClusterEnv {
    fn default() -> Self {
        ClusterEnv::paper_testbed()
    }
}

impl ClusterEnv {
    /// The paper's testbed: 2 nodes × 8 A100, 40 Gbps Ethernet, 2 NICs,
    /// NCCL + gloo (the `paper-2link` preset).
    pub fn paper_testbed() -> ClusterEnv {
        ClusterEnv {
            workers: 16,
            bandwidth_gbps: 40.0,
            efficiency: 0.469,
            links: LinkPreset::Paper2Link.links(),
            topology: Topology::Flat,
            contention: ContentionModel::default(),
        }
    }

    /// Select how shared-NIC contention is priced (planning estimate and
    /// DES execution alike).
    pub fn with_contention_model(mut self, model: ContentionModel) -> ClusterEnv {
        self.contention = model;
        self
    }

    pub fn with_workers(mut self, workers: usize) -> ClusterEnv {
        assert!(workers >= 1);
        self.workers = workers;
        self
    }

    /// Replace the rank-level topology. Hierarchical topologies must
    /// reference registered links and a node size dividing the worker
    /// count.
    pub fn with_topology(mut self, topology: Topology) -> ClusterEnv {
        if let Topology::Hierarchical {
            ranks_per_node,
            intra,
            inter,
        } = &topology
        {
            assert!(*ranks_per_node >= 1, "ranks_per_node must be ≥ 1");
            assert!(
                self.workers % *ranks_per_node == 0,
                "ranks_per_node {} must divide the worker count {}",
                ranks_per_node,
                self.workers
            );
            assert!(
                intra.index() < self.links.len() && inter.index() < self.links.len(),
                "topology references an unregistered link"
            );
            assert!(intra != inter, "intra and inter segments need distinct links");
        }
        self.topology = topology;
        self
    }

    /// Number of nodes under the current topology (flat: one rank per
    /// conceptual node).
    pub fn nodes(&self) -> usize {
        self.workers / self.topology.ranks_per_node().max(1)
    }

    pub fn with_bandwidth(mut self, gbps: f64) -> ClusterEnv {
        assert!(gbps > 0.0);
        self.bandwidth_gbps = gbps;
        self
    }

    /// Replace the link registry.
    pub fn with_links(mut self, links: Vec<LinkSpec>) -> ClusterEnv {
        assert!(!links.is_empty(), "a cluster needs at least one link");
        self.links = links;
        self
    }

    /// Collapse every link onto one NIC (all contention groups shared) —
    /// the Table IV "single-link" configuration.
    pub fn with_single_link(mut self) -> ClusterEnv {
        for l in &mut self.links {
            l.contention_group = 0;
        }
        self
    }

    /// Attach a compression codec to one registered link.
    pub fn with_codec(mut self, link: LinkId, codec: Codec) -> ClusterEnv {
        assert!(
            link.index() < self.links.len(),
            "codec targets an unregistered link {link:?}"
        );
        codec.assert_valid();
        self.links[link.0].codec = codec;
        self
    }

    /// Strip every codec back to [`Codec::Raw`] — the lifecycle's
    /// fallback registry when the Preserver rejects a lossy route.
    pub fn with_raw_codecs(mut self) -> ClusterEnv {
        for l in &mut self.links {
            l.codec = Codec::Raw;
        }
        self
    }

    /// Does any registered link carry a lossy codec?
    pub fn has_lossy_codec(&self) -> bool {
        self.links.iter().any(|l| l.codec.is_lossy())
    }

    /// Per-link codec names in registry order (metric/CSV labels).
    pub fn link_codec_names(&self) -> Vec<String> {
        self.links.iter().map(|l| l.codec.name()).collect()
    }

    /// Per-link codec gradient errors in registry order (each link's own
    /// codec, ignoring topology).
    pub fn link_codec_errors(&self) -> Vec<f64> {
        self.links.iter().map(|l| l.codec.error()).collect()
    }

    /// Codec gradient error of the full **segment path** of a transfer
    /// homed on `link`: the worst codec error among the legs it rides
    /// (flat topologies: the link's own codec error). This is what the
    /// Preserver gate must consume — under a hierarchical topology a
    /// lossy codec on the shared intra link corrupts every transfer's
    /// node-local leg, even for transfers homed elsewhere.
    pub fn path_codec_error(&self, link: LinkId) -> f64 {
        self.segment_path(link)
            .iter()
            .map(|leg| self.spec(leg.link).codec.error())
            .fold(0.0, f64::max)
    }

    /// Per-link segment-path codec errors in registry order — what
    /// [`crate::sched::DeftOptions::link_errors`] and the lifecycle gate
    /// consume.
    pub fn link_path_codec_errors(&self) -> Vec<f64> {
        self.link_ids().map(|id| self.path_codec_error(id)).collect()
    }

    /// Encode/decode compute overhead of a transfer of `params` f32
    /// parameters homed on `link`: each segment leg's codec charges for
    /// the tensor fraction that leg actually ships (flat topologies: the
    /// home codec on the full tensor; a hierarchical fabric leg encodes
    /// only its `p/n` shard). Zero when every leg is raw.
    pub fn encode_overhead_us(&self, link: LinkId, params: u64) -> Micros {
        self.segment_path(link)
            .iter()
            .map(|leg| {
                let leg_params = (params as f64 * leg.tensor_frac) as u64;
                self.spec(leg.link).codec.encode_overhead(leg_params)
            })
            .sum()
    }

    /// Number of links in the registry.
    pub fn n_links(&self) -> usize {
        self.links.len()
    }

    /// All link ids, in registry order.
    pub fn link_ids(&self) -> impl Iterator<Item = LinkId> {
        (0..self.links.len()).map(LinkId)
    }

    /// The spec of one link.
    pub fn spec(&self, id: LinkId) -> &LinkSpec {
        &self.links[id.0]
    }

    /// Look a link up by name.
    pub fn link(&self, name: &str) -> Option<LinkId> {
        self.links.iter().position(|l| l.name == name).map(LinkId)
    }

    /// Link names in registry order.
    pub fn link_names(&self) -> Vec<String> {
        self.links.iter().map(|l| l.name.clone()).collect()
    }

    /// Per-link slowdown factors μ in registry order.
    pub fn link_mus(&self) -> Vec<f64> {
        self.links.iter().map(|l| l.mu).collect()
    }

    /// The slowest **segment path** in the registry: the largest
    /// [`ClusterEnv::path_mu`] over all links (flat topologies: the
    /// largest raw μ, ≥ the reference's). Used by §III.D's partition
    /// constraint — a bucket must fit the smallest knapsack, whose
    /// capacity is compute divided by this factor.
    pub fn max_mu(&self) -> f64 {
        self.link_ids()
            .map(|id| self.path_mu(id))
            .fold(0.0_f64, f64::max)
    }

    /// Segment path of a collective launched on `link`.
    ///
    /// Flat topologies (and `ranks_per_node = 1`, where every rank is its
    /// own node) move everything on the transfer's own link. Hierarchical
    /// topologies split into a node-local leg on the `intra` link
    /// (reduce-scatter + allgather, `2(n−1)/n · p` bytes) and a
    /// cross-node shard leg on the fabric — the transfer's link, or the
    /// designated `inter` fabric when the transfer is scheduled on the
    /// intra link itself (`2(M−1)/M · p/n` bytes over `M` nodes). The
    /// traffic fractions sum to exactly 1, so the flat ring traffic is
    /// conserved and per-segment μs compose as a weighted average.
    fn segment_path(&self, link: LinkId) -> Vec<SegmentLeg> {
        let flat = |link| {
            vec![SegmentLeg {
                link,
                traffic: 1.0,
                tensor_frac: 1.0,
            }]
        };
        match self.topology {
            Topology::Flat => flat(link),
            Topology::Hierarchical {
                ranks_per_node: n,
                intra,
                inter,
            } => {
                let w = self.workers;
                if n <= 1 || w <= 1 {
                    return flat(link);
                }
                assert!(
                    w % n == 0,
                    "ranks_per_node {n} must divide the worker count {w}"
                );
                let nodes = w / n;
                let flat_ring = ring_factor_of(w);
                let fabric = if link == intra { inter } else { link };
                let mut path = Vec::with_capacity(2);
                let intra_traffic = ring_factor_of(n) / flat_ring;
                if intra_traffic > 0.0 {
                    path.push(SegmentLeg {
                        link: intra,
                        traffic: intra_traffic,
                        tensor_frac: 1.0,
                    });
                }
                let inter_traffic = ring_factor_of(nodes) / (n as f64 * flat_ring);
                if inter_traffic > 0.0 {
                    path.push(SegmentLeg {
                        link: fabric,
                        traffic: inter_traffic,
                        tensor_frac: 1.0 / n as f64,
                    });
                }
                path
            }
        }
    }

    /// Codec-effective slowdown of one link: its μ scaled by its codec's
    /// bytes-on-wire ratio (identical to the raw μ for [`Codec::Raw`]).
    fn effective_mu(&self, link: LinkId) -> f64 {
        let spec = self.spec(link);
        match spec.codec {
            Codec::Raw => spec.mu,
            codec => spec.mu * codec.wire_ratio(),
        }
    }

    /// Effective slowdown — versus the flat reference-link ring — of the
    /// full segment path of a collective launched on `link`: the
    /// traffic-weighted sum of each leg's **codec-effective** μ
    /// (μ · codec wire ratio; raw codecs leave μ untouched). Flat
    /// topologies: the link's own codec-effective μ. This is the factor
    /// knapsack capacities and the §III.D partition constraint divide by.
    pub fn path_mu(&self, link: LinkId) -> f64 {
        match self.topology {
            Topology::Flat => self.effective_mu(link),
            Topology::Hierarchical { .. } => self
                .segment_path(link)
                .iter()
                .map(|leg| self.effective_mu(leg.link) * leg.traffic)
                .sum(),
        }
    }

    /// Per-link effective path slowdowns in registry order (flat
    /// topologies: the raw μs) — what scheduler knapsack sets consume.
    pub fn link_path_mus(&self) -> Vec<f64> {
        self.link_ids().map(|id| self.path_mu(id)).collect()
    }

    /// Is `a` strictly faster than `b` for contention exemption? The
    /// order is **total** over (codec-effective μ, α, registry index),
    /// so the outcome cannot depend on registry iteration order — two
    /// links with equal effective μ tie-break on the smaller startup
    /// latency, then the lower index. Codec-effective (not raw) μ keeps
    /// the exemption consistent with the wire pricing: an fp16-coded
    /// link that outships a raw group-mate is the one that escapes the
    /// Table IV penalty.
    fn faster(&self, a: usize, b: usize) -> bool {
        let (sa, sb) = (&self.links[a], &self.links[b]);
        self.effective_mu(LinkId(a))
            .total_cmp(&self.effective_mu(LinkId(b)))
            .then(sa.alpha.cmp(&sb.alpha))
            .then(a.cmp(&b))
            .is_lt()
    }

    /// Does `id` pay the shared-NIC contention penalty under the
    /// conservative **planning** rule? True iff another link shares its
    /// contention group and `id` is not the group's fastest member per
    /// [`ClusterEnv::faster`] — the paper's observation that NCCL is
    /// unaffected while gloo degrades. The DES engine additionally scales
    /// the penalty by the actually-overlapping window (module docs).
    pub fn contended(&self, id: LinkId) -> bool {
        let group = self.links[id.0].contention_group;
        let mut members = 0usize;
        let mut fastest = id.0;
        for (i, l) in self.links.iter().enumerate() {
            if l.contention_group == group {
                members += 1;
                if self.faster(i, fastest) {
                    fastest = i;
                }
            }
        }
        members > 1 && fastest != id.0
    }

    /// Number of registry links sharing `id`'s contention group (its NIC),
    /// including `id` itself — the `k` the conservative k-way planning
    /// rule presumes concurrently active.
    pub fn group_size(&self, id: LinkId) -> usize {
        let group = self.links[id.0].contention_group;
        self.links
            .iter()
            .filter(|l| l.contention_group == group)
            .count()
    }

    /// Ring-allreduce traffic factor 2(W−1)/W over all workers.
    pub fn ring_factor(&self) -> f64 {
        ring_factor_of(self.workers)
    }

    /// Allreduce time for `params` f32 parameters on `link`,
    /// **microbenchmark calibration** (Table IV / Fig. 6 scale), with the
    /// conservative static contention rule (planning estimate).
    ///
    /// Hierarchical topologies compose the per-segment α–β terms of the
    /// path: each leg contributes its own startup latency plus its
    /// traffic share of the wire time, the inter leg seeing only the
    /// `p/n` shard for the staging ramp.
    pub fn allreduce_us(&self, link: LinkId, params: u64) -> Micros {
        if self.workers <= 1 || params == 0 {
            return Micros::ZERO;
        }
        let bytes = params as f64 * 4.0 * self.ring_factor();
        let wire_bytes_per_us = self.bandwidth_gbps * 1e9 / 8.0 / 1e6; // B/µs
        let base_us = bytes / (wire_bytes_per_us * self.efficiency);
        let mut t = Micros::ZERO;
        for leg in self.segment_path(link) {
            let spec = self.spec(leg.link);
            let leg_params = (params as f64 * leg.tensor_frac) as u64;
            t += spec.alpha
                + Micros::from_us_f64(
                    base_us
                        * leg.traffic
                        * spec.mu
                        * spec.codec.wire_ratio()
                        * self.staging_factor(spec, leg_params),
                );
        }
        let f = self.static_contention_factor(link, params);
        let t = if f == 1.0 { t } else { t.scale(f) };
        // End-to-end collective latency includes the encode/decode
        // kernels of every coded segment leg (zero on all-raw paths).
        // The scheduling-unit pricing (`wire_time`) deliberately
        // excludes it: encode runs on the compute stream, where the DES
        // engine charges it.
        t + self.encode_overhead_us(link, params)
    }

    /// Staging degradation factor: +`staging_ramp` beyond the knee
    /// (Table IV shows the NCCL:gloo ratio climbing from ~1.65 to 1.85 at
    /// 67M params ⇒ gloo's ramp is 0.12).
    fn staging_factor(&self, spec: &LinkSpec, params: u64) -> f64 {
        let p = params as f64;
        if spec.staging_ramp == 0.0 || p <= STAGING_KNEE {
            1.0
        } else {
            1.0 + spec.staging_ramp * ((p - STAGING_KNEE) / STAGING_KNEE).min(1.0)
        }
    }

    /// Contention penalty for a slow link sharing a NIC with a faster one
    /// (Table IV: +0% at 4.2M params, ramping to ~+20% at ≥8.4M). This is
    /// the pairwise (k = 2) calibration point of
    /// [`ClusterEnv::contention_factor`].
    pub fn contention_penalty(&self, params: u64) -> f64 {
        const LO: f64 = 5.0e6;
        const HI: f64 = 8.4e6;
        let p = params as f64;
        if p <= LO {
            0.0
        } else if p >= HI {
            CONTENTION_PEAK
        } else {
            CONTENTION_PEAK * (p - LO) / (HI - LO)
        }
    }

    /// Aggregate k-way degradation of one **paying** transfer when `k`
    /// members of its contention group are concurrently in flight
    /// (module docs, "Contention: pairwise vs aggregate k-way sharing"):
    ///
    /// * `k ≤ 1` ⇒ exactly `1.0` (uncontended pricing);
    /// * `k = 2` ⇒ exactly `1 + contention_penalty(params)` — bit-for-bit
    ///   the pairwise Table IV calibration;
    /// * `k ≥ 3` ⇒ `(k−1) · (1 + penalty)`: the NIC's calibrated spare
    ///   capacity beyond the exempt member (`1/(1+penalty)` of one
    ///   transfer) is split evenly among `k−1` paying members, and the
    ///   factor is monotone in `k`.
    ///
    /// `k` is the number of concurrently in-flight group members,
    /// whatever their composition; the curve's derivation presumes the
    /// exempt member is one of them, so when it rides along the paying
    /// cohort's aggregate is capped at one uncontended transfer's share
    /// (`(k−1)/factor = 1/(1+penalty)`). When only payers are in flight
    /// they price slightly generously (each still pays `factor(k)`, so
    /// the aggregate is `k/factor(k)`), but every composition stays
    /// within the NIC's calibrated capacity `1 + 1/(1+penalty)` — see
    /// `prop_group_throughput_never_exceeds_link_bandwidth`.
    pub fn contention_factor(&self, k: usize, params: u64) -> f64 {
        if k <= 1 {
            return 1.0;
        }
        (k - 1) as f64 * (1.0 + self.contention_penalty(params))
    }

    /// Memoized [`ClusterEnv::contention_factor`] staircase for one
    /// transfer size: `factor(k)` for every concurrency `0 ..= max_k`,
    /// precomputed so the DES engine's piecewise re-pricing does not
    /// re-evaluate the penalty ramp at every membership change. Entries
    /// are bit-for-bit the values `contention_factor` returns
    /// (`tests/engine_equivalence.rs` pins this).
    pub fn contention_staircase(&self, max_k: usize, params: u64) -> ContentionStaircase {
        ContentionStaircase {
            factors: (0..=max_k).map(|k| self.contention_factor(k, params)).collect(),
        }
    }

    /// The conservative **static** contention factor of a link under the
    /// environment's [`ContentionModel`]: 1 when the link is exempt (or
    /// alone on its NIC); otherwise the model's factor with every
    /// group-mate presumed in flight — pairwise at `k = 2`, k-way at
    /// `k =` the group size. For two-member groups the models agree
    /// bit-for-bit.
    pub fn static_contention_factor(&self, link: LinkId, params: u64) -> f64 {
        if !self.contended(link) {
            return 1.0;
        }
        let k = match self.contention {
            ContentionModel::Pairwise => 2,
            ContentionModel::Kway => self.group_size(link),
        };
        self.contention_factor(k, params)
    }

    /// [`ClusterEnv::static_contention_factor`] at the Table IV plateau
    /// (params-independent worst case: any tensor size lands at
    /// [`CONTENTION_PEAK`]) — what per-link planning capacities budget
    /// against.
    fn static_contention_factor_peak(&self, link: LinkId) -> f64 {
        self.static_contention_factor(link, u64::MAX)
    }

    /// Conservative planning slowdown of a link: its codec-effective
    /// segment-path μ ([`ClusterEnv::path_mu`]) times the static
    /// contention factor at the Table IV plateau. This is what scheduler
    /// knapsack capacities divide by — a link that will pay shared-NIC
    /// contention holds proportionally less reference-time communication
    /// per compute window. Registries without shared NICs (every preset's
    /// default grouping) reduce to `path_mu` exactly.
    pub fn planning_mu(&self, link: LinkId) -> f64 {
        let f = self.static_contention_factor_peak(link);
        if f == 1.0 {
            self.path_mu(link)
        } else {
            self.path_mu(link) * f
        }
    }

    /// Per-link planning slowdowns in registry order — the
    /// contention-aware counterpart of [`ClusterEnv::link_path_mus`] that
    /// [`crate::sched::Deft::for_env`] and the lifecycle feed to the
    /// knapsack set.
    pub fn link_planning_mus(&self) -> Vec<f64> {
        self.link_ids().map(|id| self.planning_mu(id)).collect()
    }

    /// The link a single-queue baseline should ride: smallest planning
    /// slowdown, tie-broken by (α, registry index) so the choice is
    /// total. Presets always resolve to the reference link.
    pub fn planning_fastest_link(&self) -> LinkId {
        let mut best = 0usize;
        for i in 1..self.links.len() {
            let a = self.planning_mu(LinkId(i));
            let b = self.planning_mu(LinkId(best));
            if a
                .total_cmp(&b)
                .then(self.links[i].alpha.cmp(&self.links[best].alpha))
                .then(i.cmp(&best))
                .is_lt()
            {
                best = i;
            }
        }
        LinkId(best)
    }

    /// Scale a *workload-calibrated* reference comm time (measured at the
    /// paper's 16-GPU / 40 Gbps point) to this environment: ring-factor
    /// scaling in W, inverse-linear in bandwidth.
    pub fn scale_workload_comm(&self, ref_time: Micros) -> Micros {
        let ref_env = ClusterEnv::paper_testbed();
        if self.workers <= 1 {
            return Micros::ZERO;
        }
        let ratio = (self.ring_factor() / ref_env.ring_factor())
            * (ref_env.bandwidth_gbps / self.bandwidth_gbps);
        ref_time.scale(ratio)
    }

    /// Workload-calibrated communication time of `params` parameters on
    /// the **flat reference ring** — the topology-independent unit all
    /// `BucketProfile::comm` values and plan pricing are denominated in.
    ///
    /// `rate_ref` is the workload's µs/param at the reference point (from
    /// [`crate::models::Workload::comm_rate_ref`]).
    pub fn reference_comm(&self, params: u64, rate_ref: f64) -> Micros {
        let ref_time = Micros::from_us_f64(params as f64 * rate_ref);
        self.scale_workload_comm(ref_time)
    }

    /// Workload-calibrated bucket communication time on a link — the
    /// planning estimate, topology- and (statically) contention-aware.
    ///
    /// `rate_ref` is the workload's µs/param at the reference point (from
    /// [`crate::models::Workload::comm_rate_ref`]).
    pub fn bucket_comm(&self, link: LinkId, params: u64, rate_ref: f64) -> Micros {
        self.wire_time(link, self.reference_comm(params, rate_ref), params)
    }

    /// Wire time on `link` of a transfer whose **flat reference-link**
    /// time is `comm_ref` — the schedulers' conservative planning
    /// estimate, including the static shared-NIC contention rule of the
    /// environment's [`ContentionModel`] (every group-mate presumed in
    /// flight). The DES engine instead starts from
    /// [`ClusterEnv::wire_time_uncontended`] and charges contention only
    /// while same-group transfers actually overlap.
    pub fn wire_time(&self, link: LinkId, comm_ref: Micros, params: u64) -> Micros {
        let t = self.wire_time_uncontended(link, comm_ref);
        let f = self.static_contention_factor(link, params);
        if f == 1.0 {
            t
        } else {
            t.scale(f)
        }
    }

    /// Uncontended wire time of a transfer's full segment path.
    pub fn wire_time_uncontended(&self, link: LinkId, comm_ref: Micros) -> Micros {
        self.wire_segments(link, comm_ref)
            .iter()
            .map(|&(_, t)| t)
            .sum()
    }

    /// Per-segment wire occupancy of a transfer launched on `link` whose
    /// flat reference-link time is `comm_ref`: (segment link, time)
    /// pairs, uncontended. Flat topologies yield one segment on the
    /// transfer's own link; hierarchical ones an intra leg plus a fabric
    /// leg. The DES engine charges the transfer's home stream with the
    /// total (the home link serializes the collective even while its
    /// intra leg runs) and records the foreign legs on their segment
    /// streams — in the degenerate single-node cluster
    /// (`ranks_per_node == workers`) the entire collective is one
    /// node-local leg, so a transfer scheduled on a fabric still blocks
    /// its home stream while all bytes move on the intra link.
    pub fn wire_segments(&self, link: LinkId, comm_ref: Micros) -> Vec<(LinkId, Micros)> {
        self.segment_path(link)
            .iter()
            .map(|leg| {
                let factor = self.effective_mu(leg.link) * leg.traffic;
                // factor = 1 short-circuits so reference-link pricing is
                // exactly the input time (no float round-trip).
                let t = if factor == 1.0 {
                    comm_ref
                } else {
                    comm_ref.scale(factor)
                };
                (leg.link, t)
            })
            .collect()
    }

    /// Wire-time rescale when cluster membership changes from the
    /// configured `workers` to `new_workers` mid-run (elastic
    /// training): ring-allreduce traffic scales with 2(k−1)/k, so
    /// every transfer's wire time re-prices by the ratio of ring
    /// factors. Degenerate memberships (either side ≤ 1 worker, where
    /// no collective runs at all) price to 1.0.
    pub fn elastic_wire_scale(&self, new_workers: usize) -> f64 {
        let base = ring_factor_of(self.workers);
        let new = ring_factor_of(new_workers);
        if base == 0.0 || new == 0.0 {
            1.0
        } else {
            new / base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nccl(env: &ClusterEnv) -> LinkId {
        env.link("nccl").expect("nccl registered")
    }

    fn gloo(env: &ClusterEnv) -> LinkId {
        env.link("gloo").expect("gloo registered")
    }

    /// Table IV (multi-link NCCL column): 4.2M→14ms … 67.1M→231ms.
    /// The α–β fit must land within 15% of each paper measurement.
    #[test]
    fn table4_nccl_fit() {
        let env = ClusterEnv::paper_testbed();
        let cases: [(u64, f64); 5] = [
            (4_194_304, 14_000.0),
            (8_388_608, 25_000.0),
            (16_777_216, 51_000.0),
            (33_554_432, 110_000.0),
            (67_108_864, 231_000.0),
        ];
        for (params, want_us) in cases {
            let got = env.allreduce_us(nccl(&env), params).as_us() as f64;
            let err = (got - want_us).abs() / want_us;
            assert!(err < 0.15, "nccl {params}: got {got}, want {want_us}");
        }
    }

    /// Table IV (multi-link gloo column): 22/41/80/169/428 ms.
    #[test]
    fn table4_gloo_multilink_fit() {
        let env = ClusterEnv::paper_testbed();
        let cases: [(u64, f64); 5] = [
            (4_194_304, 22_000.0),
            (8_388_608, 41_000.0),
            (16_777_216, 80_000.0),
            (33_554_432, 169_000.0),
            (67_108_864, 428_000.0),
        ];
        for (params, want_us) in cases {
            let got = env.allreduce_us(gloo(&env), params).as_us() as f64;
            let err = (got - want_us).abs() / want_us;
            assert!(err < 0.15, "gloo {params}: got {got}, want {want_us}");
        }
    }

    /// Table IV single-link: gloo degrades ~17–25% for ≥8.4M params, ~0%
    /// at 4.2M; NCCL unchanged.
    #[test]
    fn table4_single_link_contention() {
        let multi = ClusterEnv::paper_testbed();
        let single = ClusterEnv::paper_testbed().with_single_link();
        assert_eq!(
            multi.allreduce_us(nccl(&multi), 33_554_432),
            single.allreduce_us(nccl(&single), 33_554_432)
        );
        let g_multi = multi.allreduce_us(gloo(&multi), 33_554_432).as_us() as f64;
        let g_single = single.allreduce_us(gloo(&single), 33_554_432).as_us() as f64;
        let degradation = g_single / g_multi - 1.0;
        assert!(
            (0.15..=0.25).contains(&degradation),
            "degradation {degradation}"
        );
        // Small tensors: no contention.
        let s_multi = multi.allreduce_us(gloo(&multi), 4_194_304);
        let s_single = single.allreduce_us(gloo(&single), 4_194_304);
        assert_eq!(s_multi, s_single);
    }

    /// Fig. 6: NCCL/gloo speed ratio stabilises around μ for ≥4M params.
    #[test]
    fn fig6_speed_ratio_converges_to_mu() {
        let env = ClusterEnv::paper_testbed();
        for params in [4_194_304u64, 16_777_216, 67_108_864] {
            let n = env.allreduce_us(nccl(&env), params).as_us() as f64;
            let g = env.allreduce_us(gloo(&env), params).as_us() as f64;
            let ratio = g / n;
            // Paper Fig. 6 / Table IV: 1.57–1.85 across this size range.
            assert!(
                (1.5..=1.9).contains(&ratio),
                "ratio {ratio} at {params} params"
            );
        }
    }

    #[test]
    fn ring_factor_limits() {
        assert_eq!(ClusterEnv::paper_testbed().with_workers(1).ring_factor(), 0.0);
        let f2 = ClusterEnv::paper_testbed().with_workers(2).ring_factor();
        assert!((f2 - 1.0).abs() < 1e-12);
        let f16 = ClusterEnv::paper_testbed().ring_factor();
        assert!((f16 - 1.875).abs() < 1e-12);
    }

    #[test]
    fn workload_comm_scales_with_bandwidth_and_workers() {
        let base = ClusterEnv::paper_testbed();
        let r = LinkId::REFERENCE;
        let t40 = base.bucket_comm(r, 10_000_000, 1.8e-3);
        let t20 = base
            .clone()
            .with_bandwidth(20.0)
            .bucket_comm(r, 10_000_000, 1.8e-3);
        // Half bandwidth => double time.
        assert!((t20.as_us() as f64 / t40.as_us() as f64 - 2.0).abs() < 0.01);

        let t2 = base
            .clone()
            .with_workers(2)
            .bucket_comm(r, 10_000_000, 1.8e-3);
        // 2 workers: ring factor 1.0 vs 1.875 => ~0.533×.
        assert!((t2.as_us() as f64 / t40.as_us() as f64 - 0.5333).abs() < 0.01);

        // 1 worker: no communication at all.
        let t1 = base.with_workers(1).bucket_comm(r, 10_000_000, 1.8e-3);
        assert_eq!(t1, Micros::ZERO);
    }

    #[test]
    fn zero_params_free() {
        let env = ClusterEnv::paper_testbed();
        assert_eq!(env.allreduce_us(LinkId::REFERENCE, 0), Micros::ZERO);
    }

    // ---- Registry-specific behaviour. ----

    #[test]
    fn registry_lookup_and_presets() {
        let env = ClusterEnv::paper_testbed();
        assert_eq!(env.n_links(), 2);
        assert_eq!(env.link("nccl"), Some(LinkId(0)));
        assert_eq!(env.link("gloo"), Some(LinkId(1)));
        assert_eq!(env.link("ib"), None);
        assert_eq!(env.link_names(), vec!["nccl".to_string(), "gloo".to_string()]);
        assert_eq!(env.link_mus(), vec![1.0, PAPER_MU]);
        assert!((env.max_mu() - PAPER_MU).abs() < 1e-12);

        for preset in LinkPreset::ALL {
            assert_eq!(LinkPreset::parse(preset.name()), Some(preset));
            let links = preset.links();
            assert!(!links.is_empty());
            assert!((links[0].mu - 1.0).abs() < 1e-12, "{}: reference μ", preset.name());
        }
        assert_eq!(LinkPreset::parse("bogus"), None);
    }

    #[test]
    fn contention_applies_to_all_but_fastest_group_member() {
        // Distinct NICs: nobody contends.
        let multi = ClusterEnv::paper_testbed();
        assert!(!multi.contended(LinkId(0)));
        assert!(!multi.contended(LinkId(1)));
        // Shared NIC: only the slower link pays.
        let single = LinkPreset::SingleNic.env();
        assert!(!single.contended(LinkId(0)));
        assert!(single.contended(LinkId(1)));
        // 3-link preset: three separate groups, nobody pays.
        let three = LinkPreset::NvlinkIbTcp.env();
        for id in three.link_ids() {
            assert!(!three.contended(id), "{:?}", id);
        }
        // Collapse the 3-link preset onto one NIC: ib and tcp pay.
        let shared = LinkPreset::NvlinkIbTcp.env().with_single_link();
        assert!(!shared.contended(LinkId(0)));
        assert!(shared.contended(LinkId(1)));
        assert!(shared.contended(LinkId(2)));
    }

    #[test]
    fn wire_time_orders_by_mu() {
        let env = LinkPreset::NvlinkIbTcp.env();
        let comm = Micros(10_000);
        let t0 = env.wire_time(LinkId(0), comm, 1_000_000);
        let t1 = env.wire_time(LinkId(1), comm, 1_000_000);
        let t2 = env.wire_time(LinkId(2), comm, 1_000_000);
        // Reference pricing is exact; slower links scale by μ.
        assert_eq!(t0, comm);
        assert_eq!(t1, comm.scale(2.5));
        assert_eq!(t2, comm.scale(6.0));
        assert!(t0 < t1 && t1 < t2);
    }

    #[test]
    fn three_link_allreduce_end_to_end() {
        let env = LinkPreset::NvlinkIbTcp.env();
        let p = 16_777_216u64;
        let a0 = env.allreduce_us(LinkId(0), p);
        let a1 = env.allreduce_us(LinkId(1), p);
        let a2 = env.allreduce_us(LinkId(2), p);
        assert!(a0 < a1 && a1 < a2, "{a0:?} {a1:?} {a2:?}");
        // μ ratio dominates for large tensors.
        let r = a1.as_us() as f64 / a0.as_us() as f64;
        assert!((2.0..3.0).contains(&r), "ib/nvlink ratio {r}");
    }

    // ---- Contention tie-break (total order). ----

    #[test]
    fn contention_tiebreak_is_total_over_mu_alpha_index() {
        // Two links with equal μ but different α sharing a NIC: exactly
        // one (the lower-α one) is exempt, in either registry order.
        let fwd = ClusterEnv::paper_testbed().with_links(vec![
            LinkSpec::new("a", 1.0).with_alpha(Micros(300)).with_group(0),
            LinkSpec::new("b", 1.0).with_alpha(Micros(100)).with_group(0),
        ]);
        assert!(fwd.contended(LinkId(0)), "higher-α link must pay");
        assert!(!fwd.contended(LinkId(1)), "lower-α link is the group's fastest");
        let rev = ClusterEnv::paper_testbed().with_links(vec![
            LinkSpec::new("b", 1.0).with_alpha(Micros(100)).with_group(0),
            LinkSpec::new("a", 1.0).with_alpha(Micros(300)).with_group(0),
        ]);
        assert!(!rev.contended(LinkId(0)));
        assert!(rev.contended(LinkId(1)));
        // Fully identical specs: the index makes the order total — the
        // first registered link is exempt, every clone pays.
        let twin = ClusterEnv::paper_testbed().with_links(vec![
            LinkSpec::new("x", 1.0).with_group(0),
            LinkSpec::new("y", 1.0).with_group(0),
            LinkSpec::new("z", 1.0).with_group(0),
        ]);
        assert!(!twin.contended(LinkId(0)));
        assert!(twin.contended(LinkId(1)));
        assert!(twin.contended(LinkId(2)));
    }

    // ---- Rank-level topology. ----

    fn hier(env: &ClusterEnv, ranks_per_node: usize) -> ClusterEnv {
        env.clone()
            .with_topology(Topology::hierarchical(ranks_per_node, LinkId(0), LinkId(1)))
    }

    #[test]
    fn topology_defaults_flat_and_degenerates_at_one_rank_per_node() {
        let flat = LinkPreset::NvlinkIbTcp.env();
        assert_eq!(flat.topology, Topology::Flat);
        // ranks_per_node = 1 ⇒ every rank its own node ⇒ bit-for-bit the
        // flat registry pricing on every link, both pricing paths.
        let one = hier(&flat, 1);
        for id in flat.link_ids() {
            for params in [0u64, 1_000_000, 8_388_608, 67_108_864] {
                assert_eq!(
                    flat.allreduce_us(id, params),
                    one.allreduce_us(id, params),
                    "{id:?} @ {params}"
                );
                let comm = Micros(params / 100 + 7);
                assert_eq!(
                    flat.wire_time(id, comm, params),
                    one.wire_time(id, comm, params),
                    "{id:?} wire @ {params}"
                );
            }
            assert!((flat.path_mu(id) - one.path_mu(id)).abs() < 1e-15);
        }
    }

    #[test]
    fn hierarchical_path_conserves_traffic_and_prices_segments() {
        // 16 ranks, 8/node: intra moves 2·7/8 of p on nvlink, inter
        // 2·1/2 of p/8 on the fabric; fractions sum to the flat factor.
        let env = hier(&LinkPreset::NvlinkIbTcp.env(), 8);
        let ib = env.link("ib").unwrap();
        // path_mu is the traffic-weighted μ average: h·1 + g·μ_ib with
        // h = (2·7/8)/(2·15/16) = 14/15 and g = 1/15.
        let h = 14.0 / 15.0;
        let g = 1.0 / 15.0;
        assert!((env.path_mu(ib) - (h + g * 2.5)).abs() < 1e-12);
        // Moving most traffic onto NVLink beats the flat fabric ring.
        assert!(env.path_mu(ib) < 2.5);
        let comm = Micros(100_000);
        let segs = env.wire_segments(ib, comm);
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].0, LinkId(0), "intra leg on nvlink");
        assert_eq!(segs[1].0, ib, "inter leg on the fabric itself");
        let total: Micros = segs.iter().map(|&(_, t)| t).sum();
        assert_eq!(total, env.wire_time_uncontended(ib, comm));
        // A transfer scheduled on the intra link routes its cross-node
        // shard over the designated inter fabric.
        let segs0 = env.wire_segments(LinkId(0), comm);
        assert_eq!(segs0.len(), 2);
        assert_eq!(segs0[0].0, LinkId(0));
        assert_eq!(segs0[1].0, LinkId(1));
        // max_mu follows the slowest segment path, not the raw μ.
        let expect_max = env
            .link_ids()
            .map(|id| env.path_mu(id))
            .fold(0.0_f64, f64::max);
        assert!((env.max_mu() - expect_max).abs() < 1e-15);
        assert!(env.max_mu() < 6.0, "tcp's path must be cheaper than its flat ring");
    }

    // ---- Per-link compression codecs. ----

    #[test]
    fn codec_parse_and_name_roundtrip() {
        for codec in [Codec::Raw, Codec::Fp16, Codec::RankK { k: 1 }, Codec::RankK { k: 64 }] {
            assert_eq!(Codec::parse(&codec.name()), Some(codec));
        }
        assert_eq!(Codec::parse("half"), Some(Codec::Fp16));
        assert_eq!(Codec::parse("none"), Some(Codec::Raw));
        assert_eq!(Codec::parse("rank0"), None);
        assert_eq!(Codec::parse("rank-4"), None);
        assert_eq!(Codec::parse("rank+4"), None, "non-canonical sign");
        assert_eq!(Codec::parse("rank007"), None, "leading zeros");
        assert_eq!(Codec::parse("rank"), None);
        assert_eq!(Codec::parse("zfp"), None);
    }

    #[test]
    fn codec_terms_are_sane() {
        assert_eq!(Codec::Raw.wire_ratio(), 1.0);
        assert_eq!(Codec::Raw.encode_overhead(100_000_000), Micros::ZERO);
        assert_eq!(Codec::Raw.error(), 0.0);
        assert!(!Codec::Raw.is_lossy());

        assert_eq!(Codec::Fp16.wire_ratio(), 0.5);
        assert!(Codec::Fp16.is_lossy());
        // 1M params = 4 MB → 8 µs at 2 µs/MB.
        assert_eq!(Codec::Fp16.encode_overhead(1_000_000), Micros(8));

        // Rank-k: ratio monotone in k, capped at 1; error decays in k.
        let mut prev_ratio = 0.0;
        let mut prev_err = f64::INFINITY;
        for k in [1u32, 2, 4, 16, 64, 512, 2048] {
            let c = Codec::RankK { k };
            assert!(c.wire_ratio() >= prev_ratio && c.wire_ratio() <= 1.0, "k={k}");
            assert!(c.error() < prev_err, "k={k}");
            prev_ratio = c.wire_ratio();
            prev_err = c.error();
        }
        assert_eq!(Codec::RankK { k: 512 }.wire_ratio(), 1.0);
    }

    #[test]
    fn codec_scales_wire_and_path_mu() {
        let env = LinkPreset::NvlinkIbTcp.env();
        let tcp = env.link("tcp").unwrap();
        let fp16 = env.clone().with_codec(tcp, Codec::Fp16);
        let comm = Micros(10_000);
        // fp16 halves the wire time of the coded link only.
        assert_eq!(
            fp16.wire_time(tcp, comm, 1_000_000),
            env.wire_time(tcp, comm, 1_000_000).scale(0.5)
        );
        assert_eq!(fp16.wire_time(LinkId(0), comm, 1_000_000), comm);
        // Codec-effective μ feeds path_mu and max_mu (§III.D).
        assert!((fp16.path_mu(tcp) - 3.0).abs() < 1e-12);
        assert!((fp16.max_mu() - 3.0).abs() < 1e-12, "max_mu {}", fp16.max_mu());
        // Raw registry is untouched by the round-trip helpers.
        assert_eq!(fp16.with_raw_codecs().links, env.links);
        assert!(!env.has_lossy_codec());
        assert!(env.clone().with_codec(tcp, Codec::RankK { k: 4 }).has_lossy_codec());
        assert_eq!(env.link_codec_errors(), vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn codec_allreduce_includes_encode_overhead() {
        let env = ClusterEnv::paper_testbed();
        let gloo = env.link("gloo").unwrap();
        let fp16 = env.clone().with_codec(gloo, Codec::Fp16);
        let p = 16_777_216u64;
        let raw = env.allreduce_us(gloo, p);
        let coded = fp16.allreduce_us(gloo, p);
        // α + wire/2 + encode: the wire part halves exactly.
        let alpha = env.spec(gloo).alpha;
        let wire = raw - alpha;
        let expect = alpha + wire.scale(0.5) + Codec::Fp16.encode_overhead(p);
        // Wire halving happens pre-rounding; allow 1 µs of rounding slack.
        let got = coded.as_us() as i64;
        let want = expect.as_us() as i64;
        assert!((got - want).abs() <= 1, "got {got}, want {want}");
        // Large tensors: compression wins despite the encode cost.
        assert!(coded < raw);
    }

    #[test]
    fn codec_on_hierarchical_fabric_compresses_only_its_leg() {
        // fp16 on the ib fabric of a hierarchical cluster: the intra leg
        // ships raw, the inter leg at half time.
        let env = hier(&LinkPreset::NvlinkIbTcp.env(), 8);
        let ib = env.link("ib").unwrap();
        let coded = env.clone().with_codec(ib, Codec::Fp16);
        let comm = Micros(100_000);
        let raw_segs = env.wire_segments(ib, comm);
        let segs = coded.wire_segments(ib, comm);
        assert_eq!(segs[0], raw_segs[0], "intra leg must stay raw");
        // The halving applies pre-rounding; allow 1 µs of rounding slack.
        let (got, want) = (
            segs[1].1.as_us() as i64,
            raw_segs[1].1.scale(0.5).as_us() as i64,
        );
        assert!((got - want).abs() <= 1, "inter leg {got} vs {want}");
        let h = 14.0 / 15.0;
        let g = 1.0 / 15.0;
        assert!((coded.path_mu(ib) - (h + g * 2.5 * 0.5)).abs() < 1e-12);
    }

    #[test]
    fn contention_exemption_ranks_by_codec_effective_mu() {
        // Shared NIC, A raw at μ = 1.5, B at μ = 2.0: raw registries
        // exempt A; an fp16 codec on B (effective μ = 1.0) makes B the
        // group's effectively fastest member, flipping the exemption to
        // match the wire pricing.
        let raw = ClusterEnv::paper_testbed().with_links(vec![
            LinkSpec::new("a", 1.5).with_group(0),
            LinkSpec::new("b", 2.0).with_group(0),
        ]);
        assert!(!raw.contended(LinkId(0)));
        assert!(raw.contended(LinkId(1)));
        let coded = raw.clone().with_codec(LinkId(1), Codec::Fp16);
        assert!(coded.contended(LinkId(0)));
        assert!(!coded.contended(LinkId(1)));
    }

    #[test]
    #[should_panic(expected = "k >= 1")]
    fn rank_zero_codec_is_rejected_by_the_builder() {
        let _ = ClusterEnv::paper_testbed().with_codec(LinkId(0), Codec::RankK { k: 0 });
    }

    #[test]
    fn coded_intra_link_taints_every_path() {
        // A lossy codec on the shared intra link compresses the
        // node-local leg of *every* transfer, so the path-level error
        // and encode overhead of fabric-homed transfers must see it —
        // the Preserver gate consumes these path-level terms.
        let env = hier(&LinkPreset::NvlinkIbTcp.env(), 8)
            .with_codec(LinkId(0), Codec::RankK { k: 1 });
        let ib = env.link("ib").unwrap();
        let tcp = env.link("tcp").unwrap();
        let rank1_err = Codec::RankK { k: 1 }.error();
        for link in [ib, tcp] {
            assert_eq!(env.path_codec_error(link), rank1_err, "{link:?}");
            // The intra leg ships the full tensor through the rank-1
            // encoder; the raw fabric leg adds nothing.
            assert_eq!(
                env.encode_overhead_us(link, 1_000_000),
                Codec::RankK { k: 1 }.encode_overhead(1_000_000),
                "{link:?}"
            );
        }
        assert_eq!(env.link_path_codec_errors(), vec![rank1_err; 3]);
        // Flat topologies degenerate to the link's own codec terms.
        let flat = LinkPreset::NvlinkIbTcp
            .env()
            .with_codec(LinkId(2), Codec::Fp16);
        assert_eq!(flat.path_codec_error(LinkId(2)), Codec::Fp16.error());
        assert_eq!(flat.path_codec_error(LinkId(0)), 0.0);
        assert_eq!(
            flat.encode_overhead_us(LinkId(2), 1_000_000),
            Codec::Fp16.encode_overhead(1_000_000)
        );
        assert_eq!(flat.encode_overhead_us(LinkId(0), 1_000_000), Micros::ZERO);
    }

    // ---- Aggregate k-way contention. ----

    #[test]
    fn contention_factor_pins_k1_uncontended_and_k2_pairwise() {
        let env = ClusterEnv::paper_testbed();
        for params in [
            0u64,
            1_000_000,
            5_000_000,
            6_000_000,
            8_400_000,
            33_554_432,
            134_217_728,
        ] {
            assert_eq!(env.contention_factor(0, params), 1.0);
            assert_eq!(env.contention_factor(1, params), 1.0);
            // Bit-for-bit the pairwise Table IV calibration at k = 2.
            assert_eq!(
                env.contention_factor(2, params),
                1.0 + env.contention_penalty(params)
            );
            // Monotone non-decreasing in k; with the exempt member among
            // the k in-flight transfers, the paying cohort's aggregate
            // bandwidth share (k−1)/factor never exceeds one uncontended
            // transfer's.
            let mut prev = 1.0;
            for k in 2..=8usize {
                let f = env.contention_factor(k, params);
                assert!(f >= prev, "factor not monotone at k={k}");
                assert!((k - 1) as f64 / f <= 1.0 + 1e-12, "payers outship the NIC at k={k}");
                prev = f;
            }
        }
    }

    #[test]
    fn contention_model_parse_roundtrip() {
        for model in ContentionModel::ALL {
            assert_eq!(ContentionModel::parse(model.name()), Some(model));
        }
        assert_eq!(ContentionModel::parse("k-way"), Some(ContentionModel::Kway));
        assert_eq!(ContentionModel::parse("freeway"), None);
        assert_eq!(ContentionModel::default(), ContentionModel::Kway);
    }

    #[test]
    fn static_factor_and_planning_mus_follow_the_model() {
        // No shared NICs: planning μ degenerates to the path μ.
        for preset in [LinkPreset::Paper2Link, LinkPreset::NvlinkIbTcp] {
            let env = preset.env();
            assert_eq!(env.link_planning_mus(), env.link_path_mus(), "{}", preset.name());
            assert_eq!(env.planning_fastest_link(), LinkId(0));
        }
        // 2-member shared group: both models agree bit-for-bit.
        let p = 33_554_432u64;
        let comm = Micros(100_000);
        let single = LinkPreset::SingleNic.env();
        let pair = single.clone().with_contention_model(ContentionModel::Pairwise);
        assert_eq!(
            single.static_contention_factor(LinkId(1), p),
            pair.static_contention_factor(LinkId(1), p)
        );
        assert_eq!(single.wire_time(LinkId(1), comm, p), pair.wire_time(LinkId(1), comm, p));
        assert_eq!(single.link_planning_mus(), pair.link_planning_mus());
        assert!(
            (single.planning_mu(LinkId(1)) - PAPER_MU * (1.0 + CONTENTION_PEAK)).abs() < 1e-12
        );
        assert_eq!(single.planning_fastest_link(), LinkId(0));
        // 3-member shared group: the k-way static rule budgets
        // (k−1)·(1+peak), strictly more conservative than pairwise.
        let shared3 = LinkPreset::NvlinkIbTcp.env().with_single_link();
        let pair3 = shared3.clone().with_contention_model(ContentionModel::Pairwise);
        assert_eq!(shared3.group_size(LinkId(2)), 3);
        assert_eq!(
            shared3.static_contention_factor(LinkId(2), p),
            2.0 * (1.0 + CONTENTION_PEAK)
        );
        assert_eq!(
            pair3.static_contention_factor(LinkId(2), p),
            1.0 + CONTENTION_PEAK
        );
        assert!(shared3.wire_time(LinkId(2), comm, p) > pair3.wire_time(LinkId(2), comm, p));
        // The exempt (fastest) group member never pays under either model.
        assert_eq!(shared3.static_contention_factor(LinkId(0), p), 1.0);
        assert_eq!(shared3.planning_mu(LinkId(0)), 1.0);
    }

    #[test]
    fn prop_hierarchical_time_monotone_in_ranks_per_node() {
        use crate::util::prop::check;
        // With the intra link strictly faster than the fabric, growing the
        // node (moving traffic onto the fast segment) must never slow an
        // allreduce down; at n = 1 the model degenerates to flat pricing.
        check("hierarchical monotone in ranks/node", 40, |g| {
            let mu_fabric = 1.2 + g.f64_in(0.0, 6.0);
            let params = g.u64_in(16_000_000..=200_000_000);
            let flat = ClusterEnv::paper_testbed().with_links(vec![
                LinkSpec::new("fast", 1.0).with_alpha(Micros(150)).with_group(0),
                LinkSpec::new("fabric", mu_fabric).with_alpha(Micros(600)).with_group(1),
            ]);
            let fabric = LinkId(1);
            let comm = Micros(params / 50);
            let mut prev_allreduce = Micros::MAX;
            let mut prev_wire = Micros::MAX;
            for rpn in [1usize, 2, 4, 8, 16] {
                let env = hier(&flat, rpn);
                let a = env.allreduce_us(fabric, params);
                let wt = env.wire_time(fabric, comm, params);
                if rpn == 1 {
                    if a != flat.allreduce_us(fabric, params) {
                        return Err("rpn=1 allreduce differs from flat".into());
                    }
                    if wt != flat.wire_time(fabric, comm, params) {
                        return Err("rpn=1 wire differs from flat".into());
                    }
                }
                if a > prev_allreduce {
                    return Err(format!(
                        "allreduce not monotone at rpn={rpn}: {a:?} > {prev_allreduce:?}"
                    ));
                }
                if wt > prev_wire {
                    return Err(format!(
                        "wire not monotone at rpn={rpn}: {wt:?} > {prev_wire:?}"
                    ));
                }
                prev_allreduce = a;
                prev_wire = wt;
            }
            Ok(())
        });
    }
}
