//! Communication-link cost models (paper §III.C, Table IV, Fig. 6).
//!
//! The paper runs two collective libraries concurrently: **NCCL** on one
//! NIC and **gloo** on a second NIC ("heterogeneous multi-link"). In this
//! reproduction the transports are replaced by calibrated ring-allreduce
//! α–β cost models — the scheduler only ever consumes *times*, so a model
//! fit to the paper's own Table IV measurements preserves every
//! scheduling decision (see DESIGN.md §Substitutions).
//!
//! Model: `T(p) = α + p · 4 B · 2(W−1)/W / (η · BW)` for `p` f32
//! parameters over `W` workers at wire bandwidth `BW`, with link
//! efficiency `η`. gloo is `μ ≈ 1.65×` slower than NCCL (paper Fig. 6);
//! in **single-link** mode (both libraries on one NIC) concurrent large
//! transfers contend and gloo degrades ~20% further (paper Table IV).

use crate::util::Micros;

/// Which transport a communication op uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LinkKind {
    /// Primary GPU collective library (fast link).
    Nccl,
    /// Secondary CPU collective library (slow link, factor μ).
    Gloo,
}

impl LinkKind {
    pub const ALL: [LinkKind; 2] = [LinkKind::Nccl, LinkKind::Gloo];

    pub fn name(self) -> &'static str {
        match self {
            LinkKind::Nccl => "nccl",
            LinkKind::Gloo => "gloo",
        }
    }
}

/// The cluster communication environment: worker count, NIC bandwidth,
/// link topology (multi vs single NIC) and the gloo slowdown μ.
#[derive(Clone, Debug)]
pub struct ClusterEnv {
    /// Number of data-parallel workers (GPUs).
    pub workers: usize,
    /// Per-NIC wire bandwidth in Gbps (paper testbed: 40).
    pub bandwidth_gbps: f64,
    /// `true` = NCCL and gloo on distinct NICs (no contention);
    /// `false` = both share one NIC (Table IV "single-link" rows).
    pub multi_link: bool,
    /// Speed ratio between NCCL and gloo (paper: 1.59–1.69, set 1.65).
    pub mu: f64,
    /// NCCL link efficiency η at the microbenchmark scale (fit to
    /// Table IV: β ≈ 3.2 ns/param at 16 GPUs / 40 Gbps ⇒ η ≈ 0.469).
    pub nccl_efficiency: f64,
    /// Fixed startup latency per collective (µs).
    pub alpha_nccl: Micros,
    pub alpha_gloo: Micros,
}

/// Paper reference testbed: 16 GPUs, 40 Gbps, dual NICs.
pub const PAPER_MU: f64 = 1.65;

impl Default for ClusterEnv {
    fn default() -> Self {
        ClusterEnv::paper_testbed()
    }
}

impl ClusterEnv {
    /// The paper's testbed: 2 nodes × 8 A100, 40 Gbps Ethernet, 2 NICs.
    pub fn paper_testbed() -> ClusterEnv {
        ClusterEnv {
            workers: 16,
            bandwidth_gbps: 40.0,
            multi_link: true,
            mu: PAPER_MU,
            nccl_efficiency: 0.469,
            alpha_nccl: Micros(300),
            alpha_gloo: Micros(900),
        }
    }

    pub fn with_workers(mut self, workers: usize) -> ClusterEnv {
        assert!(workers >= 1);
        self.workers = workers;
        self
    }

    pub fn with_bandwidth(mut self, gbps: f64) -> ClusterEnv {
        assert!(gbps > 0.0);
        self.bandwidth_gbps = gbps;
        self
    }

    pub fn with_single_link(mut self) -> ClusterEnv {
        self.multi_link = false;
        self
    }

    /// Ring-allreduce traffic factor 2(W−1)/W.
    pub fn ring_factor(&self) -> f64 {
        if self.workers <= 1 {
            0.0
        } else {
            2.0 * (self.workers as f64 - 1.0) / self.workers as f64
        }
    }

    /// NCCL allreduce time for `params` f32 parameters, **microbenchmark
    /// calibration** (Table IV / Fig. 6 scale).
    pub fn allreduce_us(&self, kind: LinkKind, params: u64) -> Micros {
        if self.workers <= 1 || params == 0 {
            return Micros::ZERO;
        }
        let bytes = params as f64 * 4.0 * self.ring_factor();
        let wire_bytes_per_us = self.bandwidth_gbps * 1e9 / 8.0 / 1e6; // B/µs
        let base_us = bytes / (wire_bytes_per_us * self.nccl_efficiency);
        match kind {
            LinkKind::Nccl => self.alpha_nccl + Micros::from_us_f64(base_us),
            LinkKind::Gloo => {
                let t = self.alpha_gloo
                    + Micros::from_us_f64(base_us * self.mu * self.gloo_oversize(params));
                if self.multi_link {
                    t
                } else {
                    t.scale(1.0 + self.contention_penalty(params))
                }
            }
        }
    }

    /// gloo's CPU-staged pipeline degrades superlinearly on very large
    /// tensors (Table IV shows the NCCL:gloo ratio climbing from ~1.65 to
    /// 1.85 at 67M params): +12% ramp beyond 33.6M params.
    fn gloo_oversize(&self, params: u64) -> f64 {
        const KNEE: f64 = 33.6e6;
        let p = params as f64;
        if p <= KNEE {
            1.0
        } else {
            1.0 + 0.12 * ((p - KNEE) / KNEE).min(1.0)
        }
    }

    /// Contention penalty for gloo sharing a NIC with NCCL (Table IV:
    /// +0% at 4.2M params, ramping to ~+20% at ≥8.4M).
    pub fn contention_penalty(&self, params: u64) -> f64 {
        const LO: f64 = 5.0e6;
        const HI: f64 = 8.4e6;
        const PEAK: f64 = 0.21;
        let p = params as f64;
        if p <= LO {
            0.0
        } else if p >= HI {
            PEAK
        } else {
            PEAK * (p - LO) / (HI - LO)
        }
    }

    /// Scale a *workload-calibrated* reference comm time (measured at the
    /// paper's 16-GPU / 40 Gbps point) to this environment: ring-factor
    /// scaling in W, inverse-linear in bandwidth.
    pub fn scale_workload_comm(&self, ref_time: Micros) -> Micros {
        let ref_env = ClusterEnv::paper_testbed();
        if self.workers <= 1 {
            return Micros::ZERO;
        }
        let ratio = (self.ring_factor() / ref_env.ring_factor())
            * (ref_env.bandwidth_gbps / self.bandwidth_gbps);
        ref_time.scale(ratio)
    }

    /// Workload-calibrated bucket communication time on a link.
    ///
    /// `rate_ref` is the workload's µs/param at the reference point (from
    /// [`crate::models::Workload::comm_rate_ref`]).
    pub fn bucket_comm(&self, kind: LinkKind, params: u64, rate_ref: f64) -> Micros {
        let nccl_ref = Micros::from_us_f64(params as f64 * rate_ref);
        let scaled = self.scale_workload_comm(nccl_ref);
        match kind {
            LinkKind::Nccl => scaled,
            LinkKind::Gloo => {
                let t = scaled.scale(self.mu);
                if self.multi_link {
                    t
                } else {
                    t.scale(1.0 + self.contention_penalty(params))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table IV (multi-link NCCL column): 4.2M→14ms … 67.1M→231ms.
    /// The α–β fit must land within 15% of each paper measurement.
    #[test]
    fn table4_nccl_fit() {
        let env = ClusterEnv::paper_testbed();
        let cases: [(u64, f64); 5] = [
            (4_194_304, 14_000.0),
            (8_388_608, 25_000.0),
            (16_777_216, 51_000.0),
            (33_554_432, 110_000.0),
            (67_108_864, 231_000.0),
        ];
        for (params, want_us) in cases {
            let got = env.allreduce_us(LinkKind::Nccl, params).as_us() as f64;
            let err = (got - want_us).abs() / want_us;
            assert!(err < 0.15, "nccl {params}: got {got}, want {want_us}");
        }
    }

    /// Table IV (multi-link gloo column): 22/41/80/169/428 ms.
    #[test]
    fn table4_gloo_multilink_fit() {
        let env = ClusterEnv::paper_testbed();
        let cases: [(u64, f64); 5] = [
            (4_194_304, 22_000.0),
            (8_388_608, 41_000.0),
            (16_777_216, 80_000.0),
            (33_554_432, 169_000.0),
            (67_108_864, 428_000.0),
        ];
        for (params, want_us) in cases {
            let got = env.allreduce_us(LinkKind::Gloo, params).as_us() as f64;
            let err = (got - want_us).abs() / want_us;
            assert!(err < 0.15, "gloo {params}: got {got}, want {want_us}");
        }
    }

    /// Table IV single-link: gloo degrades ~17–25% for ≥8.4M params, ~0%
    /// at 4.2M; NCCL unchanged.
    #[test]
    fn table4_single_link_contention() {
        let multi = ClusterEnv::paper_testbed();
        let single = ClusterEnv::paper_testbed().with_single_link();
        assert_eq!(
            multi.allreduce_us(LinkKind::Nccl, 33_554_432),
            single.allreduce_us(LinkKind::Nccl, 33_554_432)
        );
        let g_multi = multi.allreduce_us(LinkKind::Gloo, 33_554_432).as_us() as f64;
        let g_single = single.allreduce_us(LinkKind::Gloo, 33_554_432).as_us() as f64;
        let degradation = g_single / g_multi - 1.0;
        assert!(
            (0.15..=0.25).contains(&degradation),
            "degradation {degradation}"
        );
        // Small tensors: no contention.
        let s_multi = multi.allreduce_us(LinkKind::Gloo, 4_194_304);
        let s_single = single.allreduce_us(LinkKind::Gloo, 4_194_304);
        assert_eq!(s_multi, s_single);
    }

    /// Fig. 6: NCCL/gloo speed ratio stabilises around μ for ≥4M params.
    #[test]
    fn fig6_speed_ratio_converges_to_mu() {
        let env = ClusterEnv::paper_testbed();
        for params in [4_194_304u64, 16_777_216, 67_108_864] {
            let n = env.allreduce_us(LinkKind::Nccl, params).as_us() as f64;
            let g = env.allreduce_us(LinkKind::Gloo, params).as_us() as f64;
            let ratio = g / n;
            // Paper Fig. 6 / Table IV: 1.57–1.85 across this size range.
            assert!(
                (1.5..=1.9).contains(&ratio),
                "ratio {ratio} at {params} params"
            );
        }
    }

    #[test]
    fn ring_factor_limits() {
        assert_eq!(ClusterEnv::paper_testbed().with_workers(1).ring_factor(), 0.0);
        let f2 = ClusterEnv::paper_testbed().with_workers(2).ring_factor();
        assert!((f2 - 1.0).abs() < 1e-12);
        let f16 = ClusterEnv::paper_testbed().ring_factor();
        assert!((f16 - 1.875).abs() < 1e-12);
    }

    #[test]
    fn workload_comm_scales_with_bandwidth_and_workers() {
        let base = ClusterEnv::paper_testbed();
        let t40 = base.bucket_comm(LinkKind::Nccl, 10_000_000, 1.8e-3);
        let t20 = base
            .clone()
            .with_bandwidth(20.0)
            .bucket_comm(LinkKind::Nccl, 10_000_000, 1.8e-3);
        // Half bandwidth => double time.
        assert!((t20.as_us() as f64 / t40.as_us() as f64 - 2.0).abs() < 0.01);

        let t2 = base
            .clone()
            .with_workers(2)
            .bucket_comm(LinkKind::Nccl, 10_000_000, 1.8e-3);
        // 2 workers: ring factor 1.0 vs 1.875 => ~0.533×.
        assert!((t2.as_us() as f64 / t40.as_us() as f64 - 0.5333).abs() < 0.01);

        // 1 worker: no communication at all.
        let t1 = base.with_workers(1).bucket_comm(LinkKind::Nccl, 10_000_000, 1.8e-3);
        assert_eq!(t1, Micros::ZERO);
    }

    #[test]
    fn zero_params_free() {
        let env = ClusterEnv::paper_testbed();
        assert_eq!(env.allreduce_us(LinkKind::Nccl, 0), Micros::ZERO);
    }
}
