"""AOT path tests: lowering to HLO text + manifest emission.

Uses a tiny model config so the test stays fast; the emitted HLO must be
valid XLA HLO *text* (the interchange format the Rust runtime parses) and
the manifest must describe exactly the signatures the model exposes.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from compile import aot
from compile import model as M


def tiny_cfg():
    return M.ModelConfig(
        vocab=64, seq=32, d_model=32, n_layers=1, n_heads=2, batch=2, n_buckets=2
    )


def test_to_hlo_text_produces_hlo_module():
    cfg = tiny_cfg()
    sizes = M.bucket_sizes(cfg)
    bspecs = [jax.ShapeDtypeStruct((s,), jnp.float32) for s in sizes]
    tokens = jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.int32)
    lowered = jax.jit(M.make_train_step(cfg)).lower(*bspecs, tokens)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text, "not HLO text"
    assert "ROOT" in text
    # return_tuple=True => the entry computation returns a tuple of
    # 1 loss + n_buckets gradients.
    assert f"f32[{sizes[0]}]" in text


def test_spec_str_format():
    assert aot.spec_str("x", "f32", (4, 5)) == "x:f32:4x5"
    assert aot.spec_str("loss", "f32", ()) == "loss:f32:1"


def test_full_aot_cli_roundtrip(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    res = subprocess.run(
        [
            sys.executable, "-m", "compile.aot",
            "--out", str(out),
            "--d-model", "32", "--n-layers", "1", "--n-heads", "2",
            "--vocab", "64", "--seq", "32", "--batch", "2",
            "--n-buckets", "2", "--workers", "2",
        ],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert res.returncode == 0, res.stderr
    for f in [
        "train_step.hlo.txt",
        "apply_update.hlo.txt",
        "grad_reduce.hlo.txt",
        "manifest.toml",
        "init_b0.bin",
        "init_b1.bin",
    ]:
        assert (out / f).exists(), f"missing {f}"
    manifest = (out / "manifest.toml").read_text()
    assert "n_buckets = 2" in manifest
    assert "[exe.train_step]" in manifest
    assert "[exe.apply_update]" in manifest
    assert "[exe.grad_reduce]" in manifest
    # init files sized as f32 * bucket sizes
    cfg = tiny_cfg()
    sizes = M.bucket_sizes(cfg)
    for i, s in enumerate(sizes):
        assert (out / f"init_b{i}.bin").stat().st_size == 4 * s
