"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py is THE
core correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, bucket_reduce, sgd_update
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------- attention
def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


@pytest.mark.parametrize("b,h,s,d", [(1, 1, 64, 16), (2, 4, 128, 32), (1, 2, 64, 64)])
def test_attention_matches_ref(b, h, s, d):
    q, k, v = (rand(i, (b, h, s, d)) for i in range(3))
    got = attention(q, k, v, True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_attention_non_causal():
    q, k, v = (rand(i + 10, (1, 2, 64, 16)) for i in range(3))
    got = attention(q, k, v, False)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.sampled_from([1, 2, 4]),
    sblk=st.sampled_from([64, 128]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_attention_hypothesis_sweep(b, h, sblk, d, seed):
    q, k, v = (rand(seed + i, (b, h, sblk, d)) for i in range(3))
    got = attention(q, k, v, True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_attention_gradients_match_ref():
    # custom_vjp backward must equal grad of the reference.
    q, k, v = (rand(i + 20, (1, 2, 64, 16)) for i in range(3))

    def f_pallas(q, k, v):
        return (attention(q, k, v, True) ** 2).sum()

    def f_ref(q, k, v):
        return (ref.attention_ref(q, k, v, causal=True) ** 2).sum()

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gp, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


def test_attention_causality():
    # Output at position t must not depend on tokens after t.
    q, k, v = (rand(i + 30, (1, 1, 64, 16)) for i in range(3))
    out1 = attention(q, k, v, True)
    k2 = k.at[:, :, 40:, :].set(123.0)
    v2 = v.at[:, :, 40:, :].set(-7.0)
    out2 = attention(q, k2, v2, True)
    np.testing.assert_allclose(out1[:, :, :40], out2[:, :, :40], rtol=1e-6, atol=1e-6)
    assert not np.allclose(out1[:, :, 40:], out2[:, :, 40:])


# ------------------------------------------------------------- bucket reduce
@settings(max_examples=12, deadline=None)
@given(
    w=st.integers(1, 8),
    n=st.sampled_from([1, 7, 512, 1024, 1025, 5000]),
    seed=st.integers(0, 2**16),
)
def test_bucket_reduce_hypothesis(w, n, seed):
    g = rand(seed, (w, n))
    got = bucket_reduce(g)
    want = ref.bucket_reduce_ref(g)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_bucket_reduce_mean_of_constants():
    g = jnp.stack([jnp.full((100,), 1.0), jnp.full((100,), 3.0)])
    np.testing.assert_allclose(bucket_reduce(g), jnp.full((100,), 2.0))


# ---------------------------------------------------------------- sgd update
@settings(max_examples=12, deadline=None)
@given(
    n=st.sampled_from([1, 3, 1024, 1500, 4096]),
    lr=st.floats(1e-4, 1.0),
    scale=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**16),
)
def test_sgd_update_hypothesis(n, lr, scale, seed):
    p = rand(seed, (n,))
    g = rand(seed + 1, (n,))
    m = rand(seed + 2, (n,))
    lr_a = jnp.asarray([lr], jnp.float32)
    sc_a = jnp.asarray([scale], jnp.float32)
    beta = jnp.asarray([0.9], jnp.float32)
    p2, m2 = sgd_update(p, g, m, lr_a, sc_a, beta)
    pr, mr = ref.sgd_update_ref(p, g, m, lr_a[0], sc_a[0], beta[0])
    np.testing.assert_allclose(p2, pr, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(m2, mr, rtol=1e-6, atol=1e-6)


def test_sgd_update_zero_lr_is_identity_on_params():
    p = rand(1, (256,))
    g = rand(2, (256,))
    m = jnp.zeros((256,))
    p2, m2 = sgd_update(
        p, g, m,
        jnp.asarray([0.0], jnp.float32),
        jnp.asarray([1.0], jnp.float32),
        jnp.asarray([0.9], jnp.float32),
    )
    np.testing.assert_allclose(p2, p, rtol=0, atol=0)
    np.testing.assert_allclose(m2, g, rtol=1e-6, atol=1e-6)
