"""L2 model tests: bucket plumbing, shapes, loss behaviour, training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


CFG = M.ModelConfig(vocab=64, seq=32, d_model=32, n_layers=2, n_heads=2, batch=2, n_buckets=3)


def test_bucket_layout_covers_all_params():
    layout = M.bucket_layout(CFG)
    names = [n for bucket in layout for n, _ in bucket]
    expected = [n for n, _ in M.param_shapes(CFG)]
    assert names == expected, "buckets must cover all tensors in order"
    assert 1 <= len(layout) <= CFG.n_buckets


def test_unflatten_roundtrip():
    sizes = M.bucket_sizes(CFG)
    buckets = [jnp.arange(s, dtype=jnp.float32) for s in sizes]
    params = M.unflatten(CFG, buckets)
    grads = {k: v for k, v in params.items()}
    back = M.flatten_grads(CFG, grads)
    for a, b in zip(buckets, back):
        np.testing.assert_array_equal(a, b)


def test_init_params_match_sizes():
    sizes = M.bucket_sizes(CFG)
    init = M.init_params(CFG)
    assert [v.shape[0] for v in init] == sizes
    # LayerNorm gains initialized to 1 => no all-zero buckets.
    assert all(float(jnp.abs(v).max()) > 0 for v in init)


def test_forward_shapes_and_loss_near_uniform_at_init():
    init = M.init_params(CFG)
    tokens = jax.random.randint(jax.random.PRNGKey(0), (CFG.batch, CFG.seq + 1), 0, CFG.vocab)
    loss = M.loss_fn(CFG, init, tokens)
    uniform = float(jnp.log(CFG.vocab))
    assert 0.5 * uniform < float(loss) < 1.5 * uniform, f"init loss {loss} vs ln(V)={uniform}"


def test_train_step_returns_grads_for_every_bucket():
    step = M.make_train_step(CFG)
    init = M.init_params(CFG)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (CFG.batch, CFG.seq + 1), 0, CFG.vocab)
    out = jax.jit(step)(*init, tokens)
    assert len(out) == 1 + len(init)
    loss, grads = out[0], out[1:]
    assert loss.shape == ()
    for g, p in zip(grads, init):
        assert g.shape == p.shape
        assert float(jnp.abs(g).max()) > 0, "dead gradient bucket"


def test_apply_update_moves_params_against_gradient():
    step = M.make_train_step(CFG)
    upd = M.make_apply_update(CFG)
    init = M.init_params(CFG)
    momenta = [jnp.zeros_like(p) for p in init]
    tokens = jax.random.randint(jax.random.PRNGKey(2), (CFG.batch, CFG.seq + 1), 0, CFG.vocab)
    out = jax.jit(step)(*init, tokens)
    loss0, grads = out[0], list(out[1:])
    lr = jnp.asarray([0.5], jnp.float32)
    scale = jnp.asarray([1.0], jnp.float32)
    res = jax.jit(upd)(*init, *grads, *momenta, lr, scale)
    k = len(init)
    new_params, new_momenta = list(res[:k]), list(res[k:])
    loss1 = M.loss_fn(CFG, new_params, tokens)
    assert float(loss1) < float(loss0), f"update did not reduce loss: {loss0} -> {loss1}"
    assert any(float(jnp.abs(m).max()) > 0 for m in new_momenta)


def test_short_training_reduces_loss():
    # 12 full-batch steps on a fixed batch must fit it substantially.
    step = jax.jit(M.make_train_step(CFG))
    upd = jax.jit(M.make_apply_update(CFG))
    params = M.init_params(CFG)
    momenta = [jnp.zeros_like(p) for p in params]
    tokens = jax.random.randint(jax.random.PRNGKey(3), (CFG.batch, CFG.seq + 1), 0, CFG.vocab)
    lr = jnp.asarray([0.3], jnp.float32)
    scale = jnp.asarray([1.0], jnp.float32)
    first = None
    last = None
    for _ in range(12):
        out = step(*params, tokens)
        loss, grads = out[0], list(out[1:])
        if first is None:
            first = float(loss)
        last = float(loss)
        res = upd(*params, *grads, *momenta, lr, scale)
        k = len(params)
        params, momenta = list(res[:k]), list(res[k:])
    assert last < 0.7 * first, f"loss {first} -> {last}"


def test_grad_reduce_matches_numpy():
    gr = M.make_grad_reduce(CFG, workers=3)
    sizes = M.bucket_sizes(CFG)
    stacked = [
        jax.random.normal(jax.random.PRNGKey(i), (3, s), jnp.float32)
        for i, s in enumerate(sizes)
    ]
    out = jax.jit(gr)(*stacked)
    for o, s in zip(out, stacked):
        np.testing.assert_allclose(o, np.asarray(s).mean(axis=0), rtol=1e-6, atol=1e-6)


def test_scale_implements_gradient_accumulation():
    # Applying the sum of two grads with scale=1/2 == applying their mean.
    upd = M.make_apply_update(CFG)
    params = M.init_params(CFG)
    momenta = [jnp.zeros_like(p) for p in params]
    g1 = [jnp.ones_like(p) for p in params]
    g2 = [3.0 * jnp.ones_like(p) for p in params]
    acc = [a + b for a, b in zip(g1, g2)]
    mean = [(a + b) / 2 for a, b in zip(g1, g2)]
    lr = jnp.asarray([0.1], jnp.float32)
    k = len(params)
    res_a = jax.jit(upd)(*params, *acc, *momenta, lr, jnp.asarray([0.5], jnp.float32))
    res_b = jax.jit(upd)(*params, *mean, *momenta, lr, jnp.asarray([1.0], jnp.float32))
    for a, b in zip(res_a[:k], res_b[:k]):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
