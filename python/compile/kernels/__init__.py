"""L1 Pallas kernels + pure-jnp reference oracles."""

from .attention import attention
from .bucket_reduce import bucket_reduce
from .sgd_update import sgd_update

__all__ = ["attention", "bucket_reduce", "sgd_update"]
